# Repo-level entry points. `make verify` is the tier-1 gate every PR must
# keep green (see ROADMAP.md); `make ci` adds formatting and compile gates.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: verify build test benches bench-smoke bench-json replay-smoke shard-smoke arm-smoke exclusivity-smoke net-smoke obs-smoke perf-smoke audit-smoke examples fmt fmt-check artifacts ci clean

verify: ## tier-1 gate: release build + full test suite
	$(CARGO) build --release
	$(CARGO) test -q

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Compile every bench binary without running it (fast structural gate).
benches:
	$(CARGO) bench --no-run

# Run every bench binary on its --smoke fast path (seconds, not minutes).
bench-smoke:
	$(CARGO) bench --bench ablations -- --smoke
	$(CARGO) bench --bench algo_runtimes -- --smoke
	$(CARGO) bench --bench coordinator -- --smoke
	$(CARGO) bench --bench profiles -- --smoke
	$(CARGO) bench --bench bench_json -- --smoke
	$(CARGO) bench --bench replay -- --smoke
	$(CARGO) bench --bench runtime_xla -- --smoke

# Machine-readable benchmark summary: the four load-bearing throughput
# numbers (dense wavefront ns/op, replay events/s, coordinator submits/s,
# loopback RPC submits/s) as one JSON document. The bench binary runs
# with the crate directory as its working directory, so the artifact
# lands in rust/.
bench-json:
	$(CARGO) bench --bench bench_json
	@echo "bench-json: rust/BENCH_replay.json"

# Seeded 2-second virtual replay across two policies; the QoS JSON lands in
# results/ (byte-identical for a fixed seed — diff two runs to check).
replay-smoke: build
	mkdir -p results
	./target/release/tapesched replay --arrivals poisson --rate 50 --duration 2 \
		--policy GS,SimpleDP --seed 7 --tapes 12 --out results/replay-smoke.json
	@echo "replay-smoke: results/replay-smoke.json"

# Sharded replay gate: 4 libraries behind the consistent-hash router (the
# --smoke preset: 2 virtual seconds at 100 rps over 48 tapes); the QoS JSON
# with its per-shard breakdown lands in results/ (byte-identical for a
# fixed seed).
shard-smoke: build
	mkdir -p results
	./target/release/tapesched replay --shards 4 --smoke --seed 7 \
		--out results/shard-smoke.json
	@echo "shard-smoke: results/shard-smoke.json"

# Mount-pipeline gate: (a) `--arms 0 --affinity none` must be byte-identical
# to the same replay without the flags — the legacy fixed mount-cost path —
# and (b) one robot arm with LRU affinity on the bursty workload must show
# remount hits, an arm-dominated tail (arm-wait p99 ≥ drive-wait p99), and a
# strictly worse latency p99.9 than the unconstrained robot (the assertion
# script lives in scripts/ci.sh; this target reproduces the artifacts).
arm-smoke: build
	mkdir -p results
	./target/release/tapesched replay --shards 4 --smoke --seed 7 \
		--exclusive-tapes off --out results/arm-legacy-default.json
	./target/release/tapesched replay --shards 4 --smoke --seed 7 \
		--exclusive-tapes off --arms 0 --affinity none --out results/arm-legacy-flags.json
	cmp results/arm-legacy-default.json results/arm-legacy-flags.json
	./target/release/tapesched replay --arrivals bursty --rate 0.1 --duration 600 \
		--tapes 4 --drives 128 --max-batch 1 --seed 7 --exclusive-tapes off \
		--out results/arm-base.json
	./target/release/tapesched replay --arrivals bursty --rate 0.1 --duration 600 \
		--tapes 4 --drives 128 --max-batch 1 --seed 7 --exclusive-tapes off \
		--arms 1 --affinity lru --out results/arm-smoke.json
	@echo "arm-smoke: results/arm-smoke.json (legacy bytes verified via cmp)"

# Cartridge-exclusivity gate: a hot-tape workload (one tape, 8 drives,
# singleton batches) run with the single-cartridge constraint on vs off —
# the exclusive run must show nonzero cartridge_wait and a strictly worse
# p99.9 (the assertion script lives in scripts/ci.sh; this target
# reproduces the artifacts).
exclusivity-smoke: build
	mkdir -p results
	./target/release/tapesched replay --arrivals poisson --rate 2 --duration 30 \
		--tapes 1 --drives 8 --max-batch 1 --seed 7 --exclusive-tapes off \
		--out results/exclusivity-base.json
	./target/release/tapesched replay --arrivals poisson --rate 2 --duration 30 \
		--tapes 1 --drives 8 --max-batch 1 --seed 7 \
		--out results/exclusivity-smoke.json
	@echo "exclusivity-smoke: results/exclusivity-smoke.json (vs exclusivity-base.json)"

# Networked-cluster gate: the same seeded request stream through the
# in-process Cluster and through a loopback coordinator/worker fleet must
# agree on every virtual-time number (counters and tour costs identical;
# only wall-clock latency — the RPC tax — may differ), and a worker cut
# mid-stream must leave the fleet-wide drain invariant
# `submitted = completed + shed` intact (the assertion script lives in
# scripts/ci.sh; this target reproduces the artifacts).
net-smoke: build
	mkdir -p results
	./target/release/tapesched rpc-tax --policy GS,SimpleDP --requests 240 \
		--seed 7 --out results/rpc-tax.json
	./target/release/tapesched rpc-tax --policy GS --requests 120 --seed 7 \
		--kill-after 1 --out results/rpc-tax-kill.json
	@echo "net-smoke: results/rpc-tax.json (vs rpc-tax-kill.json)"

# Observability gate: a traced replay must emit a span stream whose
# request chains check out (`spans --check` renders the per-stage
# breakdown), tracing must not move a byte of the QoS JSON, and the
# push-metrics rpc-tax run must beat the pull-mode closed loop on
# submits/s (the assertion script lives in scripts/ci.sh; this target
# reproduces the artifacts).
obs-smoke: build
	mkdir -p results
	./target/release/tapesched replay --shards 4 --smoke --seed 7 \
		--out results/obs-replay-plain.json
	./target/release/tapesched replay --shards 4 --smoke --seed 7 \
		--trace-out results/obs-trace.jsonl --out results/obs-replay.json
	cmp results/obs-replay-plain.json results/obs-replay.json
	./target/release/tapesched spans --in results/obs-trace.jsonl --check
	./target/release/tapesched rpc-tax --policy GS --requests 240 --seed 7 \
		--push-metrics --out results/rpc-tax-push.json
	@echo "obs-smoke: results/obs-trace.jsonl (chains checked), results/rpc-tax-push.json"

# Raw-speed gate: (a) the same sharded smoke replay single-threaded and
# over 4 worker threads — the parallel merge contract is byte-identity,
# checked with cmp; (b) the skewed 9-shard ring over 3 workers, with and
# without --steal — LPT assignment and epoch stealing move shard
# ownership, never bytes; (c) `serve --backend incremental` must finish
# the smoke workload with nonzero table appends and the drain invariant
# `submitted = completed + shed` intact (the full property gates live in
# scripts/ci.sh; this target reproduces the determinism artifacts).
perf-smoke: build
	mkdir -p results
	./target/release/tapesched replay --shards 4 --smoke --seed 7 \
		--threads 1 --out results/perf-threads1.json
	./target/release/tapesched replay --shards 4 --smoke --seed 7 \
		--threads 4 --out results/perf-threads4.json
	cmp results/perf-threads1.json results/perf-threads4.json
	./target/release/tapesched replay --shards 9 --smoke --seed 7 \
		--threads 1 --out results/perf-skew1.json
	./target/release/tapesched replay --shards 9 --smoke --seed 7 \
		--threads 3 --out results/perf-skew3.json
	./target/release/tapesched replay --shards 9 --smoke --seed 7 \
		--threads 3 --steal --out results/perf-skew3-steal.json
	cmp results/perf-skew1.json results/perf-skew3.json
	cmp results/perf-skew1.json results/perf-skew3-steal.json
	./target/release/tapesched serve --requests 400 --seed 7 \
		--backend incremental | tee results/perf-incremental.txt
	@grep -Eq 'incremental appends/rebuilds = [1-9][0-9]* /' \
		results/perf-incremental.txt \
		|| { echo "perf-smoke: no incremental appends recorded" >&2; exit 1; }
	@awk '/drain submitted\/completed\/shed/ { seen = 1; if ($$4 != $$6 + $$8) bad = 1 } \
		END { exit (bad || !seen) }' results/perf-incremental.txt \
		|| { echo "perf-smoke: drain invariant violated or missing" >&2; exit 1; }
	@echo "perf-smoke: parallel replay byte-stable (4 + 9 shards, steal on/off); incremental serve OK"

# Determinism & invariant lint: the shipped tree must audit clean — zero
# findings, zero unused waivers (rules and waiver syntax: rust/README.md,
# "Static analysis"). Exit 1 on any finding.
audit-smoke: build
	./target/release/tapesched audit rust/src
	@echo "audit-smoke: rust/src audits clean"

examples:
	$(CARGO) build --examples

fmt:
	$(CARGO) fmt --all

fmt-check:
	$(CARGO) fmt --all -- --check

# AOT-compile the SimpleDP shape-bucket artifacts consumed by the `xla`
# backend (requires jax; see python/compile/aot.py).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts

ci:
	bash scripts/ci.sh

clean:
	$(CARGO) clean
	rm -rf results bench_*.csv
