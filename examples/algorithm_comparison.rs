//! Figure 14/15/16 in miniature: performance profiles of every algorithm
//! over the calibrated dataset at the paper's three U values, printed as
//! ASCII tables (full CSVs come from `tapesched figures`).
//!
//! ```sh
//! cargo run --release --example algorithm_comparison [-- <n_tapes> <max_k>]
//! ```

use tapesched::analysis::profile::curves_ascii;
use tapesched::analysis::report::run_evaluation;
use tapesched::dataset::{generate_dataset, GeneratorConfig};
use tapesched::sched::paper_schedulers;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_tapes: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(40);
    let max_k: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(60);

    let ds = generate_dataset(&GeneratorConfig { n_tapes, ..Default::default() });
    let [u0, u_half, u_avg] = ds.paper_u_values();
    let schedulers = paper_schedulers();
    let taus = [0.0, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0];

    for (figure, u) in [("Fig 14 (U = 0)", u0), ("Fig 16 (U = avg/2)", u_half), ("Fig 15 (U = avg)", u_avg)] {
        eprintln!("evaluating {} tapes at U = {u}…", n_tapes);
        let table = run_evaluation(&ds, &schedulers, u, Some(max_k));
        let curves = table.profiles("DP");
        println!("\n=== {figure} — fraction of instances within τ of optimal ===");
        print!("{}", curves_ascii(&curves, &taus));
        println!("median time-to-solution:");
        let mut times = table.median_times();
        times.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        for (algo, t) in times {
            println!("  {algo:<12} {:>10}", tapesched::bench::fmt_seconds(t));
        }
    }

    println!(
        "\nExpected shape (paper §5.3): SimpleDP ≻ LogDP(5) ≻ LogDP(1) ≳ NFGS ≈ FGS ≻ GS ≻ NoDetour,\n\
         with the DP-family advantage widening as U grows."
    );
}
