//! Appendix C end-to-end: raw activity log → filtering pipeline → LTSP
//! instances → schedules.
//!
//! Reproduces the paper's data engineering as running code: a synthetic
//! raw log (reads mixed with writes/updates, aggregates, cross-segment
//! aggregates) goes through the documented filtering steps and comes out
//! as per-tape LTSP instances that the schedulers then solve.
//!
//! ```sh
//! cargo run --release --example rawlog_pipeline
//! ```

use std::collections::BTreeMap;

use tapesched::dataset::{filter_raw_log, synth_catalog, synth_raw_log};
use tapesched::sched::{Gs, Scheduler, SimpleDp};
use tapesched::sim::evaluate;

fn main() {
    // A small library: 12 tapes with aggregates (~30 % of segments,
    // some spanning across segments like the paper's discarded cases).
    let mut catalogs = BTreeMap::new();
    for i in 0..12 {
        let name = format!("TAPE{:03}", i + 1);
        catalogs.insert(name.clone(), synth_catalog(&name, 200 + 40 * i as usize, i));
    }

    // Two weeks of raw activity.
    let log = synth_raw_log(&catalogs, 200_000, 14 * 86_400, 0xC1A0);
    println!("raw log: {} lines over 14 days on {} tapes", log.len(), catalogs.len());

    let (tapes, stats) = filter_raw_log(&log, &catalogs);
    println!("\nfiltering pipeline (Appendix C.1):");
    println!("  total lines          {}", stats.lines_total);
    println!("  non-read dropped     {}", stats.lines_non_read);
    println!("  cross-segment aggr.  {}", stats.lines_cross_segment);
    println!("  kept                 {}", stats.lines_kept);
    println!("  → unique requested files {}", stats.unique_requests);
    println!("  → total user requests    {}", stats.total_requests);

    println!("\nper-tape LTSP instances and schedules (U = 0):");
    println!(
        "{:<10} {:>6} {:>7} {:>8} {:>18} {:>18} {:>8}",
        "tape", "n_req", "n", "detours", "SimpleDP cost", "GS cost", "gain"
    );
    let mut total_sdp: i128 = 0;
    let mut total_gs: i128 = 0;
    for t in &tapes {
        let inst = t.instance(0).expect("pipeline output is valid");
        let sdp_sched = SimpleDp.schedule(&inst);
        let sdp = evaluate(&inst, &sdp_sched).cost;
        let gs = evaluate(&inst, &Gs.schedule(&inst)).cost;
        total_sdp += sdp;
        total_gs += gs;
        println!(
            "{:<10} {:>6} {:>7} {:>8} {:>18} {:>18} {:>7.2}%",
            t.tape.name,
            inst.k(),
            inst.n(),
            sdp_sched.len(),
            sdp,
            gs,
            (gs - sdp) as f64 / gs as f64 * 100.0
        );
    }
    println!(
        "\nSimpleDP total Σ service time is {:.2}% below GS across the pipeline output.",
        (total_gs - total_sdp) as f64 / total_gs as f64 * 100.0
    );
}
