//! Quickstart: build an LTSP instance, solve it with every algorithm of
//! the paper, and inspect the optimal head trajectory.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tapesched::model::{virtual_lb, Instance, ReqFile};
use tapesched::sched::{paper_schedulers, Dp, Scheduler};
use tapesched::sim::{evaluate, trajectory};

fn main() {
    // A toy tape, 1 GB long (positions in bytes). Five requested files:
    // the hot pair far on the right is what detours are made for.
    let inst = Instance::new(
        1_000_000_000,
        2_000_000, // U-turn penalty worth 2 MB of travel
        vec![
            ReqFile { l: 10_000_000, r: 60_000_000, x: 1 },
            ReqFile { l: 200_000_000, r: 210_000_000, x: 3 },
            ReqFile { l: 650_000_000, r: 655_000_000, x: 40 }, // hot
            ReqFile { l: 655_000_000, r: 662_000_000, x: 25 }, // hot
            ReqFile { l: 900_000_000, r: 950_000_000, x: 2 },
        ],
    )
    .expect("valid instance");

    println!(
        "Instance: {} requested files, {} requests, VirtualLB = {}",
        inst.k(),
        inst.n(),
        virtual_lb(&inst)
    );
    println!();
    println!("{:<12} {:>20} {:>12} {:>10}", "algorithm", "Σ service time", "vs optimal", "detours");

    let opt = evaluate(&inst, &Dp.schedule(&inst)).cost;
    for algo in paper_schedulers() {
        let schedule = algo.schedule(&inst);
        let out = evaluate(&inst, &schedule);
        println!(
            "{:<12} {:>20} {:>11.2}% {:>10}",
            algo.name(),
            out.cost,
            (out.cost - opt) as f64 / opt as f64 * 100.0,
            schedule.len()
        );
    }

    // The optimal trajectory, as the head-position polyline.
    let schedule = Dp.schedule(&inst);
    println!("\nOptimal schedule (detours over requested-file indices): {schedule:?}");
    println!("Head trajectory (time, position), megabyte units:");
    for seg in trajectory::polyline(&inst, &schedule) {
        if seg.from == seg.to {
            println!("  t={:>7.1} U-turn at {:>7.1}", seg.t0 as f64 / 1e6, seg.from as f64 / 1e6);
        } else {
            println!(
                "  t={:>7.1} move {:>7.1} -> {:>7.1}",
                seg.t0 as f64 / 1e6,
                seg.from as f64 / 1e6,
                seg.to as f64 / 1e6
            );
        }
    }

    let out = evaluate(&inst, &schedule);
    println!("\nPer-file service times (MB units):");
    for f in 0..inst.k() {
        println!(
            "  file {f} [{:>6.1}, {:>6.1}) x{:<3} served at t={:.1}",
            inst.l(f) as f64 / 1e6,
            inst.r(f) as f64 / 1e6,
            inst.x(f),
            out.service[f] as f64 / 1e6
        );
    }
    println!("\nmean service time = {:.2} MB-units", out.mean_service_time(&inst) / 1e6);
}
