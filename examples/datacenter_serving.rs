//! End-to-end serving driver (E10): the full system on a realistic
//! workload — the headline QoS claim of the paper, measured on the
//! whole stack.
//!
//! Pipeline: the calibrated IN2P3-like dataset → the coordinator service
//! (router → per-tape batcher → drive worker pool) → per-request
//! latencies, once per scheduling policy. The paper's claim is that the
//! DP family lowers the *average service time* experienced by users over
//! the greedy heuristics the field actually deploys; here that claim is
//! exercised through the serving runtime rather than on bare instances.
//!
//! ```sh
//! cargo run --release --example datacenter_serving [-- <requests> <drives>]
//! ```

use std::sync::Arc;

use tapesched::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, ReadRequest};
use tapesched::dataset::{generate_dataset, GeneratorConfig};
use tapesched::sched::scheduler_by_name;
use tapesched::sim::DriveParams;
use tapesched::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let n_drives: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);

    // A scaled-down library (full 169-tape dataset, fewer drives than the
    // real 48 so queueing effects show at this request volume).
    let ds = generate_dataset(&GeneratorConfig::default());
    println!(
        "library: {} tapes, {} files; {n_drives} drives; {n_requests} requests\n",
        ds.tapes.len(),
        ds.total_files(),
    );

    // The same arrival trace for every policy: hot tapes + hot files, the
    // access skew a real MSMS sees.
    let mut trace = Vec::with_capacity(n_requests as usize);
    let mut rng = Rng::new(0xC0FFEE);
    for id in 0..n_requests {
        let tape_rank = rng.zipf(ds.tapes.len() as u64, 1.1) as usize - 1;
        let t = &ds.tapes[tape_rank];
        let file_rank = rng.zipf(t.tape.n_files() as u64, 1.05) as usize - 1;
        trace.push((id, t.tape.name.clone(), file_rank));
    }

    println!(
        "{:<12} {:>10} {:>14} {:>14} {:>14} {:>12}",
        "policy", "batches", "mean svc (s)", "mean lat (s)", "p99 lat (s)", "sched s/b"
    );

    let mut baseline_svc = None;
    for policy_name in ["NoDetour", "GS", "FGS", "NFGS", "LogDP(1)", "SimpleDP"] {
        let policy = scheduler_by_name(policy_name).expect("known policy");
        let coord = Coordinator::start(
            CoordinatorConfig {
                n_drives,
                batcher: BatcherConfig {
                    window: std::time::Duration::from_millis(20),
                    max_batch: 512,
                    ..BatcherConfig::default()
                },
                drive: DriveParams::default(),
                ..CoordinatorConfig::default()
            },
            ds.tapes.iter().map(|t| t.tape.clone()),
            Arc::from(policy),
        );
        for (id, tape, file) in &trace {
            assert!(
                coord
                    .submit(ReadRequest { id: *id, tape: tape.clone(), file_index: *file })
                    .is_ok(),
                "trace request must be routable"
            );
        }
        let (completions, m) = coord.finish();
        assert_eq!(completions.len() as u64, n_requests, "no request lost");
        println!(
            "{:<12} {:>10} {:>14.1} {:>14.1} {:>14.1} {:>12.4}",
            policy_name,
            m.batches,
            m.mean_service_s,
            m.mean_latency_s,
            m.p99_latency_s,
            m.mean_sched_s_per_batch
        );
        if policy_name == "GS" {
            baseline_svc = Some(m.mean_service_s);
        } else if policy_name == "SimpleDP" {
            if let Some(gs) = baseline_svc {
                println!(
                    "\nSimpleDP vs GS: mean in-tape service time {:.1}% lower",
                    (gs - m.mean_service_s) / gs * 100.0
                );
            }
        }
    }
}
