//! The paper's adversarial constructions (§4.5, Lemma 2), swept over the
//! size parameter z: watch LogDP's ratio climb toward 3 and SimpleDP's
//! toward 5/3 while DP stays optimal.
//!
//! ```sh
//! cargo run --release --example adversarial_instances
//! ```

use tapesched::model::adversarial::{logdp_worst_case, simpledp_five_thirds};
use tapesched::sched::{Dp, Gs, LogDp, Scheduler, SimpleDp};
use tapesched::sim::evaluate;

fn main() {
    println!("=== §4.5: LogDP(1) worst case — ratio → 3 as z → ∞ (U = 0) ===");
    println!("{:>4} {:>16} {:>16} {:>9} {:>16} {:>9}", "z", "OPT", "LogDP(1)", "ratio", "GS", "ratio");
    for z in [8u64, 16, 32, 64, 96] {
        let inst = logdp_worst_case(z);
        let opt = evaluate(&inst, &Dp.schedule(&inst)).cost;
        let log = evaluate(&inst, &LogDp::new(1.0).schedule(&inst)).cost;
        let gs = evaluate(&inst, &Gs.schedule(&inst)).cost;
        println!(
            "{z:>4} {opt:>16} {log:>16} {:>9.4} {gs:>16} {:>9.4}",
            log as f64 / opt as f64,
            gs as f64 / opt as f64
        );
    }

    println!("\n=== Lemma 2: SimpleDP lower bound — ratio → 5/3 ≈ 1.667 ===");
    println!("{:>4} {:>16} {:>16} {:>9}", "z", "OPT", "SimpleDP", "ratio");
    for z in [5u64, 10, 20, 40, 80, 160] {
        let inst = simpledp_five_thirds(z);
        let opt = evaluate(&inst, &Dp.schedule(&inst)).cost;
        let sdp = evaluate(&inst, &SimpleDp.schedule(&inst)).cost;
        println!("{z:>4} {opt:>16} {sdp:>16} {:>9.4}", sdp as f64 / opt as f64);
    }

    println!("\n=== The optimal intertwined structure SimpleDP cannot express ===");
    let inst = simpledp_five_thirds(20);
    println!("DP       : {:?}", Dp.schedule(&inst));
    println!("SimpleDP : {:?}", SimpleDp.schedule(&inst));
    println!(
        "DP reads f3 alone first, then rides f2→f4 over the already-read f3 — \n\
         detour intervals overlap. SimpleDP must pick disjoint intervals and \n\
         pays the 5/3 factor."
    );
}
