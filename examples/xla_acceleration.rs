//! The three-layer stack in action: run the AOT-compiled SimpleDP
//! evaluation engine (Pallas kernel → JAX scan → HLO text → PJRT) from
//! Rust and cross-validate it against the exact i128 implementation.
//!
//! Requires `make artifacts` (skips gracefully otherwise).
//!
//! ```sh
//! make artifacts && cargo run --release --example xla_acceleration
//! ```

use tapesched::runtime::{XlaSimpleDp, ARTIFACT_DIR};
use tapesched::sched::simpledp_dense::dense_cost;
use tapesched::sched::{Scheduler, SimpleDp};
use tapesched::sim::evaluate;
use tapesched::testkit::{random_instance, InstanceGenConfig};
use tapesched::util::rng::Rng;

fn main() {
    let backend = match XlaSimpleDp::new(ARTIFACT_DIR) {
        Ok(b) if !b.buckets().is_empty() => b,
        _ => {
            eprintln!("no artifacts found — run `make artifacts` first");
            std::process::exit(0);
        }
    };
    println!("PJRT buckets available: {:?}\n", backend.buckets());

    let mut rng = Rng::new(2024);
    let cfg = InstanceGenConfig {
        min_files: 3,
        max_files: 14,
        max_size: 40,
        max_gap: 25,
        max_x: 7,
        max_u: 30,
        ..Default::default()
    };

    println!(
        "{:>4} {:>3} {:>5} {:>16} {:>16} {:>16}  agree",
        "case", "k", "n", "exact i128", "XLA f64", "schedule cost"
    );
    let mut all_agree = true;
    for case in 0..20 {
        let inst = random_instance(&mut rng, &cfg);
        let exact = dense_cost(&inst);
        let xla = backend.cost(&inst).expect("instance fits a bucket");
        let sched = backend.schedule(&inst);
        let achieved = evaluate(&inst, &sched).cost;
        let rust_sched_cost = evaluate(&inst, &SimpleDp.schedule(&inst)).cost;
        let ok = xla == exact && achieved == rust_sched_cost;
        all_agree &= ok;
        println!(
            "{case:>4} {:>3} {:>5} {exact:>16} {xla:>16} {achieved:>16}  {}",
            inst.k(),
            inst.n(),
            if ok { "✓" } else { "✗ MISMATCH" }
        );
    }
    assert!(all_agree, "XLA backend must agree with the exact implementation");
    println!("\nall 20 random instances agree bit-for-bit after rounding — L1/L2/L3 compose.");
}
