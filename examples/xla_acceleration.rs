//! The pluggable SimpleDP backend layer in action: cross-validate every
//! available evaluation backend against the exact sparse solver.
//!
//! In a default build the only backend is the pure-Rust dense wavefront.
//! With `--features xla` (and `make artifacts`) the PJRT engine joins the
//! comparison: Pallas kernel → JAX scan → HLO text → PJRT, cross-validated
//! against the exact `i128` implementation, bit-for-bit after rounding.
//!
//! ```sh
//! cargo run --release --example xla_acceleration
//! make artifacts && cargo run --release --features xla --example xla_acceleration
//! ```

use tapesched::runtime::{available_backends, backend_by_name, BackendPolicy, SimpleDpBackend};
use tapesched::sched::{Scheduler, SimpleDp};
use tapesched::sim::evaluate;
use tapesched::testkit::{random_instance, InstanceGenConfig};
use tapesched::util::rng::Rng;

fn main() {
    let backends = available_backends();
    println!(
        "SimpleDP backends available: {}",
        backends.iter().map(|b| b.id()).collect::<Vec<_>>().join(", ")
    );
    if let Err(e) = backend_by_name("xla") {
        println!("({e})");
    }
    println!();

    let mut rng = Rng::new(2024);
    let cfg = InstanceGenConfig {
        min_files: 3,
        max_files: 14,
        max_size: 40,
        max_gap: 25,
        max_x: 7,
        max_u: 30,
    };

    println!(
        "{:>4} {:>3} {:>5} {:>16} {}",
        "case",
        "k",
        "n",
        "exact sparse",
        backends
            .iter()
            .map(|b| format!("{:>16}", b.id()))
            .collect::<Vec<_>>()
            .join(" ")
    );
    let mut all_agree = true;
    for case in 0..20 {
        let inst = random_instance(&mut rng, &cfg);
        let sparse = SimpleDp::cost(&inst);
        let mut row = format!(
            "{case:>4} {:>3} {:>5} {sparse:>16}",
            inst.k(),
            inst.n()
        );
        let mut ok = true;
        for b in &backends {
            let cost = b.opt_cost(&inst);
            let achieved = evaluate(&inst, &b.opt_schedule(&inst)).cost;
            ok &= cost == sparse && achieved == sparse;
            row.push_str(&format!(" {cost:>16}"));
        }
        all_agree &= ok;
        println!("{row}  {}", if ok { "✓" } else { "✗ MISMATCH" });
    }
    assert!(all_agree, "every backend must agree with the exact sparse solver");

    // Any backend doubles as a coordinator/CLI policy via the adapter.
    let policy = BackendPolicy::new(backends[0].clone());
    let inst = random_instance(&mut rng, &cfg);
    let sched = policy.schedule(&inst);
    println!(
        "\npolicy {} schedules {} detours at cost {} — backends compose with the \
         serving layer unchanged.",
        policy.name(),
        sched.len(),
        evaluate(&inst, &sched).cost
    );
}
