//! Offline API stub for the PJRT/XLA bindings.
//!
//! The offline crate registry cannot supply the real `xla` crate, so this
//! in-tree stand-in carries the exact API subset `tapesched`'s runtime
//! layer consumes. It lets `cargo build --features xla` type-check (and
//! link) with no registry access. At runtime every operation that would
//! need a real PJRT client fails with [`Error::Unimplemented`], which the
//! runtime layer treats like "no artifacts": callers fall back to the pure
//! Rust SimpleDP path and tests skip.
//!
//! To execute AOT artifacts for real, point the `xla` path dependency in
//! `rust/Cargo.toml` at actual PJRT bindings exposing this same surface
//! (client construction, HLO-text parsing, compile, execute, literal
//! conversion).

use std::fmt;

/// Errors surfaced by the (stubbed) XLA layer.
#[derive(Debug)]
pub enum Error {
    /// The stub cannot perform this operation; a real PJRT binding is
    /// required.
    Unimplemented(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unimplemented(what) => {
                write!(f, "{what} requires real PJRT bindings (offline xla stub)")
            }
        }
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real bindings' signatures.
pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle. The stub constructs (so artifact discovery and
/// graceful-fallback paths run) but cannot compile or execute.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// CPU client. Always succeeds in the stub so that backends can be
    /// constructed and report "no artifacts" instead of hard-failing.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    /// Platform name for diagnostics.
    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unimplemented("PjRtClient::compile"))
    }
}

/// Parsed HLO module.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unimplemented("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute on literal arguments, returning per-device output buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unimplemented("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer holding one executable output.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unimplemented("PjRtBuffer::to_literal_sync"))
    }
}

/// A host-side tensor literal.
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1(_data: &[f64]) -> Literal {
        Literal { _priv: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unimplemented("Literal::reshape"))
    }

    /// Unwrap a 1-tuple literal.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::Unimplemented("Literal::to_tuple1"))
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unimplemented("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_cannot_compile() {
        let client = PjRtClient::cpu().expect("stub client always constructs");
        assert_eq!(client.platform_name(), "stub-cpu");
        let comp = XlaComputation::from_proto(&HloModuleProto { _priv: () });
        assert!(client.compile(&comp).is_err());
    }

    #[test]
    fn errors_display_their_origin() {
        let e = Error::Unimplemented("Literal::to_vec");
        assert!(e.to_string().contains("Literal::to_vec"));
        assert!(e.to_string().contains("stub"));
    }
}
