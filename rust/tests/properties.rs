//! Cross-module property tests: the paper's theorems as executable
//! invariants over hundreds of random instances.

use tapesched::model::{virtual_lb, Instance};
use tapesched::sched::{
    is_strictly_laminar, BruteForce, Dp, Fgs, Gs, LogDp, LogNfgs, Nfgs, NoDetour, Scheduler,
    SimpleDp,
};
use tapesched::sched::simpledp_dense::{dense_cost, dense_table, reconstruct};
use tapesched::sim::{evaluate, trajectory};
use tapesched::testkit::{check_cases, InstanceGenConfig};

fn tiny() -> InstanceGenConfig {
    InstanceGenConfig { min_files: 1, max_files: 5, ..Default::default() }
}

fn small() -> InstanceGenConfig {
    InstanceGenConfig { min_files: 1, max_files: 10, ..Default::default() }
}

/// Theorem 1: DP is exact — equal to exhaustive search (k ≤ 5).
#[test]
fn dp_equals_bruteforce() {
    check_cases(0xD9, 120, &tiny(), |inst| {
        let dp = evaluate(inst, &Dp.schedule(inst)).cost;
        let bf = evaluate(inst, &BruteForce::default().schedule(inst)).cost;
        assert_eq!(dp, bf, "DP must match exhaustive search");
    });
}

/// Optimality: DP ≤ every other algorithm, and ≥ VirtualLB.
#[test]
fn dp_dominates_every_policy() {
    check_cases(0xA1, 150, &small(), |inst| {
        let opt = evaluate(inst, &Dp.schedule(inst)).cost;
        assert!(opt >= virtual_lb(inst), "OPT >= VirtualLB");
        let others: Vec<Box<dyn Scheduler>> = vec![
            Box::new(NoDetour),
            Box::new(Gs),
            Box::new(Fgs),
            Box::new(Nfgs),
            Box::new(LogNfgs::new(1.0)),
            Box::new(LogDp::new(1.0)),
            Box::new(LogDp::new(5.0)),
            Box::new(SimpleDp),
        ];
        for s in others {
            let c = evaluate(inst, &s.schedule(inst)).cost;
            assert!(opt <= c, "DP {opt} must be <= {} {c}", s.name());
        }
    });
}

/// DP's internal accounting: predicted cell value + VirtualLB equals the
/// simulated cost of the reconstructed schedule (Theorem 1's identity).
#[test]
fn dp_cost_identity() {
    check_cases(0xB2, 150, &small(), |inst| {
        let predicted = Dp::optimal_cost(inst);
        let sched = Dp.schedule(inst);
        assert_eq!(predicted, evaluate(inst, &sched).cost);
        assert!(is_strictly_laminar(&sched));
    });
}

/// GS is a 3-approximation when U = 0 (Cardonha & Real, via Lemma 2 logic).
#[test]
fn gs_three_approx_without_penalty() {
    let cfg = InstanceGenConfig { max_u: 0, ..small() };
    check_cases(0xC3, 150, &cfg, |inst| {
        let opt = evaluate(inst, &Dp.schedule(inst)).cost;
        let gs = evaluate(inst, &Gs.schedule(inst)).cost;
        assert!(gs <= 3 * opt, "GS {gs} <= 3*OPT {}", 3 * opt);
    });
}

/// Lemma 2: SimpleDP ≤ 3·OPT for ANY U.
#[test]
fn simpledp_three_approx_any_penalty() {
    check_cases(0xD4, 150, &small(), |inst| {
        let opt = evaluate(inst, &Dp.schedule(inst)).cost;
        let sdp = evaluate(inst, &SimpleDp.schedule(inst)).cost;
        assert!(sdp <= 3 * opt, "SimpleDP {sdp} <= 3*OPT {}", 3 * opt);
    });
}

/// LogDP's search space contains GS (all atomic detours) when U = 0, so
/// LogDP ≤ GS; same for SimpleDP at any U.
#[test]
fn dp_variants_not_worse_than_gs() {
    let cfg = InstanceGenConfig { max_u: 0, ..small() };
    check_cases(0xE5, 120, &cfg, |inst| {
        let gs = evaluate(inst, &Gs.schedule(inst)).cost;
        for lambda in [1.0, 5.0] {
            let c = evaluate(inst, &LogDp::new(lambda).schedule(inst)).cost;
            assert!(c <= gs, "LogDP({lambda}) {c} <= GS {gs}");
        }
        let sdp = evaluate(inst, &SimpleDp.schedule(inst)).cost;
        assert!(sdp <= gs);
    });
}

/// Monotonicity in λ: a larger LogDP span can only help; λ=∞ equals DP.
#[test]
fn logdp_monotone_in_lambda() {
    check_cases(0xF6, 100, &small(), |inst| {
        let c1 = evaluate(inst, &LogDp::new(1.0).schedule(inst)).cost;
        let c5 = evaluate(inst, &LogDp::new(5.0).schedule(inst)).cost;
        let cinf = evaluate(inst, &LogDp::new(1e6).schedule(inst)).cost;
        let opt = evaluate(inst, &Dp.schedule(inst)).cost;
        assert!(c5 <= c1, "λ=5 {c5} <= λ=1 {c1}");
        assert!(cinf <= c5);
        assert_eq!(cinf, opt, "unbounded span = exact DP");
    });
}

/// The two independent simulators agree on arbitrary (even non-laminar)
/// detour lists produced by every algorithm.
#[test]
fn simulators_agree() {
    check_cases(0x17, 150, &small(), |inst| {
        let schedules = [
            Dp.schedule(inst),
            Gs.schedule(inst),
            Nfgs.schedule(inst),
            SimpleDp.schedule(inst),
            vec![],
        ];
        for sched in schedules {
            let head = evaluate(inst, &sched);
            assert_eq!(
                trajectory::service_times(inst, &sched),
                head.service,
                "simulators disagree on {sched:?}"
            );
            assert_eq!(trajectory::cost(inst, &sched), head.cost);
        }
    });
}

/// Dense-table SimpleDP (the XLA twin) equals the sparse solver, and its
/// reconstruction achieves the table cost.
#[test]
fn dense_simpledp_equals_sparse() {
    check_cases(0x28, 100, &small(), |inst| {
        let sparse = evaluate(inst, &SimpleDp.schedule(inst)).cost;
        let dense = dense_cost(inst);
        assert_eq!(dense, sparse);
        let tbl = dense_table(inst);
        let sched = reconstruct(inst, &tbl);
        assert_eq!(evaluate(inst, &sched).cost, dense);
    });
}

/// Every algorithm returns structurally valid schedules: in-range detours,
/// distinct left endpoints, laminar for the DP family.
#[test]
fn schedules_are_structurally_valid() {
    check_cases(0x39, 120, &small(), |inst| {
        let algos: Vec<Box<dyn Scheduler>> = vec![
            Box::new(Gs),
            Box::new(Fgs),
            Box::new(Nfgs),
            Box::new(Dp),
            Box::new(LogDp::new(1.0)),
            Box::new(SimpleDp),
        ];
        for s in algos {
            let sched = s.schedule(inst);
            for d in &sched {
                assert!(d.a <= d.b && d.b < inst.k(), "{} out of range", s.name());
            }
            let mut lefts: Vec<usize> = sched.iter().map(|d| d.a).collect();
            lefts.sort();
            let len = lefts.len();
            lefts.dedup();
            assert_eq!(lefts.len(), len, "{}: duplicate left endpoints", s.name());
        }
        for s in [&Dp as &dyn Scheduler, &LogDp::new(1.0), &SimpleDp] {
            assert!(is_strictly_laminar(&s.schedule(inst)), "{}", s.name());
        }
    });
}

/// Raising U never lowers the optimal cost, and the no-detour cost rises
/// by exactly x·Δ per unit (one final U-turn for everyone).
#[test]
fn uturn_penalty_monotonicity() {
    check_cases(0x4A, 100, &small(), |inst| {
        let base = inst.with_u(0);
        let c0 = evaluate(&base, &Dp.schedule(&base)).cost;
        let hi = inst.with_u(1000);
        let c1 = evaluate(&hi, &Dp.schedule(&hi)).cost;
        assert!(c1 >= c0, "harsher U cannot help: {c0} -> {c1}");
        // NoDetour: exactly one U-turn before everything.
        let n0 = evaluate(&base, &[]).cost;
        let n1 = evaluate(&hi, &[]).cost;
        assert_eq!(n1 - n0, 1000 * inst.n() as i128);
    });
}

/// Scale invariance: multiplying all positions and U by a constant scales
/// every cost by the same constant (the model is unit-free).
#[test]
fn scale_invariance() {
    check_cases(0x5B, 80, &tiny(), |inst| {
        let files = inst
            .files()
            .iter()
            .map(|f| tapesched::model::ReqFile { l: f.l * 1000, r: f.r * 1000, x: f.x })
            .collect();
        let scaled =
            Instance::new(inst.tape_len() * 1000, inst.u() * 1000, files).unwrap();
        let c = evaluate(inst, &Dp.schedule(inst)).cost;
        let cs = evaluate(&scaled, &Dp.schedule(&scaled)).cost;
        assert_eq!(cs, c * 1000);
    });
}

/// With a single request per file and *uniform* sizes and no penalty, GS's
/// detours can still lose to DP — but FGS must at least never be worse
/// than GS (its passes only remove detrimental detours).
#[test]
fn fgs_never_worse_than_gs() {
    check_cases(0x6C, 150, &small(), |inst| {
        let gs = evaluate(inst, &Gs.schedule(inst)).cost;
        let fgs = evaluate(inst, &Fgs.schedule(inst)).cost;
        assert!(fgs <= gs, "FGS {fgs} <= GS {gs}");
    });
}

/// Arbitrary-start extension (paper's conclusion): DpFromStart's schedule
/// never starts a detour right of X, achieves the documented cost identity
/// `cost_from(X) = cost_from(m) − n·(m − X)`, and beats DP's *restricted*
/// competitors.
#[test]
fn from_start_extension_invariants() {
    use tapesched::sched::DpFromStart;
    use tapesched::sim::evaluate_from;
    check_cases(0x7D, 80, &small(), |inst| {
        // A start position somewhere mid-tape, but right of f₁ so every
        // schedule can still begin by moving left.
        let x_pos = inst.l(0) + (inst.tape_len() - inst.l(0)) / 2;
        let solver = DpFromStart { x_pos };
        let sched = solver.schedule(inst);
        for d in &sched {
            assert!(inst.l(d.a) <= x_pos);
        }
        let from_x = evaluate_from(inst, &sched, x_pos).cost;
        let from_m = evaluate(inst, &sched).cost;
        let delta = (inst.tape_len() - x_pos) as i128 * inst.n() as i128;
        if sched.is_empty() && x_pos <= inst.l(0) {
            // Cold-start corner (fixed U-turn semantics): the empty
            // schedule from a head already at/left of every file never
            // reverses. Skipping the turn removes `u` from every one of
            // the n request service times, so the saving is n·u.
            let saved = inst.n() as i128 * inst.u() as i128;
            assert_eq!(from_x, from_m - delta - saved, "cold identity");
        } else {
            assert_eq!(from_x, from_m - delta, "cost identity");
        }
        assert_eq!(solver.optimal_cost(inst), from_x);
        // Restricting the start can never help.
        let unrestricted = evaluate(inst, &Dp.schedule(inst)).cost;
        assert!(from_m >= unrestricted);
        // GS restricted to detours left of X is still a competitor.
        let gs_restricted: Vec<_> = Gs
            .schedule(inst)
            .into_iter()
            .filter(|d| inst.l(d.a) <= x_pos)
            .collect();
        assert!(from_x <= evaluate_from(inst, &gs_restricted, x_pos).cost);
    });
}

/// evaluate_from at the tape end is exactly evaluate.
#[test]
fn evaluate_from_tape_end_is_evaluate() {
    use tapesched::sim::evaluate_from;
    check_cases(0x8E, 80, &small(), |inst| {
        for sched in [Gs.schedule(inst), Dp.schedule(inst), vec![]] {
            let a = evaluate(inst, &sched);
            let b = evaluate_from(inst, &sched, inst.tape_len());
            assert_eq!(a.cost, b.cost);
            assert_eq!(a.service, b.service);
        }
    });
}
