//! Integration tests for the networked coordinator/worker fleet: loopback
//! parity with the in-process cluster (same stream, bit-identical virtual
//! numbers), the dead-worker shed accounting and its drain invariant,
//! worker rejoin, and the protocol-version handshake refusal.

use std::collections::BTreeSet;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use tapesched::cluster::{Cluster, ClusterConfig, HashRing};
use tapesched::coordinator::{BatcherConfig, CoordinatorConfig, ReadRequest, SubmitError};
use tapesched::model::Tape;
use tapesched::net::{
    read_frame, wire, write_frame, CoordinatorServerConfig, LoopbackFleet, Message, Role,
    PROTOCOL_VERSION,
};
use tapesched::replay::{drive_closed_loop, PoissonArrivals, RequestMix};
use tapesched::sim::{Affinity, DriveParams};

fn catalog(n: usize) -> Vec<Tape> {
    (0..n).map(|i| Tape::from_sizes(format!("TAPE{i:03}"), &[1_000; 20])).collect()
}

/// A catalog guaranteed to span both shards of a 2-shard ring (the kill
/// and rejoin tests need a surviving shard with work of its own).
fn two_shard_catalog() -> (Vec<Tape>, HashRing) {
    let ring = HashRing::new(2, 64);
    let mut tapes = Vec::new();
    for i in 0.. {
        tapes.push(Tape::from_sizes(format!("TAPE{i:03}"), &[1_000; 20]));
        let covered: BTreeSet<usize> = tapes.iter().map(|t| ring.route(&t.name)).collect();
        if tapes.len() >= 8 && covered.len() == 2 {
            break;
        }
    }
    (tapes, ring)
}

/// One giant batching window flushed at drain, no affinity/arms/
/// exclusivity: batch composition is then a pure function of the request
/// stream and the ring, so an in-process and a networked run of the same
/// stream must agree on every virtual-time number.
fn drain_flush_cfg(n_drives: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        n_drives,
        batcher: BatcherConfig {
            window: Duration::from_secs(3_600),
            ..BatcherConfig::default()
        },
        drive: DriveParams::default(),
        affinity: Affinity::None,
        exclusive_tapes: false,
    }
}

fn server_cfg(n_shards: usize, kill: Option<(usize, u64)>) -> CoordinatorServerConfig {
    CoordinatorServerConfig {
        n_shards,
        vnodes: 64,
        shard: drain_flush_cfg(2),
        policy: "GS".to_string(),
        kill,
        push_ms: 0,
        metrics_listen: None,
    }
}

/// The tentpole's parity contract: the same seeded request stream through
/// the in-process `Cluster` and through a loopback coordinator/worker
/// fleet yields identical counters and — request by request — identical
/// in-tape service times, down to the f64 bits (the wire ships IEEE-754
/// bits, not decimal). Only wall-clock latency may differ; that
/// difference is the RPC tax `tapesched rpc-tax` measures.
#[test]
fn loopback_fleet_matches_the_in_process_cluster_bit_for_bit() {
    let tapes = catalog(8);
    let n_requests = 120u64;

    let cluster = Cluster::start(
        ClusterConfig {
            n_shards: 2,
            vnodes: 64,
            shard: drain_flush_cfg(2),
            shard_configs: Vec::new(),
        },
        tapes.iter().cloned(),
        Arc::new(tapesched::sched::Gs),
    );
    let mut model = PoissonArrivals::new(RequestMix::new(&tapes), 500.0, f64::INFINITY, 42);
    let stats = drive_closed_loop(
        &cluster,
        &tapes,
        &mut model,
        n_requests,
        Duration::from_millis(1),
        n_requests,
    );
    assert_eq!(stats.submitted, n_requests);
    assert_eq!(stats.dropped, 0);
    let (mut local, local_m) = cluster.finish();

    let fleet = LoopbackFleet::spawn(server_cfg(2, None), tapes.clone()).expect("spawn fleet");
    let client = fleet.client().expect("connect client");
    let mut model = PoissonArrivals::new(RequestMix::new(&tapes), 500.0, f64::INFINITY, 42);
    let stats = drive_closed_loop(
        &client,
        &tapes,
        &mut model,
        n_requests,
        Duration::from_millis(1),
        n_requests,
    );
    assert_eq!(stats.submitted, n_requests);
    assert_eq!(stats.dropped, 0);
    let (remote, remote_m) = client.drain().expect("drain fleet");
    let (server, workers) = fleet.join();
    server.expect("server exits cleanly");
    for w in workers {
        w.expect("worker exits cleanly");
    }

    assert_eq!(local_m.submitted, remote_m.submitted);
    assert_eq!(local_m.completed, remote_m.completed);
    assert_eq!(local_m.shed, remote_m.shed);
    assert_eq!(local_m.batches, remote_m.batches);
    assert_eq!(local.len(), remote.len());
    local.sort_by_key(|c| c.request_id);
    // The fleet drain is already sorted by request id; sorting the local
    // side too makes the comparison order-insensitive.
    for (l, r) in local.iter().zip(&remote) {
        assert_eq!(l.request_id, r.request_id);
        assert_eq!(l.tape, r.tape);
        assert_eq!(
            l.service_s.to_bits(),
            r.service_s.to_bits(),
            "service time must cross the wire exactly (request {})",
            l.request_id
        );
    }
}

/// Pushed telemetry is advisory: a fleet pushing metrics snapshots
/// (`push_ms > 0`) driven through a push-fed client gauge must schedule
/// exactly the same work as a pull-mode run of the same stream — the
/// gauge changes who answers `in_flight()`, never what is submitted,
/// batched, or served.
#[test]
fn a_push_fed_client_schedules_the_same_work_as_a_pull_mode_run() {
    let tapes = catalog(8);
    let n_requests = 80u64;

    let run = |push: bool| {
        let mut cfg = server_cfg(2, None);
        if push {
            cfg.push_ms = 2;
        }
        let fleet = LoopbackFleet::spawn(cfg, tapes.clone()).expect("spawn fleet");
        let client = if push {
            fleet.client_push().expect("connect push-fed client")
        } else {
            fleet.client().expect("connect client")
        };
        let mut model = PoissonArrivals::new(RequestMix::new(&tapes), 500.0, f64::INFINITY, 9);
        let stats = drive_closed_loop(
            &client,
            &tapes,
            &mut model,
            n_requests,
            Duration::from_millis(1),
            n_requests,
        );
        assert_eq!(stats.submitted, n_requests);
        assert_eq!(stats.dropped, 0);
        let (completions, m) = client.drain().expect("drain fleet");
        let _ = fleet.join();
        (completions, m)
    };

    let (pull_c, pull_m) = run(false);
    let (push_c, push_m) = run(true);

    assert_eq!(pull_m.submitted, push_m.submitted);
    assert_eq!(pull_m.completed, push_m.completed);
    assert_eq!(pull_m.shed, push_m.shed);
    assert_eq!(pull_m.batches, push_m.batches);
    assert_eq!(push_m.submitted, push_m.completed + push_m.shed);
    assert_eq!(pull_c.len(), push_c.len());
    for (l, r) in pull_c.iter().zip(&push_c) {
        assert_eq!(l.request_id, r.request_id);
        assert_eq!(
            l.service_s.to_bits(),
            r.service_s.to_bits(),
            "pushed telemetry must not perturb service times (request {})",
            l.request_id
        );
    }
}

/// A worker cut mid-stream: its accepted-but-unserved work is shed
/// through the coordinator's synthesized accounting, later submits to the
/// dead shard fail with `ShardDown` (not `Busy`), the surviving shard
/// keeps serving, and the fleet-wide drain invariant
/// `submitted = completed + shed` holds.
#[test]
fn a_killed_worker_is_shed_and_the_drain_invariant_holds() {
    let (tapes, ring) = two_shard_catalog();
    let victim = ring.route(&tapes[0].name);
    let fleet =
        LoopbackFleet::spawn(server_cfg(2, Some((victim, 1))), tapes.clone()).expect("spawn fleet");
    let client = fleet.client().expect("connect client");

    // First submit routes to the victim, is accepted — and the kill fires
    // before the reply returns, so the death is visible immediately.
    let accepted = client
        .submit(&ReadRequest { id: 0, tape: tapes[0].name.clone(), file_index: 0 })
        .expect("round trip");
    assert_eq!(accepted, Ok(()));
    let down = client
        .submit(&ReadRequest { id: 1, tape: tapes[0].name.clone(), file_index: 1 })
        .expect("round trip");
    assert_eq!(down, Err(SubmitError::ShardDown));

    let mut accepted_elsewhere = 0u64;
    for (i, tape) in tapes.iter().enumerate() {
        if ring.route(&tape.name) == victim {
            continue;
        }
        let r = client
            .submit(&ReadRequest { id: 2 + i as u64, tape: tape.name.clone(), file_index: 0 })
            .expect("round trip");
        assert_eq!(r, Ok(()), "the surviving shard must keep serving");
        accepted_elsewhere += 1;
    }
    assert!(accepted_elsewhere > 0, "the catalog must span both shards");

    let (completions, m) = client.drain().expect("drain fleet");
    assert_eq!(m.submitted, 1 + accepted_elsewhere);
    assert_eq!(m.shed, 1, "the victim's lost request is shed, not forgotten");
    assert_eq!(m.completed, accepted_elsewhere);
    assert_eq!(m.submitted, m.completed + m.shed);
    assert_eq!(completions.len() as u64, accepted_elsewhere);
    let _ = fleet.join();
}

/// A replacement worker is just another joiner: the coordinator hands it
/// the dead shard's id and catalog partition, the shard serves again (the
/// kill trigger is one-shot), and the drained accounting stitches both
/// eras together — era 1's loss shed, era 2's work completed.
#[test]
fn a_replacement_worker_takes_over_the_dead_shard_and_resumes() {
    let (tapes, ring) = two_shard_catalog();
    let victim_tape = tapes[0].name.clone();
    let victim = ring.route(&victim_tape);
    let fleet =
        LoopbackFleet::spawn(server_cfg(2, Some((victim, 1))), tapes.clone()).expect("spawn fleet");
    let client = fleet.client().expect("connect client");

    let first = client
        .submit(&ReadRequest { id: 0, tape: victim_tape.clone(), file_index: 0 })
        .expect("round trip");
    assert_eq!(first, Ok(()));
    let down = client
        .submit(&ReadRequest { id: 1, tape: victim_tape.clone(), file_index: 1 })
        .expect("round trip");
    assert_eq!(down, Err(SubmitError::ShardDown));

    let replacement = LoopbackFleet::spawn_worker(fleet.addr());
    let mut resumed = false;
    for _ in 0..500 {
        let r = client
            .submit(&ReadRequest { id: 2, tape: victim_tape.clone(), file_index: 2 })
            .expect("round trip");
        match r {
            Ok(()) => {
                resumed = true;
                break;
            }
            Err(SubmitError::ShardDown) => std::thread::sleep(Duration::from_millis(10)),
            Err(e) => panic!("unexpected submit error: {e:?}"),
        }
    }
    assert!(resumed, "the replacement worker never took the shard over");

    let (completions, m) = client.drain().expect("drain fleet");
    assert_eq!(m.submitted, 2);
    assert_eq!(m.shed, 1, "era 1's lost request stays shed across the rejoin");
    assert_eq!(m.completed, 1);
    assert_eq!(m.submitted, m.completed + m.shed);
    assert_eq!(completions.len(), 1);
    assert_eq!(completions[0].request_id, 2);
    let _ = fleet.join();
    replacement.join().expect("replacement thread panicked").expect("replacement exits cleanly");
}

/// A peer speaking the wrong protocol version is refused with an explicit
/// `Error` frame naming both versions, then disconnected — and the fleet
/// keeps serving well-versed clients afterwards.
#[test]
fn a_version_mismatched_peer_is_refused_at_the_handshake() {
    let tapes = catalog(4);
    let fleet = LoopbackFleet::spawn(server_cfg(1, None), tapes).expect("spawn fleet");

    let mut raw = TcpStream::connect(fleet.addr()).expect("connect raw");
    write_frame(
        &mut raw,
        &wire::encode(&Message::Hello { version: PROTOCOL_VERSION + 1, role: Role::Client }),
    )
    .expect("send mismatched hello");
    let payload =
        read_frame(&mut raw).expect("read refusal").expect("server must reply before closing");
    match wire::decode(&payload).expect("decode refusal") {
        Message::Error { message } => {
            assert!(
                message.contains("protocol version mismatch"),
                "unhelpful refusal: {message}"
            );
        }
        other => panic!("expected an Error frame, got {other:?}"),
    }
    assert!(read_frame(&mut raw).expect("clean close").is_none());

    let client = fleet.client().expect("a well-versed client still connects");
    let (completions, m) = client.drain().expect("drain fleet");
    assert!(completions.is_empty());
    assert_eq!(m.submitted, 0);
    let _ = fleet.join();
}
