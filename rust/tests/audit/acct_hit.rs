// Fixture: mutates two legs of the submitted/completed/shed ledger and
// never references debug_assert_drain_invariant — one `acct-invariant`
// finding, anchored at the first mutation.
pub struct Stats {
    pub submitted: u64,
    pub completed: u64,
    pub shed: u64,
}

pub fn absorb(into: &mut Stats, from: &Stats) {
    into.submitted += from.submitted;
    into.completed += from.completed;
    into.shed += from.shed;
}
