// Fixture: real violations suppressed by well-formed waivers — one
// trailing, one standalone on the line above. Audits clean, and both
// waivers count as used.
use std::time::Instant;

pub fn diag_origin() -> Instant {
    Instant::now() // audit:allow(wallclock) diagnostic anchor; differences only, never scheduled
}

pub fn diag_pair() -> (Instant, Instant) {
    let a = diag_origin();
    // audit:allow(wallclock) second leg of the same diagnostic anchor
    let b = Instant::now();
    (a, b)
}
