// Fixture: audited as net/wire.rs. Every tag and every Message variant
// appears in both encode and decode — no parity findings.
pub const TAG_SUBMIT: u8 = 1;
pub const TAG_SHUTDOWN: u8 = 2;
pub const PROTOCOL_VERSION: u16 = 1;

pub enum Message {
    Submit { tape: String },
    Shutdown,
}

pub fn encode(msg: &Message, out: &mut Vec<u8>) {
    match msg {
        Message::Submit { tape } => {
            out.push(TAG_SUBMIT);
            out.extend_from_slice(tape.as_bytes());
        }
        Message::Shutdown => out.push(TAG_SHUTDOWN),
    }
}

pub fn decode(buf: &[u8]) -> Option<Message> {
    match *buf.first()? {
        TAG_SUBMIT => Some(Message::Submit { tape: String::new() }),
        TAG_SHUTDOWN => Some(Message::Shutdown),
        _ => None,
    }
}
