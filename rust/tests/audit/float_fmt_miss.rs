// Fixture: sanctioned float formatting in a deterministic module —
// fixed-precision placeholders and bit-exact encodings only.
pub fn report(p99: f64) -> String {
    let fixed = format!("latency {p99:.6}");
    let bits = p99.to_bits();
    format!("{fixed} raw={bits:016x}")
}

pub fn debug_ints(count: u64, ids: &[u64]) -> String {
    // Debug formatting of non-floats is fine anywhere.
    format!("{count} ids={ids:?}")
}
