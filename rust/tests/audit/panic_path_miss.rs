// Fixture: serving-path module that degrades instead of panicking —
// poisoned locks recover, absent values shed. Unwraps in the test module
// are exempt.
use std::sync::Mutex;

pub fn drain(m: &Mutex<Vec<u64>>) -> Vec<u64> {
    let mut q = match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    std::mem::take(&mut *q)
}

pub fn first(m: &Mutex<Vec<u64>>) -> Option<u64> {
    drain(m).first().copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drains_in_order() {
        let m = Mutex::new(vec![1, 2]);
        m.lock().unwrap().push(3);
        assert_eq!(drain(&m), vec![1, 2, 3]);
    }
}
