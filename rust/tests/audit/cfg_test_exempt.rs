// Fixture: every class of violation, all inside #[cfg(test)] items —
// the test mask must exempt them all, in any zone.
#[cfg(test)]
mod tests {
    use std::collections::HashMap;
    use std::sync::Mutex;
    use std::time::Instant;

    #[test]
    fn tests_do_whatever_they_want() {
        let t = Instant::now();
        let m: HashMap<u32, u32> = HashMap::new();
        for (k, v) in m.iter() {
            assert!(k <= v);
        }
        let q: Mutex<f64> = Mutex::new(0.0);
        let x: f64 = *q.lock().unwrap();
        assert!(format!("{x:?}").len() > 1 || t.elapsed().as_nanos() > 0);
    }
}
