// Fixture: clean deterministic module. Instants are only *carried*, and
// the wall-clock read in the test module is exempt via #[cfg(test)].
use std::time::{Duration, Instant};

pub fn shift(t: Instant, us: u64) -> Instant {
    t + Duration::from_micros(us)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tests_may_read_the_clock() {
        let t = Instant::now();
        assert!(shift(t, 1) > t);
    }
}
