// Fixture: a waiver that suppresses nothing. The code below is clean, so
// the waiver itself must fire `unused-waiver` (and --fix-waivers must
// delete the standalone line).
pub fn add(a: u64, b: u64) -> u64 {
    // audit:allow(wallclock) left over from a deleted diagnostic
    a + b
}
