// Fixture: accounting-clean files. Mutating a single ledger leg does not
// demand the invariant (there is nothing to balance it against), and a
// file that mutates several legs but calls the helper is sanctioned.
pub struct Stats {
    pub submitted: u64,
    pub completed: u64,
    pub shed: u64,
}

pub fn count_submit(s: &mut Stats) {
    s.submitted += 1;
}

pub fn drain(s: &mut Stats, done: u64, dropped: u64) {
    s.completed += done;
    s.shed += dropped;
    debug_assert_drain_invariant(s.submitted, s.completed, s.shed, "fixture drain");
}

fn debug_assert_drain_invariant(submitted: u64, completed: u64, shed: u64, context: &str) {
    debug_assert!(submitted == completed + shed, "{context}");
}
