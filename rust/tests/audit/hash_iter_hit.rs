// Fixture: iteration over hash-ordered containers in a deterministic
// module. Both the method call and the for-loop must fire `hash-iter`.
use std::collections::HashMap;

pub fn sum(by_tape: &HashMap<String, u64>) -> u64 {
    let mut total = 0;
    for v in by_tape.values() {
        total += v;
    }
    total
}

pub fn names(seen: std::collections::HashSet<String>) -> Vec<String> {
    let mut out = Vec::new();
    for n in &seen {
        out.push(n.clone());
    }
    out
}
