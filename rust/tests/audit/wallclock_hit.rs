// Fixture: wall-clock reads in a deterministic module. Audited as if it
// lived at replay/fixture.rs — all three sites must fire `wallclock`.
use std::time::{Instant, SystemTime};

pub fn stamp() -> (Instant, SystemTime) {
    let a = Instant::now();
    let b = SystemTime::now();
    (a, b)
}

pub fn who() -> std::thread::Thread {
    std::thread::current()
}
