// Fixture: a waiver with no reason after the closing paren. Must fire
// `waiver-syntax` — an unexplained suppression is unreviewable.
use std::time::Instant;

pub fn origin() -> Instant {
    // audit:allow(wallclock)
    Instant::now()
}
