// Fixture: order-safe container use in a deterministic module. BTreeMap
// iterates sorted, and point lookups into a HashMap never observe the
// hash order.
use std::collections::{BTreeMap, HashMap};

pub fn ordered_sum(by_tape: &BTreeMap<String, u64>) -> u64 {
    by_tape.values().sum()
}

pub fn lookup(index: &HashMap<u64, String>, id: u64) -> Option<&String> {
    index.get(&id)
}

pub fn lookup_all(index: &HashMap<u64, String>, ids: &[u64]) -> Vec<String> {
    ids.iter().filter_map(|id| index.get(id).cloned()).collect()
}
