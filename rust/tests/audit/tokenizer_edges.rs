// Fixture: tokenizer traps. Everything here LOOKS like a violation to a
// regex but is string/comment/lifetime content — audits clean in every
// zone.
pub const DOC: &str = "call Instant::now() // not a comment, not code";
pub const RAW: &str = r#"m.lock().unwrap() and "{x:?}" stay inert in raw strings"#;
pub const BYTES: &[u8] = b"SystemTime::now()";

/* Instant::now() in a block comment
   /* nested: thread::current() */
   still a comment */
pub fn lifetimes_are_not_chars<'a>(s: &'a str) -> &'a str {
    let _not_a_lifetime: char = 'a';
    s
}

pub fn ranges_survive_numbers() -> u64 {
    (0..10).map(|i| i * 2).sum()
}
