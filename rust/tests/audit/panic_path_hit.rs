// Fixture: panicking extractors in a serving-path module. Both sites
// must fire `panic-path` — a poisoned lock must degrade, not abort the
// dispatcher.
use std::sync::Mutex;

pub fn drain(m: &Mutex<Vec<u64>>) -> Vec<u64> {
    let mut q = m.lock().unwrap();
    std::mem::take(&mut *q)
}

pub fn first(m: &Mutex<Vec<u64>>) -> u64 {
    *m.lock().expect("queue lock").first().expect("non-empty")
}
