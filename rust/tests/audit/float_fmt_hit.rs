// Fixture: Debug / to_string formatting of f64 in a deterministic
// module. All three sites must fire `float-fmt` — Debug float output is
// shortest-round-trip and not byte-stable across toolchains.
pub fn report(p99: f64) -> String {
    let positional = format!("latency {:?}", p99);
    let named = format!("latency {p99:?}");
    let stringified = p99.to_string();
    format!("{positional} {named} {stringified}")
}
