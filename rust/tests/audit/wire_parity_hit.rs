// Fixture: audited as net/wire.rs. TAG_DRAIN is encoded but never
// decoded, and the Shutdown variant is decoded but never encoded — both
// must fire `wire-tag-parity`.
pub const TAG_SUBMIT: u8 = 1;
pub const TAG_DRAIN: u8 = 2;
pub const PROTOCOL_VERSION: u16 = 1;

pub enum Message {
    Submit { tape: String },
    Shutdown,
}

pub fn encode(msg: &Message, out: &mut Vec<u8>) {
    match msg {
        Message::Submit { tape } => {
            out.push(TAG_SUBMIT);
            out.extend_from_slice(tape.as_bytes());
        }
        _ => out.push(TAG_DRAIN),
    }
}

pub fn decode(buf: &[u8]) -> Option<Message> {
    match buf.first()? {
        &TAG_SUBMIT => Some(Message::Submit { tape: String::new() }),
        _ => Some(Message::Shutdown),
    }
}
