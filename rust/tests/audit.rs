//! Fixture tests for `tapesched audit`: every rule id has a firing and a
//! non-firing case, waivers suppress and rot loudly, the tokenizer
//! survives the classic lexing traps, and — the gate CI leans on — the
//! shipped source tree audits clean.
//!
//! Fixture sources live under `tests/audit/`; they are data, not
//! compiled code (cargo only builds top-level `tests/*.rs`), so they can
//! contain deliberate violations.

use std::fs;
use std::path::{Path, PathBuf};

use tapesched::audit::rules::{rule_proto_bump, ALL_RULES};
use tapesched::audit::{audit_source, audit_tree, fix_unused_waivers, render, total_findings};

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/audit").join(name);
    fs::read_to_string(&path).unwrap_or_else(|e| panic!("fixture {name}: {e}"))
}

/// Rule ids fired when `name` is audited as if it lived at `rel`.
fn fired(rel: &str, name: &str) -> Vec<&'static str> {
    audit_source(rel, &fixture(name)).into_iter().map(|f| f.rule).collect()
}

#[test]
fn wallclock_fires_on_every_clock_read() {
    assert_eq!(
        fired("replay/fixture.rs", "wallclock_hit.rs"),
        ["wallclock", "wallclock", "wallclock"],
        "Instant::now, SystemTime::now, thread::current"
    );
}

#[test]
fn wallclock_spares_carried_instants_and_tests() {
    assert!(fired("replay/fixture.rs", "wallclock_miss.rs").is_empty());
}

#[test]
fn wallclock_only_applies_in_the_determinism_zone() {
    assert!(fired("analysis/fixture.rs", "wallclock_hit.rs").is_empty());
    // Single det-zone files, not just directories, are covered.
    assert!(!fired("cluster/ring.rs", "wallclock_hit.rs").is_empty());
    assert!(!fired("coordinator/batcher.rs", "wallclock_hit.rs").is_empty());
}

#[test]
fn hash_iter_fires_on_method_and_for_loop() {
    assert_eq!(fired("sched/fixture.rs", "hash_iter_hit.rs"), ["hash-iter", "hash-iter"]);
}

#[test]
fn hash_iter_spares_btreemap_and_point_lookups() {
    assert!(fired("sched/fixture.rs", "hash_iter_miss.rs").is_empty());
}

#[test]
fn float_fmt_fires_on_debug_and_to_string() {
    assert_eq!(
        fired("model/fixture.rs", "float_fmt_hit.rs"),
        ["float-fmt", "float-fmt", "float-fmt"],
        "positional {{:?}}, named {{x:?}}, .to_string()"
    );
}

#[test]
fn float_fmt_spares_fixed_precision_and_bits() {
    assert!(fired("model/fixture.rs", "float_fmt_miss.rs").is_empty());
}

#[test]
fn float_fmt_is_sanctioned_in_the_report_module() {
    // replay/report.rs is the one deterministic formatter allowed to
    // format floats — same violating source, zero findings there.
    assert!(fired("replay/report.rs", "float_fmt_hit.rs").is_empty());
}

#[test]
fn panic_path_fires_on_unwrap_and_expect() {
    assert_eq!(
        fired("net/fixture.rs", "panic_path_hit.rs"),
        ["panic-path", "panic-path", "panic-path"]
    );
    // The two single-file panic-zone members are covered too.
    assert!(!fired("obs/expo.rs", "panic_path_hit.rs").is_empty());
    assert!(!fired("coordinator/service.rs", "panic_path_hit.rs").is_empty());
}

#[test]
fn panic_path_spares_degrading_code_and_tests() {
    assert!(fired("net/fixture.rs", "panic_path_miss.rs").is_empty());
}

#[test]
fn panic_path_only_applies_in_the_panic_zone() {
    assert!(fired("replay/fixture.rs", "panic_path_hit.rs").is_empty());
}

#[test]
fn acct_fires_once_per_file_at_first_mutation() {
    let findings = audit_source("cluster/fixture.rs", &fixture("acct_hit.rs"));
    assert_eq!(findings.len(), 1);
    assert_eq!(findings[0].rule, "acct-invariant");
    assert_eq!(findings[0].line, 11, "anchored at the first counter mutation");
}

#[test]
fn acct_spares_single_counters_and_helper_callers() {
    assert!(fired("cluster/fixture.rs", "acct_miss.rs").is_empty());
}

#[test]
fn acct_applies_outside_every_named_zone() {
    // The accounting rule is global — a util file is not exempt.
    assert_eq!(fired("util/fixture.rs", "acct_hit.rs"), ["acct-invariant"]);
}

#[test]
fn wire_parity_fires_on_one_sided_tags_and_variants() {
    let findings = audit_source("net/wire.rs", &fixture("wire_parity_hit.rs"));
    let rules: Vec<_> = findings.iter().map(|f| f.rule).collect();
    assert_eq!(rules, ["wire-tag-parity", "wire-tag-parity"]);
    let msgs: Vec<_> = findings.iter().map(|f| f.msg.as_str()).collect();
    assert!(msgs.iter().any(|m| m.contains("TAG_DRAIN") && m.contains("decode")));
    assert!(msgs.iter().any(|m| m.contains("Shutdown") && m.contains("encode")));
}

#[test]
fn wire_parity_spares_balanced_codecs_and_other_files() {
    assert!(fired("net/wire.rs", "wire_parity_miss.rs").is_empty());
    // The same lopsided codec under any other path is not checked.
    assert!(fired("net/codec.rs", "wire_parity_hit.rs").is_empty());
}

#[test]
fn waivers_suppress_trailing_and_standalone() {
    assert!(fired("replay/fixture.rs", "waived.rs").is_empty());
}

#[test]
fn unused_waivers_are_findings() {
    let findings = audit_source("replay/fixture.rs", &fixture("unused_waiver.rs"));
    assert_eq!(findings.len(), 1);
    assert_eq!(findings[0].rule, "unused-waiver");
    assert_eq!(findings[0].line, 5, "anchored at the waiver comment itself");
}

#[test]
fn reasonless_waivers_are_syntax_findings_and_do_not_suppress() {
    let rules = fired("replay/fixture.rs", "waiver_syntax.rs");
    assert!(rules.contains(&"waiver-syntax"));
    assert!(rules.contains(&"wallclock"), "a malformed waiver suppresses nothing");
}

#[test]
fn cfg_test_items_are_exempt_in_every_zone() {
    assert!(fired("replay/fixture.rs", "cfg_test_exempt.rs").is_empty());
    assert!(fired("net/fixture.rs", "cfg_test_exempt.rs").is_empty());
}

#[test]
fn tokenizer_traps_do_not_produce_findings() {
    assert!(fired("replay/fixture.rs", "tokenizer_edges.rs").is_empty());
    assert!(fired("net/fixture.rs", "tokenizer_edges.rs").is_empty());
}

#[test]
fn every_rule_id_has_fixture_coverage() {
    let mut covered: Vec<&str> = Vec::new();
    covered.extend(fired("replay/fixture.rs", "wallclock_hit.rs"));
    covered.extend(fired("sched/fixture.rs", "hash_iter_hit.rs"));
    covered.extend(fired("model/fixture.rs", "float_fmt_hit.rs"));
    covered.extend(fired("net/fixture.rs", "panic_path_hit.rs"));
    covered.extend(fired("cluster/fixture.rs", "acct_hit.rs"));
    covered.extend(fired("net/wire.rs", "wire_parity_hit.rs"));
    covered.extend(fired("replay/fixture.rs", "unused_waiver.rs"));
    covered.extend(fired("replay/fixture.rs", "waiver_syntax.rs"));
    // wire-proto-bump is diff-driven; proto_bump_needs_a_version_change
    // covers it against a scratch git repo.
    covered.push("wire-proto-bump");
    for rule in ALL_RULES {
        assert!(covered.contains(&rule), "no fixture fires `{rule}`");
    }
}

/// Scratch tree under `CARGO_TARGET_TMPDIR` seeded with fixture files.
fn scratch_tree(tag: &str, files: &[(&str, &str)]) -> PathBuf {
    let root = Path::new(env!("CARGO_TARGET_TMPDIR")).join(format!("audit_{tag}"));
    if root.exists() {
        fs::remove_dir_all(&root).expect("clear scratch tree");
    }
    for (rel, fix) in files {
        let dst = root.join(rel);
        fs::create_dir_all(dst.parent().expect("parent")).expect("mkdir");
        fs::write(&dst, fixture(fix)).expect("seed fixture");
    }
    root
}

#[test]
fn audit_tree_reports_per_file_sorted_and_renders() {
    let root = scratch_tree(
        "tree",
        &[
            ("replay/bad.rs", "wallclock_hit.rs"),
            ("replay/good.rs", "wallclock_miss.rs"),
            ("util/stale.rs", "unused_waiver.rs"),
        ],
    );
    let reports = audit_tree(&root).expect("scan scratch tree");
    let rels: Vec<_> = reports.iter().map(|r| r.rel.as_str()).collect();
    assert_eq!(rels, ["replay/bad.rs", "util/stale.rs"], "clean files are omitted, order stable");
    assert_eq!(total_findings(&reports), 4);
    let page = render(&reports);
    assert!(page.contains("replay/bad.rs:6: [wallclock]"), "page:\n{page}");
    assert!(page.contains("    hint: "));
    assert!(page.contains("4 finding(s)\n"));
}

#[test]
fn clean_tree_renders_the_zero_line() {
    let root = scratch_tree("clean", &[("replay/good.rs", "wallclock_miss.rs")]);
    let reports = audit_tree(&root).expect("scan scratch tree");
    assert_eq!(total_findings(&reports), 0);
    assert_eq!(render(&reports), "audit clean: 0 findings\n");
}

#[test]
fn fix_waivers_deletes_standalone_and_strips_trailing() {
    let root = scratch_tree("fix", &[("util/stale.rs", "unused_waiver.rs")]);
    // Add a trailing unused waiver by hand next to the standalone one.
    let extra = root.join("util/trailing.rs");
    let waiver = format!("// audit:allow({}) stale trailing reason", "wallclock");
    fs::write(&extra, format!("pub fn f() -> u64 {{\n    7 {waiver}\n}}\n")).expect("seed");
    let reports = audit_tree(&root).expect("scan");
    assert_eq!(total_findings(&reports), 2);
    let removed = fix_unused_waivers(&root, &reports).expect("rewrite");
    assert_eq!(removed, 2);
    let after = audit_tree(&root).expect("rescan");
    assert_eq!(total_findings(&after), 0, "fixed tree audits clean");
    let stale = fs::read_to_string(root.join("util/stale.rs")).expect("read back");
    assert!(!stale.contains("audit:allow"));
    let trailing = fs::read_to_string(&extra).expect("read back");
    assert_eq!(trailing, "pub fn f() -> u64 {\n    7\n}\n", "code before the waiver survives");
}

#[test]
fn proto_bump_needs_a_version_change() {
    let git = |root: &Path, args: &[&str]| {
        std::process::Command::new("git")
            .args(args)
            .current_dir(root)
            .env("GIT_AUTHOR_NAME", "audit")
            .env("GIT_AUTHOR_EMAIL", "audit@test")
            .env("GIT_COMMITTER_NAME", "audit")
            .env("GIT_COMMITTER_EMAIL", "audit@test")
            .output()
    };
    let root = scratch_tree("proto", &[("net/wire.rs", "wire_parity_miss.rs")]);
    let ok = git(&root, &["init", "-q"]).map(|o| o.status.success()).unwrap_or(false);
    if !ok {
        eprintln!("skipping proto-bump test: git unavailable");
        return;
    }
    assert!(git(&root, &["add", "."]).expect("git add").status.success());
    assert!(git(&root, &["commit", "-q", "-m", "seed"]).expect("git commit").status.success());

    // Unchanged tree: no finding.
    assert!(rule_proto_bump(&root).is_none());

    // Adding a tag without touching PROTOCOL_VERSION is the hazard.
    let wire = root.join("net/wire.rs");
    let mut src = fs::read_to_string(&wire).expect("read wire");
    src.push_str("pub const TAG_EXTRA: u8 = 9;\n");
    fs::write(&wire, &src).expect("grow wire");
    let finding = rule_proto_bump(&root).expect("new tag without bump must fire");
    assert_eq!(finding.rule, "wire-proto-bump");

    // Bumping the version in the same diff clears it.
    let bumped = src.replace("PROTOCOL_VERSION: u16 = 1", "PROTOCOL_VERSION: u16 = 2");
    assert_ne!(bumped, src, "fixture must carry a PROTOCOL_VERSION to bump");
    fs::write(&wire, bumped).expect("bump version");
    assert!(rule_proto_bump(&root).is_none());
}

#[test]
fn the_shipped_tree_audits_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let reports = audit_tree(&src).expect("scan shipped sources");
    assert_eq!(
        total_findings(&reports),
        0,
        "shipped tree must audit clean (zero findings, zero unused waivers):\n{}",
        render(&reports)
    );
}
