//! Integration tests for the shared resource layer: the cartridge
//! exclusivity invariant under adversarial workloads, and the
//! byte-identity contract of `--exclusive-tapes off` (the PR 4 document).

use std::path::PathBuf;

use tapesched::coordinator::BatcherConfig;
use tapesched::model::Tape;
use tapesched::replay::{
    reports_json, run_replay, simulate, LoopMode, PoissonArrivals, ReplayConfig, RequestMix,
};
use tapesched::sched::scheduler_by_name;
use tapesched::sim::{Affinity, DriveParams};

fn hot_catalog() -> Vec<Tape> {
    // Few tapes over many drives: same-tape batches constantly collide,
    // and under LRU affinity with more tapes than drives the eviction
    // path (unmount-in-flight cartridges) runs too.
    (0..6).map(|i| Tape::from_sizes(format!("HOT{i}"), &[1_000; 40])).collect()
}

fn contended_cfg(affinity: Affinity, n_arms: usize) -> ReplayConfig {
    ReplayConfig {
        n_drives: 4,
        batcher: BatcherConfig {
            window: std::time::Duration::from_millis(50),
            max_batch: 2,
            ..BatcherConfig::default()
        },
        drive: DriveParams {
            mount_s: 1.0,
            unmount_s: 0.5,
            bytes_per_s: 1e6,
            uturn_s: 0.001,
            n_arms,
        },
        mode: LoopMode::Open,
        affinity,
        ..ReplayConfig::default()
    }
}

/// The exclusivity property: **no tape is ever threaded in two drives at
/// any virtual instant**. The engine checks it at every dispatch — the
/// [`tapesched::resources::CartridgeLedger`] panics on acquiring a
/// cartridge busy elsewhere, and the drive pool is scanned for duplicate
/// loads (`DrivePool::assert_exclusive`) — so sweeping hot workloads
/// across affinities, arm bounds, loop modes, and seeds turns any
/// violation into a test failure. The sweep must also actually exercise
/// contention: at least one configuration has to park batches.
#[test]
fn no_cartridge_is_ever_threaded_in_two_drives() {
    let catalog = hot_catalog();
    let mut total_parks = 0;
    for seed in [1u64, 7, 23] {
        for (affinity, n_arms) in [
            (Affinity::None, 0), // legacy fixed mount-cost path
            (Affinity::None, 1), // pipeline: trailing unmounts through one arm
            (Affinity::Lru, 0),  // pipeline: lazy unmount + evictions
            (Affinity::Lru, 2),  // pipeline: evict-unmounts queue on two arms
        ] {
            let mut cfg = contended_cfg(affinity, n_arms);
            assert!(cfg.exclusive_tapes, "exclusivity is the default");
            let policy = scheduler_by_name("GS").unwrap();
            let mut model =
                PoissonArrivals::new(RequestMix::new(&catalog), 30.0, 4.0, seed);
            let out = simulate(&cfg, &catalog, policy.as_ref(), &mut model);
            assert_eq!(out.stats.completed, out.stats.submitted);
            assert_eq!(out.cartridge_wait.count(), out.stats.batches);
            total_parks += out.stats.cartridge_parks;

            // Closed loop drives the retry path over the same ledger.
            cfg.mode = LoopMode::Closed { max_in_flight: 16 };
            cfg.batcher.max_tape_backlog = 8;
            let mut model =
                PoissonArrivals::new(RequestMix::new(&catalog), 30.0, 4.0, seed);
            let out = simulate(&cfg, &catalog, policy.as_ref(), &mut model);
            assert_eq!(out.stats.completed, out.stats.submitted);
            total_parks += out.stats.cartridge_parks;
        }
    }
    assert!(
        total_parks > 0,
        "the sweep never contended a cartridge — it proves nothing"
    );
}

/// Exclusivity surfaces head-of-line waiting the old model hid: on a
/// hot-tape workload the constrained run must show nonzero cartridge
/// waits and a strictly worse tail than `--exclusive-tapes off`, while
/// serving exactly the same requests.
#[test]
fn exclusivity_costs_tail_latency_on_a_hot_tape() {
    let catalog = vec![Tape::from_sizes("HOT", &[1_000; 50])];
    let run = |exclusive: bool| {
        let mut cfg = contended_cfg(Affinity::None, 0);
        cfg.n_drives = 8;
        cfg.batcher.max_batch = 1;
        cfg.exclusive_tapes = exclusive;
        let policy = scheduler_by_name("GS").unwrap();
        let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 8.0, 4.0, 7);
        run_replay(&cfg, &catalog, policy.as_ref(), &mut model, 7, 4.0)
    };
    let (on, on_out) = run(true);
    let (off, off_out) = run(false);
    assert_eq!(on.completed, off.completed);
    assert!(on.exclusive && !off.exclusive);
    assert!(on.cartridge_parks > 0, "singleton hot batches must park");
    assert!(on.cartridge_wait.max_s > 0.0);
    assert!(
        on.latency.p999_s > off.latency.p999_s,
        "exclusivity p99.9 {} must exceed unconstrained {}",
        on.latency.p999_s,
        off.latency.p999_s
    );
    assert!(on_out.stats.makespan_us > off_out.stats.makespan_us);
    // The JSON carries the new component only when exclusivity is on.
    let on_json = reports_json(&[on]);
    let off_json = reports_json(&[off]);
    assert!(on_json.contains("\"exclusive_tapes\":true"));
    assert!(on_json.contains("\"cartridge_wait\":"));
    assert!(!off_json.contains("\"exclusive_tapes\""));
    assert!(!off_json.contains("\"cartridge_parks\""));
    assert!(!off_json.contains("\"cartridge_wait\""));
}

/// Byte-identity regression for the `--exclusive-tapes off` path: its QoS
/// JSON is pinned against a golden file. The golden self-pins on first
/// run (this PR introduced it to freeze the PR 4-equivalent document) —
/// **commit `tests/golden/exclusive-off-qos.json` after that first run**,
/// or the pin only guards within one checkout; once committed, any later
/// drift in the off path — keys, ordering, or values — fails here.
/// Delete the golden to re-pin after an *intentional* format change.
#[test]
fn exclusive_off_qos_json_matches_the_pinned_golden() {
    let catalog: Vec<Tape> = (0..12)
        .map(|i| Tape::from_sizes(format!("TAPE{i:03}"), &[2_000; 40]))
        .collect();
    let cfg = ReplayConfig {
        n_shards: 4,
        vnodes: 64,
        exclusive_tapes: false,
        ..ReplayConfig::default()
    };
    let policy = scheduler_by_name("GS").unwrap();
    let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 50.0, 2.0, 7);
    let (report, _) = run_replay(&cfg, &catalog, policy.as_ref(), &mut model, 7, 2.0);
    let json = reports_json(&[report]);

    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/exclusive-off-qos.json");
    if path.exists() {
        let want = std::fs::read_to_string(&path).expect("read golden");
        assert_eq!(
            json, want,
            "--exclusive-tapes off must keep the legacy document byte for byte \
             (delete {} to re-pin after an intentional change)",
            path.display()
        );
    } else {
        std::fs::create_dir_all(path.parent().unwrap()).expect("create golden dir");
        std::fs::write(&path, &json).expect("write golden");
        eprintln!("pinned golden QoS document at {}", path.display());
    }
}
