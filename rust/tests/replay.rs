//! Integration tests for the workload-replay subsystem: end-to-end
//! determinism, histogram percentiles against exact quantiles on real
//! replay data, and the `Busy`-retry path against the live coordinator.

use std::sync::Arc;
use std::time::Duration;

use tapesched::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use tapesched::dataset::{generate_dataset, GeneratorConfig};
use tapesched::model::Tape;
use tapesched::replay::{
    drive_closed_loop, reports_json, run_replay, LoopMode, PoissonArrivals, ReplayConfig,
    RequestMix,
};
use tapesched::sched::scheduler_by_name;
use tapesched::sim::{Affinity, DriveParams};
use tapesched::util::stats::percentile_sorted;

fn small_catalog(n_tapes: usize) -> Vec<Tape> {
    let ds = generate_dataset(&GeneratorConfig {
        n_tapes,
        nf: (30, 60.0, 70.0, 120),
        nreq: (5, 10.0, 12.0, 20),
        n: (10, 30.0, 40.0, 80),
        ..Default::default()
    });
    ds.tapes.iter().map(|t| t.tape.clone()).collect()
}

fn fast_cfg(mode: LoopMode) -> ReplayConfig {
    ReplayConfig {
        n_drives: 4,
        batcher: BatcherConfig {
            window: Duration::from_millis(100),
            max_batch: 256,
            ..BatcherConfig::default()
        },
        drive: DriveParams {
            mount_s: 2.0,
            unmount_s: 1.0,
            bytes_per_s: 1e9,
            uturn_s: 0.1,
            n_arms: 0,
        },
        mode,
        retry_backoff_s: 0.02,
        ..ReplayConfig::default()
    }
}

/// The acceptance contract: the same seed and configuration produce an
/// identical completion log, identical percentiles, and byte-identical
/// JSON — across policies.
#[test]
fn replay_is_deterministic_end_to_end() {
    let catalog = small_catalog(6);
    let cfg = fast_cfg(LoopMode::Open);
    let run = |policy_name: &str| {
        let policy = scheduler_by_name(policy_name).unwrap();
        let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 50.0, 10.0, 7);
        run_replay(&cfg, &catalog, policy.as_ref(), &mut model, 7, 10.0)
    };
    for policy in ["GS", "SimpleDP", "DP"] {
        let (ra, oa) = run(policy);
        let (rb, ob) = run(policy);
        assert!(ra.completed > 300, "{policy}: expected ~500 requests");
        assert_eq!(oa.completions, ob.completions, "{policy}: completion log differs");
        assert_eq!(ra, rb, "{policy}: QoS reports differ");
        assert_eq!(
            reports_json(&[ra]),
            reports_json(&[rb]),
            "{policy}: JSON must be byte-identical"
        );
    }
}

/// Replay percentiles come from the log-bucketed histogram; on real replay
/// latencies they must track the exact sorted-vector quantiles within the
/// bucket resolution.
#[test]
fn report_percentiles_track_exact_quantiles() {
    let catalog = small_catalog(8);
    let cfg = fast_cfg(LoopMode::Open);
    let policy = scheduler_by_name("SimpleDP").unwrap();
    let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 80.0, 15.0, 11);
    let (report, outcome) =
        run_replay(&cfg, &catalog, policy.as_ref(), &mut model, 11, 15.0);
    let mut lat: Vec<f64> =
        outcome.completions.iter().map(|c| c.latency_us as f64 / 1e6).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert!(lat.len() > 500, "need a real sample, got {}", lat.len());
    for (p, got) in [
        (50.0, report.latency.p50_s),
        (95.0, report.latency.p95_s),
        (99.0, report.latency.p99_s),
        (99.9, report.latency.p999_s),
    ] {
        // The histogram reports the high edge of the bucket holding the
        // ⌈p/100·n⌉-th smallest sample: bracket it exactly.
        let rank = ((p / 100.0) * lat.len() as f64).ceil().max(1.0) as usize;
        let exact = lat[rank - 1];
        assert!(
            got >= exact - 1e-9 && got <= exact * (1.0 + 1.0 / 64.0) + 1e-5,
            "p{p}: report {got} outside [{exact}, {exact}·(1+1/64)] (n={})",
            lat.len()
        );
        // And it stays close to the interpolated quantile, the user-facing
        // claim (one order statistic + one bucket of slack).
        let interp = percentile_sorted(&lat, p);
        assert!(
            (got - interp).abs() <= interp * 0.05 + 1e-5,
            "p{p}: report {got} vs interpolated {interp}"
        );
    }
    let exact_mean = lat.iter().sum::<f64>() / lat.len() as f64;
    assert!((report.latency.mean_s - exact_mean).abs() < 1e-5, "mean is exact");
    assert_eq!(report.completed as usize, lat.len());
}

/// Closed-loop virtual replay against a saturated single tape: the
/// backpressure bound rejects, the driver retries, nothing is lost.
#[test]
fn closed_loop_replay_exercises_busy_retry() {
    let catalog = vec![Tape::from_sizes("HOT", &[10_000; 64])];
    let mut cfg = fast_cfg(LoopMode::Closed { max_in_flight: 16 });
    cfg.n_drives = 1;
    cfg.batcher.max_tape_backlog = 6;
    let policy = scheduler_by_name("GS").unwrap();
    let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 150.0, 6.0, 3);
    let (report, outcome) =
        run_replay(&cfg, &catalog, policy.as_ref(), &mut model, 3, 6.0);
    assert!(report.busy_rejections > 0, "backlog 6 under cap 16 must reject");
    assert_eq!(report.retries, report.busy_rejections, "every Busy retries once");
    assert_eq!(report.shed, 0, "closed loop never shed");
    assert_eq!(report.completed, report.submitted);
    assert_eq!(outcome.completions.len() as u64, report.completed);
}

/// The mount pipeline end to end: a replay with a bounded arm pool and
/// LRU affinity stays byte-deterministic, reconciles its remount
/// accounting, and serializes the new QoS sections.
#[test]
fn mount_pipeline_replay_is_deterministic_and_reconciles() {
    let catalog = small_catalog(6);
    let mut cfg = fast_cfg(LoopMode::Open);
    cfg.drive.n_arms = 1;
    cfg.affinity = Affinity::Lru;
    assert!(cfg.pipeline_active());
    let run = || {
        let policy = scheduler_by_name("GS").unwrap();
        let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 10.0, 10.0, 7);
        run_replay(&cfg, &catalog, policy.as_ref(), &mut model, 7, 10.0)
    };
    let (ra, oa) = run();
    let (rb, ob) = run();
    assert_eq!(oa.completions, ob.completions, "pipeline replay must stay deterministic");
    assert_eq!(
        reports_json(&[ra.clone()]),
        reports_json(&[rb]),
        "pipeline QoS JSON must be byte-identical for a fixed seed"
    );
    assert!(ra.pipeline);
    assert_eq!(ra.completed, ra.submitted, "drain invariant");
    assert_eq!(ra.remount_hits + ra.remount_misses, ra.batches);
    assert_eq!(oa.mount_wait.count(), ra.batches, "one pipeline sample per batch");
    let doc = reports_json(&[ra]);
    for key in [
        "\"arms\":1",
        "\"affinity\":\"lru\"",
        "\"remount_hits\":",
        "\"arm_wait\":",
        "\"mount_wait\":",
        "\"drive_wait\":",
    ] {
        assert!(doc.contains(key), "missing {key} in pipeline JSON");
    }
}

/// The live (wall-clock) side of the same contract: a real coordinator
/// with a tight backlog bound pushes `Busy` back to the closed-loop
/// driver, which retries until every request lands.
#[test]
fn live_coordinator_busy_retry_roundtrip() {
    let tapes = vec![Tape::from_sizes("HOT", &[1_000; 50])];
    let coord = Coordinator::start(
        CoordinatorConfig {
            n_drives: 1,
            batcher: BatcherConfig {
                // Window-gated (no size-cap closes): each window drains at
                // most one 8-request batch, so the blasting driver is
                // *guaranteed* to hit the backlog bound in between.
                window: Duration::from_millis(50),
                max_batch: 4096,
                max_tape_backlog: 8,
            },
            drive: DriveParams::default(),
            ..CoordinatorConfig::default()
        },
        tapes.clone(),
        Arc::new(tapesched::sched::Gs),
    );
    let mut model =
        PoissonArrivals::new(RequestMix::new(&tapes), 1_000.0, f64::INFINITY, 5);
    let stats = drive_closed_loop(
        &coord,
        &tapes,
        &mut model,
        64, // in-flight cap above the backlog bound, so Busy must fire
        Duration::from_millis(1),
        120,
    );
    assert_eq!(stats.submitted, 120, "every request lands after retries");
    assert!(stats.busy_retries > 0, "backlog 8 must push back at this pace");
    assert_eq!(stats.dropped, 0);
    let (completions, m) = coord.finish();
    assert_eq!(completions.len(), 120);
    assert_eq!(m.completed, 120);
    assert_eq!(m.rejected, stats.busy_retries);
}
