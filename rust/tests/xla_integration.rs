//! Cross-layer integration of the SimpleDP backend layer.
//!
//! The backend-agnostic half runs in every build: the pure-Rust dense
//! backend (the default) must agree with the exact sparse solver, the
//! policy adapter must behave as a scheduler, and backend selection must
//! resolve/reject names correctly.
//!
//! The PJRT half (`mod xla`) compiles only with `--features xla` and is
//! additionally gated on `artifacts/` (produced by `make artifacts`);
//! every test there skips cleanly when artifacts are absent so
//! `cargo test` works pre-build.

use tapesched::runtime::{
    available_backends, backend_by_name, default_backend, BackendPolicy, SimpleDpBackend,
};
use tapesched::sched::{Scheduler, SimpleDp};
use tapesched::sim::evaluate;
use tapesched::testkit::{random_instance, InstanceGenConfig};
use tapesched::util::rng::Rng;

#[test]
fn dense_backend_matches_sparse_on_random_instances() {
    let backend = default_backend();
    assert_eq!(backend.id(), "dense");
    let mut rng = Rng::new(0x71A);
    let cfg = InstanceGenConfig {
        min_files: 1,
        max_files: 14,
        max_size: 60,
        max_gap: 40,
        max_x: 8,
        max_u: 50,
    };
    for case in 0..60 {
        let inst = random_instance(&mut rng, &cfg);
        let sparse = SimpleDp::cost(&inst);
        assert_eq!(backend.opt_cost(&inst), sparse, "case {case}: {inst:?}");
        assert_eq!(
            evaluate(&inst, &backend.opt_schedule(&inst)).cost,
            sparse,
            "case {case}: schedule must achieve the optimal cost"
        );
    }
}

#[test]
fn every_available_backend_agrees_with_sparse() {
    let backends = available_backends();
    assert!(!backends.is_empty());
    let mut rng = Rng::new(0x71B);
    let cfg = InstanceGenConfig { min_files: 2, max_files: 12, ..Default::default() };
    for _ in 0..40 {
        let inst = random_instance(&mut rng, &cfg);
        let sparse = SimpleDp::cost(&inst);
        for b in &backends {
            assert_eq!(b.opt_cost(&inst), sparse, "backend {}", b.id());
            assert_eq!(
                evaluate(&inst, &b.opt_schedule(&inst)).cost,
                sparse,
                "backend {}",
                b.id()
            );
        }
    }
}

#[test]
fn backend_policy_plugs_into_the_scheduler_surface() {
    let policy = BackendPolicy::new(default_backend());
    assert_eq!(policy.name(), "SimpleDP[dense]");
    let mut rng = Rng::new(0x71C);
    let inst = random_instance(
        &mut rng,
        &InstanceGenConfig { min_files: 3, max_files: 9, ..Default::default() },
    );
    let sparse = evaluate(&inst, &SimpleDp.schedule(&inst)).cost;
    assert_eq!(evaluate(&inst, &policy.schedule(&inst)).cost, sparse);
}

#[test]
fn backend_selection_resolves_and_rejects() {
    assert_eq!(backend_by_name("dense").unwrap().id(), "dense");
    assert_eq!(backend_by_name("DENSE").unwrap().id(), "dense");
    let err = backend_by_name("tpu").unwrap_err();
    assert!(err.contains("unknown backend"), "{err}");
}

#[cfg(not(feature = "xla"))]
#[test]
fn xla_backend_unavailable_without_feature() {
    let err = backend_by_name("xla").unwrap_err();
    assert!(err.contains("--features xla"), "{err}");
    assert_eq!(available_backends().len(), 1, "dense only");
}

/// PJRT engine vs the exact implementations — `--features xla` builds only,
/// skipping without artifacts.
#[cfg(feature = "xla")]
mod xla {
    use super::*;
    use tapesched::model::adversarial::simpledp_five_thirds;
    use tapesched::runtime::{XlaSimpleDp, ARTIFACT_DIR};
    use tapesched::sched::simpledp_dense::dense_cost;

    fn backend() -> Option<XlaSimpleDp> {
        let b = XlaSimpleDp::new(ARTIFACT_DIR).ok()?;
        if b.buckets().is_empty() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            None
        } else {
            Some(b)
        }
    }

    #[test]
    fn xla_cost_matches_exact_on_random_instances() {
        let Some(b) = backend() else { return };
        let mut rng = Rng::new(0x71A);
        let cfg = InstanceGenConfig {
            min_files: 1,
            max_files: 14,
            max_size: 60,
            max_gap: 40,
            max_x: 8,
            max_u: 50,
        };
        for case in 0..60 {
            let inst = random_instance(&mut rng, &cfg);
            let exact = dense_cost(&inst);
            let xla = b.cost(&inst).expect("fits smallest bucket");
            assert_eq!(xla, exact, "case {case}: {inst:?}");
        }
    }

    #[test]
    fn xla_schedule_cost_matches_exact_everywhere() {
        let Some(b) = backend() else { return };
        let mut rng = Rng::new(0x71B);
        let cfg = InstanceGenConfig {
            min_files: 2,
            max_files: 12,
            ..Default::default()
        };
        for _ in 0..40 {
            let inst = random_instance(&mut rng, &cfg);
            let sched = b.try_schedule(&inst).unwrap();
            let exact_sched = SimpleDp.schedule(&inst);
            assert_eq!(
                evaluate(&inst, &sched).cost,
                evaluate(&inst, &exact_sched).cost,
                "XLA reconstruction must achieve the exact cost"
            );
        }
    }

    #[test]
    fn xla_handles_byte_scale_positions() {
        // GB-scale byte positions (the real dataset's regime): the
        // POS_SCALE rescaling must keep f64 exact enough for i128 equality
        // after rounding.
        let Some(b) = backend() else { return };
        let mut rng = Rng::new(0x71C);
        let cfg = InstanceGenConfig {
            min_files: 2,
            max_files: 10,
            max_size: 170_000, // scaled ×1e6 below
            max_gap: 120_000,
            max_x: 9,
            max_u: 30_000,
        };
        for _ in 0..20 {
            let small = random_instance(&mut rng, &cfg);
            let files = small
                .files()
                .iter()
                .map(|f| tapesched::model::ReqFile {
                    l: f.l * 1_000_000,
                    r: f.r * 1_000_000,
                    x: f.x,
                })
                .collect();
            let inst = tapesched::model::Instance::new(
                small.tape_len() * 1_000_000,
                small.u() * 1_000_000,
                files,
            )
            .unwrap();
            assert_eq!(b.cost(&inst).unwrap(), dense_cost(&inst));
        }
    }

    #[test]
    fn xla_agrees_on_adversarial_instance() {
        let Some(b) = backend() else { return };
        for z in [5u64, 10, 20] {
            let inst = simpledp_five_thirds(z);
            if b.bucket_for(&inst).is_none() {
                continue; // n = 2z²+z+1 outgrows the shipped buckets fast
            }
            assert_eq!(b.cost(&inst).unwrap(), dense_cost(&inst), "z={z}");
        }
    }

    #[test]
    fn bucket_routing_picks_smallest_fit() {
        let Some(b) = backend() else { return };
        if b.buckets().len() < 2 {
            return;
        }
        let mut rng = Rng::new(0x71D);
        let small = random_instance(
            &mut rng,
            &InstanceGenConfig { min_files: 2, max_files: 8, max_x: 3, ..Default::default() },
        );
        let bucket = b.bucket_for(&small).unwrap();
        for other in b.buckets() {
            if other.fits(&small) {
                assert!(bucket.k * bucket.ns <= other.k * other.ns);
            }
        }
    }

    #[test]
    fn xla_backend_appears_in_selection() {
        // Engine construction works even artifact-less (the backend then
        // serves through its sparse fallback), so selection must succeed.
        match backend_by_name("xla") {
            Ok(b) => assert_eq!(b.id(), "xla"),
            Err(e) => {
                // Real bindings may fail client construction in exotic
                // environments; the error must at least be descriptive.
                assert!(e.contains("xla"), "{e}");
            }
        }
    }
}
