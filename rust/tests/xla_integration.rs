//! Cross-layer integration: the AOT-compiled XLA SimpleDP engine vs the
//! exact Rust implementation over random and adversarial instances.
//!
//! Gated on `artifacts/` (produced by `make artifacts`); every test skips
//! cleanly when artifacts are absent so `cargo test` works pre-build.

use tapesched::model::adversarial::simpledp_five_thirds;
use tapesched::runtime::{XlaSimpleDp, ARTIFACT_DIR};
use tapesched::sched::simpledp_dense::dense_cost;
use tapesched::sched::{Scheduler, SimpleDp};
use tapesched::sim::evaluate;
use tapesched::testkit::{random_instance, InstanceGenConfig};
use tapesched::util::rng::Rng;

fn backend() -> Option<XlaSimpleDp> {
    let b = XlaSimpleDp::new(ARTIFACT_DIR).ok()?;
    if b.buckets().is_empty() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    } else {
        Some(b)
    }
}

#[test]
fn xla_cost_matches_exact_on_random_instances() {
    let Some(b) = backend() else { return };
    let mut rng = Rng::new(0x71A);
    let cfg = InstanceGenConfig {
        min_files: 1,
        max_files: 14,
        max_size: 60,
        max_gap: 40,
        max_x: 8,
        max_u: 50,
    };
    for case in 0..60 {
        let inst = random_instance(&mut rng, &cfg);
        let exact = dense_cost(&inst);
        let xla = b.cost(&inst).expect("fits smallest bucket");
        assert_eq!(xla, exact, "case {case}: {inst:?}");
    }
}

#[test]
fn xla_schedule_cost_matches_exact_everywhere() {
    let Some(b) = backend() else { return };
    let mut rng = Rng::new(0x71B);
    let cfg = InstanceGenConfig {
        min_files: 2,
        max_files: 12,
        ..Default::default()
    };
    for _ in 0..40 {
        let inst = random_instance(&mut rng, &cfg);
        let sched = b.try_schedule(&inst).unwrap();
        let exact_sched = SimpleDp.schedule(&inst);
        assert_eq!(
            evaluate(&inst, &sched).cost,
            evaluate(&inst, &exact_sched).cost,
            "XLA reconstruction must achieve the exact cost"
        );
    }
}

#[test]
fn xla_handles_byte_scale_positions() {
    // GB-scale byte positions (the real dataset's regime): the POS_SCALE
    // rescaling must keep f64 exact enough for i128 equality after
    // rounding.
    let Some(b) = backend() else { return };
    let mut rng = Rng::new(0x71C);
    let cfg = InstanceGenConfig {
        min_files: 2,
        max_files: 10,
        max_size: 170_000, // scaled ×1e6 below
        max_gap: 120_000,
        max_x: 9,
        max_u: 30_000,
    };
    for _ in 0..20 {
        let small = random_instance(&mut rng, &cfg);
        let files = small
            .files()
            .iter()
            .map(|f| tapesched::model::ReqFile {
                l: f.l * 1_000_000,
                r: f.r * 1_000_000,
                x: f.x,
            })
            .collect();
        let inst = tapesched::model::Instance::new(
            small.tape_len() * 1_000_000,
            small.u() * 1_000_000,
            files,
        )
        .unwrap();
        assert_eq!(b.cost(&inst).unwrap(), dense_cost(&inst));
    }
}

#[test]
fn xla_agrees_on_adversarial_instance() {
    let Some(b) = backend() else { return };
    for z in [5u64, 10, 20] {
        let inst = simpledp_five_thirds(z);
        if b.bucket_for(&inst).is_none() {
            continue; // n = 2z²+z+1 outgrows the shipped buckets fast
        }
        assert_eq!(b.cost(&inst).unwrap(), dense_cost(&inst), "z={z}");
    }
}

#[test]
fn bucket_routing_picks_smallest_fit() {
    let Some(b) = backend() else { return };
    if b.buckets().len() < 2 {
        return;
    }
    let mut rng = Rng::new(0x71D);
    let small = random_instance(
        &mut rng,
        &InstanceGenConfig { min_files: 2, max_files: 8, max_x: 3, ..Default::default() },
    );
    let bucket = b.bucket_for(&small).unwrap();
    for other in b.buckets() {
        if other.fits(&small) {
            assert!(bucket.k * bucket.ns <= other.k * other.ns);
        }
    }
}
