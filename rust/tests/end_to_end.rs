//! End-to-end integration: dataset pipeline → evaluation harness →
//! coordinator service, exercising the public API the way the CLI and the
//! examples do.

use std::sync::Arc;

use tapesched::analysis::report::run_evaluation;
use tapesched::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, ReadRequest};
use tapesched::dataset::{
    dataset_stats, generate_dataset, load_dataset, write_dataset, GeneratorConfig,
};
use tapesched::sched::{paper_schedulers, scheduler_by_name};
use tapesched::sim::{DriveParams, LibrarySim, TapeJob};
use tapesched::util::rng::Rng;

fn small_cfg(n_tapes: usize) -> GeneratorConfig {
    GeneratorConfig {
        n_tapes,
        nf: (30, 60.0, 70.0, 150),
        nreq: (5, 12.0, 14.0, 25),
        n: (10, 40.0, 50.0, 120),
        ..Default::default()
    }
}

#[test]
fn dataset_roundtrip_through_disk() {
    let dir = std::env::temp_dir().join(format!("tapesched_e2e_{}", std::process::id()));
    let ds = generate_dataset(&small_cfg(6));
    write_dataset(&dir, &ds).unwrap();
    let loaded = load_dataset(&dir).unwrap();
    assert_eq!(loaded.tapes.len(), ds.tapes.len());
    for (a, b) in ds.tapes.iter().zip(&loaded.tapes) {
        assert_eq!(a.tape.name, b.tape.name);
        assert_eq!(a.tape.files, b.tape.files);
        assert_eq!(a.requests, b.requests);
    }
    // Stats identical through the round trip.
    let sa = dataset_stats(&ds);
    let sb = dataset_stats(&loaded);
    assert_eq!(sa.total_files, sb.total_files);
    assert_eq!(sa.total_requests, sb.total_requests);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn full_evaluation_reproduces_expected_ordering() {
    // The qualitative "shape" of Figures 14–16 on a small sampled dataset:
    // DP optimal everywhere; SimpleDP/LogDP(5) dominate the FGS family in
    // aggregate; NoDetour trails.
    let ds = generate_dataset(&small_cfg(14));
    let [_, _, u_avg] = ds.paper_u_values();
    let table = run_evaluation(&ds, &paper_schedulers(), u_avg, None);

    let total = |name: &str| -> i128 {
        table
            .records
            .iter()
            .filter(|r| r.algorithm == name)
            .map(|r| r.cost)
            .sum()
    };
    let dp = total("DP");
    assert!(dp <= total("SimpleDP"));
    assert!(total("SimpleDP") <= total("GS"));
    assert!(total("LogDP(5)") <= total("LogDP(1)"));
    assert!(total("GS") < total("NoDetour"), "detours must pay off at dataset scale");

    // Profiles: DP-normalized curves reach 1.0 by τ = ∞-ish for sane algos.
    for c in table.profiles("DP") {
        let last = c.points.last().unwrap().fraction;
        assert!(last > 0.0, "{} never within 50% of OPT?", c.algorithm);
    }
}

#[test]
fn coordinator_full_stack_improves_with_better_policy() {
    let ds = generate_dataset(&small_cfg(8));
    let mut results = Vec::new();
    for policy in ["NoDetour", "SimpleDP"] {
        let coord = Coordinator::start(
            CoordinatorConfig {
                n_drives: 4,
                batcher: BatcherConfig {
                    window: std::time::Duration::from_millis(1),
                    max_batch: 512,
                    ..BatcherConfig::default()
                },
                drive: DriveParams::default(),
                ..CoordinatorConfig::default()
            },
            ds.tapes.iter().map(|t| t.tape.clone()),
            Arc::from(scheduler_by_name(policy).unwrap()),
        );
        let mut rng = Rng::new(42);
        let n = 2_000u64;
        for id in 0..n {
            let t = &ds.tapes[rng.below(ds.tapes.len() as u64) as usize];
            // Skewed file popularity: detours earn their keep.
            let f = rng.zipf(t.tape.n_files() as u64, 1.2) as usize - 1;
            assert!(coord
                .submit(ReadRequest { id, tape: t.tape.name.clone(), file_index: f })
                .is_ok());
        }
        let (completions, m) = coord.finish();
        assert_eq!(completions.len() as u64, n);
        assert_eq!(m.completed, n);
        results.push((policy, m.mean_service_s));
    }
    let (nd, sdp) = (results[0].1, results[1].1);
    assert!(
        sdp <= nd * 1.001,
        "SimpleDP mean service {sdp} should not exceed NoDetour {nd}"
    );
}

#[test]
fn library_sim_serves_dataset_jobs() {
    let ds = generate_dataset(&small_cfg(10));
    let policy = scheduler_by_name("LogDP(1)").unwrap();
    let params = DriveParams::default();
    let u = params.uturn_bytes();
    let jobs: Vec<TapeJob> = ds
        .tapes
        .iter()
        .enumerate()
        .map(|(i, t)| TapeJob {
            tape_name: t.tape.name.clone(),
            arrival_s: i as f64 * 5.0,
            instance: t.instance(u).unwrap(),
        })
        .collect();
    let sim = LibrarySim::new(params, 3, policy.as_ref());
    let (results, metrics) = sim.run(jobs);
    assert_eq!(results.len(), 10);
    assert_eq!(metrics.jobs, 10);
    assert!(metrics.drive_utilization > 0.0 && metrics.drive_utilization <= 1.0);
    assert!(metrics.mean_latency_s >= metrics.mean_service_s);
    // Every job's completion respects causality.
    for r in &results {
        assert!(r.done_s >= r.mount_s);
        assert!(r.mean_latency_s >= r.mean_service_s);
    }
}

#[test]
fn paper_u_values_follow_the_rule() {
    let ds = generate_dataset(&small_cfg(5));
    let [u0, u_half, u_avg] = ds.paper_u_values();
    assert_eq!(u0, 0);
    assert_eq!(u_half, ds.avg_segment_size() / 2);
    assert_eq!(u_avg, ds.avg_segment_size());
    // On the full default dataset the average-segment U is in the tens of
    // GB, like the paper's 28,509,500,000.
    let full = generate_dataset(&GeneratorConfig::default());
    let avg = full.avg_segment_size();
    assert!(
        (10_000_000_000..60_000_000_000).contains(&avg),
        "avg segment size {avg} should be tens of GB"
    );
}
