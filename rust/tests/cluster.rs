//! Integration tests for the multi-library cluster layer: consistent-hash
//! ring stability (bounded key movement, byte-deterministic routing), the
//! live sharded cluster behind the closed-loop driver, and end-to-end
//! byte-stability of sharded replay QoS JSON.

use std::sync::Arc;
use std::time::Duration;

use tapesched::cluster::{Cluster, ClusterConfig, HashRing};
use tapesched::coordinator::{BatcherConfig, CoordinatorConfig};
use tapesched::model::Tape;
use tapesched::replay::{
    drive_closed_loop, reports_json, run_replay, PoissonArrivals, ReplayConfig, RequestMix,
};
use tapesched::sched::scheduler_by_name;
use tapesched::sim::DriveParams;

fn tape_names(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("IN2P3-TAPE-{i:05}")).collect()
}

/// Adding one shard to an N-shard ring must (a) move every remapped key
/// *to* the new shard — the defining consistent-hashing property, exact,
/// not statistical — and (b) move roughly `keys/(N+1)` keys, the bounded-
/// movement contract (vnodes keep the variance small; the bound below is
/// ~1.5× the expectation, many standard deviations of slack at 256
/// vnodes).
#[test]
fn adding_a_shard_moves_a_bounded_fraction_to_the_newcomer() {
    let keys = tape_names(10_000);
    let n_shards = 4;
    let mut ring = HashRing::new(n_shards, 256);
    let before: Vec<usize> = keys.iter().map(|k| ring.route(k)).collect();
    let new_id = ring.add_shard();
    let after: Vec<usize> = keys.iter().map(|k| ring.route(k)).collect();

    let mut moved = 0;
    for (b, a) in before.iter().zip(&after) {
        if b != a {
            assert_eq!(*a, new_id, "a remapped key must move to the new shard");
            moved += 1;
        }
    }
    assert!(moved > 0, "the new shard must take over some keys");
    let expected = keys.len() / (n_shards + 1);
    let bound = expected + expected / 2; // (keys/(N+1)) · 1.5
    assert!(
        moved <= bound,
        "moved {moved} keys, bound {bound} (expected ≈{expected})"
    );
}

/// Removing a shard must remap exactly the keys it owned, nothing else.
#[test]
fn removing_a_shard_only_remaps_its_own_keys() {
    let keys = tape_names(5_000);
    let mut ring = HashRing::new(5, 128);
    let victim = 2;
    let before: Vec<usize> = keys.iter().map(|k| ring.route(k)).collect();
    assert!(ring.remove_shard(victim));
    let after: Vec<usize> = keys.iter().map(|k| ring.route(k)).collect();
    for (i, (b, a)) in before.iter().zip(&after).enumerate() {
        if *b == victim {
            assert_ne!(*a, victim, "key {i} still routes to the removed shard");
        } else {
            assert_eq!(b, a, "key {i} moved although its shard survived");
        }
    }
}

/// Routing is byte-deterministic: two independently constructed rings with
/// the same shape — and the same ring after an add/remove round trip of an
/// *unrelated* shard — route every key identically.
#[test]
fn routing_is_byte_deterministic_across_runs() {
    let keys = tape_names(2_000);
    let a = HashRing::new(6, 64);
    let b = HashRing::new(6, 64);
    let routes: Vec<usize> = keys.iter().map(|k| a.route(k)).collect();
    assert_eq!(routes, keys.iter().map(|k| b.route(k)).collect::<Vec<_>>());

    // Membership round trip: removing a shard and re-adding one disturbs
    // only arcs belonging to the membership change, deterministically.
    let mut c = HashRing::new(6, 64);
    let before: Vec<usize> = keys.iter().map(|k| c.route(k)).collect();
    c.remove_shard(3);
    let id = c.add_shard();
    assert_eq!(id, 6);
    let after: Vec<usize> = keys.iter().map(|k| c.route(k)).collect();
    for (b, a) in before.iter().zip(&after) {
        if *b != 3 && *a != id {
            assert_eq!(b, a, "an uninvolved key moved across the round trip");
        }
    }
}

/// The live cluster serves a closed-loop workload end to end through the
/// same driver the single coordinator uses (`RequestSink`), with per-shard
/// metrics that reconcile at the rollup.
#[test]
fn live_cluster_serves_closed_loop_workload() {
    let tapes: Vec<Tape> = (0..32)
        .map(|i| Tape::from_sizes(format!("TAPE{i:03}"), &[1_000; 30]))
        .collect();
    let cluster = Cluster::start(
        ClusterConfig {
            n_shards: 4,
            vnodes: 64,
            shard: CoordinatorConfig {
                n_drives: 2,
                batcher: BatcherConfig {
                    window: Duration::from_millis(2),
                    max_batch: 64,
                    ..BatcherConfig::default()
                },
                drive: DriveParams {
                    mount_s: 0.5,
                    unmount_s: 0.2,
                    bytes_per_s: 1e9,
                    uturn_s: 0.01,
                    n_arms: 0,
                },
                ..CoordinatorConfig::default()
            },
            ..ClusterConfig::default()
        },
        tapes.clone(),
        Arc::new(tapesched::sched::Gs),
    );
    let mut model =
        PoissonArrivals::new(RequestMix::new(&tapes), 200.0, f64::INFINITY, 11);
    let stats = drive_closed_loop(
        &cluster,
        &tapes,
        &mut model,
        64,
        Duration::from_millis(1),
        400,
    );
    assert_eq!(stats.submitted, 400);
    assert_eq!(stats.dropped, 0);
    let (completions, m) = cluster.finish();
    assert_eq!(completions.len(), 400);
    assert_eq!(m.completed, 400);
    assert_eq!(m.routed_total, 400 + stats.busy_retries);
    assert_eq!(m.shards.len(), 4);
    assert_eq!(m.shards.iter().map(|s| s.metrics.completed).sum::<u64>(), 400);
    assert!(m.imbalance_ratio() >= 1.0);
}

/// Acceptance gate: a sharded replay's QoS JSON is byte-stable for a fixed
/// seed, per-shard sections reconcile with the fleet, and every shard that
/// owns tapes appears in the report.
#[test]
fn sharded_replay_qos_json_is_byte_stable() {
    let catalog: Vec<Tape> = (0..24)
        .map(|i| Tape::from_sizes(format!("TAPE{i:03}"), &[2_000; 40]))
        .collect();
    let cfg = ReplayConfig {
        n_drives: 2,
        n_shards: 4,
        vnodes: 64,
        batcher: BatcherConfig {
            window: Duration::from_millis(100),
            max_batch: 128,
            ..BatcherConfig::default()
        },
        drive: DriveParams {
            mount_s: 2.0,
            unmount_s: 1.0,
            bytes_per_s: 1e9,
            uturn_s: 0.1,
            n_arms: 0,
        },
        ..ReplayConfig::default()
    };
    let run = || {
        let policy = scheduler_by_name("SimpleDP").unwrap();
        let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 50.0, 10.0, 7);
        run_replay(&cfg, &catalog, policy.as_ref(), &mut model, 7, 10.0)
    };
    let (ra, oa) = run();
    let (rb, ob) = run();
    assert!(ra.completed > 300, "expected ~500 requests, got {}", ra.completed);
    assert_eq!(oa.completions, ob.completions);
    assert_eq!(ra, rb);
    assert_eq!(
        reports_json(&[ra.clone()]),
        reports_json(&[rb]),
        "sharded QoS JSON must be byte-identical for a fixed seed"
    );
    // Structure: 4 shard entries reconciling with the fleet counters.
    assert_eq!(ra.shards.len(), 4);
    assert_eq!(ra.shards.iter().map(|s| s.completed).sum::<u64>(), ra.completed);
    assert_eq!(ra.shards.iter().map(|s| s.tapes).sum::<usize>(), 24);
    for s in &ra.shards {
        if s.tapes == 0 {
            assert_eq!(s.completed, 0, "a tapeless shard cannot serve");
        }
        if s.completed > 0 {
            assert!(s.latency.p50_s <= s.latency.p999_s);
        }
    }
}

/// `--shards 1` reproduces the single-library replay exactly: the fleet
/// percentile objects in the JSON are byte-identical to a config that
/// never mentions sharding (the default), for the same seed.
#[test]
fn one_shard_reproduces_the_single_library_replay() {
    let catalog: Vec<Tape> = (0..8)
        .map(|i| Tape::from_sizes(format!("TAPE{i:03}"), &[2_000; 40]))
        .collect();
    let base = ReplayConfig {
        n_drives: 3,
        batcher: BatcherConfig {
            window: Duration::from_millis(100),
            max_batch: 128,
            ..BatcherConfig::default()
        },
        drive: DriveParams {
            mount_s: 2.0,
            unmount_s: 1.0,
            bytes_per_s: 1e9,
            uturn_s: 0.1,
            n_arms: 0,
        },
        ..ReplayConfig::default()
    };
    assert_eq!(base.n_shards, 1, "default config is the single-library replay");
    let explicit = ReplayConfig { n_shards: 1, vnodes: 64, ..base.clone() };
    let run = |cfg: &ReplayConfig| {
        let policy = scheduler_by_name("GS").unwrap();
        let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 40.0, 8.0, 13);
        run_replay(cfg, &catalog, policy.as_ref(), &mut model, 13, 8.0)
    };
    let (ra, oa) = run(&base);
    let (rb, ob) = run(&explicit);
    assert_eq!(oa.completions, ob.completions, "identical completion logs");
    assert_eq!(ra.latency, rb.latency, "identical fleet percentiles");
    assert_eq!(ra.service, rb.service);
    assert_eq!(reports_json(&[ra]), reports_json(&[rb]), "byte-identical JSON");
}
