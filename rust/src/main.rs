//! `tapesched` — CLI for the LTSP scheduling framework.
//!
//! Subcommands:
//!
//! - `generate`       — synthesize the IN2P3-calibrated dataset to disk
//! - `dataset-stats`  — Tables 1–2 and the Fig. 17–19 scatter CSV
//! - `figures`        — regenerate Fig. 14/15/16 + the §5.3 timing table
//! - `adversarial`    — the §4.5 / Lemma 2 adversarial instances
//! - `solve`          — run one algorithm on one tape of a dataset
//! - `serve`          — run the coordinator serving demo (wall clock)
//! - `replay`         — virtual-time workload replay with QoS JSON reports
//! - `coordinator`    — networked fleet: listen for workers + clients (TCP)
//! - `worker`         — networked fleet: serve one shard for a coordinator
//! - `rpc-tax`        — in-process vs loopback-networked QoS comparison
//! - `spans`          — per-stage latency breakdown of a `--trace-out` dump
//! - `audit`          — determinism & invariant lint over the source tree
//!
//! Run `tapesched <cmd> --help` equivalent: flags are documented below in
//! each handler (and in README.md).

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use tapesched::analysis::{
    cartridge_summary, mount_summary, qos_comparison, report::run_evaluation_with_threads,
    shard_summary,
};
use tapesched::audit;
use tapesched::cli::Args;
use tapesched::cluster::{Cluster, ClusterConfig, ClusterMetricsSnapshot, HashRing};
use tapesched::coordinator::{BatcherConfig, Completion, Coordinator, CoordinatorConfig};
use tapesched::dataset::{
    dataset_stats, generate_dataset, load_dataset, open_trace_file, read_trace_file,
    synth_catalog, synth_raw_log, write_dataset, Dataset, GeneratorConfig,
};
use tapesched::model::{virtual_lb, Tape};
use tapesched::net::{CoordinatorServerConfig, LoopbackFleet, RemoteCluster};
use tapesched::obs::{
    breakdown, check_chains, parse_jsonl, render_breakdown, ExpositionServer, Registry,
    TraceRecorder, DEFAULT_TRACE_CAP,
};
use tapesched::replay::{
    busy_ratio, drive_closed_loop, reports_json, round_robin_assignment, run_replay_parallel,
    run_replay_traced, run_replay_with_arena, scan_trace, worker_busy_us, ArrivalModel,
    AssignMode, BurstyArrivals, DiurnalArrivals, LiveDriveStats, LoopMode, PoissonArrivals,
    ReplayArena, ReplayConfig, ReplayOutcome, RequestMix, StreamingTraceArrivals, TraceArrivals,
    WorkerBalance, DEFAULT_TRACE_WINDOW,
};
use tapesched::runtime::{
    backend_by_name, dense_cache_stats, incremental_stats, BackendPolicy,
};
use tapesched::sched::{paper_schedulers, scheduler_by_name, Scheduler};
use tapesched::sim::{evaluate, Affinity, DriveParams};
use tapesched::util::rng::Rng;
use tapesched::util::stats::percentile_sorted;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        usage();
        std::process::exit(2);
    };
    let args = Args::parse(&argv[1..]);
    match cmd {
        "generate" => cmd_generate(&args),
        "dataset-stats" => cmd_dataset_stats(&args),
        "figures" => cmd_figures(&args),
        "adversarial" => cmd_adversarial(&args),
        "solve" => cmd_solve(&args),
        "draw" => cmd_draw(&args),
        "serve" => cmd_serve(&args),
        "replay" => cmd_replay(&args),
        "coordinator" => cmd_coordinator(&args),
        "worker" => cmd_worker(&args),
        "rpc-tax" => cmd_rpc_tax(&args),
        "spans" => cmd_spans(&args),
        "audit" => cmd_audit(&args),
        "help" | "--help" | "-h" => usage(),
        other => {
            eprintln!("error: unknown command `{other}`");
            usage();
            std::process::exit(2);
        }
    }
}

fn usage() {
    eprintln!(
        "tapesched — Linear Tape Scheduling (Honoré, Simon, Suter 2021)

USAGE: tapesched <COMMAND> [FLAGS]

COMMANDS:
  generate        --out DIR [--seed N] [--tapes N]
  dataset-stats   [--data DIR] [--scatter FILE]
  figures         --experiment fig14|fig15|fig16|timing|all
                  [--data DIR] [--out DIR] [--max-k N] [--algos a,b,…]
                  [--threads N]
  adversarial     [--z N]
  solve           --tape NAME --algo NAME [--data DIR] [--u N]
                  [--backend dense|incremental|xla]
  draw            --out FILE.svg [--tape NAME] [--algo NAME] [--u N]
                  [--backend dense|incremental|xla]
  serve           [--policy NAME] [--drives N] [--requests N] [--seed N]
                  [--cap N] [--backlog N] [--backend dense|incremental|xla]
                  [--shards N] [--vnodes K] [--affinity none|lru]
                  [--arms N] [--exclusive-tapes on|off]
                  [--trace-out FILE.jsonl] [--trace-cap N]
                  [--metrics-listen ADDR] [--metrics-linger-ms N]
  replay          [--arrivals poisson|bursty|diurnal|trace] [--rate R]
                  [--duration S] [--policy NAME[,NAME…]] [--drives N] [--seed N]
                  [--mode open|closed] [--cap N] [--window-ms N] [--max-batch N]
                  [--backlog N] [--data DIR] [--tapes N] [--out FILE.json]
                  [--backend dense|incremental|xla] [--shards N] [--vnodes K]
                  [--arms N] [--affinity none|lru] [--exclusive-tapes on|off]
                  [--trace-file PATH] [--smoke] [--threads N] [--steal]
                  [--trace-out FILE.jsonl] [--trace-cap N]
  coordinator     [--listen ADDR] [--shards N] [--policy NAME] [--drives N]
                  [--seed N] [--tapes N] [--data DIR] [--vnodes K]
                  [--window-ms N] [--max-batch N] [--backlog N]
                  [--affinity none|lru] [--arms N] [--exclusive-tapes on|off]
                  [--kill-shard I --kill-after M]
                  [--push-ms N] [--metrics-listen ADDR]
  worker          --connect ADDR
  rpc-tax         [--policy NAME[,NAME…]] [--shards N] [--drives N]
                  [--vnodes K] [--requests N] [--seed N] [--tapes N]
                  [--data DIR] [--out FILE.json] [--kill-after M]
                  [--push-metrics] [--push-ms N]
  spans           --in FILE.jsonl [--check]
  audit           [--fix-waivers] [PATH]
  help

Without --data, commands use the built-in calibrated generator (seed 0x12P32021).
--backend picks the SimpleDP evaluation backend (dense = pure Rust, the
default; incremental = dense plus a re-solve table that extends on
one-file appends instead of recomputing; xla = the PJRT engine, requires
building with --features xla).
`replay` runs in virtual time (deterministic for a fixed seed) and prints a
QoS JSON document — p50/p95/p99/p99.9 latencies per policy — to stdout (or
--out); the human-readable comparison table goes to stderr. --threads N
fans the shards of an open-loop replay out over N worker threads; the
merged report is byte-identical to the single-threaded one (open-loop
only — the closed-loop in-flight cap couples shards — and incompatible
with --trace-out, which records a single engine's span stream). Shards
land on workers by a deterministic pre-pass: arrival weights are counted
per shard, then greedily bin-packed (LPT) onto the least-loaded worker;
--steal additionally re-packs at fixed virtual-time epoch barriers,
moving still-pending shards off overloaded workers (each accepted move
is a steal_event). Either way the per-worker busy times, the max/min
balance ratio, its round-robin counterfactual, and the steal count print
to stderr — never into the QoS JSON.
--shards N (serve, replay) shards the catalog over N libraries behind a
consistent-hash router (--vnodes points per shard); the replay report then
carries a per-shard QoS breakdown next to the fleet-wide one, with --drives
drives per shard. --arms N (replay) bounds each shard's robot-arm pool —
every mount/unmount occupies an arm, queueing when all are busy — and
--affinity lru (serve, replay) keeps tapes mounted so repeat batches skip
the mount (remount hits, LRU eviction); either flag adds arm-wait /
mount-wait / drive-wait ladders and remount counters to the QoS report.
--exclusive-tapes on (the default) enforces the single-cartridge
constraint — a tape can be threaded in one drive at a time, batches whose
tape is busy elsewhere park on a per-cartridge waitlist, and the report
gains cartridge_parks + a cartridge_wait ladder (fleet-wide and per
shard); --exclusive-tapes off with --arms 0 --affinity none reproduces
the legacy replay byte for byte. For serve, --arms N bounds the live
robot: each mount/unmount reserves an interval on a wall-clock arm
timeline, workers sleep to the reservation edge, and arm-wait /
cartridge-wait surface in the metrics.
`coordinator` + `worker` split the cluster across processes: the
coordinator owns the ring and routes client submits to TCP workers, each
worker runs one shard's real Coordinator over its ring partition of the
catalog (wire format: rust/README.md). `serve --connect ADDR` / `replay
--connect ADDR` drive such a fleet through the same closed-loop driver —
launch the client with the coordinator's --seed/--tapes/--data so both
sides derive the same catalog. `rpc-tax` runs one seeded stream through
the in-process cluster AND a loopback-networked fleet: counters and tour
costs must match bit for bit, the latency-ladder delta (p99.9) is the RPC
tax; --kill-after M adds a worker-crash run that must keep the fleet-wide
drain invariant (submitted = completed + shed).
--trace-file replays an on-disk timestamped log
(`timestamp_ns<TAB>tape<TAB>file_id`, see rust/README.md). --smoke is the
fast deterministic CI preset (2 virtual seconds at 100 rps over 48 tapes
unless overridden).
Observability: --trace-out FILE.jsonl (serve, replay) records one span per
pipeline stage per completed request — submit, route, batch_seal,
drive_wait, cartridge_wait, arm_wait, mount, exec, complete — into a
fixed-capacity ring buffer (--trace-cap spans, default 2^20) and dumps it
as JSONL at drain; the recorder is a pure observer, so a traced replay's
QoS JSON is byte-identical to an untraced one. `spans --in FILE.jsonl`
renders the per-stage latency breakdown (--check additionally verifies
every request carries one full monotone chain). --metrics-listen ADDR
(serve, coordinator) serves a Prometheus text-format scrape page
(`tapesched_submitted_total`, `tapesched_latency_seconds_bucket{le=…}`,
per-shard labels) over HTTP/1.0, rendered from the same counters the
drain report prints; serve's --metrics-linger-ms keeps the page up that
long after the drain so scrapers can read the final numbers.
--push-ms N (coordinator) has every worker push a metrics snapshot to the
coordinator on that interval (wire tags 13–14) instead of being polled;
clients connected with the push-fed gauge then track in-flight locally
and skip one MetricsPull round trip per submit. `rpc-tax --push-metrics`
measures exactly that recovery: the loopback closed loop runs once in
pull mode and once in push mode, and the report gains a push_report
section with both submits/s figures.
`audit` runs the built-in determinism & invariant linter over the crate
sources (default PATH: rust/src, or src when run from rust/): wall-clock
reads and hash-order iteration in the deterministic replay/scheduling
zone, unwrap/expect on the networked request path, encode/decode tag
parity in net/wire.rs, and drain-invariant references in files that
mutate the submitted/completed/shed ledger. Findings print as
file:line: [rule-id] with a one-line hint; suppress a line with
`audit:allow(rule-id) reason` in a `//` comment (unused waivers are
themselves findings; --fix-waivers deletes them). Exit 0 clean, 1 with
findings. CI runs this gate before clippy (scripts/ci.sh)."
    );
}

/// Load `--data DIR` or fall back to the calibrated generator.
fn dataset_from(args: &Args) -> Dataset {
    match args.get("data") {
        Some(dir) => match load_dataset(Path::new(dir)) {
            Ok(ds) => {
                eprintln!("loaded {} tapes from {dir}", ds.tapes.len());
                ds
            }
            Err(e) => {
                eprintln!("error loading dataset: {e}");
                std::process::exit(1);
            }
        },
        None => {
            let tapes = args.get_parsed_or("tapes", 169usize);
            let seed = args.get_parsed_or("seed", GeneratorConfig::default().seed);
            generate_dataset(&GeneratorConfig { n_tapes: tapes, seed, ..Default::default() })
        }
    }
}

/// Whether `--backend dense` was selected — the only configuration in
/// which the dense result-cache counters describe the serving path.
fn dense_backend_selected(args: &Args) -> bool {
    matches!(args.get("backend"), Some(b) if b.eq_ignore_ascii_case("dense"))
}

/// Whether `--backend incremental` was selected — the only configuration
/// in which the append/rebuild repair counters describe the serving path.
fn incremental_backend_selected(args: &Args) -> bool {
    matches!(args.get("backend"), Some(b) if b.eq_ignore_ascii_case("incremental"))
}

/// Print the parallel-replay balance evidence to stderr (never into the
/// QoS JSON — the report stays byte-identical across thread counts).
/// Includes the counterfactual round-robin ratio computed from the same
/// outcome, so a single run shows what the weighted assignment bought.
fn print_worker_balance(balance: &WorkerBalance, outcome: &ReplayOutcome) {
    let threads = balance.worker_busy_us.len();
    let rr = round_robin_assignment(balance.assignment.len(), threads);
    let rr_busy = worker_busy_us(&rr, threads, &outcome.per_shard);
    let busy: Vec<String> = balance
        .worker_busy_us
        .iter()
        .map(|&us| format!("{:.1}", us as f64 / 1e6))
        .collect();
    eprintln!(
        "worker balance ({:?}): busy_s [{}], max/min {:.2} (round-robin {:.2}), steal_events {}",
        balance.mode,
        busy.join(" "),
        balance.busy_ratio(),
        busy_ratio(&rr_busy),
        balance.steal_events
    );
}

/// Resolve `--<flag>` (an algorithm name) plus the optional `--backend`
/// into a scheduling policy. `--backend` selects the execution engine of
/// the SimpleDP policy, so it only combines with `--<flag> SimpleDP` (the
/// default for every command that accepts it).
fn resolve_policy(args: &Args, flag: &str, default_name: &str) -> Box<dyn Scheduler + Send + Sync> {
    let name = args.get_or(flag, default_name);
    if args.get("backend").is_some() {
        let backend_name =
            args.get_choice_or("backend", &["dense", "incremental", "xla"], "dense");
        if !name.eq_ignore_ascii_case("simpledp") {
            eprintln!(
                "error: --backend selects a SimpleDP backend; it cannot combine with --{flag} {name}"
            );
            std::process::exit(2);
        }
        match backend_by_name(&backend_name) {
            Ok(b) => return Box::new(BackendPolicy::new(b)),
            Err(e) => {
                eprintln!("error: {e}");
                std::process::exit(2);
            }
        }
    }
    match scheduler_by_name(&name) {
        Some(s) => s,
        None => {
            eprintln!("error: unknown algorithm {name}");
            std::process::exit(2);
        }
    }
}

fn cmd_generate(args: &Args) {
    args.reject_unknown(&["out", "seed", "tapes"]);
    let out = PathBuf::from(args.get_or("out", "data/in2p3-synth"));
    let ds = dataset_from(args);
    write_dataset(&out, &ds).unwrap_or_else(|e| {
        eprintln!("error writing dataset: {e}");
        std::process::exit(1);
    });
    println!(
        "wrote {} tapes ({} files, {} unique requested, {} user requests) to {}",
        ds.tapes.len(),
        ds.total_files(),
        ds.total_unique_requests(),
        ds.total_user_requests(),
        out.display()
    );
}

fn cmd_dataset_stats(args: &Args) {
    args.reject_unknown(&["data", "scatter", "seed", "tapes"]);
    let ds = dataset_from(args);
    let st = dataset_stats(&ds);
    print!("{}", st.render_tables());
    if let Some(path) = args.get("scatter") {
        std::fs::write(path, st.scatter_csv()).expect("write scatter CSV");
        println!("scatter data (Figs 17–19) → {path}");
    }
}

fn cmd_figures(args: &Args) {
    args.reject_unknown(&[
        "experiment", "data", "out", "max-k", "algos", "seed", "tapes", "threads",
    ]);
    let experiment = args.get_or("experiment", "all");
    let ds = dataset_from(args);
    let out_dir = PathBuf::from(args.get_or("out", "results"));
    std::fs::create_dir_all(&out_dir).expect("create results dir");
    // Exact DP is O(n_req³·n): cap instance size by default so the full
    // sweep stays tractable; `--max-k 0` removes the cap.
    let max_k = match args.get_parsed_or("max-k", 80usize) {
        0 => None,
        k => Some(k),
    };
    // --threads N caps the sweep's thread pool (default: one per core).
    // The records are identical for any width — this is a machine-share
    // knob, not a result knob.
    let threads = match args.get("threads") {
        None => None,
        Some(_) => match args.get_parsed_or("threads", 0usize) {
            0 => {
                eprintln!("error: --threads must be positive");
                std::process::exit(2);
            }
            n => Some(n),
        },
    };

    let schedulers: Vec<Box<dyn Scheduler + Send + Sync>> = match args.get("algos") {
        None => paper_schedulers(),
        Some(list) => list
            .split(',')
            .map(|n| {
                scheduler_by_name(n.trim()).unwrap_or_else(|| {
                    eprintln!("error: unknown algorithm `{n}`");
                    std::process::exit(2);
                })
            })
            .collect(),
    };

    let [_, u_half, u_avg] = ds.paper_u_values();
    let runs: Vec<(&str, u64)> = match experiment.as_str() {
        "fig14" => vec![("fig14", 0)],
        "fig15" => vec![("fig15", u_avg)],
        "fig16" => vec![("fig16", u_half)],
        "timing" => vec![("timing", u_avg)],
        "all" => vec![("fig14", 0), ("fig15", u_avg), ("fig16", u_half)],
        other => {
            eprintln!("error: unknown experiment `{other}`");
            std::process::exit(2);
        }
    };

    for (name, u) in runs {
        eprintln!("running {name} (U = {u}) on {} tapes…", ds.tapes.len());
        let table = run_evaluation_with_threads(&ds, &schedulers, u, max_k, threads);
        let profile_path = out_dir.join(format!("{name}.csv"));
        std::fs::write(&profile_path, table.profiles_csv("DP")).expect("write profiles");
        let raw_path = out_dir.join(format!("{name}_raw.csv"));
        std::fs::write(&raw_path, table.records_csv()).expect("write records");
        println!("{name}: profiles → {} ; raw → {}", profile_path.display(), raw_path.display());
        println!("median time-to-solution (s):");
        for (algo, t) in table.median_times() {
            println!("  {algo:<12} {t:>12.6}");
        }
    }
}

/// §4.5's adversarial instances: the LogDP ratio→3 family and the Lemma 2
/// 5/3 family, parameterized by z.
fn cmd_adversarial(args: &Args) {
    args.reject_unknown(&["z"]);
    let z = args.get_parsed_or("z", 20u64);
    println!("LogDP worst case (§4.5), z = {z}:");
    let inst = tapesched::model::adversarial::logdp_worst_case(z);
    let dp = tapesched::sched::Dp.schedule(&inst);
    let logdp = tapesched::sched::LogDp::new(1.0).schedule(&inst);
    let gs = tapesched::sched::Gs.schedule(&inst);
    let c_dp = evaluate(&inst, &dp).cost;
    let c_log = evaluate(&inst, &logdp).cost;
    let c_gs = evaluate(&inst, &gs).cost;
    println!("  OPT(DP) = {c_dp}");
    println!("  LogDP(1) = {c_log}  (ratio {:.4})", c_log as f64 / c_dp as f64);
    println!("  GS = {c_gs}  (ratio {:.4})", c_gs as f64 / c_dp as f64);

    println!("SimpleDP 5/3 lower-bound instance (Lemma 2), z = {z}:");
    let inst = tapesched::model::adversarial::simpledp_five_thirds(z);
    let c_dp = evaluate(&inst, &tapesched::sched::Dp.schedule(&inst)).cost;
    let c_sdp = evaluate(&inst, &tapesched::sched::SimpleDp.schedule(&inst)).cost;
    println!("  OPT(DP) = {c_dp}");
    println!(
        "  SimpleDP = {c_sdp}  (ratio {:.4}, → 5/3 as z→∞)",
        c_sdp as f64 / c_dp as f64
    );
}

fn cmd_solve(args: &Args) {
    args.reject_unknown(&["tape", "algo", "data", "u", "seed", "tapes", "backend"]);
    let ds = dataset_from(args);
    let name = args.get_or("tape", &ds.tapes[0].tape.name);
    let Some(tape) = ds.tapes.iter().find(|t| t.tape.name == name) else {
        eprintln!("error: tape {name} not in dataset");
        std::process::exit(1);
    };
    let u = args.get_parsed_or("u", ds.avg_segment_size());
    let algo = resolve_policy(args, "algo", "SimpleDP");
    let inst = tape.instance(u).expect("valid tape");
    let t0 = std::time::Instant::now();
    let sched = algo.schedule(&inst);
    let secs = t0.elapsed().as_secs_f64();
    let out = evaluate(&inst, &sched);
    println!("tape {name}: n_f={} n_req={} n={} U={u}", tape.tape.n_files(), inst.k(), inst.n());
    println!("algorithm {}: {} detours in {secs:.4}s", algo.name(), sched.len());
    println!("  sum of service times = {}", out.cost);
    println!("  mean service time    = {:.1}", out.mean_service_time(&inst));
    println!("  VirtualLB            = {}", virtual_lb(&inst));
    println!("  detours: {:?}", &sched[..sched.len().min(20)]);
}

/// Render a schedule's head trajectory as an SVG (the artifact's draw.py).
fn cmd_draw(args: &Args) {
    args.reject_unknown(&["tape", "algo", "data", "u", "out", "seed", "tapes", "backend"]);
    let ds = dataset_from(args);
    let name = args.get_or("tape", &ds.tapes[0].tape.name);
    let Some(tape) = ds.tapes.iter().find(|t| t.tape.name == name) else {
        eprintln!("error: tape {name} not in dataset");
        std::process::exit(1);
    };
    let u = args.get_parsed_or("u", ds.avg_segment_size());
    let algo = resolve_policy(args, "algo", "SimpleDP");
    let inst = tape.instance(u).expect("valid tape");
    let sched = algo.schedule(&inst);
    let title = format!("{name} — {} ({} detours, U = {u})", algo.name(), sched.len());
    let svg = tapesched::analysis::trajectory_svg(&inst, &sched, &title);
    let out = args.get_or("out", "trajectory.svg");
    std::fs::write(&out, svg).expect("write SVG");
    println!("trajectory → {out}");
}

fn cmd_serve(args: &Args) {
    args.reject_unknown(&[
        "policy", "drives", "requests", "seed", "tapes", "data", "backend", "cap", "backlog",
        "shards", "vnodes", "affinity", "arms", "exclusive-tapes", "connect", "trace-out",
        "trace-cap", "metrics-listen", "metrics-linger-ms",
    ]);
    // --connect ADDR: drive a *networked* fleet (`tapesched coordinator`
    // elsewhere) instead of starting coordinators in-process; every other
    // serving knob then lives on the coordinator's command line.
    if let Some(addr) = args.get("connect") {
        if args.get("trace-out").is_some() || args.get("metrics-listen").is_some() {
            eprintln!(
                "error: --trace-out/--metrics-listen instrument the in-process service; \
                 with --connect they belong on the coordinator's command line"
            );
            std::process::exit(2);
        }
        drive_remote(args, addr);
        return;
    }
    let policy = resolve_policy(args, "policy", "SimpleDP");
    let policy_name = policy.name();
    let n_drives = args.get_parsed_or("drives", 8usize);
    let n_requests = args.get_parsed_or("requests", 5_000u64);
    let seed = args.get_parsed_or("seed", 1u64);
    let cap = args.get_parsed_or("cap", 1_024u64);
    let n_shards = args.get_parsed_or("shards", 1usize);
    let vnodes = args.get_parsed_or("vnodes", 64usize);
    if cap == 0 || args.get_parsed_or("backlog", 1usize) == 0 {
        eprintln!("error: --cap and --backlog must be positive");
        std::process::exit(2);
    }
    if n_shards == 0 || vnodes == 0 {
        eprintln!("error: --shards and --vnodes must be positive");
        std::process::exit(2);
    }
    let affinity = Affinity::from_name(&args.get_choice_or("affinity", &["none", "lru"], "none"))
        .expect("choice already validated");
    let n_arms = args.get_parsed_or("arms", 0usize);
    let exclusive_tapes =
        args.get_choice_or("exclusive-tapes", &["on", "off"], "on") == "on";
    let shard_cfg = CoordinatorConfig {
        n_drives,
        batcher: BatcherConfig {
            max_tape_backlog: args
                .get_parsed_or("backlog", BatcherConfig::default().max_tape_backlog),
            ..BatcherConfig::default()
        },
        drive: DriveParams { n_arms, ..DriveParams::default() },
        affinity,
        exclusive_tapes,
    };
    // Lifecycle tracing and the scrape endpoint instrument one live
    // coordinator; the sharded demo routes through `Cluster`, which owns
    // its shards internally — keep the combination an explicit error
    // rather than silently tracing nothing.
    if n_shards > 1 && (args.get("trace-out").is_some() || args.get("metrics-listen").is_some())
    {
        eprintln!("error: --trace-out/--metrics-listen require --shards 1");
        std::process::exit(2);
    }
    let ds = dataset_from(args);
    let tapes: Vec<Tape> = ds.tapes.iter().map(|t| t.tape.clone()).collect();
    // The same arrival models and closed-loop driver the replay engine
    // evaluates with, here against the real threaded service (timestamps
    // ignored: the demo generates load as fast as the cap allows).
    let mut model =
        PoissonArrivals::new(RequestMix::new(&tapes), 1_000.0, f64::INFINITY, seed);

    if n_shards > 1 {
        // Multi-library cluster: one coordinator per shard behind the
        // consistent-hash router, same driver via the RequestSink trait.
        let cluster = Cluster::start(
            ClusterConfig { n_shards, vnodes, shard: shard_cfg, shard_configs: Vec::new() },
            tapes.iter().cloned(),
            Arc::from(policy),
        );
        let stats = drive_closed_loop(
            &cluster,
            &tapes,
            &mut model,
            cap,
            Duration::from_millis(1),
            n_requests,
        );
        let (completions, m) = cluster.finish();
        println!(
            "policy {policy_name}, {n_shards} shards × {n_drives} drives, {} requests:",
            completions.len()
        );
        println!("  batches dispatched      = {}", m.batches);
        println!("  busy retries / rejected = {} / {}", stats.busy_retries, m.rejected);
        println!("  mean in-tape service    = {:.1} s", m.mean_service_s);
        println!("  mean end-to-end latency = {:.1} s", m.mean_latency_s);
        println!(
            "  shard load max/min      = {} / {} (ratio {:.2})",
            m.max_shard_completed,
            m.min_shard_completed,
            m.imbalance_ratio()
        );
        if affinity == Affinity::Lru {
            println!(
                "  remount hits / misses   = {} / {}",
                m.remount_hits, m.remount_misses
            );
        }
        if exclusive_tapes {
            println!(
                "  cartridge parks         = {} (mean wait {:.3} s, max {:.3} s)",
                m.cartridge_parks, m.mean_cartridge_wait_s, m.max_cartridge_wait_s
            );
        }
        if n_arms > 0 {
            println!(
                "  arm ops / mean wait     = {} / {:.3} s (max {:.3} s)",
                m.arm_ops, m.mean_arm_wait_s, m.max_arm_wait_s
            );
        }
        for s in &m.shards {
            println!(
                "  shard {:<2} routed/completed = {} / {} (p99 {:.1} s)",
                s.shard, s.routed, s.metrics.completed, s.metrics.p99_latency_s
            );
        }
        if dense_backend_selected(args) {
            let (hits, misses) = dense_cache_stats();
            println!("  dense cache hits/misses = {hits} / {misses}");
        }
        if incremental_backend_selected(args) {
            println!(
                "  incremental appends/rebuilds = {} / {}",
                m.incremental_appends, m.incremental_rebuilds
            );
        }
        return;
    }

    let trace = args
        .get("trace-out")
        .map(|_| Arc::new(TraceRecorder::new(args.get_parsed_or("trace-cap", DEFAULT_TRACE_CAP))));
    let coord = Coordinator::start_traced(
        shard_cfg,
        tapes.iter().cloned(),
        Arc::from(policy),
        trace.clone(),
        0,
    );
    // The scrape endpoint renders the coordinator's live SharedMetrics —
    // the registry closure holds the shared state, so the page keeps
    // serving the final numbers through the post-drain linger window.
    let exposition = args.get("metrics-listen").map(|listen| {
        let registry = Arc::new(Registry::new());
        coord.register_exposition(&registry);
        let server =
            net_ok(ExpositionServer::bind(listen, registry), "cannot bind --metrics-listen");
        eprintln!("metrics exposition on http://{}/metrics", server.addr());
        server
    });
    let stats = drive_closed_loop(
        &coord,
        &tapes,
        &mut model,
        cap,
        Duration::from_millis(1),
        n_requests,
    );
    let (completions, m) = coord.finish();
    println!("policy {policy_name}, {n_drives} drives, {} requests:", completions.len());
    println!("  batches dispatched      = {}", m.batches);
    println!("  busy retries / rejected = {} / {}", stats.busy_retries, m.rejected);
    println!("  mean in-tape service    = {:.1} s", m.mean_service_s);
    println!("  mean end-to-end latency = {:.1} s", m.mean_latency_s);
    println!("  p50 / p99 latency       = {:.1} / {:.1} s", m.p50_latency_s, m.p99_latency_s);
    println!("  mean schedule compute   = {:.4} s/batch", m.mean_sched_s_per_batch);
    if affinity == Affinity::Lru {
        println!("  remount hits / misses   = {} / {}", m.remount_hits, m.remount_misses);
    }
    if exclusive_tapes {
        println!(
            "  cartridge parks         = {} (mean wait {:.3} s, max {:.3} s)",
            m.cartridge_parks, m.mean_cartridge_wait_s, m.max_cartridge_wait_s
        );
    }
    if n_arms > 0 {
        println!(
            "  arm ops / mean wait     = {} / {:.3} s (max {:.3} s)",
            m.arm_ops, m.mean_arm_wait_s, m.max_arm_wait_s
        );
    }
    if dense_backend_selected(args) {
        let (hits, misses) = dense_cache_stats();
        println!("  dense cache hits/misses = {hits} / {misses}");
    }
    if incremental_backend_selected(args) {
        println!(
            "  incremental appends/rebuilds = {} / {}",
            m.incremental_appends, m.incremental_rebuilds
        );
        // The drain triple the perf-smoke gate checks (`submitted =
        // completed + shed` with nonzero appends): the incremental path
        // must repair tables, never drop work.
        println!(
            "  drain submitted/completed/shed = {} / {} / {}",
            m.submitted, m.completed, m.shed
        );
    }
    if let (Some(path), Some(trace)) = (args.get("trace-out"), &trace) {
        write_trace(path, trace);
    }
    // Hold the scrape page open after the drain so an external scraper
    // can read the final counters (ci.sh's obs gate does exactly this).
    if let Some(server) = exposition {
        let linger_ms = args.get_parsed_or("metrics-linger-ms", 0u64);
        if linger_ms > 0 {
            std::thread::sleep(Duration::from_millis(linger_ms));
        }
        server.stop();
    }
}

/// Dump a trace recorder as JSONL, reporting span count and any
/// ring-buffer overwrites.
fn write_trace(path: &str, trace: &TraceRecorder) {
    use std::io::Write;
    let file = std::fs::File::create(path).unwrap_or_else(|e| {
        eprintln!("error creating {path}: {e}");
        std::process::exit(1);
    });
    let mut w = std::io::BufWriter::new(file);
    let n = trace.write_jsonl(&mut w).and_then(|n| w.flush().map(|()| n)).unwrap_or_else(|e| {
        eprintln!("error writing {path}: {e}");
        std::process::exit(1);
    });
    if trace.dropped() > 0 {
        eprintln!(
            "trace: ring overwrote {} spans — raise --trace-cap for a full record",
            trace.dropped()
        );
    }
    eprintln!("trace: {n} spans → {path}");
}

/// Virtual-time workload replay: a timestamped request stream (trace,
/// Poisson, bursty, or diurnal arrivals) through the production batching
/// layer onto a simulated drive pool, per policy, at CPU speed. Emits the
/// deterministic QoS JSON document on stdout (or `--out`) and the
/// cross-policy comparison table on stderr.
fn cmd_replay(args: &Args) {
    args.reject_unknown(&[
        "arrivals", "rate", "duration", "policy", "drives", "seed", "mode", "cap", "data",
        "tapes", "backend", "window-ms", "max-batch", "backlog", "out", "shards", "vnodes",
        "arms", "affinity", "exclusive-tapes", "trace-file", "smoke", "connect", "requests",
        "trace-out", "trace-cap", "threads", "steal",
    ]);
    // --connect ADDR: there is no virtual clock across a process boundary,
    // so a networked replay degrades to the wall-clock closed-loop driver —
    // the same seam `serve --connect` uses.
    if let Some(addr) = args.get("connect") {
        drive_remote(args, addr);
        return;
    }
    let mut kind =
        args.get_choice_or("arrivals", &["poisson", "bursty", "diurnal", "trace"], "poisson");
    // --trace-file only makes sense for trace arrivals: imply them when
    // --arrivals was left to default, reject the contradiction otherwise
    // (silently replaying synthetic load instead of the operator's log
    // would produce a valid-looking report of the wrong workload).
    if args.get("trace-file").is_some() && kind != "trace" {
        if args.get("arrivals").is_some() {
            eprintln!("error: --trace-file requires --arrivals trace (got --arrivals {kind})");
            std::process::exit(2);
        }
        kind = "trace".to_string();
    }
    // --smoke: the fast deterministic CI preset — 2 virtual seconds at
    // 100 rps over 48 generated tapes — any of which an explicit flag
    // overrides.
    let smoke = args.has("smoke");
    let rate = args.get_parsed_or("rate", if smoke { 100.0f64 } else { 50.0f64 });
    let mut duration = args.get_parsed_or("duration", if smoke { 2.0f64 } else { 60.0f64 });
    let n_drives = args.get_parsed_or("drives", 4usize);
    let seed = args.get_parsed_or("seed", 1u64);
    let n_shards = args.get_parsed_or("shards", 1usize);
    let vnodes = args.get_parsed_or("vnodes", 64usize);
    if rate <= 0.0 || duration <= 0.0 || n_drives == 0 {
        eprintln!("error: --rate, --duration and --drives must be positive");
        std::process::exit(2);
    }
    if n_shards == 0 || vnodes == 0 {
        eprintln!("error: --shards and --vnodes must be positive");
        std::process::exit(2);
    }
    if args.get_parsed_or("backlog", 1usize) == 0 {
        eprintln!("error: --backlog must be positive (0 would reject every request)");
        std::process::exit(2);
    }
    let mode = match args.get_choice_or("mode", &["open", "closed"], "open").as_str() {
        "closed" => {
            let cap = args.get_parsed_or("cap", 256usize);
            if cap == 0 {
                eprintln!("error: --cap must be positive in closed mode");
                std::process::exit(2);
            }
            LoopMode::Closed { max_in_flight: cap }
        }
        _ => LoopMode::Open,
    };
    // --threads N: fan the shards out over worker threads. The merge is
    // byte-identical, but only open-loop replays decompose (the closed-loop
    // in-flight cap couples shards), and the span recorder assumes a single
    // engine's id sequence — reject both combinations up front rather than
    // panicking deep in the engine.
    let threads = args.get_parsed_or("threads", 1usize);
    if threads == 0 {
        eprintln!("error: --threads must be positive");
        std::process::exit(2);
    }
    if threads > 1 {
        if matches!(mode, LoopMode::Closed { .. }) {
            eprintln!(
                "error: --threads {threads} requires --mode open \
                 (the closed-loop in-flight cap couples shards)"
            );
            std::process::exit(2);
        }
        if args.get("trace-out").is_some() {
            eprintln!(
                "error: --trace-out records a single-threaded replay; drop --threads"
            );
            std::process::exit(2);
        }
    }
    // --steal: epoch-barrier work stealing on top of the pre-pass
    // assignment. Ownership stays a pure function of the seeded pre-pass,
    // so the report is byte-identical either way; only the balance
    // evidence printed to stderr changes.
    let steal = args.has("steal");
    if steal && threads <= 1 {
        eprintln!("error: --steal rebalances parallel workers; combine it with --threads N > 1");
        std::process::exit(2);
    }
    let assign_mode = if steal { AssignMode::Stolen } else { AssignMode::Weighted };
    let n_arms = args.get_parsed_or("arms", 0usize);
    let affinity = Affinity::from_name(&args.get_choice_or("affinity", &["none", "lru"], "none"))
        .expect("choice already validated");
    let exclusive_tapes =
        args.get_choice_or("exclusive-tapes", &["on", "off"], "on") == "on";
    let cfg = ReplayConfig {
        n_drives,
        batcher: BatcherConfig {
            window: Duration::from_millis(args.get_parsed_or("window-ms", 100u64)),
            max_batch: args.get_parsed_or("max-batch", 4096usize),
            max_tape_backlog: args
                .get_parsed_or("backlog", BatcherConfig::default().max_tape_backlog),
        },
        drive: DriveParams { n_arms, ..DriveParams::default() },
        mode,
        retry_backoff_s: 0.01,
        n_shards,
        vnodes,
        affinity,
        exclusive_tapes,
    };

    // Policies: comma-separated list; `--backend` selects the SimpleDP
    // evaluation engine and therefore combines with a single entry only.
    let policy_list = args.get_or("policy", "SimpleDP");
    let names: Vec<&str> =
        policy_list.split(',').map(|s| s.trim()).filter(|s| !s.is_empty()).collect();
    if names.is_empty() {
        eprintln!("error: --policy needs at least one algorithm");
        std::process::exit(2);
    }
    let policies: Vec<Box<dyn Scheduler + Send + Sync>> = if args.get("backend").is_some() {
        if names.len() != 1 {
            eprintln!("error: --backend combines with a single --policy entry");
            std::process::exit(2);
        }
        vec![resolve_policy(args, "policy", "SimpleDP")]
    } else {
        names
            .iter()
            .map(|n| {
                scheduler_by_name(n).unwrap_or_else(|| {
                    eprintln!("error: unknown algorithm `{n}`");
                    std::process::exit(2);
                })
            })
            .collect()
    };

    // The catalog and a factory producing the identical arrival stream for
    // every policy (fresh model, same seed ⇒ same stream).
    let (catalog, make_model): (Vec<Tape>, Box<dyn Fn() -> Box<dyn ArrivalModel> + Sync>) =
        if kind == "trace" && args.get("trace-file").is_some() {
            // Replay an operator-supplied on-disk log (the trace format
            // specified in rust/README.md) against the configured catalog.
            // Two passes, both streaming in O(window) memory: a dry-run
            // scan validates the file, counts the resolvable requests, and
            // finds the horizon; then each policy's replay re-reads the
            // file through a fresh StreamingTraceArrivals — the trace is
            // never materialized as a Vec, so multi-GB logs replay flat.
            let path = args.get("trace-file").unwrap().to_string();
            let ds = dataset_from(args);
            let catalog: Vec<Tape> = ds.tapes.iter().map(|t| t.tape.clone()).collect();
            let reader = open_trace_file(Path::new(&path)).unwrap_or_else(|e| {
                eprintln!("error: {e}");
                std::process::exit(1);
            });
            let scan = scan_trace(reader, &catalog, DEFAULT_TRACE_WINDOW).unwrap_or_else(|e| {
                eprintln!("error: {e}");
                std::process::exit(1);
            });
            if scan.events == 0 {
                eprintln!(
                    "error: no record of {path} matches the catalog \
                     ({} skipped: unknown tape or file id)",
                    scan.skipped
                );
                std::process::exit(1);
            }
            eprintln!(
                "trace file {path}: {} requests ({} skipped)",
                scan.events, scan.skipped
            );
            // The report's `duration_s` echoes the replayed window: for a
            // file trace that is the trace's own horizon, not the
            // synthetic-arrivals default (an explicit --duration wins).
            if args.get("duration").is_none() && scan.horizon_s > 0.0 {
                duration = scan.horizon_s;
            }
            if scan.within_window {
                // Name matches the eager path's `trace-file(N reads)` so
                // reports are byte-identical either way.
                let name = format!("trace-file({} reads)", scan.events);
                let cat = catalog.clone();
                (
                    catalog,
                    Box::new(move || -> Box<dyn ArrivalModel> {
                        let reader = open_trace_file(Path::new(&path))
                            .expect("trace file readable moments ago at scan time");
                        Box::new(StreamingTraceArrivals::new(
                            name.clone(),
                            reader,
                            &cat,
                            DEFAULT_TRACE_WINDOW,
                        ))
                    }),
                )
            } else {
                // A record is displaced further than the reorder window:
                // the streaming heap cannot reproduce the eager sort, so
                // fall back to the whole-file path rather than replay a
                // different order.
                eprintln!(
                    "trace file {path}: reorder exceeds the {DEFAULT_TRACE_WINDOW}-record \
                     window — falling back to eager (whole-file) replay"
                );
                let records = read_trace_file(Path::new(&path)).unwrap_or_else(|e| {
                    eprintln!("error: {e}");
                    std::process::exit(1);
                });
                let (proto, _skipped) = TraceArrivals::from_records(&records, &catalog);
                (catalog, Box::new(move || Box::new(proto.clone()) as Box<dyn ArrivalModel>))
            }
        } else if kind == "trace" {
            // Synthesize a raw activity log over synthetic tape catalogs and
            // replay it through the Appendix-C filters — the full
            // `dataset::rawlog` path, timestamps included.
            let n_tapes = args.get_parsed_or("tapes", 16usize).max(1);
            let mut rng = Rng::new(seed ^ 0x7_2ACE);
            let mut cats = std::collections::BTreeMap::new();
            for i in 0..n_tapes {
                let name = format!("TAPE{i:03}");
                let segs = rng.range(60, 400) as usize;
                cats.insert(name.clone(), synth_catalog(&name, segs, seed ^ (i as u64)));
            }
            // Oversample: ~20% of synthetic lines are writes/updates the
            // filter drops, plus the spanning-aggregate discards.
            let n_lines = (((rate * duration) as usize).max(1) * 5) / 4 + 8;
            let log = synth_raw_log(&cats, n_lines, duration.ceil() as u64, seed);
            let catalog = TraceArrivals::catalog_tapes(&cats);
            let proto = TraceArrivals::from_log(&log, &cats);
            eprintln!(
                "trace: {} raw lines over {} tapes → {} read requests",
                n_lines,
                n_tapes,
                proto.remaining()
            );
            (catalog, Box::new(move || Box::new(proto.clone()) as Box<dyn ArrivalModel>))
        } else {
            // --smoke shrinks the default catalog (48 tapes instead of
            // 169) so the preset runs in seconds; explicit --data/--tapes
            // win.
            let ds = if smoke && args.get("data").is_none() && args.get("tapes").is_none() {
                generate_dataset(&GeneratorConfig {
                    n_tapes: 48,
                    seed: args.get_parsed_or("seed", GeneratorConfig::default().seed),
                    ..Default::default()
                })
            } else {
                dataset_from(args)
            };
            let catalog: Vec<Tape> = ds.tapes.iter().map(|t| t.tape.clone()).collect();
            let mix = RequestMix::new(&catalog);
            (
                catalog,
                Box::new(move || -> Box<dyn ArrivalModel> {
                    match kind.as_str() {
                        "bursty" => {
                            Box::new(BurstyArrivals::new(mix.clone(), rate, duration, seed))
                        }
                        "diurnal" => {
                            Box::new(DiurnalArrivals::new(mix.clone(), rate, duration, seed))
                        }
                        _ => Box::new(PoissonArrivals::new(mix.clone(), rate, duration, seed)),
                    }
                }),
            )
        };

    // Request-lifecycle tracing: ids restart at 0 for every policy's
    // replay, so a shared dump would interleave chains — one policy per
    // trace file keeps `spans --check` meaningful.
    let trace = args.get("trace-out").map(|_| {
        if policies.len() > 1 {
            eprintln!("error: --trace-out records a single replay; use one --policy entry");
            std::process::exit(2);
        }
        TraceRecorder::new(args.get_parsed_or("trace-cap", DEFAULT_TRACE_CAP))
    });

    let mut reports = Vec::new();
    // One arena shared across the policy sweep: the event queue, histogram
    // pool, and completion log are recycled between policies instead of
    // reallocated. Parallel runs merge per-worker outcomes and traced runs
    // record spans, so both manage their own buffers.
    let mut arena = ReplayArena::new();
    for policy in &policies {
        let (report, outcome) = if threads > 1 {
            let (report, outcome, balance) = run_replay_parallel(
                &cfg,
                &catalog,
                policy.as_ref(),
                &*make_model,
                seed,
                duration,
                threads,
                assign_mode,
            );
            print_worker_balance(&balance, &outcome);
            (report, outcome)
        } else if trace.is_some() {
            let mut model = make_model();
            run_replay_traced(
                &cfg,
                &catalog,
                policy.as_ref(),
                model.as_mut(),
                seed,
                duration,
                trace.as_ref(),
            )
        } else {
            let mut model = make_model();
            run_replay_with_arena(
                &cfg,
                &catalog,
                policy.as_ref(),
                model.as_mut(),
                seed,
                duration,
                &mut arena,
            )
        };
        eprintln!(
            "replay {}: {} completed over {:.1} virtual s ({} batches, {:.3} wall s of schedule compute)",
            report.policy,
            report.completed,
            report.makespan_s,
            report.batches,
            outcome.stats.sched_wall_s
        );
        if n_shards > 1 {
            eprint!("{}", shard_summary(&report));
        }
        if report.pipeline {
            eprint!("{}", mount_summary(&report));
        }
        if report.exclusive {
            eprint!("{}", cartridge_summary(&report));
        }
        if threads == 1 && trace.is_none() {
            arena.recycle(outcome);
        }
        reports.push(report);
    }
    if dense_backend_selected(args) {
        let (hits, misses) = dense_cache_stats();
        eprintln!("dense cache hits/misses: {hits} / {misses}");
    }
    if incremental_backend_selected(args) {
        let (appends, rebuilds) = incremental_stats();
        eprintln!("incremental appends/rebuilds: {appends} / {rebuilds}");
    }
    if let (Some(path), Some(trace)) = (args.get("trace-out"), &trace) {
        write_trace(path, trace);
    }

    eprint!("{}", qos_comparison(&reports));
    let json = reports_json(&reports);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &json).unwrap_or_else(|e| {
                eprintln!("error writing {path}: {e}");
                std::process::exit(1);
            });
            eprintln!("QoS report → {path}");
        }
        None => print!("{json}"),
    }
}

/// Unwrap a networked-path result or exit with a message.
fn net_ok<T>(r: std::io::Result<T>, what: &str) -> T {
    r.unwrap_or_else(|e| {
        eprintln!("error: {what}: {e}");
        std::process::exit(1);
    })
}

/// `tapesched coordinator`: the fleet's routing process. Owns the
/// consistent-hash ring, waits for `--shards` workers to join (each is
/// handed the policy, the shard configuration, and its ring partition of
/// the catalog over the wire), then serves client submits until a client
/// drains or shuts the fleet down. The catalog derives from
/// `--seed/--tapes/--data` exactly as the in-process commands derive
/// theirs, so clients launched with the same flags agree on every tape
/// name.
fn cmd_coordinator(args: &Args) {
    args.reject_unknown(&[
        "listen", "shards", "policy", "drives", "seed", "tapes", "data", "vnodes",
        "window-ms", "max-batch", "backlog", "affinity", "arms", "exclusive-tapes",
        "kill-shard", "kill-after", "push-ms", "metrics-listen",
    ]);
    let listen = args.get_or("listen", "127.0.0.1:7171");
    let n_shards = args.get_parsed_or("shards", 2usize);
    let vnodes = args.get_parsed_or("vnodes", 64usize);
    let n_drives = args.get_parsed_or("drives", 4usize);
    if n_shards == 0 || vnodes == 0 || n_drives == 0 {
        eprintln!("error: --shards, --vnodes and --drives must be positive");
        std::process::exit(2);
    }
    if args.get_parsed_or("backlog", 1usize) == 0 {
        eprintln!("error: --backlog must be positive");
        std::process::exit(2);
    }
    // Name only: the policy is *resolved* by each worker
    // (`scheduler_by_name` on its side of the wire) — validating here
    // catches the typo before a fleet assembles around it.
    let policy = args.get_or("policy", "GS");
    if scheduler_by_name(&policy).is_none() {
        eprintln!("error: unknown algorithm {policy}");
        std::process::exit(2);
    }
    let affinity = Affinity::from_name(&args.get_choice_or("affinity", &["none", "lru"], "none"))
        .expect("choice already validated");
    let shard = CoordinatorConfig {
        n_drives,
        batcher: BatcherConfig {
            window: Duration::from_millis(args.get_parsed_or("window-ms", 100u64)),
            max_batch: args.get_parsed_or("max-batch", 4096usize),
            max_tape_backlog: args
                .get_parsed_or("backlog", BatcherConfig::default().max_tape_backlog),
        },
        drive: DriveParams {
            n_arms: args.get_parsed_or("arms", 0usize),
            ..DriveParams::default()
        },
        affinity,
        exclusive_tapes: args.get_choice_or("exclusive-tapes", &["on", "off"], "on") == "on",
    };
    // Fault injection for the robustness gate: cut shard I's connection
    // right after its M-th accepted submit (one-shot; a rejoining worker
    // is not re-killed).
    let kill = (args.get("kill-shard").is_some() || args.get("kill-after").is_some()).then(|| {
        (args.get_parsed_or("kill-shard", 0usize), args.get_parsed_or("kill-after", 1u64))
    });
    // --push-ms N > 0: workers push MetricsSnapshot deltas on this
    // interval (wire tags 13–14) and push-subscribed clients stop paying
    // a MetricsPull round trip per submit; 0 keeps the pull-only wire.
    let push_ms = args.get_parsed_or("push-ms", 0u64);
    let metrics_listen = args.get("metrics-listen").map(str::to_string);
    let ds = dataset_from(args);
    let catalog: Vec<Tape> = ds.tapes.iter().map(|t| t.tape.clone()).collect();
    let listener = net_ok(TcpListener::bind(listen.as_str()), "cannot bind --listen address");
    let addr = net_ok(listener.local_addr(), "cannot read bound address");
    eprintln!(
        "coordinator on {addr}: {n_shards} shards × {n_drives} drives, policy {policy}, {} tapes",
        catalog.len()
    );
    net_ok(
        tapesched::net::serve(
            listener,
            CoordinatorServerConfig {
                n_shards,
                vnodes,
                shard,
                policy,
                kill,
                push_ms,
                metrics_listen,
            },
            catalog,
        ),
        "coordinator failed",
    );
}

/// `tapesched worker`: serve one shard for a networked coordinator. A
/// worker brings nothing but compute — policy, configuration, and its
/// slice of the catalog all arrive over the wire — so the replacement for
/// a crashed worker is the same command line pointed at the same address.
fn cmd_worker(args: &Args) {
    args.reject_unknown(&["connect"]);
    let Some(addr) = args.get("connect") else {
        eprintln!("error: worker needs --connect ADDR");
        std::process::exit(2);
    };
    eprintln!("worker connecting to {addr}");
    net_ok(tapesched::net::run_worker(addr), "worker failed");
}

/// `serve --connect` / `replay --connect`: feed a networked fleet through
/// the unchanged closed-loop driver via [`RemoteCluster`] (the
/// `RequestSink` arm of the wire). The coordinator owns every serving knob
/// — policy, drives, batching — so this side only generates load and
/// prints the drained rollup. Launch with the coordinator's
/// `--seed/--tapes/--data`: the request stream names tapes from the
/// locally derived catalog, and names the fleet does not know are dropped
/// as `UnknownTape`.
fn drive_remote(args: &Args, addr: &str) {
    let n_requests = args.get_parsed_or("requests", 5_000u64);
    let cap = args.get_parsed_or("cap", 1_024u64);
    let seed = args.get_parsed_or("seed", 1u64);
    if cap == 0 || n_requests == 0 {
        eprintln!("error: --cap and --requests must be positive");
        std::process::exit(2);
    }
    let ds = dataset_from(args);
    let tapes: Vec<Tape> = ds.tapes.iter().map(|t| t.tape.clone()).collect();
    let client = net_ok(RemoteCluster::connect(addr), "cannot connect to coordinator");
    let mut model =
        PoissonArrivals::new(RequestMix::new(&tapes), 1_000.0, f64::INFINITY, seed);
    let stats = drive_closed_loop(
        &client,
        &tapes,
        &mut model,
        cap,
        Duration::from_millis(1),
        n_requests,
    );
    let (completions, m) = net_ok(client.drain(), "drain failed");
    println!("remote fleet at {addr}: {} completions", completions.len());
    println!("  accepted / dropped      = {} / {}", stats.submitted, stats.dropped);
    println!("  busy retries / rejected = {} / {}", stats.busy_retries, m.rejected);
    println!("  completed / shed        = {} / {}", m.completed, m.shed);
    println!("  batches dispatched      = {}", m.batches);
    println!("  mean in-tape service    = {:.1} s", m.mean_service_s);
    println!("  mean end-to-end latency = {:.1} s", m.mean_latency_s);
    println!(
        "  shard load max/min      = {} / {} (ratio {:.2})",
        m.max_shard_completed,
        m.min_shard_completed,
        m.imbalance_ratio()
    );
    for s in &m.shards {
        println!(
            "  shard {:<2} routed/completed = {} / {} (p99 {:.1} s)",
            s.shard, s.routed, s.metrics.completed, s.metrics.p99_latency_s
        );
    }
}

/// One mode's digest in the `rpc-tax` report, computed client-side from
/// the completion stream so both modes go through identical arithmetic.
struct ModeDigest {
    submitted: u64,
    completed: u64,
    shed: u64,
    dropped: u64,
    busy_retries: u64,
    tour_cost_s: f64,
    mean_latency_s: f64,
    p50_latency_s: f64,
    p99_latency_s: f64,
    p999_latency_s: f64,
}

fn mode_digest(
    stats: LiveDriveStats,
    mut completions: Vec<Completion>,
    m: &ClusterMetricsSnapshot,
) -> ModeDigest {
    // Tour cost = Σ service_s in request-id order. Pinning the summation
    // order makes the float total a pure function of the request stream,
    // so the in-process and loopback runs of the same stream must agree
    // bit for bit — ci.sh compares the printed values.
    completions.sort_by_key(|c| c.request_id);
    let tour_cost_s: f64 = completions.iter().map(|c| c.service_s).sum();
    let mut lats: Vec<f64> = completions.iter().map(|c| c.latency_s).collect();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| if lats.is_empty() { 0.0 } else { percentile_sorted(&lats, p) };
    ModeDigest {
        submitted: m.submitted,
        completed: m.completed,
        shed: m.shed,
        dropped: stats.dropped,
        busy_retries: stats.busy_retries,
        tour_cost_s,
        mean_latency_s: if lats.is_empty() {
            0.0
        } else {
            lats.iter().sum::<f64>() / lats.len() as f64
        },
        p50_latency_s: pct(50.0),
        p99_latency_s: pct(99.0),
        p999_latency_s: pct(99.9),
    }
}

fn mode_json(d: &ModeDigest) -> String {
    format!(
        "{{\"submitted\": {}, \"completed\": {}, \"shed\": {}, \"dropped\": {}, \
         \"busy_retries\": {}, \"tour_cost_s\": {:.6}, \"mean_latency_s\": {:.6}, \
         \"p50_latency_s\": {:.6}, \"p99_latency_s\": {:.6}, \"p999_latency_s\": {:.6}}}",
        d.submitted,
        d.completed,
        d.shed,
        d.dropped,
        d.busy_retries,
        d.tour_cost_s,
        d.mean_latency_s,
        d.p50_latency_s,
        d.p99_latency_s,
        d.p999_latency_s
    )
}

/// `tapesched rpc-tax`: what does the process boundary cost? The same
/// seeded request stream is driven twice per policy — through the
/// in-process [`Cluster`] (the seam is a function call) and through a
/// loopback-networked coordinator/worker fleet (every submit a framed TCP
/// round trip) — under one giant batching window flushed at drain, so
/// both modes compose identical batches and the counters and tour costs
/// must match bit for bit. What is *allowed* to differ is wall-clock
/// latency: `p999_delta_s` is the RPC tax. `--kill-after M` appends a
/// worker-crash run gated on the fleet-wide drain invariant
/// `submitted = completed + shed`.
fn cmd_rpc_tax(args: &Args) {
    args.reject_unknown(&[
        "policy", "shards", "drives", "vnodes", "requests", "seed", "tapes", "data", "out",
        "kill-after", "push-metrics", "push-ms",
    ]);
    let n_shards = args.get_parsed_or("shards", 2usize);
    let n_drives = args.get_parsed_or("drives", 4usize);
    let vnodes = args.get_parsed_or("vnodes", 64usize);
    let n_requests = args.get_parsed_or("requests", 240u64);
    let seed = args.get_parsed_or("seed", 1u64);
    if n_shards == 0 || n_drives == 0 || vnodes == 0 || n_requests == 0 {
        eprintln!("error: --shards, --drives, --vnodes and --requests must be positive");
        std::process::exit(2);
    }
    let policy_list = args.get_or("policy", "GS");
    let names: Vec<&str> =
        policy_list.split(',').map(|s| s.trim()).filter(|s| !s.is_empty()).collect();
    if names.is_empty() {
        eprintln!("error: --policy needs at least one algorithm");
        std::process::exit(2);
    }
    for n in &names {
        if scheduler_by_name(n).is_none() {
            eprintln!("error: unknown algorithm `{n}`");
            std::process::exit(2);
        }
    }
    // Small catalog by default (12 tapes): the measurement wants round
    // trips, not tape-hours; --data/--tapes override as everywhere else.
    let ds = if args.get("data").is_some() {
        dataset_from(args)
    } else {
        generate_dataset(&GeneratorConfig {
            n_tapes: args.get_parsed_or("tapes", 12usize),
            seed: args.get_parsed_or("seed", GeneratorConfig::default().seed),
            ..Default::default()
        })
    };
    let catalog: Vec<Tape> = ds.tapes.iter().map(|t| t.tape.clone()).collect();
    // One giant window, flushed at drain, no affinity/arms/exclusivity:
    // batch composition is then a pure function of the stream and the
    // ring — identical across modes — and every QoS difference is the
    // wire.
    let shard_cfg = CoordinatorConfig {
        n_drives,
        batcher: BatcherConfig {
            window: Duration::from_secs(3_600),
            ..BatcherConfig::default()
        },
        drive: DriveParams::default(),
        affinity: Affinity::None,
        exclusive_tapes: false,
    };
    let fresh_model =
        || PoissonArrivals::new(RequestMix::new(&catalog), 1_000.0, f64::INFINITY, seed);
    let backoff = Duration::from_millis(1);

    let mut sections = Vec::new();
    for name in &names {
        // In-process: the RequestSink seam stays a function call.
        let policy = scheduler_by_name(name).expect("validated above");
        let cluster = Cluster::start(
            ClusterConfig {
                n_shards,
                vnodes,
                shard: shard_cfg.clone(),
                shard_configs: Vec::new(),
            },
            catalog.iter().cloned(),
            Arc::from(policy),
        );
        let mut model = fresh_model();
        let stats =
            drive_closed_loop(&cluster, &catalog, &mut model, n_requests, backoff, n_requests);
        let (completions, m) = cluster.finish();
        let local = mode_digest(stats, completions, &m);

        // Loopback-networked: same stream, every submit a framed TCP
        // round trip through coordinator and worker (threads here, but
        // the frames and handshakes are exactly the standalone
        // subcommands').
        let fleet = net_ok(
            LoopbackFleet::spawn(
                CoordinatorServerConfig {
                    n_shards,
                    vnodes,
                    shard: shard_cfg.clone(),
                    policy: name.to_string(),
                    kill: None,
                    push_ms: 0,
                    metrics_listen: None,
                },
                catalog.clone(),
            ),
            "cannot spawn loopback fleet",
        );
        let client = net_ok(fleet.client(), "cannot connect loopback client");
        let mut model = fresh_model();
        let stats =
            drive_closed_loop(&client, &catalog, &mut model, n_requests, backoff, n_requests);
        let (completions, m) = net_ok(client.drain(), "loopback drain failed");
        let _ = fleet.join();
        let remote = mode_digest(stats, completions, &m);

        let delta = remote.p999_latency_s - local.p999_latency_s;
        eprintln!(
            "rpc-tax {name}: tour {:.6} s vs {:.6} s, p99.9 latency {:.6} s vs {:.6} s (delta {:+.6} s)",
            local.tour_cost_s,
            remote.tour_cost_s,
            local.p999_latency_s,
            remote.p999_latency_s,
            delta
        );
        sections.push(format!(
            "    {{\"policy\": \"{name}\", \"in_process\": {}, \"loopback\": {}, \"p999_delta_s\": {:.6}}}",
            mode_json(&local),
            mode_json(&remote),
            delta
        ));
    }

    // The robustness run: cut one worker mid-stream and check the
    // fleet-wide drain invariant. The victim is the shard owning the
    // stream's first arrival, so with --kill-after 1 the kill is
    // guaranteed to fire (larger values need the victim to see that many
    // submits before the drain).
    let kill_json = args.get("kill-after").map(|_| {
        let kill_after = args.get_parsed_or("kill-after", 1u64);
        let ring = HashRing::new(n_shards, vnodes);
        let mut probe = fresh_model();
        let first = probe.next_arrival().expect("positive --requests implies an arrival");
        let victim = ring.route(&catalog[first.tape].name);
        let name = names[0];
        let fleet = net_ok(
            LoopbackFleet::spawn(
                CoordinatorServerConfig {
                    n_shards,
                    vnodes,
                    shard: shard_cfg.clone(),
                    policy: name.to_string(),
                    kill: Some((victim, kill_after)),
                    push_ms: 0,
                    metrics_listen: None,
                },
                catalog.clone(),
            ),
            "cannot spawn loopback fleet",
        );
        let client = net_ok(fleet.client(), "cannot connect loopback client");
        let mut model = fresh_model();
        let stats =
            drive_closed_loop(&client, &catalog, &mut model, n_requests, backoff, n_requests);
        let (_completions, m) = net_ok(client.drain(), "loopback drain failed");
        let _ = fleet.join();
        let holds = m.submitted == m.completed + m.shed;
        eprintln!(
            "rpc-tax kill: shard {victim} cut after {kill_after} accepted — \
             submitted {} = completed {} + shed {}: {}",
            m.submitted,
            m.completed,
            m.shed,
            if holds { "invariant holds" } else { "INVARIANT VIOLATED" }
        );
        format!(
            "  \"kill_report\": {{\"policy\": \"{name}\", \"kill_shard\": {victim}, \
             \"kill_after\": {kill_after}, \"submitted\": {}, \"completed\": {}, \
             \"shed\": {}, \"dropped\": {}, \"drain_invariant_holds\": {}}},\n",
            m.submitted, m.completed, m.shed, stats.dropped, holds
        )
    });

    // The telemetry-tax run: the closed-loop driver reads `in_flight()`
    // once per arrival, so pull-mode pays two round trips per request
    // (MetricsPull + Submit) where push-mode pays one (the gauge is fed by
    // the coordinator's MetricsPush stream and read locally). Paired runs
    // over the same stream make the recovered submit throughput visible.
    let push_json = if args.has("push-metrics") {
        let name = names[0];
        let push_ms = args.get_parsed_or("push-ms", 5u64);
        let timed_run = |push_ms: u64| {
            let fleet = net_ok(
                LoopbackFleet::spawn(
                    CoordinatorServerConfig {
                        n_shards,
                        vnodes,
                        shard: shard_cfg.clone(),
                        policy: name.to_string(),
                        kill: None,
                        push_ms,
                        metrics_listen: None,
                    },
                    catalog.clone(),
                ),
                "cannot spawn loopback fleet",
            );
            let client = if push_ms > 0 {
                net_ok(fleet.client_push(), "cannot connect push-fed loopback client")
            } else {
                net_ok(fleet.client(), "cannot connect loopback client")
            };
            let mut model = fresh_model();
            let t0 = std::time::Instant::now();
            drive_closed_loop(&client, &catalog, &mut model, n_requests, backoff, n_requests);
            let wall_s = t0.elapsed().as_secs_f64();
            let (_completions, m) = net_ok(client.drain(), "loopback drain failed");
            let _ = fleet.join();
            (wall_s, m)
        };
        let (pull_wall_s, pull_m) = timed_run(0);
        let (push_wall_s, push_m) = timed_run(push_ms);
        if pull_m.completed != push_m.completed {
            eprintln!(
                "error: push/pull runs diverged ({} vs {} completions) — \
                 the gauge must not change what gets scheduled",
                pull_m.completed, push_m.completed
            );
            std::process::exit(1);
        }
        let pull_rate = n_requests as f64 / pull_wall_s;
        let push_rate = n_requests as f64 / push_wall_s;
        eprintln!(
            "rpc-tax push-metrics {name}: pull {pull_rate:.0} submits/s \
             ({pull_wall_s:.3} s) vs push {push_rate:.0} submits/s \
             ({push_wall_s:.3} s) — {:.2}x",
            push_rate / pull_rate
        );
        format!(
            "  \"push_report\": {{\"policy\": \"{name}\", \"push_ms\": {push_ms}, \
             \"requests\": {n_requests}, \"pull_wall_s\": {pull_wall_s:.6}, \
             \"pull_submits_per_s\": {pull_rate:.3}, \"push_wall_s\": {push_wall_s:.6}, \
             \"push_submits_per_s\": {push_rate:.3}}},\n"
        )
    } else {
        String::new()
    };

    let json = format!(
        "{{\n  \"schema\": \"tapesched-rpc-tax-v1\",\n  \"seed\": {seed},\n  \
         \"shards\": {n_shards},\n  \"drives\": {n_drives},\n  \
         \"requests\": {n_requests},\n{}{}  \"rpc_reports\": [\n{}\n  ]\n}}\n",
        kill_json.unwrap_or_default(),
        push_json,
        sections.join(",\n")
    );
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &json).unwrap_or_else(|e| {
                eprintln!("error writing {path}: {e}");
                std::process::exit(1);
            });
            eprintln!("rpc-tax report → {path}");
        }
        None => print!("{json}"),
    }
}

/// `tapesched spans` — render a per-stage latency breakdown of a
/// `--trace-out` JSONL dump, optionally verifying chain integrity first.
fn cmd_spans(args: &Args) {
    args.reject_unknown(&["in", "check"]);
    let path = args.get("in").unwrap_or_else(|| {
        eprintln!("error: spans needs --in FILE (a --trace-out JSONL dump)");
        std::process::exit(2);
    });
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        eprintln!("error reading {path}: {e}");
        std::process::exit(1);
    });
    let spans = parse_jsonl(&text);
    if spans.is_empty() {
        eprintln!("error: {path} holds no parsable spans");
        std::process::exit(1);
    }
    if args.has("check") {
        match check_chains(&spans) {
            Ok(n) => eprintln!("spans: {n} complete request chains, all monotone and contiguous"),
            Err(e) => {
                eprintln!("spans: chain check FAILED: {e}");
                std::process::exit(1);
            }
        }
    }
    print!("{}", render_breakdown(&breakdown(&spans)));
}

fn cmd_audit(args: &Args) {
    args.reject_unknown(&["fix-waivers"]);
    if args.positional.len() > 1 {
        eprintln!("error: audit takes at most one PATH (the source root to scan)");
        std::process::exit(2);
    }
    let root = match args.positional.first() {
        Some(p) => PathBuf::from(p),
        // Default to the crate sources regardless of whether we run from
        // the repo root or from rust/.
        None => ["rust/src", "src"]
            .into_iter()
            .map(PathBuf::from)
            .find(|p| p.is_dir())
            .unwrap_or_else(|| {
                eprintln!("error: neither rust/src nor src exists here; pass PATH explicitly");
                std::process::exit(2);
            }),
    };
    if !root.is_dir() {
        eprintln!("error: {} is not a directory", root.display());
        std::process::exit(2);
    }
    let run = |root: &Path| {
        audit::audit_tree(root).unwrap_or_else(|e| {
            eprintln!("error scanning {}: {e}", root.display());
            std::process::exit(1);
        })
    };
    let mut reports = run(&root);
    if args.has("fix-waivers") {
        let removed = audit::fix_unused_waivers(&root, &reports).unwrap_or_else(|e| {
            eprintln!("error rewriting waivers under {}: {e}", root.display());
            std::process::exit(1);
        });
        eprintln!("audit: removed {removed} unused waiver(s)");
        reports = run(&root);
    }
    print!("{}", audit::render(&reports));
    if audit::total_findings(&reports) > 0 {
        std::process::exit(1);
    }
}
