//! **GS** — Greedy Scheduling (§4.2, Appendix B.2): one atomic detour per
//! requested file, i.e. every file is read as soon as the head reaches it.
//! A 3-approximation when `U = 0` [Cardonha & Real], with no guarantee under
//! U-turn penalties.

use crate::model::Instance;
use crate::sched::{Detour, Schedule, Scheduler};

#[derive(Debug, Clone, Copy, Default)]
pub struct Gs;

impl Scheduler for Gs {
    fn name(&self) -> String {
        "GS".into()
    }

    fn schedule(&self, inst: &Instance) -> Schedule {
        (0..inst.k()).map(Detour::atomic).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ReqFile;
    use crate::sim::evaluate;

    #[test]
    fn reads_every_file_on_sight() {
        let inst = Instance::new(
            100,
            0,
            vec![ReqFile { l: 10, r: 20, x: 5 }, ReqFile { l: 60, r: 80, x: 1 }],
        )
        .unwrap();
        let out = evaluate(&inst, &Gs.schedule(&inst));
        // f1: 100→60 (40), served at 60. Back at 60 (80)... then 60→10 (130),
        // served f0 at 140.
        assert_eq!(out.service, vec![140, 60]);
    }

    #[test]
    fn worst_case_shape_small_urgent_left_of_large_single() {
        // §4.2's worst case: many requests on a small file left of a large
        // single-request file. GS pays the big detour before the urgent file.
        let inst = Instance::new(
            2_000,
            0,
            vec![ReqFile { l: 0, r: 10, x: 100 }, ReqFile { l: 1_000, r: 2_000, x: 1 }],
        )
        .unwrap();
        let gs = evaluate(&inst, &Gs.schedule(&inst));
        let nodetour = evaluate(&inst, &[]);
        // GS detours through the 1000-long file first: the 100 urgent
        // requests on f0 are all delayed by 2·s(f1) = 2000.
        assert_eq!(gs.service, vec![4_010, 2_000]);
        assert_eq!(nodetour.service, vec![2_010, 4_000]);
        assert!(nodetour.cost < gs.cost);
    }
}
