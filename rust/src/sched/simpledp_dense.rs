//! Dense bottom-up SimpleDP evaluation — the exact Rust mirror of the
//! L2 JAX model (`python/compile/model.py`).
//!
//! Computes the full `(k × (n+1))` table `T[b, n_skip]` for **every**
//! `n_skip` value (the sparse solver only touches reachable ones). This is
//! the semantics the AOT-compiled XLA artifact implements, so this module
//! is both the default execution engine (`runtime::DenseBackend`) and the
//! cross-validation reference for the XLA backend in [`crate::runtime`]:
//! same wavefront order, same dense grid, exact `i128` arithmetic here vs
//! `f64` there.
//!
//! Memory/time are Θ(k·n) and Θ(k²·n): use for moderate instances only.
//!
//! ## Allocation discipline
//!
//! A literal two-row rolling wavefront is **impossible** for this
//! recurrence: the detour branch of row `b` reads row `c−1` for every
//! `c ≤ b`, so all earlier value rows stay live. What hot dispatch paths
//! (coordinator drive workers, the replay engine) *can* avoid paying per
//! call is (a) the choice table when only the cost is consumed — see
//! [`dense_cost_into`], which runs the wavefront value-only — and (b) the
//! Θ(k·n) allocation itself: [`DenseScratch`] keeps the buffers alive
//! across calls, so repeated dispatches on hot tapes reuse capacity
//! instead of round-tripping the allocator ([`dense_solve_into`]).

use crate::model::{virtual_lb, Cost, Instance};
use crate::sched::{Detour, Schedule};

/// Full dense table: `table[b][ns]` for `b ∈ 0..k`, `ns ∈ 0..=n`.
pub struct DenseTable {
    pub k: usize,
    pub ns_max: usize,
    /// Row-major `k × (ns_max+1)`.
    pub t: Vec<Cost>,
    /// Choice per cell: `u32::MAX` = skip, else chosen `c`.
    pub choice: Vec<u32>,
}

const SKIP: u32 = u32::MAX;

impl DenseTable {
    #[inline]
    pub fn at(&self, b: usize, ns: usize) -> Cost {
        self.t[b * (self.ns_max + 1) + ns]
    }
}

/// Reusable buffers for dense evaluations. Capacity survives across calls,
/// so a hot caller pays the Θ(k·n) allocation once, not per dispatch.
#[derive(Debug, Default)]
pub struct DenseScratch {
    t: Vec<Cost>,
    choice: Vec<u32>,
}

/// The wavefront core: fill `t` (and, when `TRACK`, `choice`) bottom-up.
/// The const generic folds the decision bookkeeping out of the inner loop
/// entirely for cost-only queries. Buffers are cleared and resized here;
/// their capacity is reused.
fn fill_dense<const TRACK: bool>(
    inst: &Instance,
    t: &mut Vec<Cost>,
    choice: &mut Vec<u32>,
) -> usize {
    let k = inst.k();
    let ns_max = inst.n() as usize;
    let width = ns_max + 1;
    t.clear();
    t.resize(k * width, 0);
    if TRACK {
        choice.clear();
        choice.resize(k * width, SKIP);
    }

    // Base row b = 0: T[0, ns] = 2·s(0)·ns.
    for ns in 0..width {
        t[ns] = 2 * inst.s(0) as Cost * ns as Cost;
    }

    let u = inst.u() as Cost;
    for b in 1..k {
        let (prev_rows, row) = t.split_at_mut(b * width);
        let row = &mut row[..width];
        let xb = inst.x(b) as usize;
        let gap2 = 2 * (inst.r(b) - inst.r(b - 1)) as Cost;
        let lead2 = 2 * (inst.l(b) - inst.r(b - 1)) as Cost * inst.x(b) as Cost;

        // skip branch — shifted read of row b−1 (clamped at the edge; the
        // clamped cells are unreachable from the root where Σ skipped ≤ n).
        // The choice row is already SKIP from the resize above.
        let prev = &prev_rows[(b - 1) * width..];
        for ns in 0..width {
            let shifted = (ns + xb).min(ns_max);
            row[ns] = prev[shifted] + gap2 * ns as Cost + lead2;
        }
        // detour_c branches.
        for c in 1..=b {
            let pc = &prev_rows[(c - 1) * width..(c - 1) * width + width];
            let span2 = 2 * (inst.r(b) - inst.r(c - 1)) as Cost;
            let det2 = 2 * (u + inst.r(b) as Cost - inst.l(c) as Cost);
            let nlc = inst.nl(c) as Cost;
            let inner2 = 2 * inst.in_detour_span_cost(c, b);
            for ns in 0..width {
                let v = pc[ns]
                    + span2 * ns as Cost
                    + det2 * (ns as Cost + nlc)
                    + inner2;
                if v < row[ns] {
                    row[ns] = v;
                    if TRACK {
                        choice[b * width + ns] = c as u32;
                    }
                }
            }
        }
    }
    width
}

/// Compute the dense SimpleDP table bottom-up (wavefront over `b`).
pub fn dense_table(inst: &Instance) -> DenseTable {
    let mut t = Vec::new();
    let mut choice = Vec::new();
    fill_dense::<true>(inst, &mut t, &mut choice);
    DenseTable { k: inst.k(), ns_max: inst.n() as usize, t, choice }
}

/// Optimal disjoint-detour cost (value wavefront only, no choice table).
pub fn dense_cost(inst: &Instance) -> Cost {
    dense_cost_into(inst, &mut DenseScratch::default())
}

/// [`dense_cost`] writing into reusable buffers: no choice table, and the
/// value table reuses `scratch`'s capacity.
pub fn dense_cost_into(inst: &Instance, scratch: &mut DenseScratch) -> Cost {
    let width = fill_dense::<false>(inst, &mut scratch.t, &mut scratch.choice);
    scratch.t[(inst.k() - 1) * width] + virtual_lb(inst)
}

/// Optimal cost **and** schedule, writing into reusable buffers.
pub fn dense_solve_into(inst: &Instance, scratch: &mut DenseScratch) -> (Cost, Schedule) {
    let width = fill_dense::<true>(inst, &mut scratch.t, &mut scratch.choice);
    let cost = scratch.t[(inst.k() - 1) * width] + virtual_lb(inst);
    (cost, reconstruct_choices(inst, &scratch.choice, width - 1))
}

/// Walk a choice table root-down into the detour list (the values are not
/// needed — decisions alone determine the schedule).
fn reconstruct_choices(inst: &Instance, choice: &[u32], ns_max: usize) -> Schedule {
    let width = ns_max + 1;
    let mut detours = Vec::new();
    let (mut b, mut ns) = (inst.k() - 1, 0usize);
    while b > 0 {
        let ch = choice[b * width + ns];
        if ch == SKIP {
            ns = (ns + inst.x(b) as usize).min(ns_max);
            b -= 1;
        } else {
            let c = ch as usize;
            detours.push(Detour::new(c, b));
            b = c - 1;
        }
    }
    detours
}

/// Reconstruct the schedule from a dense table (same walk as the sparse
/// solver). Exposed so the XLA runtime can reconstruct from its own table.
pub fn reconstruct(inst: &Instance, tbl: &DenseTable) -> Schedule {
    reconstruct_choices(inst, &tbl.choice, tbl.ns_max)
}

/// Reconstruct a schedule from raw table values only (no choice array) by
/// re-deriving the argmin at each visited cell — this is what the XLA
/// backend does, since the artifact returns values, not decisions.
pub fn reconstruct_from_values(
    inst: &Instance,
    at: &dyn Fn(usize, usize) -> f64,
    tol: f64,
) -> Schedule {
    let k = inst.k();
    let ns_max = inst.n() as usize;
    let u = inst.u() as f64;
    let mut detours = Vec::new();
    let (mut b, mut ns) = (k - 1, 0usize);
    while b > 0 {
        let here = at(b, ns);
        // Try skip first (ties favor skip, like the exact solver).
        let shifted = (ns + inst.x(b) as usize).min(ns_max);
        let skip = at(b - 1, shifted)
            + 2.0 * (inst.r(b) - inst.r(b - 1)) as f64 * ns as f64
            + 2.0 * (inst.l(b) - inst.r(b - 1)) as f64 * inst.x(b) as f64;
        if (skip - here).abs() <= tol * here.abs().max(1.0) {
            ns = shifted;
            b -= 1;
            continue;
        }
        let mut chosen = None;
        for c in 1..=b {
            let v = at(c - 1, ns)
                + 2.0 * (inst.r(b) - inst.r(c - 1)) as f64 * ns as f64
                + 2.0 * (u + (inst.r(b) - inst.l(c)) as f64)
                    * (ns as f64 + inst.nl(c) as f64)
                + 2.0 * inst.in_detour_span_cost(c, b) as f64;
            if (v - here).abs() <= tol * here.abs().max(1.0) {
                chosen = Some(c);
                break;
            }
        }
        let c = chosen.expect("no branch reproduces the table value");
        detours.push(Detour::new(c, b));
        b = c - 1;
    }
    detours
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ReqFile;
    use crate::sched::{Scheduler, SimpleDp};
    use crate::sim::evaluate;

    fn inst(u: u64, files: &[(u64, u64, u64)], m: u64) -> Instance {
        Instance::new(m, u, files.iter().map(|&(l, r, x)| ReqFile { l, r, x }).collect())
            .unwrap()
    }

    fn fixtures() -> Vec<Instance> {
        vec![
            inst(0, &[(0, 5, 1), (10, 12, 9), (40, 60, 1)], 80),
            inst(7, &[(0, 5, 1), (10, 12, 9), (40, 60, 1)], 80),
            inst(3, &[(5, 6, 2), (6, 30, 1), (31, 32, 8), (60, 61, 3)], 100),
            inst(0, &[(2, 4, 2), (10, 30, 5), (33, 34, 1), (50, 80, 4), (90, 99, 2)], 110),
            inst(11, &[(0, 4, 3), (8, 20, 1), (25, 26, 14), (40, 70, 2), (90, 95, 6)], 120),
        ]
    }

    #[test]
    fn dense_equals_sparse() {
        for i in fixtures() {
            assert_eq!(dense_cost(&i), SimpleDp::cost(&i));
        }
    }

    #[test]
    fn dense_reconstruction_achieves_table_cost() {
        for i in fixtures() {
            let tbl = dense_table(&i);
            let sched = reconstruct(&i, &tbl);
            assert_eq!(evaluate(&i, &sched).cost, dense_cost(&i));
            // and matches the sparse schedule's cost
            let sparse = SimpleDp.schedule(&i);
            assert_eq!(evaluate(&i, &sparse).cost, dense_cost(&i));
        }
    }

    #[test]
    fn edge_clamp_when_one_file_carries_nearly_all_requests() {
        // The skip branch reads row b−1 at column `(ns + x(b)).min(ns_max)`.
        // A file holding (almost) all n requests pushes that index against
        // the clamp for most ns; dense and sparse must still agree because
        // clamped cells are unreachable from the root (Σ skipped ≤ n).
        let cases = vec![
            // All n requests on the single requested file (k = 1).
            inst(4, &[(10, 20, 17)], 50),
            // One dominant file left, right, and mid among unit requests.
            inst(0, &[(0, 5, 60), (20, 30, 1), (40, 45, 1)], 60),
            inst(3, &[(0, 5, 1), (20, 30, 1), (40, 45, 60)], 60),
            inst(7, &[(0, 5, 1), (20, 30, 60), (40, 45, 1)], 60),
        ];
        for i in cases {
            assert_eq!(dense_cost(&i), SimpleDp::cost(&i), "instance {i:?}");
            let tbl = dense_table(&i);
            let sched = reconstruct(&i, &tbl);
            assert_eq!(evaluate(&i, &sched).cost, dense_cost(&i), "instance {i:?}");
        }
    }

    #[test]
    fn scratch_paths_match_the_full_table_and_survive_reuse() {
        // One scratch across instances of different shapes (grow, shrink,
        // grow again): cost-only and solve paths must keep agreeing with
        // the freshly-allocated table and the sparse solver.
        let mut scratch = DenseScratch::default();
        let mut order = fixtures();
        order.reverse();
        for pass in 0..2 {
            for i in &order {
                let expected = SimpleDp::cost(i);
                assert_eq!(dense_cost_into(i, &mut scratch), expected, "pass {pass}");
                let (cost, sched) = dense_solve_into(i, &mut scratch);
                assert_eq!(cost, expected);
                assert_eq!(evaluate(i, &sched).cost, expected);
            }
        }
        // The single-request edge case (k = 1, no wavefront rows).
        let tiny = inst(4, &[(10, 20, 17)], 50);
        assert_eq!(dense_cost_into(&tiny, &mut scratch), SimpleDp::cost(&tiny));
        let (c, s) = dense_solve_into(&tiny, &mut scratch);
        assert_eq!(c, SimpleDp::cost(&tiny));
        assert!(s.is_empty());
    }

    #[test]
    fn value_only_reconstruction() {
        for i in fixtures() {
            let tbl = dense_table(&i);
            let at = |b: usize, ns: usize| tbl.at(b, ns) as f64;
            let sched = reconstruct_from_values(&i, &at, 1e-9);
            assert_eq!(evaluate(&i, &sched).cost, dense_cost(&i));
        }
    }
}
