//! Scheduling algorithms for LTSP.
//!
//! Every algorithm implements [`Scheduler`] and returns a [`Schedule`]: a
//! list of detours over requested-file indices. A detour `(a, b)` means the
//! head, upon first attaining `ℓ(a)`, turns, goes to `r(b)` and comes back
//! to `ℓ(a)` (§4.1). The implicit final detour `(f₁, f_{n_f})` — the final
//! left-to-right sweep serving skipped files — is never listed explicitly.
//!
//! Algorithms (paper §4.2–4.5, Appendix B):
//! - [`NoDetour`] — makespan-optimal straight sweep.
//! - [`Gs`] — Greedy Scheduling, one atomic detour per requested file.
//! - [`Fgs`] — GS + iterated removal of detrimental detours (Eq. 5).
//! - [`Nfgs`] / [`LogNfgs`] — FGS + non-atomic detour upgrades (Δ function).
//! - [`Dp`] — the paper's exact polynomial dynamic program (§4.3).
//! - [`LogDp`] — DP with detour span capped at `λ·log₂ n_req` (§4.5).
//! - [`SimpleDp`] — DP restricted to disjoint detours (§4.5).
//! - [`BruteForce`] — exhaustive search over detour sets (test oracle).

mod bruteforce;
mod dp;
mod fgs;
mod gs;
mod nfgs;
mod nodetour;
mod simpledp;
pub mod simpledp_dense;

pub use bruteforce::BruteForce;
pub use dp::{Dp, DpFromStart, LogDp};
pub use fgs::Fgs;
pub use gs::Gs;
pub use nfgs::{LogNfgs, Nfgs};
pub use nodetour::NoDetour;
pub use simpledp::SimpleDp;

use crate::model::Instance;

/// A detour `(a, b)` over requested-file indices, `a ≤ b`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Detour {
    pub a: usize,
    pub b: usize,
}

impl Detour {
    pub fn new(a: usize, b: usize) -> Detour {
        assert!(a <= b, "detour must satisfy a <= b (got {a} > {b})");
        Detour { a, b }
    }

    /// Atomic detour on a single file.
    pub fn atomic(f: usize) -> Detour {
        Detour { a: f, b: f }
    }
}

/// An ordered list of detours. Execution order is decreasing left endpoint
/// (the head meets detours right-to-left); [`crate::sim::evaluate`] sorts.
pub type Schedule = Vec<Detour>;

/// A scheduling policy: maps an instance to a detour list.
pub trait Scheduler {
    /// Display name (matches the paper's algorithm names).
    fn name(&self) -> String;

    /// Compute the schedule for `inst`.
    fn schedule(&self, inst: &Instance) -> Schedule;
}

/// Check the *strictly laminar* property of §4.1: any two detours are either
/// disjoint or strictly nested, and left endpoints are pairwise distinct.
pub fn is_strictly_laminar(detours: &[Detour]) -> bool {
    for (i, d1) in detours.iter().enumerate() {
        for d2 in &detours[i + 1..] {
            let (lo, hi) = if d1.a <= d2.a { (d1, d2) } else { (d2, d1) };
            if lo.a == hi.a {
                return false; // duplicate left endpoint
            }
            let disjoint = hi.a > lo.b;
            let nested = hi.b < lo.b; // hi strictly inside lo
            if !disjoint && !nested {
                return false;
            }
        }
    }
    true
}

/// All schedulers evaluated in the paper's §5, in the paper's naming.
/// (`BruteForce` is excluded: it is a test oracle, not an evaluated policy.)
pub fn paper_schedulers() -> Vec<Box<dyn Scheduler + Send + Sync>> {
    vec![
        Box::new(NoDetour),
        Box::new(Gs),
        Box::new(Fgs),
        Box::new(Nfgs),
        Box::new(LogNfgs::new(5.0)),
        Box::new(LogDp::new(1.0)),
        Box::new(LogDp::new(5.0)),
        Box::new(SimpleDp),
        Box::new(Dp),
    ]
}

/// Look a scheduler up by (case-insensitive) paper name, e.g. `"logdp(5)"`.
pub fn scheduler_by_name(name: &str) -> Option<Box<dyn Scheduler + Send + Sync>> {
    let n = name.to_ascii_lowercase();
    Some(match n.as_str() {
        "nodetour" => Box::new(NoDetour),
        "gs" => Box::new(Gs),
        "fgs" => Box::new(Fgs),
        "nfgs" => Box::new(Nfgs),
        "lognfgs" | "lognfgs(5)" => Box::new(LogNfgs::new(5.0)),
        "lognfgs(1)" => Box::new(LogNfgs::new(1.0)),
        "dp" => Box::new(Dp),
        "logdp(1)" => Box::new(LogDp::new(1.0)),
        "logdp(5)" => Box::new(LogDp::new(5.0)),
        "simpledp" => Box::new(SimpleDp),
        "bruteforce" => Box::new(BruteForce::default()),
        _ => {
            // Generic parameterized forms: logdp(<float>), lognfgs(<float>)
            if let Some(arg) = n.strip_prefix("logdp(").and_then(|s| s.strip_suffix(')')) {
                return arg.parse::<f64>().ok().map(|l| Box::new(LogDp::new(l)) as _);
            }
            if let Some(arg) = n.strip_prefix("lognfgs(").and_then(|s| s.strip_suffix(')')) {
                return arg.parse::<f64>().ok().map(|l| Box::new(LogNfgs::new(l)) as _);
            }
            return None;
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laminar_checks() {
        let d = |a, b| Detour::new(a, b);
        assert!(is_strictly_laminar(&[d(0, 3), d(1, 2)])); // nested
        assert!(is_strictly_laminar(&[d(0, 1), d(2, 3)])); // disjoint
        assert!(!is_strictly_laminar(&[d(0, 2), d(1, 3)])); // crossing
        assert!(!is_strictly_laminar(&[d(0, 2), d(2, 3)])); // touching
        assert!(!is_strictly_laminar(&[d(1, 2), d(1, 3)])); // same left
        assert!(is_strictly_laminar(&[d(5, 5)]));
        assert!(is_strictly_laminar(&[]));
    }

    #[test]
    fn paper_schedulers_round_trip_through_scheduler_by_name() {
        // Every self-reported name must resolve back to a scheduler with
        // the same name — catches name-format drift like `LogDP(5)` vs
        // `logdp(5.0)` between the registry and the implementations.
        for s in paper_schedulers() {
            let name = s.name();
            let resolved = scheduler_by_name(&name)
                .unwrap_or_else(|| panic!("scheduler_by_name cannot resolve {name:?}"));
            assert_eq!(resolved.name(), name, "round trip must preserve the name");
        }
    }

    #[test]
    fn lookup_by_name() {
        for n in [
            "NoDetour", "GS", "FGS", "NFGS", "LogNFGS", "DP", "LogDP(1)", "LogDP(5)",
            "SimpleDP", "LogDP(2.5)", "BruteForce",
        ] {
            assert!(scheduler_by_name(n).is_some(), "missing {n}");
        }
        assert!(scheduler_by_name("nope").is_none());
        assert!(scheduler_by_name("logdp(x)").is_none());
    }

    #[test]
    #[should_panic]
    fn bad_detour_panics() {
        let _ = Detour::new(3, 2);
    }
}

/// Diagnostic: solve DP and report (optimal cost, number of memoized cells).
/// Used by the perf harness to size the reachable state space.
pub fn dp_debug_stats(inst: &Instance) -> (crate::model::Cost, usize) {
    let mut s = dp::DpSolver::new(inst, usize::MAX);
    let root = s.cell(0, inst.k() - 1, 0);
    (root + crate::model::virtual_lb(inst), s.memo_len())
}
