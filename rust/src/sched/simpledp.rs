//! **SimpleDP** (§4.5): the DP restricted to solutions whose detour
//! intervals are pairwise disjoint (no intertwined detours). The first DP
//! index is then always `f₁`, giving a two-dimensional table `T[b, n_skip]`:
//!
//! ```text
//! T[f₁, ns]  = 2·s(f₁)·ns
//! skip(b,ns) = T[b−1, ns + x(b)] + 2·(r(b) − r(b−1))·ns
//!            + 2·(ℓ(b) − r(b−1))·x(b)
//! detour_c(b,ns) = T[c−1, ns]
//!            + 2·(r(b) − r(c−1))·ns
//!            + 2·(U + r(b) − ℓ(c))·(ns + n_ℓ(c))
//!            + Σ_{c<f≤b} 2·(ℓ(f) − ℓ(c))·x(f)
//! T[b, ns] = min(skip, min_{c ∈ (f₁, b]} detour_c)
//! cost = T[f_{n_req−1}, 0] + VirtualLB
//! ```
//!
//! (`n_ℓ(f₁) = 0` since no request lies left of the leftmost requested
//! file, which is why the `n_ℓ(a)` terms of the full DP collapse to `ns`.)
//!
//! Complexity `O(n·n_req²)` worst case; like [`super::Dp`] we memoize
//! top-down so only `n_skip` values reachable from the root are computed.
//! Approximation ratio is in `[5/3, 3]` for any `U` (Lemma 2).

use crate::model::{virtual_lb, Cost, Instance};
use crate::sched::{Detour, Schedule, Scheduler};
use crate::util::hash::FxHashMap;

#[derive(Debug, Clone, Copy, Default)]
pub struct SimpleDp;

impl Scheduler for SimpleDp {
    fn name(&self) -> String {
        "SimpleDP".into()
    }

    fn schedule(&self, inst: &Instance) -> Schedule {
        SimpleDpSolver::new(inst).solve().1
    }
}

impl SimpleDp {
    /// Cost of the best disjoint-detour schedule, without reconstruction.
    pub fn cost(inst: &Instance) -> Cost {
        let mut s = SimpleDpSolver::new(inst);
        s.cell(inst.k() - 1, 0) + virtual_lb(inst)
    }
}

const SKIP: u32 = u32::MAX;

pub(crate) struct SimpleDpSolver<'a> {
    inst: &'a Instance,
    memo: FxHashMap<u64, (Cost, u32)>,
}

impl<'a> SimpleDpSolver<'a> {
    pub(crate) fn new(inst: &'a Instance) -> SimpleDpSolver<'a> {
        assert!(inst.k() < (1 << 20));
        assert!(inst.n() < (1 << 44));
        SimpleDpSolver { inst, memo: FxHashMap::default() }
    }

    #[inline]
    fn key(b: usize, ns: u64) -> u64 {
        (b as u64) << 44 | ns
    }

    /// `T[b, ns]`, memoized with an explicit worklist.
    pub(crate) fn cell(&mut self, b: usize, ns: u64) -> Cost {
        let mut stack = vec![(b, ns)];
        while let Some(&(fb, fns)) = stack.last() {
            if self.memo.contains_key(&Self::key(fb, fns)) {
                stack.pop();
                continue;
            }
            if let Some(vc) = self.try_eval(fb, fns, &mut stack) {
                self.memo.insert(Self::key(fb, fns), vc);
                stack.pop();
            }
        }
        self.memo[&Self::key(b, ns)].0
    }

    fn try_eval(
        &self,
        b: usize,
        ns: u64,
        stack: &mut Vec<(usize, u64)>,
    ) -> Option<(Cost, u32)> {
        let inst = self.inst;
        if b == 0 {
            return Some((2 * inst.s(0) as Cost * ns as Cost, SKIP));
        }
        let mut missing = false;
        let lookup = |bb: usize, nns: u64, stack: &mut Vec<(usize, u64)>| -> Option<Cost> {
            match self.memo.get(&Self::key(bb, nns)) {
                Some(&(v, _)) => Some(v),
                None => {
                    stack.push((bb, nns));
                    None
                }
            }
        };

        let mut best: Option<(Cost, u32)> = None;
        // skip branch
        match lookup(b - 1, ns + inst.x(b), stack) {
            Some(t) => {
                let v = t
                    + 2 * (inst.r(b) - inst.r(b - 1)) as Cost * ns as Cost
                    + 2 * (inst.l(b) - inst.r(b - 1)) as Cost * inst.x(b) as Cost;
                best = Some((v, SKIP));
            }
            None => missing = true,
        }
        // detour_c branches: closed-form in-detour cost, no inner recursion.
        let u = inst.u() as Cost;
        for c in 1..=b {
            let Some(t) = lookup(c - 1, ns, stack) else {
                missing = true;
                continue;
            };
            let v = t
                + 2 * (inst.r(b) - inst.r(c - 1)) as Cost * ns as Cost
                + 2 * (u + inst.r(b) as Cost - inst.l(c) as Cost)
                    * (ns as Cost + inst.nl(c) as Cost)
                + 2 * inst.in_detour_span_cost(c, b);
            if best.map_or(true, |(bv, _)| v < bv) {
                best = Some((v, c as u32));
            }
        }
        if missing {
            None
        } else {
            Some(best.expect("at least one branch"))
        }
    }

    pub(crate) fn solve(mut self) -> (Cost, Schedule) {
        let k = self.inst.k();
        let root = self.cell(k - 1, 0);
        let opt = root + virtual_lb(self.inst);
        let mut detours = Vec::new();
        let (mut b, mut ns) = (k - 1, 0u64);
        loop {
            if b == 0 {
                break;
            }
            let (_, choice) = self.memo[&Self::key(b, ns)];
            if choice == SKIP {
                ns += self.inst.x(b);
                b -= 1;
            } else {
                let c = choice as usize;
                detours.push(Detour::new(c, b));
                b = c - 1;
                // ns unchanged: files in (c−1, b] are read by the detour.
            }
        }
        (opt, detours)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ReqFile;
    use crate::sched::{is_strictly_laminar, Dp, Gs, Scheduler};
    use crate::sim::evaluate;

    fn inst(u: u64, files: &[(u64, u64, u64)], m: u64) -> Instance {
        Instance::new(m, u, files.iter().map(|&(l, r, x)| ReqFile { l, r, x }).collect())
            .unwrap()
    }

    #[test]
    fn predicted_cost_equals_simulated() {
        let cases = vec![
            inst(0, &[(0, 5, 1), (10, 12, 9), (40, 60, 1)], 80),
            inst(7, &[(0, 5, 1), (10, 12, 9), (40, 60, 1)], 80),
            inst(3, &[(5, 6, 2), (6, 30, 1), (31, 32, 8), (60, 61, 3)], 100),
            inst(0, &[(2, 4, 2), (10, 30, 5), (33, 34, 1), (50, 80, 4), (90, 99, 2)], 110),
        ];
        for i in cases {
            let (cost, sched) = SimpleDpSolver::new(&i).solve();
            assert_eq!(cost, evaluate(&i, &sched).cost);
            assert!(is_strictly_laminar(&sched));
            // disjointness: stronger than laminar
            let mut s = sched.clone();
            s.sort();
            for w in s.windows(2) {
                assert!(w[0].b < w[1].a, "detours must be disjoint: {:?}", s);
            }
        }
    }

    #[test]
    fn sandwiched_between_dp_and_gs() {
        let cases = vec![
            inst(0, &[(0, 4, 3), (8, 20, 1), (25, 26, 14), (40, 70, 2), (90, 95, 6)], 120),
            inst(13, &[(0, 4, 3), (8, 20, 1), (25, 26, 14), (40, 70, 2), (90, 95, 6)], 120),
        ];
        for i in cases {
            let opt = Dp::optimal_cost(&i);
            let sdp = SimpleDp::cost(&i);
            let gs = evaluate(&i, &Gs.schedule(&i)).cost;
            assert!(opt <= sdp, "OPT {opt} <= SimpleDP {sdp}");
            assert!(sdp <= gs, "SimpleDP {sdp} <= GS {gs} (search space contains GS)");
            assert!(sdp <= 3 * opt, "Lemma 2 upper bound");
        }
    }

    #[test]
    fn atomic_detour_case_matches_full_dp_formula() {
        // On a 2-file instance SimpleDP and DP agree (no intertwining possible).
        for u in [0u64, 5, 50] {
            let i = inst(u, &[(0, 10, 4), (30, 50, 1)], 70);
            assert_eq!(SimpleDp::cost(&i), Dp::optimal_cost(&i));
        }
    }
}
