//! Exhaustive search over detour sets — the exactness oracle for tests.
//!
//! Enumerates every subset of the `k(k+1)/2` possible detours `(a, b)` and
//! evaluates each with the ground-truth simulator. By Lemma 1 an optimal
//! solution is describable as a (strictly laminar) detour set, so the
//! minimum over all subsets is the true optimum. Exponential: use only for
//! `k ≤ ~6`.

use crate::model::Instance;
use crate::sched::{Detour, Schedule, Scheduler};
use crate::sim::evaluate;

#[derive(Debug, Clone, Copy)]
pub struct BruteForce {
    /// Safety cap on `k`: enumeration is `2^(k(k+1)/2)`.
    pub max_k: usize,
}

impl Default for BruteForce {
    fn default() -> Self {
        BruteForce { max_k: 6 }
    }
}

impl Scheduler for BruteForce {
    fn name(&self) -> String {
        "BruteForce".into()
    }

    fn schedule(&self, inst: &Instance) -> Schedule {
        let k = inst.k();
        assert!(
            k <= self.max_k,
            "BruteForce is exponential; refusing k={k} > max_k={}",
            self.max_k
        );
        let mut pairs = Vec::new();
        for a in 0..k {
            for b in a..k {
                pairs.push(Detour::new(a, b));
            }
        }
        let n_pairs = pairs.len();
        assert!(n_pairs < 64);
        let mut best: Option<(i128, Schedule)> = None;
        for mask in 0u64..(1u64 << n_pairs) {
            let detours: Schedule = (0..n_pairs)
                .filter(|&i| mask >> i & 1 == 1)
                .map(|i| pairs[i])
                .collect();
            let cost = evaluate(inst, &detours).cost;
            if best.as_ref().map_or(true, |(c, _)| cost < *c) {
                best = Some((cost, detours));
            }
        }
        best.unwrap().1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{virtual_lb, ReqFile};

    #[test]
    fn finds_the_obvious_detour() {
        // Urgent small file far right of the start: serving it first wins.
        let inst = Instance::new(
            1_000,
            0,
            vec![ReqFile { l: 0, r: 10, x: 1 }, ReqFile { l: 900, r: 910, x: 50 }],
        )
        .unwrap();
        let sched = BruteForce::default().schedule(&inst);
        let cost = evaluate(&inst, &sched).cost;
        // Detour (1,1) then sweep: f1 at 110, f0 at... vs no detour.
        let with_detour = evaluate(&inst, &[Detour::atomic(1)]).cost;
        assert_eq!(cost, with_detour);
        assert!(cost >= virtual_lb(&inst));
    }

    #[test]
    fn single_file_needs_no_detour() {
        let inst =
            Instance::new(100, 5, vec![ReqFile { l: 40, r: 50, x: 2 }]).unwrap();
        let sched = BruteForce::default().schedule(&inst);
        let best = evaluate(&inst, &sched).cost;
        assert_eq!(best, evaluate(&inst, &[]).cost);
        assert_eq!(best, virtual_lb(&inst));
    }

    #[test]
    #[should_panic]
    fn refuses_large_k() {
        let files: Vec<ReqFile> = (0..10)
            .map(|i| ReqFile { l: i * 10, r: i * 10 + 5, x: 1 })
            .collect();
        let inst = Instance::new(200, 0, files).unwrap();
        let _ = BruteForce::default().schedule(&inst);
    }
}
