//! **DP** — the paper's exact polynomial algorithm (§4.3–4.4) — and its
//! span-restricted variant **LogDP** (§4.5).
//!
//! Cell `T[a, b, n_skip]` is the extra cost (on top of `VirtualLB`) of the
//! best head strategy between `r(b)` and `ℓ(a)` given that a detour
//! `(a, f≥b)` exists, no detour `(f₁, f₂)` with `a < f₁ < b < f₂` exists,
//! and exactly `n_skip` file *requests* are skipped when the head first
//! reaches `r(b)`. Recurrence:
//!
//! ```text
//! T[b, b, ns] = 2·s(b)·(ns + n_ℓ(b))
//! skip(a,b,ns)     = T[a, b−1, ns + x(b)]
//!                  + 2·(r(b) − r(b−1))·(ns + n_ℓ(a))
//!                  + 2·(ℓ(b) − r(b−1))·x(b)
//! detour_c(a,b,ns) = T[a, c−1, ns] + T[c, b, ns]
//!                  + 2·(r(b) − r(c−1))·(ns + n_ℓ(a))
//!                  + 2·U·(ns + n_ℓ(c))
//! T[a, b, ns] = min(skip, min_{c ∈ (a, b]} detour_c)
//! OPT = T[f₁, f_{n_f}, 0] + VirtualLB
//! ```
//!
//! The table is *sparsely* reachable in `n_skip`: we memoize top-down so
//! only cells actually touched from the root are computed (the paper's own
//! implementation does the same; the `O(n_req³·n)` bound is a worst case).
//!
//! **LogDP** limits `c` to at most `⌊λ·log₂ n_req⌋` requested files left of
//! `b`, shrinking both the reachable table and the per-cell scan; it is
//! optimal among schedules whose detours span at most that many files.

use crate::model::{virtual_lb, Cost, Instance};
use crate::sched::{Detour, Schedule, Scheduler};
use crate::util::hash::FxHashMap;

/// The exact algorithm.
#[derive(Debug, Clone, Copy, Default)]
pub struct Dp;

/// `LogDP(λ)`: detour span (in requested files) capped at `⌊λ·log₂ k⌋`.
#[derive(Debug, Clone, Copy)]
pub struct LogDp {
    pub lambda: f64,
}

impl LogDp {
    pub fn new(lambda: f64) -> LogDp {
        assert!(lambda > 0.0);
        LogDp { lambda }
    }

    /// Maximum detour span in requested files for instance size `k`.
    pub fn span(&self, k: usize) -> usize {
        let lg = (k.max(2) as f64).log2();
        ((self.lambda * lg).floor() as usize).max(1)
    }
}

impl Scheduler for Dp {
    fn name(&self) -> String {
        "DP".into()
    }

    fn schedule(&self, inst: &Instance) -> Schedule {
        DpSolver::new(inst, usize::MAX).solve().1
    }
}

impl Scheduler for LogDp {
    fn name(&self) -> String {
        format!("LogDP({})", self.lambda)
    }

    fn schedule(&self, inst: &Instance) -> Schedule {
        let span = self.span(inst.k());
        DpSolver::new(inst, span).solve().1
    }
}

impl Dp {
    /// Optimal cost (root cell + VirtualLB) without reconstructing detours.
    pub fn optimal_cost(inst: &Instance) -> Cost {
        let mut s = DpSolver::new(inst, usize::MAX);
        let k = inst.k();
        let root = s.cell(0, k - 1, 0);
        root + virtual_lb(inst)
    }
}

/// The arbitrary-starting-position extension (paper's conclusion): the
/// head starts at position `x_pos` instead of the right end of the tape.
///
/// As the paper observes, it suffices to forbid detours *starting* on the
/// right of `x_pos`: such a schedule is exactly a right-end schedule whose
/// initial `m → x_pos` leg serves nothing, so for every candidate schedule
/// `cost_from(x_pos) = cost_from(m) − n·(m − x_pos)` and the argmin is
/// preserved. [`Scheduler::schedule`] therefore returns the optimal detour
/// list for a head starting at `x_pos`.
///
/// One exception to the identity: at `x_pos ≤ ℓ(f₁)` the *empty* schedule
/// is a cold start — the head never reverses, so its final sweep saves the
/// U-turn ([`crate::sim::evaluate_from`]'s cold-start semantics), which
/// the from-`m` framing cannot express. Both [`DpFromStart::optimal_cost`]
/// and [`Scheduler::schedule`] compare that cold sweep against the DP
/// argmin and prefer it when strictly cheaper.
#[derive(Debug, Clone, Copy)]
pub struct DpFromStart {
    pub x_pos: u64,
}

impl Scheduler for DpFromStart {
    fn name(&self) -> String {
        format!("DP[start={}]", self.x_pos)
    }

    fn schedule(&self, inst: &Instance) -> Schedule {
        if self.x_pos < inst.l(0) {
            // No detour is executable (every ℓ(a) lies right of the head):
            // the only schedule is the cold rightward sweep.
            return Vec::new();
        }
        let (cost_from_m, sched) = DpSolver::new(inst, usize::MAX)
            .with_max_start(self.x_pos)
            .solve();
        if self.x_pos == inst.l(0) {
            let delta = inst.tape_len() as Cost - self.x_pos as Cost;
            let from_x = cost_from_m - inst.n() as Cost * delta;
            if self.cold_sweep_cost(inst) < from_x {
                return Vec::new();
            }
        }
        sched
    }
}

impl DpFromStart {
    /// Optimal cost for a head starting at `x_pos` (requires `x_pos ≥
    /// r(f₁)` so every file remains servable without moving right first;
    /// costs are measured from t = 0 at `x_pos`).
    pub fn optimal_cost(&self, inst: &Instance) -> Cost {
        if self.x_pos < inst.l(0) {
            return self.cold_sweep_cost(inst);
        }
        let (cost_from_m, _) = DpSolver::new(inst, usize::MAX)
            .with_max_start(self.x_pos)
            .solve();
        let delta = inst.tape_len() as Cost - self.x_pos as Cost;
        let from_x = cost_from_m - inst.n() as Cost * delta;
        if self.x_pos == inst.l(0) {
            return from_x.min(self.cold_sweep_cost(inst));
        }
        from_x
    }

    /// Cost of the empty schedule under the cold-start semantics: the head
    /// at `x_pos ≤ ℓ(f₁)` sweeps right with no reversal, so each file is
    /// served at `r(f) − x_pos` with no U-turn charge.
    fn cold_sweep_cost(&self, inst: &Instance) -> Cost {
        (0..inst.k())
            .map(|f| inst.x(f) as Cost * (inst.r(f) as Cost - self.x_pos as Cost))
            .sum()
    }
}

/// Decision stored per cell for reconstruction: `u32::MAX` = skip,
/// otherwise the chosen `c`.
const SKIP: u32 = u32::MAX;

/// Sentinel for "cell not yet computed" in a layer.
const UNSET: Cost = Cost::MIN;

/// One `n_skip` layer of the memo: the `(a, b)` plane for a fixed skip
/// count, as a flat triangular-ish array indexed `a·k + b`.
///
/// The detour scan of a cell reads ~`2·span` cells **all within two
/// layers** (`ns` and `ns + x(b)`), so keeping a layer contiguous turns
/// what was a 100-ns cache miss per lookup on a single 240 MB hashmap into
/// L1/L2 hits — the dominant win of the §Perf pass (see EXPERIMENTS.md).
struct Layer {
    cells: Box<[(Cost, u32)]>,
}

impl Layer {
    fn new(k: usize) -> Layer {
        // Triangular: only a <= b pairs exist (see DpSolver::idx).
        Layer { cells: vec![(UNSET, 0); k * (k + 1) / 2].into_boxed_slice() }
    }
}

pub(crate) struct DpSolver<'a> {
    inst: &'a Instance,
    /// Max `b − c` allowed in `detour_c` (LogDP restriction).
    span: usize,
    /// Highest index allowed to *start* a detour (arbitrary-start-position
    /// extension, paper's conclusion): `k - 1` = unrestricted.
    c_max: usize,
    k: usize,
    /// Memo: `n_skip` → the (a, b) plane for that skip count.
    layers: FxHashMap<u64, Layer>,
}

impl<'a> DpSolver<'a> {
    pub(crate) fn new(inst: &'a Instance, span: usize) -> DpSolver<'a> {
        let k = inst.k();
        assert!(k < (1 << 12), "DP supports up to 4095 requested files");
        // Construct through the alias: `std::collections::HashMap::default()`
        // would silently fall back to SipHash if the field type ever loosened.
        DpSolver { inst, span, c_max: k - 1, k, layers: FxHashMap::default() }
    }

    /// Restrict detours to start at files whose left end is at most
    /// `x_pos` (the head's arbitrary starting position).
    pub(crate) fn with_max_start(mut self, x_pos: u64) -> DpSolver<'a> {
        // Largest index c with l(c) <= x_pos; detours from righter files
        // can never be met by a head starting at x_pos.
        self.c_max = (0..self.k).rev().find(|&c| self.inst.l(c) <= x_pos).unwrap_or(0);
        self
    }

    /// Triangular index for `a <= b`: row `b` holds `b + 1` cells.
    #[inline]
    fn idx(&self, a: usize, b: usize) -> usize {
        debug_assert!(a <= b && b < self.k);
        b * (b + 1) / 2 + a
    }

    fn lookup(&self, a: usize, b: usize, ns: u64) -> Option<Cost> {
        let v = self.layers.get(&ns)?.cells[self.idx(a, b)].0;
        (v != UNSET).then_some(v)
    }

    /// Compute `T[a, b, ns]` — memoized, iterative two-phase DFS.
    ///
    /// Phase 0 of a frame pushes every missing dependency; phase 1
    /// (re-visited once the deps completed) evaluates the cell in a single
    /// O(span) scan over exactly two memo layers.
    pub(crate) fn cell(&mut self, a: usize, b: usize, ns: u64) -> Cost {
        if let Some(v) = self.lookup(a, b, ns) {
            return v;
        }
        let k = self.k;
        // (a, b, ns, phase)
        let mut stack: Vec<(usize, usize, u64, u8)> = vec![(a, b, ns, 0)];
        while let Some((fa, fb, fns, phase)) = stack.pop() {
            if fa == fb {
                let inst = self.inst;
                let v = 2 * inst.s(fb) as Cost * (fns as Cost + inst.nl(fb) as Cost);
                let i = self.idx(fa, fb);
                self.layers.entry(fns).or_insert_with(|| Layer::new(k)).cells[i] = (v, SKIP);
                continue;
            }
            if phase == 0 {
                if self.lookup(fa, fb, fns).is_some() {
                    continue;
                }
                // Re-visit for evaluation once the deps below are done.
                stack.push((fa, fb, fns, 1));
                let xb = self.inst.x(fb);
                {
                    // Layer refs fetched once; dep checks are array reads.
                    let lay_same = self.layers.get(&fns).map(|l| &l.cells);
                    let lay_skip = self.layers.get(&(fns + xb)).map(|l| &l.cells);
                    let missing = |lay: Option<&Box<[(Cost, u32)]>>, i: usize| {
                        lay.map_or(true, |c| c[i].0 == UNSET)
                    };
                    if missing(lay_skip, self.idx(fa, fb - 1)) {
                        stack.push((fa, fb - 1, fns + xb, 0));
                    }
                    for c in self.c_lo(fa, fb)..=fb.min(self.c_max) {
                        if missing(lay_same, self.idx(fa, c - 1)) {
                            stack.push((fa, c - 1, fns, 0));
                        }
                        if missing(lay_same, self.idx(c, fb)) {
                            stack.push((c, fb, fns, 0));
                        }
                    }
                }
            } else {
                let vc = self.eval(fa, fb, fns);
                let i = self.idx(fa, fb);
                self.layers.entry(fns).or_insert_with(|| Layer::new(k)).cells[i] = vc;
            }
        }
        self.lookup(a, b, ns).expect("root cell computed")
    }

    /// Lowest detour start `c` for a cell (LogDP span cap `b − c`).
    #[inline]
    fn c_lo(&self, a: usize, b: usize) -> usize {
        if self.span == usize::MAX {
            a + 1
        } else {
            (a + 1).max(b.saturating_sub(self.span))
        }
    }

    /// Evaluate a cell whose dependencies are all memoized.
    fn eval(&self, a: usize, b: usize, ns: u64) -> (Cost, u32) {
        let inst = self.inst;
        debug_assert!(a < b);
        let skip_dep = self.layers[&(ns + inst.x(b))].cells[self.idx(a, b - 1)].0;
        debug_assert_ne!(skip_dep, UNSET);

        // skip(a, b, ns)
        let skip = skip_dep
            + 2 * (inst.r(b) - inst.r(b - 1)) as Cost * (ns as Cost + inst.nl(a) as Cost)
            + 2 * (inst.l(b) - inst.r(b - 1)) as Cost * inst.x(b) as Cost;
        let mut best = (skip, SKIP);

        // detour_c(a, b, ns) for c ∈ (a, b], with the LogDP span cap and
        // the arbitrary-start cap. The range may be empty (harsh c_max),
        // in which case layer `ns` may not even exist yet.
        let (lo, hi) = (self.c_lo(a, b), b.min(self.c_max));
        if lo <= hi {
            let lay_same = &self.layers[&ns].cells;
            let nla = inst.nl(a) as Cost;
            let u2 = 2 * inst.u() as Cost;
            let rb = inst.r(b) as Cost;
            for c in lo..=hi {
                let t_left = lay_same[self.idx(a, c - 1)].0;
                let t_in = lay_same[self.idx(c, b)].0;
                debug_assert!(t_left != UNSET && t_in != UNSET);
                let v = t_left
                    + t_in
                    + 2 * (rb - inst.r(c - 1) as Cost) * (ns as Cost + nla)
                    + u2 * (ns as Cost + inst.nl(c) as Cost);
                if v < best.0 {
                    best = (v, c as u32);
                }
            }
        }
        best
    }

    /// Solve from the root and reconstruct the detour list.
    pub(crate) fn solve(mut self) -> (Cost, Schedule) {
        let k = self.inst.k();
        let root = self.cell(0, k - 1, 0);
        let opt = root + virtual_lb(self.inst);
        // Reconstruct: walk decisions. A cell's context detour (a, ·) is
        // implicit (root = final sweep); each detour_c decision materializes
        // the detour (c, b).
        let mut detours = Vec::new();
        let mut todo = vec![(0usize, k - 1, 0u64)];
        while let Some((a, b, ns)) = todo.pop() {
            if a == b {
                continue;
            }
            let (_, choice) = self.layers[&ns].cells[self.idx(a, b)];
            if choice == SKIP {
                todo.push((a, b - 1, ns + self.inst.x(b)));
            } else {
                let c = choice as usize;
                detours.push(Detour::new(c, b));
                // strategy left of the detour (may itself contain detours)
                todo.push((a, c - 1, ns));
                // strategy inside the detour (c, b)
                todo.push((c, b, ns));
            }
        }
        (opt, detours)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ReqFile;
    use crate::sched::{is_strictly_laminar, BruteForce, Gs, NoDetour};
    use crate::sim::evaluate;

    fn inst(u: u64, files: &[(u64, u64, u64)], m: u64) -> Instance {
        Instance::new(m, u, files.iter().map(|&(l, r, x)| ReqFile { l, r, x }).collect())
            .unwrap()
    }

    #[test]
    fn two_files_hand_checked() {
        // Contiguous files; worked through §4.3's formulas by hand in the
        // design notes: OPT = min(no-detour, atomic detour on f2).
        for (x1, x2, u) in [(1u64, 1u64, 0u64), (5, 1, 0), (1, 5, 0), (3, 4, 7), (10, 1, 100)] {
            let i = inst(u, &[(0, 10, x1), (10, 30, x2)], 50);
            let (opt, sched) = DpSolver::new(&i, usize::MAX).solve();
            let simulated = evaluate(&i, &sched).cost;
            assert_eq!(opt, simulated, "predicted vs simulated, x=({x1},{x2}) U={u}");
            let no_detour = evaluate(&i, &[]).cost;
            let detour2 = evaluate(&i, &[Detour::atomic(1)]).cost;
            assert_eq!(opt, no_detour.min(detour2));
        }
    }

    #[test]
    fn matches_bruteforce_on_fixtures() {
        let cases = vec![
            inst(0, &[(0, 5, 1), (10, 12, 9), (40, 60, 1)], 80),
            inst(7, &[(0, 5, 1), (10, 12, 9), (40, 60, 1)], 80),
            inst(0, &[(5, 6, 2), (6, 30, 1), (31, 32, 8), (60, 61, 3)], 100),
            inst(3, &[(5, 6, 2), (6, 30, 1), (31, 32, 8), (60, 61, 3)], 100),
            inst(1, &[(0, 1, 1), (2, 3, 1), (4, 5, 1), (6, 7, 1), (8, 9, 1)], 10),
        ];
        for i in cases {
            let (opt, sched) = DpSolver::new(&i, usize::MAX).solve();
            assert_eq!(opt, evaluate(&i, &sched).cost, "self-consistency");
            let bf = BruteForce::default().schedule(&i);
            assert_eq!(opt, evaluate(&i, &bf).cost, "DP vs brute force");
            assert!(is_strictly_laminar(&sched), "laminar: {:?}", sched);
        }
    }

    #[test]
    fn never_worse_than_baselines() {
        let i = inst(
            11,
            &[(0, 4, 3), (8, 20, 1), (25, 26, 14), (40, 70, 2), (90, 95, 6)],
            120,
        );
        let opt = Dp::optimal_cost(&i);
        for s in [&NoDetour as &dyn Scheduler, &Gs] {
            assert!(opt <= evaluate(&i, &s.schedule(&i)).cost, "vs {}", s.name());
        }
        assert!(opt >= virtual_lb(&i));
    }

    #[test]
    fn logdp_spans() {
        assert_eq!(LogDp::new(1.0).span(256), 8);
        assert_eq!(LogDp::new(5.0).span(256), 40);
        assert_eq!(LogDp::new(1.0).span(2), 1);
        assert_eq!(LogDp::new(0.1).span(4), 1); // floor→0 clamped to 1
    }

    #[test]
    fn logdp_between_gs_and_dp() {
        let i = inst(
            2,
            &[(0, 4, 3), (8, 20, 1), (25, 26, 14), (40, 70, 2), (90, 95, 6)],
            120,
        );
        let opt = Dp::optimal_cost(&i);
        let gs = evaluate(&i, &Gs.schedule(&i)).cost;
        for lambda in [1.0, 5.0] {
            let c = evaluate(&i, &LogDp::new(lambda).schedule(&i)).cost;
            assert!(c >= opt && c <= gs, "λ={lambda}: {opt} <= {c} <= {gs}");
        }
        // λ large enough ⇒ LogDP == DP.
        let c = evaluate(&i, &LogDp::new(100.0).schedule(&i)).cost;
        assert_eq!(c, opt);
    }

    #[test]
    fn from_start_restricts_detours_and_stays_optimal() {
        use crate::sim::evaluate_from;
        // Urgent file far right: unrestricted DP detours on it, but a head
        // starting left of it cannot.
        let i = inst(2, &[(0, 10, 1), (200, 210, 1), (800, 810, 30)], 1000);
        for x_pos in [1000u64, 600, 150] {
            let solver = DpFromStart { x_pos };
            let sched = solver.schedule(&i);
            for d in &sched {
                assert!(i.l(d.a) <= x_pos, "detour {d:?} beyond start {x_pos}");
            }
            // Optimal among ALL laminar schedules whose detours start <= x_pos:
            // enumerate via brute force over detour subsets.
            let k = i.k();
            let mut pairs = Vec::new();
            for a in 0..k {
                if i.l(a) <= x_pos {
                    for b in a..k {
                        pairs.push(Detour::new(a, b));
                    }
                }
            }
            let mut best = Cost::MAX;
            for mask in 0u32..(1 << pairs.len()) {
                let ds: Vec<Detour> = (0..pairs.len())
                    .filter(|&j| mask >> j & 1 == 1)
                    .map(|j| pairs[j])
                    .collect();
                best = best.min(evaluate_from(&i, &ds, x_pos).cost);
            }
            assert_eq!(evaluate_from(&i, &sched, x_pos).cost, best, "x_pos={x_pos}");
            // And the documented cost identity.
            let delta = (i.tape_len() - x_pos) as Cost * i.n() as Cost;
            assert_eq!(
                evaluate_from(&i, &sched, x_pos).cost,
                evaluate(&i, &sched).cost - delta
            );
            assert_eq!(solver.optimal_cost(&i), best);
        }
    }

    #[test]
    fn from_start_cold_boundary_prefers_the_sweep() {
        use crate::sim::evaluate_from;
        // Head exactly at ℓ(f₁): the empty schedule is a cold start — the
        // head never reverses, so it saves the U-turn (fixed semantics) —
        // while any detour pays two. With a large U the cold sweep wins
        // and the solver must both return and predict it.
        let i = inst(50, &[(10, 20, 1), (30, 40, 1)], 100);
        let solver = DpFromStart { x_pos: 10 };
        let sched = solver.schedule(&i);
        let cost = evaluate_from(&i, &sched, 10).cost;
        assert_eq!(solver.optimal_cost(&i), cost, "predicted vs simulated");
        // Exhaustive over the valid laminar lists (only f0 starts ≤ 10).
        let mut best = Cost::MAX;
        for ds in [vec![], vec![Detour::atomic(0)], vec![Detour::new(0, 1)]] {
            best = best.min(evaluate_from(&i, &ds, 10).cost);
        }
        assert_eq!(cost, best);
        assert!(sched.is_empty(), "cold sweep beats every detour at U=50");
        assert_eq!(cost, (20 - 10) + (40 - 10));
    }

    #[test]
    fn from_start_at_tape_end_equals_plain_dp() {
        let i = inst(7, &[(0, 4, 3), (8, 20, 1), (25, 26, 14), (40, 70, 2)], 120);
        let plain = evaluate(&i, &Dp.schedule(&i)).cost;
        let ext = DpFromStart { x_pos: i.tape_len() };
        assert_eq!(evaluate(&i, &ext.schedule(&i)).cost, plain);
        assert_eq!(ext.optimal_cost(&i), plain);
    }

    #[test]
    fn uturn_penalty_changes_the_optimal_structure() {
        // With U = 0 a detour is worth it; with a harsh U it is not.
        let i0 = inst(0, &[(0, 100, 1), (500, 501, 30)], 1000);
        let (_, s0) = DpSolver::new(&i0, usize::MAX).solve();
        assert!(!s0.is_empty(), "cheap U-turns: serve the urgent file first");
        let i1 = i0.with_u(1_000_000);
        let (_, s1) = DpSolver::new(&i1, usize::MAX).solve();
        assert!(s1.is_empty(), "harsh U-turns: a single sweep is optimal");
    }
}

impl<'a> DpSolver<'a> {
    /// Number of memoized cells (diagnostics).
    pub(crate) fn memo_len(&self) -> usize {
        // audit:allow(hash-iter) order-insensitive sum over memo layers; diagnostics only, never serialized into a golden artifact
        self.layers
            .values()
            .map(|l| l.cells.iter().filter(|c| c.0 != UNSET).count())
            .sum()
    }
}
