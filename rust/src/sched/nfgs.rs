//! **NFGS** / **LogNFGS** — Non-atomic Filtered Greedy Scheduling
//! (Appendix B.4–B.5): start from FGS, then scan files left-to-right and
//! upgrade atomic detours to the multi-file detour `(f, f*)` minimizing the
//! Δ estimate of Definition 1 (U-turn aware):
//!
//! ```text
//! Δ(L,(a,b)) = 2·(r(b) − ℓ(a) + U)·( Σ_{f<a} x(f) + Σ_{f>b, f∉L} x(f) )
//!   − 2·Σ_{f∈[a,b], f∉L} x(f) · ( ℓ(a) − ℓ(f₁) + Σ_{(f',g')∈L, f'<a} (r(g')−ℓ(f')+U) )
//! ```
//!
//! where `f ∈ L` means `f` is covered by some detour of `L`. We apply the
//! paper's three corrections (allow `f* = f`; never drop a detour covered by
//! an earlier multi-file detour; index `f' < a` in the last sum) and one
//! further repair implied by §4.2's prose ("after removing the detour
//! starting from a if it existed"): accepting `(f, f*)` *replaces* the
//! previous detour starting at `f` instead of coexisting with it.
//!
//! **LogNFGS** caps the candidate span at `⌊λ·log₂ n_req⌋` requested files.

use crate::model::{Cost, Instance};
use crate::sched::fgs::fgs_filter;
use crate::sched::{Detour, Schedule, Scheduler};

#[derive(Debug, Clone, Copy, Default)]
pub struct Nfgs;

/// LogNFGS with span parameter λ (the paper's experiments use λ = 5).
#[derive(Debug, Clone, Copy)]
pub struct LogNfgs {
    pub lambda: f64,
}

impl LogNfgs {
    pub fn new(lambda: f64) -> LogNfgs {
        assert!(lambda > 0.0);
        LogNfgs { lambda }
    }

    fn span(&self, k: usize) -> usize {
        let lg = (k.max(2) as f64).log2();
        ((self.lambda * lg).floor() as usize).max(1)
    }
}

impl Scheduler for Nfgs {
    fn name(&self) -> String {
        "NFGS".into()
    }

    fn schedule(&self, inst: &Instance) -> Schedule {
        nfgs_run(inst, usize::MAX)
    }
}

impl Scheduler for LogNfgs {
    fn name(&self) -> String {
        format!("LogNFGS({})", self.lambda)
    }

    fn schedule(&self, inst: &Instance) -> Schedule {
        nfgs_run(inst, self.span(inst.k()))
    }
}

fn nfgs_run(inst: &Instance, span: usize) -> Schedule {
    let k = inst.k();
    let u = inst.u() as Cost;
    let l0 = inst.l(0) as Cost;
    // det[a] = Some(b): the detour starting at a (at most one per file).
    let mut det: Vec<Option<usize>> = fgs_filter(inst)
        .into_iter()
        .map(|keep| if keep { Some(0) } else { None })
        .collect();
    for (f, d) in det.iter_mut().enumerate() {
        if d.is_some() {
            *d = Some(f);
        }
    }

    let mut rightest: i64 = -1;
    for f in 0..k {
        let was = det[f];
        det[f] = None; // temp = res \ {(f, f)}

        // Coverage of temp and its prefix sums (O(k) per iteration).
        let mut covered = vec![false; k];
        for (a, d) in det.iter().enumerate() {
            if let Some(b) = *d {
                for g in a..=b {
                    covered[g] = true;
                }
            }
        }
        // uncx[i+1] = Σ_{g ≤ i, g∉L} x(g)
        let mut uncx = vec![0 as Cost; k + 1];
        for g in 0..k {
            uncx[g + 1] = uncx[g] + if covered[g] { 0 } else { inst.x(g) as Cost };
        }
        // D = Σ_{(f',g')∈L, f'<f} (r(g') − ℓ(f') + U)
        let d_left: Cost = det[..f]
            .iter()
            .enumerate()
            .filter_map(|(a, d)| d.map(|b| inst.r(b) as Cost - inst.l(a) as Cost + u))
            .sum();
        let depth = inst.l(f) as Cost - l0 + d_left;
        let pending_left = inst.nl(f) as Cost; // Σ_{g<f} x(g)

        // Scan candidates f' ∈ [f, f+span]; Δ in O(1) each.
        let hi = if span == usize::MAX { k - 1 } else { (f + span).min(k - 1) };
        let mut best: Option<(Cost, usize)> = None;
        for fp in f..=hi {
            let skipped_right = uncx[k] - uncx[fp + 1];
            let term1 = 2 * (inst.r(fp) as Cost - inst.l(f) as Cost + u)
                * (pending_left + skipped_right);
            let inside_uncov = uncx[fp + 1] - uncx[f];
            let term2 = 2 * inside_uncov * depth;
            let delta = term1 - term2;
            if best.map_or(true, |(bd, _)| delta < bd) {
                best = Some((delta, fp));
            }
        }
        let (mut best_delta, mut fstar) = best.expect("candidate range non-empty");

        // Correction 2 (Appendix B): if f held a detour and is covered by an
        // earlier multi-file detour, Δ ≥ 0 artificially — keep the atomic
        // detour rather than dropping it.
        if best_delta >= 0 && was.is_some() && rightest > f as i64 {
            fstar = f;
            // Recompute Δ for (f, f) — same formula, fp = f.
            let skipped_right = uncx[k] - uncx[f + 1];
            let term1 = 2 * (inst.r(f) as Cost - inst.l(f) as Cost + u)
                * (pending_left + skipped_right);
            let term2 = 2 * (uncx[f + 1] - uncx[f]) * depth;
            best_delta = term1 - term2;
            // Keep regardless of sign (the "never remove" repair).
            det[f] = was;
            let _ = (fstar, best_delta);
            continue;
        }

        if best_delta < 0 {
            det[f] = Some(fstar);
            rightest = rightest.max(fstar as i64);
        } else {
            det[f] = was; // keep whatever FGS decided
        }
    }

    det.iter()
        .enumerate()
        .filter_map(|(a, d)| d.map(|b| Detour::new(a, b)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ReqFile;
    use crate::sched::{Fgs, Gs};
    use crate::sim::evaluate;

    fn inst(u: u64, files: &[(u64, u64, u64)], m: u64) -> Instance {
        Instance::new(m, u, files.iter().map(|&(l, r, x)| ReqFile { l, r, x }).collect())
            .unwrap()
    }

    #[test]
    fn multi_file_detour_beats_atomic_ones() {
        // A mildly urgent file (f2) whose own detour FGS filters out, right
        // next to a hot file (f1): riding f2 on the (1,2) detour serves it
        // almost for free, so NFGS must upgrade (1,1) -> (1,2). (NFGS's
        // delta cannot merge two detours that FGS *kept* -- the estimate
        // sees covered files as zero-benefit -- so the inner file must be
        // one FGS dropped.)
        let i = inst(
            50,
            &[(0, 10, 1), (800, 810, 30), (820, 830, 1)],
            1_000,
        );
        let sched = Nfgs.schedule(&i);
        let cost = evaluate(&i, &sched).cost;
        let gs = evaluate(&i, &Gs.schedule(&i)).cost;
        assert!(cost <= gs, "NFGS {cost} <= GS {gs}");
        // And it should find a multi-file detour.
        assert!(
            sched.iter().any(|d| d.b > d.a),
            "expected a non-atomic detour in {sched:?}"
        );
    }

    #[test]
    fn not_worse_than_fgs_on_fixtures() {
        let cases = vec![
            inst(0, &[(0, 4, 3), (8, 20, 1), (25, 26, 14), (40, 70, 2), (90, 95, 6)], 120),
            inst(9, &[(0, 4, 3), (8, 20, 1), (25, 26, 14), (40, 70, 2), (90, 95, 6)], 120),
            inst(50, &[(0, 10, 1), (800, 810, 20), (820, 830, 20)], 1_000),
            inst(3, &[(5, 6, 1), (7, 40, 1), (41, 42, 20)], 50),
        ];
        for i in cases {
            let nfgs = evaluate(&i, &Nfgs.schedule(&i)).cost;
            let fgs = evaluate(&i, &Fgs.schedule(&i)).cost;
            assert!(nfgs <= fgs, "NFGS {nfgs} <= FGS {fgs}");
        }
    }

    #[test]
    fn lognfgs_restricts_span() {
        let files: Vec<(u64, u64, u64)> = (0..12)
            .map(|i| (i * 100, i * 100 + 10, if i > 5 { 30 } else { 1 }))
            .collect();
        let i = inst(5, &files, 1_200);
        let span = LogNfgs::new(1.0).span(12); // ⌊log₂ 12⌋ = 3
        assert_eq!(span, 3);
        for d in LogNfgs::new(1.0).schedule(&i) {
            assert!(d.b - d.a <= span);
        }
        // λ large enough ⇒ identical to NFGS.
        assert_eq!(LogNfgs::new(100.0).schedule(&i), Nfgs.schedule(&i));
    }

    #[test]
    fn schedules_have_distinct_left_endpoints() {
        let i = inst(7, &[(0, 4, 3), (8, 20, 1), (25, 26, 14), (40, 70, 2)], 120);
        let s = Nfgs.schedule(&i);
        let mut lefts: Vec<usize> = s.iter().map(|d| d.a).collect();
        lefts.sort();
        lefts.dedup();
        assert_eq!(lefts.len(), s.len());
    }
}
