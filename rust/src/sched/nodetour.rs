//! **NoDetour** (§4.2): no detour at all — the head moves straight to the
//! leftmost requested file and reads everything in a single left-to-right
//! sweep. Minimizes the makespan but can be arbitrarily far from the optimal
//! average service time.

use crate::model::Instance;
use crate::sched::{Schedule, Scheduler};

#[derive(Debug, Clone, Copy, Default)]
pub struct NoDetour;

impl Scheduler for NoDetour {
    fn name(&self) -> String {
        "NoDetour".into()
    }

    fn schedule(&self, _inst: &Instance) -> Schedule {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ReqFile;
    use crate::sim::evaluate;

    #[test]
    fn single_sweep_cost() {
        let inst = Instance::new(
            100,
            4,
            vec![ReqFile { l: 10, r: 20, x: 1 }, ReqFile { l: 60, r: 80, x: 2 }],
        )
        .unwrap();
        let out = evaluate(&inst, &NoDetour.schedule(&inst));
        // 100→10 (90) + U (94); f0 at 94+10, f1 at 94+70.
        assert_eq!(out.cost, 104 + 2 * 164);
        assert_eq!(out.uturns, 1);
    }
}
