//! **FGS** — Filtered Greedy Scheduling (Appendix B.3): start from GS and
//! iteratively remove detours that Equation (5) marks detrimental, for
//! `n_req` passes (a removal can make another detour detrimental).
//!
//! Equation (5) (U-turn-aware, factor 2 dropped, and with `ℓ` measured from
//! the leftmost requested file so the paper's "tape starts at a requested
//! file" simplification is not required): remove `(f, f)` iff
//!
//! ```text
//! x(f)·( ℓ(f) − ℓ(f₁) + Σ_{g<f, g∈L} (s(g)+U) )
//!      <  (s(f)+U) · ( Σ_{g<f} x(g) + Σ_{g>f, g∉L} x(g) )
//! ```
//!
//! LHS = delay inflicted on `f` by serving it in the final sweep instead;
//! RHS = delay its detour inflicts on every pending request.

use crate::model::{Cost, Instance};
use crate::sched::{Detour, Schedule, Scheduler};

#[derive(Debug, Clone, Copy, Default)]
pub struct Fgs;

impl Scheduler for Fgs {
    fn name(&self) -> String {
        "FGS".into()
    }

    fn schedule(&self, inst: &Instance) -> Schedule {
        let in_l = fgs_filter(inst);
        (0..inst.k()).filter(|&f| in_l[f]).map(Detour::atomic).collect()
    }
}

/// Run the FGS filtering passes; returns which files keep their detour.
/// O(n_req²): each pass maintains running prefix/suffix terms in O(n_req).
pub(crate) fn fgs_filter(inst: &Instance) -> Vec<bool> {
    let k = inst.k();
    let u = inst.u() as Cost;
    let l0 = inst.l(0) as Cost;
    let mut in_l = vec![true; k];
    for _pass in 0..k {
        let mut changed = false;
        // Running: Σ_{g<f, g∈L}(s(g)+U)   (left-to-right accumulator)
        let mut left_detour_len: Cost = 0;
        // Σ_{g>f, g∉L} x(g): start with the full not-in-L sum and peel.
        let mut notl_x_right: Cost = (0..k)
            .filter(|&g| !in_l[g])
            .map(|g| inst.x(g) as Cost)
            .sum();
        for f in 0..k {
            // peel f itself from the suffix (it concerns only g > f)
            if !in_l[f] {
                notl_x_right -= inst.x(f) as Cost;
            }
            if in_l[f] {
                let lhs = inst.x(f) as Cost
                    * (inst.l(f) as Cost - l0 + left_detour_len);
                let rhs = (inst.s(f) as Cost + u)
                    * (inst.nl(f) as Cost + notl_x_right);
                if lhs < rhs {
                    in_l[f] = false;
                    changed = true;
                    // f is now not-in-L but only affects g < f terms of
                    // *later* passes; within this pass the suffix for the
                    // remaining f' > f must now count f... it already
                    // does not (we peeled it only when !in_l — re-add):
                    // f < f' means f contributes to Σ_{g<f'} x(g) via
                    // nl(f'), not the suffix. Nothing to fix.
                } else {
                    left_detour_len += inst.s(f) as Cost + u;
                }
            }
        }
        if !changed {
            break;
        }
    }
    in_l
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ReqFile;
    use crate::sched::Gs;
    use crate::sim::evaluate;

    fn inst(u: u64, files: &[(u64, u64, u64)], m: u64) -> Instance {
        Instance::new(m, u, files.iter().map(|&(l, r, x)| ReqFile { l, r, x }).collect())
            .unwrap()
    }

    #[test]
    fn removes_the_gs_worst_case_detour() {
        // GS's worst case (§4.2): a huge single-request file right of a
        // small very urgent one. FGS must drop the huge file's detour.
        let i = inst(0, &[(0, 10, 100), (500, 1_500, 1)], 2_000);
        let sched = Fgs.schedule(&i);
        assert!(
            !sched.contains(&Detour::atomic(1)),
            "the 1000-long detour delays 100 urgent requests and must go"
        );
        let fgs = evaluate(&i, &sched).cost;
        let gs = evaluate(&i, &Gs.schedule(&i)).cost;
        assert!(fgs < gs);
    }

    #[test]
    fn keeps_beneficial_detours() {
        // Urgent file far right: its detour helps and must stay.
        let i = inst(0, &[(0, 10, 1), (900, 910, 50)], 1_000);
        let sched = Fgs.schedule(&i);
        assert!(sched.contains(&Detour::atomic(1)));
    }

    #[test]
    fn never_worse_than_gs_on_fixtures() {
        let cases = vec![
            inst(0, &[(0, 4, 3), (8, 20, 1), (25, 26, 14), (40, 70, 2), (90, 95, 6)], 120),
            inst(9, &[(0, 4, 3), (8, 20, 1), (25, 26, 14), (40, 70, 2), (90, 95, 6)], 120),
            inst(2, &[(5, 6, 1), (7, 40, 1), (41, 42, 20)], 50),
        ];
        for i in cases {
            let fgs = evaluate(&i, &Fgs.schedule(&i)).cost;
            let gs = evaluate(&i, &Gs.schedule(&i)).cost;
            assert!(fgs <= gs, "FGS {fgs} <= GS {gs}");
        }
    }

    #[test]
    fn harsh_uturn_penalty_strips_all_detours() {
        let i = inst(
            1_000_000,
            &[(0, 4, 3), (8, 20, 1), (25, 26, 14), (40, 70, 2)],
            120,
        );
        assert!(Fgs.schedule(&i).is_empty());
    }
}
