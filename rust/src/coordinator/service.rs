//! The coordinator service: router + dispatcher + drive-worker pool.
//!
//! Built on `std::thread` + channels (the offline registry has no tokio;
//! the work here is CPU-bound scheduling, for which OS threads are the
//! right tool anyway). One worker thread models one tape drive: batches
//! for distinct tapes run concurrently up to the drive count, batches for
//! the same tape serialize through the batcher (one open batch per tape).
//!
//! **Drive placement** is a second routing stage after the batcher: the
//! dispatcher picks *which* drive a batch lands on through the shared
//! resource layer ([`crate::resources`] — the same [`DrivePool`] state
//! machine the replay engine steps in virtual time). Under
//! [`Affinity::Lru`] a tape stays mounted after its batch (lazy unmount),
//! a batch for a loaded idle drive is a *remount hit* (mount charge
//! skipped, `remount_hits` metric), and when no empty drive is free the
//! least-recently-used loaded drive is evicted (charging
//! `unmount_s + mount_s`). Under [`Affinity::None`] every batch pays the
//! paper's fixed `mount_s` — the legacy model.
//!
//! **Cartridge exclusivity** (`exclusive_tapes`, default on): a physical
//! cartridge exists once, so the dispatcher consults the shared
//! [`CartridgeLedger`] before placement — a batch whose tape is in use in
//! another drive parks on that cartridge's FIFO waitlist instead of
//! mounting a second copy, and dispatches when the worker serving the
//! tape frees it. The park → dispatch interval is the `cartridge_wait`
//! metric (`cartridge_parks`, mean/max wait in [`MetricsSnapshot`]).
//!
//! **Robot arms**: with `DriveParams::n_arms > 0` every mount/unmount
//! reserves an interval on the shared [`ArmTimeline`] (wall-clock µs,
//! anchored at service start); the drive worker *sleeps to the
//! reservation edge* — so arm contention shows up in real end-to-end
//! latency — and then charges the op durations exactly as before. The
//! exact event-ordered arm pool remains a virtual-time phenomenon of the
//! replay engine; this is its wall-clock charge model, sharing the same
//! reservation arithmetic as the analytic [`crate::sim::LibrarySim`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::{Batch, Batcher, BatcherConfig, PushOutcome};
use super::metrics::{debug_assert_drain_invariant, MetricsSnapshot, SharedMetrics};
use crate::model::{Instance, Tape};
use crate::obs::{write_counter, write_gauge, write_type, Registry, TraceRecorder};
use crate::resources::{ArmTimeline, CartridgeLedger, DrivePool, DriveStage};
use crate::runtime::{BackendPolicy, SimpleDpBackend};
use crate::sched::Scheduler;
use crate::sim::{evaluate, Affinity, DriveParams, MountPlan};
use crate::util::sync::{lock_recover, wait_recover, wait_timeout_recover};

/// A client read request for one file on one tape.
#[derive(Debug, Clone)]
pub struct ReadRequest {
    pub id: u64,
    pub tape: String,
    /// 0-based index of the file on the tape.
    pub file_index: usize,
}

/// Why a [`Coordinator::submit`] was not accepted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// No tape with that name in the catalog.
    UnknownTape,
    /// The file index is past the end of the tape.
    BadFileIndex,
    /// The service is draining ([`Coordinator::finish`] was called).
    Stopping,
    /// The tape's batch queue is at its backlog bound (`max_tape_backlog`).
    /// The request was shed; the caller may retry once the dispatcher
    /// drains the tape.
    Busy,
    /// The shard that owns this tape has no live server behind it (a
    /// networked worker died and has not rejoined). Unlike `Busy` this is
    /// not retryable on a timescale the submitter controls: the request
    /// was never accepted anywhere. Only the networked cluster paths
    /// (`net::server`) produce this; an in-process `Coordinator` never
    /// does.
    ShardDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::UnknownTape => write!(f, "unknown tape"),
            SubmitError::BadFileIndex => write!(f, "file index out of range"),
            SubmitError::Stopping => write!(f, "service is stopping"),
            SubmitError::Busy => write!(f, "tape backlog full, retry later"),
            SubmitError::ShardDown => write!(f, "shard down, request not accepted"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A served request with its measured latencies.
#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    pub request_id: u64,
    pub tape: String,
    /// End-to-end: submit → served (queueing + mount + in-tape), seconds.
    pub latency_s: f64,
    /// In-tape service time component, seconds (the paper's objective).
    pub service_s: f64,
}

/// Coordinator configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct CoordinatorConfig {
    /// Number of drive workers (48 in the IN2P3 library).
    pub n_drives: usize,
    pub batcher: BatcherConfig,
    pub drive: DriveParams,
    /// Drive-placement policy: [`Affinity::Lru`] keeps tapes mounted and
    /// routes batches to drives already holding them; [`Affinity::None`]
    /// is the legacy fixed mount-cost model.
    pub affinity: Affinity,
    /// Per-tape mount exclusivity (default on): one cartridge, one drive.
    /// Batches whose tape is in use elsewhere park on a per-cartridge
    /// waitlist until the cartridge frees; `false` restores the old
    /// any-drive placement (a hot tape could be "mounted" twice).
    pub exclusive_tapes: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            n_drives: 4,
            batcher: BatcherConfig::default(),
            drive: DriveParams::default(),
            affinity: Affinity::None,
            exclusive_tapes: true,
        }
    }
}

/// A batch parked by the dispatcher because its cartridge was in use.
struct ParkedBatch {
    batch: Batch,
    parked_at: Instant,
}

/// The coordinator's share of the physical resource layer, under one lock
/// (drive table + cartridge ledger must transition together). The
/// dispatcher claims drives and cartridges here; workers release them and
/// signal `resource_freed`.
struct Resources {
    drives: DrivePool<String, ()>,
    ledger: CartridgeLedger<String, ParkedBatch>,
    /// Monotone dispatch tick feeding the drives' LRU order.
    tick: u64,
}

struct Shared {
    batcher: Mutex<Batcher>,
    wakeup: Condvar,
    submit_times: Mutex<HashMap<u64, Instant>>,
    catalog: Mutex<HashMap<String, Tape>>,
    metrics: SharedMetrics,
    completions: Mutex<Vec<Completion>>,
    stopping: AtomicBool,
    resources: Mutex<Resources>,
    resource_freed: Condvar,
    /// The virtual arm timeline (wall-µs grid anchored at `arm_origin`):
    /// mounts/unmounts reserve intervals, workers sleep to the edge.
    arms: Mutex<ArmTimeline>,
    arm_origin: Instant,
    /// Request-lifecycle trace sink: when set, every completion emits one
    /// span per pipeline stage, on the wall-µs grid of `arm_origin`
    /// (`--trace-out`). `None` keeps the hot path free of span work.
    trace: Option<Arc<TraceRecorder>>,
    /// Shard id stamped on every span and exposition label (0 for a
    /// standalone coordinator).
    shard: u32,
}

impl Shared {
    /// Wall-clock µs since service start — the arm timeline's grid.
    fn wall_us(&self) -> u64 {
        self.arm_origin.elapsed().as_micros() as u64
    }
}

/// The running service. Create with [`Coordinator::start`], feed with
/// [`Coordinator::submit`], stop with [`Coordinator::finish`].
pub struct Coordinator {
    cfg: CoordinatorConfig,
    shared: Arc<Shared>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

struct Job {
    batch: Batch,
    instance: Instance,
    /// Mount-pipeline latency this batch pays (0 on a remount hit; see
    /// [`DriveParams::mount_charge_s`]).
    mount_charge_s: f64,
    /// How the batch landed on its drive — drives the worker's robot-arm
    /// reservation (hits need no arm).
    plan: MountPlan,
    /// On an eviction with exclusive tapes: the cartridge the placement
    /// stage began evicting. The worker holds it through the arm
    /// reservation and releases it unthreaded once the arm op clears —
    /// mirroring the replay engine, where the evict-unmount frees the
    /// cartridge at the unmount-done event, not at placement.
    evicted: Option<String>,
    /// When the batch left the batcher (window close, cap split, or
    /// drain flush) — the end of its `batch_seal` span.
    sealed_at: Instant,
    /// When it became placeable: `sealed_at` unless the batch parked on
    /// its cartridge first (the gap is its `cartridge_wait` span).
    unparked_at: Instant,
    /// When the placement stage claimed its drive.
    placed_at: Instant,
}

impl Coordinator {
    /// Start the service over a tape catalog with the given policy.
    pub fn start(
        cfg: CoordinatorConfig,
        catalog: impl IntoIterator<Item = Tape>,
        policy: Arc<dyn Scheduler + Send + Sync>,
    ) -> Coordinator {
        Coordinator::start_traced(cfg, catalog, policy, None, 0)
    }

    /// [`Coordinator::start`] with a request-lifecycle trace sink: every
    /// completion records one span per pipeline stage (submit → … →
    /// complete) into `trace`, stamped with `shard`, on a wall-clock µs
    /// grid anchored at service start. The recorder is a pure observer —
    /// serving behavior is identical with it on or off.
    pub fn start_traced(
        cfg: CoordinatorConfig,
        catalog: impl IntoIterator<Item = Tape>,
        policy: Arc<dyn Scheduler + Send + Sync>,
        trace: Option<Arc<TraceRecorder>>,
        shard: u32,
    ) -> Coordinator {
        assert!(cfg.n_drives > 0, "a coordinator needs at least one drive");
        let shared = Arc::new(Shared {
            batcher: Mutex::new(Batcher::new(cfg.batcher)),
            wakeup: Condvar::new(),
            submit_times: Mutex::new(HashMap::new()),
            catalog: Mutex::new(
                catalog.into_iter().map(|t| (t.name.clone(), t)).collect(),
            ),
            metrics: SharedMetrics::default(),
            completions: Mutex::new(Vec::new()),
            stopping: AtomicBool::new(false),
            resources: Mutex::new(Resources {
                drives: DrivePool::new(cfg.n_drives),
                ledger: CartridgeLedger::new(),
                tick: 0,
            }),
            resource_freed: Condvar::new(),
            arms: Mutex::new(ArmTimeline::new(cfg.drive.n_arms)),
            arm_origin: Instant::now(),
            trace,
            shard,
        });

        // One channel per drive worker: the dispatcher routes each batch
        // to the specific drive the placement stage chose.
        let mut txs = Vec::with_capacity(cfg.n_drives);
        let workers = (0..cfg.n_drives)
            .map(|i| {
                let (tx, rx) = channel::<Job>();
                txs.push(tx);
                let shared = Arc::clone(&shared);
                let worker_cfg = cfg.clone();
                let policy = Arc::clone(&policy);
                std::thread::spawn(move || worker_loop(shared, i, rx, worker_cfg, policy))
            })
            .collect();

        let dispatcher = {
            let shared = Arc::clone(&shared);
            let dispatcher_cfg = cfg.clone();
            std::thread::spawn(move || dispatcher_loop(shared, txs, dispatcher_cfg))
        };

        Coordinator { cfg, shared, dispatcher: Some(dispatcher), workers }
    }

    /// [`Coordinator::start`] with a SimpleDP evaluation backend as the
    /// policy: the backend (pure-Rust dense or the XLA engine) is wrapped
    /// in a [`BackendPolicy`] so drive workers schedule batches through it.
    pub fn start_with_backend(
        cfg: CoordinatorConfig,
        catalog: impl IntoIterator<Item = Tape>,
        backend: Arc<dyn SimpleDpBackend>,
    ) -> Coordinator {
        Coordinator::start(cfg, catalog, Arc::new(BackendPolicy::new(backend)))
    }

    /// Submit one read request. The request is shed — with the reason —
    /// when the tape is unknown, the file index is invalid, the service is
    /// stopping, or the tape's backlog is at its bound ([`SubmitError::Busy`],
    /// the backpressure signal: retry after the dispatcher drains the tape).
    pub fn submit(&self, req: ReadRequest) -> Result<(), SubmitError> {
        if self.shared.stopping.load(Ordering::SeqCst) {
            return Err(SubmitError::Stopping);
        }
        {
            let catalog = lock_recover(&self.shared.catalog, "submit catalog");
            match catalog.get(&req.tape) {
                None => return Err(SubmitError::UnknownTape),
                Some(t) if req.file_index >= t.n_files() => {
                    return Err(SubmitError::BadFileIndex)
                }
                Some(_) => {}
            }
        }
        let now = Instant::now();
        // Record the submit time while holding the batcher lock: the
        // dispatcher needs that lock to pop, so a worker can never serve
        // the request before its submit time is registered.
        let cap_hit = {
            let mut batcher = lock_recover(&self.shared.batcher, "submit batcher");
            match batcher.push(&req.tape, req.file_index, req.id, now) {
                PushOutcome::Busy => {
                    self.shared.metrics.on_reject(1);
                    return Err(SubmitError::Busy);
                }
                outcome => {
                    lock_recover(&self.shared.submit_times, "submit times")
                        .insert(req.id, now);
                    self.shared.metrics.on_submit(1);
                    outcome.ready()
                }
            }
        };
        if cap_hit {
            self.shared.wakeup.notify_all();
        }
        Ok(())
    }

    /// Register a tape (or replace its catalog entry) while running.
    pub fn register_tape(&self, tape: Tape) {
        lock_recover(&self.shared.catalog, "register_tape").insert(tape.name.clone(), tape);
    }

    /// Remove a tape from the catalog so subsequent submits for it fail
    /// with [`SubmitError::UnknownTape`] — the rehoming half of cluster
    /// rebalancing. Refuses (returns `false`) while requests for the tape
    /// are still queued, so accepted work is never orphaned; callers
    /// retry after the dispatcher drains the tape. (A submit that passed
    /// validation concurrently with this call may still land its push —
    /// the dispatcher sheds such batches, see `dispatcher_loop`.)
    pub fn deregister_tape(&self, name: &str) -> bool {
        // Hold the batcher lock across the backlog check and the catalog
        // removal: a queued request observed as zero backlog here cannot
        // reappear, because every push needs this lock.
        let batcher = lock_recover(&self.shared.batcher, "deregister_tape batcher");
        if batcher.tape_backlog(name) > 0 {
            return false;
        }
        let removed =
            lock_recover(&self.shared.catalog, "deregister_tape catalog").remove(name).is_some();
        drop(batcher);
        removed
    }

    /// Current metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Number of drive workers configured.
    pub fn n_drives(&self) -> usize {
        self.cfg.n_drives
    }

    /// Register this coordinator's metrics on a scrape [`Registry`]
    /// (`--metrics-listen`). The closures render the *live*
    /// [`SharedMetrics`] — the same atomics the drain report reads — so
    /// the scrape and the report can never disagree.
    pub fn register_exposition(&self, reg: &Registry) {
        const LE_BOUNDS_S: [f64; 7] = [0.001, 0.01, 0.1, 1.0, 10.0, 60.0, 600.0];
        let shared = Arc::clone(&self.shared);
        let shard = self.shared.shard.to_string();
        reg.register(move |buf| {
            let m = shared.metrics.snapshot();
            let labels: &[(&str, &str)] = &[("shard", &shard)];
            for (name, v) in [
                ("tapesched_submitted_total", m.submitted),
                ("tapesched_completed_total", m.completed),
                ("tapesched_rejected_total", m.rejected),
                ("tapesched_shed_total", m.shed),
                ("tapesched_batches_total", m.batches),
                ("tapesched_incremental_appends_total", m.incremental_appends),
                ("tapesched_incremental_rebuilds_total", m.incremental_rebuilds),
            ] {
                write_type(buf, name, "counter");
                write_counter(buf, name, labels, v);
            }
            write_type(buf, "tapesched_in_flight", "gauge");
            write_counter(
                buf,
                "tapesched_in_flight",
                labels,
                m.submitted.saturating_sub(m.completed + m.shed),
            );
            for (name, v) in [
                ("tapesched_mean_latency_seconds", m.mean_latency_s),
                ("tapesched_p50_latency_seconds", m.p50_latency_s),
                ("tapesched_p99_latency_seconds", m.p99_latency_s),
            ] {
                write_type(buf, name, "gauge");
                write_gauge(buf, name, labels, v);
            }
            write_type(buf, "tapesched_latency_seconds", "histogram");
            shared.metrics.with_latency_hist(|h| {
                for le in LE_BOUNDS_S {
                    let le_s = format!("{le}");
                    let lb: &[(&str, &str)] = &[("shard", &shard), ("le", &le_s)];
                    let cum = h.count_le_us((le * 1e6).round() as u64);
                    write_counter(buf, "tapesched_latency_seconds_bucket", lb, cum);
                }
                let inf: &[(&str, &str)] = &[("shard", &shard), ("le", "+Inf")];
                write_counter(buf, "tapesched_latency_seconds_bucket", inf, h.count());
                write_gauge(buf, "tapesched_latency_seconds_sum", labels, h.sum_seconds());
                write_counter(buf, "tapesched_latency_seconds_count", labels, h.count());
            });
        });
    }

    /// Drain: stop accepting, flush all open batches, join every thread,
    /// return all completions + the final metrics snapshot.
    pub fn finish(mut self) -> (Vec<Completion>, MetricsSnapshot) {
        self.shared.stopping.store(true, Ordering::SeqCst);
        self.shared.wakeup.notify_all();
        // A panicked thread already aborted its own work; finish still
        // returns whatever the healthy threads completed, so the drain
        // degrades instead of cascading the panic into the caller.
        let mut degraded = false;
        if let Some(d) = self.dispatcher.take() {
            if d.join().is_err() {
                eprintln!("tapesched: dispatcher panicked; returning partial drain");
                degraded = true;
            }
        }
        for w in self.workers.drain(..) {
            if w.join().is_err() {
                eprintln!("tapesched: drive worker panicked; returning partial drain");
                degraded = true;
            }
        }
        let completions =
            std::mem::take(&mut *lock_recover(&self.shared.completions, "finish completions"));
        let snap = self.shared.metrics.snapshot();
        // Every thread is joined, so the ledger is quiescent: anything
        // accepted either completed or was shed (`rejected` never entered
        // the system). A panicked thread may have dropped work on the
        // floor, so a degraded drain skips the exact check.
        if !degraded {
            debug_assert_drain_invariant(snap.submitted, snap.completed, snap.shed, "finish");
        }
        (completions, snap)
    }
}

fn dispatcher_loop(shared: Arc<Shared>, txs: Vec<Sender<Job>>, cfg: CoordinatorConfig) {
    let exclusive = cfg.exclusive_tapes;
    loop {
        let stopping = shared.stopping.load(Ordering::SeqCst);
        // Stage 0: a parked batch whose cartridge has freed goes first
        // (FIFO by free time — it was popped from the batcher earlier).
        if exclusive {
            let unparked =
                lock_recover(&shared.resources, "dispatcher unpark").ledger.pop_ready();
            if let Some((_tape, parked)) = unparked {
                let unparked_at = Instant::now();
                shared.metrics.on_cartridge_wait(
                    unparked_at.duration_since(parked.parked_at).as_secs_f64(),
                );
                if !place_and_send(&shared, &txs, &cfg, parked.batch, parked.parked_at, unparked_at)
                {
                    break; // worker gone
                }
                continue;
            }
        }
        let batch = {
            let mut b = lock_recover(&shared.batcher, "dispatcher batcher");
            match b.pop_ready(Instant::now(), stopping) {
                Some(batch) => Some(batch),
                None if stopping && b.pending() == 0 => {
                    drop(b);
                    // Parked batches still wait on their cartridge: keep
                    // looping until the serving workers free them,
                    // blocking on the wakeup workers notify on every
                    // release (the timeout bounds a lost-notify race
                    // between the waiter check and the wait).
                    if !exclusive
                        || lock_recover(&shared.resources, "dispatcher drain check")
                            .ledger
                            .no_waiters()
                    {
                        break;
                    }
                    let guard = lock_recover(&shared.batcher, "dispatcher drain wait");
                    let _ = wait_timeout_recover(
                        &shared.wakeup,
                        guard,
                        Duration::from_millis(5),
                        "dispatcher drain wait",
                    );
                    None
                }
                None => {
                    // Sleep until the oldest batch's window or a notify
                    // (workers notify on every release, so parked batches
                    // are re-checked promptly).
                    let deadline = b.next_deadline();
                    let wait = deadline
                        .map(|d| d.saturating_duration_since(Instant::now()))
                        .unwrap_or(Duration::from_millis(20));
                    let _b = wait_timeout_recover(
                        &shared.wakeup,
                        b,
                        wait.min(Duration::from_millis(50)),
                        "dispatcher batch wait",
                    );
                    None
                }
            }
        };
        if let Some(batch) = batch {
            let sealed_at = Instant::now();
            // Exclusivity gate: a batch whose cartridge is in use in
            // another drive (or already has earlier batches waiting)
            // parks FIFO until the cartridge frees.
            if exclusive {
                let mut res = lock_recover(&shared.resources, "dispatcher park");
                if !res.ledger.available(&batch.tape) {
                    let tape = batch.tape.clone();
                    res.ledger.park(tape, ParkedBatch { batch, parked_at: sealed_at });
                    continue;
                }
            }
            if !place_and_send(&shared, &txs, &cfg, batch, sealed_at, sealed_at) {
                break; // worker gone
            }
        }
    }
    drop(txs); // closes every channel; workers drain and exit
}

/// Build the batch's LTSP instance, place it on a drive through the
/// shared resource layer, and hand it to that drive's worker. Returns
/// `false` when the worker channel closed (service tearing down); a shed
/// batch (tape deregistered mid-flight) returns `true` so the dispatcher
/// keeps going.
fn place_and_send(
    shared: &Shared,
    txs: &[Sender<Job>],
    cfg: &CoordinatorConfig,
    batch: Batch,
    sealed_at: Instant,
    unparked_at: Instant,
) -> bool {
    let instance = {
        let catalog = lock_recover(&shared.catalog, "dispatcher catalog");
        let built = catalog.get(&batch.tape).map(|tape| {
            Instance::from_tape(tape, &batch.multiplicities(), cfg.drive.uturn_bytes())
        });
        match built {
            Some(Ok(instance)) => instance,
            missing_or_invalid => {
                // The tape was deregistered between a submit's validation
                // and its push (rehoming race), or its catalog entry was
                // replaced by one the batch no longer fits (`register_tape`
                // mid-flight): shed the batch rather than panicking in the
                // dispatcher. `on_shed` (not `on_reject`) keeps the
                // in-flight accounting honest — these requests were
                // accepted but will never complete.
                if let Some(Err(e)) = missing_or_invalid {
                    eprintln!(
                        "tapesched: shedding batch for {}: stale instance ({e:?})",
                        batch.tape
                    );
                }
                drop(catalog);
                let n = batch.n_requests() as u64;
                {
                    let mut submit = lock_recover(&shared.submit_times, "dispatcher shed");
                    for (_, ids) in &batch.by_file {
                        for id in ids {
                            submit.remove(id);
                        }
                    }
                }
                shared.metrics.on_shed(n);
                // A shed batch never acquires its cartridge, so it will
                // never release it either: re-arm any remaining waiters
                // or they would wedge the drain.
                if cfg.exclusive_tapes {
                    lock_recover(&shared.resources, "dispatcher shed renote")
                        .ledger
                        .renote(&batch.tape);
                }
                return true;
            }
        }
    };
    // Placement stage: wait for a free drive and pick which one the
    // batch lands on (affinity-first), claiming the cartridge in the
    // same critical section. Workers signal `resource_freed` after every
    // batch, so this cannot wedge while any drive is still serving.
    let (drive_idx, plan, evicted_hold) = {
        let mut res = lock_recover(&shared.resources, "dispatcher placement");
        loop {
            if let Some((i, plan)) = res.drives.pick(cfg.affinity, &batch.tape) {
                res.tick += 1;
                let tick = res.tick;
                let mut evicted_hold = None;
                if cfg.exclusive_tapes {
                    if plan == MountPlan::EvictMount {
                        // The evict-unmount owns the outgoing cartridge
                        // until the worker's arm reservation clears
                        // (`begin_evict` → the worker releases it
                        // unthreaded) — the same event order as the
                        // replay engine, so waiters for the evicted tape
                        // cannot dispatch while its cartridge is still in
                        // the robot's hands.
                        if let Some(evicted) = res.drives.drive(i).loaded.clone() {
                            res.ledger.begin_evict(&evicted);
                            evicted_hold = Some(evicted);
                        }
                    }
                    res.ledger.acquire(&batch.tape, i);
                }
                let loaded = match cfg.affinity {
                    Affinity::Lru => Some(batch.tape.clone()),
                    Affinity::None => None,
                };
                res.drives.begin_cycle(i, loaded, tick, 0);
                res.drives.set_stage(i, DriveStage::Executing);
                break (i, plan, evicted_hold);
            }
            res = wait_recover(&shared.resource_freed, res, "dispatcher placement wait");
        }
    };
    // Remount accounting only when the placement policy can produce hits
    // — parity with the replay engine, whose legacy (no-affinity,
    // no-arms) path keeps both counters at zero.
    if cfg.affinity == Affinity::Lru {
        if plan == MountPlan::Hit {
            shared.metrics.on_remount_hit();
        } else {
            shared.metrics.on_remount_miss();
        }
    }
    let mount_charge_s = cfg.drive.mount_charge_s(plan);
    txs[drive_idx]
        .send(Job {
            batch,
            instance,
            mount_charge_s,
            plan,
            evicted: evicted_hold,
            sealed_at,
            unparked_at,
            placed_at: Instant::now(),
        })
        .is_ok()
}

fn worker_loop(
    shared: Arc<Shared>,
    drive_idx: usize,
    rx: Receiver<Job>,
    cfg: CoordinatorConfig,
    policy: Arc<dyn Scheduler + Send + Sync>,
) {
    let drive = cfg.drive;
    loop {
        let mut job = match rx.recv() {
            Ok(j) => j,
            Err(_) => break, // dispatcher closed the channel
        };
        // Robot-arm timeline: the batch's mount work reserves an interval
        // on the earliest-free arm (an eviction's unmount+mount ride the
        // same arm back-to-back) and the worker sleeps to the reservation
        // edge, so arm contention appears in measured wall latency. The
        // op durations themselves stay a charge (`mount_charge_s`), not a
        // sleep — exactly the pre-arm accounting.
        let mut arm_wait_us = 0u64;
        if drive.n_arms > 0 && job.plan != MountPlan::Hit {
            let dur_us = match job.plan {
                MountPlan::Mount => drive.mount_us(),
                MountPlan::EvictMount => drive.unmount_us() + drive.mount_us(),
                MountPlan::Hit => 0,
            };
            let now_us = shared.wall_us();
            let r = lock_recover(&shared.arms, "worker arm reserve").reserve(now_us, dur_us);
            shared.metrics.on_arm_wait(r.wait_us as f64 / 1e6);
            arm_wait_us = r.wait_us;
            if r.wait_us > 0 {
                std::thread::sleep(Duration::from_micros(r.wait_us));
            }
        }
        // The evict-unmount has cleared the robot: the outgoing cartridge
        // returns to its shelf and its waiters become dispatchable. The
        // unmount *duration* stays a charge (part of `mount_charge_s`),
        // not a sleep — only the hold is timed, matching the replay
        // engine's unmount-done event.
        if let Some(evicted) = job.evicted.take() {
            lock_recover(&shared.resources, "worker evict release")
                .ledger
                .release_unthreaded(&evicted);
            shared.resource_freed.notify_all();
            shared.wakeup.notify_all();
        }
        let policy_t0 = Instant::now();
        let schedule = policy.schedule(&job.instance);
        let sched_s = policy_t0.elapsed().as_secs_f64();
        shared.metrics.on_batch(sched_s);
        // Drain the incremental backend's thread-local repair counters
        // (this worker thread just ran the solve, so the delta is its
        // own). A (0, 0) delta — any other backend — is a no-op.
        let (inc_appends, inc_rebuilds) = crate::runtime::take_thread_incremental_stats();
        shared.metrics.on_incremental(inc_appends, inc_rebuilds);

        let out = evaluate(&job.instance, &schedule);
        let done_wall = Instant::now();

        // Map per-file service times back to request ids through the one
        // shared accounting path (`Batch::request_service_times`), with
        // the mount charge the placement stage determined (0 on a hit).
        {
            let mut submit = lock_recover(&shared.submit_times, "worker completion");
            let mut completions = lock_recover(&shared.completions, "worker completion");
            // Span boundaries on the wall-µs grid of `arm_origin`. The
            // dispatcher does drive placement *after* any cartridge park,
            // so the measured waits are re-laid in the canonical stage
            // order (drive_wait, then cartridge_wait) with their true
            // durations: drive_wait = placed − unparked, cartridge_wait =
            // unparked − sealed. `exec` runs to the per-request completion
            // instant (submit + latency), so the chain tiles the measured
            // latency exactly.
            let us =
                |t: Instant| t.saturating_duration_since(shared.arm_origin).as_micros() as u64;
            let sealed = us(job.sealed_at);
            let placed = us(job.placed_at);
            let drive_got = sealed + placed.saturating_sub(us(job.unparked_at));
            let arm_got = placed + arm_wait_us;
            for (id, service_s) in
                job.batch.request_service_times(&out, drive, job.mount_charge_s)
            {
                let t_submit = submit.remove(&id).unwrap_or(job.batch.opened_at);
                let queue_s = done_wall.duration_since(t_submit).as_secs_f64();
                let latency_s = queue_s + service_s;
                shared.metrics.on_complete(latency_s, service_s);
                if let Some(tr) = &shared.trace {
                    let arrived = us(t_submit);
                    let done = arrived + (latency_s * 1e6).round() as u64;
                    tr.record_chain(
                        id,
                        shared.shard,
                        drive_idx as u32,
                        &job.batch.tape,
                        [
                            arrived, arrived, arrived, sealed, drive_got, placed, arm_got,
                            arm_got, done, done,
                        ],
                    );
                }
                completions.push(Completion {
                    request_id: id,
                    tape: job.batch.tape.clone(),
                    latency_s,
                    service_s,
                });
            }
        }
        // Release the drive and the cartridge, and wake the placement
        // stage (and the dispatcher's batcher sleep, so parked batches
        // are re-checked promptly).
        {
            let mut res = lock_recover(&shared.resources, "worker release");
            if cfg.exclusive_tapes {
                match cfg.affinity {
                    Affinity::Lru => res.ledger.release_threaded(&job.batch.tape),
                    Affinity::None => res.ledger.release_unthreaded(&job.batch.tape),
                }
            }
            res.drives.release(drive_idx);
        }
        shared.resource_freed.notify_all();
        shared.wakeup.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{Gs, SimpleDp};
    use std::time::Duration;

    fn catalog() -> Vec<Tape> {
        vec![
            Tape::from_sizes("TAPE001", &[1_000; 50]),
            Tape::from_sizes("TAPE002", &[500; 100]),
        ]
    }

    fn cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            n_drives: 3,
            batcher: BatcherConfig {
                window: Duration::from_millis(5),
                max_batch: 64,
                ..BatcherConfig::default()
            },
            drive: DriveParams {
                mount_s: 1.0,
                unmount_s: 0.5,
                bytes_per_s: 1e6,
                uturn_s: 0.001,
                n_arms: 0,
            },
            affinity: Affinity::None,
            exclusive_tapes: true,
        }
    }

    #[test]
    fn serves_every_submitted_request_exactly_once() {
        let c = Coordinator::start(cfg(), catalog(), Arc::new(SimpleDp));
        let mut ids = Vec::new();
        for i in 0..500u64 {
            let tape = if i % 3 == 0 { "TAPE001" } else { "TAPE002" };
            let req = ReadRequest {
                id: i,
                tape: tape.into(),
                file_index: (i % 50) as usize,
            };
            assert!(c.submit(req).is_ok());
            ids.push(i);
        }
        let (completions, m) = c.finish();
        assert_eq!(m.submitted, 500);
        assert_eq!(m.completed, 500);
        let mut got: Vec<u64> = completions.iter().map(|c| c.request_id).collect();
        got.sort();
        assert_eq!(got, ids);
        assert!(m.mean_latency_s >= m.mean_service_s * 0.99);
        assert!(m.batches >= 2, "both tapes must have been dispatched");
    }

    #[test]
    fn rejects_unknown_tape_and_bad_index() {
        let c = Coordinator::start(cfg(), catalog(), Arc::new(Gs));
        assert_eq!(
            c.submit(ReadRequest { id: 1, tape: "NOPE".into(), file_index: 0 }),
            Err(SubmitError::UnknownTape)
        );
        assert_eq!(
            c.submit(ReadRequest {
                id: 2,
                tape: "TAPE001".into(),
                file_index: 9_999
            }),
            Err(SubmitError::BadFileIndex)
        );
        let (completions, m) = c.finish();
        assert!(completions.is_empty());
        assert_eq!(m.submitted, 0);
    }

    #[test]
    fn register_tape_makes_it_routable() {
        let c = Coordinator::start(cfg(), catalog(), Arc::new(Gs));
        assert_eq!(
            c.submit(ReadRequest { id: 1, tape: "NEW".into(), file_index: 0 }),
            Err(SubmitError::UnknownTape)
        );
        c.register_tape(Tape::from_sizes("NEW", &[100, 100]));
        assert!(c.submit(ReadRequest { id: 2, tape: "NEW".into(), file_index: 1 }).is_ok());
        let (completions, _) = c.finish();
        assert_eq!(completions.len(), 1);
        assert_eq!(completions[0].request_id, 2);
        assert_eq!(completions[0].tape, "NEW");
    }

    #[test]
    fn deregister_tape_rejects_new_submits_but_never_orphans_queued_work() {
        // A window far longer than the test: queued requests stay queued,
        // so the busy-tape refusal is deterministic.
        let mut config = cfg();
        config.batcher.window = Duration::from_secs(3600);
        let c = Coordinator::start(config, catalog(), Arc::new(Gs));
        assert!(c
            .submit(ReadRequest { id: 1, tape: "TAPE001".into(), file_index: 3 })
            .is_ok());
        assert!(
            !c.deregister_tape("TAPE001"),
            "a tape with queued requests must refuse deregistration"
        );
        // An idle tape deregisters; submits then fail as unknown.
        assert!(c.deregister_tape("TAPE002"));
        assert!(!c.deregister_tape("TAPE002"), "already gone");
        assert_eq!(
            c.submit(ReadRequest { id: 2, tape: "TAPE002".into(), file_index: 0 }),
            Err(SubmitError::UnknownTape)
        );
        // The refused tape's queued request still completes at drain.
        let (completions, m) = c.finish();
        assert_eq!(completions.len(), 1);
        assert_eq!(completions[0].request_id, 1);
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn duplicate_file_requests_batch_into_multiplicity() {
        let c = Coordinator::start(cfg(), catalog(), Arc::new(SimpleDp));
        for i in 0..10u64 {
            assert!(c
                .submit(ReadRequest {
                    id: i,
                    tape: "TAPE001".into(),
                    file_index: 7,
                })
                .is_ok());
        }
        let (completions, m) = c.finish();
        assert_eq!(completions.len(), 10);
        // All ten requests share one batch (same tape, inside the window or
        // flushed at shutdown) and thus the same service time.
        let s0 = completions[0].service_s;
        assert!(completions.iter().all(|c| (c.service_s - s0).abs() < 1e-9));
        assert!(m.batches >= 1);
    }

    #[test]
    fn backend_policy_serves_like_the_sparse_scheduler() {
        // A window far longer than the test (batches only flush at drain)
        // makes batch composition deterministic: one batch per tape, so
        // in-tape service times are comparable across runs.
        let mut config = cfg();
        config.batcher.window = Duration::from_secs(3600);

        let drain = |c: Coordinator| -> Vec<f64> {
            for i in 0..120u64 {
                let tape = if i % 2 == 0 { "TAPE001" } else { "TAPE002" };
                assert!(c
                    .submit(ReadRequest {
                        id: i,
                        tape: tape.into(),
                        file_index: (i % 40) as usize,
                    })
                    .is_ok());
            }
            let (mut completions, m) = c.finish();
            assert_eq!(m.completed, 120);
            completions.sort_by_key(|c| c.request_id);
            completions.iter().map(|c| c.service_s).collect()
        };

        let via_backend = drain(Coordinator::start_with_backend(
            config.clone(),
            catalog(),
            crate::runtime::default_backend(),
        ));
        let via_sparse = drain(Coordinator::start(config, catalog(), Arc::new(SimpleDp)));
        assert_eq!(via_backend.len(), via_sparse.len());
        for (a, b) in via_backend.iter().zip(&via_sparse) {
            assert!((a - b).abs() < 1e-9, "backend {a} vs sparse {b}");
        }
    }

    #[test]
    fn incremental_backend_serves_bit_equal_to_the_fresh_solve() {
        // Pseudorandom grow sequences (fixed LCG, deterministic batch
        // composition via the drain-only window + cap splits) through a
        // live Coordinator, served once by `--backend incremental` and
        // once by the fresh dense solve. Schedules are bit-equal (the
        // debug assertion inside the backend checks every solve), so the
        // per-request service times must match to the bit. Single-file
        // batches drive the rebuild path, multi-file batches the append
        // path — both legs are required to fire.
        let mut config = cfg();
        config.batcher.window = Duration::from_secs(3600);
        config.batcher.max_batch = 5;

        let drain = |c: Coordinator| -> (Vec<f64>, MetricsSnapshot) {
            let mut rng: u64 = 0x5eed_cafe;
            let mut id = 0u64;
            for wave in 0..6u64 {
                // TAPE001 gets bursts (cap-split multi-file batches →
                // appends); TAPE002 gets one lone request per wave (k=1
                // batches → rebuilds).
                for _ in 0..5 {
                    rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    let file_index = (rng >> 33) as usize % 50;
                    assert!(c
                        .submit(ReadRequest { id, tape: "TAPE001".into(), file_index })
                        .is_ok());
                    id += 1;
                }
                assert!(c
                    .submit(ReadRequest {
                        id,
                        tape: "TAPE002".into(),
                        file_index: (wave * 7) as usize,
                    })
                    .is_ok());
                id += 1;
            }
            let (mut completions, m) = c.finish();
            assert_eq!(m.completed, 36);
            debug_assert_drain_invariant(m.submitted, m.completed, m.shed, "incremental test");
            completions.sort_by_key(|c| c.request_id);
            (completions.iter().map(|c| c.service_s).collect(), m)
        };

        let (via_incremental, m_inc) = drain(Coordinator::start_with_backend(
            config.clone(),
            catalog(),
            crate::runtime::backend_by_name("incremental").unwrap(),
        ));
        let (via_fresh, m_fresh) = drain(Coordinator::start_with_backend(
            config,
            catalog(),
            crate::runtime::default_backend(),
        ));
        assert_eq!(via_incremental.len(), via_fresh.len());
        for (a, b) in via_incremental.iter().zip(&via_fresh) {
            assert_eq!(a.to_bits(), b.to_bits(), "incremental {a} vs fresh {b}");
        }
        assert!(m_inc.incremental_appends > 0, "append repairs must fire");
        assert!(m_inc.incremental_rebuilds > 0, "rebuilds must fire");
        assert_eq!(m_fresh.incremental_appends, 0, "dense backend does no repairs");
        assert_eq!(m_fresh.incremental_rebuilds, 0);
    }

    #[test]
    fn size_cap_splits_batches() {
        let mut config = cfg();
        config.batcher.max_batch = 4;
        let c = Coordinator::start(config, catalog(), Arc::new(Gs));
        for i in 0..16u64 {
            assert!(c
                .submit(ReadRequest {
                    id: i,
                    tape: "TAPE002".into(),
                    file_index: i as usize,
                })
                .is_ok());
        }
        let (_, m) = c.finish();
        assert!(m.batches >= 4, "16 requests with cap 4 ⇒ ≥4 batches, got {}", m.batches);
    }

    #[test]
    fn busy_backpressure_bounds_the_tape_queue() {
        // A window far longer than the test: nothing dispatches until
        // drain, so the 9th..20th submits must all see the bound.
        let mut config = cfg();
        config.batcher.window = Duration::from_secs(3600);
        config.batcher.max_tape_backlog = 8;
        let c = Coordinator::start(config, catalog(), Arc::new(Gs));
        let mut busy = 0;
        for i in 0..20u64 {
            match c.submit(ReadRequest {
                id: i,
                tape: "TAPE001".into(),
                file_index: (i % 50) as usize,
            }) {
                Ok(()) => {}
                Err(SubmitError::Busy) => busy += 1,
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        assert_eq!(busy, 12, "bound 8 must shed exactly the overflow");
        let (completions, m) = c.finish();
        assert_eq!(completions.len(), 8);
        assert_eq!(m.submitted, 8);
        assert_eq!(m.completed, 8);
        assert_eq!(m.rejected, 12);
    }

    #[test]
    fn lru_affinity_scores_remount_hits_and_skips_the_mount() {
        // One tape, one drive, size-cap-split batches: under LRU affinity
        // only the first batch mounts; every later batch finds the tape
        // already threaded in drive 0. Deterministic regardless of thread
        // timing — there is exactly one drive and one tape.
        let run = |affinity: Affinity| {
            let mut config = cfg();
            config.n_drives = 1;
            config.batcher.window = Duration::from_secs(3600);
            config.batcher.max_batch = 4;
            config.affinity = affinity;
            let c = Coordinator::start(
                config,
                vec![Tape::from_sizes("TAPE001", &[1_000; 50])],
                Arc::new(Gs),
            );
            for i in 0..16u64 {
                assert!(c
                    .submit(ReadRequest {
                        id: i,
                        tape: "TAPE001".into(),
                        file_index: (i % 50) as usize,
                    })
                    .is_ok());
            }
            c.finish()
        };
        let (done_lru, m_lru) = run(Affinity::Lru);
        assert_eq!(m_lru.completed, 16);
        assert_eq!(m_lru.batches, 4, "cap 4 splits 16 requests into 4 batches");
        assert_eq!(m_lru.remount_misses, 1, "only the first batch mounts");
        assert_eq!(m_lru.remount_hits, 3, "every later batch is a remount hit");

        let (done_none, m_none) = run(Affinity::None);
        // No affinity = the legacy model: no remount accounting at all
        // (parity with the replay engine's legacy path).
        assert_eq!(m_none.remount_hits, 0);
        assert_eq!(m_none.remount_misses, 0);
        // Skipped mounts show up in the in-tape+mount service component.
        assert!(
            m_lru.mean_service_s < m_none.mean_service_s,
            "LRU {} must beat None {}",
            m_lru.mean_service_s,
            m_none.mean_service_s
        );
        assert_eq!(done_lru.len(), done_none.len());
    }

    /// A policy that holds its drive for a fixed wall interval before
    /// delegating — makes live resource contention deterministic.
    struct SlowPolicy(Duration);

    impl crate::sched::Scheduler for SlowPolicy {
        fn name(&self) -> String {
            "SlowGS".into()
        }

        fn schedule(&self, inst: &crate::model::Instance) -> crate::sched::Schedule {
            std::thread::sleep(self.0);
            Gs.schedule(inst)
        }
    }

    #[test]
    fn exclusivity_pins_a_hot_tape_to_one_drive() {
        // Three drives, one tape, cap-split batches, LRU affinity. Without
        // exclusivity a batch arriving while drive 0 is busy mounts a
        // second "copy" of the cartridge into an empty drive (a remount
        // miss); with it, every batch after the first waits for — and
        // lands on — the one drive that physically holds the tape.
        let mut config = cfg();
        config.batcher.window = Duration::from_secs(3600);
        config.batcher.max_batch = 4;
        config.affinity = Affinity::Lru;
        assert!(config.exclusive_tapes, "exclusivity is the default");
        let c = Coordinator::start(
            config,
            vec![Tape::from_sizes("TAPE001", &[1_000; 50])],
            Arc::new(SlowPolicy(Duration::from_millis(200))),
        );
        for i in 0..16u64 {
            assert!(c
                .submit(ReadRequest {
                    id: i,
                    tape: "TAPE001".into(),
                    file_index: (i % 50) as usize,
                })
                .is_ok());
        }
        let (completions, m) = c.finish();
        assert_eq!(completions.len(), 16);
        assert_eq!(m.batches, 4, "cap 4 splits 16 requests into 4 batches");
        assert_eq!(m.remount_misses, 1, "one cartridge, one mount");
        assert_eq!(m.remount_hits, 3, "every later batch lands on the holder");
        // The 200 ms the policy holds the drive means a later batch only
        // avoids parking if the dispatcher stalls that long before its
        // pop — all three dodging it is not a realistic schedule. (Exact
        // counts stay timing-dependent, so assert the floor, not 3.)
        assert!(
            (1..=3).contains(&m.cartridge_parks),
            "batches 2..4 must wait for the cartridge (parks = {})",
            m.cartridge_parks
        );
        assert!(m.mean_cartridge_wait_s > 0.0);
        assert!(m.max_cartridge_wait_s >= m.mean_cartridge_wait_s);
    }

    #[test]
    fn arm_timeline_serializes_live_mounts() {
        // Two tapes on two drives but one robot arm, with the mount span
        // dominating dispatch skew: both batches place immediately, yet
        // the second mount's reservation starts after the first ends —
        // the worker sleeps to the edge and the wait lands in metrics.
        let mut config = cfg();
        config.n_drives = 2;
        config.batcher.window = Duration::from_secs(3600);
        config.drive.mount_s = 0.2;
        config.drive.n_arms = 1;
        let c = Coordinator::start(config.clone(), catalog(), Arc::new(Gs));
        assert!(c.submit(ReadRequest { id: 1, tape: "TAPE001".into(), file_index: 0 }).is_ok());
        assert!(c.submit(ReadRequest { id: 2, tape: "TAPE002".into(), file_index: 0 }).is_ok());
        let (completions, m) = c.finish();
        assert_eq!(completions.len(), 2);
        assert_eq!(m.arm_ops, 2, "both mounts reserve the arm");
        assert!(
            m.max_arm_wait_s > 0.05,
            "the second mount must queue behind the first (waited {})",
            m.max_arm_wait_s
        );
        assert!(m.mean_arm_wait_s > 0.0);

        // Unconstrained robot: no reservations, no arm metrics.
        let mut free = config;
        free.drive.n_arms = 0;
        let c = Coordinator::start(free, catalog(), Arc::new(Gs));
        assert!(c.submit(ReadRequest { id: 1, tape: "TAPE001".into(), file_index: 0 }).is_ok());
        let (_, m) = c.finish();
        assert_eq!(m.arm_ops, 0);
        assert_eq!(m.max_arm_wait_s, 0.0);
    }

    #[test]
    fn evict_hold_parks_waiters_until_the_unmount_clears_the_robot() {
        // One drive, one arm, alternating tapes, one request per batch.
        // Batch 1 mounts TAPE001 (arm busy [0, 0.2s] as a reservation, no
        // wait). Batch 2 (TAPE002) evicts TAPE001: the placement stage
        // begins the evict, and the worker must wait ~0.2s for the arm —
        // the evicted cartridge is in the robot's hands for that span.
        // Batch 3 (TAPE001 again) pops microseconds later, finds its
        // cartridge mid-evict, and parks: before the timed hold it would
        // have dispatched instantly against a cartridge still physically
        // in the drive.
        let mut config = cfg();
        config.n_drives = 1;
        config.batcher.window = Duration::from_secs(3600);
        config.batcher.max_batch = 1;
        config.affinity = Affinity::Lru;
        config.drive.mount_s = 0.2;
        config.drive.unmount_s = 0.2;
        config.drive.n_arms = 1;
        let c = Coordinator::start(config, catalog(), Arc::new(Gs));
        for (i, tape) in ["TAPE001", "TAPE002", "TAPE001"].iter().enumerate() {
            assert!(c
                .submit(ReadRequest {
                    id: i as u64,
                    tape: (*tape).into(),
                    file_index: i,
                })
                .is_ok());
        }
        let (completions, m) = c.finish();
        assert_eq!(completions.len(), 3, "the hold must never wedge the drain");
        assert_eq!(m.completed, 3);
        assert!(
            m.cartridge_parks >= 1,
            "the third batch must park behind the evict-unmount (parks = {})",
            m.cartridge_parks
        );
        assert!(
            m.max_cartridge_wait_s > 0.05,
            "the parked batch's wait must cover the arm-queued unmount (waited {})",
            m.max_cartridge_wait_s
        );
    }

    #[test]
    fn live_tracing_emits_full_chains_and_the_scrape_matches_the_drain() {
        use crate::obs::{check_chains, parse_jsonl};
        let trace = Arc::new(TraceRecorder::new(1 << 14));
        let c = Coordinator::start_traced(
            cfg(),
            catalog(),
            Arc::new(SimpleDp),
            Some(Arc::clone(&trace)),
            3,
        );
        let reg = Registry::new();
        c.register_exposition(&reg);
        for i in 0..60u64 {
            let tape = if i % 3 == 0 { "TAPE001" } else { "TAPE002" };
            assert!(c
                .submit(ReadRequest { id: i, tape: tape.into(), file_index: (i % 50) as usize })
                .is_ok());
        }
        let (completions, m) = c.finish();
        assert_eq!(m.completed, 60);
        // One full canonical chain per completion, on the wall-µs grid.
        let mut buf = Vec::new();
        trace.write_jsonl(&mut buf).unwrap();
        let parsed = parse_jsonl(std::str::from_utf8(&buf).unwrap());
        assert_eq!(check_chains(&parsed), Ok(60));
        assert!(parsed.iter().all(|s| s.shard == 3), "spans carry the shard id");
        // The scrape renders the same atomics the drain snapshot read.
        let page = reg.render();
        assert!(page.contains("tapesched_submitted_total{shard=\"3\"} 60"), "{page}");
        assert!(page.contains("tapesched_completed_total{shard=\"3\"} 60"), "{page}");
        assert!(page.contains("tapesched_in_flight{shard=\"3\"} 0"), "{page}");
        assert!(
            page.contains("tapesched_latency_seconds_bucket{shard=\"3\",le=\"+Inf\"} 60"),
            "{page}"
        );
        assert!(page.contains("tapesched_latency_seconds_count{shard=\"3\"} 60"), "{page}");
        assert_eq!(completions.len(), 60);
    }

    #[test]
    fn submit_after_finish_reports_stopping() {
        let c = Coordinator::start(cfg(), catalog(), Arc::new(Gs));
        c.shared.stopping.store(true, Ordering::SeqCst);
        assert_eq!(
            c.submit(ReadRequest { id: 1, tape: "TAPE001".into(), file_index: 0 }),
            Err(SubmitError::Stopping)
        );
        let (completions, _) = c.finish();
        assert!(completions.is_empty());
    }
}
