//! Coordinator metrics: lock-free counters + a sampled latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::replay::LatencyHistogram;

/// Shared metrics handle (cheaply clonable via `Arc` at the service layer).
#[derive(Debug, Default)]
pub struct SharedMetrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    /// Submissions rejected with `Busy` by the per-tape backlog bound.
    rejected: AtomicU64,
    /// Requests *accepted* but dropped at dispatch because their tape was
    /// deregistered in between (the rehoming race) — distinct from
    /// `rejected`, which never entered the system.
    shed: AtomicU64,
    batches: AtomicU64,
    /// Batches that landed on a drive already holding their tape (the
    /// mount was skipped entirely — drive affinity).
    remount_hits: AtomicU64,
    /// Batches that needed a fresh mount (empty drive or LRU eviction).
    remount_misses: AtomicU64,
    /// Batches that waited on a cartridge waitlist (per-tape mount
    /// exclusivity), with their total and worst wait in µs.
    cartridge_parks: AtomicU64,
    cartridge_wait_sum_us: AtomicU64,
    cartridge_wait_max_us: AtomicU64,
    /// Robot-arm reservations made (mount/unmount ops through the arm
    /// timeline), with their total and worst wait in µs.
    arm_ops: AtomicU64,
    arm_wait_sum_us: AtomicU64,
    arm_wait_max_us: AtomicU64,
    /// Incremental-backend repair work: columns appended onto a stored
    /// per-prefix table vs. restarts from a fresh one-file prefix. Zero
    /// unless the shard serves with `--backend incremental`.
    incremental_appends: AtomicU64,
    incremental_rebuilds: AtomicU64,
    /// Sum of end-to-end request latencies, in µs.
    latency_sum_us: AtomicU64,
    /// Sum of in-tape service times, in µs.
    service_sum_us: AtomicU64,
    /// Scheduler compute time, in µs.
    sched_sum_us: AtomicU64,
    /// Reservoir of end-to-end latencies (seconds) for percentiles.
    reservoir: Mutex<Vec<f64>>,
    /// Log-bucketed end-to-end latency histogram — the source of the
    /// `tapesched_latency_seconds_bucket{le=…}` exposition lines. Fed by
    /// the same `on_complete` call as everything else, so a scrape and a
    /// drain report can never disagree on what completed.
    latency_hist: Mutex<LatencyHistogram>,
}

/// Point-in-time snapshot of all metrics.
///
/// `Default` is the all-zero snapshot — what a shard that never accepted
/// a request reports. The networked coordinator synthesizes snapshots
/// from it for dead workers (see `net::server`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    /// Submissions rejected with `Busy` (backpressure shed load).
    pub rejected: u64,
    /// Accepted requests dropped at dispatch (tape deregistered while
    /// they were queued — the rehoming race). These will never complete:
    /// in-flight accounting is `submitted − completed − shed`.
    pub shed: u64,
    pub batches: u64,
    /// Batches served without a mount (drive already held the tape).
    pub remount_hits: u64,
    /// Batches that paid a mount (empty drive or eviction).
    pub remount_misses: u64,
    /// Batches that waited on a cartridge waitlist (per-tape mount
    /// exclusivity: one cartridge, one drive).
    pub cartridge_parks: u64,
    /// Mean / worst cartridge wait over those batches, seconds.
    pub mean_cartridge_wait_s: f64,
    pub max_cartridge_wait_s: f64,
    /// Robot-arm reservations (mount/unmount ops; 0 with an unconstrained
    /// robot).
    pub arm_ops: u64,
    /// Mean / worst wait for a free arm over those ops, seconds.
    pub mean_arm_wait_s: f64,
    pub max_arm_wait_s: f64,
    pub mean_latency_s: f64,
    pub mean_service_s: f64,
    pub mean_sched_s_per_batch: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    /// Incremental-backend solve work (0 on other backends): table
    /// columns appended in place vs. rebuilds from a one-file prefix.
    /// Appended after the latency fields — the wire codec encodes
    /// snapshots in declaration order (`net::wire`, protocol v3).
    pub incremental_appends: u64,
    pub incremental_rebuilds: u64,
}

const RESERVOIR_CAP: usize = 65_536;

impl SharedMetrics {
    pub fn on_submit(&self, n: u64) {
        self.submitted.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` submissions rejected by backpressure (`Busy`).
    pub fn on_reject(&self, n: u64) {
        self.rejected.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` accepted requests shed at dispatch (deregistered tape).
    pub fn on_shed(&self, n: u64) {
        self.shed.fetch_add(n, Ordering::Relaxed);
    }

    /// Record a dispatched batch: scheduler compute seconds.
    pub fn on_batch(&self, sched_s: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.sched_sum_us
            .fetch_add((sched_s * 1e6) as u64, Ordering::Relaxed);
    }

    /// Record a batch landing on a drive that already held its tape.
    pub fn on_remount_hit(&self) {
        self.remount_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a batch that needed a fresh mount.
    pub fn on_remount_miss(&self) {
        self.remount_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one batch leaving a cartridge waitlist after `wait_s`.
    pub fn on_cartridge_wait(&self, wait_s: f64) {
        let us = (wait_s.max(0.0) * 1e6) as u64;
        self.cartridge_parks.fetch_add(1, Ordering::Relaxed);
        self.cartridge_wait_sum_us.fetch_add(us, Ordering::Relaxed);
        self.cartridge_wait_max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Record one robot-arm reservation that waited `wait_s` for an arm.
    pub fn on_arm_wait(&self, wait_s: f64) {
        let us = (wait_s.max(0.0) * 1e6) as u64;
        self.arm_ops.fetch_add(1, Ordering::Relaxed);
        self.arm_wait_sum_us.fetch_add(us, Ordering::Relaxed);
        self.arm_wait_max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Record incremental-backend repair work drained from a drive
    /// worker after a dispatch (`take_thread_incremental_stats`). Both
    /// legs are usually small; (0, 0) is a cheap no-op for the common
    /// non-incremental backends.
    pub fn on_incremental(&self, appends: u64, rebuilds: u64) {
        if appends > 0 {
            self.incremental_appends.fetch_add(appends, Ordering::Relaxed);
        }
        if rebuilds > 0 {
            self.incremental_rebuilds.fetch_add(rebuilds, Ordering::Relaxed);
        }
    }

    /// Record one served request: end-to-end latency + in-tape service (s).
    pub fn on_complete(&self, latency_s: f64, service_s: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us
            .fetch_add((latency_s * 1e6) as u64, Ordering::Relaxed);
        self.service_sum_us
            .fetch_add((service_s * 1e6) as u64, Ordering::Relaxed);
        self.latency_hist.lock().unwrap().record_seconds(latency_s);
        let mut r = self.reservoir.lock().unwrap();
        if r.len() < RESERVOIR_CAP {
            r.push(latency_s);
        } else {
            // Cheap replacement keyed on the counter: uniform-ish reservoir.
            let i = (self.completed.load(Ordering::Relaxed) as usize)
                .wrapping_mul(0x9E3779B9)
                % RESERVOIR_CAP;
            r[i] = latency_s;
        }
    }

    /// Read the live latency histogram under its lock — how the
    /// exposition layer renders `…_bucket{le=…}` lines without copying
    /// the histogram per scrape.
    pub fn with_latency_hist<R>(&self, f: impl FnOnce(&LatencyHistogram) -> R) -> R {
        f(&self.latency_hist.lock().unwrap())
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let completed = self.completed.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let mut lat: Vec<f64> = self.reservoir.lock().unwrap().clone();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            if lat.is_empty() {
                0.0
            } else {
                crate::util::stats::percentile_sorted(&lat, p)
            }
        };
        let cartridge_parks = self.cartridge_parks.load(Ordering::Relaxed);
        let arm_ops = self.arm_ops.load(Ordering::Relaxed);
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            rejected: self.rejected.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            batches,
            remount_hits: self.remount_hits.load(Ordering::Relaxed),
            remount_misses: self.remount_misses.load(Ordering::Relaxed),
            cartridge_parks,
            mean_cartridge_wait_s: self.cartridge_wait_sum_us.load(Ordering::Relaxed)
                as f64
                / 1e6
                / cartridge_parks.max(1) as f64,
            max_cartridge_wait_s: self.cartridge_wait_max_us.load(Ordering::Relaxed)
                as f64
                / 1e6,
            arm_ops,
            mean_arm_wait_s: self.arm_wait_sum_us.load(Ordering::Relaxed) as f64
                / 1e6
                / arm_ops.max(1) as f64,
            max_arm_wait_s: self.arm_wait_max_us.load(Ordering::Relaxed) as f64 / 1e6,
            mean_latency_s: self.latency_sum_us.load(Ordering::Relaxed) as f64
                / 1e6
                / completed.max(1) as f64,
            mean_service_s: self.service_sum_us.load(Ordering::Relaxed) as f64
                / 1e6
                / completed.max(1) as f64,
            mean_sched_s_per_batch: self.sched_sum_us.load(Ordering::Relaxed) as f64
                / 1e6
                / batches.max(1) as f64,
            p50_latency_s: pct(50.0),
            p99_latency_s: pct(99.0),
            incremental_appends: self.incremental_appends.load(Ordering::Relaxed),
            incremental_rebuilds: self.incremental_rebuilds.load(Ordering::Relaxed),
        }
    }
}

/// Assert the drain-ledger invariant at a quiescent point: every request
/// that entered the system has left it, `submitted == completed + shed`.
///
/// Call this only where the counters are stable — after joins, at the
/// end of a drain, on a folded dead-worker snapshot — never on a live
/// snapshot, whose three legs are Relaxed loads taken at different
/// instants and may transiently disagree. Callers whose `submitted` leg
/// excludes shed requests (the replay engine's convention) pass
/// `submitted + shed` for the first argument. The `tapesched audit`
/// accounting rule requires any file mutating two or more of these
/// counters to reference this helper.
#[track_caller]
pub fn debug_assert_drain_invariant(submitted: u64, completed: u64, shed: u64, context: &str) {
    debug_assert!(
        submitted == completed + shed,
        "drain invariant violated in {context}: \
         submitted={submitted} != completed={completed} + shed={shed}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_means() {
        let m = SharedMetrics::default();
        m.on_submit(3);
        m.on_reject(2);
        m.on_shed(1);
        m.on_batch(0.5);
        m.on_remount_hit();
        m.on_remount_miss();
        m.on_remount_miss();
        m.on_cartridge_wait(2.0);
        m.on_cartridge_wait(4.0);
        m.on_arm_wait(0.5);
        m.on_incremental(4, 1);
        m.on_incremental(0, 0);
        m.on_complete(2.0, 1.0);
        m.on_complete(4.0, 3.0);
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.rejected, 2);
        assert_eq!(s.shed, 1);
        assert_eq!(s.completed, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.remount_hits, 1);
        assert_eq!(s.remount_misses, 2);
        assert_eq!(s.cartridge_parks, 2);
        assert!((s.mean_cartridge_wait_s - 3.0).abs() < 1e-3);
        assert!((s.max_cartridge_wait_s - 4.0).abs() < 1e-3);
        assert_eq!(s.arm_ops, 1);
        assert!((s.mean_arm_wait_s - 0.5).abs() < 1e-3);
        assert!((s.max_arm_wait_s - 0.5).abs() < 1e-3);
        assert!((s.mean_latency_s - 3.0).abs() < 1e-3);
        assert!((s.mean_service_s - 2.0).abs() < 1e-3);
        assert!((s.mean_sched_s_per_batch - 0.5).abs() < 1e-3);
        assert_eq!(s.incremental_appends, 4);
        assert_eq!(s.incremental_rebuilds, 1);
        assert!(s.p50_latency_s >= 2.0 && s.p99_latency_s <= 4.0 + 1e-9);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = SharedMetrics::default().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.mean_latency_s, 0.0);
        assert_eq!(s.p99_latency_s, 0.0);
        assert_eq!(s.incremental_appends, 0);
        assert_eq!(s.incremental_rebuilds, 0);
    }

    #[test]
    fn completions_feed_the_latency_histogram() {
        let m = SharedMetrics::default();
        m.on_complete(0.5, 0.1);
        m.on_complete(2.0, 0.1);
        m.with_latency_hist(|h| {
            assert_eq!(h.count(), 2);
            assert_eq!(h.count_le_us(1_000_000), 1, "only the 0.5 s sample fits under 1 s");
            assert!((h.sum_seconds() - 2.5).abs() < 1e-6);
        });
    }

    #[test]
    fn reservoir_survives_many_samples() {
        let m = SharedMetrics::default();
        for i in 0..(RESERVOIR_CAP + 1000) {
            m.on_complete(i as f64 * 1e-3, 0.0);
        }
        let s = m.snapshot();
        assert_eq!(s.completed as usize, RESERVOIR_CAP + 1000);
        assert!(s.p50_latency_s > 0.0);
    }
}
