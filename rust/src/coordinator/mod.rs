//! The mass-storage request coordinator — the serving layer a datacenter
//! deployment would put in front of the tape library (the role HPSS/DMF
//! play in §1 of the paper).
//!
//! Architecture (vLLM-router-like, adapted to tapes):
//!
//! ```text
//!   clients ──submit──▶ [Router / per-tape Batcher] ──jobs──▶ worker pool
//!                            │  (batch window, size cap)       (1 thread
//!                            ▼                                  = 1 drive)
//!                        [Metrics]  ◀──────── completions ────────┘
//! ```
//!
//! - Incoming read requests are routed to a **per-tape batch**: tapes are
//!   the unit of mounting, so batching by tape is what converts random
//!   arrivals into LTSP instances worth optimizing. Each tape's backlog is
//!   bounded (`BatcherConfig::max_tape_backlog`): past it, `submit` sheds
//!   the request with [`SubmitError::Busy`] instead of growing memory —
//!   callers retry after the dispatcher drains (see `replay::driver`).
//! - A batch is dispatched when its window expires or it hits the size cap;
//!   the dispatched job carries the LTSP instance for the batch.
//! - Each worker owns one (virtual) drive: it computes the schedule with
//!   the configured policy ([`crate::sched`]), obtains exact service times
//!   from the ground-truth simulator, and reports per-request latencies.
//!
//! Python never appears anywhere on this path; when the XLA engine is
//! enabled the worker calls the AOT-compiled artifact through
//! [`crate::runtime`], still in-process.
//!
//! One coordinator models one tape **library**. Fleet deployments put
//! several behind the consistent-hash router of [`crate::cluster`], which
//! partitions the catalog by tape name and preserves every per-shard
//! contract here (validation, `Busy` backpressure, drain-on-finish).

mod batcher;
mod metrics;
mod service;

pub use batcher::{Batch, Batcher, BatcherConfig, PushOutcome};
pub use metrics::{debug_assert_drain_invariant, MetricsSnapshot, SharedMetrics};
pub use service::{Completion, Coordinator, CoordinatorConfig, ReadRequest, SubmitError};
