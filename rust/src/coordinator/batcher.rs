//! Per-tape request batching.
//!
//! Pure, deterministic, lock-free data structure (the [`super::service`]
//! layer wraps it in a mutex): requests accumulate per tape; a batch closes
//! when its window elapses or it reaches the size cap. Tapes are dispatched
//! FIFO by batch-open time, which keeps the router fair across tapes.

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

use crate::sim::{DriveParams, SimOutcome};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatcherConfig {
    /// A batch is dispatchable once this much time passed since its first
    /// request (lets more requests for the same tape coalesce).
    pub window: Duration,
    /// … or as soon as it holds this many requests.
    pub max_batch: usize,
    /// Per-tape backlog bound: the number of requests waiting for one tape
    /// (open batch plus cap-closed batches not yet dispatched). A push at
    /// the bound is rejected with [`PushOutcome::Busy`] so callers shed or
    /// retry instead of growing memory without bound under overload.
    pub max_tape_backlog: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            window: Duration::from_millis(100),
            max_batch: 4096,
            // Generous safety valve (~1M queued requests per tape): real
            // deployments lower it to taste; the default only guards
            // against unbounded growth when drives fall hopelessly behind.
            max_tape_backlog: 1 << 20,
        }
    }
}

/// Result of [`Batcher::push`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushOutcome {
    /// Accepted, and a batch became dispatchable (size cap reached).
    Ready,
    /// Accepted into an open batch.
    Accepted,
    /// Rejected: the tape is at `max_tape_backlog`. The request was NOT
    /// enqueued; the caller may retry once the dispatcher drains the tape.
    Busy,
}

impl PushOutcome {
    /// The request was enqueued (either variant but [`PushOutcome::Busy`]).
    pub fn accepted(self) -> bool {
        self != PushOutcome::Busy
    }

    /// A batch became dispatchable as a result of the push.
    pub fn ready(self) -> bool {
        self == PushOutcome::Ready
    }
}

/// A closed batch ready for dispatch: request ids per file index.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tape: String,
    /// `(file index on tape, request ids)` — multiplicity = `ids.len()`.
    pub by_file: Vec<(usize, Vec<u64>)>,
    /// When the batch was opened (its first request's enqueue time).
    pub opened_at: Instant,
    /// When the batch first became dispatchable: the size-cap close time,
    /// the window expiry, or (under forced drain) the pop itself. The gap
    /// between this and the actual dispatch is the batch's *drive wait* —
    /// time spent queueing for a free drive, a first-class latency
    /// component of the mount pipeline.
    pub ready_at: Instant,
}

impl Batch {
    /// Total number of user requests in the batch.
    pub fn n_requests(&self) -> usize {
        self.by_file.iter().map(|(_, ids)| ids.len()).sum()
    }

    /// `(file index, multiplicity)` pairs, the [`crate::model::Instance`]
    /// input shape.
    pub fn multiplicities(&self) -> Vec<(usize, u64)> {
        self.by_file.iter().map(|(f, ids)| (*f, ids.len() as u64)).collect()
    }

    /// Map the ground-truth outcome of this batch's schedule back to per
    /// request `(id, mount-inclusive service seconds)` pairs.
    /// `mount_charge_s` is the mount-pipeline latency this batch actually
    /// paid — `drive.mount_s` in the legacy fixed-cost model, `0` on a
    /// drive-affinity remount hit, `unmount_s + mount_s` on an eviction
    /// (see [`crate::sim::DriveParams::mount_charge_s`]).
    ///
    /// This is the single home of a load-bearing invariant: the instance
    /// built from [`Batch::multiplicities`] has its files in *this batch's
    /// sorted file order* ([`Batcher::push`] seals sorted,
    /// `Instance::from_tape` folds by index), so `out.service[i]` belongs
    /// to `by_file[i]`. Both the coordinator drive worker and the replay
    /// engine account completions through here — change it in one place.
    pub fn request_service_times<'a>(
        &'a self,
        out: &'a SimOutcome,
        drive: DriveParams,
        mount_charge_s: f64,
    ) -> impl Iterator<Item = (u64, f64)> + 'a {
        self.by_file.iter().enumerate().flat_map(move |(i, (_file, ids))| {
            let service_s = drive.to_seconds(out.service[i]) + mount_charge_s;
            ids.iter().map(move |&id| (id, service_s))
        })
    }

    /// Integer-µs sibling of [`Batch::request_service_times`] for the
    /// replay engine's event-driven mount pipeline: `mount_delay_us` is
    /// the measured virtual pipeline latency (arm waits + robot ops) from
    /// dispatch to execution start. Same `by_file[i] ↔ out.service[i]`
    /// invariant; the in-tape component uses the engine's `secs_to_us`
    /// rounding so completions stay on the deterministic µs grid.
    pub fn request_service_times_us<'a>(
        &'a self,
        out: &'a SimOutcome,
        drive: DriveParams,
        mount_delay_us: u64,
    ) -> impl Iterator<Item = (u64, u64)> + 'a {
        self.by_file.iter().enumerate().flat_map(move |(i, (_file, ids))| {
            let in_tape_us = crate::util::secs_to_us(drive.to_seconds(out.service[i]));
            let service_us = mount_delay_us + in_tape_us;
            ids.iter().map(move |&id| (id, service_us))
        })
    }
}

#[derive(Debug)]
struct OpenBatch {
    /// `(file index, request ids)` in first-touch order. Batches are small
    /// (bounded by `max_batch`, typically a few dozen live files), so a
    /// linear scan on push beats hashing — and keeping the insertion
    /// sequence lets us track sortedness as we go.
    by_file: Vec<(usize, Vec<u64>)>,
    /// True while `by_file` is ascending in file index. Real request
    /// streams batch mostly-sequential reads, so this usually survives to
    /// seal time and the sort there is skipped entirely.
    sorted: bool,
    n: usize,
    opened_at: Instant,
}

/// The batcher: open batches per tape + FIFO of tapes by open time, plus a
/// queue of batches already closed by the size cap.
#[derive(Debug)]
pub struct Batcher {
    cfg: BatcherConfig,
    open: HashMap<String, OpenBatch>,
    fifo: VecDeque<String>,
    closed: VecDeque<Batch>,
    /// Requests waiting per tape (open + cap-closed undispatched batches);
    /// entries are removed when they hit zero so the map tracks only tapes
    /// with live backlog.
    backlog: HashMap<String, u64>,
    enqueued: u64,
    dispatched: u64,
    rejected: u64,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher {
            cfg,
            open: HashMap::new(),
            fifo: VecDeque::new(),
            closed: VecDeque::new(),
            backlog: HashMap::new(),
            enqueued: 0,
            dispatched: 0,
            rejected: 0,
        }
    }

    fn seal(tape: String, b: OpenBatch, ready_at: Instant) -> Batch {
        let mut by_file = b.by_file;
        // File indices are unique within a batch, so sorting by key alone
        // is deterministic; `sorted` means the push path already proved the
        // order and the O(m log m) pass (plus its swaps of id vectors) is
        // pure waste.
        if !b.sorted {
            by_file.sort_by_key(|&(file, _)| file);
        }
        debug_assert!(by_file.windows(2).all(|w| w[0].0 < w[1].0));
        Batch { tape, by_file, opened_at: b.opened_at, ready_at }
    }

    /// Add one request. When the tape's open batch reaches the size cap it
    /// is *closed* immediately (a later request opens a fresh batch), so no
    /// dispatched batch ever exceeds `max_batch`. A push finding the tape's
    /// backlog at `max_tape_backlog` is rejected ([`PushOutcome::Busy`]).
    pub fn push(
        &mut self,
        tape: &str,
        file_index: usize,
        request_id: u64,
        now: Instant,
    ) -> PushOutcome {
        if self.tape_backlog(tape) >= self.cfg.max_tape_backlog {
            self.rejected += 1;
            return PushOutcome::Busy;
        }
        self.enqueued += 1;
        // Avoid allocating the key when the tape already has live backlog
        // (this runs once per request under the service's batcher mutex).
        if let Some(v) = self.backlog.get_mut(tape) {
            *v += 1;
        } else {
            self.backlog.insert(tape.to_string(), 1);
        }
        let entry = self.open.entry(tape.to_string()).or_insert_with(|| {
            self.fifo.push_back(tape.to_string());
            OpenBatch { by_file: Vec::new(), sorted: true, n: 0, opened_at: now }
        });
        if let Some((_, ids)) =
            entry.by_file.iter_mut().find(|(f, _)| *f == file_index)
        {
            // Repeat read of an already-batched file: multiplicity bump,
            // order untouched.
            ids.push(request_id);
        } else {
            if let Some(&(last, _)) = entry.by_file.last() {
                if file_index < last {
                    entry.sorted = false;
                }
            }
            entry.by_file.push((file_index, vec![request_id]));
        }
        entry.n += 1;
        if entry.n >= self.cfg.max_batch {
            let b = self.open.remove(tape).unwrap();
            self.fifo.retain(|t| t != tape);
            // The size cap closes the batch right now: dispatchable from
            // this instant.
            self.closed.push_back(Self::seal(tape.to_string(), b, now));
            PushOutcome::Ready
        } else {
            PushOutcome::Accepted
        }
    }

    fn debit_backlog(backlog: &mut HashMap<String, u64>, tape: &str, n: u64) {
        if let Some(v) = backlog.get_mut(tape) {
            *v = v.saturating_sub(n);
            if *v == 0 {
                backlog.remove(tape);
            }
        }
    }

    /// Pop the next dispatchable batch: a cap-closed batch first, otherwise
    /// the oldest open batch whose window has expired. `force` dispatches
    /// the oldest batch regardless of window (used at drain/shutdown or
    /// when drives are idle — an idle drive should never wait on a timer).
    pub fn pop_ready(&mut self, now: Instant, force: bool) -> Option<Batch> {
        if let Some(b) = self.closed.pop_front() {
            self.dispatched += b.n_requests() as u64;
            Self::debit_backlog(&mut self.backlog, &b.tape, b.n_requests() as u64);
            return Some(b);
        }
        let pos = self.fifo.iter().position(|t| {
            let b = &self.open[t];
            force || now.duration_since(b.opened_at) >= self.cfg.window
        })?;
        let tape = self.fifo.remove(pos).unwrap();
        let b = self.open.remove(&tape).unwrap();
        self.dispatched += b.n as u64;
        Self::debit_backlog(&mut self.backlog, &tape, b.n as u64);
        // Dispatchable since its window expired — or, when force-popped
        // before that (drain / idle drive), since right now.
        let ready_at = (b.opened_at + self.cfg.window).min(now);
        Some(Self::seal(tape, b, ready_at))
    }

    /// Requests currently queued for `tape` (open + cap-closed batches).
    pub fn tape_backlog(&self, tape: &str) -> usize {
        self.backlog.get(tape).copied().unwrap_or(0) as usize
    }

    /// Pushes rejected by the per-tape backlog bound since construction.
    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Number of requests currently waiting in open batches.
    pub fn pending(&self) -> u64 {
        self.enqueued - self.dispatched
    }

    /// Number of open (undispatched) tape batches.
    pub fn open_tapes(&self) -> usize {
        self.open.len()
    }

    /// Wake-up deadline: immediate (a past instant) when a cap-closed
    /// batch is already waiting, otherwise the oldest open batch's window
    /// expiry. `pop_ready` serves closed batches regardless of windows, so
    /// a caller that pops before sleeping (as the dispatcher does, under
    /// one lock) never observes the closed branch — it exists so the
    /// deadline contract holds for *any* caller, not just that pattern.
    pub fn next_deadline(&self) -> Option<Instant> {
        if let Some(b) = self.closed.front() {
            return Some(b.opened_at); // in the past ⇒ zero wait
        }
        self.fifo.front().map(|t| self.open[t].opened_at + self.cfg.window)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(window_ms: u64, max_batch: usize) -> BatcherConfig {
        BatcherConfig {
            window: Duration::from_millis(window_ms),
            max_batch,
            max_tape_backlog: usize::MAX,
        }
    }

    #[test]
    fn batches_by_tape_and_respects_window() {
        let mut b = Batcher::new(cfg(50, 100));
        let t0 = Instant::now();
        b.push("A", 3, 1, t0);
        b.push("A", 3, 2, t0);
        b.push("B", 7, 3, t0);
        assert_eq!(b.open_tapes(), 2);
        assert_eq!(b.pending(), 3);
        // Window not expired: nothing ready.
        assert!(b.pop_ready(t0, false).is_none());
        // After the window, FIFO order: A first.
        let later = t0 + Duration::from_millis(60);
        let batch = b.pop_ready(later, false).unwrap();
        assert_eq!(batch.tape, "A");
        assert_eq!(batch.by_file, vec![(3, vec![1, 2])]);
        assert_eq!(batch.n_requests(), 2);
        assert_eq!(batch.multiplicities(), vec![(3, 2)]);
        let batch = b.pop_ready(later, false).unwrap();
        assert_eq!(batch.tape, "B");
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn size_cap_triggers_immediate_dispatch() {
        let mut b = Batcher::new(cfg(1_000_000, 3));
        let t0 = Instant::now();
        assert_eq!(b.push("A", 0, 1, t0), PushOutcome::Accepted);
        assert_eq!(b.push("A", 1, 2, t0), PushOutcome::Accepted);
        assert!(b.push("A", 0, 3, t0).ready(), "cap reached");
        let batch = b.pop_ready(t0, false).expect("cap makes it ready");
        assert_eq!(batch.n_requests(), 3);
    }

    #[test]
    fn backlog_bound_rejects_and_recovers() {
        let mut b = Batcher::new(BatcherConfig {
            window: Duration::from_millis(1_000_000),
            max_batch: 2,
            max_tape_backlog: 3,
        });
        let t0 = Instant::now();
        // Two pushes close a batch (cap 2); the third sits in a new open
        // batch. Backlog = 3 = bound ⇒ the fourth push is rejected, and the
        // rejected request must not be counted as pending.
        assert!(b.push("A", 0, 1, t0).ready());
        assert_eq!(b.push("A", 1, 2, t0), PushOutcome::Accepted);
        assert_eq!(b.tape_backlog("A"), 3);
        assert_eq!(b.push("A", 2, 3, t0), PushOutcome::Busy);
        assert_eq!(b.rejected(), 1);
        assert_eq!(b.pending(), 3);
        // Another tape is unaffected.
        assert_eq!(b.push("B", 0, 4, t0), PushOutcome::Accepted);
        // Dispatching the cap-closed batch frees 2 slots on A.
        let batch = b.pop_ready(t0, false).expect("closed batch ready");
        assert_eq!(batch.tape, "A");
        assert_eq!(b.tape_backlog("A"), 1);
        assert_eq!(b.push("A", 2, 5, t0), PushOutcome::Accepted);
        // Drain everything; the backlog map must empty out.
        while b.pop_ready(t0, true).is_some() {}
        assert_eq!(b.tape_backlog("A"), 0);
        assert_eq!(b.tape_backlog("B"), 0);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn force_dispatches_oldest_regardless_of_window() {
        let mut b = Batcher::new(cfg(1_000_000, 1_000_000));
        let t0 = Instant::now();
        b.push("A", 0, 1, t0);
        assert!(b.pop_ready(t0, false).is_none());
        let batch = b.pop_ready(t0, true).unwrap();
        assert_eq!(batch.tape, "A");
    }

    #[test]
    fn multiplicities_sorted_by_file() {
        let mut b = Batcher::new(cfg(0, 100));
        let t0 = Instant::now();
        b.push("A", 9, 1, t0);
        b.push("A", 2, 2, t0);
        b.push("A", 9, 3, t0);
        let batch = b.pop_ready(t0, false).unwrap();
        assert_eq!(batch.multiplicities(), vec![(2, 1), (9, 2)]);
    }

    #[test]
    fn seal_order_is_identical_with_and_without_the_sort_fast_path() {
        // Pin the sealed-batch contract the scheduler relies on: files
        // strictly ascending, ids within a file in push order — whether the
        // pushes arrived pre-sorted (sort skipped) or scrambled (sort
        // taken). A regression in the sortedness tracking would surface
        // here as a misordered `by_file`.
        let t0 = Instant::now();

        // Ascending pushes: the fast path. Repeat files must not disturb it.
        let mut b = Batcher::new(cfg(0, 100));
        b.push("A", 1, 10, t0);
        b.push("A", 4, 11, t0);
        b.push("A", 1, 12, t0);
        b.push("A", 4, 13, t0);
        b.push("A", 9, 14, t0);
        let fast = b.pop_ready(t0, false).unwrap();
        assert_eq!(
            fast.by_file,
            vec![(1, vec![10, 12]), (4, vec![11, 13]), (9, vec![14])]
        );

        // Same requests, scrambled arrival order: the sort path must land
        // on the same sealed shape (ids keep *their* push order, which here
        // differs per file).
        let mut b = Batcher::new(cfg(0, 100));
        b.push("A", 9, 14, t0);
        b.push("A", 4, 13, t0);
        b.push("A", 1, 12, t0);
        b.push("A", 4, 11, t0);
        b.push("A", 1, 10, t0);
        let slow = b.pop_ready(t0, false).unwrap();
        assert_eq!(
            slow.by_file,
            vec![(1, vec![12, 10]), (4, vec![13, 11]), (9, vec![14])]
        );

        // An equal file index is NOT a sort violation — only a strictly
        // descending step is.
        let mut b = Batcher::new(cfg(0, 100));
        b.push("A", 3, 1, t0);
        b.push("A", 3, 2, t0);
        b.push("A", 5, 3, t0);
        let batch = b.pop_ready(t0, false).unwrap();
        assert_eq!(batch.by_file, vec![(3, vec![1, 2]), (5, vec![3])]);
    }

    #[test]
    fn next_deadline_tracks_oldest() {
        let mut b = Batcher::new(cfg(100, 10));
        let t0 = Instant::now();
        assert!(b.next_deadline().is_none());
        b.push("A", 0, 1, t0);
        b.push("B", 0, 2, t0 + Duration::from_millis(10));
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(100)));
    }

    #[test]
    fn next_deadline_is_immediate_while_a_closed_batch_waits() {
        // Regression: a cap-closed batch used to be invisible to
        // next_deadline (only fifo.front() was inspected), so a caller
        // sleeping until the reported deadline would wait out an open
        // batch's window while a dispatchable batch sat in the closed
        // queue. (The in-tree dispatcher pops before sleeping and so never
        // hit this; the contract must hold for external callers too.)
        let window = Duration::from_millis(1_000_000);
        let mut b = Batcher::new(cfg(1_000_000, 2));
        let t0 = Instant::now();
        b.push("A", 0, 1, t0);
        assert!(b.push("A", 1, 2, t0).ready(), "cap of 2 closes A's batch");
        b.push("B", 0, 3, t0 + Duration::from_millis(5));
        // A's closed batch makes the deadline immediate (not B's window).
        let d = b.next_deadline().expect("work pending");
        assert!(d <= t0, "deadline {d:?} must not wait for an open window");
        // Popping the closed batch restores the open batch's window.
        assert_eq!(b.pop_ready(t0, false).unwrap().tape, "A");
        assert_eq!(
            b.next_deadline(),
            Some(t0 + Duration::from_millis(5) + window)
        );
    }

    #[test]
    fn ready_at_marks_when_a_batch_became_dispatchable() {
        let mut b = Batcher::new(cfg(100, 2));
        let t0 = Instant::now();
        // Size-cap close: dispatchable the instant the cap is hit.
        b.push("A", 0, 1, t0);
        assert!(b.push("A", 1, 2, t0 + Duration::from_millis(3)).ready());
        let batch = b.pop_ready(t0 + Duration::from_millis(50), false).unwrap();
        assert_eq!(batch.ready_at, t0 + Duration::from_millis(3));
        // Window pop: ready at the window expiry even when popped later.
        b.push("B", 0, 3, t0);
        let batch = b.pop_ready(t0 + Duration::from_millis(250), false).unwrap();
        assert_eq!(batch.ready_at, t0 + Duration::from_millis(100));
        // Forced pop before the window (drain / idle drive): ready now.
        b.push("C", 0, 4, t0);
        let batch = b.pop_ready(t0 + Duration::from_millis(10), true).unwrap();
        assert_eq!(batch.ready_at, t0 + Duration::from_millis(10));
    }

    #[test]
    fn no_request_lost_or_duplicated() {
        let mut b = Batcher::new(cfg(0, 7));
        let t0 = Instant::now();
        let mut sent: Vec<u64> = Vec::new();
        for id in 0..1_000u64 {
            let tape = format!("T{}", id % 13);
            b.push(&tape, (id % 5) as usize, id, t0);
            sent.push(id);
        }
        let mut got: Vec<u64> = Vec::new();
        while let Some(batch) = b.pop_ready(t0, true) {
            for (_, ids) in batch.by_file {
                got.extend(ids);
            }
        }
        got.sort();
        assert_eq!(got, sent);
        assert_eq!(b.pending(), 0);
    }
}
