//! Loader / writer for the paper's public dataset format (Appendix C.1):
//!
//! ```text
//! <root>/list_of_tape.txt          one tape name per line (TAPE001 …)
//! <root>/tapes/TAPEXXX.txt         id  cumulative_position  segment_size  index
//! <root>/requests/TAPEXXX.txt      index  nb_requests
//! ```
//!
//! Columns are whitespace- or comma-separated; a non-numeric first line is
//! treated as a header and skipped. File `index` is 1-based in the dataset
//! (leftmost file = 1) and converted to 0-based in memory.

use std::fs;
use std::io;
use std::path::Path;

use super::{Dataset, TapeData};
use crate::model::{FileExtent, Tape};

/// Errors raised while reading a dataset directory.
#[derive(Debug)]
pub enum LoadError {
    Io { path: String, source: io::Error },
    BadColumns { path: String, line: usize, expected: usize, got: usize },
    BadIndex { path: String, line: usize, got: usize, expected: usize },
    UnknownFile { path: String, line: usize, index: usize, n_files: usize },
    Inconsistent { path: String, line: usize },
    NoRequests(String),
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadError::Io { path, source } => write!(f, "I/O error on {path}: {source}"),
            LoadError::BadColumns { path, line, expected, got } => {
                write!(f, "{path}:{line}: expected {expected} numeric columns, got {got}")
            }
            LoadError::BadIndex { path, line, got, expected } => write!(
                f,
                "{path}:{line}: file indices must be 1-based and contiguous \
                 (got {got}, expected {expected})"
            ),
            LoadError::UnknownFile { path, line, index, n_files } => write!(
                f,
                "{path}:{line}: request on unknown file index {index} \
                 (tape has {n_files} files)"
            ),
            LoadError::Inconsistent { path, line } => write!(
                f,
                "{path}:{line}: positions must be non-decreasing / consistent with sizes"
            ),
            LoadError::NoRequests(tape) => write!(f, "tape {tape} has no requests"),
        }
    }
}

impl std::error::Error for LoadError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LoadError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

fn read(path: &Path) -> Result<String, LoadError> {
    fs::read_to_string(path).map_err(|source| LoadError::Io {
        path: path.display().to_string(),
        source,
    })
}

/// Parse whitespace/comma separated numeric rows, skipping header lines,
/// blank lines, and `#` comments.
fn numeric_rows(content: &str) -> impl Iterator<Item = (usize, Vec<u64>)> + '_ {
    content.lines().enumerate().filter_map(|(i, line)| {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            return None;
        }
        let cols: Vec<&str> = line
            .split(|c: char| c.is_whitespace() || c == ',' || c == ';')
            .filter(|s| !s.is_empty())
            .collect();
        let nums: Option<Vec<u64>> = cols.iter().map(|s| s.parse().ok()).collect();
        match nums {
            Some(v) => Some((i + 1, v)),
            None if i == 0 => None, // header line
            None => Some((i + 1, Vec::new())), // poisoned row → error downstream
        }
    })
}

/// Load a single tape description + its request file.
pub fn load_tape(root: &Path, name: &str) -> Result<TapeData, LoadError> {
    // --- tapes/NAME.txt: id, cumulative_position, segment_size, index ---
    let tpath = root.join("tapes").join(format!("{name}.txt"));
    let tstr = tpath.display().to_string();
    let mut files = Vec::new();
    let mut cursor = 0u64;
    for (line, cols) in numeric_rows(&read(&tpath)?) {
        if cols.len() != 4 {
            return Err(LoadError::BadColumns {
                path: tstr.clone(),
                line,
                expected: 4,
                got: cols.len(),
            });
        }
        let (pos, size, index) = (cols[1], cols[2], cols[3] as usize);
        if index != files.len() + 1 {
            return Err(LoadError::BadIndex {
                path: tstr.clone(),
                line,
                got: index,
                expected: files.len() + 1,
            });
        }
        // `cumulative_position` is the position of the segment's right end
        // (cumulative sum of sizes, as documented in Appendix C.2); accept
        // either that or a left-end convention, and validate continuity.
        let left = if pos == cursor + size || pos == cursor {
            cursor
        } else {
            return Err(LoadError::Inconsistent { path: tstr.clone(), line });
        };
        files.push(FileExtent { left, size });
        cursor = left + size;
    }

    // --- requests/NAME.txt: index, nb_requests ---
    let rpath = root.join("requests").join(format!("{name}.txt"));
    let rstr = rpath.display().to_string();
    let mut requests = Vec::new();
    for (line, cols) in numeric_rows(&read(&rpath)?) {
        if cols.len() != 2 {
            return Err(LoadError::BadColumns {
                path: rstr.clone(),
                line,
                expected: 2,
                got: cols.len(),
            });
        }
        let (index, x) = (cols[0] as usize, cols[1]);
        if index == 0 || index > files.len() {
            return Err(LoadError::UnknownFile {
                path: rstr.clone(),
                line,
                index,
                n_files: files.len(),
            });
        }
        if x > 0 {
            requests.push((index - 1, x));
        }
    }
    if requests.is_empty() {
        return Err(LoadError::NoRequests(name.to_string()));
    }
    requests.sort();

    Ok(TapeData { tape: Tape { name: name.to_string(), files }, requests })
}

/// Load a full dataset directory (`list_of_tape.txt` + `tapes/` + `requests/`).
pub fn load_dataset(root: &Path) -> Result<Dataset, LoadError> {
    let list = read(&root.join("list_of_tape.txt"))?;
    let mut tapes = Vec::new();
    for name in list.lines().map(str::trim).filter(|l| !l.is_empty()) {
        let name = name.strip_suffix(".txt").unwrap_or(name);
        tapes.push(load_tape(root, name)?);
    }
    Ok(Dataset { tapes })
}

/// Write a dataset in the paper's on-disk format (inverse of [`load_dataset`]).
pub fn write_dataset(root: &Path, ds: &Dataset) -> io::Result<()> {
    fs::create_dir_all(root.join("tapes"))?;
    fs::create_dir_all(root.join("requests"))?;
    let mut list = String::new();
    for t in &ds.tapes {
        list.push_str(&t.tape.name);
        list.push('\n');

        let mut tf = String::from("id cumulative_position segment_size index\n");
        for (i, f) in t.tape.files.iter().enumerate() {
            tf.push_str(&format!("{} {} {} {}\n", i + 1, f.right(), f.size, i + 1));
        }
        fs::write(root.join("tapes").join(format!("{}.txt", t.tape.name)), tf)?;

        let mut rf = String::from("index nb_requests\n");
        for &(idx, x) in &t.requests {
            rf.push_str(&format!("{} {}\n", idx + 1, x));
        }
        fs::write(root.join("requests").join(format!("{}.txt", t.tape.name)), rf)?;
    }
    fs::write(root.join("list_of_tape.txt"), list)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tapesched_loader_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample() -> Dataset {
        let tape = Tape::from_sizes("TAPE001", &[100, 250, 50]);
        Dataset {
            tapes: vec![TapeData { tape, requests: vec![(0, 3), (2, 1)] }],
        }
    }

    #[test]
    fn roundtrip() {
        let d = tmpdir("roundtrip");
        write_dataset(&d, &sample()).unwrap();
        let ds = load_dataset(&d).unwrap();
        assert_eq!(ds.tapes.len(), 1);
        let t = &ds.tapes[0];
        assert_eq!(t.tape.name, "TAPE001");
        assert_eq!(t.tape.n_files(), 3);
        assert_eq!(t.tape.files[1], FileExtent { left: 100, size: 250 });
        assert_eq!(t.requests, vec![(0, 3), (2, 1)]);
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn header_and_separator_tolerance() {
        let d = tmpdir("tolerance");
        fs::create_dir_all(d.join("tapes")).unwrap();
        fs::create_dir_all(d.join("requests")).unwrap();
        fs::write(d.join("list_of_tape.txt"), "TAPE001\n\n").unwrap();
        fs::write(
            d.join("tapes/TAPE001.txt"),
            "id,cumulative_position,segment_size,index\n1,10,10,1\n2,25,15,2\n",
        )
        .unwrap();
        fs::write(d.join("requests/TAPE001.txt"), "index nb_requests\n2 4\n").unwrap();
        let ds = load_dataset(&d).unwrap();
        assert_eq!(ds.tapes[0].tape.len(), 25);
        assert_eq!(ds.tapes[0].requests, vec![(1, 4)]);
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn rejects_bad_index() {
        let d = tmpdir("badindex");
        fs::create_dir_all(d.join("tapes")).unwrap();
        fs::create_dir_all(d.join("requests")).unwrap();
        fs::write(d.join("list_of_tape.txt"), "TAPE001\n").unwrap();
        fs::write(d.join("tapes/TAPE001.txt"), "h h h h\n1 10 10 2\n").unwrap();
        fs::write(d.join("requests/TAPE001.txt"), "h h\n1 1\n").unwrap();
        match load_dataset(&d) {
            Err(LoadError::BadIndex { got: 2, expected: 1, .. }) => {}
            other => panic!("expected BadIndex, got {other:?}"),
        }
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn rejects_request_on_unknown_file() {
        let d = tmpdir("unknownfile");
        fs::create_dir_all(d.join("tapes")).unwrap();
        fs::create_dir_all(d.join("requests")).unwrap();
        fs::write(d.join("list_of_tape.txt"), "TAPE001\n").unwrap();
        fs::write(d.join("tapes/TAPE001.txt"), "h h h h\n1 10 10 1\n").unwrap();
        fs::write(d.join("requests/TAPE001.txt"), "h h\n5 1\n").unwrap();
        assert!(matches!(
            load_dataset(&d),
            Err(LoadError::UnknownFile { index: 5, n_files: 1, .. })
        ));
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn missing_dir_is_io_error() {
        assert!(matches!(
            load_dataset(Path::new("/nonexistent/nowhere")),
            Err(LoadError::Io { .. })
        ));
    }
}
