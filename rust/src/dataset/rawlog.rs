//! The raw-log processing pipeline of Appendix C.1.
//!
//! The paper starts from "millions of lines of reading, writing, and
//! update requests with their associated timestamp" and derives its
//! 169-instance dataset through documented filtering steps. This module
//! implements that pipeline — plus a synthetic raw-log generator standing
//! in for the (private) production logs — so the whole data path exists
//! as code:
//!
//! 1. keep read operations only;
//! 2. drop requests on aggregates spanning several segments;
//! 3. collapse every file request inside an aggregate into **one** request
//!    for the whole aggregate, with multiplicity = number of requested
//!    files in it (the paper's disk-buffering optimization);
//! 4. merge duplicates into per-file multiplicities.

use std::collections::BTreeMap;

use super::TapeData;
use crate::model::Tape;
use crate::util::rng::Rng;

/// Kind of operation in the raw log.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Read,
    Write,
    Update,
}

/// One raw log line: an operation on one file of one tape.
#[derive(Debug, Clone)]
pub struct LogLine {
    /// Seconds since the start of the log window.
    pub timestamp: u64,
    pub tape: String,
    /// Segment index on the tape (0-based).
    pub segment: usize,
    /// File offset *within* the segment's aggregate (0 = the aggregate
    /// head, also used for plain single-file segments).
    pub offset: usize,
    pub op: OpKind,
}

/// Catalog-side description of one segment: either a plain file or an
/// aggregate of `n_files` related files; aggregates may continue into the
/// next segment (`spans_next`), which the paper's pipeline filters out.
#[derive(Debug, Clone, Copy)]
pub struct SegmentDesc {
    pub n_files: usize,
    pub spans_next: bool,
}

/// Catalog for one tape: the physical layout plus per-segment structure.
#[derive(Debug, Clone)]
pub struct TapeCatalog {
    pub tape: Tape,
    pub segments: Vec<SegmentDesc>,
}

/// Statistics of one pipeline run (the counts Appendix C reports).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FilterStats {
    pub lines_total: usize,
    pub lines_non_read: usize,
    pub lines_cross_segment: usize,
    pub lines_kept: usize,
    /// Distinct (tape, segment) requests after aggregate collapsing.
    pub unique_requests: usize,
    /// Total request multiplicity after collapsing.
    pub total_requests: u64,
}

/// Run the Appendix C pipeline: raw lines + catalogs → per-tape LTSP
/// request sets. Unknown tapes/segments are ignored (logs mention tapes
/// outside the selected set).
pub fn filter_raw_log(
    lines: &[LogLine],
    catalogs: &BTreeMap<String, TapeCatalog>,
) -> (Vec<TapeData>, FilterStats) {
    let mut stats = FilterStats { lines_total: lines.len(), ..Default::default() };
    // (tape, segment) → multiplicity. BTreeMap keeps tape/file order
    // deterministic.
    let mut counts: BTreeMap<(&str, usize), u64> = BTreeMap::new();

    for line in lines {
        if line.op != OpKind::Read {
            stats.lines_non_read += 1;
            continue;
        }
        let Some(cat) = catalogs.get(&line.tape) else { continue };
        let Some(seg) = cat.segments.get(line.segment) else { continue };
        if seg.spans_next {
            // Aggregate spills into the following segment(s): discarded,
            // with its requests (paper: "we discarded such aggregates and
            // their associated requests").
            stats.lines_cross_segment += 1;
            continue;
        }
        stats.lines_kept += 1;
        // Aggregate collapsing: any offset within the segment becomes a
        // request for the segment head; multiplicity accumulates per
        // *requested file*, exactly the paper's rule ("a number of
        // requests equal to the number of requested files in that
        // aggregate" — duplicates of the same offset still count once
        // buffered on disk, so we count log lines, the upper bound the
        // paper's optimization realizes).
        *counts.entry((line.tape.as_str(), line.segment)).or_insert(0) += 1;
    }

    let mut tapes: BTreeMap<&str, Vec<(usize, u64)>> = BTreeMap::new();
    for ((tape, seg), x) in counts {
        tapes.entry(tape).or_default().push((seg, x));
    }
    stats.unique_requests = tapes.values().map(|v| v.len()).sum();
    stats.total_requests = tapes.values().flatten().map(|&(_, x)| x).sum();

    let data = tapes
        .into_iter()
        .map(|(name, requests)| TapeData {
            tape: catalogs[name].tape.clone(),
            requests,
        })
        .collect();
    (data, stats)
}

/// Synthesize a raw activity log over a set of catalogs: a stand-in for
/// the IN2P3 production logs with the same *structure* (reads mixed with
/// writes/updates, skewed file popularity, cross-segment aggregates).
pub fn synth_raw_log(
    catalogs: &BTreeMap<String, TapeCatalog>,
    n_lines: usize,
    window_s: u64,
    seed: u64,
) -> Vec<LogLine> {
    let mut rng = Rng::new(seed);
    let names: Vec<&String> = catalogs.keys().collect();
    let mut lines = Vec::with_capacity(n_lines);
    for _ in 0..n_lines {
        let tape = names[rng.zipf(names.len() as u64, 1.1) as usize - 1];
        let cat = &catalogs[tape];
        let segment = rng.zipf(cat.segments.len() as u64, 1.05) as usize - 1;
        let seg = cat.segments[segment];
        let offset = if seg.n_files > 1 { rng.below(seg.n_files as u64) as usize } else { 0 };
        // ~80 % reads, matching a read-dominated archive workload.
        let op = match rng.below(10) {
            0 => OpKind::Write,
            1 => OpKind::Update,
            _ => OpKind::Read,
        };
        lines.push(LogLine {
            timestamp: rng.below(window_s),
            tape: tape.clone(),
            segment,
            offset,
            op,
        });
    }
    lines.sort_by_key(|l| l.timestamp);
    lines
}

/// One line of the on-disk replay trace format:
/// `timestamp_ns<TAB>tape<TAB>file_id` (see `rust/README.md`, "Trace file
/// format"). This is the operator-facing ingestion point — `tapesched
/// replay --arrivals trace --trace-file <path>` replays real logs through
/// it instead of the in-process synthesizer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceRecord {
    /// Nanoseconds since the start of the trace window.
    pub timestamp_ns: u64,
    /// Catalog tape name.
    pub tape: String,
    /// 0-based file index on the tape.
    pub file_id: usize,
}

/// Parse one line of the on-disk trace format (`line_no` is 1-based, for
/// error messages). `Ok(None)` means the line carries no record — blank
/// or a `#` comment. Leading/trailing whitespace is trimmed, which also
/// makes CRLF line endings transparent. This is the one grammar shared by
/// the eager [`parse_trace`] and the streaming [`TraceReader`], so the
/// two paths cannot drift.
pub fn parse_trace_line(raw: &str, line_no: usize) -> Result<Option<TraceRecord>, String> {
    let line = raw.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let fields: Vec<&str> = line.split('\t').collect();
    if fields.len() != 3 {
        return Err(format!(
            "trace line {line_no}: expected `timestamp_ns<TAB>tape<TAB>file_id`, got {} field(s)",
            fields.len()
        ));
    }
    let timestamp_ns: u64 = fields[0]
        .trim()
        .parse()
        .map_err(|_| format!("trace line {line_no}: bad timestamp_ns `{}`", fields[0]))?;
    let tape = fields[1].trim();
    if tape.is_empty() {
        return Err(format!("trace line {line_no}: empty tape name"));
    }
    let file_id: usize = fields[2]
        .trim()
        .parse()
        .map_err(|_| format!("trace line {line_no}: bad file_id `{}`", fields[2]))?;
    Ok(Some(TraceRecord { timestamp_ns, tape: tape.to_string(), file_id }))
}

/// Parse the on-disk trace format: one `timestamp_ns<TAB>tape<TAB>file_id`
/// record per line; blank lines and `#` comments are skipped. Errors carry
/// the 1-based line number. Records are returned in file order (the
/// consumer sorts by timestamp — real logs are near-sorted but rotation
/// can interleave).
pub fn parse_trace(text: &str) -> Result<Vec<TraceRecord>, String> {
    let mut records = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        if let Some(rec) = parse_trace_line(raw, i + 1)? {
            records.push(rec);
        }
    }
    Ok(records)
}

/// Streaming trace reader: a buffered line iterator yielding
/// [`TraceRecord`]s one at a time, holding one line of text in memory
/// regardless of trace size — the O(window) ingestion path a 10⁸-request
/// replay needs (the eager [`read_trace_file`] holds the whole record
/// vector). A final line without a trailing newline still parses; after
/// the first error (or EOF) the iterator latches done and yields nothing
/// further.
pub struct TraceReader<R: std::io::BufRead> {
    src: R,
    buf: String,
    line_no: usize,
    skipped: usize,
    done: bool,
}

impl<R: std::io::BufRead> TraceReader<R> {
    pub fn new(src: R) -> TraceReader<R> {
        TraceReader { src, buf: String::new(), line_no: 0, skipped: 0, done: false }
    }

    /// Blank and comment lines skipped so far.
    pub fn skipped(&self) -> usize {
        self.skipped
    }

    /// 1-based number of the last line read (0 before the first).
    pub fn line_no(&self) -> usize {
        self.line_no
    }
}

impl<R: std::io::BufRead> Iterator for TraceReader<R> {
    type Item = Result<TraceRecord, String>;

    fn next(&mut self) -> Option<Self::Item> {
        while !self.done {
            self.buf.clear();
            match self.src.read_line(&mut self.buf) {
                Ok(0) => self.done = true,
                Ok(_) => {
                    self.line_no += 1;
                    match parse_trace_line(&self.buf, self.line_no) {
                        Ok(Some(rec)) => return Some(Ok(rec)),
                        Ok(None) => self.skipped += 1,
                        Err(e) => {
                            self.done = true;
                            return Some(Err(e));
                        }
                    }
                }
                Err(e) => {
                    self.done = true;
                    return Some(Err(format!(
                        "trace line {}: read error: {e}",
                        self.line_no + 1
                    )));
                }
            }
        }
        None
    }
}

/// Open `path` as a streaming [`TraceReader`] — the constant-memory
/// ingestion point ([`read_trace_file`] is the collecting shim over it).
pub fn open_trace_file(
    path: &std::path::Path,
) -> Result<TraceReader<std::io::BufReader<std::fs::File>>, String> {
    let file = std::fs::File::open(path)
        .map_err(|e| format!("cannot read trace file {}: {e}", path.display()))?;
    Ok(TraceReader::new(std::io::BufReader::new(file)))
}

/// Read and parse a whole trace file (a thin collector over
/// [`open_trace_file`], kept for callers that want the full record set).
pub fn read_trace_file(path: &std::path::Path) -> Result<Vec<TraceRecord>, String> {
    open_trace_file(path)?.collect()
}

/// Render records back into the on-disk trace format (round-trips through
/// [`parse_trace`]; used to export synthetic traces and in tests).
pub fn trace_to_string(records: &[TraceRecord]) -> String {
    let mut out = String::new();
    for r in records {
        out.push_str(&format!("{}\t{}\t{}\n", r.timestamp_ns, r.tape, r.file_id));
    }
    out
}

/// Build a synthetic catalog: `n_segments` segments, a fraction of which
/// are aggregates, a fraction of those spanning into the next segment.
pub fn synth_catalog(name: &str, n_segments: usize, seed: u64) -> TapeCatalog {
    let mut rng = Rng::new(seed ^ 0xCA7A_7061);
    let mut sizes = Vec::with_capacity(n_segments);
    let mut segments = Vec::with_capacity(n_segments);
    for i in 0..n_segments {
        sizes.push(rng.range(1_000_000, 200_000_000_000));
        let is_aggregate = rng.f64() < 0.3;
        let n_files = if is_aggregate { rng.range(2, 40) as usize } else { 1 };
        // A segment cannot "span next" if it is the last one.
        let spans_next = is_aggregate && i + 1 < n_segments && rng.f64() < 0.15;
        segments.push(SegmentDesc { n_files, spans_next });
    }
    TapeCatalog { tape: Tape::from_sizes(name, &sizes), segments }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn catalogs() -> BTreeMap<String, TapeCatalog> {
        let mut m = BTreeMap::new();
        // TAPE A: segment 0 plain, 1 aggregate(3), 2 aggregate spanning.
        m.insert(
            "A".to_string(),
            TapeCatalog {
                tape: Tape::from_sizes("A", &[10, 20, 30]),
                segments: vec![
                    SegmentDesc { n_files: 1, spans_next: false },
                    SegmentDesc { n_files: 3, spans_next: false },
                    SegmentDesc { n_files: 5, spans_next: true },
                ],
            },
        );
        m
    }

    fn line(seg: usize, offset: usize, op: OpKind) -> LogLine {
        LogLine { timestamp: 0, tape: "A".into(), segment: seg, offset, op }
    }

    #[test]
    fn keeps_reads_only() {
        let lines = vec![
            line(0, 0, OpKind::Read),
            line(0, 0, OpKind::Write),
            line(1, 1, OpKind::Update),
        ];
        let (data, stats) = filter_raw_log(&lines, &catalogs());
        assert_eq!(stats.lines_non_read, 2);
        assert_eq!(stats.lines_kept, 1);
        assert_eq!(data.len(), 1);
        assert_eq!(data[0].requests, vec![(0, 1)]);
    }

    #[test]
    fn discards_cross_segment_aggregates() {
        let lines = vec![line(2, 0, OpKind::Read), line(2, 3, OpKind::Read)];
        let (data, stats) = filter_raw_log(&lines, &catalogs());
        assert_eq!(stats.lines_cross_segment, 2);
        assert_eq!(stats.lines_kept, 0);
        assert!(data.is_empty());
    }

    #[test]
    fn collapses_aggregate_requests_into_multiplicity() {
        // Three reads on different files of aggregate segment 1 → one
        // requested file (the aggregate) with multiplicity 3.
        let lines = vec![
            line(1, 0, OpKind::Read),
            line(1, 1, OpKind::Read),
            line(1, 2, OpKind::Read),
        ];
        let (data, stats) = filter_raw_log(&lines, &catalogs());
        assert_eq!(data[0].requests, vec![(1, 3)]);
        assert_eq!(stats.unique_requests, 1);
        assert_eq!(stats.total_requests, 3);
    }

    #[test]
    fn unknown_tape_or_segment_is_skipped() {
        let mut l1 = line(0, 0, OpKind::Read);
        l1.tape = "NOPE".into();
        let l2 = line(99, 0, OpKind::Read);
        let (data, stats) = filter_raw_log(&[l1, l2], &catalogs());
        assert!(data.is_empty());
        assert_eq!(stats.lines_kept, 0);
        assert_eq!(stats.lines_total, 2);
    }

    #[test]
    fn pipeline_output_is_a_valid_instance() {
        let mut cats = BTreeMap::new();
        for i in 0..4 {
            let name = format!("T{i}");
            cats.insert(name.clone(), synth_catalog(&name, 50, i));
        }
        let log = synth_raw_log(&cats, 5_000, 86_400, 7);
        assert_eq!(log.len(), 5_000);
        assert!(log.windows(2).all(|w| w[0].timestamp <= w[1].timestamp));
        let (data, stats) = filter_raw_log(&log, &cats);
        assert!(stats.lines_non_read > 0, "log must mix writes in");
        assert!(stats.lines_kept > 0);
        assert_eq!(
            stats.lines_total,
            stats.lines_kept + stats.lines_non_read + stats.lines_cross_segment
        );
        for t in &data {
            let inst = t.instance(0).expect("valid LTSP instance");
            assert!(inst.k() > 0);
        }
        let total: u64 = data.iter().map(|t| t.n_total()).sum();
        assert_eq!(total, stats.total_requests);
    }

    #[test]
    fn trace_format_round_trips_and_reports_bad_lines() {
        let text = "# comment line\n\
                    \n\
                    0\tTAPE001\t3\n\
                    1500000000\tTAPE002\t0\n\
                    1500000000\tTAPE001\t17\n";
        let records = parse_trace(text).expect("valid trace");
        assert_eq!(records.len(), 3);
        assert_eq!(
            records[0],
            TraceRecord { timestamp_ns: 0, tape: "TAPE001".into(), file_id: 3 }
        );
        assert_eq!(records[1].timestamp_ns, 1_500_000_000);
        // Round trip: render → parse is the identity.
        assert_eq!(parse_trace(&trace_to_string(&records)).unwrap(), records);

        // Error paths carry the 1-based line number.
        let e = parse_trace("123\tT1\n").unwrap_err();
        assert!(e.contains("line 1"), "{e}");
        let e = parse_trace("0\tT1\t0\nnope\tT1\t2\n").unwrap_err();
        assert!(e.contains("line 2") && e.contains("timestamp_ns"), "{e}");
        let e = parse_trace("0\tT1\tx\n").unwrap_err();
        assert!(e.contains("file_id"), "{e}");
        let e = parse_trace("0\t \t1\n").unwrap_err();
        assert!(e.contains("empty tape"), "{e}");
    }

    #[test]
    fn streaming_reader_matches_parse_trace() {
        // The parity the streaming pipeline rests on: same records, same
        // skip accounting, same errors as the eager parser, on the same
        // bytes.
        let text = "# comment line\n\
                    \n\
                    0\tTAPE001\t3\n\
                    \t\n\
                    1500000000\tTAPE002\t0\n\
                    # trailing comment\n\
                    1500000000\tTAPE001\t17\n";
        let eager = parse_trace(text).expect("valid trace");
        let mut reader = TraceReader::new(text.as_bytes());
        let streamed: Vec<TraceRecord> =
            reader.by_ref().collect::<Result<_, _>>().expect("valid trace");
        assert_eq!(streamed, eager);
        assert_eq!(reader.skipped(), 4, "2 comments + 2 blank-ish lines");
        assert_eq!(reader.line_no(), 7, "every line was visited");

        // Error parity, byte for byte, and the done-latch after an error.
        let bad = "0\tT1\t0\nnope\tT1\t2\n10\tT1\t1\n";
        let eager_err = parse_trace(bad).unwrap_err();
        let mut reader = TraceReader::new(bad.as_bytes());
        assert_eq!(reader.next(), Some(Ok(TraceRecord {
            timestamp_ns: 0,
            tape: "T1".into(),
            file_id: 0,
        })));
        assert_eq!(reader.next(), Some(Err(eager_err)));
        assert_eq!(reader.next(), None, "the reader latches done after an error");
        assert_eq!(reader.next(), None);
    }

    #[test]
    fn streaming_reader_handles_truncated_final_line() {
        // No trailing newline: the last record must still come through.
        let text = "0\tT1\t1\n5\tT2\t2";
        let records: Vec<TraceRecord> =
            TraceReader::new(text.as_bytes()).collect::<Result<_, _>>().unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(records[1], TraceRecord { timestamp_ns: 5, tape: "T2".into(), file_id: 2 });
        // A truncated *malformed* final line still errors with its number.
        let e: Result<Vec<TraceRecord>, String> =
            TraceReader::new("0\tT1\t1\n5\tT2".as_bytes()).collect();
        assert!(e.unwrap_err().contains("line 2"), "truncated line keeps its number");
    }

    #[test]
    fn streaming_reader_tolerates_crlf() {
        let text = "# comment\r\n0\tT1\t1\r\n5\tT2\t2\r\n";
        let mut reader = TraceReader::new(text.as_bytes());
        let records: Vec<TraceRecord> =
            reader.by_ref().collect::<Result<_, _>>().unwrap();
        assert_eq!(records, parse_trace(text).unwrap(), "CRLF parity with the eager path");
        assert_eq!(records.len(), 2);
        assert_eq!(records[1].file_id, 2);
        assert_eq!(reader.skipped(), 1);
    }

    #[test]
    fn read_trace_file_streams_and_round_trips() {
        let dir = std::env::temp_dir().join("tapesched-rawlog-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream-roundtrip.trace");
        let records = vec![
            TraceRecord { timestamp_ns: 0, tape: "A".into(), file_id: 1 },
            TraceRecord { timestamp_ns: 7, tape: "B".into(), file_id: 0 },
        ];
        std::fs::write(&path, trace_to_string(&records)).unwrap();
        assert_eq!(read_trace_file(&path).unwrap(), records);
        let streamed: Vec<TraceRecord> =
            open_trace_file(&path).unwrap().collect::<Result<_, _>>().unwrap();
        assert_eq!(streamed, records);
        let missing = read_trace_file(&dir.join("nope.trace")).unwrap_err();
        assert!(missing.contains("cannot read trace file"), "{missing}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn deterministic_synthesis() {
        let mut cats = BTreeMap::new();
        cats.insert("T".to_string(), synth_catalog("T", 30, 1));
        let a = synth_raw_log(&cats, 100, 3600, 9);
        let b = synth_raw_log(&cats, 100, 3600, 9);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.timestamp, x.segment, x.offset, x.op), (y.timestamp, y.segment, y.offset, y.op));
        }
    }
}
