//! Dataset pipeline: the paper's public IN2P3 dataset format (Appendix C.1),
//! a calibrated synthetic generator reproducing its published statistics,
//! and the statistics harness behind Tables 1–2 and Figures 17–19.
//!
//! The authors' real dataset (figshare) is not reachable offline; the
//! [`generator`] synthesizes 169 tapes matching every published marginal
//! (see DESIGN.md §4). The [`loader`] reads either the authors' files
//! unchanged or the generator's output — they share the same on-disk format.

pub mod generator;
pub mod loader;
pub mod rawlog;
pub mod stats;

pub use generator::{generate_dataset, GeneratorConfig};
pub use loader::{load_dataset, load_tape, write_dataset, LoadError};
pub use rawlog::{
    filter_raw_log, open_trace_file, parse_trace, parse_trace_line, read_trace_file,
    synth_catalog, synth_raw_log, trace_to_string, FilterStats, LogLine, OpKind, TraceReader,
    TraceRecord,
};
pub use stats::{dataset_stats, DatasetStats, ScatterPoint};

use crate::model::{Instance, InstanceError, Tape};

/// One tape with its read-request multiset — a single LTSP instance modulo
/// the choice of the U-turn penalty.
#[derive(Debug, Clone)]
pub struct TapeData {
    pub tape: Tape,
    /// `(file index on tape, request multiplicity)`, 0-based, sorted.
    pub requests: Vec<(usize, u64)>,
}

impl TapeData {
    /// Compact this tape into an LTSP [`Instance`] with penalty `u`.
    pub fn instance(&self, u: u64) -> Result<Instance, InstanceError> {
        Instance::from_tape(&self.tape, &self.requests, u)
    }

    /// Number of distinct requested files `n_req`.
    pub fn n_req(&self) -> usize {
        self.requests.len()
    }

    /// Total number of user requests `n`.
    pub fn n_total(&self) -> u64 {
        self.requests.iter().map(|&(_, x)| x).sum()
    }
}

/// The full dataset: one [`TapeData`] per tape, i.e. 169 LTSP instances.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub tapes: Vec<TapeData>,
}

impl Dataset {
    /// Average file ("segment") size across all tapes of the dataset —
    /// the paper derives its non-zero U values from this quantity
    /// (`U ∈ {0, avg/2, avg}`, §5.2 and Appendix C.2).
    pub fn avg_segment_size(&self) -> u64 {
        let (mut len, mut nf) = (0u128, 0u128);
        for t in &self.tapes {
            len += t.tape.len() as u128;
            nf += t.tape.n_files() as u128;
        }
        if nf == 0 {
            0
        } else {
            (len / nf) as u64
        }
    }

    /// The paper's three U-turn penalty scenarios: `[0, avg/2, avg]`.
    pub fn paper_u_values(&self) -> [u64; 3] {
        let avg = self.avg_segment_size();
        [0, avg / 2, avg]
    }

    /// Total number of files stored across all tapes.
    pub fn total_files(&self) -> usize {
        self.tapes.iter().map(|t| t.tape.n_files()).sum()
    }

    /// Total number of distinct requested files across all tapes.
    pub fn total_unique_requests(&self) -> usize {
        self.tapes.iter().map(|t| t.n_req()).sum()
    }

    /// Total number of user requests across all tapes.
    pub fn total_user_requests(&self) -> u64 {
        self.tapes.iter().map(|t| t.n_total()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::FileExtent;

    fn tiny() -> Dataset {
        let t1 = Tape {
            name: "TAPE001".into(),
            files: vec![
                FileExtent { left: 0, size: 10 },
                FileExtent { left: 10, size: 30 },
            ],
        };
        let t2 = Tape {
            name: "TAPE002".into(),
            files: vec![FileExtent { left: 0, size: 20 }],
        };
        Dataset {
            tapes: vec![
                TapeData { tape: t1, requests: vec![(0, 2), (1, 1)] },
                TapeData { tape: t2, requests: vec![(0, 5)] },
            ],
        }
    }

    #[test]
    fn aggregate_counters() {
        let d = tiny();
        assert_eq!(d.total_files(), 3);
        assert_eq!(d.total_unique_requests(), 3);
        assert_eq!(d.total_user_requests(), 8);
        // (40 + 20) / 3 = 20
        assert_eq!(d.avg_segment_size(), 20);
        assert_eq!(d.paper_u_values(), [0, 10, 20]);
    }

    #[test]
    fn tape_data_to_instance() {
        let d = tiny();
        let inst = d.tapes[0].instance(7).unwrap();
        assert_eq!(inst.k(), 2);
        assert_eq!(inst.u(), 7);
        assert_eq!(inst.n(), 3);
        assert_eq!(d.tapes[0].n_req(), 2);
        assert_eq!(d.tapes[0].n_total(), 3);
    }

    #[test]
    fn empty_dataset_is_safe() {
        let d = Dataset::default();
        assert_eq!(d.avg_segment_size(), 0);
        assert_eq!(d.paper_u_values(), [0, 0, 0]);
    }
}
