//! Dataset statistics: Tables 1–2 and the Figure 17/18/19 scatter data of
//! Appendix C.1.

use super::Dataset;
use crate::util::stats::{summarize, Summary};

/// One point of the Fig. 17/18/19 scatters: per-tape characteristics.
#[derive(Debug, Clone, Copy)]
pub struct ScatterPoint {
    /// Tape index (1-based, matches TAPEXXX naming).
    pub tape: usize,
    /// `n_f` — number of files on the tape (Fig. 17 y-axis).
    pub n_f: usize,
    /// `n_req` — unique requested files (Fig. 17 x-axis, Fig. 18 y-axis).
    pub n_req: usize,
    /// `n` — total user requests (Fig. 18 x-axis).
    pub n: u64,
    /// Mean file size in GB (Fig. 19 x-axis).
    pub mean_size_gb: f64,
    /// File-size coefficient of variation, % (Fig. 19 y-axis).
    pub cv_pct: f64,
}

/// Aggregated dataset statistics (Tables 1–2 + document totals).
#[derive(Debug, Clone)]
pub struct DatasetStats {
    /// Table 1: per-tape `n_f`, `n_req`, `n` summaries.
    pub n_f: Summary,
    pub n_req: Summary,
    pub n: Summary,
    /// Table 2: per-tape mean file size (GB) and size CV (%) summaries.
    pub mean_size_gb: Summary,
    pub cv_pct: Summary,
    /// Document totals: tapes, files, unique requested files, user requests.
    pub n_tapes: usize,
    pub total_files: usize,
    pub total_unique: usize,
    pub total_requests: u64,
    /// Average segment size in bytes (the U-value base of §5.2).
    pub avg_segment_size: u64,
    /// Per-tape scatter points (Figs 17–19).
    pub points: Vec<ScatterPoint>,
}

/// Compute all statistics for a dataset.
pub fn dataset_stats(ds: &Dataset) -> DatasetStats {
    const GB: f64 = 1e9;
    let points: Vec<ScatterPoint> = ds
        .tapes
        .iter()
        .enumerate()
        .map(|(i, t)| ScatterPoint {
            tape: i + 1,
            n_f: t.tape.n_files(),
            n_req: t.n_req(),
            n: t.n_total(),
            mean_size_gb: t.tape.mean_file_size() / GB,
            cv_pct: t.tape.file_size_cv() * 100.0,
        })
        .collect();

    let col = |f: &dyn Fn(&ScatterPoint) -> f64| -> Vec<f64> {
        points.iter().map(f).collect()
    };
    DatasetStats {
        n_f: summarize(&col(&|p| p.n_f as f64)),
        n_req: summarize(&col(&|p| p.n_req as f64)),
        n: summarize(&col(&|p| p.n as f64)),
        mean_size_gb: summarize(&col(&|p| p.mean_size_gb)),
        cv_pct: summarize(&col(&|p| p.cv_pct)),
        n_tapes: ds.tapes.len(),
        total_files: ds.total_files(),
        total_unique: ds.total_unique_requests(),
        total_requests: ds.total_user_requests(),
        avg_segment_size: ds.avg_segment_size(),
        points,
    }
}

impl DatasetStats {
    /// Render Tables 1 and 2 in the paper's layout.
    pub fn render_tables(&self) -> String {
        let int = |v: f64| format!("{}", v.round() as i64);
        let f1 = |v: f64| format!("{v:.1}");
        let mut out = String::new();
        out.push_str("Table 1 — instance characteristics (per tape)\n");
        out.push_str("|         |  Tape size | #Requested |  #Requests |\n");
        out.push_str(&format!(
            "| Maximum | {:>10} | {:>10} | {:>10} |\n",
            int(self.n_f.max), int(self.n_req.max), int(self.n.max)
        ));
        out.push_str(&format!(
            "| Minimum | {:>10} | {:>10} | {:>10} |\n",
            int(self.n_f.min), int(self.n_req.min), int(self.n.min)
        ));
        out.push_str(&format!(
            "| Median  | {:>10} | {:>10} | {:>10} |\n",
            int(self.n_f.median), int(self.n_req.median), int(self.n.median)
        ));
        out.push_str(&format!(
            "| Mean    | {:>10} | {:>10} | {:>10} |\n",
            int(self.n_f.mean), int(self.n_req.mean), int(self.n.mean)
        ));
        out.push('\n');
        out.push_str("Table 2 — file sizes (per tape)\n");
        out.push_str("|         | Avg size (GB) | Size CV (%) |\n");
        let accessors: [(&str, fn(&Summary) -> f64); 4] = [
            ("Maximum", |s| s.max),
            ("Minimum", |s| s.min),
            ("Median", |s| s.median),
            ("Mean", |s| s.mean),
        ];
        for (name, acc) in accessors {
            out.push_str(&format!(
                "| {name:<7} | {:>13} | {:>11} |\n",
                f1(acc(&self.mean_size_gb)),
                f1(acc(&self.cv_pct))
            ));
        }
        out.push('\n');
        out.push_str(&format!(
            "Totals: {} tapes, {} files, {} unique requested files, {} user requests\n",
            self.n_tapes, self.total_files, self.total_unique, self.total_requests
        ));
        out.push_str(&format!(
            "Average segment size: {} bytes (paper U values: 0, {}, {})\n",
            self.avg_segment_size,
            self.avg_segment_size / 2,
            self.avg_segment_size
        ));
        out
    }

    /// CSV for Figure 17 (`n_req` vs `n_f`), 18 (`n` vs `n_req`) and 19
    /// (mean size vs CV) — one file with all per-tape columns.
    pub fn scatter_csv(&self) -> String {
        let mut out = String::from("tape,n_f,n_req,n,mean_size_gb,cv_pct\n");
        for p in &self.points {
            out.push_str(&format!(
                "{},{},{},{},{:.3},{:.1}\n",
                p.tape, p.n_f, p.n_req, p.n, p.mean_size_gb, p.cv_pct
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{generate_dataset, GeneratorConfig};

    #[test]
    fn stats_on_generated_dataset() {
        let ds = generate_dataset(&GeneratorConfig { n_tapes: 20, ..Default::default() });
        let st = dataset_stats(&ds);
        assert_eq!(st.n_tapes, 20);
        assert_eq!(st.points.len(), 20);
        assert!(st.n_f.min >= 111.0 && st.n_f.max <= 4142.0);
        assert!(st.total_files > 0);
        // Mean size ≈ 20 TB / n_f for every tape (full tapes).
        for p in &st.points {
            let expect = 20_000.0 / p.n_f as f64;
            assert!((p.mean_size_gb - expect).abs() / expect < 1e-6);
        }
    }

    #[test]
    fn tables_render_plausibly() {
        let ds = generate_dataset(&GeneratorConfig { n_tapes: 8, ..Default::default() });
        let txt = dataset_stats(&ds).render_tables();
        assert!(txt.contains("Table 1"));
        assert!(txt.contains("Table 2"));
        assert!(txt.contains("8 tapes"));
    }

    #[test]
    fn scatter_csv_has_header_and_rows() {
        let ds = generate_dataset(&GeneratorConfig { n_tapes: 3, ..Default::default() });
        let csv = dataset_stats(&ds).scatter_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("tape,n_f,"));
    }
}
