//! Synthetic IN2P3-calibrated dataset generator.
//!
//! The paper's dataset (figshare) is not reachable offline; this generator
//! reproduces **every published marginal** of Appendix C.1 so that the
//! evaluation preserves the structure the paper reports:
//!
//! - Table 1 — per-tape file counts `n_f` (min 111 / median 490 / mean 709 /
//!   max 4142), distinct requested files `n_req` (31/148/170/852) and total
//!   user requests `n` (1182/2669/3640/15477);
//! - Table 2 — per-tape mean file size 4.9–167 GB (median 40, mean 50) and
//!   file-size coefficient of variation 6–379 % (median 56 %, mean 94 %);
//! - totals — 169 tapes, ≈119 k files, ≈28.8 k unique requested files,
//!   ≈615 k user requests.
//!
//! Mean file size falls out of `n_f` automatically: tapes are (nearly) full
//! 20 TB cartridges, so mean size ≈ 20 TB / n_f — exactly the relation the
//! paper notes ("this information is slightly redundant as usually
//! proportional to 1/n_f"). `n_f`, `n_req`, `n` and the size CV are drawn
//! from log-normals fitted to the published median/mean pairs and clipped
//! to the published min/max; one tape is pinned to each published extreme
//! so the table reproduces exactly.

use super::{Dataset, TapeData};
use crate::model::Tape;
use crate::util::rng::Rng;

/// Tape capacity of the IN2P3 library's cartridges (20 TB Jaguar E).
pub const TAPE_CAPACITY: u64 = 20_000_000_000_000;

/// Calibration knobs. Defaults reproduce Appendix C.1.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    pub n_tapes: usize,
    pub seed: u64,
    /// `n_f` marginal: (min, median, mean, max) — Table 1 column 1.
    pub nf: (u64, f64, f64, u64),
    /// `n_req` marginal — Table 1 column 2.
    pub nreq: (u64, f64, f64, u64),
    /// `n` marginal — Table 1 column 3.
    pub n: (u64, f64, f64, u64),
    /// File-size CV marginal (fractions) — Table 2 column 2.
    pub cv: (f64, f64, f64, f64),
    /// Tape capacity in bytes.
    pub capacity: u64,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        GeneratorConfig {
            n_tapes: 169,
            seed: 0x12_B3_2021, // "IN2P3 2021"
            nf: (111, 490.0, 709.0, 4142),
            nreq: (31, 148.0, 170.0, 852),
            n: (1182, 2669.0, 3640.0, 15477),
            cv: (0.06, 0.56, 0.94, 3.79),
            capacity: TAPE_CAPACITY,
        }
    }
}

/// Draw from a log-normal fitted to `(median, mean)` and clipped to
/// `[min, max]`: `exp(μ) = median`, `exp(μ + σ²/2) = mean` ⇒
/// `σ = sqrt(2·ln(mean/median))`.
fn lognormal_fit(rng: &mut Rng, median: f64, mean: f64, lo: f64, hi: f64) -> f64 {
    let mu = median.ln();
    let sigma = (2.0 * (mean / median).ln()).max(0.0).sqrt();
    rng.lognormal(mu, sigma).clamp(lo, hi)
}

/// Generate file sizes with a target coefficient of variation, scaled so
/// they exactly fill `capacity`. Log-normal sizes: `CV² = exp(σ²) − 1`.
fn gen_sizes(rng: &mut Rng, n_f: usize, target_cv: f64, capacity: u64) -> Vec<u64> {
    let sigma = (1.0 + target_cv * target_cv).ln().sqrt();
    let raw: Vec<f64> = (0..n_f).map(|_| rng.lognormal(0.0, sigma)).collect();
    let total: f64 = raw.iter().sum();
    let scale = capacity as f64 / total;
    let mut sizes: Vec<u64> = raw.iter().map(|&r| ((r * scale) as u64).max(1)).collect();
    // Fix rounding drift on the last file so the tape is exactly full.
    let sum: u64 = sizes.iter().sum();
    let last = sizes.len() - 1;
    if sum < capacity {
        sizes[last] += capacity - sum;
    } else if sum > capacity {
        let over = sum - capacity;
        sizes[last] = sizes[last].saturating_sub(over).max(1);
    }
    sizes
}

/// Distribute `n` requests over `n_req` files with a heavy-tailed
/// multiplicity profile (a few very hot aggregates, many singletons) —
/// matching the paper's observation that its dataset, unlike [8]'s, has
/// a broad multiplicity spectrum.
fn gen_multiplicities(rng: &mut Rng, n_req: usize, n: u64) -> Vec<u64> {
    debug_assert!(n >= n_req as u64);
    let mut x = vec![1u64; n_req];
    let mut rest = n - n_req as u64;
    // Zipf-ish weights over a random permutation of the files.
    let mut order: Vec<usize> = (0..n_req).collect();
    rng.shuffle(&mut order);
    let weights: Vec<f64> = (0..n_req).map(|i| 1.0 / (i as f64 + 1.0)).collect();
    let wsum: f64 = weights.iter().sum();
    for (rank, &f) in order.iter().enumerate() {
        if rest == 0 {
            break;
        }
        let share = ((weights[rank] / wsum) * (n - n_req as u64) as f64).round() as u64;
        let add = share.min(rest);
        x[f] += add;
        rest -= add;
    }
    // Rounding residue → hottest file.
    x[order[0]] += rest;
    x
}

/// Generate one tape. `pins` optionally force `(n_f, n_req, n, cv)` to the
/// published extremes.
fn gen_tape(
    rng: &mut Rng,
    cfg: &GeneratorConfig,
    name: String,
    pins: Option<(u64, u64, u64, f64)>,
) -> TapeData {
    let (nf, nreq, n, cv) = match pins {
        Some(p) => p,
        None => {
            let nf = lognormal_fit(rng, cfg.nf.1, cfg.nf.2, cfg.nf.0 as f64, cfg.nf.3 as f64)
                .round() as u64;
            let nreq = lognormal_fit(
                rng,
                cfg.nreq.1,
                cfg.nreq.2,
                cfg.nreq.0 as f64,
                cfg.nreq.3 as f64,
            )
            .round() as u64;
            let nreq = nreq.min(nf); // cannot request more distinct files than exist
            let n = lognormal_fit(rng, cfg.n.1, cfg.n.2, cfg.n.0 as f64, cfg.n.3 as f64)
                .round() as u64;
            let n = n.max(nreq); // each requested file has ≥ 1 request
            let cv = lognormal_fit(rng, cfg.cv.1, cfg.cv.2, cfg.cv.0, cfg.cv.3);
            (nf, nreq, n, cv)
        }
    };

    let sizes = gen_sizes(rng, nf as usize, cv, cfg.capacity);
    let tape = Tape::from_sizes(name, &sizes);

    // Requested files: uniform distinct sample (requests arrive for files
    // written over a long period, with no positional preference).
    let mut idx: Vec<usize> = (0..nf as usize).collect();
    rng.shuffle(&mut idx);
    let mut chosen: Vec<usize> = idx[..nreq as usize].to_vec();
    chosen.sort();
    let mult = gen_multiplicities(rng, nreq as usize, n);
    let requests = chosen.into_iter().zip(mult).collect();

    TapeData { tape, requests }
}

/// Generate the full 169-tape dataset (deterministic in `cfg.seed`).
pub fn generate_dataset(cfg: &GeneratorConfig) -> Dataset {
    let mut rng = Rng::new(cfg.seed);
    let mut tapes = Vec::with_capacity(cfg.n_tapes);
    for i in 0..cfg.n_tapes {
        let name = format!("TAPE{:03}", i + 1);
        // Pin the four Table 1/2 extremes onto the first four tapes so the
        // published min/max reproduce exactly; the rest is sampled.
        let pins = match i {
            0 => Some((cfg.nf.0, cfg.nreq.0, cfg.n.0, cfg.cv.3)), // smallest tape, max CV
            1 => Some((cfg.nf.3, cfg.nreq.3, cfg.n.3, cfg.cv.0)), // largest tape, min CV
            _ => None,
        };
        let mut child = rng.fork(i as u64);
        tapes.push(gen_tape(&mut child, cfg, name, pins));
    }
    Dataset { tapes }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let cfg = GeneratorConfig { n_tapes: 5, ..Default::default() };
        let a = generate_dataset(&cfg);
        let b = generate_dataset(&cfg);
        for (x, y) in a.tapes.iter().zip(&b.tapes) {
            assert_eq!(x.tape.files, y.tape.files);
            assert_eq!(x.requests, y.requests);
        }
    }

    #[test]
    fn tapes_are_valid_instances() {
        let cfg = GeneratorConfig { n_tapes: 12, ..Default::default() };
        let ds = generate_dataset(&cfg);
        for t in &ds.tapes {
            let inst = t.instance(0).expect("valid instance");
            assert_eq!(inst.k(), t.n_req());
            assert_eq!(inst.n(), t.n_total());
            assert_eq!(inst.tape_len(), TAPE_CAPACITY);
        }
    }

    #[test]
    fn tapes_are_exactly_full() {
        let cfg = GeneratorConfig { n_tapes: 8, ..Default::default() };
        for t in &generate_dataset(&cfg).tapes {
            assert_eq!(t.tape.len(), TAPE_CAPACITY, "{}", t.tape.name);
        }
    }

    #[test]
    fn pinned_extremes_match_table1() {
        let ds = generate_dataset(&GeneratorConfig { n_tapes: 4, ..Default::default() });
        assert_eq!(ds.tapes[0].tape.n_files() as u64, 111);
        assert_eq!(ds.tapes[0].n_req() as u64, 31);
        assert_eq!(ds.tapes[0].n_total(), 1182);
        assert_eq!(ds.tapes[1].tape.n_files() as u64, 4142);
        assert_eq!(ds.tapes[1].n_req() as u64, 852);
        assert_eq!(ds.tapes[1].n_total(), 15477);
    }

    #[test]
    fn multiplicities_sum_and_floor() {
        let mut rng = Rng::new(7);
        for (nreq, n) in [(5usize, 100u64), (31, 1182), (148, 2669), (10, 10)] {
            let x = gen_multiplicities(&mut rng, nreq, n);
            assert_eq!(x.len(), nreq);
            assert_eq!(x.iter().sum::<u64>(), n);
            assert!(x.iter().all(|&v| v >= 1));
        }
    }

    #[test]
    fn size_cv_tracks_target() {
        let mut rng = Rng::new(11);
        for target in [0.1f64, 0.6, 1.5] {
            let sizes = gen_sizes(&mut rng, 2_000, target, TAPE_CAPACITY);
            let t = Tape::from_sizes("T", &sizes);
            let cv = t.file_size_cv();
            assert!(
                (cv - target).abs() / target < 0.25,
                "target {target}, got {cv}"
            );
        }
    }

    #[test]
    fn full_dataset_marginals_land_near_table1() {
        // Sampled medians/means drift a little; require ±20 % of Table 1.
        let ds = generate_dataset(&GeneratorConfig::default());
        assert_eq!(ds.tapes.len(), 169);
        let nf: Vec<f64> = ds.tapes.iter().map(|t| t.tape.n_files() as f64).collect();
        let nreq: Vec<f64> = ds.tapes.iter().map(|t| t.n_req() as f64).collect();
        let n: Vec<f64> = ds.tapes.iter().map(|t| t.n_total() as f64).collect();
        let s_nf = crate::util::stats::summarize(&nf);
        let s_nreq = crate::util::stats::summarize(&nreq);
        let s_n = crate::util::stats::summarize(&n);
        let close = |got: f64, want: f64| (got - want).abs() / want < 0.20;
        assert!(close(s_nf.median, 490.0), "nf median {}", s_nf.median);
        assert!(close(s_nf.mean, 709.0), "nf mean {}", s_nf.mean);
        assert!(close(s_nreq.median, 148.0), "nreq median {}", s_nreq.median);
        assert!(close(s_nreq.mean, 170.0), "nreq mean {}", s_nreq.mean);
        assert!(close(s_n.median, 2669.0), "n median {}", s_n.median);
        assert!(close(s_n.mean, 3640.0), "n mean {}", s_n.mean);
    }
}
