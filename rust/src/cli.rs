//! Minimal argument parser (the offline registry has no clap).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments, with typed accessors and an unknown-flag check.

use std::collections::HashMap;

/// Parsed command line: positionals + flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    seen: Vec<String>,
}

impl Args {
    /// Parse raw arguments (exclusive of argv[0] and the subcommand).
    pub fn parse(raw: &[String]) -> Args {
        let mut args = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(flag) = a.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    args.insert(k, v);
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    args.insert(flag, &raw[i + 1]);
                    i += 1;
                } else {
                    args.insert(flag, "true");
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        args
    }

    fn insert(&mut self, k: &str, v: &str) {
        self.flags.insert(k.to_string(), v.to_string());
        self.seen.push(k.to_string());
    }

    /// String flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String flag with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed flag with default; exits with a message on a parse failure.
    pub fn get_parsed_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("error: invalid value for --{key}: {v}");
                std::process::exit(2);
            }),
        }
    }

    /// Boolean flag (`--x` or `--x=true`).
    pub fn has(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Constrained-choice flag: the value (or `default` when absent) must
    /// be one of `allowed`, case-insensitively; exits with a message
    /// otherwise. Returns the matched value in lowercase.
    pub fn get_choice_or(&self, key: &str, allowed: &[&str], default: &str) -> String {
        let v = self.get_or(key, default).to_ascii_lowercase();
        if allowed.iter().any(|a| a.eq_ignore_ascii_case(&v)) {
            v
        } else {
            eprintln!(
                "error: invalid value for --{key}: {v} (expected one of: {})",
                allowed.join("|")
            );
            std::process::exit(2);
        }
    }

    /// Abort on flags not in `known` (catches typos).
    pub fn reject_unknown(&self, known: &[&str]) {
        for k in &self.seen {
            if !known.contains(&k.as_str()) {
                eprintln!("error: unknown flag --{k} (known: {})", known.join(", "));
                std::process::exit(2);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["pos1", "--x", "5", "--flag", "--y=hello", "pos2"]);
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
        assert_eq!(a.get("x"), Some("5"));
        assert_eq!(a.get_parsed_or("x", 0u64), 5);
        assert!(a.has("flag"));
        assert_eq!(a.get_or("y", ""), "hello");
        assert_eq!(a.get_or("absent", "dflt"), "dflt");
        assert_eq!(a.get_parsed_or("absent", 7i32), 7);
    }

    #[test]
    fn choice_flags() {
        let a = parse(&["--backend", "XLA"]);
        assert_eq!(a.get_choice_or("backend", &["dense", "xla"], "dense"), "xla");
        // Absent flag: the default is returned (and must itself be valid).
        assert_eq!(a.get_choice_or("mode", &["fast", "slow"], "slow"), "slow");
    }

    #[test]
    fn boolean_styles() {
        let a = parse(&["--a", "--b=true", "--c=1", "--d=no"]);
        assert!(a.has("a") && a.has("b") && a.has("c"));
        assert!(!a.has("d") && !a.has("zzz"));
    }
}
