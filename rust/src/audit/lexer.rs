//! A line-aware Rust tokenizer — just enough lexing for the audit rules.
//!
//! This is deliberately **not** a parser: the rules in [`super::rules`]
//! work on token sequences (`Instant :: now`, `. unwrap (`), so all the
//! lexer must get right is what is *code* versus what is a string, a char
//! literal, or a comment — the classic places a naive `grep` lint goes
//! wrong (`"// audit"` inside a string, `{:?}` inside a doc comment,
//! `'a'` versus the lifetime `'a`). It handles line comments, nested
//! block comments, string and byte-string literals, raw strings with any
//! number of `#`s, char literals, lifetimes, and raw identifiers, and
//! tags every token with its 1-based source line so findings point at
//! real locations.
//!
//! Line comments are returned separately from the token stream: they are
//! dead weight for every rule except the waiver scanner, which reads
//! `// audit:allow(rule-id) reason` annotations out of them.

/// What kind of lexeme a [`Tok`] is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `unwrap`, `HashMap`).
    Ident,
    /// Operator or delimiter, multi-char ops pre-joined (`::`, `+=`).
    Punct,
    /// String or byte-string literal, raw or not, quotes included.
    Str,
    /// Character literal, quotes included.
    CharLit,
    /// Lifetime (`'a`, `'static`), leading quote included.
    Lifetime,
    /// Numeric literal (approximate: suffixes ride along).
    Num,
}

/// One token: kind, exact text, and the 1-based line it starts on.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// One `//` comment: its line and the text after the slashes.
#[derive(Debug, Clone)]
pub struct LineComment {
    pub line: u32,
    pub text: String,
}

/// The lexer's output: the code tokens plus the line comments.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<LineComment>,
}

/// Multi-character operators the rules care about, longest-match-first so
/// `==` never lexes as two `=`s (the accounting rule tells assignment
/// from comparison by exactly this distinction).
const PUNCT2: [&str; 16] = [
    "::", "==", "!=", "+=", "-=", "*=", "/=", "=>", "->", "..", "&&", "||", "<=", ">=", "<<",
    ">>",
];

/// Tokenize `src`. Never fails: unexpected bytes become single-char
/// punctuation tokens, and unterminated literals run to end of input —
/// an audit must degrade on weird input, not abort.
pub fn tokenize(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b' ' | b'\t' | b'\r' => i += 1,
            b'/' if b.get(i + 1) == Some(&b'/') => {
                let end = line_end(b, i);
                out.comments.push(LineComment {
                    line,
                    text: src[i + 2..end].to_string(),
                });
                i = end;
            }
            b'/' if b.get(i + 1) == Some(&b'*') => {
                // Block comments nest in Rust.
                let mut depth = 1usize;
                let mut j = i + 2;
                while j < b.len() && depth > 0 {
                    if b[j] == b'/' && b.get(j + 1) == Some(&b'*') {
                        depth += 1;
                        j += 2;
                    } else if b[j] == b'*' && b.get(j + 1) == Some(&b'/') {
                        depth -= 1;
                        j += 2;
                    } else {
                        if b[j] == b'\n' {
                            line += 1;
                        }
                        j += 1;
                    }
                }
                i = j;
            }
            b'r' | b'b' if raw_str_hashes(b, i).is_some() => {
                let (open, hashes) = raw_str_hashes(b, i).unwrap_or((i, 0));
                let (end, newlines) = raw_str_end(b, open + 1, hashes);
                out.toks.push(Tok {
                    kind: TokKind::Str,
                    text: src[i..end].to_string(),
                    line,
                });
                line += newlines;
                i = end;
            }
            b'r' if b.get(i + 1) == Some(&b'#')
                && b.get(i + 2).is_some_and(|c| is_ident_start(*c)) =>
            {
                // Raw identifier r#ident: token text keeps only the name.
                let end = ident_end(b, i + 2);
                out.toks.push(Tok {
                    kind: TokKind::Ident,
                    text: src[i + 2..end].to_string(),
                    line,
                });
                i = end;
            }
            b'"' => {
                let (end, newlines) = string_end(b, i + 1);
                out.toks.push(Tok {
                    kind: TokKind::Str,
                    text: src[i..end].to_string(),
                    line,
                });
                line += newlines;
                i = end;
            }
            b'b' if b.get(i + 1) == Some(&b'"') => {
                let (end, newlines) = string_end(b, i + 2);
                out.toks.push(Tok {
                    kind: TokKind::Str,
                    text: src[i..end].to_string(),
                    line,
                });
                line += newlines;
                i = end;
            }
            b'\'' => {
                let (tok, end) = char_or_lifetime(src, b, i, line);
                out.toks.push(tok);
                i = end;
            }
            _ if c.is_ascii_digit() => {
                let mut end = ident_end(b, i);
                // Fractional part: `.` followed by a digit (so `0..n`
                // stays a range, not a malformed float).
                if b.get(end) == Some(&b'.') && b.get(end + 1).is_some_and(|c| c.is_ascii_digit())
                {
                    end = ident_end(b, end + 1);
                }
                out.toks.push(Tok {
                    kind: TokKind::Num,
                    text: src[i..end].to_string(),
                    line,
                });
                i = end;
            }
            _ if is_ident_start(c) => {
                let end = ident_end(b, i);
                out.toks.push(Tok {
                    kind: TokKind::Ident,
                    text: src[i..end].to_string(),
                    line,
                });
                i = end;
            }
            _ => {
                let two = PUNCT2
                    .iter()
                    .find(|p| src[i..].starts_with(*p))
                    .copied();
                let text = match two {
                    Some(p) => p.to_string(),
                    None => (c as char).to_string(),
                };
                let len = text.len();
                out.toks.push(Tok {
                    kind: TokKind::Punct,
                    text,
                    line,
                });
                i += len;
            }
        }
    }
    out
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_continue(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

fn ident_end(b: &[u8], start: usize) -> usize {
    let mut j = start;
    while j < b.len() && is_ident_continue(b[j]) {
        j += 1;
    }
    j
}

fn line_end(b: &[u8], start: usize) -> usize {
    let mut j = start;
    while j < b.len() && b[j] != b'\n' {
        j += 1;
    }
    j
}

/// If `i` starts a raw (byte) string — `r"`, `r#"`, `br##"` … — return
/// the index of the opening quote and the hash count.
fn raw_str_hashes(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b.get(j) == Some(&b'b') {
        j += 1;
    }
    if b.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while b.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&b'"') {
        Some((j, hashes))
    } else {
        None
    }
}

/// Scan a raw string body from just past the opening quote to just past
/// the closing `"###…`; returns (end index, newlines crossed).
fn raw_str_end(b: &[u8], start: usize, hashes: usize) -> (usize, u32) {
    let mut j = start;
    let mut newlines = 0u32;
    while j < b.len() {
        if b[j] == b'"' && b[j + 1..].iter().take(hashes).filter(|c| **c == b'#').count() == hashes
        {
            return (j + 1 + hashes, newlines);
        }
        if b[j] == b'\n' {
            newlines += 1;
        }
        j += 1;
    }
    (b.len(), newlines)
}

/// Scan a normal string body (escapes honored) from just past the opening
/// quote to just past the closing quote; returns (end, newlines crossed).
fn string_end(b: &[u8], start: usize) -> (usize, u32) {
    let mut j = start;
    let mut newlines = 0u32;
    while j < b.len() {
        match b[j] {
            b'\\' => j += 2,
            b'"' => return (j + 1, newlines),
            b'\n' => {
                newlines += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    (b.len(), newlines)
}

/// Disambiguate `'a'` (char literal) from `'a` (lifetime) at index `i`
/// (the quote). Escapes (`'\n'`) and punctuation chars (`'('`) are
/// always char literals.
fn char_or_lifetime(src: &str, b: &[u8], i: usize, line: u32) -> (Tok, usize) {
    if b.get(i + 1).is_some_and(|c| is_ident_start(*c)) {
        let end = ident_end(b, i + 1);
        if b.get(end) == Some(&b'\'') && end == i + 2 {
            // 'x' — one identifier char then a closing quote.
            return (
                Tok { kind: TokKind::CharLit, text: src[i..end + 1].to_string(), line },
                end + 1,
            );
        }
        return (
            Tok { kind: TokKind::Lifetime, text: src[i..end].to_string(), line },
            end,
        );
    }
    // Escaped or punctuation char literal: scan to the closing quote.
    let mut j = i + 1;
    if b.get(j) == Some(&b'\\') {
        j += 2;
    } else if j < b.len() {
        j += 1;
    }
    while j < b.len() && b[j] != b'\'' {
        j += 1;
    }
    let end = (j + 1).min(b.len());
    (
        Tok { kind: TokKind::CharLit, text: src[i..end].to_string(), line },
        end,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        tokenize(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn slashes_inside_strings_are_not_comments() {
        let lexed = tokenize(r#"let url = "http://example.com"; // real comment"#);
        assert_eq!(lexed.comments.len(), 1);
        assert_eq!(lexed.comments[0].text, " real comment");
        let strs: Vec<_> =
            lexed.toks.iter().filter(|t| t.kind == TokKind::Str).collect();
        assert_eq!(strs.len(), 1);
        assert!(strs[0].text.contains("http://example.com"));
    }

    #[test]
    fn block_comments_nest() {
        let lexed = tokenize("a /* outer /* inner */ still comment */ b");
        assert_eq!(idents("a /* outer /* inner */ still comment */ b"), ["a", "b"]);
        assert!(lexed.toks.iter().all(|t| t.text != "inner"));
    }

    #[test]
    fn raw_strings_swallow_quotes_and_hashes() {
        let src = r##"let s = r#"say "hi" // not a comment"#; done();"##;
        let lexed = tokenize(src);
        assert!(lexed.comments.is_empty());
        assert!(idents(src).contains(&"done".to_string()));
        let strs: Vec<_> =
            lexed.toks.iter().filter(|t| t.kind == TokKind::Str).collect();
        assert_eq!(strs.len(), 1);
        assert!(strs[0].text.contains("not a comment"));
    }

    #[test]
    fn char_literals_versus_lifetimes() {
        let lexed = tokenize(r"fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\n'; }");
        let kinds: Vec<_> = lexed
            .toks
            .iter()
            .filter(|t| matches!(t.kind, TokKind::Lifetime | TokKind::CharLit))
            .map(|t| (t.kind, t.text.clone()))
            .collect();
        assert_eq!(
            kinds,
            vec![
                (TokKind::Lifetime, "'a".to_string()),
                (TokKind::Lifetime, "'a".to_string()),
                (TokKind::CharLit, "'x'".to_string()),
                (TokKind::CharLit, r"'\n'".to_string()),
            ]
        );
    }

    #[test]
    fn multi_char_operators_stay_joined() {
        let texts: Vec<String> =
            tokenize("a += b; c == d; e::f()").toks.into_iter().map(|t| t.text).collect();
        assert!(texts.contains(&"+=".to_string()));
        assert!(texts.contains(&"==".to_string()));
        assert!(texts.contains(&"::".to_string()));
        assert!(!texts.contains(&"=".to_string()));
    }

    #[test]
    fn lines_are_tracked_across_literals_and_comments() {
        let src = "a\n/* two\nlines */\nb \"str\nspan\" c\nd";
        let lexed = tokenize(src);
        let line_of = |name: &str| {
            lexed.toks.iter().find(|t| t.text == name).map(|t| t.line)
        };
        assert_eq!(line_of("a"), Some(1));
        assert_eq!(line_of("b"), Some(4));
        assert_eq!(line_of("c"), Some(5));
        assert_eq!(line_of("d"), Some(6));
    }

    #[test]
    fn numbers_do_not_eat_ranges() {
        let texts: Vec<String> =
            tokenize("for i in 0..10 { let x = 1.5; }").toks.into_iter().map(|t| t.text).collect();
        assert!(texts.contains(&"0".to_string()));
        assert!(texts.contains(&"..".to_string()));
        assert!(texts.contains(&"10".to_string()));
        assert!(texts.contains(&"1.5".to_string()));
    }
}
