//! `tapesched audit` — a dependency-free static-analysis pass over this
//! crate's own sources, enforcing the invariants the test suite can only
//! check dynamically:
//!
//! * **determinism zone** (`replay/`, `sched/`, `sim/`, `model/`,
//!   `dataset/`, `cluster/ring.rs`, `coordinator/batcher.rs`): no wall
//!   clocks, no thread identity, no iteration over hash-ordered
//!   containers, no Debug/`to_string` formatting of `f64`.
//! * **wire zone** (`net/wire.rs`): every `TAG_*` constant and `Message`
//!   variant present in both `encode` and `decode`; a diff adding a tag
//!   must also bump `PROTOCOL_VERSION`.
//! * **panic policy** (`net/`, `obs/expo.rs`, `coordinator/service.rs`):
//!   no `.unwrap()` / `.expect(` — serving loops degrade, never abort.
//! * **accounting** (everywhere): files mutating two or more of the
//!   `submitted`/`completed`/`shed` ledger counters must reference the
//!   `debug_assert_drain_invariant` helper.
//!
//! Findings can be suppressed with a waiver comment on (or immediately
//! above) the offending line — `audit:allow(rule-id)` after `//`,
//! followed by a mandatory reason. A waiver that suppresses nothing is
//! itself a finding (`unused-waiver`), so the waiver set cannot rot;
//! `--fix-waivers` deletes stale ones mechanically. `#[cfg(test)]` items
//! are exempt from all rules.

pub mod lexer;
pub mod rules;
pub mod zones;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use lexer::{tokenize, Lexed};
use rules::Finding;

/// All findings for one file, `rel` being `/`-separated and relative to
/// the scan root.
#[derive(Debug)]
pub struct FileReport {
    pub rel: String,
    pub findings: Vec<Finding>,
}

/// A parsed waiver comment.
#[derive(Debug)]
struct Waiver {
    rule: String,
    /// Line the comment itself is on (the line `--fix-waivers` edits).
    comment_line: u32,
    /// Line whose findings it suppresses: its own line for a trailing
    /// comment, the next code line for a standalone one.
    target_line: u32,
}

/// Parse one line-comment body. `Some(Ok(...))` is a well-formed waiver,
/// `Some(Err(line))` is a waiver missing its reason, `None` is an
/// ordinary comment. Doc comments never match: their body starts with
/// `/` or `!`, not with the `audit:allow` keyword.
fn parse_waiver(text: &str) -> Option<Result<String, ()>> {
    let t = text.trim_start();
    let rest = t.strip_prefix("audit:allow(")?;
    let close = rest.find(')')?;
    let rule = &rest[..close];
    if rule.is_empty()
        || !rule.bytes().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == b'-')
    {
        return None;
    }
    let reason = rest[close + 1..].trim();
    if reason.is_empty() {
        return Some(Err(()));
    }
    Some(Ok(rule.to_string()))
}

/// Extract waivers from a lexed file, plus `waiver-syntax` findings for
/// malformed ones (a waiver without a reason is a reviewable lie).
fn collect_waivers(lexed: &Lexed) -> (Vec<Waiver>, Vec<Finding>) {
    let mut code_lines: Vec<u32> = lexed.toks.iter().map(|t| t.line).collect();
    code_lines.sort_unstable();
    code_lines.dedup();
    let mut waivers = Vec::new();
    let mut syntax = Vec::new();
    for c in &lexed.comments {
        match parse_waiver(&c.text) {
            None => {}
            Some(Err(())) => syntax.push(Finding {
                rule: "waiver-syntax",
                line: c.line,
                msg: "waiver needs a reason after the closing paren".to_string(),
                hint: "write the why inline: audit:allow(rule-id) <reason>",
            }),
            Some(Ok(rule)) => {
                let target_line = if code_lines.binary_search(&c.line).is_ok() {
                    c.line
                } else {
                    code_lines
                        .iter()
                        .copied()
                        .find(|l| *l > c.line)
                        .unwrap_or(c.line)
                };
                waivers.push(Waiver { rule, comment_line: c.line, target_line });
            }
        }
    }
    (waivers, syntax)
}

/// Audit one file's source. Applies the zone-appropriate rules, then the
/// waiver pass; returns findings sorted by line. Pure — no filesystem or
/// git access (the diff-aware `wire-proto-bump` rule lives in
/// [`audit_tree`]).
pub fn audit_source(rel: &str, src: &str) -> Vec<Finding> {
    let lexed = tokenize(src);
    let mask = rules::test_mask(&lexed.toks);
    let mut findings = Vec::new();
    if zones::in_det_zone(rel) {
        rules::rule_wallclock(&lexed.toks, &mask, &mut findings);
        rules::rule_hash_iter(&lexed.toks, &mask, &mut findings);
        if !zones::float_fmt_sanctioned(rel) {
            rules::rule_float_fmt(&lexed.toks, &mask, &mut findings);
        }
    }
    if zones::in_panic_zone(rel) {
        rules::rule_panic_path(&lexed.toks, &mask, &mut findings);
    }
    rules::rule_acct(&lexed.toks, &mask, &mut findings);
    if rel == zones::WIRE_FILE {
        rules::rule_wire_parity(&lexed.toks, &mut findings);
    }

    let (waivers, syntax) = collect_waivers(&lexed);
    let mut used = vec![false; waivers.len()];
    let mut kept: Vec<Finding> = Vec::new();
    for f in findings {
        let mut waived = false;
        for (wi, w) in waivers.iter().enumerate() {
            if w.rule == f.rule && w.target_line == f.line {
                used[wi] = true;
                waived = true;
            }
        }
        if !waived {
            kept.push(f);
        }
    }
    for (wi, w) in waivers.iter().enumerate() {
        if !used[wi] {
            kept.push(Finding {
                rule: "unused-waiver",
                line: w.comment_line,
                msg: format!("waiver for `{}` suppresses nothing", w.rule),
                hint: "delete the stale waiver, or run: tapesched audit --fix-waivers",
            });
        }
    }
    kept.extend(syntax);
    kept.sort_by_key(|f| f.line);
    kept
}

/// Recursively collect `*.rs` paths under `dir`, sorted at every level
/// so the report order is byte-stable across platforms.
fn walk(dir: &Path, root: &Path, out: &mut Vec<(String, PathBuf)>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, root, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push((rel, p));
        }
    }
    Ok(())
}

/// Audit every `.rs` file under `root` (normally `rust/src`). Also runs
/// the git-diff `wire-proto-bump` check when a git work tree is
/// reachable from `root`; skipped silently otherwise. Only files with
/// findings appear in the result, sorted by path.
pub fn audit_tree(root: &Path) -> io::Result<Vec<FileReport>> {
    let mut files = Vec::new();
    walk(root, root, &mut files)?;
    let mut reports: Vec<FileReport> = Vec::new();
    for (rel, path) in files {
        let src = fs::read_to_string(&path)?;
        let findings = audit_source(&rel, &src);
        if !findings.is_empty() {
            reports.push(FileReport { rel, findings });
        }
    }
    if let Some(f) = rules::rule_proto_bump(root) {
        match reports.iter_mut().find(|r| r.rel == zones::WIRE_FILE) {
            Some(r) => {
                r.findings.push(f);
                r.findings.sort_by_key(|f| f.line);
            }
            None => reports.push(FileReport {
                rel: zones::WIRE_FILE.to_string(),
                findings: vec![f],
            }),
        }
        reports.sort_by(|a, b| a.rel.cmp(&b.rel));
    }
    Ok(reports)
}

/// Total finding count across a report set.
pub fn total_findings(reports: &[FileReport]) -> usize {
    reports.iter().map(|r| r.findings.len()).sum()
}

/// Render reports as `file:line: [rule] message` lines with an indented
/// fix hint under each, plus a one-line summary.
pub fn render(reports: &[FileReport]) -> String {
    let mut out = String::new();
    for r in reports {
        for f in &r.findings {
            out.push_str(&format!("{}:{}: [{}] {}\n", r.rel, f.line, f.rule, f.msg));
            out.push_str(&format!("    hint: {}\n", f.hint));
        }
    }
    let n = total_findings(reports);
    if n == 0 {
        out.push_str("audit clean: 0 findings\n");
    } else {
        out.push_str(&format!("{n} finding(s)\n"));
    }
    out
}

/// Mechanically remove waivers reported as `unused-waiver`: a standalone
/// waiver line is deleted outright, a trailing waiver is stripped back
/// to the code before its `//`. Returns the number of waivers removed.
pub fn fix_unused_waivers(root: &Path, reports: &[FileReport]) -> io::Result<usize> {
    let mut removed = 0usize;
    for r in reports {
        let mut lines: Vec<u32> = r
            .findings
            .iter()
            .filter(|f| f.rule == "unused-waiver")
            .map(|f| f.line)
            .collect();
        if lines.is_empty() {
            continue;
        }
        lines.sort_unstable();
        lines.dedup();
        let path = root.join(&r.rel);
        let src = fs::read_to_string(&path)?;
        let had_trailing_newline = src.ends_with('\n');
        let mut out_lines: Vec<String> = src.lines().map(|l| l.to_string()).collect();
        // Highest line first so earlier indices stay valid on deletion.
        for line in lines.into_iter().rev() {
            let idx = (line as usize).saturating_sub(1);
            if idx >= out_lines.len() {
                continue;
            }
            let l = &out_lines[idx];
            let keep = match l.find("audit:allow(") {
                Some(pos) => match l[..pos].rfind("//") {
                    Some(slash) => l[..slash].trim_end().to_string(),
                    None => String::new(),
                },
                None => continue,
            };
            if keep.trim().is_empty() {
                out_lines.remove(idx);
            } else {
                out_lines[idx] = keep;
            }
            removed += 1;
        }
        let mut new_src = out_lines.join("\n");
        if had_trailing_newline {
            new_src.push('\n');
        }
        fs::write(&path, new_src)?;
    }
    Ok(removed)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Fixture sources are built by joining lines, so no literal waiver
    // comment appears in this file's own token stream.
    fn waiver(rule: &str, reason: &str) -> String {
        format!("// audit:allow({rule}) {reason}")
    }

    #[test]
    fn trailing_waiver_suppresses_its_own_line() {
        let src = format!(
            "fn f() {{ let t = Instant::now(); {} }}",
            waiver("wallclock", "startup banner only")
        );
        assert!(audit_source("replay/x.rs", &src).is_empty());
    }

    #[test]
    fn standalone_waiver_targets_next_code_line() {
        let src = format!(
            "fn f() {{\n    {}\n    let t = Instant::now();\n}}",
            waiver("wallclock", "diagnostic timer")
        );
        assert!(audit_source("replay/x.rs", &src).is_empty());
    }

    #[test]
    fn wrong_rule_waiver_leaves_finding_and_flags_waiver() {
        let src = format!(
            "fn f() {{ let t = Instant::now(); {} }}",
            waiver("hash-iter", "mismatched rule id")
        );
        let fs = audit_source("replay/x.rs", &src);
        let rules: Vec<_> = fs.iter().map(|f| f.rule).collect();
        assert!(rules.contains(&"wallclock"));
        assert!(rules.contains(&"unused-waiver"));
    }

    #[test]
    fn waiver_without_reason_is_a_syntax_finding() {
        let src = format!("fn f() {{}}\n{}\n", "// audit:allow(wallclock)");
        let fs = audit_source("replay/x.rs", &src);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, "waiver-syntax");
    }

    #[test]
    fn doc_comments_never_parse_as_waivers() {
        let src = "/// audit:allow(wallclock) not a real waiver\nfn f() {}\n";
        assert!(audit_source("replay/x.rs", src).is_empty());
    }

    #[test]
    fn zone_gating_applies_rules_per_path() {
        let src = "fn f(m: &Mutex<u32>) { let t = Instant::now(); m.lock().unwrap(); }";
        let det: Vec<_> =
            audit_source("sched/x.rs", src).iter().map(|f| f.rule).collect::<Vec<_>>();
        assert_eq!(det, vec!["wallclock"]);
        let panic: Vec<_> =
            audit_source("net/x.rs", src).iter().map(|f| f.rule).collect::<Vec<_>>();
        assert_eq!(panic, vec!["panic-path"]);
        assert!(audit_source("cluster/shard.rs", src).is_empty());
    }
}
