//! Module-zone classification: which rules apply to which files.
//!
//! Paths are relative to the scan root (normally `rust/src`), always
//! `/`-separated. The zone map is deliberately a hard-coded table rather
//! than configuration: the zones *are* repo policy, and changing them
//! should be a reviewed diff here, not an env var.

/// Directories whose entire contents are deterministic-by-contract:
/// replay must be bit-reproducible, the DP/solver and simulator feed
/// golden files, and the model/dataset layers feed both.
const DET_DIRS: [&str; 5] = ["replay/", "sched/", "sim/", "model/", "dataset/"];

/// Individual files in otherwise non-deterministic trees that still sit
/// on the deterministic path (the rendezvous ring drives placement; the
/// batcher orders requests into batches).
const DET_FILES: [&str; 2] = ["cluster/ring.rs", "coordinator/batcher.rs"];

/// Serving-path zones where a panic aborts a loop that must degrade
/// instead: the whole wire layer, the exposition endpoint, and the
/// coordinator dispatcher.
const PANIC_DIRS: [&str; 1] = ["net/"];
const PANIC_FILES: [&str; 2] = ["obs/expo.rs", "coordinator/service.rs"];

/// Files sanctioned to format floats for humans: the QoS report writer
/// (its JSON formatter is itself deterministic and golden-tested).
/// `net/wire.rs` needs no entry — it is outside the determinism zone.
const FLOAT_FMT_SANCTIONED: [&str; 1] = ["replay/report.rs"];

/// The one file subject to the encode/decode tag-parity cross-check.
pub const WIRE_FILE: &str = "net/wire.rs";

/// True if `rel` is in the determinism zone (wallclock / hash-iter /
/// float-fmt rules apply).
pub fn in_det_zone(rel: &str) -> bool {
    DET_DIRS.iter().any(|d| rel.starts_with(d)) || DET_FILES.contains(&rel)
}

/// True if `rel` is in the panic-policy zone (`unwrap`/`expect` banned).
pub fn in_panic_zone(rel: &str) -> bool {
    PANIC_DIRS.iter().any(|d| rel.starts_with(d)) || PANIC_FILES.contains(&rel)
}

/// True if `rel` may Debug-format / stringify floats even though it sits
/// in the determinism zone.
pub fn float_fmt_sanctioned(rel: &str) -> bool {
    FLOAT_FMT_SANCTIONED.contains(&rel)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zone_membership() {
        assert!(in_det_zone("replay/engine.rs"));
        assert!(in_det_zone("sched/dp.rs"));
        assert!(in_det_zone("cluster/ring.rs"));
        assert!(in_det_zone("coordinator/batcher.rs"));
        assert!(!in_det_zone("coordinator/service.rs"));
        assert!(!in_det_zone("net/wire.rs"));
        assert!(!in_det_zone("cluster/shard.rs"));

        assert!(in_panic_zone("net/server.rs"));
        assert!(in_panic_zone("net/wire.rs"));
        assert!(in_panic_zone("obs/expo.rs"));
        assert!(in_panic_zone("coordinator/service.rs"));
        assert!(!in_panic_zone("coordinator/batcher.rs"));
        assert!(!in_panic_zone("replay/engine.rs"));

        assert!(float_fmt_sanctioned("replay/report.rs"));
        assert!(!float_fmt_sanctioned("replay/engine.rs"));
    }
}
