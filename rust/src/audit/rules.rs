//! The audit rules. Each rule walks the token stream from
//! [`super::lexer`] and appends [`Finding`]s; none of them parses Rust —
//! they match short token patterns (`Instant :: now`, `. unwrap (`),
//! which is exactly as much syntax as the invariants need.
//!
//! Code under `#[cfg(test)]` is exempt everywhere: tests may use wall
//! clocks, unwraps, and Debug formatting freely. The exemption is a
//! token mask computed once per file by [`test_mask`].

use super::lexer::{Tok, TokKind};

/// One rule violation: rule id, 1-based line, message, and a fix hint.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub line: u32,
    pub msg: String,
    pub hint: &'static str,
}

/// Every rule id the engine can emit, in display order. Fixture tests
/// iterate this to prove each rule has a firing and a non-firing case.
pub const ALL_RULES: [&str; 9] = [
    "wallclock",
    "hash-iter",
    "float-fmt",
    "panic-path",
    "acct-invariant",
    "wire-tag-parity",
    "wire-proto-bump",
    "unused-waiver",
    "waiver-syntax",
];

const HINT_WALLCLOCK: &str =
    "thread time through SimClock / pass timestamps in as data; waive only for diagnostics";
const HINT_HASH_ITER: &str =
    "collect and sort keys first, or switch the container to BTreeMap/Vec";
const HINT_FLOAT_FMT: &str =
    "route floats through replay/report.rs formatters or encode bits via f64::to_bits";
const HINT_PANIC: &str =
    "serving loops must degrade: use util::sync recover helpers or match and shed";
const HINT_ACCT: &str =
    "call coordinator::debug_assert_drain_invariant at the drain/fold point, or waive with why";
const HINT_PARITY: &str = "add the tag to the missing match so encode and decode stay exhaustive";
const HINT_BUMP: &str = "bump PROTOCOL_VERSION in net/wire.rs alongside the new tag";

fn is_open(t: &str) -> bool {
    matches!(t, "(" | "[" | "{")
}

fn is_close(t: &str) -> bool {
    matches!(t, ")" | "]" | "}")
}

/// Mark every token inside a `#[cfg(test)]`-attributed item. The scan
/// finds the attribute, skips to its closing `]`, then swallows the
/// following item up to its matching top-level `}` (or a `;` for
/// declarations without a body).
pub fn test_mask(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        let is_cfg_test = toks[i].text == "#"
            && i + 6 < toks.len()
            && toks[i + 1].text == "["
            && toks[i + 2].text == "cfg"
            && toks[i + 3].text == "("
            && toks[i + 4].text == "test";
        if !is_cfg_test {
            i += 1;
            continue;
        }
        // Closing `]` of the attribute (depth counted from the `cfg`).
        let mut j = i + 2;
        let mut depth = 0i32;
        while j < toks.len() {
            if is_open(&toks[j].text) {
                depth += 1;
            } else if is_close(&toks[j].text) {
                depth -= 1;
                if depth < 0 {
                    break;
                }
            }
            j += 1;
        }
        // Skip the attributed item: to matching `}` or a top-level `;`.
        let mut k = j + 1;
        let mut bdepth = 0i32;
        while k < toks.len() {
            match toks[k].text.as_str() {
                "{" => bdepth += 1,
                "}" => {
                    bdepth -= 1;
                    if bdepth == 0 {
                        break;
                    }
                }
                ";" if bdepth == 0 => break,
                _ => {}
            }
            k += 1;
        }
        let end = (k + 1).min(toks.len());
        for slot in &mut mask[i..end] {
            *slot = true;
        }
        i = k + 1;
    }
    mask
}

/// determinism zone: no wall clocks, no thread identity.
pub fn rule_wallclock(toks: &[Tok], mask: &[bool], findings: &mut Vec<Finding>) {
    for i in 0..toks.len().saturating_sub(2) {
        if mask[i] {
            continue;
        }
        let (a, b, c) = (&toks[i], &toks[i + 1], &toks[i + 2]);
        if b.text == "::" && c.text == "now" && (a.text == "Instant" || a.text == "SystemTime") {
            findings.push(Finding {
                rule: "wallclock",
                line: a.line,
                msg: format!("{}::now() in a deterministic module", a.text),
                hint: HINT_WALLCLOCK,
            });
        }
        if a.text == "thread" && b.text == "::" && c.text == "current" {
            findings.push(Finding {
                rule: "wallclock",
                line: a.line,
                msg: "thread::current() in a deterministic module".to_string(),
                hint: HINT_WALLCLOCK,
            });
        }
    }
}

const HASH_TYPES: [&str; 4] = ["HashMap", "HashSet", "FxHashMap", "FxHashSet"];
const ORDER_METHODS: [&str; 9] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
];

/// Identifiers bound to a hash-ordered container in this file, found via
/// type ascription (`x: FxHashMap<…>`) or construction assignment
/// (`let x = HashMap::new()`).
fn hash_bound_idents(toks: &[Tok], mask: &[bool]) -> Vec<String> {
    let mut bound = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if mask[i] || t.kind != TokKind::Ident || !HASH_TYPES.contains(&t.text.as_str()) {
            continue;
        }
        // ident ':' [& mut path-segments]* HashX
        let mut j = i as isize - 1;
        while j >= 0
            && matches!(
                toks[j as usize].text.as_str(),
                "&" | "mut" | "::" | "collections" | "std" | "util" | "hash" | "crate"
            )
        {
            j -= 1;
        }
        if j >= 1
            && toks[j as usize].text == ":"
            && toks[j as usize - 1].kind == TokKind::Ident
        {
            bound.push(toks[j as usize - 1].text.clone());
            continue;
        }
        // let [mut] ident = HashX::new / ::default / ::with_capacity
        let mut j = i as isize - 1;
        while j >= 0 && matches!(toks[j as usize].text.as_str(), "::" | "collections" | "std") {
            j -= 1;
        }
        if j >= 1 && toks[j as usize].text == "=" && toks[j as usize - 1].kind == TokKind::Ident {
            bound.push(toks[j as usize - 1].text.clone());
        }
    }
    bound
}

/// determinism zone: no iteration over hash-ordered containers.
pub fn rule_hash_iter(toks: &[Tok], mask: &[bool], findings: &mut Vec<Finding>) {
    let bound = hash_bound_idents(toks, mask);
    for (i, t) in toks.iter().enumerate() {
        if mask[i] || t.kind != TokKind::Ident || !bound.contains(&t.text) {
            continue;
        }
        if i + 2 < toks.len()
            && toks[i + 1].text == "."
            && ORDER_METHODS.contains(&toks[i + 2].text.as_str())
        {
            findings.push(Finding {
                rule: "hash-iter",
                line: t.line,
                msg: format!("iteration over hash-ordered `{}` in a deterministic module", t.text),
                hint: HINT_HASH_ITER,
            });
        }
        // for x in [&][mut] ident {
        let mut j = i as isize - 1;
        while j >= 0 && matches!(toks[j as usize].text.as_str(), "&" | "mut") {
            j -= 1;
        }
        if j >= 0
            && toks[j as usize].text == "in"
            && i + 1 < toks.len()
            && toks[i + 1].text == "{"
        {
            findings.push(Finding {
                rule: "hash-iter",
                line: t.line,
                msg: format!("for-loop over hash-ordered `{}` in a deterministic module", t.text),
                hint: HINT_HASH_ITER,
            });
        }
    }
}

const FMT_MACROS: [&str; 7] =
    ["format", "print", "println", "eprint", "eprintln", "write", "writeln"];

/// Identifiers known to be `f64` in this file, via ascription
/// (`x: f64`, `x: &mut f64`) or `let x = … as f64`.
fn float_idents(toks: &[Tok], mask: &[bool]) -> Vec<String> {
    let mut floats = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if mask[i] || t.text != "f64" {
            continue;
        }
        let mut j = i as isize - 1;
        while j >= 0 && matches!(toks[j as usize].text.as_str(), "&" | "mut") {
            j -= 1;
        }
        if j >= 1
            && toks[j as usize].text == ":"
            && toks[j as usize - 1].kind == TokKind::Ident
        {
            floats.push(toks[j as usize - 1].text.clone());
        }
        if i >= 1 && toks[i - 1].text == "as" {
            // Walk back to the statement start; if it is a `let`, bind.
            let mut j = i as isize - 2;
            while j >= 0 && !matches!(toks[j as usize].text.as_str(), ";" | "{" | "}") {
                j -= 1;
            }
            let mut k = (j + 1) as usize;
            if k < toks.len() && toks[k].text == "let" {
                k += 1;
                if k < toks.len() && toks[k].text == "mut" {
                    k += 1;
                }
                if k < toks.len() && toks[k].kind == TokKind::Ident {
                    floats.push(toks[k].text.clone());
                }
            }
        }
    }
    floats
}

/// Does a `{name:?}` placeholder for any known float appear in `lit`?
fn debug_named_float(lit: &str, floats: &[String]) -> Option<String> {
    let bytes = lit.as_bytes();
    let mut i = 0usize;
    while i < bytes.len() {
        if bytes[i] == b'{' {
            let mut j = i + 1;
            while j < bytes.len()
                && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_')
            {
                j += 1;
            }
            if j > i + 1 && lit[j..].starts_with(":?}") {
                let name = &lit[i + 1..j];
                if floats.iter().any(|f| f == name) {
                    return Some(name.to_string());
                }
            }
        }
        i += 1;
    }
    None
}

/// determinism zone: no Debug-formatting or `to_string()` on f64.
pub fn rule_float_fmt(toks: &[Tok], mask: &[bool], findings: &mut Vec<Finding>) {
    let floats = float_idents(toks, mask);
    for (i, t) in toks.iter().enumerate() {
        if mask[i] {
            continue;
        }
        if t.kind == TokKind::Ident
            && floats.contains(&t.text)
            && i + 3 < toks.len()
            && toks[i + 1].text == "."
            && toks[i + 2].text == "to_string"
            && toks[i + 3].text == "("
        {
            findings.push(Finding {
                rule: "float-fmt",
                line: t.line,
                msg: format!("to_string() on f64 `{}` in a deterministic module", t.text),
                hint: HINT_FLOAT_FMT,
            });
        }
        if t.kind == TokKind::Ident
            && FMT_MACROS.contains(&t.text.as_str())
            && i + 2 < toks.len()
            && toks[i + 1].text == "!"
        {
            // Scan the macro call: first string literal + ident args.
            let mut j = i + 2;
            let mut depth = 0i32;
            let mut lit: Option<&str> = None;
            let mut args: Vec<&str> = Vec::new();
            while j < toks.len() {
                let tj = &toks[j];
                if is_open(&tj.text) {
                    depth += 1;
                } else if is_close(&tj.text) {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if tj.kind == TokKind::Str && lit.is_none() {
                    lit = Some(&tj.text);
                } else if tj.kind == TokKind::Ident {
                    args.push(&tj.text);
                }
                j += 1;
            }
            if let Some(l) = lit {
                if l.contains("{:?}") && args.iter().any(|a| floats.iter().any(|f| f == a)) {
                    findings.push(Finding {
                        rule: "float-fmt",
                        line: t.line,
                        msg: "Debug-formatting an f64 in a deterministic module".to_string(),
                        hint: HINT_FLOAT_FMT,
                    });
                }
                if let Some(name) = debug_named_float(l, &floats) {
                    findings.push(Finding {
                        rule: "float-fmt",
                        line: t.line,
                        msg: format!("Debug-formatting f64 `{name}` in a deterministic module"),
                        hint: HINT_FLOAT_FMT,
                    });
                }
            }
        }
    }
}

/// panic zone: `.unwrap()` / `.expect(` forbidden — serving loops degrade.
pub fn rule_panic_path(toks: &[Tok], mask: &[bool], findings: &mut Vec<Finding>) {
    for i in 1..toks.len().saturating_sub(1) {
        if mask[i] {
            continue;
        }
        let t = &toks[i];
        if t.kind == TokKind::Ident
            && (t.text == "unwrap" || t.text == "expect")
            && toks[i - 1].text == "."
            && toks[i + 1].text == "("
        {
            findings.push(Finding {
                rule: "panic-path",
                line: t.line,
                msg: format!(".{}() in a serving-path module", t.text),
                hint: HINT_PANIC,
            });
        }
    }
}

const ACCT_COUNTERS: [&str; 3] = ["submitted", "completed", "shed"];

/// accounting zone (all files): a file mutating two or more of the
/// drain-ledger counters must reference `debug_assert_drain_invariant`.
/// One finding per file, anchored at the first mutation site.
pub fn rule_acct(toks: &[Tok], mask: &[bool], findings: &mut Vec<Finding>) {
    let has_helper = toks.iter().any(|t| t.text == "debug_assert_drain_invariant");
    let mut mutated: Vec<(&str, u32)> = Vec::new();
    for i in 1..toks.len().saturating_sub(1) {
        if mask[i] {
            continue;
        }
        let t = &toks[i];
        if t.kind != TokKind::Ident
            || !ACCT_COUNTERS.contains(&t.text.as_str())
            || toks[i - 1].text != "."
        {
            continue;
        }
        let nxt = toks[i + 1].text.as_str();
        let is_mut = matches!(nxt, "+=" | "-=" | "=")
            || (nxt == "."
                && i + 2 < toks.len()
                && matches!(toks[i + 2].text.as_str(), "fetch_add" | "fetch_sub"));
        if is_mut && !mutated.iter().any(|(n, _)| *n == t.text) {
            let name: &str = ACCT_COUNTERS
                .iter()
                .find(|c| **c == t.text)
                .copied()
                .unwrap_or("submitted");
            mutated.push((name, t.line));
        }
    }
    if mutated.len() >= 2 && !has_helper {
        let first = mutated.iter().map(|(_, l)| *l).min().unwrap_or(1);
        let mut names: Vec<&str> = mutated.iter().map(|(n, _)| *n).collect();
        names.sort_unstable();
        findings.push(Finding {
            rule: "acct-invariant",
            line: first,
            msg: format!(
                "mutates [{}] but never references debug_assert_drain_invariant",
                names.join(", ")
            ),
            hint: HINT_ACCT,
        });
    }
}

/// Token span `[open_brace, close_brace]` of the first `fn <name>` body.
fn fn_body_span(toks: &[Tok], name: &str) -> Option<(usize, usize)> {
    for i in 0..toks.len().saturating_sub(1) {
        if toks[i].text != "fn" || toks[i + 1].text != name {
            continue;
        }
        let mut j = i;
        while j < toks.len() && toks[j].text != "{" {
            j += 1;
        }
        let mut depth = 0i32;
        let mut k = j;
        while k < toks.len() {
            match toks[k].text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        return Some((j, k));
                    }
                }
                _ => {}
            }
            k += 1;
        }
    }
    None
}

/// wire zone (`net/wire.rs` only): every `TAG_*` constant and every
/// `Message` enum variant must appear in both `fn encode` and
/// `fn decode`, so the two match arms can never drift apart.
pub fn rule_wire_parity(toks: &[Tok], findings: &mut Vec<Finding>) {
    let mut names: Vec<(String, u32)> = Vec::new();
    for i in 0..toks.len().saturating_sub(1) {
        if toks[i].text == "const"
            && toks[i + 1].kind == TokKind::Ident
            && toks[i + 1].text.starts_with("TAG_")
        {
            names.push((toks[i + 1].text.clone(), toks[i + 1].line));
        }
    }
    for i in 0..toks.len().saturating_sub(2) {
        if toks[i].text != "enum" || toks[i + 1].text != "Message" {
            continue;
        }
        let mut j = i;
        while j < toks.len() && toks[j].text != "{" {
            j += 1;
        }
        let mut depth = 0i32;
        let mut k = j;
        while k < toks.len() {
            match toks[k].text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {
                    if depth == 1
                        && toks[k].kind == TokKind::Ident
                        && k + 1 < toks.len()
                        && matches!(toks[k + 1].text.as_str(), "{" | "(" | ",")
                    {
                        names.push((toks[k].text.clone(), toks[k].line));
                    }
                }
            }
            k += 1;
        }
        break;
    }
    let enc = fn_body_span(toks, "encode");
    let dec = fn_body_span(toks, "decode");
    let (enc, dec) = match (enc, dec) {
        (Some(e), Some(d)) => (e, d),
        _ => {
            findings.push(Finding {
                rule: "wire-tag-parity",
                line: 1,
                msg: "cannot locate fn encode / fn decode bodies".to_string(),
                hint: HINT_PARITY,
            });
            return;
        }
    };
    let present = |name: &str, span: (usize, usize)| {
        toks[span.0..=span.1].iter().any(|t| t.text == name)
    };
    for (name, line) in names {
        let (in_enc, in_dec) = (present(&name, enc), present(&name, dec));
        if in_enc != in_dec {
            let missing = if in_enc { "decode" } else { "encode" };
            findings.push(Finding {
                rule: "wire-tag-parity",
                line,
                msg: format!("`{name}` missing from fn {missing}"),
                hint: HINT_PARITY,
            });
        }
    }
}

/// Cross-diff rule: run `git diff HEAD -- net/wire.rs` from the scan
/// root; a diff adding a `const TAG_` line without touching
/// `PROTOCOL_VERSION` is a protocol-compat hazard. Silently skipped when
/// git is unavailable or the root is not a work tree.
pub fn rule_proto_bump(root: &std::path::Path) -> Option<Finding> {
    let out = std::process::Command::new("git")
        .args(["diff", "HEAD", "--", super::zones::WIRE_FILE])
        .current_dir(root)
        .output()
        .ok()?;
    if !out.status.success() {
        return None;
    }
    let diff = String::from_utf8_lossy(&out.stdout);
    let mut added_tag = false;
    let mut touched_ver = false;
    for l in diff.lines() {
        if l.starts_with('+') && l.contains("const TAG_") {
            added_tag = true;
        }
        if (l.starts_with('+') || l.starts_with('-')) && l.contains("PROTOCOL_VERSION") {
            touched_ver = true;
        }
    }
    if added_tag && !touched_ver {
        return Some(Finding {
            rule: "wire-proto-bump",
            line: 1,
            msg: "new TAG_ constant without a PROTOCOL_VERSION bump in the same diff".to_string(),
            hint: HINT_BUMP,
        });
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::audit::lexer::tokenize;

    fn run<F>(src: &str, rule: F) -> Vec<Finding>
    where
        F: Fn(&[Tok], &[bool], &mut Vec<Finding>),
    {
        let lexed = tokenize(src);
        let mask = test_mask(&lexed.toks);
        let mut out = Vec::new();
        rule(&lexed.toks, &mask, &mut out);
        out
    }

    #[test]
    fn cfg_test_items_are_masked() {
        let src = "fn live() { let t = Instant::now(); }\n\
                   #[cfg(test)]\nmod tests { fn t() { let u = Instant::now(); } }";
        let hits = run(src, rule_wallclock);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].line, 1);
    }

    #[test]
    fn acct_requires_two_counters() {
        // Only one counter mutated → no finding (replay/driver.rs case).
        let one = "fn f(s: &mut S) { s.submitted += 1; }";
        assert!(run(one, rule_acct).is_empty());
        // Two counters, no helper → fires once at the first site.
        let two = "fn f(s: &mut S) { s.submitted += 1; s.shed += n; }";
        let hits = run(two, rule_acct);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].rule, "acct-invariant");
        // Helper referenced anywhere in the file → clean.
        let ok = "fn f(s: &mut S) { s.submitted += 1; s.shed += n; \
                  debug_assert_drain_invariant(s.submitted, 0, s.shed, \"f\"); }";
        assert!(run(ok, rule_acct).is_empty());
    }

    #[test]
    fn acct_sees_atomic_mutation() {
        let src = "fn f(m: &M) { m.submitted.fetch_add(1, O); m.completed.fetch_add(1, O); }";
        assert_eq!(run(src, rule_acct).len(), 1);
        // Comparison is not mutation.
        let cmp = "fn f(s: &S) -> bool { s.submitted == s.completed }";
        assert!(run(cmp, rule_acct).is_empty());
    }

    #[test]
    fn hash_iter_binds_by_ascription_and_ctor() {
        let asc = "fn f(m: &FxHashMap<u32, u32>) {}\nfn g(m: &M) { for k in &m.m {} }";
        // `m` ascribed FxHashMap; plain field access not flagged, but
        // direct iteration of the bound name is.
        let src = "fn f(scores: &FxHashMap<u32, u32>) { for k in scores { use_it(k); } }";
        assert_eq!(run(src, rule_hash_iter).len(), 1);
        let ctor = "fn f() { let mut seen = HashSet::new(); for s in &seen {} }";
        assert_eq!(run(ctor, rule_hash_iter).len(), 1);
        let method = "fn f(idx: &FxHashMap<u32, u32>) { let v: Vec<_> = idx.keys().collect(); }";
        assert_eq!(run(method, rule_hash_iter).len(), 1);
        assert!(run(asc, rule_hash_iter).is_empty());
        // Sorted-afterwards pattern on a Vec is fine.
        let vec = "fn f(v: &Vec<u32>) { for x in v {} }";
        assert!(run(vec, rule_hash_iter).is_empty());
    }

    #[test]
    fn float_fmt_catches_debug_and_to_string() {
        let dbg = "fn f(ratio: f64) { println!(\"{:?}\", ratio); }";
        assert_eq!(run(dbg, rule_float_fmt).len(), 1);
        let named = "fn f(ratio: f64) { println!(\"{ratio:?}\"); }";
        assert_eq!(run(named, rule_float_fmt).len(), 1);
        let ts = "fn f(x: u64) { let share = x as f64; let s = share.to_string(); }";
        assert_eq!(run(ts, rule_float_fmt).len(), 1);
        // Display formatting of ints and {} on floats are not flagged.
        let ok = "fn f(n: u64, ratio: f64) { println!(\"{} {ratio}\", n); }";
        assert!(run(ok, rule_float_fmt).is_empty());
    }

    #[test]
    fn panic_path_matches_method_calls_only() {
        let bad = "fn f(m: &Mutex<u32>) { let g = m.lock().unwrap(); }";
        assert_eq!(run(bad, rule_panic_path).len(), 1);
        let exp = "fn f(o: Option<u32>) { o.expect(\"present\"); }";
        assert_eq!(run(exp, rule_panic_path).len(), 1);
        // `unwrap_or_else` is a different identifier; free fn `expect` too.
        let ok = "fn f(m: &Mutex<u32>) { let g = m.lock().unwrap_or_else(p); expect(1); }";
        assert!(run(ok, rule_panic_path).is_empty());
    }

    #[test]
    fn wire_parity_cross_checks_encode_and_decode() {
        let src = "const TAG_A: u8 = 1;\nconst TAG_B: u8 = 2;\n\
                   enum Message { Ping, Pong { x: u8 } }\n\
                   fn encode() { t(TAG_A); t(TAG_B); m(Message::Ping); m(Message::Pong); }\n\
                   fn decode() { t(TAG_A); m(Message::Ping); m(Message::Pong); }";
        let lexed = tokenize(src);
        let mut out = Vec::new();
        rule_wire_parity(&lexed.toks, &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].msg.contains("TAG_B"));
        assert!(out[0].msg.contains("decode"));
    }
}
