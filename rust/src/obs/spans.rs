//! Span analysis: parse `--trace-out` JSONL back in, render a per-stage
//! latency breakdown, and validate chain integrity (the ci obs gate).
//!
//! The parser is a tolerant, hand-rolled field extractor — it reads
//! exactly the flat one-object-per-line format [`super::trace`] writes,
//! skips lines it cannot parse (a truncated tail from a killed run must
//! not poison the analysis), and needs no JSON dependency.

use std::collections::BTreeMap;

use super::trace::Stage;
use crate::util::stats::percentile_sorted;

/// A span read back from a JSONL trace. `stage` stays a string so
/// foreign or future stage names still parse (the chain checker is where
/// strictness lives).
#[derive(Debug, Clone, PartialEq)]
pub struct ParsedSpan {
    pub request_id: u64,
    pub stage: String,
    pub t_start_us: u64,
    pub t_end_us: u64,
    pub shard: u32,
    pub drive: u32,
    pub tape: String,
}

fn num_field(line: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = line[start..].trim_start();
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    if end == 0 {
        return None;
    }
    rest[..end].parse().ok()
}

fn str_field(line: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = line[start..].trim_start().strip_prefix('"')?;
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => return Some(out),
            '\\' => match chars.next()? {
                '"' => out.push('"'),
                '\\' => out.push('\\'),
                'n' => out.push('\n'),
                't' => out.push('\t'),
                'u' => {
                    let hex: String = chars.by_ref().take(4).collect();
                    let code = u32::from_str_radix(&hex, 16).ok()?;
                    out.push(char::from_u32(code)?);
                }
                other => out.push(other),
            },
            c => out.push(c),
        }
    }
    None
}

/// Parse one JSONL line; `None` if any required field is missing.
pub fn parse_line(line: &str) -> Option<ParsedSpan> {
    let line = line.trim();
    if line.is_empty() {
        return None;
    }
    Some(ParsedSpan {
        request_id: num_field(line, "request_id")?,
        stage: str_field(line, "stage")?,
        t_start_us: num_field(line, "t_start_us")?,
        t_end_us: num_field(line, "t_end_us")?,
        shard: num_field(line, "shard")? as u32,
        drive: num_field(line, "drive")? as u32,
        tape: str_field(line, "tape")?,
    })
}

/// Parse a whole trace file, skipping blank and malformed lines.
pub fn parse_jsonl(text: &str) -> Vec<ParsedSpan> {
    text.lines().filter_map(parse_line).collect()
}

/// One row of the per-stage latency breakdown.
#[derive(Debug, Clone, PartialEq)]
pub struct StageRow {
    pub stage: String,
    pub count: u64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub p999_us: f64,
    pub max_us: u64,
    /// This stage's share of total traced time, percent.
    pub share_pct: f64,
}

/// Aggregate spans into per-stage rows, canonical chain order first, any
/// unknown stage names appended alphabetically.
pub fn breakdown(spans: &[ParsedSpan]) -> Vec<StageRow> {
    let mut by_stage: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    for s in spans {
        by_stage.entry(s.stage.as_str()).or_default().push((s.t_end_us - s.t_start_us) as f64);
    }
    let grand_total: f64 = by_stage.values().flatten().sum();
    let mut order: Vec<&str> = Stage::CHAIN
        .iter()
        .map(|s| s.as_str())
        .filter(|name| by_stage.contains_key(name))
        .collect();
    for name in by_stage.keys() {
        if Stage::parse(name).is_none() {
            order.push(*name);
        }
    }
    order
        .into_iter()
        .map(|name| {
            let mut durs = by_stage[name].clone();
            durs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let count = durs.len() as u64;
            let sum: f64 = durs.iter().sum();
            StageRow {
                stage: name.to_string(),
                count,
                mean_us: sum / count as f64,
                p50_us: percentile_sorted(&durs, 50.0),
                p99_us: percentile_sorted(&durs, 99.0),
                p999_us: percentile_sorted(&durs, 99.9),
                max_us: *durs.last().unwrap() as u64,
                share_pct: if grand_total > 0.0 { 100.0 * sum / grand_total } else { 0.0 },
            }
        })
        .collect()
}

/// Render the breakdown as an aligned plaintext table.
pub fn render_breakdown(rows: &[StageRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<15} {:>10} {:>12} {:>12} {:>12} {:>12} {:>12} {:>7}\n",
        "stage", "count", "mean_us", "p50_us", "p99_us", "p99.9_us", "max_us", "share"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<15} {:>10} {:>12.1} {:>12.1} {:>12.1} {:>12.1} {:>12} {:>6.1}%\n",
            r.stage, r.count, r.mean_us, r.p50_us, r.p99_us, r.p999_us, r.max_us, r.share_pct
        ));
    }
    out
}

/// Validate chain integrity: every request with spans must have exactly
/// one span per canonical stage, in [`Stage::CHAIN`] order, contiguous
/// (each stage starts where the previous ended) and monotone. Returns
/// the number of complete chains, or the first violation.
pub fn check_chains(spans: &[ParsedSpan]) -> Result<usize, String> {
    let mut by_request: BTreeMap<u64, Vec<&ParsedSpan>> = BTreeMap::new();
    for s in spans {
        by_request.entry(s.request_id).or_default().push(s);
    }
    for (id, chain) in &by_request {
        if chain.len() != Stage::CHAIN.len() {
            return Err(format!(
                "request {id}: {} spans, expected {} (one per stage)",
                chain.len(),
                Stage::CHAIN.len()
            ));
        }
        for (i, span) in chain.iter().enumerate() {
            let want = Stage::CHAIN[i].as_str();
            if span.stage != want {
                return Err(format!(
                    "request {id}: stage {i} is {:?}, expected {want:?}",
                    span.stage
                ));
            }
            if span.t_end_us < span.t_start_us {
                return Err(format!(
                    "request {id}: stage {want} runs backwards ({} → {})",
                    span.t_start_us, span.t_end_us
                ));
            }
            if i > 0 && span.t_start_us != chain[i - 1].t_end_us {
                return Err(format!(
                    "request {id}: gap/overlap before {want} \
                     (previous ended {}, this starts {})",
                    chain[i - 1].t_end_us, span.t_start_us
                ));
            }
        }
    }
    Ok(by_request.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceRecorder;

    fn traced_text() -> String {
        let rec = TraceRecorder::new(64);
        rec.record_chain(1, 0, 0, "TAPE000", [0, 2, 2, 10, 10, 12, 15, 20, 40, 40]);
        rec.record_chain(2, 1, 3, "TAPE001", [5, 5, 5, 11, 14, 14, 14, 22, 50, 50]);
        let mut buf = Vec::new();
        rec.write_jsonl(&mut buf).unwrap();
        String::from_utf8(buf).unwrap()
    }

    #[test]
    fn writer_output_parses_back_and_checks_clean() {
        let spans = parse_jsonl(&traced_text());
        assert_eq!(spans.len(), 18);
        assert_eq!(spans[0].request_id, 1);
        assert_eq!(spans[0].stage, "submit");
        assert_eq!(spans[9].tape, "TAPE001");
        assert_eq!(check_chains(&spans), Ok(2));
    }

    #[test]
    fn malformed_lines_are_skipped_not_fatal() {
        let text = format!("garbage\n{}{{\"request_id\":9}}\n", traced_text());
        let spans = parse_jsonl(&text);
        assert_eq!(spans.len(), 18, "only well-formed spans survive");
    }

    #[test]
    fn gaps_and_wrong_order_are_rejected() {
        let mut spans = parse_jsonl(&traced_text());
        // Introduce a gap: request 1's exec starts 1µs late.
        let exec = spans.iter_mut().find(|s| s.request_id == 1 && s.stage == "exec").unwrap();
        exec.t_start_us += 1;
        let err = check_chains(&spans).unwrap_err();
        assert!(err.contains("request 1"), "{err}");
        assert!(err.contains("gap/overlap"), "{err}");

        let mut spans = parse_jsonl(&traced_text());
        spans.retain(|s| !(s.request_id == 2 && s.stage == "mount"));
        let err = check_chains(&spans).unwrap_err();
        assert!(err.contains("request 2"), "{err}");
    }

    #[test]
    fn breakdown_orders_stages_and_shares_sum_to_100() {
        let spans = parse_jsonl(&traced_text());
        let rows = breakdown(&spans);
        assert_eq!(rows.first().unwrap().stage, "submit");
        assert_eq!(rows.last().unwrap().stage, "complete");
        let share: f64 = rows.iter().map(|r| r.share_pct).sum();
        assert!((share - 100.0).abs() < 1e-6, "shares sum to {share}");
        let exec = rows.iter().find(|r| r.stage == "exec").unwrap();
        assert_eq!(exec.count, 2);
        // Request 1 exec: 40−20 = 20; request 2 exec: 50−22 = 28.
        assert!((exec.mean_us - 24.0).abs() < 1e-9);
        assert_eq!(exec.max_us, 28);
        let table = render_breakdown(&rows);
        assert!(table.contains("exec"));
        assert!(table.contains("share"));
    }
}
