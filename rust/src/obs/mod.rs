//! Observability: request-lifecycle tracing, span analysis, and a
//! Prometheus-style exposition surface. Dependency-free, like the rest
//! of the crate.
//!
//! Three pillars, one per module:
//!
//! - [`trace`] — the span recorder: a fixed-capacity ring buffer of
//!   `Span { request_id, stage, t_start_us, t_end_us, shard, drive,
//!   tape }`, filled by the replay engine (virtual µs) and the live
//!   coordinator (wall µs) through the same nine-stage chain, dumped as
//!   newline-delimited JSON by `replay --trace-out` / `serve
//!   --trace-out`.
//! - [`spans`] — the reader: parse a JSONL trace back in, render the
//!   per-stage latency breakdown (`tapesched spans`), and validate chain
//!   integrity for the ci obs gate (no gaps, no overlaps, monotone).
//! - [`expo`] — the scrape surface: a [`Registry`] of render closures
//!   over the *live* metrics (never a copied value, so exposition and
//!   drain reports cannot diverge) behind a hand-rolled HTTP/1.0
//!   plaintext endpoint in Prometheus text exposition format
//!   (`serve --metrics-listen` / `coordinator --metrics-listen`).
//!
//! The push-based fleet telemetry that feeds the networked coordinator's
//! exposition (wire tags 13–14) lives in [`crate::net`]; this module
//! only renders what that layer accounts.

pub mod expo;
pub mod spans;
pub mod trace;

pub use expo::{write_counter, write_gauge, write_type, ExpositionServer, Registry};
pub use spans::{
    breakdown, check_chains, parse_jsonl, render_breakdown, ParsedSpan, StageRow,
};
pub use trace::{clamp_boundaries, Span, Stage, TraceRecorder, DEFAULT_TRACE_CAP};
