//! Request-lifecycle tracing: a fixed-capacity ring-buffer span recorder.
//!
//! Every request that crosses the stack traverses the same nine stages —
//! submit → route → batch-seal → drive-wait → cartridge-wait → arm-wait →
//! mount → exec → complete — whether it runs through the virtual-time
//! replay engine (stage times in virtual µs) or the live coordinator
//! (wall µs since service start). Both emitters record one [`Span`] per
//! stage through the same [`TraceRecorder`], so the `tapesched spans`
//! breakdown and the ci chain gate read one format regardless of source.
//!
//! The recorder is deliberately cheap: a single mutex around a
//! pre-sized ring. Emitters record a whole request's chain in one lock
//! acquisition ([`TraceRecorder::record_chain`]), and when the ring is
//! full the oldest spans are overwritten (`dropped` counts them) rather
//! than growing memory or blocking the hot path. Tracing that is *off*
//! costs nothing at all — every instrumentation site is gated on an
//! `Option` that is `None` by default, and the default replay path stays
//! byte-identical with the recorder absent.
//!
//! ## Chain construction
//!
//! A chain is built from **10 boundary timestamps** (9 contiguous
//! stages). Raw boundaries are not always monotone — a replay request can
//! join a batch after its window already expired, so its submit time may
//! exceed the batch's seal time — so [`clamp_boundaries`] applies a
//! prefix-max before spans are cut: every stage keeps its true share of
//! the request's life where the measurements are ordered, and degenerates
//! to a zero-length span where they are not. After clamping, the stage
//! durations of a chain sum exactly to `boundary[9] − boundary[0]`.

use std::io::{self, Write};
use std::sync::Mutex;

/// Default ring capacity for `--trace-out` runs (spans, not requests: a
/// full chain is 9 spans, so this holds the last ~116k requests).
pub const DEFAULT_TRACE_CAP: usize = 1 << 20;

/// One stage of a request's life. The order of [`Stage::CHAIN`] is the
/// canonical chain order every complete request traverses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Arrival → accepted by the submit path.
    Submit,
    /// Accepted → routed to its shard.
    Route,
    /// Routed → the request's batch was sealed (window expiry or size
    /// cap) and became dispatchable.
    BatchSeal,
    /// Sealed → a drive was claimed for the batch.
    DriveWait,
    /// Waiting for the physical cartridge (per-tape mount exclusivity).
    CartridgeWait,
    /// Waiting for a robot arm to pick the cartridge up.
    ArmWait,
    /// The mount operation itself (zero-length on a remount hit, and on
    /// the live path where the mount is a charge, not a wall sleep).
    Mount,
    /// In-drive execution: scheduling plus the in-tape tour.
    Exec,
    /// Served → completion recorded.
    Complete,
}

impl Stage {
    /// The canonical chain order (index i spans boundaries i → i+1).
    pub const CHAIN: [Stage; 9] = [
        Stage::Submit,
        Stage::Route,
        Stage::BatchSeal,
        Stage::DriveWait,
        Stage::CartridgeWait,
        Stage::ArmWait,
        Stage::Mount,
        Stage::Exec,
        Stage::Complete,
    ];

    /// Stable wire name (the `stage` field of a JSONL span).
    pub fn as_str(self) -> &'static str {
        match self {
            Stage::Submit => "submit",
            Stage::Route => "route",
            Stage::BatchSeal => "batch_seal",
            Stage::DriveWait => "drive_wait",
            Stage::CartridgeWait => "cartridge_wait",
            Stage::ArmWait => "arm_wait",
            Stage::Mount => "mount",
            Stage::Exec => "exec",
            Stage::Complete => "complete",
        }
    }

    /// Inverse of [`Stage::as_str`].
    pub fn parse(s: &str) -> Option<Stage> {
        Stage::CHAIN.iter().copied().find(|st| st.as_str() == s)
    }
}

/// One recorded stage interval of one request.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub request_id: u64,
    pub stage: Stage,
    /// Stage entry, µs on the emitter's clock (virtual µs in replay, wall
    /// µs since service start in the live coordinator).
    pub t_start_us: u64,
    /// Stage exit, same clock. Always ≥ `t_start_us`.
    pub t_end_us: u64,
    pub shard: u32,
    pub drive: u32,
    pub tape: String,
}

struct Ring {
    buf: Vec<Span>,
    /// Next overwrite position once the buffer is full.
    head: usize,
    /// Spans overwritten because the ring was full.
    dropped: u64,
}

/// The span sink: a fixed-capacity ring under one mutex.
pub struct TraceRecorder {
    cap: usize,
    ring: Mutex<Ring>,
}

impl TraceRecorder {
    /// A recorder holding at most `cap` spans (oldest overwritten first).
    pub fn new(cap: usize) -> TraceRecorder {
        let cap = cap.max(1);
        TraceRecorder {
            cap,
            ring: Mutex::new(Ring { buf: Vec::new(), head: 0, dropped: 0 }),
        }
    }

    /// Record capacity in spans.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    fn push_locked(ring: &mut Ring, cap: usize, span: Span) {
        if ring.buf.len() < cap {
            ring.buf.push(span);
        } else {
            ring.buf[ring.head] = span;
            ring.head = (ring.head + 1) % cap;
            ring.dropped += 1;
        }
    }

    /// Record one span.
    pub fn record(&self, span: Span) {
        let mut ring = self.ring.lock().unwrap();
        TraceRecorder::push_locked(&mut ring, self.cap, span);
    }

    /// Record a request's whole chain in one lock acquisition: 10
    /// boundary timestamps → 9 contiguous spans in [`Stage::CHAIN`]
    /// order, with [`clamp_boundaries`] applied first.
    pub fn record_chain(
        &self,
        request_id: u64,
        shard: u32,
        drive: u32,
        tape: &str,
        boundaries: [u64; 10],
    ) {
        let b = clamp_boundaries(boundaries);
        let mut ring = self.ring.lock().unwrap();
        for (i, stage) in Stage::CHAIN.iter().enumerate() {
            TraceRecorder::push_locked(
                &mut ring,
                self.cap,
                Span {
                    request_id,
                    stage: *stage,
                    t_start_us: b[i],
                    t_end_us: b[i + 1],
                    shard,
                    drive,
                    tape: tape.to_string(),
                },
            );
        }
    }

    /// Spans currently held.
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans overwritten because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.ring.lock().unwrap().dropped
    }

    /// A copy of the held spans in insertion order (oldest first).
    pub fn snapshot(&self) -> Vec<Span> {
        let ring = self.ring.lock().unwrap();
        let mut out = Vec::with_capacity(ring.buf.len());
        out.extend_from_slice(&ring.buf[ring.head..]);
        out.extend_from_slice(&ring.buf[..ring.head]);
        out
    }

    /// Write the held spans as newline-delimited JSON (insertion order).
    /// Returns the number of spans written.
    pub fn write_jsonl<W: Write>(&self, w: &mut W) -> io::Result<usize> {
        let spans = self.snapshot();
        let mut line = String::new();
        for span in &spans {
            line.clear();
            span_json(&mut line, span);
            line.push('\n');
            w.write_all(line.as_bytes())?;
        }
        Ok(spans.len())
    }
}

/// Prefix-max over the 10 chain boundaries: measurements that arrive out
/// of order (e.g. a request submitted after its batch's window already
/// expired) collapse the affected stage to zero length instead of
/// producing a negative span.
pub fn clamp_boundaries(mut b: [u64; 10]) -> [u64; 10] {
    for i in 1..b.len() {
        if b[i] < b[i - 1] {
            b[i] = b[i - 1];
        }
    }
    b
}

/// One span as a single-line JSON object (the `--trace-out` format).
fn span_json(out: &mut String, s: &Span) {
    out.push_str("{\"request_id\":");
    out.push_str(&s.request_id.to_string());
    out.push_str(",\"stage\":\"");
    out.push_str(s.stage.as_str());
    out.push_str("\",\"t_start_us\":");
    out.push_str(&s.t_start_us.to_string());
    out.push_str(",\"t_end_us\":");
    out.push_str(&s.t_end_us.to_string());
    out.push_str(",\"shard\":");
    out.push_str(&s.shard.to_string());
    out.push_str(",\"drive\":");
    out.push_str(&s.drive.to_string());
    out.push_str(",\"tape\":\"");
    for c in s.tape.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push_str("\"}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_round_trip() {
        for stage in Stage::CHAIN {
            assert_eq!(Stage::parse(stage.as_str()), Some(stage));
        }
        assert_eq!(Stage::parse("nope"), None);
    }

    #[test]
    fn clamping_is_prefix_max_and_preserves_the_total() {
        let raw = [5, 3, 3, 10, 8, 12, 12, 12, 20, 20];
        let b = clamp_boundaries(raw);
        for i in 1..b.len() {
            assert!(b[i] >= b[i - 1]);
        }
        // The chain still starts at the first boundary and ends at the
        // running max — stage durations sum to b[9] − b[0].
        assert_eq!(b[0], 5);
        assert_eq!(b[9], 20);
        let total: u64 = (0..9).map(|i| b[i + 1] - b[i]).sum();
        assert_eq!(total, b[9] - b[0]);
    }

    #[test]
    fn record_chain_emits_nine_contiguous_spans() {
        let rec = TraceRecorder::new(64);
        rec.record_chain(7, 1, 2, "TAPE001", [0, 1, 1, 4, 6, 6, 9, 12, 30, 30]);
        let spans = rec.snapshot();
        assert_eq!(spans.len(), 9);
        for (i, s) in spans.iter().enumerate() {
            assert_eq!(s.request_id, 7);
            assert_eq!(s.shard, 1);
            assert_eq!(s.drive, 2);
            assert_eq!(s.tape, "TAPE001");
            assert_eq!(s.stage, Stage::CHAIN[i]);
            assert!(s.t_end_us >= s.t_start_us);
            if i > 0 {
                assert_eq!(s.t_start_us, spans[i - 1].t_end_us, "chain gap at {i}");
            }
        }
        assert_eq!(spans[0].t_start_us, 0);
        assert_eq!(spans[8].t_end_us, 30);
        assert_eq!(rec.dropped(), 0);
    }

    #[test]
    fn the_ring_overwrites_oldest_and_counts_drops() {
        let rec = TraceRecorder::new(4);
        for id in 0..10u64 {
            rec.record(Span {
                request_id: id,
                stage: Stage::Submit,
                t_start_us: id,
                t_end_us: id + 1,
                shard: 0,
                drive: 0,
                tape: "T".into(),
            });
        }
        assert_eq!(rec.len(), 4);
        assert_eq!(rec.dropped(), 6);
        let ids: Vec<u64> = rec.snapshot().iter().map(|s| s.request_id).collect();
        assert_eq!(ids, vec![6, 7, 8, 9], "oldest-first insertion order");
    }

    #[test]
    fn jsonl_lines_are_stable_and_escaped() {
        let rec = TraceRecorder::new(4);
        rec.record(Span {
            request_id: 3,
            stage: Stage::ArmWait,
            t_start_us: 10,
            t_end_us: 25,
            shard: 2,
            drive: 1,
            tape: "TA\"PE".into(),
        });
        let mut out = Vec::new();
        let n = rec.write_jsonl(&mut out).unwrap();
        assert_eq!(n, 1);
        let line = String::from_utf8(out).unwrap();
        assert_eq!(
            line,
            "{\"request_id\":3,\"stage\":\"arm_wait\",\"t_start_us\":10,\
             \"t_end_us\":25,\"shard\":2,\"drive\":1,\"tape\":\"TA\\\"PE\"}\n"
        );
    }
}
