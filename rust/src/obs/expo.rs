//! Prometheus-style exposition: a [`Registry`] of render closures and a
//! hand-rolled HTTP/1.0 plaintext endpoint ([`ExpositionServer`]).
//!
//! The registry holds no metric *values* — only closures that render the
//! live source of truth (`SharedMetrics`, the networked coordinator's
//! per-shard accounting) at scrape time. There is deliberately no second
//! copy of any counter: whatever the drain report says, the scrape says,
//! because both read the same atomics.
//!
//! The HTTP server is the smallest thing that `curl` and a Prometheus
//! scraper both accept: read one request, answer
//! `HTTP/1.0 200 OK` with `Content-Type: text/plain; version=0.0.4` and
//! an exact `Content-Length`, close. No keep-alive, no routing — every
//! path serves the metrics page.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

type Source = Box<dyn Fn(&mut String) + Send + Sync>;

/// A set of exposition sources rendered in registration order.
#[derive(Default)]
pub struct Registry {
    sources: Mutex<Vec<Source>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register a render closure. It is called at every scrape with the
    /// page buffer; it must append complete exposition lines.
    pub fn register<F>(&self, f: F)
    where
        F: Fn(&mut String) + Send + Sync + 'static,
    {
        crate::util::sync::lock_recover(&self.sources, "registry register").push(Box::new(f));
    }

    /// Render the whole page (the body of a scrape response).
    pub fn render(&self) -> String {
        let mut buf = String::new();
        for f in crate::util::sync::lock_recover(&self.sources, "registry render").iter() {
            f(&mut buf);
        }
        buf
    }
}

fn write_labels(buf: &mut String, labels: &[(&str, &str)]) {
    if labels.is_empty() {
        return;
    }
    buf.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            buf.push(',');
        }
        buf.push_str(k);
        buf.push_str("=\"");
        for c in v.chars() {
            match c {
                '"' => buf.push_str("\\\""),
                '\\' => buf.push_str("\\\\"),
                '\n' => buf.push_str("\\n"),
                c => buf.push(c),
            }
        }
        buf.push('"');
    }
    buf.push('}');
}

/// Append one integer-valued sample line (`name{labels} value`).
pub fn write_counter(buf: &mut String, name: &str, labels: &[(&str, &str)], value: u64) {
    buf.push_str(name);
    write_labels(buf, labels);
    buf.push(' ');
    buf.push_str(&value.to_string());
    buf.push('\n');
}

/// Append one float-valued sample line. Non-finite values render as the
/// exposition format's `+Inf`/`-Inf`/`NaN`.
pub fn write_gauge(buf: &mut String, name: &str, labels: &[(&str, &str)], value: f64) {
    buf.push_str(name);
    write_labels(buf, labels);
    buf.push(' ');
    if value.is_nan() {
        buf.push_str("NaN");
    } else if value == f64::INFINITY {
        buf.push_str("+Inf");
    } else if value == f64::NEG_INFINITY {
        buf.push_str("-Inf");
    } else {
        buf.push_str(&format!("{value}"));
    }
    buf.push('\n');
}

/// Append a `# TYPE` header for a metric family.
pub fn write_type(buf: &mut String, name: &str, kind: &str) {
    buf.push_str("# TYPE ");
    buf.push_str(name);
    buf.push(' ');
    buf.push_str(kind);
    buf.push('\n');
}

/// A background scrape endpoint bound to one address. Dropping (or
/// calling [`ExpositionServer::stop`]) stops the accept loop and joins
/// the thread.
pub struct ExpositionServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ExpositionServer {
    /// Bind `addr` (e.g. `127.0.0.1:9187`, or port 0 for ephemeral) and
    /// serve `registry` until stopped.
    pub fn bind(addr: &str, registry: Arc<Registry>) -> io::Result<ExpositionServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            while !stop_flag.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // Scrapes are tiny; serve inline so a stop is
                        // never racing detached handler threads.
                        let _ = serve_scrape(stream, &registry);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(ExpositionServer { addr, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join the server thread.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ExpositionServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_scrape(mut stream: TcpStream, registry: &Registry) -> io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_millis(500))).ok();
    // Read until the blank line ending the request head (or the client
    // stops sending). The request itself is ignored: every path is the
    // metrics page.
    let mut head = Vec::new();
    let mut chunk = [0u8; 512];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                head.extend_from_slice(&chunk[..n]);
                if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 8 * 1024 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let body = registry.render();
    let response = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn sample_lines_render_labels_and_values() {
        let mut buf = String::new();
        write_type(&mut buf, "tapesched_submitted_total", "counter");
        write_counter(&mut buf, "tapesched_submitted_total", &[("shard", "0")], 42);
        write_gauge(&mut buf, "tapesched_mean_latency_seconds", &[], 1.5);
        write_gauge(&mut buf, "tapesched_odd", &[("q", "a\"b")], f64::INFINITY);
        assert_eq!(
            buf,
            "# TYPE tapesched_submitted_total counter\n\
             tapesched_submitted_total{shard=\"0\"} 42\n\
             tapesched_mean_latency_seconds 1.5\n\
             tapesched_odd{q=\"a\\\"b\"} +Inf\n"
        );
    }

    #[test]
    fn registry_renders_sources_in_registration_order() {
        let reg = Registry::new();
        let counter = Arc::new(AtomicU64::new(7));
        let c = Arc::clone(&counter);
        reg.register(move |buf| {
            write_counter(buf, "a_total", &[], c.load(Ordering::Relaxed));
        });
        reg.register(|buf| buf.push_str("b_gauge 1\n"));
        assert_eq!(reg.render(), "a_total 7\nb_gauge 1\n");
        counter.store(9, Ordering::Relaxed);
        assert_eq!(reg.render(), "a_total 9\nb_gauge 1\n", "live source, no cached copy");
    }

    #[test]
    fn the_endpoint_answers_a_scrape_and_stops_cleanly() {
        let reg = Arc::new(Registry::new());
        reg.register(|buf| buf.push_str("tapesched_up 1\n"));
        let server = ExpositionServer::bind("127.0.0.1:0", Arc::clone(&reg))
            .expect("bind ephemeral endpoint");
        let addr = server.addr();

        let mut conn = TcpStream::connect(addr).expect("connect scraper");
        conn.write_all(b"GET /metrics HTTP/1.0\r\nHost: x\r\n\r\n").unwrap();
        let mut response = String::new();
        conn.read_to_string(&mut response).expect("read scrape");
        assert!(response.starts_with("HTTP/1.0 200 OK\r\n"), "got: {response}");
        assert!(response.contains("Content-Type: text/plain; version=0.0.4"));
        let body = response.split("\r\n\r\n").nth(1).expect("body");
        assert_eq!(body, "tapesched_up 1\n");
        let head = response.split("\r\n\r\n").next().unwrap();
        assert!(head.contains(&format!("Content-Length: {}", body.len())));

        server.stop();
        // The listener is gone after stop: a fresh connect must fail (or
        // connect and then see an immediate close on some platforms — so
        // only assert the success path no longer serves).
        if let Ok(mut late) = TcpStream::connect(addr) {
            late.write_all(b"GET / HTTP/1.0\r\n\r\n").ok();
            let mut s = String::new();
            let n = late.read_to_string(&mut s).unwrap_or(0);
            assert_eq!(n, 0, "stopped endpoint must not serve");
        }
    }
}
