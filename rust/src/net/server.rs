//! The coordinator process: owns the consistent-hash ring, routes client
//! submits to TCP workers, and keeps the fleet's accounting exact across
//! worker deaths.
//!
//! ```text
//!   clients ──Submit──▶ ┌──────────────────────────────┐
//!                       │ serve(): ShardSet over        │
//!                       │ WorkerShard backends (ring)   │
//!                       └──┬──────────┬──────────┬──────┘
//!                          ▼          ▼          ▼
//!                     worker 0    worker 1    worker 2     (TCP, one
//!                     Coordinator Coordinator Coordinator   shard each)
//! ```
//!
//! Each connected worker is wrapped in a [`WorkerShard`] — the remote arm
//! of the [`ShardBackend`] seam — and attached to a [`ShardSet`], so the
//! routing layer is byte-identical to the in-process one: same ring, same
//! per-shard routed counters, same rollup.
//!
//! ## Dead workers and the drain invariant
//!
//! The per-shard connection lock is held across each request/response
//! pair, so the coordinator always knows exactly how many submits the
//! worker *accepted* this era (`accepted_era`). When the connection dies,
//! the shard's lost work is synthesized into a carried snapshot:
//! `submitted := accepted_era`, `completed := last pulled completed`,
//! `shed := accepted_era − completed` — every accepted-but-unserved
//! request is shed through the same accounting the in-process dispatcher
//! uses for deregistered tapes, so the fleet-wide drain invariant
//! `submitted − completed − shed == 0` holds with workers dying
//! mid-replay. Submits routed to a dead shard fail with
//! [`SubmitError::ShardDown`] (not `Busy`: there is nothing to retry
//! against) until a replacement worker connects, takes over the dead
//! shard id and its catalog partition, and starts a fresh era;
//! [`merge_snapshots`] stitches the eras back into one shard history.

use std::collections::BTreeMap;
use std::io;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Duration;

use crate::cluster::{
    merge_snapshots, partition_catalog, HashRing, ShardBackend, ShardSet,
};
use crate::coordinator::{
    Completion, CoordinatorConfig, MetricsSnapshot, ReadRequest, SubmitError,
};
use crate::model::Tape;

use super::frame::{read_frame, write_frame};
use super::wire::{self, Message, Role, SubmitOutcome, PROTOCOL_VERSION};

/// Configuration for [`serve`] — the `tapesched coordinator` subcommand.
#[derive(Debug, Clone)]
pub struct CoordinatorServerConfig {
    /// Ring size; the fleet is ready once this many workers have joined.
    pub n_shards: usize,
    /// Virtual nodes per shard on the ring.
    pub vnodes: usize,
    /// Per-shard coordinator configuration, shipped to every worker.
    pub shard: CoordinatorConfig,
    /// Scheduler policy name (resolved by the worker via
    /// `sched::scheduler_by_name`).
    pub policy: String,
    /// Fault injection for the robustness gate: cut shard `.0`'s
    /// connection right after it accepts its `.1`-th submit. One-shot — a
    /// rejoining worker is not re-killed.
    pub kill: Option<(usize, u64)>,
}

fn send(stream: &mut TcpStream, msg: &Message) -> io::Result<()> {
    write_frame(stream, &wire::encode(msg)).map_err(io::Error::from)
}

fn recv(stream: &mut TcpStream) -> io::Result<Option<Message>> {
    match read_frame(stream) {
        Ok(None) => Ok(None),
        Ok(Some(payload)) => Ok(Some(wire::decode(&payload)?)),
        Err(e) => Err(e.into()),
    }
}

struct WorkerState {
    /// Live connection; `None` while the shard has no worker.
    conn: Option<TcpStream>,
    /// A worker handshake for this shard is in progress (blocks a second
    /// joiner from grabbing the same id).
    joining: bool,
    /// The shard has had a live worker at least once (fleet readiness
    /// counts dead-but-created shards — their accounting is carried).
    ever_live: bool,
    /// Terminal: the shard was drained; submits fail with `Stopping`.
    drained: bool,
    /// Submits the *current* worker accepted, counted on this side of the
    /// wire — the ground truth for shed synthesis when it dies.
    accepted_era: u64,
    /// Most recent snapshot pulled from the current worker.
    last: Option<MetricsSnapshot>,
    /// Merged accounting of all dead eras (see [`merge_snapshots`]).
    carry: Option<MetricsSnapshot>,
    /// One-shot kill trigger (fault injection), armed on the target shard.
    kill_after: Option<u64>,
}

/// The remote arm of the [`ShardBackend`] seam: one shard served by a TCP
/// worker. The state lock is held across each request/response pair, so
/// request/reply frames can never interleave on the connection.
struct WorkerShard {
    shard: usize,
    state: Mutex<WorkerState>,
}

impl WorkerShard {
    fn new(shard: usize, kill_after: Option<u64>) -> WorkerShard {
        WorkerShard {
            shard,
            state: Mutex::new(WorkerState {
                conn: None,
                joining: false,
                ever_live: false,
                drained: false,
                accepted_era: 0,
                last: None,
                carry: None,
                kill_after,
            }),
        }
    }

    /// The worker is gone: fold the era's accounting into the carry.
    /// Everything it accepted but had not completed at the last pull is
    /// shed — the drain invariant stays exact fleet-wide.
    fn die(st: &mut WorkerState) {
        st.conn = None;
        let mut synth = st.last.take().unwrap_or_default();
        synth.submitted = st.accepted_era;
        synth.shed = st.accepted_era.saturating_sub(synth.completed);
        st.carry = Some(match st.carry.take() {
            Some(c) => merge_snapshots(&c, &synth),
            None => synth,
        });
        st.accepted_era = 0;
    }

    fn carry_or_default(st: &WorkerState) -> MetricsSnapshot {
        st.carry.clone().unwrap_or_default()
    }

    fn round_trip(conn: &mut TcpStream, msg: &Message) -> io::Result<Message> {
        send(conn, msg)?;
        match recv(conn)? {
            Some(reply) => Ok(reply),
            None => Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "worker closed mid-request",
            )),
        }
    }
}

impl ShardBackend for WorkerShard {
    fn submit(&self, req: ReadRequest) -> Result<(), SubmitError> {
        let mut st = self.state.lock().unwrap();
        if st.drained {
            return Err(SubmitError::Stopping);
        }
        if st.conn.is_none() {
            return Err(SubmitError::ShardDown);
        }
        let msg = Message::Submit {
            id: req.id,
            tape: req.tape,
            file_index: req.file_index as u64,
        };
        let reply = WorkerShard::round_trip(st.conn.as_mut().unwrap(), &msg);
        let outcome = match reply {
            Ok(Message::SubmitResult { outcome }) => outcome,
            Ok(_) | Err(_) => {
                WorkerShard::die(&mut st);
                return Err(SubmitError::ShardDown);
            }
        };
        if outcome == SubmitOutcome::Accepted {
            st.accepted_era += 1;
            if st.kill_after.map_or(false, |n| st.accepted_era >= n) {
                // Fault injection: the request was accepted, then the
                // worker "crashes" — the shed synthesis must cover it.
                st.kill_after = None;
                if let Some(c) = &st.conn {
                    c.shutdown(Shutdown::Both).ok();
                }
                WorkerShard::die(&mut st);
            }
        }
        outcome.into_submit()
    }

    fn metrics(&self) -> MetricsSnapshot {
        let mut st = self.state.lock().unwrap();
        if st.drained || st.conn.is_none() {
            return WorkerShard::carry_or_default(&st);
        }
        let reply = WorkerShard::round_trip(st.conn.as_mut().unwrap(), &Message::MetricsPull);
        match reply {
            Ok(Message::MetricsReply { loads }) => {
                let m = loads
                    .into_iter()
                    .find(|l| l.shard == self.shard)
                    .map(|l| l.metrics)
                    .unwrap_or_default();
                st.last = Some(m.clone());
                match &st.carry {
                    Some(c) => merge_snapshots(c, &m),
                    None => m,
                }
            }
            Ok(_) | Err(_) => {
                WorkerShard::die(&mut st);
                WorkerShard::carry_or_default(&st)
            }
        }
    }

    fn drain(&self) -> (Vec<Completion>, MetricsSnapshot) {
        let mut st = self.state.lock().unwrap();
        if st.drained {
            return (Vec::new(), WorkerShard::carry_or_default(&st));
        }
        st.drained = true;
        if st.conn.is_none() {
            // Already died: the carry IS the shard's final accounting.
            return (Vec::new(), WorkerShard::carry_or_default(&st));
        }
        let reply = WorkerShard::round_trip(st.conn.as_mut().unwrap(), &Message::Drain);
        match reply {
            Ok(Message::DrainResult { completions, loads }) => {
                let fin = loads
                    .into_iter()
                    .find(|l| l.shard == self.shard)
                    .map(|l| l.metrics)
                    .unwrap_or_default();
                let merged = match &st.carry {
                    Some(c) => merge_snapshots(c, &fin),
                    None => fin,
                };
                st.carry = Some(merged.clone());
                if let Some(conn) = st.conn.as_mut() {
                    send(conn, &Message::Shutdown).ok();
                }
                st.conn = None;
                st.last = None;
                st.accepted_era = 0;
                (completions, merged)
            }
            Ok(_) | Err(_) => {
                WorkerShard::die(&mut st);
                (Vec::new(), WorkerShard::carry_or_default(&st))
            }
        }
    }
}

struct ServerState {
    set: RwLock<ShardSet>,
    members: Mutex<BTreeMap<usize, Arc<WorkerShard>>>,
    fleet_ready: Condvar,
    done: AtomicBool,
    partitions: BTreeMap<usize, Vec<Tape>>,
    shard_cfg: CoordinatorConfig,
    policy: String,
    n_shards: usize,
    kill: Option<(usize, u64)>,
}

impl ServerState {
    /// All `n_shards` have been live at least once (a shard whose worker
    /// died still counts: its accounting is carried and submits to it
    /// report `ShardDown` rather than wedging the fleet).
    fn fleet_ready(members: &BTreeMap<usize, Arc<WorkerShard>>, n_shards: usize) -> bool {
        members.len() == n_shards
            && members.values().all(|w| w.state.lock().unwrap().ever_live)
    }

    fn wait_fleet_ready(&self) {
        let mut members = self.members.lock().unwrap();
        while !ServerState::fleet_ready(&members, self.n_shards)
            && !self.done.load(Ordering::SeqCst)
        {
            let (guard, _) = self
                .fleet_ready
                .wait_timeout(members, Duration::from_millis(50))
                .unwrap();
            members = guard;
        }
    }
}

/// Serve a fleet on `listener` until a client drains or shuts it down.
/// This is `tapesched coordinator --listen ADDR --shards N`: bind first,
/// then call `serve` — workers and clients may connect in any order
/// (clients block until all `n_shards` workers have joined).
pub fn serve(
    listener: TcpListener,
    cfg: CoordinatorServerConfig,
    catalog: Vec<Tape>,
) -> io::Result<()> {
    assert!(cfg.n_shards > 0, "a fleet needs at least one shard");
    let ring = HashRing::new(cfg.n_shards, cfg.vnodes);
    let partitions = partition_catalog(&ring, catalog);
    let state = Arc::new(ServerState {
        set: RwLock::new(ShardSet::new(ring)),
        members: Mutex::new(BTreeMap::new()),
        fleet_ready: Condvar::new(),
        done: AtomicBool::new(false),
        partitions,
        shard_cfg: cfg.shard,
        policy: cfg.policy,
        n_shards: cfg.n_shards,
        kill: cfg.kill,
    });
    // Poll accept so the loop can observe `done` (set by the draining
    // client's handler thread) without a self-connection trick.
    listener.set_nonblocking(true)?;
    while !state.done.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let state = Arc::clone(&state);
                // Handler threads are detached: they exit on client EOF,
                // and the drain handler replies before flagging `done`.
                std::thread::spawn(move || {
                    let _ = handle_connection(state, stream);
                });
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

fn handle_connection(state: Arc<ServerState>, mut stream: TcpStream) -> io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true).ok();
    match recv(&mut stream)? {
        Some(Message::Hello { version, role }) => {
            if version != PROTOCOL_VERSION {
                send(
                    &mut stream,
                    &Message::Error {
                        message: format!(
                            "protocol version mismatch: coordinator speaks \
                             {PROTOCOL_VERSION}, peer speaks {version}"
                        ),
                    },
                )?;
                return Ok(());
            }
            match role {
                Role::Worker => handle_worker(state, stream),
                Role::Client => handle_client(state, stream),
            }
        }
        other => {
            send(
                &mut stream,
                &Message::Error {
                    message: format!("expected Hello, got {other:?}"),
                },
            )?;
            Ok(())
        }
    }
}

/// Assign the joining worker a shard — the lowest id that never had a
/// worker, else the lowest whose worker died (a rejoin: it inherits the
/// dead shard's id, catalog partition, and carried accounting) — then run
/// the handshake and mark the shard live.
fn handle_worker(state: Arc<ServerState>, mut stream: TcpStream) -> io::Result<()> {
    let (id, shard_arc, fresh) = {
        let mut members = state.members.lock().unwrap();
        let mut pick = None;
        for id in 0..state.n_shards {
            match members.get(&id) {
                None => {
                    pick = Some(id);
                    break;
                }
                Some(ws) => {
                    let mut st = ws.state.lock().unwrap();
                    if st.conn.is_none() && !st.drained && !st.joining {
                        st.joining = true;
                        pick = Some(id);
                        break;
                    }
                }
            }
        }
        let Some(id) = pick else {
            send(
                &mut stream,
                &Message::Error { message: "no shard available for a worker".into() },
            )?;
            return Ok(());
        };
        match members.get(&id) {
            Some(ws) => (id, Arc::clone(ws), false),
            None => {
                let kill_after =
                    state.kill.and_then(|(s, n)| (s == id).then_some(n));
                let ws = Arc::new(WorkerShard::new(id, kill_after));
                ws.state.lock().unwrap().joining = true;
                members.insert(id, Arc::clone(&ws));
                (id, ws, true)
            }
        }
    };
    if fresh {
        state.set.write().unwrap().attach(id, Arc::clone(&shard_arc) as Arc<dyn ShardBackend>);
    }
    let handshake = (|| -> io::Result<()> {
        send(
            &mut stream,
            &Message::HelloAck { version: PROTOCOL_VERSION, shard: id as u32 },
        )?;
        send(
            &mut stream,
            &Message::Assign {
                shard: id as u32,
                policy: state.policy.clone(),
                config: state.shard_cfg.clone(),
                catalog: state.partitions.get(&id).cloned().unwrap_or_default(),
            },
        )?;
        match recv(&mut stream)? {
            Some(Message::AssignAck { shard }) if shard == id as u32 => Ok(()),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected AssignAck for shard {id}, got {other:?}"),
            )),
        }
    })();
    {
        let mut st = shard_arc.state.lock().unwrap();
        st.joining = false;
        if handshake.is_ok() {
            st.conn = Some(stream);
            st.ever_live = true;
        }
    }
    // Wake clients blocked on fleet readiness (the members mutex is the
    // condvar's companion; notify without it is fine — waiters re-check).
    state.fleet_ready.notify_all();
    handshake
}

fn handle_client(state: Arc<ServerState>, mut stream: TcpStream) -> io::Result<()> {
    send(
        &mut stream,
        &Message::HelloAck { version: PROTOCOL_VERSION, shard: u32::MAX },
    )?;
    // Block until every shard has a worker: the ShardSet routes over all
    // of them, and a half-joined fleet would misreport ShardDown.
    state.wait_fleet_ready();
    loop {
        match recv(&mut stream)? {
            None => return Ok(()),
            Some(Message::Submit { id, tape, file_index }) => {
                let result = state.set.read().unwrap().submit(ReadRequest {
                    id,
                    tape,
                    file_index: file_index as usize,
                });
                send(
                    &mut stream,
                    &Message::SubmitResult {
                        outcome: SubmitOutcome::from_submit(&result),
                    },
                )?;
            }
            Some(Message::MetricsPull) => {
                let loads = state.set.read().unwrap().loads();
                send(&mut stream, &Message::MetricsReply { loads })?;
            }
            Some(Message::Drain) => {
                let (completions, loads) = state.set.read().unwrap().drain();
                send(&mut stream, &Message::DrainResult { completions, loads })?;
                // Reply first, then stop the accept loop: the frame is in
                // the socket before the process can exit.
                state.done.store(true, Ordering::SeqCst);
                return Ok(());
            }
            Some(Message::Shutdown) => {
                // Abandon without draining: tell live workers to exit.
                let members = state.members.lock().unwrap();
                for ws in members.values() {
                    let mut st = ws.state.lock().unwrap();
                    if let Some(conn) = st.conn.as_mut() {
                        send(conn, &Message::Shutdown).ok();
                    }
                    st.conn = None;
                }
                drop(members);
                state.done.store(true, Ordering::SeqCst);
                return Ok(());
            }
            Some(other) => {
                send(
                    &mut stream,
                    &Message::Error {
                        message: format!("coordinator cannot serve {other:?}"),
                    },
                )?;
                return Ok(());
            }
        }
    }
}
