//! The coordinator process: owns the consistent-hash ring, routes client
//! submits to TCP workers, and keeps the fleet's accounting exact across
//! worker deaths.
//!
//! ```text
//!   clients ──Submit──▶ ┌──────────────────────────────┐
//!                       │ serve(): ShardSet over        │
//!                       │ WorkerShard backends (ring)   │
//!                       └──┬──────────┬──────────┬──────┘
//!                          ▼          ▼          ▼
//!                     worker 0    worker 1    worker 2     (TCP, one
//!                     Coordinator Coordinator Coordinator   shard each)
//! ```
//!
//! Each connected worker is wrapped in a [`WorkerShard`] — the remote arm
//! of the [`ShardBackend`] seam — and attached to a [`ShardSet`], so the
//! routing layer is byte-identical to the in-process one: same ring, same
//! per-shard routed counters, same rollup.
//!
//! ## Dead workers and the drain invariant
//!
//! The per-shard connection lock is held across each request/response
//! pair, so the coordinator always knows exactly how many submits the
//! worker *accepted* this era (`accepted_era`). When the connection dies,
//! the shard's lost work is synthesized into a carried snapshot:
//! `submitted := accepted_era`, `completed := last pulled completed`,
//! `shed := accepted_era − completed` — every accepted-but-unserved
//! request is shed through the same accounting the in-process dispatcher
//! uses for deregistered tapes, so the fleet-wide drain invariant
//! `submitted − completed − shed == 0` holds with workers dying
//! mid-replay. Submits routed to a dead shard fail with
//! [`SubmitError::ShardDown`] (not `Busy`: there is nothing to retry
//! against) until a replacement worker connects, takes over the dead
//! shard id and its catalog partition, and starts a fresh era;
//! [`merge_snapshots`] stitches the eras back into one shard history.
//!
//! ## Push telemetry is advisory, drains are authoritative
//!
//! With `push_ms > 0` every worker opens a second connection
//! ([`Role::MetricsPusher`]) and streams `MetricsPush` snapshots on that
//! interval. Those land in `WorkerState::pushed` and feed exactly three
//! read-only consumers: the `--metrics-listen` exposition page, the
//! [`Role::MetricsSubscriber`] stream (which lets clients keep an
//! `in_flight` gauge without a `MetricsPull` round trip per submit), and
//! nothing else. The drain path, the shed synthesis in
//! [`fold_dead_era`], and the parity-critical rollups never read a pushed
//! snapshot — so a stale push from a dying worker's telemetry thread can
//! at worst make a scrape momentarily optimistic, never corrupt the
//! drain invariant. Pushes for a shard with no live worker are ignored.

use std::collections::BTreeMap;
use std::io;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::Duration;

use crate::cluster::{
    merge_snapshots, partition_catalog, HashRing, ShardBackend, ShardSet,
};
use crate::coordinator::{
    debug_assert_drain_invariant, Completion, CoordinatorConfig, MetricsSnapshot, ReadRequest,
    SubmitError,
};
use crate::model::Tape;
use crate::obs::{write_counter, write_gauge, write_type, ExpositionServer, Registry};
use crate::util::sync::{lock_recover, read_recover, wait_timeout_recover, write_recover};

use super::frame::{read_frame, write_frame};
use super::wire::{self, Message, Role, SubmitOutcome, PROTOCOL_VERSION};

/// Configuration for [`serve`] — the `tapesched coordinator` subcommand.
#[derive(Debug, Clone)]
pub struct CoordinatorServerConfig {
    /// Ring size; the fleet is ready once this many workers have joined.
    pub n_shards: usize,
    /// Virtual nodes per shard on the ring.
    pub vnodes: usize,
    /// Per-shard coordinator configuration, shipped to every worker.
    pub shard: CoordinatorConfig,
    /// Scheduler policy name (resolved by the worker via
    /// `sched::scheduler_by_name`).
    pub policy: String,
    /// Fault injection for the robustness gate: cut shard `.0`'s
    /// connection right after it accepts its `.1`-th submit. One-shot — a
    /// rejoining worker is not re-killed.
    pub kill: Option<(usize, u64)>,
    /// Telemetry push interval shipped to every worker in `Assign`.
    /// `0` disables push telemetry (workers open no pusher connection,
    /// clients fall back to `MetricsPull`).
    pub push_ms: u64,
    /// Bind a Prometheus-style exposition endpoint here (e.g.
    /// `127.0.0.1:9187`). `None` disables the scrape surface.
    pub metrics_listen: Option<String>,
}

fn send(stream: &mut TcpStream, msg: &Message) -> io::Result<()> {
    write_frame(stream, &wire::encode(msg)).map_err(io::Error::from)
}

fn recv(stream: &mut TcpStream) -> io::Result<Option<Message>> {
    match read_frame(stream) {
        Ok(None) => Ok(None),
        Ok(Some(payload)) => Ok(Some(wire::decode(&payload)?)),
        Err(e) => Err(e.into()),
    }
}

struct WorkerState {
    /// Live connection; `None` while the shard has no worker.
    conn: Option<TcpStream>,
    /// A worker handshake for this shard is in progress (blocks a second
    /// joiner from grabbing the same id).
    joining: bool,
    /// The shard has had a live worker at least once (fleet readiness
    /// counts dead-but-created shards — their accounting is carried).
    ever_live: bool,
    /// Terminal: the shard was drained; submits fail with `Stopping`.
    drained: bool,
    /// Submits the *current* worker accepted, counted on this side of the
    /// wire — the ground truth for shed synthesis when it dies.
    accepted_era: u64,
    /// Most recent snapshot pulled from the current worker.
    last: Option<MetricsSnapshot>,
    /// Most recent snapshot *pushed* by the current worker's telemetry
    /// connection. Advisory only: read by the exposition page and the
    /// subscriber stream, never by drain or shed accounting.
    pushed: Option<MetricsSnapshot>,
    /// Merged accounting of all dead eras (see [`merge_snapshots`]).
    carry: Option<MetricsSnapshot>,
    /// One-shot kill trigger (fault injection), armed on the target shard.
    kill_after: Option<u64>,
}

/// Fold a dead era into the shard's carried accounting — the pure core of
/// [`WorkerShard::die`]. `last` is the freshest snapshot *pulled* from the
/// worker before it died; `accepted_era` is this side's count of submits
/// the worker accepted. Everything accepted but not seen completed is
/// shed, so the result always satisfies `submitted == completed + shed`
/// (completions the worker finished after the last pull are lost with the
/// connection — they never reached a client, so counting them shed is the
/// honest ledger).
fn fold_dead_era(
    carry: Option<MetricsSnapshot>,
    last: Option<MetricsSnapshot>,
    accepted_era: u64,
) -> MetricsSnapshot {
    let mut synth = last.unwrap_or_default();
    synth.submitted = accepted_era;
    synth.shed = accepted_era.saturating_sub(synth.completed);
    debug_assert_drain_invariant(synth.submitted, synth.completed, synth.shed, "fold_dead_era");
    match carry {
        Some(c) => merge_snapshots(&c, &synth),
        None => synth,
    }
}

/// The remote arm of the [`ShardBackend`] seam: one shard served by a TCP
/// worker. The state lock is held across each request/response pair, so
/// request/reply frames can never interleave on the connection.
struct WorkerShard {
    shard: usize,
    state: Mutex<WorkerState>,
}

impl WorkerShard {
    fn new(shard: usize, kill_after: Option<u64>) -> WorkerShard {
        WorkerShard {
            shard,
            state: Mutex::new(WorkerState {
                conn: None,
                joining: false,
                ever_live: false,
                drained: false,
                accepted_era: 0,
                last: None,
                pushed: None,
                carry: None,
                kill_after,
            }),
        }
    }

    /// The worker is gone: fold the era's accounting into the carry.
    /// Everything it accepted but had not completed at the last pull is
    /// shed — the drain invariant stays exact fleet-wide.
    fn die(st: &mut WorkerState) {
        st.conn = None;
        st.pushed = None;
        let last = st.last.take();
        st.carry = Some(fold_dead_era(st.carry.take(), last, st.accepted_era));
        st.accepted_era = 0;
    }

    fn carry_or_default(st: &WorkerState) -> MetricsSnapshot {
        st.carry.clone().unwrap_or_default()
    }

    /// Best current guess at the shard's accounting *without a worker
    /// round trip*: carried history merged with the freshest era snapshot
    /// on hand (a push if the worker pushes, else the last pull).
    /// Advisory — feeds the exposition page and the subscriber stream
    /// only; drains re-pull the authoritative numbers.
    fn advisory(st: &WorkerState) -> MetricsSnapshot {
        let era = st.pushed.clone().or_else(|| st.last.clone()).unwrap_or_default();
        match &st.carry {
            Some(c) => merge_snapshots(c, &era),
            None => era,
        }
    }

    /// The error a round trip reports when the connection slot emptied
    /// between the liveness check and the send (a concurrent `die`). The
    /// callers' `Err` handling treats it exactly like a mid-request
    /// hangup, so the shard degrades to its carried accounting.
    fn conn_lost_error() -> io::Error {
        io::Error::new(io::ErrorKind::NotConnected, "worker connection lost")
    }

    fn round_trip(conn: &mut TcpStream, msg: &Message) -> io::Result<Message> {
        send(conn, msg)?;
        match recv(conn)? {
            Some(reply) => Ok(reply),
            None => Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "worker closed mid-request",
            )),
        }
    }
}

impl ShardBackend for WorkerShard {
    fn submit(&self, req: ReadRequest) -> Result<(), SubmitError> {
        let mut st = lock_recover(&self.state, "shard submit");
        if st.drained {
            return Err(SubmitError::Stopping);
        }
        if st.conn.is_none() {
            return Err(SubmitError::ShardDown);
        }
        let msg = Message::Submit {
            id: req.id,
            tape: req.tape,
            file_index: req.file_index as u64,
        };
        let reply = match st.conn.as_mut() {
            Some(conn) => WorkerShard::round_trip(conn, &msg),
            None => Err(WorkerShard::conn_lost_error()),
        };
        let outcome = match reply {
            Ok(Message::SubmitResult { outcome }) => outcome,
            Ok(_) | Err(_) => {
                WorkerShard::die(&mut st);
                return Err(SubmitError::ShardDown);
            }
        };
        if outcome == SubmitOutcome::Accepted {
            st.accepted_era += 1;
            if st.kill_after.map_or(false, |n| st.accepted_era >= n) {
                // Fault injection: the request was accepted, then the
                // worker "crashes" — the shed synthesis must cover it.
                st.kill_after = None;
                if let Some(c) = &st.conn {
                    c.shutdown(Shutdown::Both).ok();
                }
                WorkerShard::die(&mut st);
            }
        }
        outcome.into_submit()
    }

    fn metrics(&self) -> MetricsSnapshot {
        let mut st = lock_recover(&self.state, "shard metrics");
        if st.drained || st.conn.is_none() {
            return WorkerShard::carry_or_default(&st);
        }
        let reply = match st.conn.as_mut() {
            Some(conn) => WorkerShard::round_trip(conn, &Message::MetricsPull),
            None => Err(WorkerShard::conn_lost_error()),
        };
        match reply {
            Ok(Message::MetricsReply { loads }) => {
                let m = loads
                    .into_iter()
                    .find(|l| l.shard == self.shard)
                    .map(|l| l.metrics)
                    .unwrap_or_default();
                st.last = Some(m.clone());
                match &st.carry {
                    Some(c) => merge_snapshots(c, &m),
                    None => m,
                }
            }
            Ok(_) | Err(_) => {
                WorkerShard::die(&mut st);
                WorkerShard::carry_or_default(&st)
            }
        }
    }

    fn drain(&self) -> (Vec<Completion>, MetricsSnapshot) {
        let mut st = lock_recover(&self.state, "shard drain");
        if st.drained {
            return (Vec::new(), WorkerShard::carry_or_default(&st));
        }
        st.drained = true;
        if st.conn.is_none() {
            // Already died: the carry IS the shard's final accounting.
            return (Vec::new(), WorkerShard::carry_or_default(&st));
        }
        let reply = match st.conn.as_mut() {
            Some(conn) => WorkerShard::round_trip(conn, &Message::Drain),
            None => Err(WorkerShard::conn_lost_error()),
        };
        match reply {
            Ok(Message::DrainResult { completions, loads }) => {
                let fin = loads
                    .into_iter()
                    .find(|l| l.shard == self.shard)
                    .map(|l| l.metrics)
                    .unwrap_or_default();
                let merged = match &st.carry {
                    Some(c) => merge_snapshots(c, &fin),
                    None => fin,
                };
                st.carry = Some(merged.clone());
                if let Some(conn) = st.conn.as_mut() {
                    send(conn, &Message::Shutdown).ok();
                }
                st.conn = None;
                st.last = None;
                st.pushed = None;
                st.accepted_era = 0;
                (completions, merged)
            }
            Ok(_) | Err(_) => {
                WorkerShard::die(&mut st);
                (Vec::new(), WorkerShard::carry_or_default(&st))
            }
        }
    }
}

struct ServerState {
    set: RwLock<ShardSet>,
    members: Mutex<BTreeMap<usize, Arc<WorkerShard>>>,
    fleet_ready: Condvar,
    done: AtomicBool,
    partitions: BTreeMap<usize, Vec<Tape>>,
    shard_cfg: CoordinatorConfig,
    policy: String,
    n_shards: usize,
    kill: Option<(usize, u64)>,
    push_ms: u64,
}

impl ServerState {
    /// All `n_shards` have been live at least once (a shard whose worker
    /// died still counts: its accounting is carried and submits to it
    /// report `ShardDown` rather than wedging the fleet).
    fn fleet_ready(members: &BTreeMap<usize, Arc<WorkerShard>>, n_shards: usize) -> bool {
        members.len() == n_shards
            && members.values().all(|w| lock_recover(&w.state, "fleet_ready").ever_live)
    }

    fn wait_fleet_ready(&self) {
        let mut members = lock_recover(&self.members, "wait_fleet_ready");
        while !ServerState::fleet_ready(&members, self.n_shards)
            && !self.done.load(Ordering::SeqCst)
        {
            members = wait_timeout_recover(
                &self.fleet_ready,
                members,
                Duration::from_millis(50),
                "wait_fleet_ready",
            );
        }
    }
}

/// Serve a fleet on `listener` until a client drains or shuts it down.
/// This is `tapesched coordinator --listen ADDR --shards N`: bind first,
/// then call `serve` — workers and clients may connect in any order
/// (clients block until all `n_shards` workers have joined).
pub fn serve(
    listener: TcpListener,
    cfg: CoordinatorServerConfig,
    catalog: Vec<Tape>,
) -> io::Result<()> {
    assert!(cfg.n_shards > 0, "a fleet needs at least one shard");
    let ring = HashRing::new(cfg.n_shards, cfg.vnodes);
    let partitions = partition_catalog(&ring, catalog);
    let metrics_listen = cfg.metrics_listen.clone();
    let state = Arc::new(ServerState {
        set: RwLock::new(ShardSet::new(ring)),
        members: Mutex::new(BTreeMap::new()),
        fleet_ready: Condvar::new(),
        done: AtomicBool::new(false),
        partitions,
        shard_cfg: cfg.shard,
        policy: cfg.policy,
        n_shards: cfg.n_shards,
        kill: cfg.kill,
        push_ms: cfg.push_ms,
    });
    // The scrape endpoint renders the advisory per-shard accounting at
    // scrape time — no copied values. Dropped (stopped + joined) when
    // serve returns.
    let _exposition = match &metrics_listen {
        Some(addr) => {
            let registry = Arc::new(Registry::new());
            register_fleet_exposition(&state, &registry);
            Some(ExpositionServer::bind(addr, registry)?)
        }
        None => None,
    };
    // Poll accept so the loop can observe `done` (set by the draining
    // client's handler thread) without a self-connection trick.
    listener.set_nonblocking(true)?;
    while !state.done.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let state = Arc::clone(&state);
                // Handler threads are detached: they exit on client EOF,
                // and the drain handler replies before flagging `done`.
                std::thread::spawn(move || {
                    let _ = handle_connection(state, stream);
                });
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

fn handle_connection(state: Arc<ServerState>, mut stream: TcpStream) -> io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true).ok();
    match recv(&mut stream)? {
        Some(Message::Hello { version, role }) => {
            if version != PROTOCOL_VERSION {
                send(
                    &mut stream,
                    &Message::Error {
                        message: format!(
                            "protocol version mismatch: coordinator speaks \
                             {PROTOCOL_VERSION}, peer speaks {version}"
                        ),
                    },
                )?;
                return Ok(());
            }
            match role {
                Role::Worker => handle_worker(state, stream),
                Role::Client => handle_client(state, stream),
                Role::MetricsPusher => handle_pusher(state, stream),
                Role::MetricsSubscriber => handle_subscriber(state, stream),
            }
        }
        other => {
            send(
                &mut stream,
                &Message::Error {
                    message: format!("expected Hello, got {other:?}"),
                },
            )?;
            Ok(())
        }
    }
}

/// Assign the joining worker a shard — the lowest id that never had a
/// worker, else the lowest whose worker died (a rejoin: it inherits the
/// dead shard's id, catalog partition, and carried accounting) — then run
/// the handshake and mark the shard live.
fn handle_worker(state: Arc<ServerState>, mut stream: TcpStream) -> io::Result<()> {
    let (id, shard_arc, fresh) = {
        let mut members = lock_recover(&state.members, "worker join");
        let mut pick = None;
        for id in 0..state.n_shards {
            match members.get(&id) {
                None => {
                    pick = Some(id);
                    break;
                }
                Some(ws) => {
                    let mut st = lock_recover(&ws.state, "worker join pick");
                    if st.conn.is_none() && !st.drained && !st.joining {
                        st.joining = true;
                        pick = Some(id);
                        break;
                    }
                }
            }
        }
        let Some(id) = pick else {
            send(
                &mut stream,
                &Message::Error { message: "no shard available for a worker".into() },
            )?;
            return Ok(());
        };
        match members.get(&id) {
            Some(ws) => (id, Arc::clone(ws), false),
            None => {
                let kill_after =
                    state.kill.and_then(|(s, n)| (s == id).then_some(n));
                let ws = Arc::new(WorkerShard::new(id, kill_after));
                lock_recover(&ws.state, "worker join fresh").joining = true;
                members.insert(id, Arc::clone(&ws));
                (id, ws, true)
            }
        }
    };
    if fresh {
        write_recover(&state.set, "worker attach")
            .attach(id, Arc::clone(&shard_arc) as Arc<dyn ShardBackend>);
    }
    let handshake = (|| -> io::Result<()> {
        send(
            &mut stream,
            &Message::HelloAck { version: PROTOCOL_VERSION, shard: id as u32 },
        )?;
        send(
            &mut stream,
            &Message::Assign {
                shard: id as u32,
                policy: state.policy.clone(),
                config: state.shard_cfg.clone(),
                catalog: state.partitions.get(&id).cloned().unwrap_or_default(),
                push_ms: state.push_ms,
            },
        )?;
        match recv(&mut stream)? {
            Some(Message::AssignAck { shard }) if shard == id as u32 => Ok(()),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected AssignAck for shard {id}, got {other:?}"),
            )),
        }
    })();
    {
        let mut st = lock_recover(&shard_arc.state, "worker handshake finish");
        st.joining = false;
        if handshake.is_ok() {
            st.conn = Some(stream);
            st.ever_live = true;
        }
    }
    // Wake clients blocked on fleet readiness (the members mutex is the
    // condvar's companion; notify without it is fine — waiters re-check).
    state.fleet_ready.notify_all();
    handshake
}

fn handle_client(state: Arc<ServerState>, mut stream: TcpStream) -> io::Result<()> {
    send(
        &mut stream,
        &Message::HelloAck { version: PROTOCOL_VERSION, shard: u32::MAX },
    )?;
    // Block until every shard has a worker: the ShardSet routes over all
    // of them, and a half-joined fleet would misreport ShardDown.
    state.wait_fleet_ready();
    loop {
        match recv(&mut stream)? {
            None => return Ok(()),
            Some(Message::Submit { id, tape, file_index }) => {
                let result = read_recover(&state.set, "client submit").submit(ReadRequest {
                    id,
                    tape,
                    file_index: file_index as usize,
                });
                send(
                    &mut stream,
                    &Message::SubmitResult {
                        outcome: SubmitOutcome::from_submit(&result),
                    },
                )?;
            }
            Some(Message::MetricsPull) => {
                let loads = read_recover(&state.set, "client pull").loads();
                send(&mut stream, &Message::MetricsReply { loads })?;
            }
            Some(Message::Drain) => {
                let (completions, loads) = read_recover(&state.set, "client drain").drain();
                send(&mut stream, &Message::DrainResult { completions, loads })?;
                // Reply first, then stop the accept loop: the frame is in
                // the socket before the process can exit.
                state.done.store(true, Ordering::SeqCst);
                return Ok(());
            }
            Some(Message::Shutdown) => {
                // Abandon without draining: tell live workers to exit.
                let members = lock_recover(&state.members, "client shutdown");
                for ws in members.values() {
                    let mut st = lock_recover(&ws.state, "client shutdown shard");
                    if let Some(conn) = st.conn.as_mut() {
                        send(conn, &Message::Shutdown).ok();
                    }
                    st.conn = None;
                }
                drop(members);
                state.done.store(true, Ordering::SeqCst);
                return Ok(());
            }
            Some(other) => {
                send(
                    &mut stream,
                    &Message::Error {
                        message: format!("coordinator cannot serve {other:?}"),
                    },
                )?;
                return Ok(());
            }
        }
    }
}

/// A worker's telemetry side-connection: absorb each pushed snapshot into
/// the owning shard's advisory state and ack it. The worker is the sole
/// initiator here — the main worker connection stays strictly
/// request/response, so pushes can never interleave with an in-flight
/// submit round trip. Pushes for a shard whose worker is gone or drained
/// are dropped: a dying worker's last push must not resurrect accounting
/// that [`WorkerShard::die`] already folded.
fn handle_pusher(state: Arc<ServerState>, mut stream: TcpStream) -> io::Result<()> {
    send(
        &mut stream,
        &Message::HelloAck { version: PROTOCOL_VERSION, shard: u32::MAX },
    )?;
    loop {
        match recv(&mut stream)? {
            None | Some(Message::Shutdown) => return Ok(()),
            Some(Message::MetricsPush { loads }) => {
                {
                    let members = lock_recover(&state.members, "pusher absorb");
                    for load in loads {
                        if let Some(ws) = members.get(&load.shard) {
                            let mut st = lock_recover(&ws.state, "pusher absorb shard");
                            if st.conn.is_some() && !st.drained {
                                st.pushed = Some(load.metrics);
                            }
                        }
                    }
                }
                send(&mut stream, &Message::MetricsPushAck)?;
            }
            Some(other) => {
                send(
                    &mut stream,
                    &Message::Error {
                        message: format!("pusher connection cannot serve {other:?}"),
                    },
                )?;
                return Ok(());
            }
        }
    }
}

/// Advisory per-shard loads, composed entirely from state already on this
/// side of the wire — zero worker round trips (that is the whole point of
/// the push path). `routed` is reported as 0: the subscriber stream and
/// the scrape page consume the metrics sums, not the router counters.
fn advisory_loads(state: &ServerState) -> Vec<crate::cluster::ShardLoad> {
    let members = lock_recover(&state.members, "advisory loads");
    members
        .iter()
        .map(|(id, ws)| crate::cluster::ShardLoad {
            shard: *id,
            routed: 0,
            metrics: WorkerShard::advisory(&lock_recover(&ws.state, "advisory loads shard")),
        })
        .collect()
}

/// A client's telemetry side-connection: the *server* initiates here,
/// pushing advisory fleet loads on the configured interval; the client
/// acks each push. Exits when the fleet is done or the client hangs up.
fn handle_subscriber(state: Arc<ServerState>, mut stream: TcpStream) -> io::Result<()> {
    send(
        &mut stream,
        &Message::HelloAck { version: PROTOCOL_VERSION, shard: u32::MAX },
    )?;
    let interval = Duration::from_millis(if state.push_ms > 0 { state.push_ms } else { 100 });
    while !state.done.load(Ordering::SeqCst) {
        let loads = advisory_loads(&state);
        send(&mut stream, &Message::MetricsPush { loads })?;
        match recv(&mut stream)? {
            Some(Message::MetricsPushAck) => {}
            _ => return Ok(()),
        }
        std::thread::sleep(interval);
    }
    Ok(())
}

/// Register the fleet's scrape page: per-shard counters and latency
/// gauges rendered from the advisory accounting at scrape time. No value
/// is copied into the registry — a scrape and a drain report read the
/// same state, so they cannot diverge further than one push interval.
fn register_fleet_exposition(state: &Arc<ServerState>, registry: &Registry) {
    let state = Arc::clone(state);
    registry.register(move |buf| {
        let members = lock_recover(&state.members, "fleet scrape");
        let shards: Vec<(usize, bool, MetricsSnapshot)> = members
            .iter()
            .map(|(id, ws)| {
                let st = lock_recover(&ws.state, "fleet scrape shard");
                (*id, st.conn.is_some(), WorkerShard::advisory(&st))
            })
            .collect();
        drop(members);
        write_type(buf, "tapesched_shards", "gauge");
        write_counter(buf, "tapesched_shards", &[], shards.len() as u64);
        let counters: [(&str, fn(&MetricsSnapshot) -> u64); 5] = [
            ("tapesched_submitted_total", |m| m.submitted),
            ("tapesched_completed_total", |m| m.completed),
            ("tapesched_rejected_total", |m| m.rejected),
            ("tapesched_shed_total", |m| m.shed),
            ("tapesched_batches_total", |m| m.batches),
        ];
        for (name, get) in counters {
            write_type(buf, name, "counter");
            for (id, _, m) in &shards {
                let label = id.to_string();
                write_counter(buf, name, &[("shard", &label)], get(m));
            }
        }
        write_type(buf, "tapesched_worker_up", "gauge");
        for (id, up, _) in &shards {
            let label = id.to_string();
            write_counter(buf, "tapesched_worker_up", &[("shard", &label)], u64::from(*up));
        }
        write_type(buf, "tapesched_in_flight", "gauge");
        for (id, _, m) in &shards {
            let label = id.to_string();
            let in_flight = m.submitted.saturating_sub(m.completed + m.shed);
            write_counter(buf, "tapesched_in_flight", &[("shard", &label)], in_flight);
        }
        let gauges: [(&str, fn(&MetricsSnapshot) -> f64); 3] = [
            ("tapesched_mean_latency_seconds", |m| m.mean_latency_s),
            ("tapesched_p50_latency_seconds", |m| m.p50_latency_s),
            ("tapesched_p99_latency_seconds", |m| m.p99_latency_s),
        ];
        for (name, get) in gauges {
            write_type(buf, name, "gauge");
            for (id, _, m) in &shards {
                let label = id.to_string();
                write_gauge(buf, name, &[("shard", &label)], get(m));
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shorthand: a snapshot whose drain-critical counters are set and
    /// whose means are nonzero, as a pulled-worker snapshot would be.
    fn snap(submitted: u64, completed: u64, shed: u64, mean: f64) -> MetricsSnapshot {
        MetricsSnapshot {
            submitted,
            completed,
            shed,
            mean_latency_s: mean,
            ..MetricsSnapshot::default()
        }
    }

    fn invariant(m: &MetricsSnapshot) {
        assert_eq!(
            m.submitted,
            m.completed + m.shed,
            "drain invariant: submitted ({}) == completed ({}) + shed ({})",
            m.submitted,
            m.completed,
            m.shed
        );
    }

    #[test]
    fn fold_dead_era_sheds_everything_unreported() {
        // Era accepted 10 submits; last pull saw 6 complete. The other 4
        // are shed regardless of what the worker did after that pull.
        let folded = fold_dead_era(None, Some(snap(10, 6, 0, 1.0)), 10);
        assert_eq!(folded.submitted, 10);
        assert_eq!(folded.completed, 6);
        assert_eq!(folded.shed, 4);
        invariant(&folded);
    }

    #[test]
    fn fold_dead_era_with_no_pull_sheds_the_whole_era() {
        // Worker died before any MetricsPull: everything accepted is shed.
        let folded = fold_dead_era(None, None, 7);
        assert_eq!(folded.submitted, 7);
        assert_eq!(folded.completed, 0);
        assert_eq!(folded.shed, 7);
        invariant(&folded);
    }

    #[test]
    fn kill_rejoin_second_kill_keeps_the_invariant() {
        // Era 1: accepted 10, last pull saw 6 completed → 4 shed.
        let carry = fold_dead_era(None, Some(snap(10, 6, 0, 2.0)), 10);
        invariant(&carry);

        // Rejoin: era 2 runs and dies too — accepted 5, pull saw 5 done.
        let carry = fold_dead_era(Some(carry), Some(snap(5, 5, 0, 1.0)), 5);
        assert_eq!(carry.submitted, 15);
        assert_eq!(carry.completed, 11);
        assert_eq!(carry.shed, 4);
        invariant(&carry);

        // Second rejoin dies with nothing pulled: 3 accepted, all shed.
        let carry = fold_dead_era(Some(carry), None, 3);
        assert_eq!(carry.submitted, 18);
        assert_eq!(carry.completed, 11);
        assert_eq!(carry.shed, 7);
        invariant(&carry);
    }

    #[test]
    fn shed_then_complete_late_stays_consistent() {
        // The edge: the worker completed 8 of 10 by the time it died, but
        // the last pull only saw 5. The 3 late completions are lost with
        // the connection — they must be shed, not double-counted, and the
        // invariant must hold on the numbers the fleet actually reports.
        let last_pull = snap(10, 5, 0, 1.5);
        let folded = fold_dead_era(None, Some(last_pull), 10);
        assert_eq!(folded.completed, 5, "late completions never reach a client");
        assert_eq!(folded.shed, 5);
        invariant(&folded);

        // A replacement era then completes cleanly; the stitched history
        // still balances.
        let total = fold_dead_era(Some(folded), Some(snap(20, 20, 0, 0.5)), 20);
        assert_eq!(total.submitted, 30);
        assert_eq!(total.completed, 25);
        assert_eq!(total.shed, 5);
        invariant(&total);
    }

    #[test]
    fn fold_weights_latency_means_by_completions() {
        // 6 completions at mean 2.0 then 6 more at mean 1.0 → 1.5.
        let a = fold_dead_era(None, Some(snap(6, 6, 0, 2.0)), 6);
        let b = fold_dead_era(Some(a), Some(snap(6, 6, 0, 1.0)), 6);
        assert!((b.mean_latency_s - 1.5).abs() < 1e-9, "got {}", b.mean_latency_s);
        invariant(&b);
    }
}
