//! Length-prefixed frame codec — the lowest wire layer.
//!
//! Every message on a coordinator/worker/client connection travels as one
//! *frame*: a 4-byte big-endian payload length followed by exactly that
//! many payload bytes (the first of which is the message tag, see
//! [`super::wire`]). The codec is deliberately dumb: no compression, no
//! checksums (TCP provides integrity), no partial frames — which keeps the
//! format byte-auditable with nothing but `xxd`.
//!
//! ```text
//!   ┌──────────────┬───────────────────────────────┐
//!   │ len: u32 BE  │ payload: len bytes (tag + body)│
//!   └──────────────┴───────────────────────────────┘
//! ```
//!
//! A length prefix above [`MAX_FRAME`] is rejected before any payload is
//! read — a peer speaking a different protocol (or garbage) cannot make us
//! allocate gigabytes. EOF exactly *between* frames is a clean close
//! ([`read_frame`] returns `Ok(None)`); EOF inside a header or payload is
//! [`FrameError::Truncated`].

use std::io::{Read, Write};

/// Upper bound on a frame's payload size (16 MiB). Catalog assignments for
/// very large fleets dominate frame sizes; 16 MiB covers hundreds of
/// thousands of file extents while still rejecting nonsense prefixes.
pub const MAX_FRAME: usize = 16 << 20;

/// Why a frame could not be read or written.
#[derive(Debug)]
pub enum FrameError {
    /// Underlying transport error.
    Io(std::io::Error),
    /// The length prefix (read) or payload (write) exceeds [`MAX_FRAME`].
    Oversized { len: usize },
    /// The stream ended inside a header or payload.
    Truncated,
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "frame i/o error: {e}"),
            FrameError::Oversized { len } => {
                write!(f, "frame of {len} bytes exceeds the {MAX_FRAME}-byte cap")
            }
            FrameError::Truncated => write!(f, "stream ended mid-frame"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<FrameError> for std::io::Error {
    fn from(e: FrameError) -> std::io::Error {
        match e {
            FrameError::Io(io) => io,
            other => std::io::Error::new(std::io::ErrorKind::InvalidData, other.to_string()),
        }
    }
}

/// Write one frame: length prefix + payload, then flush.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<(), FrameError> {
    if payload.len() > MAX_FRAME {
        return Err(FrameError::Oversized { len: payload.len() });
    }
    let len = (payload.len() as u32).to_be_bytes();
    w.write_all(&len).map_err(FrameError::Io)?;
    w.write_all(payload).map_err(FrameError::Io)?;
    w.flush().map_err(FrameError::Io)
}

/// Read exactly `buf.len()` bytes. `eof_ok` permits a clean EOF *before
/// the first byte* (returns `Ok(false)`); EOF after any byte was read is
/// always [`FrameError::Truncated`].
fn read_exactly<R: Read>(r: &mut R, buf: &mut [u8], eof_ok: bool) -> Result<bool, FrameError> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 && eof_ok {
                    return Ok(false);
                }
                return Err(FrameError::Truncated);
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(true)
}

/// Read one frame's payload. `Ok(None)` is a clean close: the peer shut
/// the stream down exactly at a frame boundary.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>, FrameError> {
    let mut header = [0u8; 4];
    if !read_exactly(r, &mut header, true)? {
        return Ok(None);
    }
    let len = u32::from_be_bytes(header) as usize;
    if len > MAX_FRAME {
        return Err(FrameError::Oversized { len });
    }
    let mut payload = vec![0u8; len];
    read_exactly(r, &mut payload, false)?;
    Ok(Some(payload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_round_trip_back_to_back() {
        let payloads: Vec<Vec<u8>> =
            vec![vec![], vec![7], vec![0xAB; 1_000], (0..=255u8).collect()];
        let mut wire = Vec::new();
        for p in &payloads {
            write_frame(&mut wire, p).unwrap();
        }
        let mut r = Cursor::new(wire);
        for p in &payloads {
            assert_eq!(read_frame(&mut r).unwrap().as_deref(), Some(p.as_slice()));
        }
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF at the boundary");
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(u32::MAX).to_be_bytes());
        wire.extend_from_slice(b"junk");
        match read_frame(&mut Cursor::new(wire)) {
            Err(FrameError::Oversized { len }) => assert_eq!(len, u32::MAX as usize),
            other => panic!("expected Oversized, got {other:?}"),
        }
    }

    #[test]
    fn oversized_payload_is_rejected_on_write() {
        let mut sink = Vec::new();
        let big = vec![0u8; MAX_FRAME + 1];
        assert!(matches!(
            write_frame(&mut sink, &big),
            Err(FrameError::Oversized { .. })
        ));
        assert!(sink.is_empty(), "nothing may hit the wire");
    }

    #[test]
    fn truncated_header_and_payload_are_distinguished_from_clean_eof() {
        // EOF inside the 4-byte header.
        assert!(matches!(
            read_frame(&mut Cursor::new(vec![0u8, 0])),
            Err(FrameError::Truncated)
        ));
        // EOF inside the payload: header promises 8 bytes, 3 arrive.
        let mut wire = Vec::new();
        wire.extend_from_slice(&8u32.to_be_bytes());
        wire.extend_from_slice(&[1, 2, 3]);
        assert!(matches!(
            read_frame(&mut Cursor::new(wire)),
            Err(FrameError::Truncated)
        ));
        // The empty stream is a clean close, not an error.
        assert!(read_frame(&mut Cursor::new(Vec::new())).unwrap().is_none());
    }
}
