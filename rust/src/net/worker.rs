//! The worker process: one shard's [`Coordinator`] behind a TCP
//! connection.
//!
//! A worker is deliberately thin — it owns no routing, no ring, no fleet
//! state. It connects to the coordinator process, introduces itself
//! (`Hello{role: Worker}`), receives its shard assignment (policy name,
//! [`CoordinatorConfig`], and its ring partition of the catalog), starts a
//! real in-process `Coordinator` over that partition, and then answers the
//! coordinator's requests one frame at a time until `Drain`/`Shutdown` or
//! the connection dies. Because requests arrive over a single connection
//! and the worker replies in order, the protocol needs no request ids —
//! the coordinator holds the per-shard connection lock across each
//! request/response pair (see `net::server`).
//!
//! A worker that loses its connection simply exits after discarding its
//! coordinator; the server side synthesizes the shed accounting for
//! whatever it had accepted (the drain invariant `submitted − completed −
//! shed` is kept by the *coordinator*, not by the dying worker).
//!
//! When the assignment carries `push_ms > 0`, the worker also opens a
//! *second* connection back to the same coordinator address with
//! `Role::MetricsPusher` and streams its metrics snapshot on that
//! interval. The push conversation lives entirely on that side channel —
//! the main connection stays strictly one-initiator request/response, so
//! a push can never interleave with an in-flight submit round trip.
//! Telemetry is best-effort: if the pusher cannot connect or its
//! connection dies, the worker keeps serving and the coordinator falls
//! back to pull accounting.

use std::io;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::cluster::ShardLoad;
use crate::coordinator::{Coordinator, ReadRequest, SubmitError};
use crate::sched::scheduler_by_name;
use crate::util::sync::lock_recover;

use super::frame::{read_frame, write_frame};
use super::wire::{self, Message, Role, SubmitOutcome, PROTOCOL_VERSION};

fn send(stream: &mut TcpStream, msg: &Message) -> io::Result<()> {
    write_frame(stream, &wire::encode(msg)).map_err(io::Error::from)
}

/// Read the next message; `Ok(None)` on a clean close at a frame boundary.
fn recv(stream: &mut TcpStream) -> io::Result<Option<Message>> {
    match read_frame(stream) {
        Ok(None) => Ok(None),
        Ok(Some(payload)) => Ok(Some(wire::decode(&payload)?)),
        Err(e) => Err(e.into()),
    }
}

/// Connect to a coordinator at `addr` and serve a shard until drained,
/// shut down, or disconnected. This is `tapesched worker --connect ADDR`.
pub fn run_worker(addr: &str) -> io::Result<()> {
    let stream = TcpStream::connect(addr)?;
    run_worker_on(stream)
}

/// Serve a shard over an already-connected stream (loopback tests connect
/// the stream themselves).
pub fn run_worker_on(mut stream: TcpStream) -> io::Result<()> {
    stream.set_nodelay(true).ok();
    send(&mut stream, &Message::Hello { version: PROTOCOL_VERSION, role: Role::Worker })?;
    let shard = match recv(&mut stream)? {
        Some(Message::HelloAck { shard, .. }) => shard,
        Some(Message::Error { message }) => {
            return Err(io::Error::new(io::ErrorKind::ConnectionRefused, message))
        }
        other => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected HelloAck, got {other:?}"),
            ))
        }
    };
    let (policy_name, config, catalog, push_ms) = match recv(&mut stream)? {
        Some(Message::Assign { shard: s, policy, config, catalog, push_ms }) if s == shard => {
            (policy, config, catalog, push_ms)
        }
        other => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected Assign for shard {shard}, got {other:?}"),
            ))
        }
    };
    let policy = scheduler_by_name(&policy_name).ok_or_else(|| {
        io::Error::new(
            io::ErrorKind::InvalidData,
            format!("coordinator assigned unknown policy {policy_name:?}"),
        )
    })?;
    // The pusher thread snapshots metrics concurrently with the serving
    // loop, so the coordinator lives behind a mutex; `None` after drain.
    let coordinator: Arc<Mutex<Option<Coordinator>>> =
        Arc::new(Mutex::new(Some(Coordinator::start(config, catalog, Arc::from(policy)))));
    send(&mut stream, &Message::AssignAck { shard })?;

    let pusher = if push_ms > 0 {
        stream
            .peer_addr()
            .ok()
            .map(|addr| spawn_pusher(addr.to_string(), shard, push_ms, Arc::clone(&coordinator)))
    } else {
        None
    };
    let stop_pusher = |pusher: Option<(Arc<AtomicBool>, JoinHandle<()>)>| {
        if let Some((stop, handle)) = pusher {
            stop.store(true, Ordering::SeqCst);
            let _ = handle.join();
        }
    };

    loop {
        let msg = match recv(&mut stream) {
            Ok(Some(msg)) => msg,
            // Clean close or a dead coordinator: discard un-drained work —
            // the server side sheds this shard's accepted batches.
            Ok(None) | Err(_) => {
                if let Some(c) = lock_recover(&coordinator, "worker serve").take() {
                    let _ = c.finish();
                }
                stop_pusher(pusher);
                return Ok(());
            }
        };
        match msg {
            Message::Submit { id, tape, file_index } => {
                let result = match &*lock_recover(&coordinator, "worker serve") {
                    Some(c) => c.submit(ReadRequest {
                        id,
                        tape,
                        file_index: file_index as usize,
                    }),
                    None => Err(SubmitError::Stopping),
                };
                send(
                    &mut stream,
                    &Message::SubmitResult { outcome: SubmitOutcome::from_submit(&result) },
                )?;
            }
            Message::MetricsPull => {
                let metrics = match &*lock_recover(&coordinator, "worker serve") {
                    Some(c) => c.metrics(),
                    None => Default::default(),
                };
                // One entry, own shard, routed = 0: the coordinator owns
                // routing counts, a worker only knows what it served.
                send(
                    &mut stream,
                    &Message::MetricsReply {
                        loads: vec![ShardLoad { shard: shard as usize, routed: 0, metrics }],
                    },
                )?;
            }
            Message::Drain => {
                let (completions, metrics) = match lock_recover(&coordinator, "worker serve").take() {
                    Some(c) => c.finish(),
                    None => (Vec::new(), Default::default()),
                };
                send(
                    &mut stream,
                    &Message::DrainResult {
                        completions,
                        loads: vec![ShardLoad { shard: shard as usize, routed: 0, metrics }],
                    },
                )?;
                // Drained: nothing left to push. Stop the telemetry thread
                // but keep answering the main connection until Shutdown.
                stop_pusher(pusher);
                return serve_drained(stream, shard);
            }
            Message::Shutdown => {
                if let Some(c) = lock_recover(&coordinator, "worker serve").take() {
                    let _ = c.finish();
                }
                stop_pusher(pusher);
                return Ok(());
            }
            other => {
                send(
                    &mut stream,
                    &Message::Error {
                        message: format!("worker cannot serve {other:?}"),
                    },
                )?;
            }
        }
    }
}

/// After a drain the worker keeps the main connection alive (the
/// coordinator sends `Shutdown` once the fleet report is assembled), but
/// every request answers from the empty state.
fn serve_drained(mut stream: TcpStream, shard: u32) -> io::Result<()> {
    loop {
        match recv(&mut stream) {
            Ok(None) | Err(_) | Ok(Some(Message::Shutdown)) => return Ok(()),
            Ok(Some(Message::Submit { .. })) => {
                send(&mut stream, &Message::SubmitResult { outcome: SubmitOutcome::Stopping })?;
            }
            Ok(Some(Message::MetricsPull)) => {
                send(
                    &mut stream,
                    &Message::MetricsReply {
                        loads: vec![ShardLoad {
                            shard: shard as usize,
                            routed: 0,
                            metrics: Default::default(),
                        }],
                    },
                )?;
            }
            Ok(Some(other)) => {
                send(
                    &mut stream,
                    &Message::Error { message: format!("worker is drained; cannot serve {other:?}") },
                )?;
            }
        }
    }
}

/// Open the telemetry side channel and stream metrics snapshots every
/// `push_ms` until stopped or the coordinator is drained. Best-effort by
/// design — any failure ends telemetry, never the worker.
fn spawn_pusher(
    addr: String,
    shard: u32,
    push_ms: u64,
    coordinator: Arc<Mutex<Option<Coordinator>>>,
) -> (Arc<AtomicBool>, JoinHandle<()>) {
    let stop = Arc::new(AtomicBool::new(false));
    let stop_flag = Arc::clone(&stop);
    let handle = std::thread::spawn(move || {
        let _ = push_loop(&addr, shard, push_ms, &coordinator, &stop_flag);
    });
    (stop, handle)
}

fn push_loop(
    addr: &str,
    shard: u32,
    push_ms: u64,
    coordinator: &Mutex<Option<Coordinator>>,
    stop: &AtomicBool,
) -> io::Result<()> {
    let mut conn = TcpStream::connect(addr)?;
    conn.set_nodelay(true).ok();
    send(&mut conn, &Message::Hello { version: PROTOCOL_VERSION, role: Role::MetricsPusher })?;
    match recv(&mut conn)? {
        Some(Message::HelloAck { .. }) => {}
        _ => return Ok(()),
    }
    loop {
        // Sleep in short slices so a stop request is honored promptly
        // even with a long push interval.
        let mut slept = 0;
        while slept < push_ms {
            if stop.load(Ordering::SeqCst) {
                return Ok(());
            }
            let slice = (push_ms - slept).min(20);
            std::thread::sleep(Duration::from_millis(slice));
            slept += slice;
        }
        let metrics = match &*lock_recover(&coordinator, "worker pusher") {
            Some(c) => c.metrics(),
            None => return Ok(()), // drained under us
        };
        send(
            &mut conn,
            &Message::MetricsPush {
                loads: vec![ShardLoad { shard: shard as usize, routed: 0, metrics }],
            },
        )?;
        match recv(&mut conn)? {
            Some(Message::MetricsPushAck) => {}
            _ => return Ok(()),
        }
    }
}
