//! The worker process: one shard's [`Coordinator`] behind a TCP
//! connection.
//!
//! A worker is deliberately thin — it owns no routing, no ring, no fleet
//! state. It connects to the coordinator process, introduces itself
//! (`Hello{role: Worker}`), receives its shard assignment (policy name,
//! [`CoordinatorConfig`], and its ring partition of the catalog), starts a
//! real in-process `Coordinator` over that partition, and then answers the
//! coordinator's requests one frame at a time until `Drain`/`Shutdown` or
//! the connection dies. Because requests arrive over a single connection
//! and the worker replies in order, the protocol needs no request ids —
//! the coordinator holds the per-shard connection lock across each
//! request/response pair (see `net::server`).
//!
//! A worker that loses its connection simply exits after discarding its
//! coordinator; the server side synthesizes the shed accounting for
//! whatever it had accepted (the drain invariant `submitted − completed −
//! shed` is kept by the *coordinator*, not by the dying worker).

use std::io;
use std::net::TcpStream;
use std::sync::Arc;

use crate::cluster::ShardLoad;
use crate::coordinator::{Coordinator, ReadRequest, SubmitError};
use crate::sched::scheduler_by_name;

use super::frame::{read_frame, write_frame};
use super::wire::{self, Message, Role, SubmitOutcome, PROTOCOL_VERSION};

fn send(stream: &mut TcpStream, msg: &Message) -> io::Result<()> {
    write_frame(stream, &wire::encode(msg)).map_err(io::Error::from)
}

/// Read the next message; `Ok(None)` on a clean close at a frame boundary.
fn recv(stream: &mut TcpStream) -> io::Result<Option<Message>> {
    match read_frame(stream) {
        Ok(None) => Ok(None),
        Ok(Some(payload)) => Ok(Some(wire::decode(&payload)?)),
        Err(e) => Err(e.into()),
    }
}

/// Connect to a coordinator at `addr` and serve a shard until drained,
/// shut down, or disconnected. This is `tapesched worker --connect ADDR`.
pub fn run_worker(addr: &str) -> io::Result<()> {
    let stream = TcpStream::connect(addr)?;
    run_worker_on(stream)
}

/// Serve a shard over an already-connected stream (loopback tests connect
/// the stream themselves).
pub fn run_worker_on(mut stream: TcpStream) -> io::Result<()> {
    stream.set_nodelay(true).ok();
    send(&mut stream, &Message::Hello { version: PROTOCOL_VERSION, role: Role::Worker })?;
    let shard = match recv(&mut stream)? {
        Some(Message::HelloAck { shard, .. }) => shard,
        Some(Message::Error { message }) => {
            return Err(io::Error::new(io::ErrorKind::ConnectionRefused, message))
        }
        other => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected HelloAck, got {other:?}"),
            ))
        }
    };
    let (policy_name, config, catalog) = match recv(&mut stream)? {
        Some(Message::Assign { shard: s, policy, config, catalog }) if s == shard => {
            (policy, config, catalog)
        }
        other => {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected Assign for shard {shard}, got {other:?}"),
            ))
        }
    };
    let policy = scheduler_by_name(&policy_name).ok_or_else(|| {
        io::Error::new(
            io::ErrorKind::InvalidData,
            format!("coordinator assigned unknown policy {policy_name:?}"),
        )
    })?;
    let mut coordinator = Some(Coordinator::start(config, catalog, Arc::from(policy)));
    send(&mut stream, &Message::AssignAck { shard })?;

    loop {
        let msg = match recv(&mut stream) {
            Ok(Some(msg)) => msg,
            // Clean close or a dead coordinator: discard un-drained work —
            // the server side sheds this shard's accepted batches.
            Ok(None) | Err(_) => {
                if let Some(c) = coordinator.take() {
                    let _ = c.finish();
                }
                return Ok(());
            }
        };
        match msg {
            Message::Submit { id, tape, file_index } => {
                let result = match &coordinator {
                    Some(c) => c.submit(ReadRequest {
                        id,
                        tape,
                        file_index: file_index as usize,
                    }),
                    None => Err(SubmitError::Stopping),
                };
                send(
                    &mut stream,
                    &Message::SubmitResult { outcome: SubmitOutcome::from_submit(&result) },
                )?;
            }
            Message::MetricsPull => {
                let metrics = match &coordinator {
                    Some(c) => c.metrics(),
                    None => Default::default(),
                };
                // One entry, own shard, routed = 0: the coordinator owns
                // routing counts, a worker only knows what it served.
                send(
                    &mut stream,
                    &Message::MetricsReply {
                        loads: vec![ShardLoad { shard: shard as usize, routed: 0, metrics }],
                    },
                )?;
            }
            Message::Drain => {
                let (completions, metrics) = match coordinator.take() {
                    Some(c) => c.finish(),
                    None => (Vec::new(), Default::default()),
                };
                send(
                    &mut stream,
                    &Message::DrainResult {
                        completions,
                        loads: vec![ShardLoad { shard: shard as usize, routed: 0, metrics }],
                    },
                )?;
            }
            Message::Shutdown => {
                if let Some(c) = coordinator.take() {
                    let _ = c.finish();
                }
                return Ok(());
            }
            other => {
                send(
                    &mut stream,
                    &Message::Error {
                        message: format!("worker cannot serve {other:?}"),
                    },
                )?;
            }
        }
    }
}
