//! The networked cluster: coordinator/worker processes speaking a
//! hand-rolled, dependency-free binary protocol over `std::net`.
//!
//! The in-process [`Cluster`](crate::cluster::Cluster) bounds a fleet at
//! one machine's threads; this subsystem splits it across processes
//! behind the same two seams the rest of the crate already routes
//! through — [`RequestSink`](crate::replay::RequestSink) on the client
//! side and [`ShardBackend`](crate::cluster::ShardBackend) on the
//! routing side — so the closed-loop driver, the QoS reporting, and the
//! consistent-hash placement are unchanged whether a shard is a local
//! `Coordinator` or a TCP worker.
//!
//! Layer map (wire to CLI):
//!
//! - [`frame`] — length-prefixed frames over any `Read`/`Write`: `u32` BE
//!   payload length (capped), then the payload. Clean-close vs truncation
//!   is explicit.
//! - [`wire`] — tagged messages and their exact binary schema
//!   (handshake, submit, metrics, drain), `f64` as IEEE-754 bits so QoS
//!   numbers cross the wire without rounding.
//! - [`server`] — the coordinator process: ring + routing over
//!   `WorkerShard` backends, fleet readiness, dead-worker shed
//!   accounting, worker rejoin, advisory push-telemetry state and the
//!   `--metrics-listen` exposition page.
//! - [`worker`] — the worker process: one shard's `Coordinator` behind a
//!   connection, plus the optional telemetry pusher side channel.
//! - [`client`] — [`RemoteCluster`]: the `RequestSink` a driver plugs
//!   into; `connect_push` adds the push-fed in-flight gauge.
//! - [`loopback`] — the whole fleet on `127.0.0.1` in one process, for
//!   integration tests and the RPC-tax measurement.
//!
//! The byte-level format is specified in `rust/README.md` (“Wire
//! format”).

pub mod client;
pub mod frame;
pub mod loopback;
pub mod server;
pub mod wire;
pub mod worker;

pub use client::RemoteCluster;
pub use frame::{read_frame, write_frame, FrameError, MAX_FRAME};
pub use loopback::LoopbackFleet;
pub use server::{serve, CoordinatorServerConfig};
pub use wire::{Message, Role, SubmitOutcome, WireError, PROTOCOL_VERSION};
pub use worker::{run_worker, run_worker_on};
