//! Loopback integration mode: a whole coordinator/worker fleet in one
//! process over `127.0.0.1`, each process boundary a real TCP connection.
//!
//! This is how the RPC tax is measured (`tapesched rpc-tax`) and how the
//! networked paths are integration-tested without multi-process
//! orchestration: the frames, handshakes, and failure paths are exactly
//! the ones the standalone `coordinator`/`worker` subcommands run —
//! only the thread/process boundary differs.

use std::io;
use std::net::{SocketAddr, TcpListener};
use std::thread::JoinHandle;

use crate::model::Tape;

use super::client::RemoteCluster;
use super::server::{serve, CoordinatorServerConfig};
use super::worker::run_worker;

/// A coordinator thread plus its worker threads, bound on an ephemeral
/// loopback port.
pub struct LoopbackFleet {
    addr: SocketAddr,
    server: JoinHandle<io::Result<()>>,
    workers: Vec<JoinHandle<io::Result<()>>>,
}

impl LoopbackFleet {
    /// Bind `127.0.0.1:0`, start the coordinator server thread, and spawn
    /// `cfg.n_shards` worker threads against it. Returns as soon as the
    /// threads are launched — the first client *request* blocks until
    /// every worker has joined (fleet readiness is the server's job).
    pub fn spawn(cfg: CoordinatorServerConfig, catalog: Vec<Tape>) -> io::Result<LoopbackFleet> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let n_workers = cfg.n_shards;
        let server = std::thread::spawn(move || serve(listener, cfg, catalog));
        let workers = (0..n_workers).map(|_| Self::spawn_worker(addr)).collect();
        Ok(LoopbackFleet { addr, server, workers })
    }

    /// The fleet's address (connect clients or replacement workers here).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connect a client handle to the fleet.
    pub fn client(&self) -> io::Result<RemoteCluster> {
        RemoteCluster::connect(&self.addr.to_string())
    }

    /// Connect a client handle with the push-fed in-flight gauge (only
    /// meaningful when the fleet runs with `push_ms > 0`).
    pub fn client_push(&self) -> io::Result<RemoteCluster> {
        RemoteCluster::connect_push(&self.addr.to_string())
    }

    /// Spawn one worker thread against `addr` — also the rejoin path: a
    /// replacement worker for a killed shard is just another worker
    /// connecting (the server hands it the dead shard's id).
    pub fn spawn_worker(addr: SocketAddr) -> JoinHandle<io::Result<()>> {
        std::thread::spawn(move || run_worker(&addr.to_string()))
    }

    /// Join every thread after the fleet was drained or shut down.
    /// Worker threads that were deliberately killed report their I/O
    /// error; that is expected, so per-thread results are returned rather
    /// than unwrapped. A thread that *panicked* (rather than erroring)
    /// is reported as an `io::Error` too — the caller sees a failed leg,
    /// not a cascaded abort.
    pub fn join(self) -> (io::Result<()>, Vec<io::Result<()>>) {
        fn flatten(joined: std::thread::Result<io::Result<()>>, who: &str) -> io::Result<()> {
            match joined {
                Ok(r) => r,
                Err(_) => {
                    Err(io::Error::new(io::ErrorKind::Other, format!("{who} thread panicked")))
                }
            }
        }
        let server = flatten(self.server.join(), "coordinator server");
        let workers = self
            .workers
            .into_iter()
            .map(|w| flatten(w.join(), "worker"))
            .collect();
        (server, workers)
    }
}
