//! The client side of the wire: [`RemoteCluster`], a connection to a
//! coordinator process that implements [`RequestSink`] — so the unchanged
//! closed-loop driver (`replay::drive_closed_loop`) can feed a networked
//! fleet exactly as it feeds an in-process `Coordinator` or `Cluster`.
//!
//! The protocol is strictly request/response on one connection, so the
//! whole client is a `Mutex<TcpStream>` held across each pair. That is
//! deliberate: the serve path measures the *RPC tax* of the seam (see
//! `tapesched rpc-tax`), and a pipelined client would hide exactly the
//! per-submit round-trip latency the measurement is after.
//!
//! [`RemoteCluster::connect_push`] opens a *second* connection with
//! `Role::MetricsSubscriber` on which the coordinator streams advisory
//! fleet loads. A background reader folds them into a [`PushGauge`], and
//! `in_flight()` then answers from two atomics instead of a
//! `MetricsPull` round trip per admission check — that is the half of the
//! RPC tax `tapesched rpc-tax --push-metrics` recovers. The gauge is
//! deliberately conservative: `accepted` counts this client's accepted
//! submits synchronously, `done` lags by at most one push interval, so
//! the gauge can overestimate in-flight (briefly throttling the driver)
//! but never underestimate it past the admission limit.

use std::io;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cluster::{rollup, ClusterMetricsSnapshot, ShardLoad};
use crate::coordinator::{Completion, ReadRequest, SubmitError};
use crate::replay::RequestSink;
use crate::util::sync::lock_recover;

use super::frame::{read_frame, write_frame};
use super::wire::{self, Message, Role, SubmitOutcome, PROTOCOL_VERSION};

/// The push-fed in-flight gauge: `accepted − done`, both monotone.
#[derive(Default)]
struct PushGauge {
    /// Accepted submits, counted synchronously on this client.
    accepted: AtomicU64,
    /// Fleet-wide `completed + shed` from the latest push.
    done: AtomicU64,
    /// At least one push has arrived; before that, fall back to pull so
    /// an early admission check is not answered from a zeroed gauge.
    seen: AtomicBool,
}

/// A connected client handle on a networked fleet.
pub struct RemoteCluster {
    conn: Mutex<TcpStream>,
    /// Present only on [`RemoteCluster::connect_push`] handles.
    gauge: Option<Arc<PushGauge>>,
}

impl RemoteCluster {
    /// Connect and handshake. Blocks until the coordinator accepts the
    /// hello; the coordinator in turn blocks the first *request* until
    /// its fleet is fully joined.
    pub fn connect(addr: &str) -> io::Result<RemoteCluster> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        write_frame(
            &mut stream,
            &wire::encode(&Message::Hello { version: PROTOCOL_VERSION, role: Role::Client }),
        )?;
        match read_frame(&mut stream)? {
            Some(payload) => match wire::decode(&payload)? {
                Message::HelloAck { .. } => {
                    Ok(RemoteCluster { conn: Mutex::new(stream), gauge: None })
                }
                Message::Error { message } => {
                    Err(io::Error::new(io::ErrorKind::ConnectionRefused, message))
                }
                other => Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("expected HelloAck, got {other:?}"),
                )),
            },
            None => Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "coordinator closed during handshake",
            )),
        }
    }

    /// Connect like [`RemoteCluster::connect`], then open the telemetry
    /// subscription: a second connection on which the coordinator pushes
    /// advisory fleet loads (the fleet must run with `push_ms > 0` for
    /// those to carry live numbers). `in_flight()` on this handle reads
    /// the push-fed gauge instead of doing a `MetricsPull` round trip.
    pub fn connect_push(addr: &str) -> io::Result<RemoteCluster> {
        let mut client = RemoteCluster::connect(addr)?;
        let mut sub = TcpStream::connect(addr)?;
        sub.set_nodelay(true).ok();
        write_frame(
            &mut sub,
            &wire::encode(&Message::Hello {
                version: PROTOCOL_VERSION,
                role: Role::MetricsSubscriber,
            }),
        )?;
        match read_frame(&mut sub)? {
            Some(payload) => match wire::decode(&payload)? {
                Message::HelloAck { .. } => {}
                Message::Error { message } => {
                    return Err(io::Error::new(io::ErrorKind::ConnectionRefused, message))
                }
                other => {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("expected HelloAck, got {other:?}"),
                    ))
                }
            },
            None => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "coordinator closed during subscriber handshake",
                ))
            }
        }
        let gauge = Arc::new(PushGauge::default());
        let sink = Arc::clone(&gauge);
        // Detached: exits on EOF when the coordinator stops pushing
        // (fleet drained) or the connection dies.
        std::thread::spawn(move || {
            let _ = subscriber_loop(sub, &sink);
        });
        client.gauge = Some(gauge);
        Ok(client)
    }

    /// One request/response round trip. The connection lock is held
    /// across the pair so concurrent callers cannot interleave frames.
    fn call(&self, msg: &Message) -> io::Result<Message> {
        let mut conn = lock_recover(&self.conn, "client connection");
        write_frame(&mut *conn, &wire::encode(msg))?;
        match read_frame(&mut *conn)? {
            Some(payload) => Ok(wire::decode(&payload)?),
            None => Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "coordinator closed mid-request",
            )),
        }
    }

    /// Submit one read request to the fleet (routed by the coordinator).
    pub fn submit(&self, req: &ReadRequest) -> io::Result<Result<(), SubmitError>> {
        let reply = self.call(&Message::Submit {
            id: req.id,
            tape: req.tape.clone(),
            file_index: req.file_index as u64,
        })?;
        match reply {
            Message::SubmitResult { outcome } => {
                let result = outcome.into_submit();
                if result.is_ok() {
                    if let Some(g) = &self.gauge {
                        g.accepted.fetch_add(1, Ordering::Relaxed);
                    }
                }
                Ok(result)
            }
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected SubmitResult, got {other:?}"),
            )),
        }
    }

    /// Per-shard loads, fresh from the fleet.
    pub fn loads(&self) -> io::Result<Vec<ShardLoad>> {
        match self.call(&Message::MetricsPull)? {
            Message::MetricsReply { loads } => Ok(loads),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected MetricsReply, got {other:?}"),
            )),
        }
    }

    /// Fleet rollup (client-side arithmetic over [`RemoteCluster::loads`]).
    pub fn metrics(&self) -> io::Result<ClusterMetricsSnapshot> {
        Ok(rollup(self.loads()?))
    }

    /// Drain the whole fleet: completions (sorted by request id by the
    /// coordinator) plus the final rollup. Consumes the handle — the
    /// coordinator stops serving after a drain.
    pub fn drain(self) -> io::Result<(Vec<Completion>, ClusterMetricsSnapshot)> {
        match self.call(&Message::Drain)? {
            Message::DrainResult { completions, loads } => {
                Ok((completions, rollup(loads)))
            }
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected DrainResult, got {other:?}"),
            )),
        }
    }

    /// Tell the coordinator to shut the fleet down without draining.
    pub fn shutdown(self) -> io::Result<()> {
        let mut conn = lock_recover(&self.conn, "client connection");
        write_frame(&mut *conn, &wire::encode(&Message::Shutdown))?;
        Ok(())
    }
}

impl RequestSink for RemoteCluster {
    /// I/O failures surface as [`SubmitError::Stopping`]: the driver
    /// treats it as non-retryable and counts the request dropped, which
    /// is the honest reading of a dead coordinator connection.
    fn submit_request(&self, req: ReadRequest) -> Result<(), SubmitError> {
        match self.submit(&req) {
            Ok(r) => r,
            Err(_) => Err(SubmitError::Stopping),
        }
    }

    /// Fleet-wide `submitted − completed − shed`. On a push handle the
    /// answer comes from the locally-maintained gauge (no round trip);
    /// before the first push, and always on a plain handle, it is a
    /// `MetricsPull`. An I/O failure reports 0 in-flight rather than
    /// wedging the driver's admission gate against a connection that will
    /// never answer again.
    fn in_flight(&self) -> u64 {
        if let Some(g) = &self.gauge {
            if g.seen.load(Ordering::Acquire) {
                let accepted = g.accepted.load(Ordering::Relaxed);
                let done = g.done.load(Ordering::Relaxed);
                return accepted.saturating_sub(done);
            }
        }
        match self.metrics() {
            Ok(m) => m.submitted.saturating_sub(m.completed + m.shed),
            Err(_) => 0,
        }
    }
}

/// Drain the subscriber stream: each push replaces `done` with the
/// fleet-wide `completed + shed` sum and is acked. Returns on EOF or any
/// protocol surprise — the gauge then freezes and `in_flight` keeps
/// answering from its last state (the driver is already past admission
/// by the time a fleet stops pushing).
fn subscriber_loop(mut sub: TcpStream, gauge: &PushGauge) -> io::Result<()> {
    loop {
        match read_frame(&mut sub)? {
            None => return Ok(()),
            Some(payload) => match wire::decode(&payload)? {
                Message::MetricsPush { loads } => {
                    let done: u64 =
                        loads.iter().map(|l| l.metrics.completed + l.metrics.shed).sum();
                    gauge.done.store(done, Ordering::Relaxed);
                    gauge.seen.store(true, Ordering::Release);
                    write_frame(&mut sub, &wire::encode(&Message::MetricsPushAck))?;
                }
                Message::Shutdown => return Ok(()),
                _ => return Ok(()),
            },
        }
    }
}
