//! The client side of the wire: [`RemoteCluster`], a connection to a
//! coordinator process that implements [`RequestSink`] — so the unchanged
//! closed-loop driver (`replay::drive_closed_loop`) can feed a networked
//! fleet exactly as it feeds an in-process `Coordinator` or `Cluster`.
//!
//! The protocol is strictly request/response on one connection, so the
//! whole client is a `Mutex<TcpStream>` held across each pair. That is
//! deliberate: the serve path measures the *RPC tax* of the seam (see
//! `tapesched rpc-tax`), and a pipelined client would hide exactly the
//! per-submit round-trip latency the measurement is after.

use std::io;
use std::net::TcpStream;
use std::sync::Mutex;

use crate::cluster::{rollup, ClusterMetricsSnapshot, ShardLoad};
use crate::coordinator::{Completion, ReadRequest, SubmitError};
use crate::replay::RequestSink;

use super::frame::{read_frame, write_frame};
use super::wire::{self, Message, Role, SubmitOutcome, PROTOCOL_VERSION};

/// A connected client handle on a networked fleet.
pub struct RemoteCluster {
    conn: Mutex<TcpStream>,
}

impl RemoteCluster {
    /// Connect and handshake. Blocks until the coordinator accepts the
    /// hello; the coordinator in turn blocks the first *request* until
    /// its fleet is fully joined.
    pub fn connect(addr: &str) -> io::Result<RemoteCluster> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        write_frame(
            &mut stream,
            &wire::encode(&Message::Hello { version: PROTOCOL_VERSION, role: Role::Client }),
        )?;
        match read_frame(&mut stream)? {
            Some(payload) => match wire::decode(&payload)? {
                Message::HelloAck { .. } => Ok(RemoteCluster { conn: Mutex::new(stream) }),
                Message::Error { message } => {
                    Err(io::Error::new(io::ErrorKind::ConnectionRefused, message))
                }
                other => Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("expected HelloAck, got {other:?}"),
                )),
            },
            None => Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "coordinator closed during handshake",
            )),
        }
    }

    /// One request/response round trip. The connection lock is held
    /// across the pair so concurrent callers cannot interleave frames.
    fn call(&self, msg: &Message) -> io::Result<Message> {
        let mut conn = self.conn.lock().unwrap();
        write_frame(&mut *conn, &wire::encode(msg))?;
        match read_frame(&mut *conn)? {
            Some(payload) => Ok(wire::decode(&payload)?),
            None => Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "coordinator closed mid-request",
            )),
        }
    }

    /// Submit one read request to the fleet (routed by the coordinator).
    pub fn submit(&self, req: &ReadRequest) -> io::Result<Result<(), SubmitError>> {
        let reply = self.call(&Message::Submit {
            id: req.id,
            tape: req.tape.clone(),
            file_index: req.file_index as u64,
        })?;
        match reply {
            Message::SubmitResult { outcome } => Ok(outcome.into_submit()),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected SubmitResult, got {other:?}"),
            )),
        }
    }

    /// Per-shard loads, fresh from the fleet.
    pub fn loads(&self) -> io::Result<Vec<ShardLoad>> {
        match self.call(&Message::MetricsPull)? {
            Message::MetricsReply { loads } => Ok(loads),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected MetricsReply, got {other:?}"),
            )),
        }
    }

    /// Fleet rollup (client-side arithmetic over [`RemoteCluster::loads`]).
    pub fn metrics(&self) -> io::Result<ClusterMetricsSnapshot> {
        Ok(rollup(self.loads()?))
    }

    /// Drain the whole fleet: completions (sorted by request id by the
    /// coordinator) plus the final rollup. Consumes the handle — the
    /// coordinator stops serving after a drain.
    pub fn drain(self) -> io::Result<(Vec<Completion>, ClusterMetricsSnapshot)> {
        match self.call(&Message::Drain)? {
            Message::DrainResult { completions, loads } => {
                Ok((completions, rollup(loads)))
            }
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("expected DrainResult, got {other:?}"),
            )),
        }
    }

    /// Tell the coordinator to shut the fleet down without draining.
    pub fn shutdown(self) -> io::Result<()> {
        let mut conn = self.conn.lock().unwrap();
        write_frame(&mut *conn, &wire::encode(&Message::Shutdown))?;
        Ok(())
    }
}

impl RequestSink for RemoteCluster {
    /// I/O failures surface as [`SubmitError::Stopping`]: the driver
    /// treats it as non-retryable and counts the request dropped, which
    /// is the honest reading of a dead coordinator connection.
    fn submit_request(&self, req: ReadRequest) -> Result<(), SubmitError> {
        match self.submit(&req) {
            Ok(r) => r,
            Err(_) => Err(SubmitError::Stopping),
        }
    }

    /// Fleet-wide `submitted − completed − shed`. An I/O failure reports
    /// 0 in-flight rather than wedging the driver's admission gate
    /// against a connection that will never answer again.
    fn in_flight(&self) -> u64 {
        match self.metrics() {
            Ok(m) => m.submitted.saturating_sub(m.completed + m.shed),
            Err(_) => 0,
        }
    }
}
