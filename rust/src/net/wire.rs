//! Message layer: tags, bodies, and their explicit serialization.
//!
//! Every frame payload is `tag: u8` followed by the tag's body, encoded
//! with four primitives only — `u8`, big-endian fixed-width integers,
//! `f64` as its IEEE-754 bit pattern (`to_bits`, so values round-trip
//! *exactly*: the loopback-parity gate compares tour costs bit for bit),
//! and length-prefixed UTF-8 strings (`u32` BE length + bytes). No
//! varints, no optional fields: decode either consumes the body exactly or
//! fails. The full format is specified in `rust/README.md`.
//!
//! The conversation (see [`super::server`]):
//!
//! ```text
//!   any peer   → Hello{version, role}        (first frame on a connection)
//!   coordinator→ HelloAck{version, shard}    (or Error + close on mismatch)
//!   coordinator→ Assign{shard, policy, config, catalog, push_ms}  (workers)
//!   worker     → AssignAck{shard}
//!   client     → Submit / MetricsPull / Drain / Shutdown
//!   coordinator→ SubmitResult / MetricsReply / DrainResult
//! ```
//!
//! Every connection has exactly **one initiator**. The two telemetry
//! roles added in protocol version 2 keep that rule by opening their own
//! connections instead of interleaving frames on an existing one:
//!
//! ```text
//!   pusher     → Hello{role: MetricsPusher}, then
//!                MetricsPush{loads} ⇄ MetricsPushAck   (worker initiates)
//!   subscriber → Hello{role: MetricsSubscriber}, then
//!                MetricsPush{loads} ⇄ MetricsPushAck   (server initiates)
//! ```

use crate::cluster::ShardLoad;
use crate::coordinator::{
    BatcherConfig, Completion, CoordinatorConfig, MetricsSnapshot, SubmitError,
};
use crate::model::{FileExtent, Tape};
use crate::sim::{Affinity, DriveParams};

/// Bumped on any incompatible change to the frame or message format. The
/// handshake rejects a peer with a different version outright — there is
/// no negotiation, the fleet is deployed as one unit. Version 2 added
/// the push-telemetry roles, `MetricsPush`/`MetricsPushAck` (tags
/// 13–14), and `Assign::push_ms`. Version 3 appended the incremental
/// backend's repair counters (`incremental_appends`/`incremental_rebuilds`)
/// to the [`MetricsSnapshot`] encoding.
pub const PROTOCOL_VERSION: u16 = 3;

/// Decode failure: the payload did not match its tag's schema.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The body ended before the schema was satisfied.
    Truncated,
    /// Unknown message tag.
    BadTag(u8),
    /// An enum byte outside its domain (`what` names the field).
    BadEnum { what: &'static str, value: u8 },
    /// A string body was not UTF-8.
    BadUtf8,
    /// Bytes remained after the schema was satisfied.
    TrailingBytes(usize),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "message body truncated"),
            WireError::BadTag(t) => write!(f, "unknown message tag {t}"),
            WireError::BadEnum { what, value } => {
                write!(f, "bad {what} discriminant {value}")
            }
            WireError::BadUtf8 => write!(f, "string field is not UTF-8"),
            WireError::TrailingBytes(n) => write!(f, "{n} trailing bytes after message"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<WireError> for std::io::Error {
    fn from(e: WireError) -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
    }
}

/// Who is on the far end of a fresh connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Submits requests and pulls metrics (a [`super::client::RemoteCluster`]).
    Client,
    /// Runs a shard's `Coordinator` and serves routed submits.
    Worker,
    /// A worker's telemetry side-connection: pushes that worker's
    /// `MetricsSnapshot` to the coordinator on the assigned interval.
    /// The pusher is the only initiator on its connection.
    MetricsPusher,
    /// A client's telemetry side-connection: the *coordinator* initiates
    /// here, pushing fleet loads on a timer so the client can maintain
    /// its in-flight gauge without a `MetricsPull` round trip per submit.
    MetricsSubscriber,
}

/// Wire form of `Result<(), SubmitError>` plus the one condition only the
/// networked coordinator can produce: the routed shard is down.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitOutcome {
    Accepted,
    UnknownTape,
    BadFileIndex,
    Stopping,
    Busy,
    /// The shard this tape routes to has no live worker; the request was
    /// never accepted (non-retryable until a replacement worker rejoins).
    ShardDown,
}

impl SubmitOutcome {
    pub fn from_submit(r: &Result<(), SubmitError>) -> SubmitOutcome {
        match r {
            Ok(()) => SubmitOutcome::Accepted,
            Err(SubmitError::UnknownTape) => SubmitOutcome::UnknownTape,
            Err(SubmitError::BadFileIndex) => SubmitOutcome::BadFileIndex,
            Err(SubmitError::Stopping) => SubmitOutcome::Stopping,
            Err(SubmitError::Busy) => SubmitOutcome::Busy,
            Err(SubmitError::ShardDown) => SubmitOutcome::ShardDown,
        }
    }

    pub fn into_submit(self) -> Result<(), SubmitError> {
        match self {
            SubmitOutcome::Accepted => Ok(()),
            SubmitOutcome::UnknownTape => Err(SubmitError::UnknownTape),
            SubmitOutcome::BadFileIndex => Err(SubmitError::BadFileIndex),
            SubmitOutcome::Stopping => Err(SubmitError::Stopping),
            SubmitOutcome::Busy => Err(SubmitError::Busy),
            SubmitOutcome::ShardDown => Err(SubmitError::ShardDown),
        }
    }
}

/// Every message that can cross a connection. One frame = one message.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    Hello { version: u16, role: Role },
    /// `shard` is the assigned shard id for a worker, `u32::MAX` for a
    /// client (clients have no shard identity).
    HelloAck { version: u16, shard: u32 },
    /// Hand a worker its shard: the coordinator-wide policy name, the
    /// shard's `CoordinatorConfig`, its ring partition of the catalog,
    /// and the telemetry push interval in ms (0 = the worker opens no
    /// pusher connection).
    Assign {
        shard: u32,
        policy: String,
        config: CoordinatorConfig,
        catalog: Vec<Tape>,
        push_ms: u64,
    },
    AssignAck { shard: u32 },
    Submit { id: u64, tape: String, file_index: u64 },
    SubmitResult { outcome: SubmitOutcome },
    MetricsPull,
    /// Per-shard loads. A worker replies with exactly one entry (its own
    /// shard, `routed = 0` — the coordinator owns routing counts); the
    /// coordinator replies to clients with the whole fleet.
    MetricsReply { loads: Vec<ShardLoad> },
    Drain,
    DrainResult { completions: Vec<Completion>, loads: Vec<ShardLoad> },
    Shutdown,
    /// Handshake or protocol failure; the sender closes after this.
    Error { message: String },
    /// Push-based telemetry (protocol v2): a worker's pusher connection
    /// carries one entry (its own shard); the coordinator's subscriber
    /// pushes carry the whole fleet. Advisory only — drain accounting
    /// stays on the pull/drain path.
    MetricsPush { loads: Vec<ShardLoad> },
    MetricsPushAck,
}

const TAG_HELLO: u8 = 1;
const TAG_HELLO_ACK: u8 = 2;
const TAG_ASSIGN: u8 = 3;
const TAG_ASSIGN_ACK: u8 = 4;
const TAG_SUBMIT: u8 = 5;
const TAG_SUBMIT_RESULT: u8 = 6;
const TAG_METRICS_PULL: u8 = 7;
const TAG_METRICS_REPLY: u8 = 8;
const TAG_DRAIN: u8 = 9;
const TAG_DRAIN_RESULT: u8 = 10;
const TAG_SHUTDOWN: u8 = 11;
const TAG_ERROR: u8 = 12;
const TAG_METRICS_PUSH: u8 = 13;
const TAG_METRICS_PUSH_ACK: u8 = 14;

// ---- encode primitives ------------------------------------------------

fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_be_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_be_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_be_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_bool(out: &mut Vec<u8>, v: bool) {
    put_u8(out, v as u8);
}

// ---- decode primitives ------------------------------------------------

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        Ok(u16::from_be_bytes(self.take(2)?.try_into().unwrap())) // audit:allow(panic-path) take(n) returned exactly n bytes; infallible conversion
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_be_bytes(self.take(4)?.try_into().unwrap())) // audit:allow(panic-path) take(n) returned exactly n bytes; infallible conversion
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_be_bytes(self.take(8)?.try_into().unwrap())) // audit:allow(panic-path) take(n) returned exactly n bytes; infallible conversion
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn str(&mut self) -> Result<String, WireError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::BadUtf8)
    }

    fn bool(&mut self, what: &'static str) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(WireError::BadEnum { what, value: v }),
        }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

// ---- composite fields -------------------------------------------------

fn put_tape(out: &mut Vec<u8>, t: &Tape) {
    put_str(out, &t.name);
    put_u32(out, t.files.len() as u32);
    for f in &t.files {
        put_u64(out, f.left);
        put_u64(out, f.size);
    }
}

fn get_tape(r: &mut Reader<'_>) -> Result<Tape, WireError> {
    let name = r.str()?;
    let n = r.u32()? as usize;
    let mut files = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        let left = r.u64()?;
        let size = r.u64()?;
        files.push(FileExtent { left, size });
    }
    Ok(Tape { name, files })
}

fn put_config(out: &mut Vec<u8>, c: &CoordinatorConfig) {
    put_u32(out, c.n_drives as u32);
    put_u64(out, c.batcher.window.as_nanos() as u64);
    put_u32(out, c.batcher.max_batch as u32);
    put_u64(out, c.batcher.max_tape_backlog as u64);
    put_f64(out, c.drive.mount_s);
    put_f64(out, c.drive.unmount_s);
    put_f64(out, c.drive.bytes_per_s);
    put_f64(out, c.drive.uturn_s);
    put_u32(out, c.drive.n_arms as u32);
    put_u8(out, match c.affinity {
        Affinity::None => 0,
        Affinity::Lru => 1,
    });
    put_bool(out, c.exclusive_tapes);
}

fn get_config(r: &mut Reader<'_>) -> Result<CoordinatorConfig, WireError> {
    let n_drives = r.u32()? as usize;
    let window = std::time::Duration::from_nanos(r.u64()?);
    let max_batch = r.u32()? as usize;
    let max_tape_backlog = r.u64()? as usize;
    let mount_s = r.f64()?;
    let unmount_s = r.f64()?;
    let bytes_per_s = r.f64()?;
    let uturn_s = r.f64()?;
    let n_arms = r.u32()? as usize;
    let affinity = match r.u8()? {
        0 => Affinity::None,
        1 => Affinity::Lru,
        v => return Err(WireError::BadEnum { what: "affinity", value: v }),
    };
    let exclusive_tapes = r.bool("exclusive_tapes")?;
    Ok(CoordinatorConfig {
        n_drives,
        batcher: BatcherConfig { window, max_batch, max_tape_backlog },
        drive: DriveParams { mount_s, unmount_s, bytes_per_s, uturn_s, n_arms },
        affinity,
        exclusive_tapes,
    })
}

/// [`MetricsSnapshot`] in exact declaration order — extend *in place* when
/// the snapshot grows (and bump [`PROTOCOL_VERSION`]).
fn put_snapshot(out: &mut Vec<u8>, m: &MetricsSnapshot) {
    put_u64(out, m.submitted);
    put_u64(out, m.completed);
    put_u64(out, m.rejected);
    put_u64(out, m.shed);
    put_u64(out, m.batches);
    put_u64(out, m.remount_hits);
    put_u64(out, m.remount_misses);
    put_u64(out, m.cartridge_parks);
    put_f64(out, m.mean_cartridge_wait_s);
    put_f64(out, m.max_cartridge_wait_s);
    put_u64(out, m.arm_ops);
    put_f64(out, m.mean_arm_wait_s);
    put_f64(out, m.max_arm_wait_s);
    put_f64(out, m.mean_latency_s);
    put_f64(out, m.mean_service_s);
    put_f64(out, m.mean_sched_s_per_batch);
    put_f64(out, m.p50_latency_s);
    put_f64(out, m.p99_latency_s);
    put_u64(out, m.incremental_appends);
    put_u64(out, m.incremental_rebuilds);
}

fn get_snapshot(r: &mut Reader<'_>) -> Result<MetricsSnapshot, WireError> {
    Ok(MetricsSnapshot {
        submitted: r.u64()?,
        completed: r.u64()?,
        rejected: r.u64()?,
        shed: r.u64()?,
        batches: r.u64()?,
        remount_hits: r.u64()?,
        remount_misses: r.u64()?,
        cartridge_parks: r.u64()?,
        mean_cartridge_wait_s: r.f64()?,
        max_cartridge_wait_s: r.f64()?,
        arm_ops: r.u64()?,
        mean_arm_wait_s: r.f64()?,
        max_arm_wait_s: r.f64()?,
        mean_latency_s: r.f64()?,
        mean_service_s: r.f64()?,
        mean_sched_s_per_batch: r.f64()?,
        p50_latency_s: r.f64()?,
        p99_latency_s: r.f64()?,
        incremental_appends: r.u64()?,
        incremental_rebuilds: r.u64()?,
    })
}

fn put_loads(out: &mut Vec<u8>, loads: &[ShardLoad]) {
    put_u32(out, loads.len() as u32);
    for l in loads {
        put_u32(out, l.shard as u32);
        put_u64(out, l.routed);
        put_snapshot(out, &l.metrics);
    }
}

fn get_loads(r: &mut Reader<'_>) -> Result<Vec<ShardLoad>, WireError> {
    let n = r.u32()? as usize;
    let mut loads = Vec::with_capacity(n.min(1 << 12));
    for _ in 0..n {
        let shard = r.u32()? as usize;
        let routed = r.u64()?;
        let metrics = get_snapshot(r)?;
        loads.push(ShardLoad { shard, routed, metrics });
    }
    Ok(loads)
}

fn put_completions(out: &mut Vec<u8>, cs: &[Completion]) {
    put_u32(out, cs.len() as u32);
    for c in cs {
        put_u64(out, c.request_id);
        put_str(out, &c.tape);
        put_f64(out, c.latency_s);
        put_f64(out, c.service_s);
    }
}

fn get_completions(r: &mut Reader<'_>) -> Result<Vec<Completion>, WireError> {
    let n = r.u32()? as usize;
    let mut cs = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        let request_id = r.u64()?;
        let tape = r.str()?;
        let latency_s = r.f64()?;
        let service_s = r.f64()?;
        cs.push(Completion { request_id, tape, latency_s, service_s });
    }
    Ok(cs)
}

// ---- message codec ----------------------------------------------------

/// Encode a message into a frame payload (tag + body).
pub fn encode(msg: &Message) -> Vec<u8> {
    let mut out = Vec::new();
    match msg {
        Message::Hello { version, role } => {
            put_u8(&mut out, TAG_HELLO);
            put_u16(&mut out, *version);
            put_u8(&mut out, match role {
                Role::Client => 0,
                Role::Worker => 1,
                Role::MetricsPusher => 2,
                Role::MetricsSubscriber => 3,
            });
        }
        Message::HelloAck { version, shard } => {
            put_u8(&mut out, TAG_HELLO_ACK);
            put_u16(&mut out, *version);
            put_u32(&mut out, *shard);
        }
        Message::Assign { shard, policy, config, catalog, push_ms } => {
            put_u8(&mut out, TAG_ASSIGN);
            put_u32(&mut out, *shard);
            put_str(&mut out, policy);
            put_config(&mut out, config);
            put_u32(&mut out, catalog.len() as u32);
            for t in catalog {
                put_tape(&mut out, t);
            }
            put_u64(&mut out, *push_ms);
        }
        Message::AssignAck { shard } => {
            put_u8(&mut out, TAG_ASSIGN_ACK);
            put_u32(&mut out, *shard);
        }
        Message::Submit { id, tape, file_index } => {
            put_u8(&mut out, TAG_SUBMIT);
            put_u64(&mut out, *id);
            put_str(&mut out, tape);
            put_u64(&mut out, *file_index);
        }
        Message::SubmitResult { outcome } => {
            put_u8(&mut out, TAG_SUBMIT_RESULT);
            put_u8(&mut out, match outcome {
                SubmitOutcome::Accepted => 0,
                SubmitOutcome::UnknownTape => 1,
                SubmitOutcome::BadFileIndex => 2,
                SubmitOutcome::Stopping => 3,
                SubmitOutcome::Busy => 4,
                SubmitOutcome::ShardDown => 5,
            });
        }
        Message::MetricsPull => put_u8(&mut out, TAG_METRICS_PULL),
        Message::MetricsReply { loads } => {
            put_u8(&mut out, TAG_METRICS_REPLY);
            put_loads(&mut out, loads);
        }
        Message::Drain => put_u8(&mut out, TAG_DRAIN),
        Message::DrainResult { completions, loads } => {
            put_u8(&mut out, TAG_DRAIN_RESULT);
            put_completions(&mut out, completions);
            put_loads(&mut out, loads);
        }
        Message::Shutdown => put_u8(&mut out, TAG_SHUTDOWN),
        Message::Error { message } => {
            put_u8(&mut out, TAG_ERROR);
            put_str(&mut out, message);
        }
        Message::MetricsPush { loads } => {
            put_u8(&mut out, TAG_METRICS_PUSH);
            put_loads(&mut out, loads);
        }
        Message::MetricsPushAck => put_u8(&mut out, TAG_METRICS_PUSH_ACK),
    }
    out
}

/// Decode a frame payload. The whole payload must be consumed.
pub fn decode(payload: &[u8]) -> Result<Message, WireError> {
    let mut r = Reader::new(payload);
    let tag = r.u8()?;
    let msg = match tag {
        TAG_HELLO => {
            let version = r.u16()?;
            let role = match r.u8()? {
                0 => Role::Client,
                1 => Role::Worker,
                2 => Role::MetricsPusher,
                3 => Role::MetricsSubscriber,
                v => return Err(WireError::BadEnum { what: "role", value: v }),
            };
            Message::Hello { version, role }
        }
        TAG_HELLO_ACK => Message::HelloAck { version: r.u16()?, shard: r.u32()? },
        TAG_ASSIGN => {
            let shard = r.u32()?;
            let policy = r.str()?;
            let config = get_config(&mut r)?;
            let n = r.u32()? as usize;
            let mut catalog = Vec::with_capacity(n.min(1 << 12));
            for _ in 0..n {
                catalog.push(get_tape(&mut r)?);
            }
            let push_ms = r.u64()?;
            Message::Assign { shard, policy, config, catalog, push_ms }
        }
        TAG_ASSIGN_ACK => Message::AssignAck { shard: r.u32()? },
        TAG_SUBMIT => {
            Message::Submit { id: r.u64()?, tape: r.str()?, file_index: r.u64()? }
        }
        TAG_SUBMIT_RESULT => {
            let outcome = match r.u8()? {
                0 => SubmitOutcome::Accepted,
                1 => SubmitOutcome::UnknownTape,
                2 => SubmitOutcome::BadFileIndex,
                3 => SubmitOutcome::Stopping,
                4 => SubmitOutcome::Busy,
                5 => SubmitOutcome::ShardDown,
                v => return Err(WireError::BadEnum { what: "submit outcome", value: v }),
            };
            Message::SubmitResult { outcome }
        }
        TAG_METRICS_PULL => Message::MetricsPull,
        TAG_METRICS_REPLY => Message::MetricsReply { loads: get_loads(&mut r)? },
        TAG_DRAIN => Message::Drain,
        TAG_DRAIN_RESULT => {
            let completions = get_completions(&mut r)?;
            let loads = get_loads(&mut r)?;
            Message::DrainResult { completions, loads }
        }
        TAG_SHUTDOWN => Message::Shutdown,
        TAG_ERROR => Message::Error { message: r.str()? },
        TAG_METRICS_PUSH => Message::MetricsPush { loads: get_loads(&mut r)? },
        TAG_METRICS_PUSH_ACK => Message::MetricsPushAck,
        other => return Err(WireError::BadTag(other)),
    };
    if r.remaining() > 0 {
        return Err(WireError::TrailingBytes(r.remaining()));
    }
    Ok(msg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn sample_snapshot() -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: 101,
            completed: 88,
            rejected: 3,
            shed: 13,
            batches: 21,
            remount_hits: 5,
            remount_misses: 16,
            cartridge_parks: 2,
            mean_cartridge_wait_s: 0.125,
            max_cartridge_wait_s: 1.5,
            arm_ops: 17,
            mean_arm_wait_s: 0.03125,
            max_arm_wait_s: 2.25,
            mean_latency_s: 61.0625,
            mean_service_s: 12.5,
            mean_sched_s_per_batch: 0.0009765625,
            p50_latency_s: 55.5,
            p99_latency_s: 120.75,
            incremental_appends: 34,
            incremental_rebuilds: 7,
        }
    }

    fn sample_messages() -> Vec<Message> {
        let config = CoordinatorConfig {
            n_drives: 6,
            batcher: BatcherConfig {
                window: Duration::from_millis(250),
                max_batch: 512,
                max_tape_backlog: 1 << 14,
            },
            drive: DriveParams {
                mount_s: 60.0,
                unmount_s: 40.0,
                bytes_per_s: 2e11,
                uturn_s: 2.0,
                n_arms: 3,
            },
            affinity: Affinity::Lru,
            exclusive_tapes: true,
        };
        let catalog = vec![
            Tape::from_sizes("TAPE000", &[1_000, 2_000, 3_000]),
            Tape::from_sizes("TAPE001", &[500; 8]),
            Tape { name: "EMPTY".into(), files: Vec::new() },
        ];
        vec![
            Message::Hello { version: PROTOCOL_VERSION, role: Role::Client },
            Message::Hello { version: PROTOCOL_VERSION, role: Role::Worker },
            Message::Hello { version: PROTOCOL_VERSION, role: Role::MetricsPusher },
            Message::Hello { version: PROTOCOL_VERSION, role: Role::MetricsSubscriber },
            Message::HelloAck { version: PROTOCOL_VERSION, shard: u32::MAX },
            Message::Assign {
                shard: 2,
                policy: "SimpleDP".into(),
                config,
                catalog,
                push_ms: 250,
            },
            Message::AssignAck { shard: 2 },
            Message::Submit { id: u64::MAX - 7, tape: "TAPE001".into(), file_index: 3 },
            Message::SubmitResult { outcome: SubmitOutcome::Accepted },
            Message::SubmitResult { outcome: SubmitOutcome::Busy },
            Message::SubmitResult { outcome: SubmitOutcome::ShardDown },
            Message::MetricsPull,
            Message::MetricsReply {
                loads: vec![
                    ShardLoad { shard: 0, routed: 40, metrics: sample_snapshot() },
                    ShardLoad { shard: 3, routed: 61, metrics: sample_snapshot() },
                ],
            },
            Message::Drain,
            Message::DrainResult {
                completions: vec![
                    Completion {
                        request_id: 9,
                        tape: "TAPE000".into(),
                        latency_s: 61.0625,
                        service_s: 12.03125,
                    },
                    Completion {
                        request_id: 10,
                        tape: "TAPE001".into(),
                        latency_s: 0.5,
                        service_s: 0.25,
                    },
                ],
                loads: vec![ShardLoad { shard: 1, routed: 2, metrics: sample_snapshot() }],
            },
            Message::Shutdown,
            Message::Error { message: "protocol version mismatch".into() },
            Message::MetricsPush {
                loads: vec![ShardLoad { shard: 2, routed: 0, metrics: sample_snapshot() }],
            },
            Message::MetricsPush { loads: Vec::new() },
            Message::MetricsPushAck,
        ]
    }

    #[test]
    fn every_message_round_trips_exactly() {
        for msg in sample_messages() {
            let bytes = encode(&msg);
            let back = decode(&bytes).unwrap_or_else(|e| panic!("{msg:?}: {e}"));
            assert_eq!(back, msg);
        }
    }

    #[test]
    fn f64_fields_round_trip_bit_for_bit() {
        // Values with no short decimal form: the bit-pattern encoding must
        // reproduce them exactly (the loopback-parity gate depends on it).
        let vals = [std::f64::consts::PI, 1.0 / 3.0, f64::MIN_POSITIVE, 1e300, -0.0];
        for &v in &vals {
            let msg = Message::DrainResult {
                completions: vec![Completion {
                    request_id: 1,
                    tape: "T".into(),
                    latency_s: v,
                    service_s: -v,
                }],
                loads: Vec::new(),
            };
            match decode(&encode(&msg)).unwrap() {
                Message::DrainResult { completions, .. } => {
                    assert_eq!(completions[0].latency_s.to_bits(), v.to_bits());
                    assert_eq!(completions[0].service_s.to_bits(), (-v).to_bits());
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn truncated_bodies_are_rejected() {
        for msg in sample_messages() {
            let bytes = encode(&msg);
            // Chopping any suffix (including the whole body) must fail,
            // never panic and never mis-decode.
            for cut in 0..bytes.len() {
                assert!(
                    decode(&bytes[..cut]).is_err(),
                    "{msg:?} decoded from a {cut}-byte prefix"
                );
            }
        }
    }

    #[test]
    fn trailing_bytes_and_bad_tags_are_rejected() {
        let mut bytes = encode(&Message::MetricsPull);
        bytes.push(0);
        assert_eq!(decode(&bytes), Err(WireError::TrailingBytes(1)));
        assert_eq!(decode(&[200]), Err(WireError::BadTag(200)));
        assert_eq!(decode(&[]), Err(WireError::Truncated));
    }

    #[test]
    fn bad_enum_discriminants_are_rejected() {
        // Hello with role byte 9.
        let mut bytes = encode(&Message::Hello {
            version: PROTOCOL_VERSION,
            role: Role::Client,
        });
        *bytes.last_mut().unwrap() = 9;
        assert_eq!(decode(&bytes), Err(WireError::BadEnum { what: "role", value: 9 }));
        // SubmitResult with outcome byte 77.
        let mut bytes = encode(&Message::SubmitResult { outcome: SubmitOutcome::Accepted });
        *bytes.last_mut().unwrap() = 77;
        assert!(matches!(decode(&bytes), Err(WireError::BadEnum { .. })));
    }
}
