//! Simulation layer.
//!
//! [`head`] is the **ground truth** of the whole crate: it executes a detour
//! list as an actual head trajectory and yields the exact service time of
//! every request. Every algorithm's internal cost accounting is validated
//! against it. [`trajectory`] is a second, deliberately naive implementation
//! (explicit polyline walk) used to cross-check `head` in tests.
//!
//! [`library`] simulates the robotic tape library (drive pool, robot-arm
//! mount pipeline, mount/unmount latencies) that the coordinator drives in
//! the end-to-end example, and hosts the [`DriveParams`] cost helpers. The
//! shared mount-pipeline vocabulary ([`Affinity`], [`MountPlan`],
//! [`pick_drive_slot`]) lives in [`crate::resources`] — the single
//! resource layer under the live coordinator and the replay engine — and
//! is re-exported here for compatibility.

pub mod head;
pub mod library;
pub mod trajectory;

pub use head::{evaluate, evaluate_from, SimOutcome};
pub use library::{
    pick_drive_slot, Affinity, DriveParams, LibraryMetrics, LibrarySim, MountPlan, TapeJob,
    TapeJobResult,
};
