//! Simulation layer.
//!
//! [`head`] is the **ground truth** of the whole crate: it executes a detour
//! list as an actual head trajectory and yields the exact service time of
//! every request. Every algorithm's internal cost accounting is validated
//! against it. [`trajectory`] is a second, deliberately naive implementation
//! (explicit polyline walk) used to cross-check `head` in tests.
//!
//! [`library`] simulates the robotic tape library (drive pool, mount/unmount
//! latencies) that the coordinator drives in the end-to-end example.

pub mod head;
pub mod library;
pub mod trajectory;

pub use head::{evaluate, evaluate_from, SimOutcome};
pub use library::{DriveParams, LibraryMetrics, LibrarySim, TapeJob, TapeJobResult};
