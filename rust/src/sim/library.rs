//! Robotic tape-library simulator: the physical substrate around LTSP.
//!
//! Models what the paper's §1 describes — a Spectra-TFinity-like library
//! where cartridges wait on shelves, a robotic arm mounts them into a pool
//! of TS1160-class drives, and the reading head then executes the schedule
//! computed by one of the [`crate::sched`] policies.
//!
//! The simulation is discrete-event over *tape jobs*: a job = one tape plus
//! the batch of requests currently queued for it. Drives are a resource
//! pool; per-request service times inside a mounted tape come from the
//! ground-truth head simulator, converted from tape-units (bytes) into
//! seconds through the drive's head speed.

use std::collections::BinaryHeap;

use crate::model::Instance;
use crate::resources::ArmTimeline;
use crate::sched::Scheduler;
use crate::sim::evaluate;

// The placement vocabulary historically lived here; it moved to the shared
// resource layer (single source of truth for replay + live coordinator)
// and is re-exported so `crate::sim::{Affinity, MountPlan, …}` callers
// keep working.
pub use crate::resources::{pick_drive_slot, Affinity, MountPlan};

/// Physical drive / robot parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriveParams {
    /// Robot fetch + load + thread time until the tape is readable (s).
    pub mount_s: f64,
    /// Rewind + unload + shelve time after the last read (s).
    pub unmount_s: f64,
    /// Head (tape) longitudinal speed in *logical* bytes/s. Positioning a
    /// 20 TB / ~1 km tape end-to-end takes on the order of 100 s (the
    /// paper's own speed estimate yields ~80 s average service times), so
    /// the effective positioning speed is ~200 GB of logical address space
    /// per second -- far above the ~400 MB/s streaming rate, because seeks
    /// move the tape without reading.
    pub bytes_per_s: f64,
    /// Seconds per U-turn (the mechanical deceleration of §3). Used to
    /// derive the byte-unit penalty `U` fed into the schedulers.
    pub uturn_s: f64,
    /// Robot arms in the library's mount pipeline. Every mount and unmount
    /// occupies one arm for `mount_s`/`unmount_s` and queues when all arms
    /// are busy. `0` models an unconstrained robot — the legacy fixed
    /// mount-cost model, in which mounts never contend.
    pub n_arms: usize,
}

impl Default for DriveParams {
    fn default() -> Self {
        DriveParams {
            mount_s: 60.0, // "about a minute" [5]
            unmount_s: 40.0,
            bytes_per_s: 200e9, // 20 TB end-to-end in ~100 s
            uturn_s: 2.0,
            n_arms: 0,
        }
    }
}

impl DriveParams {
    /// U-turn penalty expressed in tape bytes (the unit of the model),
    /// rounded to the nearest byte. Saturates explicitly at `u64::MAX`
    /// (and clamps NaN/negative products to 0) so a pathological
    /// `bytes_per_s` cannot wrap the penalty fed to the schedulers.
    pub fn uturn_bytes(&self) -> u64 {
        let b = (self.uturn_s * self.bytes_per_s).round();
        if !(b > 0.0) {
            // NaN or non-positive: no penalty.
            0
        } else if b >= u64::MAX as f64 {
            u64::MAX
        } else {
            b as u64
        }
    }

    /// Convert a tape-unit (bytes) duration to seconds.
    pub fn to_seconds(&self, tape_units: i128) -> f64 {
        tape_units as f64 / self.bytes_per_s
    }

    /// Mount duration in the virtual-time unit (µs), on the shared
    /// µs grid ([`crate::util::secs_to_us`]).
    pub fn mount_us(&self) -> u64 {
        crate::util::secs_to_us(self.mount_s)
    }

    /// Unmount duration in virtual µs (see [`DriveParams::mount_us`]).
    pub fn unmount_us(&self) -> u64 {
        crate::util::secs_to_us(self.unmount_s)
    }

    /// Mount-cost charge (seconds of added request latency) for one way a
    /// batch can land on a drive — the shared accounting used by the live
    /// coordinator and the replay engine's legacy (arm-less) path.
    pub fn mount_charge_s(&self, plan: MountPlan) -> f64 {
        match plan {
            MountPlan::Hit => 0.0,
            MountPlan::Mount => self.mount_s,
            MountPlan::EvictMount => self.unmount_s + self.mount_s,
        }
    }
}

/// One tape job to be scheduled on a drive.
#[derive(Debug, Clone)]
pub struct TapeJob {
    pub tape_name: String,
    /// Arrival time of the batch (s since simulation start).
    pub arrival_s: f64,
    /// The LTSP instance (requests on this tape, with U already set from
    /// the drive's U-turn cost).
    pub instance: Instance,
}

/// Outcome of serving one tape job.
#[derive(Debug, Clone)]
pub struct TapeJobResult {
    pub tape_name: String,
    /// Time the job waited for a free drive (s).
    pub drive_wait_s: f64,
    /// Time the mount waited for a free robot arm (s; 0 when
    /// `DriveParams::n_arms == 0`, the unconstrained robot).
    pub arm_wait_s: f64,
    /// Mount latency paid (s).
    pub mount_s: f64,
    /// Mean *in-tape* service time over the job's requests (s) — the
    /// paper's objective, scaled to seconds.
    pub mean_service_s: f64,
    /// Mean end-to-end request latency: wait + mount + in-tape service (s).
    pub mean_latency_s: f64,
    /// Total time the drive is busy with this job (mount + schedule span +
    /// unmount, s).
    pub drive_busy_s: f64,
    /// Number of user requests served.
    pub n_requests: u64,
    /// Completion time of the job (s since simulation start).
    pub done_s: f64,
}

/// Aggregate metrics over a whole simulation run.
#[derive(Debug, Clone, Default)]
pub struct LibraryMetrics {
    pub jobs: usize,
    pub requests: u64,
    /// Request-weighted mean end-to-end latency (s).
    pub mean_latency_s: f64,
    /// Request-weighted mean in-tape service time (s).
    pub mean_service_s: f64,
    /// Request-weighted mean robot-arm wait before the mount (s).
    pub mean_arm_wait_s: f64,
    /// Time the last job completes (s).
    pub makespan_s: f64,
    /// Mean drive utilization over the makespan (0..=1).
    pub drive_utilization: f64,
}

/// The library: a drive pool + a scheduler policy.
pub struct LibrarySim<'a> {
    pub params: DriveParams,
    pub n_drives: usize,
    pub policy: &'a dyn Scheduler,
}

impl<'a> LibrarySim<'a> {
    pub fn new(params: DriveParams, n_drives: usize, policy: &'a dyn Scheduler) -> Self {
        assert!(n_drives > 0);
        LibrarySim { params, n_drives, policy }
    }

    /// Run the event loop over `jobs` (any arrival order; stable FIFO per
    /// arrival time). Returns per-job results and aggregate metrics.
    pub fn run(&self, mut jobs: Vec<TapeJob>) -> (Vec<TapeJobResult>, LibraryMetrics) {
        jobs.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        // Min-heap of drive free times (via Reverse on ordered f64 bits).
        let mut drives: BinaryHeap<std::cmp::Reverse<u64>> =
            (0..self.n_drives).map(|_| std::cmp::Reverse(0u64)).collect();
        let to_bits = |s: f64| (s.max(0.0) * 1e6) as u64; // µs ticks
        let from_bits = |b: u64| b as f64 / 1e6;

        // Robot arms: the shared interval-reservation timeline
        // ([`crate::resources::ArmTimeline`]). Mounts are granted in job
        // (arrival) order — an analytic approximation; the replay engine
        // models the exact event order, unmounts included.
        let mut arms = ArmTimeline::new(self.params.n_arms);

        let mut results = Vec::with_capacity(jobs.len());
        let mut busy_total = 0.0;
        for job in &jobs {
            let std::cmp::Reverse(free_at) = drives.pop().expect("pool non-empty");
            let start = from_bits(free_at).max(job.arrival_s);
            let wait = start - job.arrival_s;

            // The mount serializes through the arm timeline (zero wait
            // when n_arms == 0: the legacy unconstrained robot).
            let arm_wait = from_bits(
                arms.reserve(to_bits(start), to_bits(self.params.mount_s)).wait_us,
            );

            // Compute the schedule and in-tape service times.
            let sched = self.policy.schedule(&job.instance);
            let out = evaluate(&job.instance, &sched);
            let mean_service =
                self.params.to_seconds(out.cost) / job.instance.n() as f64;
            let span = self.params.to_seconds(out.finish);
            let busy = arm_wait + self.params.mount_s + span + self.params.unmount_s;
            let done = start + arm_wait + self.params.mount_s + span;

            busy_total += busy;
            drives.push(std::cmp::Reverse(to_bits(start + busy)));
            results.push(TapeJobResult {
                tape_name: job.tape_name.clone(),
                drive_wait_s: wait,
                arm_wait_s: arm_wait,
                mount_s: self.params.mount_s,
                mean_service_s: mean_service,
                mean_latency_s: wait + arm_wait + self.params.mount_s + mean_service,
                drive_busy_s: busy,
                n_requests: job.instance.n(),
                done_s: done,
            });
        }

        let requests: u64 = results.iter().map(|r| r.n_requests).sum();
        let wsum = |f: &dyn Fn(&TapeJobResult) -> f64| -> f64 {
            results.iter().map(|r| f(r) * r.n_requests as f64).sum::<f64>()
                / requests.max(1) as f64
        };
        let makespan = results
            .iter()
            .map(|r| r.done_s)
            .fold(0.0f64, f64::max);
        let metrics = LibraryMetrics {
            jobs: results.len(),
            requests,
            mean_latency_s: wsum(&|r| r.mean_latency_s),
            mean_service_s: wsum(&|r| r.mean_service_s),
            mean_arm_wait_s: wsum(&|r| r.arm_wait_s),
            makespan_s: makespan,
            drive_utilization: if makespan > 0.0 {
                (busy_total / self.n_drives as f64 / makespan).min(1.0)
            } else {
                0.0
            },
        };
        (results, metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ReqFile;
    use crate::sched::{Gs, NoDetour};

    fn job(name: &str, arrival: f64, u: u64) -> TapeJob {
        let inst = Instance::new(
            1_000_000,
            u,
            vec![
                ReqFile { l: 0, r: 1_000, x: 2 },
                ReqFile { l: 900_000, r: 901_000, x: 5 },
            ],
        )
        .unwrap();
        TapeJob { tape_name: name.into(), arrival_s: arrival, instance: inst }
    }

    fn params() -> DriveParams {
        DriveParams {
            mount_s: 10.0,
            unmount_s: 5.0,
            bytes_per_s: 1e6,
            uturn_s: 1.0,
            n_arms: 0,
        }
    }

    #[test]
    fn single_drive_serializes_jobs() {
        let sim = LibrarySim::new(params(), 1, &NoDetour);
        let (res, m) = sim.run(vec![job("A", 0.0, 0), job("B", 0.0, 0)]);
        assert_eq!(res.len(), 2);
        assert_eq!(res[0].drive_wait_s, 0.0);
        // B waits for A's full busy period.
        assert!((res[1].drive_wait_s - res[0].drive_busy_s).abs() < 1e-6);
        assert_eq!(m.jobs, 2);
        assert_eq!(m.requests, 14);
    }

    #[test]
    fn more_drives_reduce_waiting() {
        let jobs: Vec<TapeJob> = (0..8).map(|i| job(&format!("T{i}"), 0.0, 0)).collect();
        let sim1 = LibrarySim::new(params(), 1, &NoDetour);
        let sim4 = LibrarySim::new(params(), 4, &NoDetour);
        let (_, m1) = sim1.run(jobs.clone());
        let (_, m4) = sim4.run(jobs);
        assert!(m4.mean_latency_s < m1.mean_latency_s);
        assert!(m4.makespan_s < m1.makespan_s);
    }

    #[test]
    fn better_policy_lowers_mean_service() {
        // The urgent far-right file makes GS beat NoDetour on this instance.
        let sim_nd = LibrarySim::new(params(), 2, &NoDetour);
        let sim_gs = LibrarySim::new(params(), 2, &Gs);
        let u = params().uturn_bytes();
        let (_, m_nd) = sim_nd.run(vec![job("A", 0.0, u)]);
        let (_, m_gs) = sim_gs.run(vec![job("A", 0.0, u)]);
        assert!(m_gs.mean_service_s < m_nd.mean_service_s);
    }

    #[test]
    fn utilization_bounded_and_positive() {
        let sim = LibrarySim::new(params(), 3, &NoDetour);
        let jobs: Vec<TapeJob> = (0..5).map(|i| job(&format!("T{i}"), i as f64, 0)).collect();
        let (_, m) = sim.run(jobs);
        assert!(m.drive_utilization > 0.0 && m.drive_utilization <= 1.0);
    }

    #[test]
    fn uturn_bytes_conversion() {
        let p = params();
        assert_eq!(p.uturn_bytes(), 1_000_000);
        assert!((p.to_seconds(2_000_000) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn uturn_bytes_rounds_and_saturates() {
        // Regression: the penalty used to truncate (0.9999… → 0) and a
        // pathological bytes_per_s could wrap through the f64→u64 cast.
        let p = |uturn_s: f64, bytes_per_s: f64| DriveParams {
            uturn_s,
            bytes_per_s,
            ..DriveParams::default()
        };
        assert_eq!(p(0.0015, 1e6).uturn_bytes(), 1_500);
        assert_eq!(p(0.9999, 1.0).uturn_bytes(), 1, "rounds, not truncates");
        assert_eq!(p(0.4, 1.0).uturn_bytes(), 0);
        assert_eq!(p(2.0, f64::MAX).uturn_bytes(), u64::MAX, "saturates high");
        assert_eq!(p(1.0, f64::INFINITY).uturn_bytes(), u64::MAX);
        assert_eq!(p(-1.0, 1e9).uturn_bytes(), 0, "negative clamps to zero");
        assert_eq!(p(f64::NAN, 1e9).uturn_bytes(), 0, "NaN clamps to zero");
    }

    #[test]
    fn mount_charge_helpers_are_consistent() {
        let p = params();
        assert_eq!(p.mount_us(), 10_000_000);
        assert_eq!(p.unmount_us(), 5_000_000);
        assert_eq!(p.mount_charge_s(MountPlan::Hit), 0.0);
        assert_eq!(p.mount_charge_s(MountPlan::Mount), p.mount_s);
        assert_eq!(p.mount_charge_s(MountPlan::EvictMount), p.unmount_s + p.mount_s);
        assert_eq!(Affinity::from_name("LRU"), Some(Affinity::Lru));
        assert_eq!(Affinity::from_name("none"), Some(Affinity::None));
        assert_eq!(Affinity::from_name("fifo"), None);
        assert_eq!(Affinity::Lru.name(), "lru");
        assert_eq!(Affinity::default(), Affinity::None);
    }

    #[test]
    fn single_arm_serializes_concurrent_mounts() {
        // Two free drives but one robot arm: both jobs get a drive at t=0,
        // yet B's mount queues behind A's for exactly mount_s.
        let mut p = params();
        p.n_arms = 1;
        let sim = LibrarySim::new(p, 2, &NoDetour);
        let (res, m) = sim.run(vec![job("A", 0.0, 0), job("B", 0.0, 0)]);
        assert_eq!(res[0].drive_wait_s, 0.0);
        assert_eq!(res[1].drive_wait_s, 0.0, "drives are not the bottleneck");
        assert_eq!(res[0].arm_wait_s, 0.0);
        assert!(
            (res[1].arm_wait_s - p.mount_s).abs() < 1e-6,
            "B's mount queues behind A's: waited {}",
            res[1].arm_wait_s
        );
        assert!(m.mean_arm_wait_s > 0.0);
        assert!(
            (res[1].mean_latency_s - (res[0].mean_latency_s + p.mount_s)).abs() < 1e-6,
            "the arm wait shows up in end-to-end latency"
        );

        // n_arms == 0 (unconstrained robot): byte-for-byte the old model.
        let sim0 = LibrarySim::new(params(), 2, &NoDetour);
        let (res0, m0) = sim0.run(vec![job("A", 0.0, 0), job("B", 0.0, 0)]);
        assert!(res0.iter().all(|r| r.arm_wait_s == 0.0));
        assert_eq!(m0.mean_arm_wait_s, 0.0);
        assert!(m0.mean_latency_s < m.mean_latency_s);
    }
}
