//! Naive polyline-based trajectory simulator.
//!
//! Builds the explicit `(time, position)` polyline of the head and derives
//! each file's service time by scanning for the first rightward segment that
//! fully covers it. Deliberately independent from [`super::head`] (different
//! data flow, no incremental serving) so the two can cross-check each other
//! in property tests.

use crate::model::{Cost, Instance};
use crate::sched::Detour;

/// A segment of head movement. U-turn dwells are encoded as zero-length
/// segments of duration `U`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    pub t0: Cost,
    pub t1: Cost,
    pub from: Cost,
    pub to: Cost,
}

/// Build the full trajectory polyline for a detour list (sorted internally),
/// extended through the implicit final sweep to the right end of the tape.
pub fn polyline(inst: &Instance, detours: &[Detour]) -> Vec<Segment> {
    let mut order: Vec<Detour> = detours.to_vec();
    order.sort_by(|p, q| q.a.cmp(&p.a).then(p.b.cmp(&q.b)));
    order.dedup();

    let u = inst.u() as Cost;
    let mut segs = Vec::new();
    let mut t: Cost = 0;
    let mut pos: Cost = inst.tape_len() as Cost;
    let push = |segs: &mut Vec<Segment>, t: &mut Cost, pos: &mut Cost, to: Cost| {
        let d = (*pos - to).abs();
        segs.push(Segment { t0: *t, t1: *t + d, from: *pos, to });
        *t += d;
        *pos = to;
    };
    let dwell = |segs: &mut Vec<Segment>, t: &mut Cost, pos: Cost, u: Cost| {
        segs.push(Segment { t0: *t, t1: *t + u, from: pos, to: pos });
        *t += u;
    };

    for d in &order {
        let la = inst.l(d.a) as Cost;
        let rb = inst.r(d.b) as Cost;
        push(&mut segs, &mut t, &mut pos, la);
        dwell(&mut segs, &mut t, pos, u);
        push(&mut segs, &mut t, &mut pos, rb);
        dwell(&mut segs, &mut t, pos, u);
        push(&mut segs, &mut t, &mut pos, la);
    }
    // Final sweep: down to the leftmost file, then all the way right.
    let lmin = inst.l(0) as Cost;
    if pos > lmin {
        push(&mut segs, &mut t, &mut pos, lmin);
    }
    dwell(&mut segs, &mut t, pos, u);
    push(&mut segs, &mut t, &mut pos, inst.tape_len() as Cost);
    segs
}

/// Service time of every file: first rightward segment fully covering it.
pub fn service_times(inst: &Instance, detours: &[Detour]) -> Vec<Cost> {
    let segs = polyline(inst, detours);
    (0..inst.k())
        .map(|f| {
            let (l, r) = (inst.l(f) as Cost, inst.r(f) as Cost);
            segs.iter()
                .filter(|s| s.to > s.from) // rightward
                .find(|s| s.from <= l && r <= s.to)
                .map(|s| s.t0 + (r - s.from))
                .expect("final sweep serves every file")
        })
        .collect()
}

/// Total cost via the polyline walk.
pub fn cost(inst: &Instance, detours: &[Detour]) -> Cost {
    service_times(inst, detours)
        .iter()
        .enumerate()
        .map(|(f, &t)| inst.x(f) as Cost * t)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ReqFile;
    use crate::sim::evaluate;

    fn inst(u: u64, files: &[(u64, u64, u64)], m: u64) -> Instance {
        Instance::new(m, u, files.iter().map(|&(l, r, x)| ReqFile { l, r, x }).collect())
            .unwrap()
    }

    #[test]
    fn agrees_with_head_simulator_on_fixtures() {
        let cases: Vec<(Instance, Vec<Detour>)> = vec![
            (inst(5, &[(10, 20, 1), (50, 60, 2)], 100), vec![]),
            (inst(5, &[(10, 20, 1), (50, 60, 2)], 100), vec![Detour::atomic(1)]),
            (inst(5, &[(10, 20, 1), (50, 60, 2)], 100), vec![Detour::atomic(0)]),
            (
                inst(3, &[(0, 10, 1), (20, 30, 4), (40, 50, 1)], 100),
                vec![Detour::new(1, 2), Detour::atomic(2)],
            ),
            (
                inst(0, &[(0, 10, 1), (20, 30, 1), (40, 50, 1)], 100),
                vec![Detour::new(0, 1), Detour::new(1, 2)],
            ),
        ];
        for (i, d) in cases {
            let head = evaluate(&i, &d);
            assert_eq!(service_times(&i, &d), head.service, "detours {:?}", d);
            assert_eq!(cost(&i, &d), head.cost);
        }
    }

    #[test]
    fn polyline_is_continuous() {
        let i = inst(2, &[(5, 10, 1), (30, 42, 2)], 80);
        let segs = polyline(&i, &[Detour::atomic(1), Detour::atomic(0)]);
        for w in segs.windows(2) {
            assert_eq!(w[0].t1, w[1].t0);
            assert_eq!(w[0].to, w[1].from);
        }
        assert_eq!(segs[0].t0, 0);
        assert_eq!(segs[0].from, 80);
    }
}
