//! Event-based head-trajectory simulator — the normative cost semantics.
//!
//! Semantics (paper §3–4.1):
//! - The head starts at the right end `m` of the tape, moving left, at t = 0.
//! - Detours are executed in decreasing order of left endpoint: when the head
//!   first attains `ℓ(a)` of detour `(a, b)`, it U-turns (+U), sweeps right to
//!   `r(b)` serving every not-yet-served file fully contained in the sweep,
//!   U-turns again (+U) and comes back to `ℓ(a)`, then resumes moving left.
//! - After all explicit detours, the implicit final detour: the head moves
//!   left to the leftmost unserved file (if any), U-turns (+U), and sweeps
//!   right, serving every remaining file. Movement after the last service
//!   does not count toward anything. The U-turn is the reversal cost, so a
//!   head that has **never** reversed — no detours executed and already at
//!   or left of every unserved file (only reachable through
//!   [`evaluate_from`]'s arbitrary start) — sweeps right without paying U.
//! - A file is served when it has been traversed left-to-right entirely; the
//!   service time of its `x(f)` requests is the instant its right end is
//!   passed. Cost = `Σ_f x(f) · t(f)`.

use crate::model::{Cost, Instance};
use crate::sched::Detour;

/// Outcome of executing a schedule.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// `Σ_f x(f) · t(f)` — the objective.
    pub cost: Cost,
    /// Service time of each requested file (all files are always served).
    pub service: Vec<Cost>,
    /// Time at which the last request is served.
    pub finish: Cost,
    /// Number of U-turns performed up to the last service.
    pub uturns: u32,
}

impl SimOutcome {
    /// Average service time over the `n` requests.
    pub fn mean_service_time(&self, inst: &Instance) -> f64 {
        self.cost as f64 / inst.n() as f64
    }
}

/// Execute `detours` on `inst` and return exact per-file service times.
///
/// Accepts **any** detour list (not only laminar ones): duplicates are
/// collapsed, execution order is decreasing left endpoint (ties broken by
/// increasing right endpoint so that redundant nested duplicates cost their
/// worth), and useless movement is still paid for — this is what makes the
/// simulator a fair judge of heuristic output such as NFGS's.
pub fn evaluate(inst: &Instance, detours: &[Detour]) -> SimOutcome {
    evaluate_from(inst, detours, inst.tape_len())
}

/// [`evaluate`] with an arbitrary head starting position (the paper's
/// conclusion extension). Every detour must start at or left of `start`
/// (a head starting at `start` can never meet a righter detour).
///
/// Cold-start semantics: the head at `start` has no momentum. If no detour
/// is executed and `start` is at or left of the leftmost requested file,
/// the final sweep proceeds rightward with **no** U-turn charge — the head
/// never reverses. (From the right tape end this case cannot arise: every
/// file lies strictly left of `m`.)
pub fn evaluate_from(inst: &Instance, detours: &[Detour], start: u64) -> SimOutcome {
    let k = inst.k();
    for d in detours {
        assert!(d.a <= d.b && d.b < k, "detour {:?} out of range (k={k})", d);
        assert!(
            inst.l(d.a) <= start,
            "detour {:?} starts right of the head start {start}",
            d
        );
    }
    // Execution order: decreasing a. For equal a, the head turning at ℓ(a)
    // performs the *shorter* detour first only if listed; we keep all and
    // execute in increasing b so each adds its movement.
    let mut order: Vec<Detour> = detours.to_vec();
    order.sort_by(|p, q| q.a.cmp(&p.a).then(p.b.cmp(&q.b)));
    order.dedup();

    let mut served = vec![false; k];
    let mut service: Vec<Cost> = vec![0; k];
    let mut t: Cost = 0;
    let mut pos: Cost = start as Cost;
    let u = inst.u() as Cost;
    let mut uturns = 0u32;

    for d in &order {
        let la = inst.l(d.a) as Cost;
        let rb = inst.r(d.b) as Cost;
        debug_assert!(la <= pos, "detours must be met right-to-left");
        // Move left to ℓ(a), turn.
        t += pos - la;
        t += u;
        uturns += 1;
        // Rightward sweep ℓ(a) → r(b): serve unserved files inside.
        for f in d.a..=d.b {
            if !served[f] {
                served[f] = true;
                service[f] = t + (inst.r(f) as Cost - la);
            }
        }
        // Reach r(b), turn, come back to ℓ(a).
        t += rb - la;
        t += u;
        uturns += 1;
        t += rb - la;
        pos = la;
    }

    // Implicit final detour: serve whatever remains.
    if let Some(fmin) = (0..k).find(|&f| !served[f]) {
        let sweep_from = pos.min(inst.l(fmin) as Cost);
        t += pos - sweep_from; // move further left if needed (free if sweep_from==pos)
        // The U-turn is the *reversal* cost (§3): it is paid only when the
        // head actually reverses — either a prior detour left it travelling
        // leftward, or it must first move left of its current position to
        // reach the leftmost unserved file. A cold start (no detours
        // executed, head already at or left of every unserved file) sweeps
        // right directly and pays nothing; charging `u` there over-counted
        // `uturns` and cost relative to the paper's U-turn model.
        if uturns > 0 || sweep_from < pos {
            t += u;
            uturns += 1;
        }
        for f in 0..k {
            if !served[f] {
                served[f] = true;
                service[f] = t + (inst.r(f) as Cost - sweep_from);
            }
        }
    }

    let cost = (0..k).map(|f| inst.x(f) as Cost * service[f]).sum();
    let finish = service.iter().copied().max().unwrap_or(0);
    SimOutcome { cost, service, finish, uturns }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ReqFile;

    fn inst(u: u64, files: &[(u64, u64, u64)], m: u64) -> Instance {
        Instance::new(
            m,
            u,
            files.iter().map(|&(l, r, x)| ReqFile { l, r, x }).collect(),
        )
        .unwrap()
    }

    #[test]
    fn no_detours_single_sweep() {
        // Files [10,20) x1, [50,60) x2, tape len 100, U = 5.
        let i = inst(5, &[(10, 20, 1), (50, 60, 2)], 100);
        let out = evaluate(&i, &[]);
        // Head: 100 → 10 (t=90), U-turn (95), then serve f0 at 95+10=105,
        // f1 at 95+50=145.
        assert_eq!(out.service, vec![105, 145]);
        assert_eq!(out.cost, 105 + 2 * 145);
        assert_eq!(out.uturns, 1);
        assert_eq!(out.finish, 145);
    }

    #[test]
    fn atomic_detour_on_right_file() {
        // Same instance; detour (1,1): serve f1 early.
        let i = inst(5, &[(10, 20, 1), (50, 60, 2)], 100);
        let out = evaluate(&i, &[Detour::atomic(1)]);
        // Head: 100 → 50 (t=50), U (55), serve f1 at 55+10=65, reach 60 (65),
        // U (70), back to 50 (80). Then to 10 (120), U (125), serve f0 at 135.
        assert_eq!(out.service, vec![135, 65]);
        assert_eq!(out.cost, 135 + 2 * 65);
        assert_eq!(out.uturns, 3);
    }

    #[test]
    fn detour_on_leftmost_file_then_final_sweep() {
        let i = inst(5, &[(10, 20, 1), (50, 60, 2)], 100);
        let out = evaluate(&i, &[Detour::atomic(0)]);
        // Head: 100 → 10 (90), U (95), serve f0 at 105, reach 20 (105), U
        // (110), back to 10 (120). f1 unserved: already at ℓ(f0)=10 < ℓ(f1);
        // final sweep starts at pos=10: U (125), serve f1 at 125+50=175.
        assert_eq!(out.service, vec![105, 175]);
        assert_eq!(out.uturns, 3);
    }

    #[test]
    fn nested_detours_figure1_style() {
        // Three files; inner detour (2,2) executed before outer (1,2).
        let i = inst(0, &[(0, 10, 1), (20, 30, 1), (40, 50, 1)], 100);
        let out = evaluate(&i, &[Detour::new(1, 2), Detour::atomic(2)]);
        // Order: (2,2) then (1,2).
        // 100→40 (60), serve f2 at 70, back at 40 (80).
        // 40→20 (100), sweep right to 50: f1 served at 110; f2 already
        // served. Back at 20 (160). Final: 20→0 (180), serve f0 at 190.
        assert_eq!(out.service, vec![190, 110, 70]);
    }

    #[test]
    fn crossing_detours_still_executable() {
        // Non-laminar list (1,2) & (0,1): f1 served by the rightmost detour.
        let i = inst(0, &[(0, 10, 1), (20, 30, 1), (40, 50, 1)], 100);
        let out = evaluate(&i, &[Detour::new(0, 1), Detour::new(1, 2)]);
        // (1,2) first: 100→20 (80), f1 at 90, f2 at 110, back at 20 (140).
        // (0,1): 20→0 (160), f0 at 170, sweep to r(1)=30 wasted, back (220).
        // Nothing left.
        assert_eq!(out.service, vec![170, 90, 110]);
    }

    #[test]
    fn duplicate_detours_collapse() {
        let i = inst(3, &[(10, 20, 2)], 100);
        let a = evaluate(&i, &[Detour::atomic(0)]);
        let b = evaluate(&i, &[Detour::atomic(0), Detour::atomic(0)]);
        assert_eq!(a.cost, b.cost);
    }

    #[test]
    fn uturn_penalty_delays_everything() {
        let i0 = inst(0, &[(10, 20, 1), (50, 60, 1)], 100);
        let i9 = inst(9, &[(10, 20, 1), (50, 60, 1)], 100);
        let d = vec![Detour::atomic(1)];
        let c0 = evaluate(&i0, &d);
        let c9 = evaluate(&i9, &d);
        // f1 pays 1 U-turn, f0 pays 3.
        assert_eq!(c9.service[1] - c0.service[1], 9);
        assert_eq!(c9.service[0] - c0.service[0], 27);
    }

    #[test]
    fn cold_start_left_of_files_pays_no_uturn() {
        // Regression: the implicit final sweep used to charge U even when
        // the head had never reversed. A head starting at 0 (left of every
        // file) with no detours sweeps right directly: 0 U-turns, and every
        // service time is exactly the right endpoint minus the start.
        let i = inst(7, &[(10, 20, 1), (50, 60, 2)], 100);
        let out = evaluate_from(&i, &[], 0);
        assert_eq!(out.uturns, 0, "cold start must not reverse");
        assert_eq!(out.service, vec![20, 60]);
        assert_eq!(out.cost, 20 + 2 * 60);

        // Starting exactly at the leftmost file's left edge is still cold.
        let out = evaluate_from(&i, &[], 10);
        assert_eq!(out.uturns, 0);
        assert_eq!(out.service, vec![20 - 10, 60 - 10]);
    }

    #[test]
    fn start_right_of_leftmost_file_still_pays_the_uturn() {
        // One step right of ℓ(f₀): the head must travel left then reverse,
        // so the U-turn is charged exactly as before.
        let i = inst(7, &[(10, 20, 1), (50, 60, 2)], 100);
        let out = evaluate_from(&i, &[], 11);
        assert_eq!(out.uturns, 1);
        // 11 → 10 (t=1), U (8), serve f0 at 8+10=18, f1 at 8+50=58.
        assert_eq!(out.service, vec![18, 58]);
    }

    #[test]
    fn cold_start_exemption_needs_a_virgin_head() {
        // After a detour the head returns moving left: even if it now sits
        // at or left of the remaining files, the final sweep reverses and
        // pays U. (Detour on f0 leaves the head at ℓ(f0)=10 < ℓ(f1)=50.)
        let i = inst(5, &[(10, 20, 1), (50, 60, 2)], 100);
        let out = evaluate_from(&i, &[Detour::atomic(0)], 100);
        assert_eq!(out.uturns, 3, "the final sweep still reverses");
        // And the default right-end entry point is untouched by the fix.
        let plain = evaluate(&i, &[]);
        assert_eq!(plain.uturns, 1);
        assert_eq!(plain.service, vec![105, 145]);
    }

    #[test]
    fn gap_between_files_costs_travel() {
        // Requested files with a hole between them; final sweep crosses it.
        let i = inst(0, &[(0, 10, 1), (90, 100, 1)], 100);
        let out = evaluate(&i, &[]);
        assert_eq!(out.service, vec![110, 200]);
    }
}
