//! The cartridge ledger: per-tape mount exclusivity.
//!
//! A physical cartridge exists once — it can be threaded in at most one
//! drive at any instant. Before this ledger existed the serving stack
//! quietly mounted "copies" of a hot tape in several drives at once, which
//! hides exactly the head-of-line waiting the approximate-policy
//! literature worries about. The ledger is the single authority both
//! serving paths consult: the replay engine keys it by catalog tape index,
//! the live coordinator by tape name, and each parks its own batch payload
//! `W` on the per-cartridge waitlist.
//!
//! Lifecycle per cartridge:
//!
//! ```text
//!             acquire(k, d)                 release_threaded(k)   (LRU)
//!  unthreaded ───────────────▶ in use in d ───────────────────▶ idle in d
//!      ▲                            │                               │
//!      │     release_unthreaded(k)  │                 begin_evict / │
//!      └────────────────────────────┴──────────────── acquire(k, d)─┘
//! ```
//!
//! A dispatcher checks [`CartridgeLedger::available`] before placing a
//! batch; unavailable batches go to [`CartridgeLedger::park`]. Every
//! release hands freed cartridges with waiters to a FIFO ready queue the
//! dispatcher drains via [`CartridgeLedger::pop_ready`] — the park → pop
//! interval is the batch's `cartridge_wait`. The ledger never reads a
//! clock; callers time the wait on their own grid (virtual or wall).

use std::collections::{HashMap, VecDeque};
use std::hash::Hash;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct CartState {
    drive: usize,
    busy: bool,
}

/// Per-cartridge exclusivity state + FIFO waitlists. `K` is the tape key
/// (catalog index in the replay engine, tape name in the live
/// coordinator); `W` is whatever the caller parks (its batch plus a
/// park timestamp).
#[derive(Debug)]
pub struct CartridgeLedger<K: Eq + Hash + Clone, W> {
    /// Cartridges currently threaded (or being moved) — absent = shelved.
    threaded: HashMap<K, CartState>,
    /// Per-cartridge FIFO of batches waiting for the cartridge to free.
    parked: HashMap<K, VecDeque<W>>,
    /// Cartridges that freed while waiters were parked, FIFO by free time.
    ready: VecDeque<K>,
}

impl<K: Eq + Hash + Clone, W> CartridgeLedger<K, W> {
    pub fn new() -> CartridgeLedger<K, W> {
        CartridgeLedger { threaded: HashMap::new(), parked: HashMap::new(), ready: VecDeque::new() }
    }

    /// May a *new* batch for `k` dispatch right now? `false` while the
    /// cartridge is in use in any drive, or while earlier batches are
    /// already parked waiting for it (FIFO fairness: latecomers queue
    /// behind them).
    pub fn available(&self, k: &K) -> bool {
        if self.parked.get(k).map_or(false, |q| !q.is_empty()) {
            return false;
        }
        self.threaded.get(k).map_or(true, |st| !st.busy)
    }

    /// Drive `drive` takes the cartridge: a fresh mount (or mount-after-
    /// evict) on an unthreaded cartridge, or a remount hit on the drive
    /// already holding it. Panics when the cartridge is busy or threaded
    /// in a *different* drive — the exclusivity invariant this ledger
    /// exists to enforce.
    pub fn acquire(&mut self, k: &K, drive: usize) {
        match self.threaded.get_mut(k) {
            Some(st) => {
                assert!(
                    st.drive == drive && !st.busy,
                    "cartridge exclusivity violated: acquiring a cartridge that is busy \
                     or threaded in another drive"
                );
                st.busy = true;
            }
            None => {
                self.threaded.insert(k.clone(), CartState { drive, busy: true });
            }
        }
    }

    /// An idle threaded cartridge is being evicted: the unmount owns it
    /// until the caller reports [`CartridgeLedger::release_unthreaded`].
    pub fn begin_evict(&mut self, k: &K) {
        let st = self.threaded.get_mut(k).expect("evicting an unthreaded cartridge");
        assert!(!st.busy, "evicting a cartridge still in use");
        st.busy = true;
    }

    /// Queue a batch until the cartridge frees.
    pub fn park(&mut self, k: K, w: W) {
        self.parked.entry(k).or_default().push_back(w);
    }

    /// The cartridge's batch finished but the tape stays threaded (LRU
    /// lazy unmount); waiters, if any, become dispatchable.
    pub fn release_threaded(&mut self, k: &K) {
        let st = self.threaded.get_mut(k).expect("releasing an unthreaded cartridge");
        st.busy = false;
        self.note_freed(k);
    }

    /// The cartridge returned to its shelf (trailing unmount done, legacy
    /// fixed-cost cycle done, or evict-unmount done); waiters, if any,
    /// become dispatchable.
    pub fn release_unthreaded(&mut self, k: &K) {
        self.threaded.remove(k).expect("releasing an unthreaded cartridge");
        self.note_freed(k);
    }

    fn note_freed(&mut self, k: &K) {
        if self.parked.get(k).map_or(false, |q| !q.is_empty()) {
            self.ready.push_back(k.clone());
        }
    }

    /// Next parked batch whose cartridge has freed, FIFO by free time. A
    /// stale entry — the cartridge was re-claimed since it freed (live
    /// path: an eviction can race the dispatcher) — is skipped; the next
    /// release re-queues it.
    pub fn pop_ready(&mut self) -> Option<(K, W)> {
        while let Some(k) = self.ready.pop_front() {
            if self.threaded.get(&k).map_or(false, |st| st.busy) {
                continue;
            }
            if let Some(q) = self.parked.get_mut(&k) {
                if let Some(w) = q.pop_front() {
                    if q.is_empty() {
                        self.parked.remove(&k);
                    }
                    return Some((k, w));
                }
            }
        }
        None
    }

    /// Re-arm the ready queue for `k`: a batch handed out by
    /// [`CartridgeLedger::pop_ready`] was dropped *without* acquiring the
    /// cartridge (e.g. shed because its tape was deregistered
    /// mid-flight), so if waiters remain and the cartridge is free they
    /// must become dispatchable again — otherwise they would wait for a
    /// release that is never coming.
    pub fn renote(&mut self, k: &K) {
        if self.threaded.get(k).map_or(true, |st| !st.busy) {
            self.note_freed(k);
        }
    }

    /// Where the cartridge is threaded, if anywhere: `(drive, busy)`.
    pub fn holder(&self, k: &K) -> Option<(usize, bool)> {
        self.threaded.get(k).map(|st| (st.drive, st.busy))
    }

    /// Batches currently parked across all cartridges.
    pub fn waiters(&self) -> usize {
        self.parked.values().map(|q| q.len()).sum()
    }

    /// No batch parked anywhere (the drain invariant).
    pub fn no_waiters(&self) -> bool {
        self.ready.is_empty() && self.parked.values().all(|q| q.is_empty())
    }
}

impl<K: Eq + Hash + Clone, W> Default for CartridgeLedger<K, W> {
    fn default() -> Self {
        CartridgeLedger::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_roundtrip_threaded_and_unthreaded() {
        let mut l: CartridgeLedger<&str, u32> = CartridgeLedger::new();
        assert!(l.available(&"A"));
        l.acquire(&"A", 0);
        assert!(!l.available(&"A"));
        assert_eq!(l.holder(&"A"), Some((0, true)));
        // LRU lazy unmount: idle but still threaded — and re-acquirable by
        // the same drive (a remount hit).
        l.release_threaded(&"A");
        assert!(l.available(&"A"));
        assert_eq!(l.holder(&"A"), Some((0, false)));
        l.acquire(&"A", 0);
        l.release_unthreaded(&"A");
        assert_eq!(l.holder(&"A"), None);
        assert!(l.no_waiters());
    }

    #[test]
    #[should_panic(expected = "cartridge exclusivity violated")]
    fn second_drive_cannot_take_a_busy_cartridge() {
        let mut l: CartridgeLedger<&str, u32> = CartridgeLedger::new();
        l.acquire(&"A", 0);
        l.acquire(&"A", 1);
    }

    #[test]
    #[should_panic(expected = "cartridge exclusivity violated")]
    fn another_drive_cannot_hit_an_idle_threaded_cartridge() {
        let mut l: CartridgeLedger<&str, u32> = CartridgeLedger::new();
        l.acquire(&"A", 0);
        l.release_threaded(&"A");
        l.acquire(&"A", 1);
    }

    #[test]
    fn waiters_queue_fifo_and_drain_one_per_release() {
        let mut l: CartridgeLedger<&str, u32> = CartridgeLedger::new();
        l.acquire(&"A", 0);
        l.park("A", 1);
        l.park("A", 2);
        assert!(!l.available(&"A"));
        assert_eq!(l.waiters(), 2);
        assert!(l.pop_ready().is_none(), "nothing freed yet");
        // One release hands back exactly the FIFO head.
        l.release_unthreaded(&"A");
        assert_eq!(l.pop_ready(), Some(("A", 1)));
        assert!(l.pop_ready().is_none(), "one release, one grant");
        // The granted batch re-acquires; the next release frees waiter 2.
        l.acquire(&"A", 1);
        assert!(!l.available(&"A"), "a parked batch still outranks newcomers");
        l.release_unthreaded(&"A");
        assert_eq!(l.pop_ready(), Some(("A", 2)));
        assert!(l.no_waiters());
        assert!(l.available(&"A"));
    }

    #[test]
    fn stale_ready_entries_are_skipped_and_requeued_by_the_next_release() {
        let mut l: CartridgeLedger<&str, u32> = CartridgeLedger::new();
        l.acquire(&"A", 0);
        l.park("A", 1);
        l.release_threaded(&"A"); // freed-with-waiters → ready
        // An eviction re-claims the cartridge before the waiter dispatches.
        l.begin_evict(&"A");
        assert!(l.pop_ready().is_none(), "stale entry must not hand out a busy cartridge");
        assert_eq!(l.waiters(), 1, "the waiter is still parked");
        // The evict-unmount completes: the waiter becomes dispatchable.
        l.release_unthreaded(&"A");
        assert_eq!(l.pop_ready(), Some(("A", 1)));
    }

    #[test]
    fn renote_rearms_waiters_after_a_dropped_grant() {
        let mut l: CartridgeLedger<&str, u32> = CartridgeLedger::new();
        l.acquire(&"A", 0);
        l.park("A", 1);
        l.park("A", 2);
        l.release_unthreaded(&"A");
        // The grant for waiter 1 is dropped (e.g. shed): without renote,
        // waiter 2 would wait forever.
        let (_, w) = l.pop_ready().unwrap();
        assert_eq!(w, 1);
        assert!(l.pop_ready().is_none());
        l.renote(&"A");
        assert_eq!(l.pop_ready(), Some(("A", 2)));
        // Renote on a busy cartridge is a no-op (the release will re-arm).
        l.acquire(&"A", 1);
        l.park("A", 3);
        l.renote(&"A");
        assert!(l.pop_ready().is_none(), "busy cartridge must not grant");
        l.release_unthreaded(&"A");
        assert_eq!(l.pop_ready(), Some(("A", 3)));
        assert!(l.no_waiters());
    }

    #[test]
    fn independent_cartridges_do_not_interact() {
        let mut l: CartridgeLedger<&str, u32> = CartridgeLedger::new();
        l.acquire(&"A", 0);
        assert!(l.available(&"B"));
        l.acquire(&"B", 1);
        l.park("A", 10);
        l.release_unthreaded(&"B");
        assert!(l.pop_ready().is_none(), "B freed with no waiters");
        l.release_unthreaded(&"A");
        assert_eq!(l.pop_ready(), Some(("A", 10)));
    }
}
