//! Robot-arm state machines: the event-driven FIFO pool (replay) and the
//! interval-reservation timeline (live coordinator, analytic library sim).
//!
//! Both model the same resource — a library's `n_arms` robot arms, each
//! able to carry out one mount or unmount at a time — under two driving
//! disciplines. `n_arms == 0` means an unconstrained robot in both: every
//! op starts immediately with zero wait, which is the legacy fixed
//! mount-cost model.

use std::collections::VecDeque;

/// One robot-arm operation that just started (or was granted from the
/// queue): the caller schedules its completion `dur_us` from now and
/// accounts `wait_us` of arm contention.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArmStart {
    /// The drive whose pipeline the op belongs to.
    pub drive: usize,
    /// Operation duration on the µs grid.
    pub dur_us: u64,
    /// Time the op spent queued behind busy arms (0 when it started
    /// immediately).
    pub wait_us: u64,
}

/// One queued robot-arm operation (FIFO behind the busy arms).
#[derive(Debug)]
struct QueuedArmOp {
    drive: usize,
    dur_us: u64,
    enqueued_us: u64,
}

/// The event-driven arm pool: at most `n_arms` ops run at once, the rest
/// queue FIFO. The caller drives it — [`ArmPool::request`] when an op
/// wants to start, [`ArmPool::op_done`] when a running op's completion
/// event fires — and schedules the completion events itself, so the pool
/// runs identically under virtual and wall time.
#[derive(Debug)]
pub struct ArmPool {
    n_arms: usize,
    busy: usize,
    queue: VecDeque<QueuedArmOp>,
}

impl ArmPool {
    /// A pool of `n_arms` arms (`0` = unconstrained robot).
    pub fn new(n_arms: usize) -> ArmPool {
        ArmPool { n_arms, busy: 0, queue: VecDeque::new() }
    }

    /// Start (or queue) one op for `drive`. Returns the started op — with
    /// zero wait — when an arm is free (always, for an unconstrained
    /// pool); returns `None` when the op queued behind busy arms, in which
    /// case a later [`ArmPool::op_done`] hands it back.
    pub fn request(&mut self, drive: usize, dur_us: u64, now_us: u64) -> Option<ArmStart> {
        if self.n_arms == 0 || self.busy < self.n_arms {
            if self.n_arms > 0 {
                self.busy += 1;
            }
            Some(ArmStart { drive, dur_us, wait_us: 0 })
        } else {
            self.queue.push_back(QueuedArmOp { drive, dur_us, enqueued_us: now_us });
            None
        }
    }

    /// One running op finished: free its arm and start the next queued op
    /// (FIFO), whose measured wait is `now - enqueue time`.
    pub fn op_done(&mut self, now_us: u64) -> Option<ArmStart> {
        if self.n_arms == 0 {
            return None;
        }
        self.busy -= 1;
        self.queue.pop_front().map(|op| {
            self.busy += 1;
            ArmStart {
                drive: op.drive,
                dur_us: op.dur_us,
                wait_us: now_us - op.enqueued_us,
            }
        })
    }

    /// No op running or queued (the drain invariant).
    pub fn idle(&self) -> bool {
        self.busy == 0 && self.queue.is_empty()
    }
}

/// One granted arm interval on the timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArmReservation {
    /// Arm index the interval landed on.
    pub arm: usize,
    /// When the op begins (≥ the requested `now_us`).
    pub start_us: u64,
    /// When the arm frees again (`start + dur`).
    pub end_us: u64,
    /// `start - now`: how long the caller must wait for the arm.
    pub wait_us: u64,
}

/// The interval-reservation view of the arm pool: each arm is a
/// monotonically advancing `free_at` edge, and an op reserves
/// `[start, start + dur)` on the earliest-free arm. The live coordinator's
/// workers sleep to `start` (the reservation edge) so arm contention shows
/// up in wall-clock latency; [`crate::sim::LibrarySim`] uses the same
/// arithmetic analytically. An empty timeline (`n_arms == 0`) is the
/// unconstrained robot: every reservation starts immediately.
#[derive(Debug, Clone)]
pub struct ArmTimeline {
    free_at_us: Vec<u64>,
}

impl ArmTimeline {
    /// A timeline over `n_arms` arms (`0` = unconstrained).
    pub fn new(n_arms: usize) -> ArmTimeline {
        ArmTimeline { free_at_us: vec![0; n_arms] }
    }

    /// Whether the robot is unconstrained (no arm ever waits).
    pub fn unconstrained(&self) -> bool {
        self.free_at_us.is_empty()
    }

    /// Reserve `dur_us` starting no earlier than `now_us` on the
    /// earliest-free arm (lowest index breaks ties).
    pub fn reserve(&mut self, now_us: u64, dur_us: u64) -> ArmReservation {
        if self.free_at_us.is_empty() {
            return ArmReservation {
                arm: 0,
                start_us: now_us,
                end_us: now_us + dur_us,
                wait_us: 0,
            };
        }
        let arm = (0..self.free_at_us.len())
            .min_by_key(|&i| self.free_at_us[i])
            .expect("non-empty timeline");
        let start_us = self.free_at_us[arm].max(now_us);
        self.free_at_us[arm] = start_us + dur_us;
        ArmReservation { arm, start_us, end_us: start_us + dur_us, wait_us: start_us - now_us }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unconstrained_pool_starts_everything_immediately() {
        let mut pool = ArmPool::new(0);
        for i in 0..10 {
            let op = pool.request(i, 1_000, i as u64).expect("no arm bound");
            assert_eq!(op.wait_us, 0);
            assert_eq!(op.drive, i);
        }
        assert!(pool.op_done(50).is_none(), "nothing queues without a bound");
        assert!(pool.idle());
    }

    #[test]
    fn bounded_pool_queues_fifo_and_measures_waits() {
        let mut pool = ArmPool::new(1);
        assert!(pool.request(0, 100, 0).is_some(), "first op starts");
        assert!(pool.request(1, 200, 10).is_none(), "second queues");
        assert!(pool.request(2, 300, 20).is_none(), "third queues");
        assert!(!pool.idle());
        // First completion grants the queue head with its measured wait.
        let next = pool.op_done(100).expect("queued op granted");
        assert_eq!((next.drive, next.dur_us, next.wait_us), (1, 200, 90));
        let next = pool.op_done(300).expect("queue drains in FIFO order");
        assert_eq!((next.drive, next.dur_us, next.wait_us), (2, 300, 280));
        assert!(pool.op_done(600).is_none());
        assert!(pool.idle());
    }

    #[test]
    fn timeline_reserves_on_the_earliest_free_arm() {
        let mut t = ArmTimeline::new(2);
        let a = t.reserve(0, 100);
        assert_eq!((a.arm, a.start_us, a.end_us, a.wait_us), (0, 0, 100, 0));
        let b = t.reserve(0, 100);
        assert_eq!((b.arm, b.start_us, b.wait_us), (1, 0, 0));
        // Both arms busy until 100: the third op waits on arm 0.
        let c = t.reserve(10, 50);
        assert_eq!((c.arm, c.start_us, c.wait_us), (0, 100, 90));
        // A late request after the arms freed starts immediately.
        let d = t.reserve(1_000, 50);
        assert_eq!((d.arm, d.start_us, d.wait_us), (1, 1_000, 0));
    }

    #[test]
    fn empty_timeline_is_unconstrained() {
        let mut t = ArmTimeline::new(0);
        assert!(t.unconstrained());
        let r = t.reserve(42, 100);
        assert_eq!((r.start_us, r.end_us, r.wait_us), (42, 142, 0));
    }
}
