//! Drive placement and the per-drive mount-pipeline state machine.
//!
//! Hosts the shared placement vocabulary ([`Affinity`], [`MountPlan`],
//! [`pick_drive_slot`]) and the [`DrivePool`] state machine both serving
//! paths step: the replay engine with catalog tape *indices* and
//! event-driven stage transitions, the live coordinator with tape *names*
//! and worker threads. The pool is generic over the tape key `K` and the
//! stage payload `P` (the replay engine parks its pending batch inside
//! [`DriveStage::Mounting`]; the live path carries no payload).

/// Drive-placement policy of a dispatcher: what happens to a tape after
/// its batch finishes, and which drive the next batch for it lands on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Affinity {
    /// Unmount after every batch; every dispatch pays a fresh mount (the
    /// paper's fixed mount-cost model).
    #[default]
    None,
    /// Keep the tape in the drive after its batch (lazy unmount). The
    /// dispatcher prefers an idle drive already holding the batch's tape —
    /// a *remount hit* skips the mount entirely — and evicts the
    /// least-recently-used loaded drive when no empty drive is free.
    Lru,
}

impl Affinity {
    /// Parse a CLI name (`"none"` / `"lru"`, case-insensitive).
    pub fn from_name(s: &str) -> Option<Affinity> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Some(Affinity::None),
            "lru" => Some(Affinity::Lru),
            _ => None,
        }
    }

    /// Stable lowercase name (reports, CLI round-trip).
    pub fn name(self) -> &'static str {
        match self {
            Affinity::None => "none",
            Affinity::Lru => "lru",
        }
    }
}

/// How a dispatched batch lands on its chosen drive: the mount work the
/// robot pipeline must perform before the head can execute the schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MountPlan {
    /// The drive already holds the tape: no robot work at all.
    Hit,
    /// Empty drive: one mount through an arm.
    Mount,
    /// A loaded drive is evicted: unmount, then mount, both through arms.
    EvictMount,
}

/// The **single home** of the drive-placement preference, shared by the
/// live coordinator's dispatcher and the replay engine so their remount
/// economics can never drift apart: among free drives, pick the first one
/// already holding the batch's tape (remount hit, LRU affinity only),
/// else the lowest-index empty one, else the least-recently-used loaded
/// one (eviction; index breaks `last_used` ties). `drives` yields one
/// `(free, holds_tape, empty, last_used)` view per drive, in drive-index
/// order. Returns `None` when every drive is busy.
pub fn pick_drive_slot(
    affinity: Affinity,
    drives: impl IntoIterator<Item = (bool, bool, bool, u64)>,
) -> Option<(usize, MountPlan)> {
    let mut first_empty: Option<usize> = None;
    let mut lru: Option<(u64, usize)> = None;
    for (i, (free, holds_tape, empty, last_used)) in drives.into_iter().enumerate() {
        if !free {
            continue;
        }
        if affinity == Affinity::Lru && holds_tape {
            return Some((i, MountPlan::Hit));
        }
        if empty {
            if first_empty.is_none() {
                first_empty = Some(i);
            }
        } else if lru.map_or(true, |(t, _)| last_used < t) {
            lru = Some((last_used, i));
        }
    }
    if let Some(i) = first_empty {
        return Some((i, MountPlan::Mount));
    }
    lru.map(|(_, i)| (i, MountPlan::EvictMount))
}

/// The mount-pipeline stage of one drive. The live coordinator only uses
/// `Idle`/`Executing` (its mount work is charged, not event-stepped); the
/// replay engine walks the full pipeline, parking the batch awaiting robot
/// work in `Mounting`'s payload.
#[derive(Debug)]
pub enum DriveStage<P> {
    Idle,
    /// Waiting on arm ops before execution; `unmount_first` marks that the
    /// evict-unmount has not finished yet (a mount op follows it).
    Mounting { pending: P, unmount_first: bool },
    /// The head is executing the schedule.
    Executing,
    /// Trailing unmount through the arm pool ([`Affinity::None`] only).
    Unloading,
}

/// One drive's placement + pipeline state.
#[derive(Debug)]
pub struct Drive<K, P> {
    /// Tape currently threaded (survives between batches under LRU
    /// affinity — the lazy unmount).
    pub loaded: Option<K>,
    pub stage: DriveStage<P>,
    /// Dispatch tick of the drive's last batch (LRU eviction order).
    pub last_used: u64,
    /// Time the current busy cycle began, on the caller's µs grid.
    pub cycle_start_us: u64,
}

/// A library's drive pool: the stage machine per drive plus the free-drive
/// gate dispatchers check before popping work.
#[derive(Debug)]
pub struct DrivePool<K, P> {
    drives: Vec<Drive<K, P>>,
    n_free: usize,
}

impl<K: PartialEq + Clone, P> DrivePool<K, P> {
    /// `n` idle, empty drives.
    pub fn new(n: usize) -> DrivePool<K, P> {
        DrivePool {
            drives: (0..n)
                .map(|_| Drive {
                    loaded: None,
                    stage: DriveStage::Idle,
                    last_used: 0,
                    cycle_start_us: 0,
                })
                .collect(),
            n_free: n,
        }
    }

    pub fn n_drives(&self) -> usize {
        self.drives.len()
    }

    /// Count of drives in [`DriveStage::Idle`] (the dispatch gate).
    pub fn n_free(&self) -> usize {
        self.n_free
    }

    pub fn drive(&self, i: usize) -> &Drive<K, P> {
        &self.drives[i]
    }

    pub fn drive_mut(&mut self, i: usize) -> &mut Drive<K, P> {
        &mut self.drives[i]
    }

    /// Choose the drive a batch for `tape` lands on, through the one
    /// shared preference ([`pick_drive_slot`]): hit, then empty, then LRU
    /// eviction — deterministic lowest-index ties.
    pub fn pick(&self, affinity: Affinity, tape: &K) -> Option<(usize, MountPlan)> {
        pick_drive_slot(
            affinity,
            self.drives.iter().map(|d| {
                (
                    matches!(d.stage, DriveStage::Idle),
                    d.loaded.as_ref() == Some(tape),
                    d.loaded.is_none(),
                    d.last_used,
                )
            }),
        )
    }

    /// Claim drive `i` for a new busy cycle: stamp its LRU tick and cycle
    /// start, set what it holds, and take it out of the free pool. The
    /// stage stays whatever the caller sets next (the claim itself leaves
    /// it `Idle`-shaped so both the legacy one-event path and the staged
    /// pipeline can follow).
    pub fn begin_cycle(&mut self, i: usize, loaded: Option<K>, tick: u64, now_us: u64) {
        let d = &mut self.drives[i];
        debug_assert!(
            matches!(d.stage, DriveStage::Idle),
            "dispatching onto a busy drive"
        );
        d.last_used = tick;
        d.cycle_start_us = now_us;
        d.loaded = loaded;
        self.n_free -= 1;
    }

    pub fn set_stage(&mut self, i: usize, stage: DriveStage<P>) {
        self.drives[i].stage = stage;
    }

    /// Take the drive's stage out (leaving `Idle`) — the event-handler
    /// pattern the replay engine steps transitions with.
    pub fn take_stage(&mut self, i: usize) -> DriveStage<P> {
        std::mem::replace(&mut self.drives[i].stage, DriveStage::Idle)
    }

    /// End the drive's busy cycle: back to `Idle` and the free pool.
    /// `loaded` is untouched (LRU lazy unmount); callers clear it when the
    /// cartridge actually returned to its shelf.
    pub fn release(&mut self, i: usize) {
        self.drives[i].stage = DriveStage::Idle;
        self.n_free += 1;
    }

    /// The cartridge-exclusivity invariant over the pool: `tape` may be
    /// loaded in `drive` and nowhere else. Panics on a violation.
    pub fn assert_exclusive(&self, tape: &K, drive: usize) {
        for (i, d) in self.drives.iter().enumerate() {
            assert!(
                i == drive || d.loaded.as_ref() != Some(tape),
                "cartridge exclusivity violated: tape threaded in drives {i} and {drive}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_drive_slot_preference_order() {
        use MountPlan::*;
        // Views: (free, holds_tape, empty, last_used), in drive order.
        let drives = [
            (true, false, true, 5),  // 0: free empty
            (true, true, false, 1),  // 1: free, holds the batch's tape
            (false, true, false, 0), // 2: busy with the tape — ineligible
            (true, false, false, 3), // 3: free, loaded with another tape
        ];
        // LRU affinity: the loaded idle drive wins even though an empty
        // drive has a lower index.
        assert_eq!(pick_drive_slot(Affinity::Lru, drives), Some((1, Hit)));
        // No affinity: holds_tape is ignored, the first empty drive wins.
        assert_eq!(pick_drive_slot(Affinity::None, drives), Some((0, Mount)));
        // No empty drive: LRU eviction by (last_used, index).
        let loaded = [
            (true, false, false, 7),
            (false, false, false, 1),
            (true, false, false, 3),
            (true, false, false, 3),
        ];
        assert_eq!(pick_drive_slot(Affinity::Lru, loaded), Some((2, EvictMount)));
        // Every drive busy: nothing to pick.
        assert_eq!(pick_drive_slot(Affinity::Lru, [(false, true, false, 0)]), None);
    }

    #[test]
    fn pool_tracks_cycles_and_the_free_gate() {
        let mut pool: DrivePool<usize, ()> = DrivePool::new(2);
        assert_eq!(pool.n_drives(), 2);
        assert_eq!(pool.n_free(), 2);
        assert_eq!(pool.pick(Affinity::Lru, &7), Some((0, MountPlan::Mount)));
        pool.begin_cycle(0, Some(7), 1, 100);
        pool.set_stage(0, DriveStage::Executing);
        assert_eq!(pool.n_free(), 1);
        // The loaded busy drive is invisible to pick; the empty one wins.
        assert_eq!(pool.pick(Affinity::Lru, &7), Some((1, MountPlan::Mount)));
        pool.release(0);
        assert_eq!(pool.n_free(), 2);
        // After release the tape stays threaded: a remount hit under LRU.
        assert_eq!(pool.pick(Affinity::Lru, &7), Some((0, MountPlan::Hit)));
        assert_eq!(pool.drive(0).last_used, 1);
        assert_eq!(pool.drive(0).cycle_start_us, 100);
        pool.assert_exclusive(&7, 0);
    }

    #[test]
    #[should_panic(expected = "cartridge exclusivity violated")]
    fn duplicate_threading_is_caught() {
        let mut pool: DrivePool<usize, ()> = DrivePool::new(2);
        pool.begin_cycle(0, Some(3), 1, 0);
        pool.begin_cycle(1, Some(3), 2, 0);
        pool.assert_exclusive(&3, 1);
    }

    #[test]
    fn take_stage_leaves_idle() {
        let mut pool: DrivePool<usize, u32> = DrivePool::new(1);
        pool.begin_cycle(0, None, 1, 0);
        pool.set_stage(0, DriveStage::Mounting { pending: 9, unmount_first: false });
        match pool.take_stage(0) {
            DriveStage::Mounting { pending, unmount_first } => {
                assert_eq!(pending, 9);
                assert!(!unmount_first);
            }
            other => panic!("unexpected stage {other:?}"),
        }
        assert!(matches!(pool.drive(0).stage, DriveStage::Idle));
    }
}
