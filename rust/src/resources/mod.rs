//! The shared physical-resource layer: tape cartridges, drives, robot arms.
//!
//! One library's physical state used to live in two divergent encodings —
//! the replay engine's event-driven `ArmPool`/`DriveSim` state machines and
//! the live coordinator's ad-hoc drive-slot table. This module is the
//! **single source of truth** both serving paths now share:
//!
//! ```text
//!            CartridgeLedger          DrivePool            ArmPool /
//!            (one cartridge,          (stage machine       ArmTimeline
//!             one drive)               per drive)          (robot arms)
//!                  ▲                      ▲                    ▲
//!        ┌─────────┴──────────┬───────────┴───────────┬────────┴───────┐
//!        │ replay::engine     │ coordinator::service  │ sim::library   │
//!        │ (VirtualClock µs)  │ (wall-clock Instants) │ (analytic)     │
//!        └────────────────────┴───────────────────────┴────────────────┘
//! ```
//!
//! **Time parameterization.** Every state machine here is *passive*: it
//! never reads a clock. Callers pass the current time on the µs grid
//! ([`crate::util::secs_to_us`]) — the replay engine passes its
//! [`crate::replay::VirtualClock`] reading, the live coordinator passes
//! `Instant`-anchored wall microseconds — so the identical transition
//! logic runs under virtual and wall time. Waiting is likewise the
//! caller's job: the replay engine schedules events at the returned
//! timestamps, the live coordinator parks batches / sleeps workers to the
//! returned reservation edges.
//!
//! **Cartridge exclusivity.** A physical cartridge can be threaded in at
//! most one drive at a time; [`CartridgeLedger`] enforces it. A batch
//! whose tape is in use elsewhere queues on a per-cartridge FIFO waitlist
//! and is handed back (`pop_ready`) once the cartridge frees — the time it
//! spends parked is the `cartridge_wait` QoS component surfaced fleet-wide
//! and per shard.
//!
//! **Robot arms, two views.** [`ArmPool`] is the exact event-driven FIFO
//! pool (mounts/unmounts occupy an arm, excess ops queue) the replay
//! engine steps; [`ArmTimeline`] is the interval-reservation view of the
//! same resource — each op reserves `[start, start+dur)` on the earliest
//! free arm — used by the live coordinator (workers sleep to the
//! reservation edge and charge the wait) and by the analytic
//! [`crate::sim::LibrarySim`] model.

pub mod arm;
pub mod cartridge;
pub mod drive;

pub use arm::{ArmPool, ArmReservation, ArmStart, ArmTimeline};
pub use cartridge::CartridgeLedger;
pub use drive::{pick_drive_slot, Affinity, Drive, DrivePool, DriveStage, MountPlan};
