//! QoS reports: the user-facing distillate of a replay.
//!
//! One [`QosReport`] per `(policy, arrival model)` replay: counters,
//! throughput, utilization, and the latency/service percentile ladder
//! (p50/p95/p99/p99.9) the paper's serving scenario cares about. Reports
//! serialize to JSON by hand (stable key order, fixed float precision, no
//! serde) so two replays with the same seed and configuration emit
//! **byte-identical** documents — the acceptance contract of the replay
//! subsystem. Wall-clock measurements (scheduler compute) deliberately
//! never enter the JSON; they go to stderr diagnostics instead.

use super::engine::{LoopMode, ReplayConfig, ReplayOutcome, ShardOutcome};
use super::histogram::LatencyHistogram;

/// Percentile ladder of one distribution, seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyStats {
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub p999_s: f64,
    pub max_s: f64,
}

impl LatencyStats {
    pub fn from_histogram(h: &LatencyHistogram) -> LatencyStats {
        LatencyStats {
            mean_s: h.mean_s(),
            p50_s: h.quantile(50.0),
            p95_s: h.quantile(95.0),
            p99_s: h.quantile(99.0),
            p999_s: h.quantile(99.9),
            max_s: h.max_s(),
        }
    }

    fn json(&self) -> String {
        format!(
            "{{\"mean_s\":{:.6},\"p50_s\":{:.6},\"p95_s\":{:.6},\"p99_s\":{:.6},\"p999_s\":{:.6},\"max_s\":{:.6}}}",
            self.mean_s, self.p50_s, self.p95_s, self.p99_s, self.p999_s, self.max_s
        )
    }
}

/// One shard's QoS breakdown inside a [`QosReport`]: the same counters
/// and percentile ladders, restricted to the requests that shard served.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardQos {
    pub shard: usize,
    /// Catalog tapes the ring routed to this shard.
    pub tapes: usize,
    /// Fraction of the ring's key space this shard owns.
    pub ring_share: f64,
    pub submitted: u64,
    pub completed: u64,
    pub shed: u64,
    pub busy_rejections: u64,
    pub retries: u64,
    pub batches: u64,
    /// Batches served without a mount (drive affinity; pipeline only).
    pub remount_hits: u64,
    /// Batches that paid a mount (pipeline only).
    pub remount_misses: u64,
    /// Virtual time of this shard's last completion, seconds.
    pub makespan_s: f64,
    /// Mean fraction of this shard's drive pool busy over its makespan.
    pub drive_utilization: f64,
    pub latency: LatencyStats,
    pub service: LatencyStats,
    /// Robot-arm wait ladder, per arm op (pipeline only).
    pub arm_wait: LatencyStats,
    /// Mount-pipeline latency ladder, per batch (pipeline only).
    pub mount_wait: LatencyStats,
    /// Free-drive wait ladder, per batch (pipeline only).
    pub drive_wait: LatencyStats,
    /// Batches parked on this shard's cartridge waitlists (exclusive
    /// tapes only).
    pub cartridge_parks: u64,
    /// Cartridge-wait ladder, per batch (exclusive tapes only).
    pub cartridge_wait: LatencyStats,
    /// Whether the mount pipeline was active — gates the extra keys so a
    /// legacy report's bytes never change.
    pipeline: bool,
    /// Whether per-tape mount exclusivity was enforced — gates the
    /// cartridge keys the same way.
    exclusive: bool,
}

impl ShardQos {
    fn from_outcome(s: &ShardOutcome, n_drives: usize, pipeline: bool, exclusive: bool) -> ShardQos {
        let st = &s.stats;
        ShardQos {
            shard: s.shard,
            tapes: s.n_tapes,
            ring_share: s.ring_share,
            submitted: st.submitted,
            completed: st.completed,
            shed: st.shed,
            busy_rejections: st.busy_rejections,
            retries: st.retries,
            batches: st.batches,
            remount_hits: st.remount_hits,
            remount_misses: st.remount_misses,
            makespan_s: st.makespan_us as f64 / 1e6,
            drive_utilization: if st.makespan_us > 0 {
                (st.busy_drive_us as f64 / (n_drives as f64 * st.makespan_us as f64))
                    .min(1.0)
            } else {
                0.0
            },
            latency: LatencyStats::from_histogram(&s.latency),
            service: LatencyStats::from_histogram(&s.service),
            arm_wait: LatencyStats::from_histogram(&s.arm_wait),
            mount_wait: LatencyStats::from_histogram(&s.mount_wait),
            drive_wait: LatencyStats::from_histogram(&s.drive_wait),
            cartridge_parks: st.cartridge_parks,
            cartridge_wait: LatencyStats::from_histogram(&s.cartridge_wait),
            pipeline,
            exclusive,
        }
    }

    fn json(&self) -> String {
        let mut out = format!(
            "{{\"shard\":{},\"tapes\":{},\"ring_share\":{:.6},\"submitted\":{},\
             \"completed\":{},\"shed\":{},\"busy_rejections\":{},\"retries\":{},\
             \"batches\":{},\"makespan_s\":{:.6},\"drive_utilization\":{:.6},\
             \"latency\":{},\"service\":{}",
            self.shard,
            self.tapes,
            self.ring_share,
            self.submitted,
            self.completed,
            self.shed,
            self.busy_rejections,
            self.retries,
            self.batches,
            self.makespan_s,
            self.drive_utilization,
            self.latency.json(),
            self.service.json(),
        );
        if self.pipeline {
            out.push_str(&format!(
                ",\"remount_hits\":{},\"remount_misses\":{},\"arm_wait\":{},\
                 \"mount_wait\":{},\"drive_wait\":{}",
                self.remount_hits,
                self.remount_misses,
                self.arm_wait.json(),
                self.mount_wait.json(),
                self.drive_wait.json(),
            ));
        }
        if self.exclusive {
            out.push_str(&format!(
                ",\"cartridge_parks\":{},\"cartridge_wait\":{}",
                self.cartridge_parks,
                self.cartridge_wait.json(),
            ));
        }
        out.push('}');
        out
    }
}

/// The quality-of-service report of one replay.
#[derive(Debug, Clone, PartialEq)]
pub struct QosReport {
    pub policy: String,
    pub arrivals: String,
    pub seed: u64,
    /// `"open"` or `"closed(cap)"`.
    pub mode: String,
    /// Drive pool size **per shard**.
    pub n_drives: usize,
    /// Number of library shards behind the consistent-hash router.
    pub n_shards: usize,
    /// Virtual nodes per shard on the ring.
    pub vnodes: usize,
    /// Robot arms per shard (0 = unconstrained legacy robot).
    pub arms: usize,
    /// Drive-placement policy name (`"none"` / `"lru"`).
    pub affinity: String,
    /// Whether the mount pipeline was modeled. Gates every pipeline key in
    /// the JSON, so a legacy replay's report stays byte-identical to the
    /// pre-pipeline format.
    pub pipeline: bool,
    /// Whether per-tape mount exclusivity was enforced. Gates the
    /// cartridge keys the same way: `--exclusive-tapes off` emits the
    /// exact pre-exclusivity document.
    pub exclusive: bool,
    /// Configured arrival horizon, seconds.
    pub duration_s: f64,
    pub submitted: u64,
    pub completed: u64,
    pub shed: u64,
    pub busy_rejections: u64,
    pub retries: u64,
    pub batches: u64,
    /// Virtual time of the last completion, seconds.
    pub makespan_s: f64,
    /// Completions per virtual second over the makespan.
    pub throughput_rps: f64,
    pub mean_batch_size: f64,
    /// Mean fraction of the fleet's drive pool busy over the makespan.
    pub drive_utilization: f64,
    /// End-to-end latency (queueing + mount + in-tape), fleet-wide.
    pub latency: LatencyStats,
    /// Mount + in-tape service time (the paper's objective, shifted).
    pub service: LatencyStats,
    /// Batches served without a mount, fleet-wide (pipeline only).
    pub remount_hits: u64,
    /// Batches that paid a mount, fleet-wide (pipeline only).
    pub remount_misses: u64,
    /// Robot-arm wait ladder, per arm op (pipeline only).
    pub arm_wait: LatencyStats,
    /// Mount-pipeline latency ladder, per batch (pipeline only).
    pub mount_wait: LatencyStats,
    /// Free-drive wait ladder, per batch (pipeline only).
    pub drive_wait: LatencyStats,
    /// Batches parked on a cartridge waitlist fleet-wide (exclusive
    /// tapes only).
    pub cartridge_parks: u64,
    /// Cartridge-wait ladder, per batch (exclusive tapes only).
    pub cartridge_wait: LatencyStats,
    /// Per-shard breakdown (one entry per shard, ascending).
    pub shards: Vec<ShardQos>,
}

impl QosReport {
    pub fn new(
        policy: &str,
        arrivals: &str,
        seed: u64,
        duration_s: f64,
        cfg: &ReplayConfig,
        outcome: &ReplayOutcome,
    ) -> QosReport {
        let s = &outcome.stats;
        let makespan_s = s.makespan_us as f64 / 1e6;
        let fleet_drives = cfg.n_shards * cfg.n_drives;
        let pipeline = cfg.pipeline_active();
        let exclusive = cfg.exclusive_tapes;
        QosReport {
            policy: policy.to_string(),
            arrivals: arrivals.to_string(),
            seed,
            mode: match cfg.mode {
                LoopMode::Open => "open".to_string(),
                LoopMode::Closed { max_in_flight } => format!("closed({max_in_flight})"),
            },
            n_drives: cfg.n_drives,
            n_shards: cfg.n_shards,
            vnodes: cfg.vnodes,
            arms: cfg.drive.n_arms,
            affinity: cfg.affinity.name().to_string(),
            pipeline,
            exclusive,
            duration_s,
            submitted: s.submitted,
            completed: s.completed,
            shed: s.shed,
            busy_rejections: s.busy_rejections,
            retries: s.retries,
            batches: s.batches,
            makespan_s,
            throughput_rps: if makespan_s > 0.0 {
                s.completed as f64 / makespan_s
            } else {
                0.0
            },
            mean_batch_size: s.completed as f64 / s.batches.max(1) as f64,
            drive_utilization: if s.makespan_us > 0 {
                (s.busy_drive_us as f64 / (fleet_drives as f64 * s.makespan_us as f64))
                    .min(1.0)
            } else {
                0.0
            },
            latency: LatencyStats::from_histogram(&outcome.latency),
            service: LatencyStats::from_histogram(&outcome.service),
            remount_hits: s.remount_hits,
            remount_misses: s.remount_misses,
            arm_wait: LatencyStats::from_histogram(&outcome.arm_wait),
            mount_wait: LatencyStats::from_histogram(&outcome.mount_wait),
            drive_wait: LatencyStats::from_histogram(&outcome.drive_wait),
            cartridge_parks: s.cartridge_parks,
            cartridge_wait: LatencyStats::from_histogram(&outcome.cartridge_wait),
            shards: outcome
                .per_shard
                .iter()
                .map(|sh| ShardQos::from_outcome(sh, cfg.n_drives, pipeline, exclusive))
                .collect(),
        }
    }

    /// Deterministic single-object JSON (stable key order, `%.6f` floats).
    /// The fleet-wide `latency`/`service` objects are rendered exactly as
    /// in the single-library report — sharding adds keys, it never
    /// perturbs the fleet percentile bytes. Likewise the mount pipeline:
    /// its keys (`arms`, `affinity`, `remount_*`, `arm_wait`,
    /// `mount_wait`, `drive_wait`) appear **only** when the pipeline was
    /// active, and the cartridge-exclusivity keys (`exclusive_tapes`,
    /// `cartridge_parks`, `cartridge_wait`) only when exclusivity was on,
    /// so an `--exclusive-tapes off --arms 0 --affinity none` replay
    /// emits the exact pre-pipeline document (regression-gated in ci.sh).
    pub fn to_json(&self) -> String {
        let mut out = format!(
            "{{\"policy\":\"{}\",\"arrivals\":\"{}\",\"seed\":{},\"mode\":\"{}\",\
             \"drives\":{},\"shards\":{},\"vnodes\":{},\"duration_s\":{:.6},\
             \"submitted\":{},\"completed\":{},\
             \"shed\":{},\"busy_rejections\":{},\"retries\":{},\"batches\":{},\
             \"makespan_s\":{:.6},\"throughput_rps\":{:.6},\"mean_batch_size\":{:.6},\
             \"drive_utilization\":{:.6},\"latency\":{},\"service\":{}",
            esc(&self.policy),
            esc(&self.arrivals),
            self.seed,
            esc(&self.mode),
            self.n_drives,
            self.n_shards,
            self.vnodes,
            self.duration_s,
            self.submitted,
            self.completed,
            self.shed,
            self.busy_rejections,
            self.retries,
            self.batches,
            self.makespan_s,
            self.throughput_rps,
            self.mean_batch_size,
            self.drive_utilization,
            self.latency.json(),
            self.service.json(),
        );
        if self.pipeline {
            out.push_str(&format!(
                ",\"arms\":{},\"affinity\":\"{}\",\"remount_hits\":{},\
                 \"remount_misses\":{},\"arm_wait\":{},\"mount_wait\":{},\
                 \"drive_wait\":{}",
                self.arms,
                esc(&self.affinity),
                self.remount_hits,
                self.remount_misses,
                self.arm_wait.json(),
                self.mount_wait.json(),
                self.drive_wait.json(),
            ));
        }
        if self.exclusive {
            out.push_str(&format!(
                ",\"exclusive_tapes\":true,\"cartridge_parks\":{},\"cartridge_wait\":{}",
                self.cartridge_parks,
                self.cartridge_wait.json(),
            ));
        }
        out.push_str(",\"per_shard\":[");
        for (i, s) in self.shards.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&s.json());
        }
        out.push_str("]}");
        out
    }
}

/// The multi-policy document the `replay` CLI emits: one report per policy,
/// one line each, wrapped in `{"reports": [...]}`.
pub fn reports_json(reports: &[QosReport]) -> String {
    let mut out = String::from("{\"reports\":[\n");
    for (i, r) in reports.iter().enumerate() {
        out.push_str("  ");
        out.push_str(&r.to_json());
        if i + 1 < reports.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("]}\n");
    out
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Tape;
    use crate::replay::arrivals::{PoissonArrivals, RequestMix};
    use crate::replay::engine::simulate;
    use crate::sched::Gs;
    use crate::sim::{Affinity, DriveParams};

    fn sample_report(seed: u64) -> QosReport {
        let catalog = vec![
            Tape::from_sizes("T0", &[1_000; 40]),
            Tape::from_sizes("T1", &[500; 80]),
        ];
        let cfg = ReplayConfig::default();
        let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 30.0, 8.0, seed);
        let outcome = simulate(&cfg, &catalog, &Gs, &mut model);
        QosReport::new("GS", &model.name(), seed, 8.0, &cfg, &outcome)
    }

    fn sharded_report(seed: u64, n_shards: usize) -> QosReport {
        let catalog: Vec<Tape> =
            (0..16).map(|i| Tape::from_sizes(format!("T{i:02}"), &[1_000; 40])).collect();
        let cfg = ReplayConfig { n_shards, vnodes: 64, ..ReplayConfig::default() };
        let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 30.0, 8.0, seed);
        let outcome = simulate(&cfg, &catalog, &Gs, &mut model);
        QosReport::new("GS", &model.name(), seed, 8.0, &cfg, &outcome)
    }

    #[test]
    fn report_fields_are_consistent() {
        let r = sample_report(5);
        assert!(r.completed > 0);
        assert_eq!(r.completed, r.submitted);
        assert!(r.makespan_s > 0.0);
        assert!(r.throughput_rps > 0.0);
        assert!(r.mean_batch_size >= 1.0);
        assert!(r.drive_utilization > 0.0 && r.drive_utilization <= 1.0);
        // The percentile ladder is monotone and capped by the max.
        let l = &r.latency;
        assert!(l.p50_s <= l.p95_s && l.p95_s <= l.p99_s && l.p99_s <= l.p999_s);
        assert!(l.p999_s <= l.max_s + 1e-9);
        assert!(l.mean_s > 0.0);
        // Latency dominates service (it includes queueing).
        assert!(r.latency.mean_s >= r.service.mean_s - 1e-9);
    }

    #[test]
    fn json_is_deterministic_and_structured() {
        let a = sample_report(7);
        let b = sample_report(7);
        assert_eq!(a.to_json(), b.to_json(), "same seed ⇒ byte-identical JSON");
        let doc = reports_json(&[a.clone(), b]);
        for key in [
            "\"policy\":\"GS\"",
            "\"arrivals\":\"poisson(rate=30)\"",
            "\"p50_s\":",
            "\"p95_s\":",
            "\"p99_s\":",
            "\"p999_s\":",
            "\"throughput_rps\":",
            "\"reports\":[",
        ] {
            assert!(doc.contains(key), "missing {key} in {doc}");
        }
        // Balanced braces/brackets ⇒ structurally sound.
        assert_eq!(doc.matches('{').count(), doc.matches('}').count());
        assert_eq!(doc.matches('[').count(), doc.matches(']').count());
        assert_ne!(sample_report(8).to_json(), sample_report(9).to_json());
    }

    #[test]
    fn json_escaping() {
        assert_eq!(esc("plain"), "plain");
        assert_eq!(esc("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(esc("x\ny"), "x\\u000ay");
    }

    #[test]
    fn single_shard_report_keeps_the_fleet_percentile_bytes() {
        // The acceptance contract of the sharding refactor: with one
        // shard, the fleet `latency`/`service` JSON objects are rendered
        // byte-for-byte from the same histograms the single-library
        // engine produced.
        let r = sample_report(7);
        assert_eq!(r.n_shards, 1);
        assert_eq!(r.shards.len(), 1);
        let s = &r.shards[0];
        assert_eq!(s.completed, r.completed);
        assert_eq!(s.latency, r.latency, "one shard IS the fleet");
        assert_eq!(s.latency.json(), r.latency.json());
        let doc = r.to_json();
        assert!(doc.contains("\"shards\":1"));
        assert!(doc.contains("\"per_shard\":[{\"shard\":0,"));
    }

    fn pipeline_report(seed: u64) -> QosReport {
        // One tape: every batch after the first few mounts lands on a
        // drive already holding it, so remount hits are structural, not a
        // seed accident.
        let catalog = vec![Tape::from_sizes("T0", &[1_000; 40])];
        let cfg = ReplayConfig {
            drive: DriveParams { n_arms: 1, ..DriveParams::default() },
            affinity: Affinity::Lru,
            ..ReplayConfig::default()
        };
        let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 5.0, 8.0, seed);
        let outcome = simulate(&cfg, &catalog, &Gs, &mut model);
        QosReport::new("GS", &model.name(), seed, 8.0, &cfg, &outcome)
    }

    fn legacy_report(seed: u64) -> QosReport {
        // `--exclusive-tapes off --arms 0 --affinity none`: the exact
        // pre-pipeline, pre-exclusivity document.
        let catalog = vec![
            Tape::from_sizes("T0", &[1_000; 40]),
            Tape::from_sizes("T1", &[500; 80]),
        ];
        let cfg = ReplayConfig { exclusive_tapes: false, ..ReplayConfig::default() };
        let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 30.0, 8.0, seed);
        let outcome = simulate(&cfg, &catalog, &Gs, &mut model);
        QosReport::new("GS", &model.name(), seed, 8.0, &cfg, &outcome)
    }

    #[test]
    fn legacy_json_never_grows_pipeline_keys() {
        // The byte-compatibility contract: a replay with no arms, no
        // affinity, and exclusivity off emits the exact pre-pipeline
        // document — none of the mount-pipeline or cartridge keys may
        // appear, at the fleet or shard level.
        let doc = legacy_report(7).to_json();
        for key in [
            "\"arms\":",
            "\"affinity\":",
            "\"remount_hits\":",
            "\"remount_misses\":",
            "\"arm_wait\":",
            "\"mount_wait\":",
            "\"drive_wait\":",
            "\"exclusive_tapes\":",
            "\"cartridge_parks\":",
            "\"cartridge_wait\":",
        ] {
            assert!(!doc.contains(key), "legacy report leaked {key}: {doc}");
        }
        // And the legacy key order is intact around the splice point.
        assert!(doc.contains("},\"per_shard\":[{\"shard\":0,"));
    }

    #[test]
    fn exclusive_json_carries_the_cartridge_sections() {
        // The default configuration enforces exclusivity: the cartridge
        // keys appear fleet-wide and per shard, deterministically, while
        // the pipeline keys stay gated on the pipeline itself.
        let a = sample_report(7);
        let b = sample_report(7);
        assert_eq!(a.to_json(), b.to_json(), "exclusive JSON stays byte-identical");
        assert!(a.exclusive && !a.pipeline);
        let doc = a.to_json();
        for key in [
            "\"exclusive_tapes\":true",
            "\"cartridge_parks\":",
            "\"cartridge_wait\":{\"mean_s\":",
        ] {
            assert!(doc.contains(key), "missing {key} in {doc}");
        }
        assert!(!doc.contains("\"arm_wait\":"), "no pipeline, no pipeline keys");
        let shard_part = doc.split("\"per_shard\":[").nth(1).unwrap();
        assert!(shard_part.contains("\"cartridge_parks\":"));
        assert!(shard_part.contains("\"cartridge_wait\":"));
        assert_eq!(doc.matches('{').count(), doc.matches('}').count());
        assert_eq!(doc.matches('[').count(), doc.matches(']').count());
    }

    #[test]
    fn pipeline_json_carries_the_mount_sections() {
        let a = pipeline_report(7);
        let b = pipeline_report(7);
        assert_eq!(a.to_json(), b.to_json(), "pipeline JSON stays byte-identical");
        assert!(a.pipeline);
        let doc = a.to_json();
        for key in [
            "\"arms\":1",
            "\"affinity\":\"lru\"",
            "\"remount_hits\":",
            "\"remount_misses\":",
            "\"arm_wait\":{\"mean_s\":",
            "\"mount_wait\":{\"mean_s\":",
            "\"drive_wait\":{\"mean_s\":",
        ] {
            assert!(doc.contains(key), "missing {key} in {doc}");
        }
        // The shard sections carry the same breakdown.
        let shard_part = doc.split("\"per_shard\":[").nth(1).unwrap();
        assert!(shard_part.contains("\"remount_hits\":"));
        assert!(shard_part.contains("\"arm_wait\":"));
        assert_eq!(doc.matches('{').count(), doc.matches('}').count());
        assert_eq!(doc.matches('[').count(), doc.matches(']').count());
        // Counters reconcile: hits + misses = batches.
        assert_eq!(a.remount_hits + a.remount_misses, a.batches);
        assert!(a.remount_hits > 0, "one tape over four drives must re-hit");
        assert!(a.remount_misses <= 4, "at most one mount per (empty) drive");
    }

    #[test]
    fn sharded_report_breaks_down_per_shard() {
        let a = sharded_report(3, 4);
        let b = sharded_report(3, 4);
        assert_eq!(a.to_json(), b.to_json(), "sharded JSON stays byte-identical");
        assert_eq!(a.shards.len(), 4);
        assert_eq!(a.shards.iter().map(|s| s.completed).sum::<u64>(), a.completed);
        assert_eq!(a.shards.iter().map(|s| s.tapes).sum::<usize>(), 16);
        let share: f64 = a.shards.iter().map(|s| s.ring_share).sum();
        assert!((share - 1.0).abs() < 1e-9);
        for s in &a.shards {
            if s.completed > 0 {
                let l = &s.latency;
                assert!(l.p50_s <= l.p95_s && l.p95_s <= l.p99_s && l.p99_s <= l.p999_s);
                assert!(s.drive_utilization > 0.0 && s.drive_utilization <= 1.0);
            }
        }
        // Balanced braces/brackets with the nested shard array present.
        let doc = reports_json(&[a]);
        assert_eq!(doc.matches('{').count(), doc.matches('}').count());
        assert_eq!(doc.matches('[').count(), doc.matches(']').count());
        assert!(doc.contains("\"ring_share\":"));
    }
}
