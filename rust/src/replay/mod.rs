//! Workload replay: virtual-time trace replay, arrival models, QoS metrics.
//!
//! The paper's evaluation (§6) judges schedulers on logs of a real
//! mass-storage system; this subsystem makes that a first-class operation.
//! A replay takes a timestamped request stream ([`arrivals`]: raw-log
//! traces via [`crate::dataset::rawlog`], Poisson, bursty on/off, or
//! diurnal), pushes it through the production batching layer onto a
//! simulated drive pool under any [`crate::sched::Scheduler`] policy
//! ([`engine`]), and reports the quality of service users would actually
//! experience ([`report`]): p50/p95/p99/p99.9 end-to-end latency and
//! in-tape service time, throughput, utilization, shed/retry counts.
//!
//! ```text
//!   ArrivalModel ──▶ [virtual clock + event queue] ──▶ Batcher (real one)
//!        trace/poisson/      engine.rs                    │ window, cap,
//!        bursty/diurnal                                   │ backlog bound
//!                                                         ▼
//!   QosReport ◀── histograms ◀── evaluate() ◀── Scheduler policy
//!     (JSON)       p50…p99.9      ground truth    (any of the nine)
//! ```
//!
//! Everything runs at CPU speed, deterministically: the same seed and
//! configuration produce a byte-identical completion log and JSON report —
//! including under [`run_replay_parallel`], which fans the shards of an
//! open-loop replay out over worker threads and merges their outcomes
//! back into the exact single-threaded result. With `ReplayConfig::n_shards > 1` the engine mirrors the
//! multi-library [`crate::cluster`] layer in virtual time — one batcher
//! and one drive pool per shard behind the consistent-hash ring — and the
//! [`QosReport`] gains a per-shard percentile breakdown next to the
//! fleet-wide ladder. With `DriveParams::n_arms > 0` and/or
//! [`crate::sim::Affinity::Lru`] the **mount pipeline** is modeled
//! end-to-end: every mount/unmount occupies a robot arm (queueing FIFO
//! when the per-shard pool is exhausted), tapes stay threaded under LRU
//! affinity so repeat batches skip the mount, and the report gains
//! arm-wait / mount-wait / drive-wait ladders plus remount hit/miss
//! counters. `--arms 0 --affinity none` (the default) reproduces the
//! legacy fixed mount-cost replay byte for byte. The physical state the
//! engine steps — drive stage machines, arm pools, and the per-tape
//! mount-exclusivity ledger behind `--exclusive-tapes` (default on; a
//! cartridge can be threaded in one drive at a time, and batches whose
//! tape is busy elsewhere park on a per-cartridge waitlist, surfacing the
//! `cartridge_wait` ladder) — lives in [`crate::resources`], shared with
//! the live coordinator. The wall-clock sibling ([`driver`]) feeds the *real*
//! threaded coordinator (or a whole [`crate::cluster::Cluster`], via
//! [`RequestSink`]) from the same arrival models — demos and backpressure
//! tests share that code path.

pub mod arrivals;
pub mod clock;
pub mod driver;
pub mod engine;
pub mod histogram;
pub mod report;

pub use arrivals::{
    scan_trace, Arrival, ArrivalModel, BurstyArrivals, DiurnalArrivals, PoissonArrivals,
    RequestMix, StreamingTraceArrivals, TraceArrivals, TraceScan, DEFAULT_TRACE_WINDOW,
};
pub use clock::{EventQueue, VirtualClock};
pub use driver::{drive_closed_loop, LiveDriveStats, RequestSink};
pub use engine::{
    busy_ratio, round_robin_assignment, simulate, simulate_parallel, simulate_parallel_balanced,
    simulate_traced, simulate_with_arena, worker_busy_us, AssignMode, LoopMode, ReplayArena,
    ReplayCompletion, ReplayConfig, ReplayOutcome, ReplayStats, ShardOutcome, WorkerBalance,
};
pub use histogram::LatencyHistogram;
pub use report::{reports_json, LatencyStats, QosReport, ShardQos};

use crate::model::Tape;
use crate::sched::Scheduler;

/// Run one full replay and distill it into a [`QosReport`].
///
/// `duration_s` is the configured arrival horizon (echoed into the report;
/// the virtual makespan may exceed it while the queue drains).
pub fn run_replay(
    cfg: &ReplayConfig,
    catalog: &[Tape],
    policy: &dyn Scheduler,
    model: &mut dyn ArrivalModel,
    seed: u64,
    duration_s: f64,
) -> (QosReport, ReplayOutcome) {
    run_replay_traced(cfg, catalog, policy, model, seed, duration_s, None)
}

/// [`run_replay`] with an optional request-lifecycle trace sink: when
/// `trace` is `Some`, the engine records one span per pipeline stage per
/// completion into the recorder (`--trace-out` dumps it as JSONL). The
/// recorder is a pure observer — the report and outcome are byte-identical
/// to an untraced run.
#[allow(clippy::too_many_arguments)]
pub fn run_replay_traced(
    cfg: &ReplayConfig,
    catalog: &[Tape],
    policy: &dyn Scheduler,
    model: &mut dyn ArrivalModel,
    seed: u64,
    duration_s: f64,
    trace: Option<&crate::obs::TraceRecorder>,
) -> (QosReport, ReplayOutcome) {
    let policy_name = policy.name();
    let arrivals_name = model.name();
    let outcome = engine::simulate_traced(cfg, catalog, policy, model, trace);
    let report = QosReport::new(&policy_name, &arrivals_name, seed, duration_s, cfg, &outcome);
    (report, outcome)
}

/// [`run_replay`] reusing a [`ReplayArena`] across policies: identical
/// report and outcome, without reallocating the event queue, histograms,
/// and completion log per policy. Hand the outcome back to
/// [`ReplayArena::recycle`] once it has been consumed.
#[allow(clippy::too_many_arguments)]
pub fn run_replay_with_arena(
    cfg: &ReplayConfig,
    catalog: &[Tape],
    policy: &dyn Scheduler,
    model: &mut dyn ArrivalModel,
    seed: u64,
    duration_s: f64,
    arena: &mut ReplayArena,
) -> (QosReport, ReplayOutcome) {
    let policy_name = policy.name();
    let arrivals_name = model.name();
    let outcome = engine::simulate_with_arena(cfg, catalog, policy, model, arena);
    let report = QosReport::new(&policy_name, &arrivals_name, seed, duration_s, cfg, &outcome);
    (report, outcome)
}

/// [`run_replay`] over `threads` worker threads (open-loop sharded
/// replays only — see [`simulate_parallel`] for the determinism
/// contract). `make_model` must yield identical arrival streams on every
/// call; the report is byte-identical to the single-threaded one for any
/// [`AssignMode`]. The returned [`WorkerBalance`] is the side channel
/// describing how evenly the work landed — callers print it to stderr or
/// benches, never into the QoS JSON.
#[allow(clippy::too_many_arguments)]
pub fn run_replay_parallel(
    cfg: &ReplayConfig,
    catalog: &[Tape],
    policy: &(dyn Scheduler + Sync),
    make_model: &(dyn Fn() -> Box<dyn ArrivalModel> + Sync),
    seed: u64,
    duration_s: f64,
    threads: usize,
    mode: AssignMode,
) -> (QosReport, ReplayOutcome, WorkerBalance) {
    let policy_name = policy.name();
    let arrivals_name = make_model().name();
    let (outcome, balance) =
        engine::simulate_parallel_balanced(cfg, catalog, policy, make_model, threads, mode);
    let report = QosReport::new(&policy_name, &arrivals_name, seed, duration_s, cfg, &outcome);
    (report, outcome, balance)
}
