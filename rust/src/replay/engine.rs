//! The discrete-event replay core.
//!
//! One single-threaded event loop simulates the full serving path in
//! virtual time: arrivals (from any [`ArrivalModel`]) flow through the
//! coordinator's *real* [`Batcher`] — fed synthetic `Instant`s from the
//! [`VirtualClock`], so batching semantics (window, size cap, per-tape
//! backlog bound) are byte-for-byte the production ones — onto a simulated
//! drive pool. Schedules come from the configured [`Scheduler`] policy and
//! service times from the ground-truth simulator, exactly like a
//! coordinator drive worker; only the waiting happens in zero wall time.
//!
//! Two driver disciplines:
//!
//! - **Open loop** — arrivals submit at their trace time regardless of
//!   system state (the offered load is external). `Busy` rejections shed
//!   the request, which is precisely what a datacenter front-end sees.
//! - **Closed loop** — at most `max_in_flight` submitted-but-unserved
//!   requests; later arrivals queue client-side, and `Busy` rejections
//!   retry after a virtual backoff (the retry path the coordinator's
//!   backpressure contract promises callers).

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use crate::coordinator::{Batch, Batcher, BatcherConfig, PushOutcome};
use crate::model::{Instance, Tape};
use crate::sched::Scheduler;
use crate::sim::{evaluate, DriveParams};

use super::arrivals::{Arrival, ArrivalModel};
use super::clock::{secs_to_us, EventQueue, VirtualClock};
use super::histogram::LatencyHistogram;

/// Driver discipline for a replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopMode {
    /// Submit at trace time; shed on `Busy`.
    Open,
    /// Cap in-flight requests; queue client-side and retry on `Busy`.
    Closed {
        max_in_flight: usize,
    },
}

/// Replay configuration: the serving stack under test plus the driver.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    /// Simulated drive pool size.
    pub n_drives: usize,
    pub batcher: BatcherConfig,
    pub drive: DriveParams,
    pub mode: LoopMode,
    /// Virtual backoff before a closed-loop `Busy` retry, seconds.
    pub retry_backoff_s: f64,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            n_drives: 4,
            batcher: BatcherConfig::default(),
            drive: DriveParams::default(),
            mode: LoopMode::Open,
            retry_backoff_s: 0.01,
        }
    }
}

/// One served request, in virtual time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplayCompletion {
    pub id: u64,
    pub tape: String,
    /// Virtual time the client first presented the request (µs). In closed
    /// loop this precedes acceptance by any client-side queueing and
    /// `Busy`-retry backoff — latency is measured from *here*, so overload
    /// is never hidden (no coordinated omission).
    pub arrived_us: u64,
    /// Virtual time the batcher accepted the request (µs).
    pub submitted_us: u64,
    /// Virtual completion time (µs).
    pub done_us: u64,
    /// End-to-end latency (µs): `done - arrived` — client-side waiting +
    /// batch queueing + mount + in-tape service.
    pub latency_us: u64,
    /// Mount + in-tape service component (µs) — the paper's objective plus
    /// the mount, matching the coordinator's `Completion::service_s`.
    pub service_us: u64,
}

/// Aggregate counters of one replay. (No `PartialEq`: `sched_wall_s` is a
/// wall-clock diagnostic, so whole-struct equality across two runs of the
/// same seed would fail spuriously — compare the deterministic fields, the
/// completion log, or the [`super::report::QosReport`] instead.)
#[derive(Debug, Clone, Default)]
pub struct ReplayStats {
    /// Requests accepted by the batcher.
    pub submitted: u64,
    /// Requests served (equals `submitted` at drain).
    pub completed: u64,
    /// Open-loop requests dropped on `Busy`.
    pub shed: u64,
    /// `Busy` rejections observed (open: each sheds; closed: each retries).
    pub busy_rejections: u64,
    /// Closed-loop retry submissions performed.
    pub retries: u64,
    /// Batches dispatched to drives.
    pub batches: u64,
    /// Virtual time of the last completion (µs).
    pub makespan_us: u64,
    /// Total virtual drive-busy time across the pool (µs).
    pub busy_drive_us: u64,
    /// Wall-clock seconds spent inside `Scheduler::schedule` — a real
    /// measurement of policy compute, NOT part of the deterministic report.
    pub sched_wall_s: f64,
}

/// Everything a replay produces.
#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    pub stats: ReplayStats,
    /// Completion log, sorted by (virtual completion time, request id).
    pub completions: Vec<ReplayCompletion>,
    /// End-to-end latency distribution.
    pub latency: LatencyHistogram,
    /// Mount + in-tape service-time distribution.
    pub service: LatencyHistogram,
}

enum Ev {
    Arrival(Arrival),
    Retry { id: u64, tape: usize, file: usize, arrived_us: u64 },
    /// Re-check batch windows (scheduled for the batcher's next deadline).
    BatchTimer,
    /// A drive finished its batch (mount + span + unmount elapsed).
    DriveFree,
    /// One request completed: closed-loop in-flight slot release.
    Slot,
}

struct Engine<'a> {
    cfg: &'a ReplayConfig,
    catalog: &'a [Tape],
    tape_index: HashMap<String, usize>,
    policy: &'a dyn Scheduler,
    clock: VirtualClock,
    events: EventQueue<Ev>,
    batcher: Batcher,
    free_drives: usize,
    /// id → (arrived, accepted) virtual µs for accepted-but-unserved
    /// requests.
    pending: HashMap<u64, (u64, u64)>,
    /// Closed-loop client-side queue: `(id, tape, file, arrived_us)`.
    client_queue: VecDeque<(u64, usize, usize, u64)>,
    in_flight: usize,
    arrivals_done: bool,
    next_timer_us: Option<u64>,
    next_id: u64,
    stats: ReplayStats,
    completions: Vec<ReplayCompletion>,
    latency: LatencyHistogram,
    service: LatencyHistogram,
}

/// Run `model` against `catalog` under `policy`: the whole replay, at CPU
/// speed. Deterministic: same config + catalog + model stream ⇒ identical
/// [`ReplayOutcome`] (modulo the wall-clock `sched_wall_s` diagnostic).
pub fn simulate(
    cfg: &ReplayConfig,
    catalog: &[Tape],
    policy: &dyn Scheduler,
    model: &mut dyn ArrivalModel,
) -> ReplayOutcome {
    assert!(cfg.n_drives > 0, "replay needs at least one drive");
    assert!(
        cfg.batcher.max_tape_backlog > 0,
        "a zero tape backlog rejects every request (and would retry forever in closed loop)"
    );
    if let LoopMode::Closed { max_in_flight } = cfg.mode {
        assert!(max_in_flight > 0, "closed loop needs a positive in-flight cap");
    }
    let mut eng = Engine {
        cfg,
        catalog,
        tape_index: catalog
            .iter()
            .enumerate()
            .map(|(i, t)| (t.name.clone(), i))
            .collect(),
        policy,
        clock: VirtualClock::new(),
        events: EventQueue::new(),
        batcher: Batcher::new(cfg.batcher),
        free_drives: cfg.n_drives,
        pending: HashMap::new(),
        client_queue: VecDeque::new(),
        in_flight: 0,
        arrivals_done: false,
        next_timer_us: None,
        next_id: 0,
        stats: ReplayStats::default(),
        completions: Vec::new(),
        latency: LatencyHistogram::new(),
        service: LatencyHistogram::new(),
    };

    eng.pull_arrival(model);
    while let Some((t, ev)) = eng.events.pop() {
        eng.clock.advance_to(t);
        match ev {
            Ev::Arrival(a) => {
                assert!(
                    a.tape < eng.catalog.len() && a.file < eng.catalog[a.tape].n_files(),
                    "arrival ({}, {}) outside the catalog",
                    a.tape,
                    a.file
                );
                let id = eng.next_id;
                eng.next_id += 1;
                eng.on_request(id, a.tape, a.file);
                eng.pull_arrival(model);
            }
            Ev::Retry { id, tape, file, arrived_us } => {
                eng.stats.retries += 1;
                eng.try_submit(id, tape, file, arrived_us);
            }
            Ev::BatchTimer => {
                if eng.next_timer_us == Some(t) {
                    eng.next_timer_us = None;
                }
            }
            Ev::DriveFree => eng.free_drives += 1,
            Ev::Slot => eng.on_slot_free(),
        }
        eng.dispatch_ready();
        eng.schedule_timer();
    }

    debug_assert_eq!(eng.batcher.pending(), 0, "replay drained with work queued");
    debug_assert!(eng.pending.is_empty(), "unserved submitted requests");
    debug_assert!(eng.client_queue.is_empty(), "stranded client-side requests");
    eng.completions.sort_by_key(|c| (c.done_us, c.id));
    ReplayOutcome {
        stats: eng.stats,
        completions: eng.completions,
        latency: eng.latency,
        service: eng.service,
    }
}

impl<'a> Engine<'a> {
    fn pull_arrival(&mut self, model: &mut dyn ArrivalModel) {
        match model.next_arrival() {
            Some(a) => {
                // Guard model misbehavior: times must never run backwards.
                let t = secs_to_us(a.at_s).max(self.clock.now_us());
                self.events.push(t, Ev::Arrival(a));
            }
            None => self.arrivals_done = true,
        }
    }

    fn on_request(&mut self, id: u64, tape: usize, file: usize) {
        let arrived_us = self.clock.now_us();
        if let LoopMode::Closed { max_in_flight } = self.cfg.mode {
            if self.in_flight >= max_in_flight {
                self.client_queue.push_back((id, tape, file, arrived_us));
                return;
            }
        }
        self.in_flight += 1;
        self.try_submit(id, tape, file, arrived_us);
    }

    fn on_slot_free(&mut self) {
        self.in_flight = self.in_flight.saturating_sub(1);
        if let LoopMode::Closed { max_in_flight } = self.cfg.mode {
            if self.in_flight < max_in_flight {
                if let Some((id, tape, file, arrived_us)) = self.client_queue.pop_front() {
                    self.in_flight += 1;
                    self.try_submit(id, tape, file, arrived_us);
                }
            }
        }
    }

    fn try_submit(&mut self, id: u64, tape: usize, file: usize, arrived_us: u64) {
        let now = self.clock.now_instant();
        match self.batcher.push(&self.catalog[tape].name, file, id, now) {
            PushOutcome::Busy => {
                self.stats.busy_rejections += 1;
                match self.cfg.mode {
                    LoopMode::Open => {
                        self.stats.shed += 1;
                        self.in_flight = self.in_flight.saturating_sub(1);
                    }
                    LoopMode::Closed { .. } => {
                        let t = self.clock.now_us()
                            + secs_to_us(self.cfg.retry_backoff_s).max(1);
                        self.events.push(t, Ev::Retry { id, tape, file, arrived_us });
                    }
                }
            }
            _accepted => {
                self.stats.submitted += 1;
                self.pending.insert(id, (arrived_us, self.clock.now_us()));
            }
        }
    }

    /// Feed ready batches to free drives. Once arrivals are exhausted and
    /// no request waits client-side, open batches dispatch without waiting
    /// out their window — the coordinator's drain semantics.
    fn dispatch_ready(&mut self) {
        while self.free_drives > 0 {
            let draining = self.arrivals_done && self.client_queue.is_empty();
            let now = self.clock.now_instant();
            let Some(batch) = self.batcher.pop_ready(now, draining) else { break };
            self.dispatch(batch);
        }
    }

    /// Wake the dispatcher at the batcher's next window expiry. Only needed
    /// while a drive is free — otherwise the next `DriveFree` re-checks.
    fn schedule_timer(&mut self) {
        if self.free_drives == 0 {
            return;
        }
        let Some(deadline) = self.batcher.next_deadline() else { return };
        let t = self.clock.us_of(deadline).max(self.clock.now_us());
        match self.next_timer_us {
            Some(cur) if cur <= t => {}
            _ => {
                self.next_timer_us = Some(t);
                self.events.push(t, Ev::BatchTimer);
            }
        }
    }

    fn dispatch(&mut self, batch: Batch) {
        self.free_drives -= 1;
        self.stats.batches += 1;
        let t_us = self.clock.now_us();
        let tape = &self.catalog[self.tape_index[&batch.tape]];
        let inst = Instance::from_tape(tape, &batch.multiplicities(), self.cfg.drive.uturn_bytes())
            .expect("replayed requests are validated against the catalog");

        let wall = Instant::now();
        let sched = self.policy.schedule(&inst);
        self.stats.sched_wall_s += wall.elapsed().as_secs_f64();
        let out = evaluate(&inst, &sched);

        // Per-request accounting through the same shared mapping the
        // coordinator drive worker uses (`Batch::request_service_times`).
        let drive = self.cfg.drive;
        for (id, service_s) in batch.request_service_times(&out, drive) {
            let service_us = secs_to_us(service_s);
            let done_us = t_us + service_us;
            let (arrived_us, submitted_us) =
                self.pending.remove(&id).expect("completion for unsubmitted id");
            let latency_us = done_us - arrived_us;
            self.latency.record_us(latency_us);
            self.service.record_us(service_us);
            self.stats.completed += 1;
            self.stats.makespan_us = self.stats.makespan_us.max(done_us);
            self.completions.push(ReplayCompletion {
                id,
                tape: batch.tape.clone(),
                arrived_us,
                submitted_us,
                done_us,
                latency_us,
                service_us,
            });
            self.events.push(done_us, Ev::Slot);
        }

        let busy_s = self.cfg.drive.mount_s
            + self.cfg.drive.to_seconds(out.finish)
            + self.cfg.drive.unmount_s;
        let busy_us = secs_to_us(busy_s);
        self.stats.busy_drive_us += busy_us;
        self.events.push(t_us + busy_us, Ev::DriveFree);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::arrivals::{PoissonArrivals, RequestMix};
    use crate::sched::{Gs, SimpleDp};
    use std::time::Duration;

    fn catalog() -> Vec<Tape> {
        vec![
            Tape::from_sizes("T0", &[1_000; 60]),
            Tape::from_sizes("T1", &[500; 120]),
            Tape::from_sizes("T2", &[2_000; 30]),
        ]
    }

    fn fast_drive() -> DriveParams {
        DriveParams { mount_s: 1.0, unmount_s: 0.5, bytes_per_s: 1e6, uturn_s: 0.001 }
    }

    fn cfg(mode: LoopMode) -> ReplayConfig {
        ReplayConfig {
            n_drives: 3,
            batcher: BatcherConfig {
                window: Duration::from_millis(200),
                max_batch: 64,
                ..BatcherConfig::default()
            },
            drive: fast_drive(),
            mode,
            retry_backoff_s: 0.05,
        }
    }

    fn poisson(rate: f64, horizon: f64, seed: u64) -> PoissonArrivals {
        PoissonArrivals::new(RequestMix::new(&catalog()), rate, horizon, seed)
    }

    #[test]
    fn serves_every_arrival_and_is_deterministic() {
        let run = || {
            let mut model = poisson(40.0, 10.0, 9);
            simulate(&cfg(LoopMode::Open), &catalog(), &SimpleDp, &mut model)
        };
        let a = run();
        let b = run();
        assert!(a.stats.submitted > 200, "expected ~400 arrivals");
        assert_eq!(a.stats.completed, a.stats.submitted);
        assert_eq!(a.stats.shed, 0);
        assert_eq!(a.completions, b.completions, "same seed ⇒ identical log");
        assert_eq!(a.latency, b.latency);
        assert_eq!(a.stats.completed, b.stats.completed);
        // Completion ids are exactly the submitted ids.
        let mut ids: Vec<u64> = a.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..a.stats.submitted).collect::<Vec<_>>());
        // Latency decomposes sanely: measured from client arrival, which in
        // open loop coincides with batcher acceptance.
        for c in &a.completions {
            assert_eq!(c.done_us - c.arrived_us, c.latency_us);
            assert_eq!(c.arrived_us, c.submitted_us, "open loop never delays submit");
            assert!(c.latency_us >= c.service_us);
        }
        assert_eq!(a.stats.makespan_us, a.completions.last().unwrap().done_us);
    }

    #[test]
    fn virtual_time_decouples_from_wall_time() {
        // 10 virtual minutes of traffic; the replay itself must be fast.
        let wall = Instant::now();
        let mut model = poisson(20.0, 600.0, 4);
        let out = simulate(&cfg(LoopMode::Open), &catalog(), &Gs, &mut model);
        assert!(out.stats.completed > 5_000, "got {}", out.stats.completed);
        assert!(out.stats.makespan_us > 500_000_000, "makespan is virtual");
        assert!(
            wall.elapsed().as_secs_f64() < 30.0,
            "replay must run at CPU speed"
        );
    }

    #[test]
    fn open_loop_sheds_on_busy() {
        let mut config = cfg(LoopMode::Open);
        config.batcher.max_tape_backlog = 4;
        config.n_drives = 1;
        // One hot tape saturates instantly at this rate.
        let catalog = vec![Tape::from_sizes("HOT", &[1_000; 50])];
        let mut model =
            PoissonArrivals::new(RequestMix::new(&catalog), 200.0, 5.0, 1);
        let out = simulate(&config, &catalog, &Gs, &mut model);
        assert!(out.stats.shed > 0, "backlog 4 at 200 rps must shed");
        assert_eq!(out.stats.shed, out.stats.busy_rejections);
        assert_eq!(out.stats.completed, out.stats.submitted);
        assert_eq!(out.stats.retries, 0);
    }

    #[test]
    fn closed_loop_retries_busy_and_respects_cap() {
        let cap = 8;
        let mut config = cfg(LoopMode::Closed { max_in_flight: cap });
        config.batcher.max_tape_backlog = 4;
        config.n_drives = 1;
        let catalog = vec![Tape::from_sizes("HOT", &[1_000; 50])];
        let mut model =
            PoissonArrivals::new(RequestMix::new(&catalog), 200.0, 5.0, 1);
        let out = simulate(&config, &catalog, &Gs, &mut model);
        assert!(out.stats.busy_rejections > 0, "backlog 4 under cap 8 must reject");
        assert!(out.stats.retries >= out.stats.busy_rejections);
        assert_eq!(out.stats.shed, 0, "closed loop never sheds");
        assert_eq!(out.stats.completed, out.stats.submitted);
        // Reconstruct the in-flight level over time from the completion
        // log: it must never exceed the cap.
        let mut edges: Vec<(u64, i64)> = Vec::new();
        for c in &out.completions {
            edges.push((c.submitted_us, 1));
            edges.push((c.done_us, -1));
        }
        // At equal times, completions free slots before submissions claim.
        edges.sort_by_key(|&(t, d)| (t, d));
        let (mut level, mut peak) = (0i64, 0i64);
        for (_, d) in edges {
            level += d;
            peak = peak.max(level);
        }
        assert!(peak <= cap as i64, "in-flight peaked at {peak} > cap {cap}");
        assert!(peak >= 2, "the hot tape should queue more than one request");
        // Latency is measured from client arrival: queued/retried requests
        // must show the client-side wait, not hide it.
        assert!(out.completions.iter().all(|c| c.submitted_us >= c.arrived_us));
        assert!(
            out.completions.iter().any(|c| c.submitted_us > c.arrived_us),
            "a saturated closed loop must delay some submissions client-side"
        );
    }

    #[test]
    fn batching_coalesces_and_better_policy_serves_faster() {
        // A long window coalesces each tape's burst into one batch.
        let mut config = cfg(LoopMode::Open);
        config.batcher.window = Duration::from_secs(30);
        let run = |policy: &dyn Scheduler| {
            let mut model = poisson(30.0, 20.0, 12);
            simulate(&config, &catalog(), policy, &mut model)
        };
        let gs = run(&Gs);
        let sdp = run(&SimpleDp);
        assert_eq!(gs.stats.completed, sdp.stats.completed);
        assert!(
            gs.stats.batches * 10 <= gs.stats.completed,
            "window must coalesce ≥10 requests/batch: {} batches for {}",
            gs.stats.batches,
            gs.stats.completed
        );
        // Batch composition is policy-independent (arrivals + batcher only),
        // and GS's atomic detours are a feasible disjoint-detour schedule,
        // so the disjoint-detour optimum can't serve slower (tolerance: µs
        // rounding of per-request service times).
        assert!(
            sdp.service.mean_s() <= gs.service.mean_s() + 1e-5,
            "SimpleDP {} vs GS {}",
            sdp.service.mean_s(),
            gs.service.mean_s()
        );
    }
}
