//! The discrete-event replay core.
//!
//! One single-threaded event loop simulates the full serving path in
//! virtual time: arrivals (from any [`ArrivalModel`]) flow through the
//! coordinator's *real* [`Batcher`] — fed synthetic `Instant`s from the
//! [`VirtualClock`], so batching semantics (window, size cap, per-tape
//! backlog bound) are byte-for-byte the production ones — onto a simulated
//! drive pool. Schedules come from the configured [`Scheduler`] policy and
//! service times from the ground-truth simulator, exactly like a
//! coordinator drive worker; only the waiting happens in zero wall time.
//!
//! **Sharded mode** (`ReplayConfig::n_shards > 1`) mirrors the live
//! [`crate::cluster::Cluster`] in virtual time: the catalog is partitioned
//! over a deterministic consistent-hash ring ([`crate::cluster::HashRing`],
//! `vnodes` points per shard), and each shard gets its *own* batcher and
//! its own `n_drives`-wide simulated drive pool. Requests route by tape
//! name exactly as the live router does; `Busy` backpressure, shedding,
//! and retries are all per shard. With `n_shards == 1` every request
//! routes to shard 0 and the engine is the single-library replay,
//! unchanged — same event order, same completion log, same percentiles.
//!
//! **Parallel mode** ([`simulate_parallel`]) exploits that shards are
//! independent between routing decisions: each of `N` worker threads
//! replays the *same* arrival stream against the shards it owns,
//! counting foreign arrivals as phantoms so request ids and event-queue
//! positions stay aligned, and the per-worker outcomes merge into a
//! [`ReplayOutcome`] byte-identical to the single-threaded one
//! (ci-gated). Ownership comes from a deterministic pre-pass over the
//! arrival stream ([`AssignMode`]): a greedy LPT bin-pack over per-shard
//! arrival weights by default, static `shard % N` round-robin as the
//! counterfactual baseline, and an epoch-barrier work-stealing re-pack
//! (`--steal`) on top of round-robin. Because the assignment is a pure
//! function of the seeded pre-pass, every mode replays the exact same
//! events — [`simulate_parallel_balanced`] reports who served what in a
//! [`WorkerBalance`] side channel instead of perturbing the outcome.
//! Open loop only — the closed-loop in-flight cap couples shards through
//! global state.
//!
//! Two driver disciplines:
//!
//! - **Open loop** — arrivals submit at their trace time regardless of
//!   system state (the offered load is external). `Busy` rejections shed
//!   the request, which is precisely what a datacenter front-end sees.
//! - **Closed loop** — at most `max_in_flight` submitted-but-unserved
//!   requests; later arrivals queue client-side, and `Busy` rejections
//!   retry after a virtual backoff (the retry path the coordinator's
//!   backpressure contract promises callers).
//!
//! The physical state the engine steps — drive stage machines, robot-arm
//! pools, the cartridge-exclusivity ledger — lives in [`crate::resources`]
//! (shared with the live coordinator); this module is the event
//! orchestration over it. With `ReplayConfig::exclusive_tapes` (the
//! default) a batch whose tape is threaded or mid-mount in another drive
//! parks on that cartridge's FIFO waitlist instead of mounting a second
//! copy; the park → dispatch interval is the `cartridge_wait` QoS
//! component. `--exclusive-tapes off` restores the pre-exclusivity
//! accounting byte for byte.

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use crate::cluster::HashRing;
use crate::coordinator::{
    debug_assert_drain_invariant, Batch, Batcher, BatcherConfig, PushOutcome,
};
use crate::model::{Instance, Tape};
use crate::obs::TraceRecorder;
use crate::resources::{ArmPool, CartridgeLedger, DrivePool, DriveStage};
use crate::sched::Scheduler;
use crate::sim::{evaluate, Affinity, DriveParams, MountPlan, SimOutcome};

use super::arrivals::{Arrival, ArrivalModel};
use super::clock::{secs_to_us, EventQueue, VirtualClock};
use super::histogram::LatencyHistogram;

/// Driver discipline for a replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopMode {
    /// Submit at trace time; shed on `Busy`.
    Open,
    /// Cap in-flight requests; queue client-side and retry on `Busy`.
    Closed {
        max_in_flight: usize,
    },
}

/// Replay configuration: the serving stack under test plus the driver.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    /// Simulated drive pool size — **per shard** (a library brings its own
    /// drives; the fleet has `n_shards · n_drives` drives total).
    pub n_drives: usize,
    pub batcher: BatcherConfig,
    pub drive: DriveParams,
    pub mode: LoopMode,
    /// Virtual backoff before a closed-loop `Busy` retry, seconds.
    pub retry_backoff_s: f64,
    /// Number of library shards (1 = the single-library replay).
    pub n_shards: usize,
    /// Virtual nodes per shard on the consistent-hash ring.
    pub vnodes: usize,
    /// Drive-placement policy inside a shard. With [`Affinity::Lru`] the
    /// mount pipeline is modeled end-to-end: tapes stay threaded after
    /// their batch, a batch landing on a drive that still holds its tape
    /// skips the mount (a *remount hit*), and the least-recently-used
    /// loaded drive is evicted (unmount + mount through the arm pool)
    /// when no empty drive is free. [`Affinity::None`] with
    /// `drive.n_arms == 0` is the legacy fixed mount-cost model — that
    /// configuration reproduces the pre-pipeline replay byte for byte.
    pub affinity: Affinity,
    /// Per-tape mount exclusivity (the default): a cartridge exists once,
    /// so a batch whose tape is in use in another drive parks on a
    /// per-cartridge waitlist ([`crate::resources::CartridgeLedger`])
    /// until the cartridge frees, surfacing the `cartridge_wait` QoS
    /// component. `false` restores the pre-exclusivity model — a hot tape
    /// may be "mounted" in several drives at once — byte for byte.
    pub exclusive_tapes: bool,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            n_drives: 4,
            batcher: BatcherConfig::default(),
            drive: DriveParams::default(),
            mode: LoopMode::Open,
            retry_backoff_s: 0.01,
            n_shards: 1,
            vnodes: 64,
            affinity: Affinity::None,
            exclusive_tapes: true,
        }
    }
}

impl ReplayConfig {
    /// Whether the event-driven mount pipeline is active: any robot-arm
    /// bound (`drive.n_arms > 0`) or drive affinity turns it on. When
    /// inactive the engine runs the legacy fixed mount-cost path, byte
    /// identical to the pre-pipeline replay.
    pub fn pipeline_active(&self) -> bool {
        self.drive.n_arms > 0 || self.affinity == Affinity::Lru
    }
}

/// One served request, in virtual time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplayCompletion {
    pub id: u64,
    pub tape: String,
    /// Virtual time the client first presented the request (µs). In closed
    /// loop this precedes acceptance by any client-side queueing and
    /// `Busy`-retry backoff — latency is measured from *here*, so overload
    /// is never hidden (no coordinated omission).
    pub arrived_us: u64,
    /// Virtual time the batcher accepted the request (µs).
    pub submitted_us: u64,
    /// Virtual completion time (µs).
    pub done_us: u64,
    /// End-to-end latency (µs): `done - arrived` — client-side waiting +
    /// batch queueing + mount + in-tape service.
    pub latency_us: u64,
    /// Mount + in-tape service component (µs) — the paper's objective plus
    /// the mount, matching the coordinator's `Completion::service_s`.
    pub service_us: u64,
}

/// Aggregate counters of one replay. (No `PartialEq`: `sched_wall_s` is a
/// wall-clock diagnostic, so whole-struct equality across two runs of the
/// same seed would fail spuriously — compare the deterministic fields, the
/// completion log, or the [`super::report::QosReport`] instead.)
#[derive(Debug, Clone, Default)]
pub struct ReplayStats {
    /// Requests accepted by the batcher.
    pub submitted: u64,
    /// Requests served (equals `submitted` at drain).
    pub completed: u64,
    /// Open-loop requests dropped on `Busy`.
    pub shed: u64,
    /// `Busy` rejections observed (open: each sheds; closed: each retries).
    pub busy_rejections: u64,
    /// Closed-loop retry submissions performed.
    pub retries: u64,
    /// Batches dispatched to drives.
    pub batches: u64,
    /// Virtual time of the last completion (µs).
    pub makespan_us: u64,
    /// Total virtual drive-busy time across the pool (µs).
    pub busy_drive_us: u64,
    /// Batches that landed on a drive still holding their tape (the mount
    /// was skipped entirely — drive affinity). 0 on the legacy path.
    pub remount_hits: u64,
    /// Batches that paid a fresh mount (every batch on the legacy path
    /// counts here once the pipeline is active; 0 when it is not).
    pub remount_misses: u64,
    /// Batches parked on a cartridge waitlist because their tape was in
    /// use in another drive (exclusive-tapes mode only; 0 when off).
    pub cartridge_parks: u64,
    /// Wall-clock seconds spent inside `Scheduler::schedule` — a real
    /// measurement of policy compute, NOT part of the deterministic report.
    pub sched_wall_s: f64,
}

/// One shard's share of a replay: its own counters and distributions.
/// (`stats` reuses [`ReplayStats`]; the fleet-level aggregate lives in
/// [`ReplayOutcome::stats`] and is *not* derived from these — both are
/// recorded first-hand, and tests assert they reconcile.)
#[derive(Debug, Clone)]
pub struct ShardOutcome {
    /// Shard index (`0..n_shards`).
    pub shard: usize,
    /// Catalog tapes the ring routed to this shard.
    pub n_tapes: usize,
    /// Fraction of the ring's key space this shard owns.
    pub ring_share: f64,
    pub stats: ReplayStats,
    /// End-to-end latency distribution of this shard's requests.
    pub latency: LatencyHistogram,
    /// Mount + in-tape service-time distribution of this shard's requests.
    pub service: LatencyHistogram,
    /// Per-arm-op wait for a free robot arm (one sample per mount/unmount;
    /// all zero when the pipeline is inactive or arms are unconstrained).
    pub arm_wait: LatencyHistogram,
    /// Per-batch mount-pipeline latency: dispatch → execution start (arm
    /// waits + robot ops; 0 on a remount hit). Empty on the legacy path.
    pub mount_wait: LatencyHistogram,
    /// Per-batch wait between becoming dispatchable and landing on a
    /// drive (recorded on both paths; serialized only when the pipeline
    /// is active). In exclusive-tapes mode a parked batch's cartridge
    /// wait is carved out of this, so the two components never overlap.
    pub drive_wait: LatencyHistogram,
    /// Per-batch wait for the tape cartridge itself (0 for batches that
    /// never parked). One sample per batch in exclusive-tapes mode; empty
    /// when exclusivity is off.
    pub cartridge_wait: LatencyHistogram,
}

/// Everything a replay produces.
#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    pub stats: ReplayStats,
    /// Completion log, sorted by (virtual completion time, request id).
    pub completions: Vec<ReplayCompletion>,
    /// End-to-end latency distribution.
    pub latency: LatencyHistogram,
    /// Mount + in-tape service-time distribution.
    pub service: LatencyHistogram,
    /// Fleet-wide robot-arm wait distribution (see [`ShardOutcome`]).
    pub arm_wait: LatencyHistogram,
    /// Fleet-wide mount-pipeline latency distribution, per batch.
    pub mount_wait: LatencyHistogram,
    /// Fleet-wide dispatchable→dispatched wait distribution, per batch.
    pub drive_wait: LatencyHistogram,
    /// Fleet-wide cartridge-wait distribution, per batch (see
    /// [`ShardOutcome::cartridge_wait`]).
    pub cartridge_wait: LatencyHistogram,
    /// Per-shard breakdown (`n_shards` entries; one entry mirroring the
    /// fleet totals in the single-library case).
    pub per_shard: Vec<ShardOutcome>,
}

enum Ev {
    Arrival(Arrival),
    Retry { id: u64, tape: usize, file: usize, arrived_us: u64 },
    /// Re-check a shard's batch windows (scheduled for that batcher's next
    /// deadline).
    BatchTimer(usize),
    /// Legacy path: this drive finished its whole busy period (mount +
    /// span + unmount rolled into one).
    DriveFree { shard: usize, drive: usize },
    /// Pipeline path: one robot-arm operation (mount or unmount) of this
    /// drive's current cycle finished; the arm frees and the next queued
    /// op (FIFO) starts.
    ArmOpDone { shard: usize, drive: usize },
    /// Pipeline path: the drive's head finished executing its batch's
    /// schedule (the tape stays threaded under LRU affinity; under
    /// `Affinity::None` a trailing unmount follows through the arm pool).
    ExecDone { shard: usize, drive: usize },
    /// One request completed: closed-loop in-flight slot release.
    Slot,
}

/// Reusable replay buffers for multi-policy runs. The event queue's heap,
/// the fleet and per-shard histograms, and the completion log are the
/// engine's only allocations that scale with the workload, and a
/// multi-policy `replay` run used to rebuild every one of them per
/// policy. Run through [`simulate_with_arena`], report the outcome, then
/// hand it back with [`ReplayArena::recycle`] so the next policy reuses
/// the buffers. Reuse is invisible in the output: recycled histograms are
/// cleared to fresh-state equality and the recycled event queue restarts
/// its FIFO tie-break counter (and debug-asserts it drained empty).
#[derive(Default)]
pub struct ReplayArena {
    events: EventQueue<Ev>,
    histograms: Vec<LatencyHistogram>,
    completions: Vec<ReplayCompletion>,
}

impl ReplayArena {
    pub fn new() -> ReplayArena {
        ReplayArena::default()
    }

    /// Number of histograms currently pooled (diagnostics and tests).
    pub fn pooled_histograms(&self) -> usize {
        self.histograms.len()
    }

    /// Reclaim a reported outcome's buffers for the next run.
    pub fn recycle(&mut self, outcome: ReplayOutcome) {
        let ReplayOutcome {
            stats: _,
            mut completions,
            latency,
            service,
            arm_wait,
            mount_wait,
            drive_wait,
            cartridge_wait,
            per_shard,
        } = outcome;
        completions.clear();
        if completions.capacity() > self.completions.capacity() {
            self.completions = completions;
        }
        for mut h in [latency, service, arm_wait, mount_wait, drive_wait, cartridge_wait] {
            h.clear();
            self.histograms.push(h);
        }
        for s in per_shard {
            for mut h in
                [s.latency, s.service, s.arm_wait, s.mount_wait, s.drive_wait, s.cartridge_wait]
            {
                h.clear();
                self.histograms.push(h);
            }
        }
    }
}

/// A batch that has a drive but is still waiting on robot-arm work before
/// its head can start executing (the payload the drive's
/// [`DriveStage::Mounting`] stage carries).
#[derive(Debug)]
struct PendingExec {
    batch: Batch,
    out: SimOutcome,
    /// Virtual dispatch time (µs) — the mount pipeline is measured from
    /// here.
    t0_us: u64,
    /// Catalog tape index the dispatch evicted from this drive, released
    /// back to the shelf (cartridge ledger) when the evict-unmount
    /// completes. Only tracked in exclusive-tapes mode.
    evicted_tape: Option<usize>,
    /// Span-chain boundaries carried from dispatch (see `exec_batch`):
    /// when the batch sealed, and its drive/cartridge wait components.
    ready_us: u64,
    dw_us: u64,
    cw_us: u64,
}

/// A batch parked on a cartridge waitlist: its tape was in use in another
/// drive at dispatch time.
#[derive(Debug)]
struct ParkedBatch {
    batch: Batch,
    /// Virtual time the batch parked (µs) — the cartridge wait is
    /// measured from here.
    parked_at_us: u64,
}

/// Per-shard live state: the real batcher plus that library's share of
/// the resource layer (drives, arms, cartridge ledger).
struct ShardState {
    batcher: Batcher,
    drives: DrivePool<usize, PendingExec>,
    arms: ArmPool,
    /// Cartridge exclusivity state, keyed by catalog tape index. Only
    /// consulted in exclusive-tapes mode.
    ledger: CartridgeLedger<usize, ParkedBatch>,
    next_timer_us: Option<u64>,
    n_tapes: usize,
    ring_share: f64,
    stats: ReplayStats,
    latency: LatencyHistogram,
    service: LatencyHistogram,
    arm_wait: LatencyHistogram,
    mount_wait: LatencyHistogram,
    drive_wait: LatencyHistogram,
    cartridge_wait: LatencyHistogram,
    /// Robot-arm wait (µs) accumulated by each drive's *current* cycle —
    /// the `arm_wait` span component. Reset at dispatch so a trailing
    /// unmount's wait never pollutes the next cycle's chain.
    arm_accum: Vec<u64>,
}

struct Engine<'a> {
    cfg: &'a ReplayConfig,
    catalog: &'a [Tape],
    tape_index: HashMap<String, usize>,
    /// Catalog tape index → owning shard (consistent-hash routing, fixed
    /// for the whole replay).
    tape_shard: Vec<usize>,
    policy: &'a dyn Scheduler,
    clock: VirtualClock,
    events: EventQueue<Ev>,
    shards: Vec<ShardState>,
    /// Whether the event-driven mount pipeline is on (cached
    /// `cfg.pipeline_active()`).
    pipeline: bool,
    /// Whether per-tape mount exclusivity is enforced (cached
    /// `cfg.exclusive_tapes`).
    exclusive: bool,
    /// Monotone dispatch counter feeding the drives' `last_used` (LRU).
    tick: u64,
    /// id → (arrived, accepted) virtual µs for accepted-but-unserved
    /// requests.
    pending: HashMap<u64, (u64, u64)>,
    /// Closed-loop client-side queue: `(id, tape, file, arrived_us)`.
    client_queue: VecDeque<(u64, usize, usize, u64)>,
    in_flight: usize,
    arrivals_done: bool,
    next_id: u64,
    /// Per-shard ownership mask (all-true outside [`simulate_parallel`]):
    /// an arrival routed to an unowned shard still consumes its request
    /// id — keeping ids and event-queue positions aligned with the
    /// single-threaded run — but is otherwise dropped as a phantom.
    owned: Vec<bool>,
    /// Arrivals dropped because another worker owns their shard.
    phantoms: u64,
    stats: ReplayStats,
    completions: Vec<ReplayCompletion>,
    latency: LatencyHistogram,
    service: LatencyHistogram,
    arm_wait: LatencyHistogram,
    mount_wait: LatencyHistogram,
    drive_wait: LatencyHistogram,
    cartridge_wait: LatencyHistogram,
    /// Span recorder, when the caller asked for request-lifecycle traces.
    /// `None` costs nothing on the hot path (one branch per completion),
    /// which is what keeps the default replay byte-identical.
    trace: Option<&'a TraceRecorder>,
}

/// Run `model` against `catalog` under `policy`: the whole replay, at CPU
/// speed. Deterministic: same config + catalog + model stream ⇒ identical
/// [`ReplayOutcome`] (modulo the wall-clock `sched_wall_s` diagnostic).
pub fn simulate(
    cfg: &ReplayConfig,
    catalog: &[Tape],
    policy: &dyn Scheduler,
    model: &mut dyn ArrivalModel,
) -> ReplayOutcome {
    simulate_impl(cfg, catalog, policy, model, None, None, None)
}

/// [`simulate`] with an optional request-lifecycle span recorder: every
/// completed request emits its full nine-stage chain (submit → … →
/// complete, virtual µs) into `trace`. `trace: None` is exactly
/// `simulate` — same events, same outcome, byte for byte.
pub fn simulate_traced(
    cfg: &ReplayConfig,
    catalog: &[Tape],
    policy: &dyn Scheduler,
    model: &mut dyn ArrivalModel,
    trace: Option<&TraceRecorder>,
) -> ReplayOutcome {
    simulate_impl(cfg, catalog, policy, model, trace, None, None)
}

/// [`simulate`] reusing a [`ReplayArena`]'s buffers instead of
/// allocating fresh ones — for multi-policy runs over the same workload.
/// The outcome is byte-identical to [`simulate`]'s (test-pinned); feed it
/// back via [`ReplayArena::recycle`] once reported.
pub fn simulate_with_arena(
    cfg: &ReplayConfig,
    catalog: &[Tape],
    policy: &dyn Scheduler,
    model: &mut dyn ArrivalModel,
    arena: &mut ReplayArena,
) -> ReplayOutcome {
    simulate_impl(cfg, catalog, policy, model, None, None, Some(arena))
}

/// How [`simulate_parallel_balanced`] maps shards to worker threads.
/// Ownership is decided *before* the replay, from a deterministic
/// pre-pass over the arrival stream, so every mode preserves the
/// byte-identical merge contract — the modes differ only in which worker
/// serves which shard, never in what any shard computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AssignMode {
    /// Static `shard % threads` ownership — the pre-balancing scheme,
    /// kept as the counterfactual baseline (it idles workers on skewed
    /// rings).
    RoundRobin,
    /// Greedy LPT bin-pack over pre-pass arrival weights: shards sorted
    /// by (weight desc, id asc) land on the least-loaded worker,
    /// lowest-index tie-break. The default for `--threads N`.
    Weighted,
    /// Deterministic work stealing (`--steal`): start from round-robin,
    /// then at each fixed virtual-time epoch barrier move shards that
    /// still have remaining work off overloaded workers whenever the
    /// move strictly improves the projected balance — a lower maximum
    /// load, or a higher minimum at equal maximum. Each accepted move is
    /// one [`WorkerBalance::steal_events`] count.
    Stolen,
}

/// Virtual-time barriers the [`AssignMode::Stolen`] re-pack evaluates at:
/// the pre-pass horizon is split into this many equal epochs.
const STEAL_EPOCHS: usize = 8;

/// The balance side channel of [`simulate_parallel_balanced`]: which
/// worker owned which shard and how busy each worker's shards kept it.
/// Deliberately *not* part of [`ReplayOutcome`] — the QoS report stays
/// byte-identical across thread counts and assignment modes (the ci.sh
/// `cmp` gate), so balance evidence travels next to the outcome, never
/// inside it.
#[derive(Debug, Clone)]
pub struct WorkerBalance {
    pub mode: AssignMode,
    /// Shard → owning worker.
    pub assignment: Vec<usize>,
    /// Σ virtual `busy_drive_us` over each worker's shards — the
    /// deterministic measure of how much serving work each worker did.
    pub worker_busy_us: Vec<u64>,
    /// Accepted epoch-barrier moves (0 outside [`AssignMode::Stolen`]).
    pub steal_events: u64,
    /// Pre-pass per-shard arrival counts (empty for `RoundRobin`, which
    /// runs no pre-pass).
    pub shard_weights: Vec<u64>,
}

impl WorkerBalance {
    /// `max/min` worker busy time: 1.0 for an idle replay, `∞` when some
    /// worker stayed idle while another served.
    pub fn busy_ratio(&self) -> f64 {
        busy_ratio(&self.worker_busy_us)
    }
}

/// `max/min` over per-worker busy times (see [`WorkerBalance::busy_ratio`]).
pub fn busy_ratio(busy: &[u64]) -> f64 {
    let max = busy.iter().copied().max().unwrap_or(0);
    let min = busy.iter().copied().min().unwrap_or(0);
    if max == 0 {
        1.0
    } else if min == 0 {
        f64::INFINITY
    } else {
        max as f64 / min as f64
    }
}

/// The static `shard % threads` ownership vector.
pub fn round_robin_assignment(n_shards: usize, threads: usize) -> Vec<usize> {
    (0..n_shards).map(|s| s % threads).collect()
}

/// Σ `busy_drive_us` of each worker's shards under `assignment` — usable
/// against any outcome's per-shard breakdown, so the counterfactual
/// round-robin balance can be computed from the same run.
pub fn worker_busy_us(
    assignment: &[usize],
    threads: usize,
    per_shard: &[ShardOutcome],
) -> Vec<u64> {
    let mut busy = vec![0u64; threads];
    for sh in per_shard {
        busy[assignment[sh.shard]] += sh.stats.busy_drive_us;
    }
    busy
}

/// Pre-pass: replay the arrival stream (routing only, no serving),
/// counting arrivals per shard and the stream horizon. The ring and
/// route duplicate `simulate_impl`'s exactly, so the weights describe
/// precisely the work each shard will see.
fn prepass_weights(
    cfg: &ReplayConfig,
    catalog: &[Tape],
    model: &mut dyn ArrivalModel,
) -> (Vec<u64>, f64) {
    let ring = HashRing::new(cfg.n_shards, cfg.vnodes);
    let tape_shard: Vec<usize> = catalog.iter().map(|t| ring.route(&t.name)).collect();
    let mut weights = vec![0u64; cfg.n_shards];
    let mut horizon_s = 0.0f64;
    while let Some(a) = model.next_arrival() {
        weights[tape_shard[a.tape]] += 1;
        horizon_s = horizon_s.max(a.at_s);
    }
    (weights, horizon_s)
}

/// Second pre-pass for [`AssignMode::Stolen`]: bucket each shard's
/// arrivals into [`STEAL_EPOCHS`] equal slices of `[0, horizon]`.
fn prepass_epochs(
    cfg: &ReplayConfig,
    catalog: &[Tape],
    model: &mut dyn ArrivalModel,
    horizon_s: f64,
) -> Vec<Vec<u64>> {
    let ring = HashRing::new(cfg.n_shards, cfg.vnodes);
    let tape_shard: Vec<usize> = catalog.iter().map(|t| ring.route(&t.name)).collect();
    let mut buckets = vec![vec![0u64; STEAL_EPOCHS]; cfg.n_shards];
    while let Some(a) = model.next_arrival() {
        let e = if horizon_s > 0.0 {
            (((a.at_s / horizon_s) * STEAL_EPOCHS as f64) as usize).min(STEAL_EPOCHS - 1)
        } else {
            0
        };
        buckets[tape_shard[a.tape]][e] += 1;
    }
    buckets
}

/// Least-loaded worker, lowest index on ties.
fn least_loaded(load: &[u64]) -> usize {
    let mut best = 0;
    for w in 1..load.len() {
        if load[w] < load[best] {
            best = w;
        }
    }
    best
}

/// Greedy LPT bin-pack: heaviest shard first onto the least-loaded
/// worker — the deterministic assignment behind [`AssignMode::Weighted`].
fn lpt_assignment(weights: &[u64], threads: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by_key(|&s| (std::cmp::Reverse(weights[s]), s));
    let mut load = vec![0u64; threads];
    let mut assignment = vec![0usize; weights.len()];
    for s in order {
        let w = least_loaded(&load);
        assignment[s] = w;
        load[w] += weights[s];
    }
    assignment
}

/// Epoch-barrier steal refinement: starting from `assignment`, consider
/// at each barrier the shards that still have work in the remaining
/// epochs (heaviest remaining first, shard-id tie-break) and move one to
/// the least-loaded worker whenever that strictly improves the projected
/// balance — `(max load, -min load)` drops lexicographically, so a steal
/// either shrinks the straggler or feeds an idle worker without growing
/// the straggler. A move re-homes the shard's *whole* lifetime — replay
/// state cannot migrate mid-run — so the barriers only stage which
/// candidates are considered when. Pure arithmetic over the pre-pass:
/// the final assignment is a function of (epoch weights, threads) alone,
/// which is what keeps the stolen replay byte-identical.
fn steal_refine(epochs: &[Vec<u64>], threads: usize, assignment: &mut [usize]) -> u64 {
    let totals: Vec<u64> = epochs.iter().map(|b| b.iter().sum()).collect();
    let mut load = vec![0u64; threads];
    for (s, &w) in assignment.iter().enumerate() {
        load[w] += totals[s];
    }
    let extremes = |load: &[u64]| {
        let max = load.iter().copied().max().unwrap_or(0);
        let min = load.iter().copied().min().unwrap_or(0);
        (max, min)
    };
    let mut steals = 0u64;
    for e in 0..STEAL_EPOCHS {
        let remaining: Vec<u64> = epochs.iter().map(|b| b[e..].iter().sum()).collect();
        let mut order: Vec<usize> = (0..epochs.len()).collect();
        order.sort_by_key(|&s| (std::cmp::Reverse(remaining[s]), s));
        for s in order {
            if remaining[s] == 0 {
                continue;
            }
            let cur = assignment[s];
            let target = least_loaded(&load);
            if target == cur {
                continue;
            }
            let (max_before, min_before) = extremes(&load);
            let after: Vec<u64> = load
                .iter()
                .enumerate()
                .map(|(w, &l)| match w {
                    _ if w == cur => l - totals[s],
                    _ if w == target => l + totals[s],
                    _ => l,
                })
                .collect();
            let (max_after, min_after) = extremes(&after);
            let improves = max_after < max_before
                || (max_after == max_before && min_after > min_before);
            if improves {
                load = after;
                assignment[s] = target;
                steals += 1;
            }
        }
    }
    steals
}

/// Fan a sharded open-loop replay out over `threads` OS threads and merge
/// the per-worker outcomes deterministically, assigning shards to workers
/// by pre-pass weight ([`AssignMode::Weighted`] — see
/// [`simulate_parallel_balanced`] for the other modes and the balance
/// side channel). Every worker replays the *same* arrival stream from its
/// own `make_model()` instance (the factory must yield identical streams:
/// a seeded synthetic model or a shared trace), serving the requests of
/// its own shards and dropping the rest as phantoms, which keeps request
/// ids, event-queue positions, and each shard's FIFO tie-break order
/// exactly as in the single-threaded run. The merged [`ReplayOutcome`] is
/// therefore identical to [`simulate`]'s — same completion log,
/// histograms, and per-shard breakdown; only the wall-clock
/// `sched_wall_s` diagnostic differs (it sums real compute across
/// workers) — and the `--threads 4` vs `--threads 1` QoS `cmp` gate in
/// ci.sh pins the reports byte for byte.
///
/// Open loop only: the closed-loop in-flight cap and client queue couple
/// shards through global state, so masking shards would change behavior.
/// `threads` is clamped to `[1, n_shards]` (with a stderr note — a
/// worker without shards would only idle); a clamp to 1 runs plain
/// [`simulate`].
pub fn simulate_parallel(
    cfg: &ReplayConfig,
    catalog: &[Tape],
    policy: &(dyn Scheduler + Sync),
    make_model: &(dyn Fn() -> Box<dyn ArrivalModel> + Sync),
    threads: usize,
) -> ReplayOutcome {
    simulate_parallel_balanced(cfg, catalog, policy, make_model, threads, AssignMode::Weighted).0
}

/// [`simulate_parallel`] with an explicit [`AssignMode`], returning the
/// [`WorkerBalance`] side channel next to the outcome. The outcome is
/// byte-identical across every mode and thread count (test-pinned);
/// only the balance — who served what, and how evenly — changes.
pub fn simulate_parallel_balanced(
    cfg: &ReplayConfig,
    catalog: &[Tape],
    policy: &(dyn Scheduler + Sync),
    make_model: &(dyn Fn() -> Box<dyn ArrivalModel> + Sync),
    threads: usize,
    mode: AssignMode,
) -> (ReplayOutcome, WorkerBalance) {
    assert!(
        matches!(cfg.mode, LoopMode::Open),
        "parallel replay requires open-loop mode (the closed-loop in-flight cap couples shards)"
    );
    let ceiling = cfg.n_shards.max(1);
    if threads > ceiling {
        eprintln!(
            "tapesched: clamping --threads {threads} to {ceiling} \
             (one worker per shard is the parallel ceiling; extra workers would own nothing)"
        );
    }
    let threads = threads.clamp(1, ceiling);
    let mut steal_events = 0u64;
    let (assignment, shard_weights) = if threads == 1 {
        (vec![0usize; cfg.n_shards], Vec::new())
    } else {
        match mode {
            AssignMode::RoundRobin => {
                (round_robin_assignment(cfg.n_shards, threads), Vec::new())
            }
            AssignMode::Weighted => {
                let (weights, _) = prepass_weights(cfg, catalog, make_model().as_mut());
                (lpt_assignment(&weights, threads), weights)
            }
            AssignMode::Stolen => {
                let (weights, horizon_s) =
                    prepass_weights(cfg, catalog, make_model().as_mut());
                let epochs = prepass_epochs(cfg, catalog, make_model().as_mut(), horizon_s);
                let mut assignment = round_robin_assignment(cfg.n_shards, threads);
                steal_events = steal_refine(&epochs, threads, &mut assignment);
                (assignment, weights)
            }
        }
    };
    let outcome = if threads == 1 {
        simulate(cfg, catalog, policy, make_model().as_mut())
    } else {
        let mut slots: Vec<Option<ReplayOutcome>> = Vec::new();
        slots.resize_with(threads, || None);
        std::thread::scope(|scope| {
            for (w, slot) in slots.iter_mut().enumerate() {
                let assignment = &assignment;
                scope.spawn(move || {
                    let owned: Vec<bool> =
                        (0..cfg.n_shards).map(|s| assignment[s] == w).collect();
                    let mut model = make_model();
                    *slot = Some(simulate_impl(
                        cfg,
                        catalog,
                        policy,
                        model.as_mut(),
                        None,
                        Some(&owned),
                        None,
                    ));
                });
            }
        });
        merge_outcomes(cfg, &assignment, slots.into_iter().map(Option::unwrap).collect())
    };
    let busy = worker_busy_us(&assignment, threads, &outcome.per_shard);
    (
        outcome,
        WorkerBalance {
            mode,
            assignment,
            worker_busy_us: busy,
            steal_events,
            shard_weights,
        },
    )
}

/// Deterministically merge the per-worker outcomes of a parallel replay.
/// Completion keys `(done_us, id)` are globally unique, so concatenating
/// and sorting reproduces the single-threaded log exactly; the integer
/// counters and histograms sum exactly because every fleet-level
/// increment in the engine pairs with a shard-level one and each shard
/// lives in exactly one worker (`assignment[shard]`).
fn merge_outcomes(
    cfg: &ReplayConfig,
    assignment: &[usize],
    workers: Vec<ReplayOutcome>,
) -> ReplayOutcome {
    let mut stats = ReplayStats::default();
    let mut completions: Vec<ReplayCompletion> =
        Vec::with_capacity(workers.iter().map(|w| w.completions.len()).sum());
    let mut latency = LatencyHistogram::new();
    let mut service = LatencyHistogram::new();
    let mut arm_wait = LatencyHistogram::new();
    let mut mount_wait = LatencyHistogram::new();
    let mut drive_wait = LatencyHistogram::new();
    let mut cartridge_wait = LatencyHistogram::new();
    let mut per_shard: Vec<Option<ShardOutcome>> = Vec::new();
    per_shard.resize_with(cfg.n_shards, || None);
    for (w, out) in workers.into_iter().enumerate() {
        let s = out.stats;
        stats.submitted += s.submitted;
        stats.completed += s.completed;
        stats.shed += s.shed;
        stats.busy_rejections += s.busy_rejections;
        stats.retries += s.retries;
        stats.batches += s.batches;
        stats.makespan_us = stats.makespan_us.max(s.makespan_us);
        stats.busy_drive_us += s.busy_drive_us;
        stats.remount_hits += s.remount_hits;
        stats.remount_misses += s.remount_misses;
        stats.cartridge_parks += s.cartridge_parks;
        stats.sched_wall_s += s.sched_wall_s;
        completions.extend(out.completions);
        latency.merge(&out.latency);
        service.merge(&out.service);
        arm_wait.merge(&out.arm_wait);
        mount_wait.merge(&out.mount_wait);
        drive_wait.merge(&out.drive_wait);
        cartridge_wait.merge(&out.cartridge_wait);
        for sh in out.per_shard {
            if assignment[sh.shard] == w {
                per_shard[sh.shard] = Some(sh);
            }
        }
    }
    completions.sort_by_key(|c| (c.done_us, c.id));
    ReplayOutcome {
        stats,
        completions,
        latency,
        service,
        arm_wait,
        mount_wait,
        drive_wait,
        cartridge_wait,
        per_shard: per_shard
            .into_iter()
            .map(|s| s.expect("every shard has exactly one owning worker"))
            .collect(),
    }
}

/// The one replay implementation behind [`simulate`], [`simulate_traced`],
/// [`simulate_with_arena`] and [`simulate_parallel`]'s workers. `owned`
/// masks which shards this run serves (`None` = all); `arena` supplies
/// recycled buffers (`None` = allocate fresh).
fn simulate_impl(
    cfg: &ReplayConfig,
    catalog: &[Tape],
    policy: &dyn Scheduler,
    model: &mut dyn ArrivalModel,
    trace: Option<&TraceRecorder>,
    owned: Option<&[bool]>,
    arena: Option<&mut ReplayArena>,
) -> ReplayOutcome {
    assert!(cfg.n_drives > 0, "replay needs at least one drive per shard");
    assert!(cfg.n_shards > 0, "replay needs at least one shard");
    assert!(cfg.vnodes > 0, "the ring needs at least one virtual node per shard");
    assert!(
        cfg.batcher.max_tape_backlog > 0,
        "a zero tape backlog rejects every request (and would retry forever in closed loop)"
    );
    if let LoopMode::Closed { max_in_flight } = cfg.mode {
        assert!(max_in_flight > 0, "closed loop needs a positive in-flight cap");
    }
    if let Some(o) = owned {
        assert_eq!(o.len(), cfg.n_shards, "ownership mask must cover every shard");
        assert!(
            matches!(cfg.mode, LoopMode::Open),
            "shard-masked (parallel) replay is open-loop only"
        );
    }
    // Recycled buffers, when the caller keeps a ReplayArena across
    // policies; fresh allocations otherwise. Pooled histograms are
    // cleared at recycle time and the pooled event queue restarts its
    // FIFO sequence counter, so both behave exactly like fresh ones.
    let mut arena = arena;
    let (events, mut hist_pool, completions) = match arena.as_deref_mut() {
        Some(a) => (
            std::mem::take(&mut a.events),
            std::mem::take(&mut a.histograms),
            std::mem::take(&mut a.completions),
        ),
        None => (EventQueue::new(), Vec::new(), Vec::new()),
    };
    fn take_hist(pool: &mut Vec<LatencyHistogram>) -> LatencyHistogram {
        pool.pop().unwrap_or_else(LatencyHistogram::new)
    }
    // Partition the catalog over the ring once; routing is fixed for the
    // whole replay (fresh ring ⇒ shard ids are exactly 0..n_shards).
    let ring = HashRing::new(cfg.n_shards, cfg.vnodes);
    let spread = ring.spread();
    let tape_shard: Vec<usize> = catalog.iter().map(|t| ring.route(&t.name)).collect();
    let mut shards: Vec<ShardState> = Vec::with_capacity(cfg.n_shards);
    for s in 0..cfg.n_shards {
        shards.push(ShardState {
            batcher: Batcher::new(cfg.batcher),
            drives: DrivePool::new(cfg.n_drives),
            arms: ArmPool::new(cfg.drive.n_arms),
            ledger: CartridgeLedger::new(),
            next_timer_us: None,
            n_tapes: tape_shard.iter().filter(|&&owner| owner == s).count(),
            ring_share: spread[s],
            stats: ReplayStats::default(),
            latency: take_hist(&mut hist_pool),
            service: take_hist(&mut hist_pool),
            arm_wait: take_hist(&mut hist_pool),
            mount_wait: take_hist(&mut hist_pool),
            drive_wait: take_hist(&mut hist_pool),
            cartridge_wait: take_hist(&mut hist_pool),
            arm_accum: vec![0; cfg.n_drives],
        });
    }
    let mut eng = Engine {
        pipeline: cfg.pipeline_active(),
        exclusive: cfg.exclusive_tapes,
        cfg,
        catalog,
        tape_index: catalog
            .iter()
            .enumerate()
            .map(|(i, t)| (t.name.clone(), i))
            .collect(),
        tape_shard,
        policy,
        clock: VirtualClock::new(),
        events,
        shards,
        tick: 0,
        pending: HashMap::new(),
        client_queue: VecDeque::new(),
        in_flight: 0,
        arrivals_done: false,
        next_id: 0,
        owned: owned.map(<[bool]>::to_vec).unwrap_or_else(|| vec![true; cfg.n_shards]),
        phantoms: 0,
        stats: ReplayStats::default(),
        completions,
        latency: take_hist(&mut hist_pool),
        service: take_hist(&mut hist_pool),
        arm_wait: take_hist(&mut hist_pool),
        mount_wait: take_hist(&mut hist_pool),
        drive_wait: take_hist(&mut hist_pool),
        cartridge_wait: take_hist(&mut hist_pool),
        trace,
    };

    eng.pull_arrival(model);
    while let Some((t, ev)) = eng.events.pop() {
        eng.clock.advance_to(t);
        let was_draining = eng.arrivals_done && eng.client_queue.is_empty();
        // Each event touches at most one shard's batcher (requests route
        // by tape; timers and drives are shard-tagged), so only that
        // shard needs a dispatch/timer pass — an untouched shard cannot
        // have become dispatchable, because readiness only changes via
        // its own pushes, pops, drive returns, or window expiries (for
        // which it holds a scheduled `BatchTimer`). The one global
        // transition is entering drain (`force` dispatch everywhere).
        let affected: Option<usize> = match ev {
            Ev::Arrival(a) => {
                assert!(
                    a.tape < eng.catalog.len() && a.file < eng.catalog[a.tape].n_files(),
                    "arrival ({}, {}) outside the catalog",
                    a.tape,
                    a.file
                );
                let id = eng.next_id;
                eng.next_id += 1;
                let shard = eng.tape_shard[a.tape];
                if eng.owned[shard] {
                    eng.on_request(id, a.tape, a.file);
                    eng.pull_arrival(model);
                    Some(shard)
                } else {
                    // Parallel-replay phantom: another worker owns this
                    // shard. The id is consumed and the next arrival is
                    // pulled from *this* pop all the same, so ids, queue
                    // positions and the FIFO tie-break stay aligned with
                    // the single-threaded run.
                    eng.phantoms += 1;
                    eng.pull_arrival(model);
                    None
                }
            }
            Ev::Retry { id, tape, file, arrived_us } => {
                eng.stats.retries += 1;
                let shard = eng.tape_shard[tape];
                eng.shards[shard].stats.retries += 1;
                eng.try_submit(id, tape, file, arrived_us);
                Some(shard)
            }
            Ev::BatchTimer(shard) => {
                if eng.shards[shard].next_timer_us == Some(t) {
                    eng.shards[shard].next_timer_us = None;
                }
                Some(shard)
            }
            Ev::DriveFree { shard, drive } => {
                eng.release_drive(shard, drive);
                Some(shard)
            }
            Ev::ArmOpDone { shard, drive } => {
                eng.on_arm_op_done(shard, drive);
                Some(shard)
            }
            Ev::ExecDone { shard, drive } => {
                eng.on_exec_done(shard, drive);
                Some(shard)
            }
            Ev::Slot => eng.on_slot_free(),
        };
        let draining = eng.arrivals_done && eng.client_queue.is_empty();
        if draining != was_draining {
            // Entering drain flushes every shard's open batches.
            for shard in 0..eng.shards.len() {
                eng.dispatch_ready(shard);
                eng.schedule_timer(shard);
            }
        } else if let Some(shard) = affected {
            eng.dispatch_ready(shard);
            eng.schedule_timer(shard);
        }
    }

    // Drain invariants — hard asserts, not debug: the tie-broken event
    // order (FIFO sequence numbers on time ties) is what makes these hold
    // deterministically, so a violation is a replay-engine bug, never a
    // workload property.
    for (i, shard) in eng.shards.iter().enumerate() {
        assert_eq!(
            shard.batcher.pending(),
            0,
            "replay drained with work queued on shard {i}"
        );
        assert_eq!(
            shard.drives.n_free(),
            eng.cfg.n_drives,
            "shard {i} drained with a drive still in its mount pipeline"
        );
        assert!(
            shard.arms.idle(),
            "shard {i} drained with robot-arm work outstanding"
        );
        assert!(
            shard.ledger.no_waiters(),
            "shard {i} drained with batches parked on a cartridge waitlist"
        );
        assert_eq!(
            shard.stats.submitted, shard.stats.completed,
            "shard {i}: accepted requests must all complete at drain"
        );
    }
    assert!(eng.pending.is_empty(), "unserved submitted requests");
    assert!(eng.client_queue.is_empty(), "stranded client-side requests");
    // The in-flight identity `submitted − completed − shed` over the whole
    // run: every id handed out was either accepted (and completed) or
    // shed; nothing is in flight once the queue drains.
    assert_eq!(
        eng.stats.submitted, eng.stats.completed,
        "in-flight invariant: submitted − completed must be 0 at drain"
    );
    // Same ledger through the shared helper (the audit accounting rule's
    // anchor). The engine's `submitted` counts *accepted* requests only —
    // shed ones never enter it — so the helper's ledger-side `submitted`
    // is accepted + shed.
    debug_assert_drain_invariant(
        eng.stats.submitted + eng.stats.shed,
        eng.stats.completed,
        eng.stats.shed,
        "replay drain",
    );
    assert_eq!(
        eng.next_id,
        eng.stats.submitted + eng.stats.shed + eng.phantoms,
        "every request id is accounted as completed, shed, or phantom"
    );
    assert_eq!(eng.in_flight, 0, "in-flight level must drain to zero");
    eng.completions.sort_by_key(|c| (c.done_us, c.id));
    if let Some(a) = arena {
        // Hand the drained queue's allocation back for the next policy
        // (recycle debug-asserts it really is empty and restarts the FIFO
        // sequence counter).
        let mut q = eng.events;
        q.recycle();
        a.events = q;
    }
    let per_shard = eng
        .shards
        .into_iter()
        .enumerate()
        .map(|(i, s)| ShardOutcome {
            shard: i,
            n_tapes: s.n_tapes,
            ring_share: s.ring_share,
            stats: s.stats,
            latency: s.latency,
            service: s.service,
            arm_wait: s.arm_wait,
            mount_wait: s.mount_wait,
            drive_wait: s.drive_wait,
            cartridge_wait: s.cartridge_wait,
        })
        .collect();
    ReplayOutcome {
        stats: eng.stats,
        completions: eng.completions,
        latency: eng.latency,
        service: eng.service,
        arm_wait: eng.arm_wait,
        mount_wait: eng.mount_wait,
        drive_wait: eng.drive_wait,
        cartridge_wait: eng.cartridge_wait,
        per_shard,
    }
}

impl<'a> Engine<'a> {
    fn pull_arrival(&mut self, model: &mut dyn ArrivalModel) {
        match model.next_arrival() {
            Some(a) => {
                // Guard model misbehavior: times must never run backwards.
                let t = secs_to_us(a.at_s).max(self.clock.now_us());
                self.events.push(t, Ev::Arrival(a));
            }
            None => self.arrivals_done = true,
        }
    }

    fn on_request(&mut self, id: u64, tape: usize, file: usize) {
        let arrived_us = self.clock.now_us();
        if let LoopMode::Closed { max_in_flight } = self.cfg.mode {
            if self.in_flight >= max_in_flight {
                self.client_queue.push_back((id, tape, file, arrived_us));
                return;
            }
        }
        self.in_flight += 1;
        self.try_submit(id, tape, file, arrived_us);
    }

    /// Release one in-flight slot; in closed loop, admit the next queued
    /// request. Returns the shard that request routed to (the only shard
    /// this event can have touched), if any.
    fn on_slot_free(&mut self) -> Option<usize> {
        self.in_flight = self.in_flight.saturating_sub(1);
        if let LoopMode::Closed { max_in_flight } = self.cfg.mode {
            if self.in_flight < max_in_flight {
                if let Some((id, tape, file, arrived_us)) = self.client_queue.pop_front() {
                    self.in_flight += 1;
                    self.try_submit(id, tape, file, arrived_us);
                    return Some(self.tape_shard[tape]);
                }
            }
        }
        None
    }

    fn try_submit(&mut self, id: u64, tape: usize, file: usize, arrived_us: u64) {
        let now = self.clock.now_instant();
        let shard = self.tape_shard[tape];
        let catalog = self.catalog;
        match self.shards[shard].batcher.push(&catalog[tape].name, file, id, now) {
            PushOutcome::Busy => {
                self.stats.busy_rejections += 1;
                self.shards[shard].stats.busy_rejections += 1;
                match self.cfg.mode {
                    LoopMode::Open => {
                        self.stats.shed += 1;
                        self.shards[shard].stats.shed += 1;
                        self.in_flight = self.in_flight.saturating_sub(1);
                    }
                    LoopMode::Closed { .. } => {
                        let t = self.clock.now_us()
                            + secs_to_us(self.cfg.retry_backoff_s).max(1);
                        self.events.push(t, Ev::Retry { id, tape, file, arrived_us });
                    }
                }
            }
            _accepted => {
                self.stats.submitted += 1;
                self.shards[shard].stats.submitted += 1;
                self.pending.insert(id, (arrived_us, self.clock.now_us()));
            }
        }
    }

    /// Feed one shard's ready batches to its free drives. Batches parked
    /// on a cartridge waitlist whose cartridge has since freed go first
    /// (FIFO by free time — they were popped from the batcher earlier);
    /// then the batcher's queue, parking any batch whose tape is in use
    /// elsewhere. Once arrivals are exhausted and no request waits
    /// client-side, open batches dispatch without waiting out their
    /// window — the coordinator's drain semantics.
    fn dispatch_ready(&mut self, shard: usize) {
        if self.exclusive {
            while self.shards[shard].drives.n_free() > 0 {
                let Some((_tape, parked)) = self.shards[shard].ledger.pop_ready() else {
                    break;
                };
                self.dispatch(shard, parked.batch, Some(parked.parked_at_us));
            }
        }
        while self.shards[shard].drives.n_free() > 0 {
            let draining = self.arrivals_done && self.client_queue.is_empty();
            let now = self.clock.now_instant();
            let Some(batch) = self.shards[shard].batcher.pop_ready(now, draining) else {
                break;
            };
            if self.exclusive {
                let tape_idx = self.tape_index[&batch.tape];
                if !self.shards[shard].ledger.available(&tape_idx) {
                    // The cartridge is threaded or mid-mount in another
                    // drive (or earlier batches already wait for it):
                    // park FIFO until it frees.
                    self.stats.cartridge_parks += 1;
                    self.shards[shard].stats.cartridge_parks += 1;
                    let parked_at_us = self.clock.now_us();
                    self.shards[shard]
                        .ledger
                        .park(tape_idx, ParkedBatch { batch, parked_at_us });
                    continue;
                }
            }
            self.dispatch(shard, batch, None);
        }
    }

    /// Wake one shard's dispatcher at its batcher's next window expiry.
    /// Only needed while that shard has a free drive — otherwise its next
    /// drive release re-checks.
    fn schedule_timer(&mut self, shard: usize) {
        if self.shards[shard].drives.n_free() == 0 {
            return;
        }
        let Some(deadline) = self.shards[shard].batcher.next_deadline() else { return };
        let t = self.clock.us_of(deadline).max(self.clock.now_us());
        let current = self.shards[shard].next_timer_us;
        match current {
            Some(cur) if cur <= t => {}
            _ => {
                self.shards[shard].next_timer_us = Some(t);
                self.events.push(t, Ev::BatchTimer(shard));
            }
        }
    }

    /// Dispatch one popped (or unparked) batch: placement (which drive),
    /// then either the legacy fixed mount-cost accounting or the
    /// event-driven mount pipeline. The legacy branch is byte-for-byte
    /// the pre-pipeline engine — same event pushes in the same order with
    /// the same timestamps — which is what keeps `--arms 0 --affinity
    /// none` reports byte-identical (regression-gated in ci.sh).
    /// `parked_at_us` is set when the batch waited on a cartridge
    /// waitlist (exclusive-tapes mode; the wait is recorded per batch).
    fn dispatch(&mut self, shard: usize, batch: Batch, parked_at_us: Option<u64>) {
        let t_us = self.clock.now_us();
        self.stats.batches += 1;
        self.shards[shard].stats.batches += 1;
        // Dispatchable→dispatched wait (a free-drive wait): recorded on
        // both paths, serialized only when the pipeline is active. The
        // cartridge wait of a parked batch (park → dispatch) is carved
        // *out* of it so the two components never overlap — a parked
        // batch's drive_wait is dispatchable → park (it parked the moment
        // a drive was free for it), and cartridge_wait covers the rest.
        let ready_us = self.clock.us_of(batch.ready_at).min(t_us);
        let cw_us = if self.exclusive {
            parked_at_us.map_or(0, |p| t_us - p)
        } else {
            0
        };
        let dw_us = t_us - ready_us - cw_us;
        self.drive_wait.record_us(dw_us);
        self.shards[shard].drive_wait.record_us(dw_us);

        let tape_idx = self.tape_index[&batch.tape];
        let tape = &self.catalog[tape_idx];
        let inst = Instance::from_tape(tape, &batch.multiplicities(), self.cfg.drive.uturn_bytes())
            .expect("replayed requests are validated against the catalog");

        // audit:allow(wallclock) measures real scheduler compute for the sched_wall_s diagnostic; never feeds virtual time or any golden field
        let wall = Instant::now();
        let sched = self.policy.schedule(&inst);
        let wall_s = wall.elapsed().as_secs_f64();
        self.stats.sched_wall_s += wall_s;
        self.shards[shard].stats.sched_wall_s += wall_s;
        let out = evaluate(&inst, &sched);

        // Placement: which drive, and what mount work that implies.
        let (drive_idx, plan) = self
            .shards[shard]
            .drives
            .pick(self.cfg.affinity, &tape_idx)
            .expect("dispatch_ready gates on a free drive");
        self.tick += 1;
        // A fresh cycle starts: whatever arm wait the drive's previous
        // cycle accumulated (trailing unmount included) is not this
        // batch's wait.
        self.shards[shard].arm_accum[drive_idx] = 0;
        // Exclusive-tapes bookkeeping: the cartridge this dispatch evicts
        // (released at evict-unmount completion), the acquisition of the
        // batch's own cartridge, and the per-batch cartridge-wait sample.
        let evicted_tape = if plan == MountPlan::EvictMount {
            self.shards[shard].drives.drive(drive_idx).loaded
        } else {
            None
        };
        // Under exclusivity the drive remembers its tape on every path so
        // the release paths know which cartridge to free; without it the
        // legacy `Affinity::None` behavior (never loaded) is preserved
        // byte for byte.
        let loaded = if self.cfg.affinity == Affinity::Lru || self.exclusive {
            Some(tape_idx)
        } else {
            None
        };
        self.shards[shard].drives.begin_cycle(drive_idx, loaded, self.tick, t_us);
        if self.exclusive {
            self.cartridge_wait.record_us(cw_us);
            self.shards[shard].cartridge_wait.record_us(cw_us);
            if let Some(ev) = evicted_tape {
                self.shards[shard].ledger.begin_evict(&ev);
            }
            self.shards[shard].ledger.acquire(&tape_idx, drive_idx);
            // The invariant the ledger exists for, cross-checked against
            // the drive pool itself in debug builds (tests run the full
            // scan; release replays rely on the ledger's own panic).
            if cfg!(debug_assertions) {
                self.shards[shard].drives.assert_exclusive(&tape_idx, drive_idx);
            }
        }

        if !self.pipeline {
            // Legacy fixed mount-cost path (plan is always `Mount` here:
            // no affinity, so drives never stay loaded).
            self.exec_batch(shard, drive_idx, &batch, &out, t_us, t_us, ready_us, dw_us, cw_us);
            let busy_s = self.cfg.drive.mount_s
                + self.cfg.drive.to_seconds(out.finish)
                + self.cfg.drive.unmount_s;
            let busy_us = secs_to_us(busy_s);
            self.stats.busy_drive_us += busy_us;
            self.shards[shard].stats.busy_drive_us += busy_us;
            self.shards[shard].drives.set_stage(drive_idx, DriveStage::Executing);
            self.events
                .push(t_us + busy_us, Ev::DriveFree { shard, drive: drive_idx });
            return;
        }

        // Event-driven mount pipeline.
        if plan == MountPlan::Hit {
            self.stats.remount_hits += 1;
            self.shards[shard].stats.remount_hits += 1;
        } else {
            self.stats.remount_misses += 1;
            self.shards[shard].stats.remount_misses += 1;
        }
        let pending = PendingExec { batch, out, t0_us: t_us, evicted_tape, ready_us, dw_us, cw_us };
        match plan {
            MountPlan::Hit => self.start_exec(shard, drive_idx, pending),
            MountPlan::Mount => {
                self.shards[shard].drives.set_stage(
                    drive_idx,
                    DriveStage::Mounting { pending, unmount_first: false },
                );
                self.request_arm(shard, drive_idx, self.cfg.drive.mount_us());
            }
            MountPlan::EvictMount => {
                self.shards[shard].drives.set_stage(
                    drive_idx,
                    DriveStage::Mounting { pending, unmount_first: true },
                );
                self.request_arm(shard, drive_idx, self.cfg.drive.unmount_us());
            }
        }
    }

    /// Start (or queue) one robot-arm operation for `drive`. Unconstrained
    /// pools (`n_arms == 0`) start every op immediately with zero wait.
    fn request_arm(&mut self, shard: usize, drive: usize, dur_us: u64) {
        let now = self.clock.now_us();
        if let Some(op) = self.shards[shard].arms.request(drive, dur_us, now) {
            self.arm_wait.record_us(op.wait_us);
            self.shards[shard].arm_wait.record_us(op.wait_us);
            self.shards[shard].arm_accum[op.drive] += op.wait_us;
            self.events.push(now + op.dur_us, Ev::ArmOpDone { shard, drive: op.drive });
        }
    }

    /// One arm op finished: free the arm, start the next queued op (FIFO),
    /// then advance the owning drive's pipeline stage.
    fn on_arm_op_done(&mut self, shard: usize, drive: usize) {
        let now = self.clock.now_us();
        if let Some(op) = self.shards[shard].arms.op_done(now) {
            self.arm_wait.record_us(op.wait_us);
            self.shards[shard].arm_wait.record_us(op.wait_us);
            self.shards[shard].arm_accum[op.drive] += op.wait_us;
            self.events
                .push(now + op.dur_us, Ev::ArmOpDone { shard, drive: op.drive });
        }
        let stage = self.shards[shard].drives.take_stage(drive);
        match stage {
            DriveStage::Mounting { mut pending, unmount_first: true } => {
                // Evict-unmount done: the evicted cartridge is back on its
                // shelf (waiters for it become dispatchable) and the mount
                // follows through the pool.
                if self.exclusive {
                    if let Some(ev) = pending.evicted_tape.take() {
                        self.shards[shard].ledger.release_unthreaded(&ev);
                    }
                }
                self.shards[shard].drives.set_stage(
                    drive,
                    DriveStage::Mounting { pending, unmount_first: false },
                );
                self.request_arm(shard, drive, self.cfg.drive.mount_us());
            }
            DriveStage::Mounting { pending, unmount_first: false } => {
                self.start_exec(shard, drive, pending);
            }
            DriveStage::Unloading => {
                // Trailing unmount finished: the drive is free again.
                self.finish_cycle(shard, drive);
            }
            other => unreachable!(
                "arm op completed for shard {shard} drive {drive} in stage {other:?}"
            ),
        }
    }

    /// The drive's mount pipeline is clear: record the pipeline latency,
    /// account every request of the batch, and run the schedule span.
    fn start_exec(&mut self, shard: usize, drive: usize, pending: PendingExec) {
        let now = self.clock.now_us();
        let PendingExec { batch, out, t0_us, ready_us, dw_us, cw_us, .. } = pending;
        let mount_delay_us = now - t0_us;
        self.mount_wait.record_us(mount_delay_us);
        self.shards[shard].mount_wait.record_us(mount_delay_us);
        self.shards[shard].drives.set_stage(drive, DriveStage::Executing);
        self.exec_batch(shard, drive, &batch, &out, t0_us, now, ready_us, dw_us, cw_us);
        let span_us = secs_to_us(self.cfg.drive.to_seconds(out.finish));
        self.events.push(now + span_us, Ev::ExecDone { shard, drive });
    }

    /// The head finished its schedule: under LRU affinity the tape stays
    /// threaded and the drive frees immediately (lazy unmount); otherwise
    /// the trailing unmount goes through the arm pool first.
    fn on_exec_done(&mut self, shard: usize, drive: usize) {
        match self.cfg.affinity {
            Affinity::Lru => self.finish_cycle(shard, drive),
            Affinity::None => {
                self.shards[shard].drives.set_stage(drive, DriveStage::Unloading);
                self.request_arm(shard, drive, self.cfg.drive.unmount_us());
            }
        }
    }

    /// End of a pipeline drive cycle: account the busy span and free the
    /// drive.
    fn finish_cycle(&mut self, shard: usize, drive: usize) {
        let now = self.clock.now_us();
        let busy_us = now - self.shards[shard].drives.drive(drive).cycle_start_us;
        self.stats.busy_drive_us += busy_us;
        self.shards[shard].stats.busy_drive_us += busy_us;
        self.release_drive(shard, drive);
    }

    /// Mark a drive idle again (both paths), handing its cartridge back
    /// to the ledger in exclusive-tapes mode: under LRU affinity the tape
    /// stays threaded (waiters dispatch as remount hits); otherwise it
    /// returned to the shelf with the cycle's trailing unmount.
    fn release_drive(&mut self, shard: usize, drive: usize) {
        if self.exclusive {
            if let Some(tape_idx) = self.shards[shard].drives.drive(drive).loaded {
                match self.cfg.affinity {
                    Affinity::Lru => self.shards[shard].ledger.release_threaded(&tape_idx),
                    Affinity::None => {
                        self.shards[shard].ledger.release_unthreaded(&tape_idx);
                        self.shards[shard].drives.drive_mut(drive).loaded = None;
                    }
                }
            }
        }
        self.shards[shard].drives.release(drive);
    }

    /// Account every request of a batch: completions at
    /// `exec_start + in-tape service`, with the mount component measured
    /// as `exec_start − dispatch` (the legacy path passes
    /// `exec_start == dispatch` and folds its fixed `mount_s` into the
    /// f64 service computation below, preserving its historical rounding
    /// byte for byte).
    #[allow(clippy::too_many_arguments)]
    fn exec_batch(
        &mut self,
        shard: usize,
        drive_idx: usize,
        batch: &Batch,
        out: &SimOutcome,
        t0_us: u64,
        exec_start_us: u64,
        ready_us: u64,
        dw_us: u64,
        // The cartridge wait is implied by the boundaries (`t0_us` is the
        // cartridge-grant instant); the explicit value is accepted for
        // call-site symmetry with `ready_us`/`dw_us`.
        _cw_us: u64,
    ) {
        let drive = self.cfg.drive;
        // Robot-arm wait accumulated by this drive's cycle so far — the
        // `arm_wait` span component (zeroed at dispatch, so it covers only
        // the mount-side waits of *this* batch, not the previous cycle's
        // trailing unmount).
        let arm_us = self.shards[shard].arm_accum[drive_idx];
        if !self.pipeline {
            // Per-request accounting through the same shared mapping the
            // coordinator drive worker uses (`Batch::request_service_times`)
            // — the legacy f64 sum `to_seconds(service) + mount_s`, rounded
            // once, exactly as before the pipeline existed.
            for (id, service_s) in batch.request_service_times(out, drive, drive.mount_s) {
                let service_us = secs_to_us(service_s);
                let done_us = t0_us + service_us;
                let (arrived_us, submitted_us) =
                    self.record_completion(shard, &batch.tape, id, service_us, done_us);
                if let Some(tr) = self.trace {
                    tr.record_chain(
                        id,
                        shard as u32,
                        drive_idx as u32,
                        &batch.tape,
                        [
                            arrived_us,
                            submitted_us,
                            submitted_us,
                            ready_us,
                            ready_us + dw_us,
                            t0_us,
                            t0_us + arm_us,
                            exec_start_us,
                            done_us,
                            done_us,
                        ],
                    );
                }
            }
        } else {
            // Pipeline accounting: the measured mount delay (arm waits +
            // robot ops, 0 on a remount hit) plus the in-tape component on
            // the µs grid (`Batch::request_service_times_us`).
            let mount_delay_us = exec_start_us - t0_us;
            for (id, service_us) in batch.request_service_times_us(out, drive, mount_delay_us) {
                let done_us = t0_us + service_us;
                let (arrived_us, submitted_us) =
                    self.record_completion(shard, &batch.tape, id, service_us, done_us);
                if let Some(tr) = self.trace {
                    tr.record_chain(
                        id,
                        shard as u32,
                        drive_idx as u32,
                        &batch.tape,
                        [
                            arrived_us,
                            submitted_us,
                            submitted_us,
                            ready_us,
                            ready_us + dw_us,
                            t0_us,
                            t0_us + arm_us,
                            exec_start_us,
                            done_us,
                            done_us,
                        ],
                    );
                }
            }
        }
    }

    /// Record one served request on the fleet and shard ledgers, emit its
    /// completion-log entry, and release its closed-loop slot. Returns the
    /// request's `(arrived_us, submitted_us)` pair so the caller can stamp
    /// its trace chain without a second `pending` lookup.
    fn record_completion(
        &mut self,
        shard: usize,
        tape: &str,
        id: u64,
        service_us: u64,
        done_us: u64,
    ) -> (u64, u64) {
        let (arrived_us, submitted_us) =
            self.pending.remove(&id).expect("completion for unsubmitted id");
        let latency_us = done_us - arrived_us;
        self.latency.record_us(latency_us);
        self.service.record_us(service_us);
        self.stats.completed += 1;
        self.stats.makespan_us = self.stats.makespan_us.max(done_us);
        let sh = &mut self.shards[shard];
        sh.latency.record_us(latency_us);
        sh.service.record_us(service_us);
        sh.stats.completed += 1;
        sh.stats.makespan_us = sh.stats.makespan_us.max(done_us);
        self.completions.push(ReplayCompletion {
            id,
            tape: tape.to_string(),
            arrived_us,
            submitted_us,
            done_us,
            latency_us,
            service_us,
        });
        self.events.push(done_us, Ev::Slot);
        (arrived_us, submitted_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::arrivals::{PoissonArrivals, RequestMix};
    use crate::sched::{Gs, SimpleDp};
    use std::time::Duration;

    fn catalog() -> Vec<Tape> {
        vec![
            Tape::from_sizes("T0", &[1_000; 60]),
            Tape::from_sizes("T1", &[500; 120]),
            Tape::from_sizes("T2", &[2_000; 30]),
        ]
    }

    fn fast_drive() -> DriveParams {
        DriveParams {
            mount_s: 1.0,
            unmount_s: 0.5,
            bytes_per_s: 1e6,
            uturn_s: 0.001,
            n_arms: 0,
        }
    }

    fn cfg(mode: LoopMode) -> ReplayConfig {
        ReplayConfig {
            n_drives: 3,
            batcher: BatcherConfig {
                window: Duration::from_millis(200),
                max_batch: 64,
                ..BatcherConfig::default()
            },
            drive: fast_drive(),
            mode,
            retry_backoff_s: 0.05,
            ..ReplayConfig::default()
        }
    }

    fn poisson(rate: f64, horizon: f64, seed: u64) -> PoissonArrivals {
        PoissonArrivals::new(RequestMix::new(&catalog()), rate, horizon, seed)
    }

    #[test]
    fn serves_every_arrival_and_is_deterministic() {
        let run = || {
            let mut model = poisson(40.0, 10.0, 9);
            simulate(&cfg(LoopMode::Open), &catalog(), &SimpleDp, &mut model)
        };
        let a = run();
        let b = run();
        assert!(a.stats.submitted > 200, "expected ~400 arrivals");
        assert_eq!(a.stats.completed, a.stats.submitted);
        assert_eq!(a.stats.shed, 0);
        assert_eq!(a.completions, b.completions, "same seed ⇒ identical log");
        assert_eq!(a.latency, b.latency);
        assert_eq!(a.stats.completed, b.stats.completed);
        // Completion ids are exactly the submitted ids.
        let mut ids: Vec<u64> = a.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..a.stats.submitted).collect::<Vec<_>>());
        // Latency decomposes sanely: measured from client arrival, which in
        // open loop coincides with batcher acceptance.
        for c in &a.completions {
            assert_eq!(c.done_us - c.arrived_us, c.latency_us);
            assert_eq!(c.arrived_us, c.submitted_us, "open loop never delays submit");
            assert!(c.latency_us >= c.service_us);
        }
        assert_eq!(a.stats.makespan_us, a.completions.last().unwrap().done_us);
    }

    #[test]
    fn virtual_time_decouples_from_wall_time() {
        // 10 virtual minutes of traffic; the replay itself must be fast.
        let wall = Instant::now();
        let mut model = poisson(20.0, 600.0, 4);
        let out = simulate(&cfg(LoopMode::Open), &catalog(), &Gs, &mut model);
        assert!(out.stats.completed > 5_000, "got {}", out.stats.completed);
        assert!(out.stats.makespan_us > 500_000_000, "makespan is virtual");
        assert!(
            wall.elapsed().as_secs_f64() < 30.0,
            "replay must run at CPU speed"
        );
    }

    #[test]
    fn open_loop_sheds_on_busy() {
        let mut config = cfg(LoopMode::Open);
        config.batcher.max_tape_backlog = 4;
        config.n_drives = 1;
        // One hot tape saturates instantly at this rate.
        let catalog = vec![Tape::from_sizes("HOT", &[1_000; 50])];
        let mut model =
            PoissonArrivals::new(RequestMix::new(&catalog), 200.0, 5.0, 1);
        let out = simulate(&config, &catalog, &Gs, &mut model);
        assert!(out.stats.shed > 0, "backlog 4 at 200 rps must shed");
        assert_eq!(out.stats.shed, out.stats.busy_rejections);
        assert_eq!(out.stats.completed, out.stats.submitted);
        assert_eq!(out.stats.retries, 0);
    }

    #[test]
    fn closed_loop_retries_busy_and_respects_cap() {
        let cap = 8;
        let mut config = cfg(LoopMode::Closed { max_in_flight: cap });
        config.batcher.max_tape_backlog = 4;
        config.n_drives = 1;
        let catalog = vec![Tape::from_sizes("HOT", &[1_000; 50])];
        let mut model =
            PoissonArrivals::new(RequestMix::new(&catalog), 200.0, 5.0, 1);
        let out = simulate(&config, &catalog, &Gs, &mut model);
        assert!(out.stats.busy_rejections > 0, "backlog 4 under cap 8 must reject");
        assert!(out.stats.retries >= out.stats.busy_rejections);
        assert_eq!(out.stats.shed, 0, "closed loop never sheds");
        assert_eq!(out.stats.completed, out.stats.submitted);
        // Reconstruct the in-flight level over time from the completion
        // log: it must never exceed the cap.
        let mut edges: Vec<(u64, i64)> = Vec::new();
        for c in &out.completions {
            edges.push((c.submitted_us, 1));
            edges.push((c.done_us, -1));
        }
        // At equal times, completions free slots before submissions claim.
        edges.sort_by_key(|&(t, d)| (t, d));
        let (mut level, mut peak) = (0i64, 0i64);
        for (_, d) in edges {
            level += d;
            peak = peak.max(level);
        }
        assert!(peak <= cap as i64, "in-flight peaked at {peak} > cap {cap}");
        assert!(peak >= 2, "the hot tape should queue more than one request");
        // Latency is measured from client arrival: queued/retried requests
        // must show the client-side wait, not hide it.
        assert!(out.completions.iter().all(|c| c.submitted_us >= c.arrived_us));
        assert!(
            out.completions.iter().any(|c| c.submitted_us > c.arrived_us),
            "a saturated closed loop must delay some submissions client-side"
        );
    }

    #[test]
    fn batching_coalesces_and_better_policy_serves_faster() {
        // A long window coalesces each tape's burst into one batch.
        let mut config = cfg(LoopMode::Open);
        config.batcher.window = Duration::from_secs(30);
        let run = |policy: &dyn Scheduler| {
            let mut model = poisson(30.0, 20.0, 12);
            simulate(&config, &catalog(), policy, &mut model)
        };
        let gs = run(&Gs);
        let sdp = run(&SimpleDp);
        assert_eq!(gs.stats.completed, sdp.stats.completed);
        assert!(
            gs.stats.batches * 10 <= gs.stats.completed,
            "window must coalesce ≥10 requests/batch: {} batches for {}",
            gs.stats.batches,
            gs.stats.completed
        );
        // Batch composition is policy-independent (arrivals + batcher only),
        // and GS's atomic detours are a feasible disjoint-detour schedule,
        // so the disjoint-detour optimum can't serve slower (tolerance: µs
        // rounding of per-request service times).
        assert!(
            sdp.service.mean_s() <= gs.service.mean_s() + 1e-5,
            "SimpleDP {} vs GS {}",
            sdp.service.mean_s(),
            gs.service.mean_s()
        );
    }

    #[test]
    fn single_shard_outcome_mirrors_the_fleet() {
        // n_shards = 1 IS the single-library replay: the one shard entry
        // must reproduce the fleet totals and distributions exactly.
        let mut model = poisson(40.0, 10.0, 9);
        let out = simulate(&cfg(LoopMode::Open), &catalog(), &SimpleDp, &mut model);
        assert_eq!(out.per_shard.len(), 1);
        let s = &out.per_shard[0];
        assert_eq!(s.shard, 0);
        assert_eq!(s.n_tapes, 3);
        assert!((s.ring_share - 1.0).abs() < 1e-12);
        assert_eq!(s.stats.submitted, out.stats.submitted);
        assert_eq!(s.stats.completed, out.stats.completed);
        assert_eq!(s.stats.batches, out.stats.batches);
        assert_eq!(s.stats.makespan_us, out.stats.makespan_us);
        assert_eq!(s.stats.busy_drive_us, out.stats.busy_drive_us);
        assert_eq!(s.latency, out.latency);
        assert_eq!(s.service, out.service);
    }

    #[test]
    fn sharded_replay_partitions_and_reconciles() {
        // A wider catalog so several shards own tapes.
        let catalog: Vec<Tape> = (0..24)
            .map(|i| Tape::from_sizes(format!("TAPE{i:03}"), &[1_000; 40]))
            .collect();
        let mut config = cfg(LoopMode::Open);
        config.n_shards = 4;
        config.vnodes = 64;
        let run = || {
            let mut model =
                PoissonArrivals::new(RequestMix::new(&catalog), 60.0, 10.0, 5);
            simulate(&config, &catalog, &Gs, &mut model)
        };
        let a = run();
        let b = run();
        assert_eq!(a.completions, b.completions, "sharded replay stays deterministic");
        assert_eq!(a.per_shard.len(), 4);
        // Every catalog tape is owned by exactly one shard.
        assert_eq!(a.per_shard.iter().map(|s| s.n_tapes).sum::<usize>(), 24);
        let share: f64 = a.per_shard.iter().map(|s| s.ring_share).sum();
        assert!((share - 1.0).abs() < 1e-9, "ring shares sum to {share}");
        // Per-shard counters reconcile with the fleet totals.
        let sum = |f: fn(&ReplayStats) -> u64| -> u64 {
            a.per_shard.iter().map(|s| f(&s.stats)).sum()
        };
        assert_eq!(sum(|s| s.submitted), a.stats.submitted);
        assert_eq!(sum(|s| s.completed), a.stats.completed);
        assert_eq!(sum(|s| s.batches), a.stats.batches);
        assert_eq!(sum(|s| s.shed), a.stats.shed);
        assert_eq!(sum(|s| s.busy_drive_us), a.stats.busy_drive_us);
        assert_eq!(
            a.per_shard.iter().map(|s| s.latency.count()).sum::<u64>(),
            a.latency.count()
        );
        assert_eq!(
            a.per_shard.iter().map(|s| s.stats.makespan_us).max().unwrap(),
            a.stats.makespan_us
        );
        // With 24 tapes over 4 shards, more than one library must own
        // tapes and serve traffic (the routing actually spreads).
        let active = a.per_shard.iter().filter(|s| s.stats.completed > 0).count();
        assert!(active >= 2, "only {active} shard(s) served anything");
        assert_eq!(a.stats.completed, a.stats.submitted);
    }

    /// Field-by-field equality of the deterministic parts of two
    /// outcomes — everything the QoS report serializes (`sched_wall_s`,
    /// the wall-clock diagnostic, is deliberately excluded).
    fn assert_outcomes_identical(a: &ReplayOutcome, b: &ReplayOutcome, ctx: &str) {
        let same_stats = |x: &ReplayStats, y: &ReplayStats, where_: &str| {
            assert_eq!(x.submitted, y.submitted, "{where_}: submitted");
            assert_eq!(x.completed, y.completed, "{where_}: completed");
            assert_eq!(x.shed, y.shed, "{where_}: shed");
            assert_eq!(x.busy_rejections, y.busy_rejections, "{where_}: busy_rejections");
            assert_eq!(x.retries, y.retries, "{where_}: retries");
            assert_eq!(x.batches, y.batches, "{where_}: batches");
            assert_eq!(x.makespan_us, y.makespan_us, "{where_}: makespan_us");
            assert_eq!(x.busy_drive_us, y.busy_drive_us, "{where_}: busy_drive_us");
            assert_eq!(x.remount_hits, y.remount_hits, "{where_}: remount_hits");
            assert_eq!(x.remount_misses, y.remount_misses, "{where_}: remount_misses");
            assert_eq!(x.cartridge_parks, y.cartridge_parks, "{where_}: cartridge_parks");
        };
        assert_eq!(a.completions, b.completions, "{ctx}: completion log");
        same_stats(&a.stats, &b.stats, ctx);
        assert_eq!(a.latency, b.latency, "{ctx}: latency");
        assert_eq!(a.service, b.service, "{ctx}: service");
        assert_eq!(a.arm_wait, b.arm_wait, "{ctx}: arm_wait");
        assert_eq!(a.mount_wait, b.mount_wait, "{ctx}: mount_wait");
        assert_eq!(a.drive_wait, b.drive_wait, "{ctx}: drive_wait");
        assert_eq!(a.cartridge_wait, b.cartridge_wait, "{ctx}: cartridge_wait");
        assert_eq!(a.per_shard.len(), b.per_shard.len(), "{ctx}: shard count");
        for (x, y) in a.per_shard.iter().zip(&b.per_shard) {
            let w = format!("{ctx}: shard {}", x.shard);
            assert_eq!(x.shard, y.shard, "{w}: id");
            assert_eq!(x.n_tapes, y.n_tapes, "{w}: n_tapes");
            assert_eq!(x.ring_share, y.ring_share, "{w}: ring_share");
            same_stats(&x.stats, &y.stats, &w);
            assert_eq!(x.latency, y.latency, "{w}: latency");
            assert_eq!(x.service, y.service, "{w}: service");
            assert_eq!(x.arm_wait, y.arm_wait, "{w}: arm_wait");
            assert_eq!(x.mount_wait, y.mount_wait, "{w}: mount_wait");
            assert_eq!(x.drive_wait, y.drive_wait, "{w}: drive_wait");
            assert_eq!(x.cartridge_wait, y.cartridge_wait, "{w}: cartridge_wait");
        }
    }

    #[test]
    fn parallel_replay_is_byte_identical_to_single_threaded() {
        // 24 tapes over 4 shards, enough traffic that several shards
        // shed, batch, and complete work — then every thread count must
        // reproduce the single-threaded outcome exactly, down to each
        // histogram bucket and per-shard counter.
        let catalog: Vec<Tape> = (0..24)
            .map(|i| Tape::from_sizes(format!("TAPE{i:03}"), &[1_000; 40]))
            .collect();
        let mut config = cfg(LoopMode::Open);
        config.n_shards = 4;
        config.vnodes = 64;
        let make_model = || -> Box<dyn ArrivalModel> {
            Box::new(PoissonArrivals::new(RequestMix::new(&catalog), 60.0, 10.0, 5))
        };
        let single = simulate(&config, &catalog, &Gs, make_model().as_mut());
        assert!(single.stats.completed > 300, "workload too small to be probative");
        for threads in [2, 3, 4, 9] {
            let par = simulate_parallel(&config, &catalog, &Gs, &make_model, threads);
            assert_outcomes_identical(&single, &par, &format!("threads={threads}"));
        }
    }

    #[test]
    fn parallel_replay_exercises_the_pipeline_and_exclusivity_paths() {
        // Same identity under the mount pipeline (LRU affinity + a
        // constrained arm pool) where remount hits, arm waits, and
        // cartridge parks are all live.
        let catalog: Vec<Tape> = (0..12)
            .map(|i| Tape::from_sizes(format!("TAPE{i:03}"), &[1_000; 40]))
            .collect();
        let mut config = cfg(LoopMode::Open);
        config.n_shards = 3;
        config.drive.n_arms = 1;
        config.affinity = Affinity::Lru;
        let make_model = || -> Box<dyn ArrivalModel> {
            Box::new(PoissonArrivals::new(RequestMix::new(&catalog), 50.0, 8.0, 11))
        };
        let single = simulate(&config, &catalog, &SimpleDp, make_model().as_mut());
        assert!(
            single.stats.remount_hits > 0 && single.stats.remount_misses > 0,
            "pipeline paths not exercised"
        );
        let par = simulate_parallel(&config, &catalog, &SimpleDp, &make_model, 3);
        assert_outcomes_identical(&single, &par, "pipeline threads=3");
    }

    /// Build a deliberately skewed catalog: `hot_tapes` tapes routing to
    /// shard `hot` plus exactly one tape on each shard in `colds`, found
    /// by scanning candidate names through the same ring the engine
    /// builds. All other shards stay empty — the hot shard carries the
    /// overwhelming share of the ring.
    fn skewed_catalog(
        n_shards: usize,
        vnodes: usize,
        hot: usize,
        colds: &[usize],
        hot_tapes: usize,
    ) -> Vec<Tape> {
        let ring = HashRing::new(n_shards, vnodes);
        let mut tapes = Vec::new();
        let mut hot_found = 0usize;
        let mut cold_found = vec![false; colds.len()];
        let mut i = 0usize;
        while hot_found < hot_tapes || cold_found.iter().any(|&c| !c) {
            let name = format!("SKEW{i:05}");
            let s = ring.route(&name);
            if s == hot && hot_found < hot_tapes {
                tapes.push(Tape::from_sizes(name, &[1_000; 40]));
                hot_found += 1;
            } else if let Some(k) = colds.iter().position(|&c| c == s) {
                if !cold_found[k] {
                    tapes.push(Tape::from_sizes(name, &[1_000; 40]));
                    cold_found[k] = true;
                }
            }
            i += 1;
            assert!(i < 200_000, "ring never routed a candidate to the target shards");
        }
        tapes
    }

    #[test]
    fn skewed_ring_replay_is_byte_identical_across_assign_modes() {
        // One hot shard holding 90% of the tapes (18 of 20), the rest on
        // a single cold shard whose id collides with the hot worker under
        // both `threads % 2` and `threads % 3` masks — the worst case for
        // round-robin. Every (threads, mode) combination must still
        // reproduce the single-threaded outcome byte for byte: ownership
        // is a pure function of the seeded pre-pass, never of timing.
        let mut config = cfg(LoopMode::Open);
        config.n_shards = 9;
        config.vnodes = 64;
        let catalog = skewed_catalog(config.n_shards, config.vnodes, 0, &[6], 18);
        assert_eq!(catalog.len(), 20);
        let make_model = || -> Box<dyn ArrivalModel> {
            Box::new(PoissonArrivals::new(RequestMix::new(&catalog), 60.0, 10.0, 7))
        };
        let single = simulate(&config, &catalog, &Gs, make_model().as_mut());
        assert!(single.stats.completed > 300, "workload too small to be probative");
        for threads in [2, 3, 9] {
            for mode in [AssignMode::RoundRobin, AssignMode::Weighted, AssignMode::Stolen] {
                let (par, balance) = simulate_parallel_balanced(
                    &config, &catalog, &Gs, &make_model, threads, mode,
                );
                assert_outcomes_identical(
                    &single,
                    &par,
                    &format!("threads={threads} mode={mode:?}"),
                );
                assert_eq!(balance.assignment.len(), config.n_shards);
                assert_eq!(balance.worker_busy_us.len(), threads);
                assert_eq!(
                    balance.worker_busy_us.iter().sum::<u64>(),
                    single.stats.busy_drive_us,
                    "worker busy times must partition the fleet total"
                );
                if mode != AssignMode::Stolen {
                    assert_eq!(balance.steal_events, 0, "steals only happen under --steal");
                }
            }
        }
    }

    #[test]
    fn weighted_and_stolen_strictly_beat_round_robin_on_a_hot_shard() {
        // Geometry chosen so round-robin piles the hot shard *and* both
        // cold shards onto worker 0 (cold ids ≡ hot id modulo the thread
        // count), leaving the other workers fully idle: busy ratio ∞.
        // The weight-aware assignments must split the work — a finite
        // ratio — and the stolen re-pack must record the moves it made.
        for (threads, colds) in [(2usize, [2usize, 4]), (3, [3, 6])] {
            let mut config = cfg(LoopMode::Open);
            config.n_shards = 9;
            config.vnodes = 64;
            let catalog = skewed_catalog(config.n_shards, config.vnodes, 0, &colds, 18);
            let make_model = || -> Box<dyn ArrivalModel> {
                Box::new(PoissonArrivals::new(RequestMix::new(&catalog), 60.0, 10.0, 7))
            };
            let run = |mode| {
                simulate_parallel_balanced(&config, &catalog, &Gs, &make_model, threads, mode)
            };
            let (out_rr, rr) = run(AssignMode::RoundRobin);
            let (out_w, weighted) = run(AssignMode::Weighted);
            let (out_s, stolen) = run(AssignMode::Stolen);
            assert_outcomes_identical(&out_rr, &out_w, &format!("t={threads} rr vs weighted"));
            assert_outcomes_identical(&out_rr, &out_s, &format!("t={threads} rr vs stolen"));
            assert!(
                rr.busy_ratio().is_infinite(),
                "t={threads}: round-robin should idle a worker on this geometry"
            );
            for (name, b) in [("weighted", &weighted), ("stolen", &stolen)] {
                assert!(
                    b.busy_ratio().is_finite(),
                    "t={threads} {name}: every worker should get busy shards"
                );
                assert!(
                    b.busy_ratio() < rr.busy_ratio(),
                    "t={threads} {name}: busy ratio must strictly improve on round-robin"
                );
            }
            assert!(
                stolen.steal_events > 0,
                "t={threads}: the re-pack must record its moves"
            );
            assert!(!weighted.shard_weights.is_empty() && !stolen.shard_weights.is_empty());
        }
        // At threads == n_shards every worker owns exactly one shard in
        // every scheme — no move can lower the max, so stealing is a
        // recorded no-op and the ratio can only tie round-robin's.
        let mut config = cfg(LoopMode::Open);
        config.n_shards = 9;
        config.vnodes = 64;
        let catalog = skewed_catalog(config.n_shards, config.vnodes, 0, &[6], 18);
        let make_model = || -> Box<dyn ArrivalModel> {
            Box::new(PoissonArrivals::new(RequestMix::new(&catalog), 60.0, 10.0, 7))
        };
        let (_, rr) =
            simulate_parallel_balanced(&config, &catalog, &Gs, &make_model, 9, AssignMode::RoundRobin);
        let (_, stolen) =
            simulate_parallel_balanced(&config, &catalog, &Gs, &make_model, 9, AssignMode::Stolen);
        assert_eq!(stolen.steal_events, 0);
        assert!(stolen.busy_ratio() <= rr.busy_ratio() || stolen.busy_ratio().is_infinite());
    }

    #[test]
    fn lpt_and_steal_assignments_are_deterministic_functions_of_weights() {
        // Pure-arithmetic sanity on the packers themselves, no replay:
        // LPT puts the heavy shard alone and balances the rest with
        // lowest-index tie-breaks; the steal refinement repairs the
        // round-robin pile-up and counts exactly its accepted moves.
        let weights = [100u64, 10, 10, 10, 0];
        let a = lpt_assignment(&weights, 2);
        assert_eq!(a, vec![0, 1, 1, 1, 1]);
        let mut rr = round_robin_assignment(5, 2);
        assert_eq!(rr, vec![0, 1, 0, 1, 0]);
        // All weight in one epoch: shard 0 (100) + shards 2 (10) and
        // 4 (0) start on worker 0 (load 110) vs worker 1 (load 20);
        // moving shard 2 to worker 1 lowers the max (110 → 100), then no
        // further move helps.
        let epochs: Vec<Vec<u64>> = weights.iter().map(|&w| {
            let mut b = vec![0u64; STEAL_EPOCHS];
            b[0] = w;
            b
        }).collect();
        let steals = steal_refine(&epochs, 2, &mut rr);
        assert_eq!(steals, 1);
        assert_eq!(rr, vec![0, 1, 1, 1, 0]);
        let busy = worker_busy_us(&[0, 1, 1], 2, &[]);
        assert_eq!(busy, vec![0, 0]);
        assert_eq!(busy_ratio(&[0, 0]), 1.0);
        assert!(busy_ratio(&[5, 0]).is_infinite());
        assert!((busy_ratio(&[10, 5]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "open-loop")]
    fn parallel_replay_rejects_closed_loop() {
        let catalog = catalog();
        let make_model = || -> Box<dyn ArrivalModel> { Box::new(poisson(10.0, 1.0, 1)) };
        let mut config = cfg(LoopMode::Closed { max_in_flight: 4 });
        config.n_shards = 2;
        simulate_parallel(&config, &catalog, &Gs, &make_model, 2);
    }

    #[test]
    fn arena_reuse_across_policies_is_invisible() {
        // A multi-policy run through one arena must reproduce the
        // fresh-buffer outcomes byte for byte, while actually recycling
        // (the second run draws its histograms from the pool).
        let mut config = cfg(LoopMode::Open);
        config.n_shards = 2;
        let run_fresh = |policy: &dyn Scheduler| {
            let mut model = poisson(40.0, 6.0, 21);
            simulate(&config, &catalog(), policy, &mut model)
        };
        let fresh_gs = run_fresh(&Gs);
        let fresh_sdp = run_fresh(&SimpleDp);
        let mut arena = ReplayArena::new();
        let mut model = poisson(40.0, 6.0, 21);
        let pooled_gs = simulate_with_arena(&config, &catalog(), &Gs, &mut model, &mut arena);
        assert_outcomes_identical(&fresh_gs, &pooled_gs, "arena first run");
        arena.recycle(pooled_gs);
        // Fleet + 2 shards × 6 histograms each are now pooled.
        assert_eq!(arena.pooled_histograms(), 18);
        let mut model = poisson(40.0, 6.0, 21);
        let pooled_sdp =
            simulate_with_arena(&config, &catalog(), &SimpleDp, &mut model, &mut arena);
        assert_eq!(arena.pooled_histograms(), 0, "the run must draw from the pool");
        assert_outcomes_identical(&fresh_sdp, &pooled_sdp, "arena second run");
        arena.recycle(pooled_sdp);
        assert_eq!(arena.pooled_histograms(), 18);
    }

    #[test]
    fn legacy_path_stays_clean_of_pipeline_artifacts() {
        // The default configuration (no arms, no affinity) is the legacy
        // fixed mount-cost model: no remount accounting, no mount-pipeline
        // samples — the byte-compatibility surface of the pipeline change.
        let config = cfg(LoopMode::Open);
        assert!(!config.pipeline_active());
        let mut model = poisson(40.0, 10.0, 9);
        let out = simulate(&config, &catalog(), &SimpleDp, &mut model);
        assert_eq!(out.stats.remount_hits, 0);
        assert_eq!(out.stats.remount_misses, 0);
        assert_eq!(out.mount_wait.count(), 0, "no pipeline, no mount-wait samples");
        assert_eq!(out.arm_wait.count(), 0);
        // Drive waits are recorded on both paths: one sample per batch.
        assert_eq!(out.drive_wait.count(), out.stats.batches);
        assert_eq!(out.per_shard[0].drive_wait, out.drive_wait);
        // Exclusivity (on by default) records one cartridge-wait sample
        // per batch without touching the pipeline artifacts above.
        assert_eq!(out.cartridge_wait.count(), out.stats.batches);
        assert_eq!(out.per_shard[0].cartridge_wait, out.cartridge_wait);
    }

    #[test]
    fn cartridge_exclusivity_serializes_a_hot_tape() {
        // One hot tape, many drives, single-request batches: without the
        // single-cartridge constraint every batch mounts its own "copy"
        // and runs in parallel; with it they serialize through one drive
        // cycle at a time — the head-of-line effect the ledger exists to
        // surface.
        let catalog = vec![Tape::from_sizes("HOT", &[1_000; 50])];
        let run = |exclusive: bool| {
            let mut config = cfg(LoopMode::Open);
            config.exclusive_tapes = exclusive;
            config.n_drives = 8;
            config.batcher.max_batch = 1;
            let mut model =
                PoissonArrivals::new(RequestMix::new(&catalog), 10.0, 3.0, 11);
            simulate(&config, &catalog, &Gs, &mut model)
        };
        let on = run(true);
        let off = run(false);
        assert_eq!(on.stats.completed, off.stats.completed, "nothing may be lost");
        assert_eq!(off.stats.cartridge_parks, 0, "off = the PR 4 model");
        assert_eq!(off.cartridge_wait.count(), 0, "off records no samples");
        assert!(
            on.stats.cartridge_parks > 0,
            "single-request batches on one tape must collide on the cartridge"
        );
        assert_eq!(on.cartridge_wait.count(), on.stats.batches);
        assert!(on.cartridge_wait.max_s() > 0.0, "parked batches must wait");
        assert!(
            on.latency.quantile(99.9) > off.latency.quantile(99.9),
            "exclusivity p99.9 {} must exceed the unconstrained {}",
            on.latency.quantile(99.9),
            off.latency.quantile(99.9)
        );
        assert!(
            on.stats.makespan_us > off.stats.makespan_us,
            "serialized cartridge cycles must stretch the drain"
        );
        // Deterministic, like every other replay path.
        let again = run(true);
        assert_eq!(on.completions, again.completions);
        assert_eq!(on.cartridge_wait, again.cartridge_wait);
        assert_eq!(on.stats.cartridge_parks, again.stats.cartridge_parks);
    }

    #[test]
    fn exclusivity_without_contention_changes_nothing() {
        // A single drive makes parking structurally impossible on the
        // legacy path: batches pop only when the drive is free, and a
        // free drive means every cartridge is back on its shelf (the
        // DriveFree event releases it before the dispatch pass runs). The
        // exclusive run must therefore reproduce the non-exclusive
        // completion log and histograms exactly — its only trace is the
        // all-zero cartridge_wait ladder.
        let run = |exclusive: bool| {
            let mut config = cfg(LoopMode::Open);
            config.exclusive_tapes = exclusive;
            config.n_drives = 1;
            let mut model = poisson(20.0, 5.0, 3);
            simulate(&config, &catalog(), &SimpleDp, &mut model)
        };
        let on = run(true);
        let off = run(false);
        assert_eq!(on.stats.cartridge_parks, 0, "one drive cannot contend a cartridge");
        assert_eq!(on.completions, off.completions);
        assert_eq!(on.latency, off.latency);
        assert_eq!(on.service, off.service);
        assert_eq!(on.drive_wait, off.drive_wait, "no parks ⇒ identical drive waits");
        assert_eq!(on.stats.makespan_us, off.stats.makespan_us);
        assert_eq!(on.cartridge_wait.count(), on.stats.batches);
        assert_eq!(off.cartridge_wait.count(), 0);
    }

    #[test]
    fn exclusivity_composes_with_the_mount_pipeline() {
        // LRU affinity + a bounded arm pool + exclusivity: hot batches
        // park while their cartridge mounts, then land as remount hits on
        // the holding drive; the ledger, pool, and pipeline reconcile.
        let catalog = vec![
            Tape::from_sizes("HOT", &[1_000; 50]),
            Tape::from_sizes("WARM", &[2_000; 25]),
        ];
        let run = || {
            let mut config = cfg(LoopMode::Open);
            config.n_drives = 4;
            config.batcher.max_batch = 2;
            config.drive.n_arms = 1;
            config.affinity = Affinity::Lru;
            assert!(config.exclusive_tapes, "exclusivity is the default");
            let mut model =
                PoissonArrivals::new(RequestMix::new(&catalog), 20.0, 3.0, 7);
            simulate(&config, &catalog, &Gs, &mut model)
        };
        let a = run();
        let b = run();
        assert_eq!(a.completions, b.completions, "pipeline + ledger stays deterministic");
        assert_eq!(a.stats.completed, a.stats.submitted);
        assert_eq!(a.stats.remount_hits + a.stats.remount_misses, a.stats.batches);
        assert_eq!(a.mount_wait.count(), a.stats.batches);
        assert_eq!(a.cartridge_wait.count(), a.stats.batches);
        // With exclusivity a tape's batches can only land where it is
        // threaded: every batch after a tape's first mount is a remount
        // hit (no eviction pressure with 4 drives / 2 tapes), so misses
        // are bounded by the tape count — never one per batch.
        assert!(
            (1..=2).contains(&a.stats.remount_misses),
            "one mount per active tape, got {}",
            a.stats.remount_misses
        );
        assert!(a.stats.cartridge_parks > 0, "hot batches must park while mounting");
    }

    #[test]
    fn tracing_emits_full_chains_without_perturbing_the_replay() {
        use crate::obs::{check_chains, parse_jsonl, Stage, TraceRecorder};
        use std::collections::BTreeMap;
        // The full pipeline — LRU affinity, a contended arm pool,
        // exclusivity — so every stage of the taxonomy can be non-zero.
        let catalog = vec![
            Tape::from_sizes("HOT", &[1_000; 50]),
            Tape::from_sizes("WARM", &[2_000; 25]),
        ];
        let run = |trace: Option<&TraceRecorder>| {
            let mut config = cfg(LoopMode::Open);
            config.n_drives = 4;
            config.batcher.max_batch = 2;
            config.drive.n_arms = 1;
            config.affinity = Affinity::Lru;
            let mut model =
                PoissonArrivals::new(RequestMix::new(&catalog), 20.0, 3.0, 7);
            simulate_traced(&config, &catalog, &Gs, &mut model, trace)
        };
        let rec = TraceRecorder::new(1 << 16);
        let traced = run(Some(&rec));
        let plain = run(None);
        // The recorder is a pure observer: the outcome is byte-identical.
        assert_eq!(traced.completions, plain.completions);
        assert_eq!(traced.latency, plain.latency);
        assert_eq!(traced.stats.makespan_us, plain.stats.makespan_us);
        // One full chain per completion, and it survives the JSONL
        // round-trip the `spans` subcommand consumes.
        let completed = traced.stats.completed as usize;
        assert_eq!(rec.len(), Stage::CHAIN.len() * completed);
        assert_eq!(rec.dropped(), 0);
        let mut buf = Vec::new();
        rec.write_jsonl(&mut buf).unwrap();
        let parsed = parse_jsonl(std::str::from_utf8(&buf).unwrap());
        assert_eq!(check_chains(&parsed), Ok(completed));
        // Stage durations tile the measured latency exactly: the chain is
        // contiguous from arrival to completion.
        let mut span_sum: BTreeMap<u64, u64> = BTreeMap::new();
        for s in &parsed {
            *span_sum.entry(s.request_id).or_default() += s.t_end_us - s.t_start_us;
        }
        for c in &traced.completions {
            assert_eq!(span_sum[&c.id], c.latency_us, "request {}", c.id);
        }
    }

    #[test]
    fn lru_affinity_hits_skip_the_mount() {
        // One tape, one drive, cap-split batches (the cap pins batch
        // composition regardless of placement policy): under LRU affinity
        // only the first batch mounts; the rest land on the loaded drive.
        let catalog = vec![Tape::from_sizes("HOT", &[1_000; 50])];
        let run = |affinity: Affinity| {
            let mut config = cfg(LoopMode::Open);
            config.n_drives = 1;
            config.batcher.window = Duration::from_secs(3600);
            config.batcher.max_batch = 4;
            config.affinity = affinity;
            let mut model =
                PoissonArrivals::new(RequestMix::new(&catalog), 40.0, 2.0, 11);
            simulate(&config, &catalog, &Gs, &mut model)
        };
        let lru = run(Affinity::Lru);
        assert!(lru.stats.batches >= 4, "cap 4 must split the burst");
        assert_eq!(lru.stats.remount_misses, 1, "only the first batch mounts");
        assert_eq!(
            lru.stats.remount_hits,
            lru.stats.batches - 1,
            "every later batch lands on the loaded drive"
        );
        assert_eq!(lru.mount_wait.count(), lru.stats.batches);
        // A remount hit's pipeline latency is zero; a miss pays mount_s.
        assert_eq!(lru.mount_wait.quantile(50.0), 0.0);
        assert!((lru.mount_wait.max_s() - 1.0).abs() < 1e-6);

        let none = run(Affinity::None);
        // Affinity off + no arms = the legacy path: no remount accounting.
        assert_eq!(none.stats.remount_hits, 0);
        assert_eq!(none.stats.remount_misses, 0);
        assert_eq!(none.stats.completed, lru.stats.completed);
        // Skipped mounts show up per request: same batch composition, so
        // the mean service strictly drops under affinity.
        assert!(
            lru.service.mean_s() < none.service.mean_s(),
            "LRU {} must beat None {}",
            lru.service.mean_s(),
            none.service.mean_s()
        );
        // And the pipeline run stays deterministic.
        let again = run(Affinity::Lru);
        assert_eq!(lru.completions, again.completions);
        assert_eq!(lru.latency, again.latency);
        assert_eq!(lru.arm_wait, again.arm_wait);
    }

    #[test]
    fn single_arm_serializes_mounts_and_raises_the_tail() {
        // Sixteen drives but one robot arm, with mount costs dominating
        // the in-tape spans and a load the drives handle comfortably
        // (~50% utilization unconstrained): the armed run's serialized
        // mount work (≥16 parked batches × 7.5 s of robot ops) exceeds
        // the whole unconstrained makespan — so its drain *must* stretch
        // and its tail *must* rise, no matter how the batcher coalesces
        // under the backlog. (Exclusivity off: this pins the PR 4 arm
        // geometry, where the two runs differ by the arm bound alone.)
        let run = |n_arms: usize| {
            let mut config = cfg(LoopMode::Open);
            config.exclusive_tapes = false;
            config.n_drives = 16;
            config.drive = DriveParams {
                mount_s: 5.0,
                unmount_s: 2.5,
                bytes_per_s: 1e6,
                uturn_s: 0.001,
                n_arms,
            };
            let mut model = poisson(1.0, 30.0, 21);
            simulate(&config, &catalog(), &Gs, &mut model)
        };
        let free = run(0);
        let armed = run(1);
        assert_eq!(free.stats.completed, armed.stats.completed, "nothing is lost");
        assert!(armed.arm_wait.count() > 0, "arm ops must be recorded");
        assert!(armed.arm_wait.max_s() > 0.0, "some op must have queued");
        assert!(
            armed.latency.quantile(99.9) > free.latency.quantile(99.9),
            "1 arm p99.9 {} must exceed unconstrained p99.9 {}",
            armed.latency.quantile(99.9),
            free.latency.quantile(99.9)
        );
        assert!(
            armed.stats.makespan_us > free.stats.makespan_us,
            "the serialized mounts must stretch the drain"
        );
        assert_eq!(
            armed.stats.remount_hits + armed.stats.remount_misses,
            armed.stats.batches,
            "every batch is classified hit or miss"
        );
        assert_eq!(armed.stats.remount_hits, 0, "no affinity, no hits");
        // Determinism of the event-driven pipeline.
        let again = run(1);
        assert_eq!(armed.completions, again.completions);
        assert_eq!(armed.arm_wait, again.arm_wait);
        assert_eq!(armed.mount_wait, again.mount_wait);
    }

    #[test]
    fn sharded_pipeline_reconciles_per_shard() {
        let catalog: Vec<Tape> = (0..12)
            .map(|i| Tape::from_sizes(format!("TAPE{i:03}"), &[1_000; 40]))
            .collect();
        let mut config = cfg(LoopMode::Open);
        config.n_shards = 4;
        config.n_drives = 2;
        config.drive.n_arms = 1;
        config.affinity = Affinity::Lru;
        let run = || {
            let mut model =
                PoissonArrivals::new(RequestMix::new(&catalog), 60.0, 5.0, 5);
            simulate(&config, &catalog, &Gs, &mut model)
        };
        let a = run();
        let b = run();
        assert_eq!(a.completions, b.completions, "sharded pipeline is deterministic");
        let sum = |f: fn(&ReplayStats) -> u64| -> u64 {
            a.per_shard.iter().map(|s| f(&s.stats)).sum()
        };
        assert_eq!(sum(|s| s.remount_hits), a.stats.remount_hits);
        assert_eq!(sum(|s| s.remount_misses), a.stats.remount_misses);
        assert_eq!(a.stats.remount_hits + a.stats.remount_misses, a.stats.batches);
        assert_eq!(
            a.per_shard.iter().map(|s| s.arm_wait.count()).sum::<u64>(),
            a.arm_wait.count()
        );
        assert_eq!(
            a.per_shard.iter().map(|s| s.mount_wait.count()).sum::<u64>(),
            a.mount_wait.count()
        );
        assert_eq!(a.mount_wait.count(), a.stats.batches, "one sample per batch");
        assert_eq!(a.stats.completed, a.stats.submitted);
    }

    /// A scripted stream that lands `Retry`, `BatchTimer` and `DriveFree`
    /// events on identical virtual timestamps: the EventQueue's FIFO
    /// sequence tie-break is what keeps the replay byte-deterministic.
    struct ScriptArrivals(std::collections::VecDeque<Arrival>);

    impl ArrivalModel for ScriptArrivals {
        fn name(&self) -> String {
            "script".into()
        }

        fn next_arrival(&mut self) -> Option<Arrival> {
            self.0.pop_front()
        }
    }

    #[test]
    fn colliding_events_tie_break_fifo_and_stay_deterministic() {
        // Geometry chosen so collisions are exact: window 100 ms and
        // retry backoff 100 ms put the first Retry on the BatchTimer's
        // timestamp; a 5 s drive busy period (1 mount + 3 span + 1
        // unmount) puts later Retries exactly on DriveFree timestamps.
        let catalog = vec![Tape::from_sizes("T", &[1_000_000; 2])];
        let mut config = cfg(LoopMode::Closed { max_in_flight: 8 });
        config.n_drives = 1;
        config.batcher.max_tape_backlog = 1;
        config.batcher.window = Duration::from_millis(100);
        config.retry_backoff_s = 0.1;
        config.drive = DriveParams {
            mount_s: 1.0,
            unmount_s: 1.0,
            bytes_per_s: 1e6,
            uturn_s: 0.0,
            n_arms: 0,
        };
        let run = || {
            let script: Vec<Arrival> = (0..4)
                .map(|i| Arrival { at_s: 0.0, tape: 0, file: (i % 2) as usize })
                .collect();
            let mut model = ScriptArrivals(script.into_iter().collect());
            simulate(&config, &catalog, &Gs, &mut model)
        };
        let a = run();
        let b = run();
        assert_eq!(a.completions, b.completions, "collisions must not reorder");
        assert_eq!(a.stats.completed, 4);
        assert_eq!(a.stats.submitted, 4);
        assert_eq!(a.stats.shed, 0);
        assert_eq!(
            a.stats.retries, a.stats.busy_rejections,
            "every Busy schedules exactly one retry"
        );
        assert!(a.stats.busy_rejections > 10, "the backlog bound must bounce retries");
        // Backlog 1 serializes the tape: one request per batch.
        assert_eq!(a.stats.batches, 4);
        // The drain asserts inside `simulate` already checked the
        // submitted − completed − shed in-flight identity.
    }

    #[test]
    fn sharded_backpressure_is_per_shard() {
        // One hot tape saturates its own shard; a cold tape on another
        // shard must keep being served without shedding.
        let catalog = vec![
            Tape::from_sizes("HOT", &[1_000; 50]),
            Tape::from_sizes("COLD", &[1_000; 50]),
        ];
        let mut config = cfg(LoopMode::Open);
        config.n_shards = 8; // many shards ⇒ the two tapes very likely split
        config.batcher.max_tape_backlog = 4;
        config.n_drives = 1;
        let mut model = PoissonArrivals::new(RequestMix::new(&catalog), 200.0, 5.0, 1);
        let out = simulate(&config, &catalog, &Gs, &mut model);
        // Wherever the tapes landed, shed counts stay on the shard that
        // owns the hot tape (per-shard reconciliation).
        assert_eq!(
            out.per_shard.iter().map(|s| s.stats.shed).sum::<u64>(),
            out.stats.shed
        );
        assert_eq!(out.stats.completed, out.stats.submitted);
        for s in &out.per_shard {
            if s.n_tapes == 0 {
                assert_eq!(s.stats.submitted, 0, "tapeless shard got traffic");
                assert_eq!(s.stats.batches, 0);
            }
        }
    }
}
