//! Virtual time for the replay engine.
//!
//! A replay runs at CPU speed: simulated time is an integer microsecond
//! counter ([`VirtualClock`]) advanced by a deterministic discrete-event
//! queue ([`EventQueue`]), never by sleeping. The clock still hands out
//! `std::time::Instant`s — anchored at an arbitrary origin captured at
//! construction — so virtual components can drive real-time APIs (the
//! coordinator's [`crate::coordinator::Batcher`] takes `Instant`s) without
//! those APIs knowing they are being replayed. Only *differences* between
//! instants ever matter, and those are exact integer arithmetic, so the
//! translation costs no determinism.

use std::collections::BinaryHeap;
use std::time::{Duration, Instant};

/// Convert seconds to virtual microseconds (the engine's time unit) —
/// the crate-wide µs-grid rounding rule, re-exported from
/// [`crate::util::secs_to_us`] so every consumer shares one definition.
pub use crate::util::secs_to_us;

/// Convert virtual microseconds back to seconds.
#[inline]
pub fn us_to_secs(us: u64) -> f64 {
    us as f64 / 1e6
}

/// Monotone virtual clock in integer microseconds.
#[derive(Debug, Clone)]
pub struct VirtualClock {
    origin: Instant,
    now_us: u64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        // audit:allow(wallclock) anchor only: virtual time is the integer us counter below; the origin is never read by scheduling
        VirtualClock { origin: Instant::now(), now_us: 0 }
    }

    /// Current virtual time in microseconds.
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.now_us
    }

    /// Current virtual time in seconds.
    #[inline]
    pub fn now_s(&self) -> f64 {
        us_to_secs(self.now_us)
    }

    /// Jump forward to `t_us` (no-op when `t_us` is in the past — events
    /// popped at the current instant must not rewind the clock).
    pub fn advance_to(&mut self, t_us: u64) {
        self.now_us = self.now_us.max(t_us);
    }

    /// The `Instant` corresponding to virtual time `t_us`.
    #[inline]
    pub fn instant_at(&self, t_us: u64) -> Instant {
        self.origin + Duration::from_micros(t_us)
    }

    /// The `Instant` corresponding to *now*.
    #[inline]
    pub fn now_instant(&self) -> Instant {
        self.instant_at(self.now_us)
    }

    /// Inverse of [`VirtualClock::instant_at`]: virtual microseconds of an
    /// `Instant` previously produced by this clock (pre-origin clamps to 0).
    #[inline]
    pub fn us_of(&self, i: Instant) -> u64 {
        i.saturating_duration_since(self.origin).as_micros() as u64
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        VirtualClock::new()
    }
}

struct Entry<E> {
    t_us: u64,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.t_us == other.t_us && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, the earliest (time, seq) must
        // surface first. The sequence number breaks time ties FIFO, which
        // is what makes the whole replay deterministic.
        //
        // This tie-break is load-bearing and pinned: open-loop sheds,
        // closed-loop `Retry`s, `BatchTimer`s and drive releases routinely
        // collide on the same virtual microsecond (backoffs and windows
        // share a grid), and FIFO-by-insertion is the only order that is
        // identical across runs. See the engine's
        // `colliding_events_tie_break_fifo_and_stay_deterministic` test
        // and the drain invariants in `engine::simulate`.
        (other.t_us, other.seq).cmp(&(self.t_us, self.seq))
    }
}

/// Deterministic future-event queue: events pop in (time, insertion) order.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
}

impl<E> EventQueue<E> {
    pub fn new() -> EventQueue<E> {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Schedule `ev` at virtual time `t_us`.
    pub fn push(&mut self, t_us: u64, ev: E) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { t_us, seq, ev });
    }

    /// Pop the earliest event (ties FIFO by insertion order).
    pub fn pop(&mut self) -> Option<(u64, E)> {
        self.heap.pop().map(|e| (e.t_us, e.ev))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Reset for reuse, keeping the heap's allocation. The sequence
    /// counter restarts at 0 so a recycled queue breaks time ties in
    /// exactly the order a fresh queue would — reuse must never perturb
    /// the FIFO tie-break the replay's determinism rests on. A drained
    /// replay leaves the queue empty; anything else is an engine bug,
    /// checked in debug builds.
    pub fn recycle(&mut self) {
        debug_assert!(
            self.heap.is_empty(),
            "recycling an EventQueue with {} event(s) still scheduled",
            self.heap.len()
        );
        self.heap.clear();
        self.seq = 0;
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_then_fifo_order() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a1");
        q.push(10, "a2");
        q.push(20, "b");
        assert_eq!(q.len(), 4);
        assert_eq!(q.pop(), Some((10, "a1")));
        assert_eq!(q.pop(), Some((10, "a2")), "ties break FIFO");
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn recycled_queue_restarts_the_fifo_tie_break() {
        let mut q = EventQueue::new();
        q.push(10, "a");
        q.push(10, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((10, "b")));
        q.recycle();
        // Same pushes after recycling pop in the same order: seq restarted.
        q.push(5, "x");
        q.push(5, "y");
        assert_eq!(q.pop(), Some((5, "x")));
        assert_eq!(q.pop(), Some((5, "y")));
        assert!(q.is_empty());
    }

    #[test]
    fn clock_round_trips_through_instants() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now_us(), 0);
        c.advance_to(1_500_000);
        assert_eq!(c.now_us(), 1_500_000);
        assert!((c.now_s() - 1.5).abs() < 1e-12);
        let i = c.instant_at(2_000_000);
        assert_eq!(c.us_of(i), 2_000_000);
        assert_eq!(c.us_of(c.now_instant()), 1_500_000);
        // Going backwards is a no-op, not a panic.
        c.advance_to(1_000_000);
        assert_eq!(c.now_us(), 1_500_000);
    }

    #[test]
    fn second_microsecond_conversions() {
        assert_eq!(secs_to_us(0.0), 0);
        assert_eq!(secs_to_us(1.0), 1_000_000);
        assert_eq!(secs_to_us(0.1234567), 123_457, "rounds to nearest µs");
        assert_eq!(secs_to_us(-5.0), 0, "negative clamps to zero");
        assert!((us_to_secs(2_500_000) - 2.5).abs() < 1e-12);
    }
}
