//! Arrival models: timestamped request streams for the replay engine.
//!
//! The paper evaluates schedulers on logs of a real mass-storage system;
//! this module supplies that request stream in four flavors behind one
//! [`ArrivalModel`] trait:
//!
//! - [`TraceArrivals`] — replay a raw activity log ([`crate::dataset::rawlog`])
//!   with the Appendix-C filters applied streaming: reads only, cross-segment
//!   aggregates discarded. Every surviving line is one request at its log
//!   timestamp; duplicate collapsing into multiplicities happens naturally in
//!   the coordinator's batcher.
//! - [`PoissonArrivals`] — memoryless open-loop traffic at a fixed rate.
//! - [`BurstyArrivals`] — an on/off modulated Poisson process (exponential
//!   phase durations): bursts at 4× the mean rate, quiet periods at ¼.
//! - [`DiurnalArrivals`] — a sinusoidally modulated Poisson process via
//!   thinning: trough at the window start, peak mid-window.
//!
//! All synthetic models draw targets through a shared [`RequestMix`]
//! (Zipf-skewed tape and file popularity, matching the raw-log synthesizer)
//! and are seeded through [`crate::util::rng::Rng`], so the same seed and
//! configuration always yield the identical stream.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap};

use crate::dataset::rawlog::{LogLine, OpKind, TapeCatalog, TraceRecord};
use crate::model::Tape;
use crate::util::rng::Rng;

/// One request arrival: a file on a tape at a virtual timestamp.
#[derive(Debug, Clone, PartialEq)]
pub struct Arrival {
    /// Arrival time, seconds since replay start (nondecreasing per model).
    pub at_s: f64,
    /// Index of the target tape in the replay catalog.
    pub tape: usize,
    /// 0-based file index on that tape.
    pub file: usize,
}

/// A timestamped request stream. Implementations must yield nondecreasing
/// `at_s` and in-bounds `(tape, file)` targets for the catalog they were
/// built against.
pub trait ArrivalModel {
    /// Display name for reports (stable across a replay).
    fn name(&self) -> String;

    /// Next arrival, or `None` once the stream is exhausted.
    fn next_arrival(&mut self) -> Option<Arrival>;
}

/// Exponential inter-arrival draw for a Poisson process at `rate` per s.
#[inline]
fn exp_s(rng: &mut Rng, rate: f64) -> f64 {
    // f64() ∈ [0, 1) ⇒ 1-u ∈ (0, 1] ⇒ ln ≤ 0 ⇒ the gap is ≥ 0 and finite.
    -(1.0 - rng.f64()).ln() / rate
}

/// Which tape/file a synthetic request targets: Zipf-skewed popularity over
/// tapes and files, the same shape the raw-log synthesizer uses.
#[derive(Debug, Clone)]
pub struct RequestMix {
    files_per_tape: Vec<usize>,
    /// Zipf exponent across tapes (1.1 ≈ the rawlog synthesizer).
    pub tape_skew: f64,
    /// Zipf exponent across files within a tape.
    pub file_skew: f64,
}

impl RequestMix {
    pub fn new(tapes: &[Tape]) -> RequestMix {
        assert!(!tapes.is_empty(), "request mix needs at least one tape");
        assert!(
            tapes.iter().all(|t| t.n_files() > 0),
            "every catalog tape must hold at least one file"
        );
        RequestMix {
            files_per_tape: tapes.iter().map(|t| t.n_files()).collect(),
            tape_skew: 1.1,
            file_skew: 1.05,
        }
    }

    fn draw(&self, rng: &mut Rng) -> (usize, usize) {
        let tape =
            rng.zipf(self.files_per_tape.len() as u64, self.tape_skew) as usize - 1;
        let file =
            rng.zipf(self.files_per_tape[tape] as u64, self.file_skew) as usize - 1;
        (tape, file)
    }
}

/// Homogeneous Poisson arrivals at `rate` requests/s until `horizon_s`.
#[derive(Debug, Clone)]
pub struct PoissonArrivals {
    mix: RequestMix,
    rng: Rng,
    rate: f64,
    horizon_s: f64,
    t_s: f64,
}

impl PoissonArrivals {
    pub fn new(mix: RequestMix, rate: f64, horizon_s: f64, seed: u64) -> PoissonArrivals {
        assert!(rate > 0.0, "rate must be positive");
        PoissonArrivals {
            mix,
            rng: Rng::new(seed ^ 0x9015_50AA),
            rate,
            horizon_s,
            t_s: 0.0,
        }
    }
}

impl ArrivalModel for PoissonArrivals {
    fn name(&self) -> String {
        format!("poisson(rate={})", self.rate)
    }

    fn next_arrival(&mut self) -> Option<Arrival> {
        self.t_s += exp_s(&mut self.rng, self.rate);
        if self.t_s > self.horizon_s {
            return None;
        }
        let (tape, file) = self.mix.draw(&mut self.rng);
        Some(Arrival { at_s: self.t_s, tape, file })
    }
}

/// On/off (interrupted Poisson) arrivals: exponential phase durations, a
/// hot rate during bursts and a trickle in between, averaging `rate`.
#[derive(Debug, Clone)]
pub struct BurstyArrivals {
    mix: RequestMix,
    rng: Rng,
    on_rate: f64,
    off_rate: f64,
    mean_on_s: f64,
    mean_off_s: f64,
    horizon_s: f64,
    t_s: f64,
    phase_end_s: f64,
    on: bool,
}

impl BurstyArrivals {
    /// 20% duty cycle at 4× `rate`, 80% at ¼ — the time-average stays
    /// `rate` while p99 sees genuine contention.
    pub fn new(mix: RequestMix, rate: f64, horizon_s: f64, seed: u64) -> BurstyArrivals {
        assert!(rate > 0.0, "rate must be positive");
        let mut rng = Rng::new(seed ^ 0x00B0_2575);
        let mean_on_s = 2.0;
        let mean_off_s = 8.0;
        let first_phase = exp_s(&mut rng, 1.0 / mean_on_s);
        BurstyArrivals {
            mix,
            rng,
            on_rate: rate * 4.0,
            off_rate: rate * 0.25,
            mean_on_s,
            mean_off_s,
            horizon_s,
            t_s: 0.0,
            phase_end_s: first_phase,
            on: true,
        }
    }
}

impl ArrivalModel for BurstyArrivals {
    fn name(&self) -> String {
        format!("bursty(on={},off={})", self.on_rate, self.off_rate)
    }

    fn next_arrival(&mut self) -> Option<Arrival> {
        loop {
            let rate = if self.on { self.on_rate } else { self.off_rate };
            let dt = exp_s(&mut self.rng, rate);
            if self.t_s + dt <= self.phase_end_s {
                self.t_s += dt;
                if self.t_s > self.horizon_s {
                    return None;
                }
                let (tape, file) = self.mix.draw(&mut self.rng);
                return Some(Arrival { at_s: self.t_s, tape, file });
            }
            // The draw crosses a phase boundary: jump to the boundary and
            // redraw there — memorylessness makes discarding the partial
            // exponential statistically sound.
            self.t_s = self.phase_end_s;
            if self.t_s > self.horizon_s {
                return None;
            }
            self.on = !self.on;
            let mean = if self.on { self.mean_on_s } else { self.mean_off_s };
            self.phase_end_s = self.t_s + exp_s(&mut self.rng, 1.0 / mean);
        }
    }
}

/// Sinusoidally modulated Poisson arrivals (thinning): the rate swings
/// between `(1-amp)·rate` and `(1+amp)·rate` over one `period_s` cycle,
/// trough at t=0 — a compressed day/night load curve.
#[derive(Debug, Clone)]
pub struct DiurnalArrivals {
    mix: RequestMix,
    rng: Rng,
    base_rate: f64,
    amplitude: f64,
    period_s: f64,
    horizon_s: f64,
    t_s: f64,
}

impl DiurnalArrivals {
    /// One full cycle over the replay window, amplitude 0.8.
    pub fn new(mix: RequestMix, rate: f64, horizon_s: f64, seed: u64) -> DiurnalArrivals {
        assert!(rate > 0.0, "rate must be positive");
        assert!(horizon_s > 0.0, "diurnal model needs a finite horizon");
        DiurnalArrivals {
            mix,
            rng: Rng::new(seed ^ 0x0D10_284A),
            base_rate: rate,
            amplitude: 0.8,
            period_s: horizon_s,
            horizon_s,
            t_s: 0.0,
        }
    }
}

impl ArrivalModel for DiurnalArrivals {
    fn name(&self) -> String {
        format!("diurnal(rate={},amp={})", self.base_rate, self.amplitude)
    }

    fn next_arrival(&mut self) -> Option<Arrival> {
        let peak = self.base_rate * (1.0 + self.amplitude);
        loop {
            self.t_s += exp_s(&mut self.rng, peak);
            if self.t_s > self.horizon_s {
                return None;
            }
            // sin(phase − π/2) = −cos(phase): trough at t = 0.
            let phase = std::f64::consts::TAU * self.t_s / self.period_s;
            let lambda = self.base_rate
                * (1.0 + self.amplitude * (phase - std::f64::consts::FRAC_PI_2).sin());
            if self.rng.f64() * peak <= lambda {
                let (tape, file) = self.mix.draw(&mut self.rng);
                return Some(Arrival { at_s: self.t_s, tape, file });
            }
        }
    }
}

/// Replay of a raw activity log with the Appendix-C filters.
#[derive(Debug, Clone)]
pub struct TraceArrivals {
    name: String,
    events: Vec<Arrival>,
    pos: usize,
}

impl TraceArrivals {
    /// Filter `lines` against `catalogs` (reads only; unknown tapes and
    /// segments skipped; aggregates spanning into the next segment
    /// discarded with their requests) and emit one arrival per surviving
    /// line, targeting the segment head. Tape indices follow the catalogs'
    /// key order — pair with [`TraceArrivals::catalog_tapes`].
    pub fn from_log(
        lines: &[LogLine],
        catalogs: &BTreeMap<String, TapeCatalog>,
    ) -> TraceArrivals {
        let index: BTreeMap<&str, usize> =
            catalogs.keys().enumerate().map(|(i, k)| (k.as_str(), i)).collect();
        let mut events = Vec::new();
        for line in lines {
            if line.op != OpKind::Read {
                continue;
            }
            let Some(cat) = catalogs.get(&line.tape) else { continue };
            let Some(seg) = cat.segments.get(line.segment) else { continue };
            if seg.spans_next {
                continue;
            }
            events.push(Arrival {
                at_s: line.timestamp as f64,
                tape: index[line.tape.as_str()],
                file: line.segment,
            });
        }
        // Raw logs are timestamp-sorted already; keep the contract explicit
        // (stable sort: equal-timestamp lines keep their log order).
        events.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap());
        TraceArrivals {
            name: format!("trace({} reads)", events.len()),
            events,
            pos: 0,
        }
    }

    /// The replay catalog matching this trace's tape indices.
    pub fn catalog_tapes(catalogs: &BTreeMap<String, TapeCatalog>) -> Vec<Tape> {
        catalogs.values().map(|c| c.tape.clone()).collect()
    }

    /// Build from operator-supplied on-disk trace records
    /// ([`crate::dataset::rawlog::parse_trace`]), resolved against
    /// `catalog` by tape name. Records naming unknown tapes or
    /// out-of-range file ids are skipped (returned as the second element
    /// — the same tolerance the raw-log pipeline applies to foreign
    /// lines). Arrivals sort stably by timestamp, so near-sorted real
    /// logs replay in log order.
    pub fn from_records(records: &[TraceRecord], catalog: &[Tape]) -> (TraceArrivals, usize) {
        let index: HashMap<&str, usize> =
            catalog.iter().enumerate().map(|(i, t)| (t.name.as_str(), i)).collect();
        let mut events = Vec::new();
        let mut skipped = 0usize;
        for rec in records {
            let Some(&tape) = index.get(rec.tape.as_str()) else {
                skipped += 1;
                continue;
            };
            if rec.file_id >= catalog[tape].n_files() {
                skipped += 1;
                continue;
            }
            events.push(Arrival {
                at_s: rec.timestamp_ns as f64 / 1e9,
                tape,
                file: rec.file_id,
            });
        }
        events.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap());
        let model = TraceArrivals {
            name: format!("trace-file({} reads)", events.len()),
            events,
            pos: 0,
        };
        (model, skipped)
    }

    /// Number of arrivals not yet consumed.
    pub fn remaining(&self) -> usize {
        self.events.len() - self.pos
    }

    /// The trace's time horizon: the last arrival's timestamp, seconds
    /// (0 for an empty trace). Events are kept time-sorted, so this is
    /// O(1) — reports echo it as the replayed window.
    pub fn horizon_s(&self) -> f64 {
        self.events.last().map(|a| a.at_s).unwrap_or(0.0)
    }
}

impl ArrivalModel for TraceArrivals {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn next_arrival(&mut self) -> Option<Arrival> {
        let a = self.events.get(self.pos)?.clone();
        self.pos += 1;
        Some(a)
    }
}

/// Default reorder window for [`StreamingTraceArrivals`]: real logs are
/// near-sorted (rotation interleaves a bounded number of lines), so 64Ki
/// pending records absorbs any realistic displacement while bounding
/// memory to O(window) regardless of trace length.
pub const DEFAULT_TRACE_WINDOW: usize = 1 << 16;

/// Streaming counterpart of [`TraceArrivals::from_records`]: consumes a
/// fallible [`TraceRecord`] iterator (e.g. a
/// [`crate::dataset::rawlog::TraceReader`]) incrementally, holding at
/// most `window` pending records in a min-heap instead of the whole
/// trace in a sorted vector. Within that reorder window the emitted
/// stream is *identical* to the eager path — same skips (unknown tape /
/// out-of-range file), same timestamp order, same stable tie-break by
/// record position (the heap key `(timestamp bits, sequence)` reproduces
/// the stable sort exactly; non-negative f64 timestamps order by their
/// IEEE bit patterns). A record displaced further than the window, or a
/// malformed line surfaced by the source iterator, is reported through
/// [`StreamingTraceArrivals::try_next`] — replay drivers are expected to
/// pre-validate with [`scan_trace`] (itself streaming) so the
/// [`ArrivalModel`] path can treat both as unreachable.
pub struct StreamingTraceArrivals<I: Iterator<Item = Result<TraceRecord, String>>> {
    name: String,
    src: I,
    /// Catalog tape name → index (owned, so the model can be boxed
    /// `'static` for policy factories).
    index: HashMap<String, usize>,
    files_per_tape: Vec<usize>,
    /// Pending records, keyed `(at_s.to_bits(), seq, tape, file)`.
    heap: BinaryHeap<Reverse<(u64, u64, usize, usize)>>,
    window: usize,
    seq: u64,
    skipped: usize,
    last_bits: u64,
    exhausted: bool,
}

impl<I: Iterator<Item = Result<TraceRecord, String>>> StreamingTraceArrivals<I> {
    /// `name` is the report label (use the [`scan_trace`] event count to
    /// reproduce the eager `trace-file(N reads)` label); `window` is the
    /// reorder bound in records (≥ 1; see [`DEFAULT_TRACE_WINDOW`]).
    pub fn new(
        name: impl Into<String>,
        src: I,
        catalog: &[Tape],
        window: usize,
    ) -> StreamingTraceArrivals<I> {
        StreamingTraceArrivals {
            name: name.into(),
            src,
            index: catalog
                .iter()
                .enumerate()
                .map(|(i, t)| (t.name.clone(), i))
                .collect(),
            files_per_tape: catalog.iter().map(|t| t.n_files()).collect(),
            heap: BinaryHeap::new(),
            window: window.max(1),
            seq: 0,
            skipped: 0,
            last_bits: 0,
            exhausted: false,
        }
    }

    /// Records skipped so far (unknown tape or out-of-range file id) —
    /// matches the eager path's skip count once the stream is drained.
    pub fn skipped(&self) -> usize {
        self.skipped
    }

    /// Refill the reorder heap to `window` pending records and pop the
    /// earliest, without the monotonicity check (shared by
    /// [`StreamingTraceArrivals::try_next`] and [`scan_trace`]).
    fn pull_pop(&mut self) -> Result<Option<(u64, usize, usize)>, String> {
        while !self.exhausted && self.heap.len() < self.window {
            match self.src.next() {
                None => self.exhausted = true,
                Some(Err(e)) => {
                    self.exhausted = true;
                    return Err(e);
                }
                Some(Ok(rec)) => {
                    let Some(&tape) = self.index.get(rec.tape.as_str()) else {
                        self.skipped += 1;
                        continue;
                    };
                    if rec.file_id >= self.files_per_tape[tape] {
                        self.skipped += 1;
                        continue;
                    }
                    let at_s = rec.timestamp_ns as f64 / 1e9;
                    let seq = self.seq;
                    self.seq += 1;
                    self.heap.push(Reverse((at_s.to_bits(), seq, tape, rec.file_id)));
                }
            }
        }
        Ok(self.heap.pop().map(|Reverse((bits, _, tape, file))| (bits, tape, file)))
    }

    /// Next arrival, `Ok(None)` at end of stream. `Err` on a malformed
    /// source line or a record displaced beyond the reorder window.
    pub fn try_next(&mut self) -> Result<Option<Arrival>, String> {
        let Some((bits, tape, file)) = self.pull_pop()? else {
            return Ok(None);
        };
        if bits < self.last_bits {
            return Err(format!(
                "trace reorder exceeds the {}-record window: a {:.6}s record surfaced after \
                 {:.6}s was already replayed (sort the trace or raise the window)",
                self.window,
                f64::from_bits(bits),
                f64::from_bits(self.last_bits),
            ));
        }
        self.last_bits = bits;
        Ok(Some(Arrival { at_s: f64::from_bits(bits), tape, file }))
    }
}

impl<I: Iterator<Item = Result<TraceRecord, String>>> ArrivalModel
    for StreamingTraceArrivals<I>
{
    fn name(&self) -> String {
        self.name.clone()
    }

    /// Panics on a malformed line or an out-of-window record — drivers
    /// pre-validate the trace with [`scan_trace`], which reports both
    /// conditions cleanly before any replay state exists.
    fn next_arrival(&mut self) -> Option<Arrival> {
        self.try_next().unwrap_or_else(|e| panic!("{e}"))
    }
}

/// What one streaming pass over a trace establishes (see [`scan_trace`]).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceScan {
    /// Records that resolve against the catalog (the eager path's
    /// `trace-file(N reads)` count).
    pub events: usize,
    /// Records skipped: unknown tape or out-of-range file id.
    pub skipped: usize,
    /// Largest resolved timestamp, seconds (0 for an empty trace) — the
    /// eager path's `horizon_s`.
    pub horizon_s: f64,
    /// Whether every record sorts correctly within the reorder window —
    /// when `false`, a [`StreamingTraceArrivals`] replay with this window
    /// would diverge from the eager order (drivers fall back to eager).
    pub within_window: bool,
}

/// Streaming dry-run over a trace: resolve every record against
/// `catalog` in O(window) memory, counting events and skips, finding the
/// horizon, and checking that no record is displaced beyond the reorder
/// window. `Err` only on malformed input (the error a
/// [`crate::dataset::rawlog::TraceReader`] source surfaces, with its
/// 1-based line number).
pub fn scan_trace<I>(src: I, catalog: &[Tape], window: usize) -> Result<TraceScan, String>
where
    I: Iterator<Item = Result<TraceRecord, String>>,
{
    let mut s = StreamingTraceArrivals::new("", src, catalog, window);
    let mut scan = TraceScan { events: 0, skipped: 0, horizon_s: 0.0, within_window: true };
    let mut last_bits = 0u64;
    while let Some((bits, _, _)) = s.pull_pop()? {
        if bits < last_bits {
            scan.within_window = false;
        } else {
            last_bits = bits;
        }
        scan.events += 1;
        scan.horizon_s = scan.horizon_s.max(f64::from_bits(bits));
    }
    scan.skipped = s.skipped();
    Ok(scan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::rawlog::{synth_catalog, synth_raw_log};

    fn tapes() -> Vec<Tape> {
        vec![
            Tape::from_sizes("A", &[100; 40]),
            Tape::from_sizes("B", &[50; 80]),
            Tape::from_sizes("C", &[10; 5]),
        ]
    }

    fn drain(model: &mut dyn ArrivalModel) -> Vec<Arrival> {
        let mut v = Vec::new();
        while let Some(a) = model.next_arrival() {
            v.push(a);
        }
        v
    }

    fn check_stream(arrivals: &[Arrival], horizon: f64, files: &[usize]) {
        assert!(!arrivals.is_empty());
        for w in arrivals.windows(2) {
            assert!(w[0].at_s <= w[1].at_s, "timestamps must be nondecreasing");
        }
        for a in arrivals {
            assert!(a.at_s >= 0.0 && a.at_s <= horizon);
            assert!(a.tape < files.len());
            assert!(a.file < files[a.tape], "file {} on tape {}", a.file, a.tape);
        }
    }

    #[test]
    fn poisson_is_deterministic_and_in_bounds() {
        let mix = RequestMix::new(&tapes());
        let a = drain(&mut PoissonArrivals::new(mix.clone(), 50.0, 20.0, 7));
        let b = drain(&mut PoissonArrivals::new(mix, 50.0, 20.0, 7));
        assert_eq!(a, b, "same seed ⇒ same stream");
        check_stream(&a, 20.0, &[40, 80, 5]);
        // ~1000 expected; 5σ ≈ 160.
        assert!((800..1200).contains(&a.len()), "got {}", a.len());
    }

    #[test]
    fn distinct_seeds_give_distinct_streams() {
        let mix = RequestMix::new(&tapes());
        let a = drain(&mut PoissonArrivals::new(mix.clone(), 50.0, 10.0, 1));
        let b = drain(&mut PoissonArrivals::new(mix, 50.0, 10.0, 2));
        assert_ne!(a, b);
    }

    #[test]
    fn bursty_averages_near_rate_and_actually_bursts() {
        let mix = RequestMix::new(&tapes());
        let a = drain(&mut BurstyArrivals::new(mix, 40.0, 200.0, 3));
        check_stream(&a, 200.0, &[40, 80, 5]);
        // Long-run mean ≈ rate (duty cycle 0.2·4 + 0.8·0.25 = 1.0); the
        // phase process adds variance, so accept a wide band.
        let per_s = a.len() as f64 / 200.0;
        assert!((20.0..70.0).contains(&per_s), "mean rate {per_s}/s");
        // Burstiness: the shortest 10% of gaps should be far below the
        // global mean gap (they come from the 4× phases).
        let mut gaps: Vec<f64> = a.windows(2).map(|w| w[1].at_s - w[0].at_s).collect();
        gaps.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let mean_gap = gaps.iter().sum::<f64>() / gaps.len() as f64;
        assert!(gaps[gaps.len() / 10] < mean_gap * 0.6, "no visible bursts");
    }

    #[test]
    fn diurnal_peaks_mid_window() {
        let mix = RequestMix::new(&tapes());
        let a = drain(&mut DiurnalArrivals::new(mix, 50.0, 100.0, 11));
        check_stream(&a, 100.0, &[40, 80, 5]);
        // Trough at the edges, peak in the middle: the middle half must
        // hold clearly more than half the arrivals.
        let mid = a.iter().filter(|x| x.at_s > 25.0 && x.at_s < 75.0).count();
        assert!(
            mid as f64 > a.len() as f64 * 0.55,
            "mid-window {mid}/{} not peaked",
            a.len()
        );
    }

    #[test]
    fn trace_records_resolve_against_the_catalog() {
        use crate::dataset::rawlog::TraceRecord;
        let catalog = tapes(); // A: 40 files, B: 80, C: 5
        let rec = |ns: u64, tape: &str, file: usize| TraceRecord {
            timestamp_ns: ns,
            tape: tape.into(),
            file_id: file,
        };
        let records = vec![
            rec(2_000_000_000, "B", 79),
            rec(1_000_000_000, "A", 0), // out of order: sorted on build
            rec(500_000_000, "NOPE", 0), // unknown tape: skipped
            rec(500_000_000, "C", 5),   // file out of range: skipped
            rec(1_000_000_000, "C", 4),
        ];
        let (mut model, skipped) = TraceArrivals::from_records(&records, &catalog);
        assert_eq!(skipped, 2);
        assert_eq!(model.remaining(), 3);
        assert!(model.name().contains("3 reads"));
        assert!((model.horizon_s() - 2.0).abs() < 1e-12, "horizon = last timestamp");
        assert_eq!(TraceArrivals::from_records(&[], &catalog).0.horizon_s(), 0.0);
        let arrivals = drain(&mut model);
        check_stream(&arrivals, 2.0, &[40, 80, 5]);
        assert_eq!(arrivals[0], Arrival { at_s: 1.0, tape: 0, file: 0 });
        assert_eq!(arrivals[1], Arrival { at_s: 1.0, tape: 2, file: 4 });
        assert_eq!(arrivals[2], Arrival { at_s: 2.0, tape: 1, file: 79 });
        // Stable sort: equal timestamps keep record order.
        let (again, _) = TraceArrivals::from_records(&records, &catalog);
        let mut again = again;
        assert_eq!(arrivals, drain(&mut again), "deterministic across builds");
    }

    fn rec(ns: u64, tape: &str, file: usize) -> TraceRecord {
        TraceRecord { timestamp_ns: ns, tape: tape.into(), file_id: file }
    }

    #[test]
    fn streaming_trace_matches_the_eager_path() {
        // Same records as the eager-resolution test, plus more ties and
        // interleaving: the streaming model must emit the identical
        // stream — order, tie-break, and skip accounting.
        let catalog = tapes(); // A: 40 files, B: 80, C: 5
        let records = vec![
            rec(2_000_000_000, "B", 79),
            rec(1_000_000_000, "A", 0),
            rec(500_000_000, "NOPE", 0),  // unknown tape: skipped
            rec(500_000_000, "C", 5),     // file out of range: skipped
            rec(1_000_000_000, "C", 4),   // ties with the A record above
            rec(1_000_000_000, "A", 7),   // and a second tie
            rec(250_000_000, "B", 0),
        ];
        let (mut eager, eager_skipped) = TraceArrivals::from_records(&records, &catalog);
        let expected = drain(&mut eager);
        // The 250ms record arrives last of 5 resolved records, so any
        // window holding all 5 sorts it correctly…
        for window in [5, 64, DEFAULT_TRACE_WINDOW] {
            let src = records.iter().cloned().map(Ok);
            let mut streaming =
                StreamingTraceArrivals::new("trace-file(5 reads)", src, &catalog, window);
            let mut got = Vec::new();
            while let Some(a) = streaming.try_next().expect("in-window trace") {
                got.push(a);
            }
            assert_eq!(got, expected, "window {window}");
            assert_eq!(streaming.skipped(), eager_skipped, "window {window}");
            assert_eq!(streaming.name(), "trace-file(5 reads)");
        }
        // …and any smaller window must refuse (reorder error), never
        // silently emit a different order.
        for window in [1, 2, 4] {
            let src = records.iter().cloned().map(Ok);
            let mut streaming = StreamingTraceArrivals::new("t", src, &catalog, window);
            let err = loop {
                match streaming.try_next() {
                    Ok(Some(_)) => {}
                    Ok(None) => panic!("window {window} cannot sort this trace"),
                    Err(e) => break e,
                }
            };
            assert!(err.contains("reorder exceeds"), "window {window}: {err}");
        }
    }

    #[test]
    fn streaming_trace_reports_out_of_window_reorder() {
        let catalog = tapes();
        // The 1ns record arrives 3 records late; window 2 already
        // replayed 2.0s when it surfaces.
        let records =
            vec![rec(2_000_000_000, "A", 0), rec(3_000_000_000, "A", 1), rec(4_000_000_000, "A", 2), rec(1, "A", 3)];
        let src = records.iter().cloned().map(Ok);
        let mut s = StreamingTraceArrivals::new("t", src, &catalog, 2);
        let mut err = None;
        for _ in 0..records.len() {
            match s.try_next() {
                Ok(_) => {}
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        let err = err.expect("displacement beyond the window must surface");
        assert!(err.contains("reorder exceeds the 2-record window"), "{err}");

        // scan_trace flags the same trace without erroring…
        let scan = scan_trace(records.iter().cloned().map(Ok), &catalog, 2).unwrap();
        assert!(!scan.within_window);
        assert_eq!(scan.events, 4);
        // …and clears it once the window covers the displacement.
        let scan = scan_trace(records.iter().cloned().map(Ok), &catalog, 4).unwrap();
        assert!(scan.within_window);
        assert!((scan.horizon_s - 4.0).abs() < 1e-12);
    }

    #[test]
    fn scan_trace_reports_counts_horizon_and_errors() {
        let catalog = tapes();
        let records = vec![
            rec(2_000_000_000, "B", 79),
            rec(500_000_000, "NOPE", 0),
            rec(500_000_000, "C", 5),
            rec(1_000_000_000, "C", 4),
        ];
        let scan =
            scan_trace(records.iter().cloned().map(Ok), &catalog, DEFAULT_TRACE_WINDOW).unwrap();
        assert_eq!(
            scan,
            TraceScan { events: 2, skipped: 2, horizon_s: 2.0, within_window: true }
        );
        // A malformed source line propagates with its message.
        let src = vec![Ok(rec(0, "A", 0)), Err("trace line 2: bad timestamp_ns `x`".into())];
        let e = scan_trace(src.into_iter(), &catalog, 8).unwrap_err();
        assert!(e.contains("line 2"), "{e}");
        // Empty traces scan clean.
        let empty = scan_trace(std::iter::empty(), &catalog, 8).unwrap();
        assert_eq!(empty.events, 0);
        assert_eq!(empty.horizon_s, 0.0);
    }

    #[test]
    fn streaming_trace_replays_a_trace_reader_end_to_end() {
        // TraceReader → StreamingTraceArrivals: the full streaming
        // ingestion stack against the eager read-parse-resolve stack.
        use crate::dataset::rawlog::{parse_trace, TraceReader};
        let text = "# synthetic\n\
                    250000000\tB\t0\n\
                    1000000000\tA\t0\n\
                    1000000000\tC\t4\n\
                    2000000000\tB\t79\n\
                    500000000\tZZZ\t1\n";
        let catalog = tapes();
        let eager_records = parse_trace(text).unwrap();
        let (mut eager, skipped) = TraceArrivals::from_records(&eager_records, &catalog);
        let mut streaming = StreamingTraceArrivals::new(
            eager.name(),
            TraceReader::new(text.as_bytes()),
            &catalog,
            DEFAULT_TRACE_WINDOW,
        );
        let mut got = Vec::new();
        while let Some(a) = streaming.try_next().unwrap() {
            got.push(a);
        }
        assert_eq!(got, drain(&mut eager));
        assert_eq!(streaming.skipped(), skipped);
    }

    #[test]
    fn trace_applies_the_rawlog_filters() {
        let mut cats = BTreeMap::new();
        for i in 0..3 {
            let name = format!("T{i}");
            cats.insert(name.clone(), synth_catalog(&name, 60, i));
        }
        let log = synth_raw_log(&cats, 2_000, 300, 5);
        let mut model = TraceArrivals::from_log(&log, &cats);
        let n_reads = log
            .iter()
            .filter(|l| {
                l.op == OpKind::Read && !cats[&l.tape].segments[l.segment].spans_next
            })
            .count();
        assert_eq!(model.remaining(), n_reads);
        let catalog = TraceArrivals::catalog_tapes(&cats);
        let arrivals = drain(&mut model);
        assert_eq!(arrivals.len(), n_reads);
        let files: Vec<usize> = catalog.iter().map(|t| t.n_files()).collect();
        check_stream(&arrivals, 300.0, &files);
        // Clone-before-consume replays identically.
        let again = drain(&mut TraceArrivals::from_log(&log, &cats));
        assert_eq!(arrivals, again);
    }
}
