//! Live closed-loop driver for the *real* coordinator.
//!
//! The virtual engine ([`super::engine`]) is the measurement tool; this
//! driver is its wall-clock sibling for exercising the actual threaded
//! [`Coordinator`] — the serving demo (`tapesched serve`) and the
//! backpressure integration tests share it, so the demo and the evaluation
//! drive the service through one code path. Requests come from the same
//! [`ArrivalModel`]s; arrival *timestamps* are ignored (the driver is a
//! load generator, not a simulator): it submits as fast as the in-flight
//! cap allows and retries `Busy` rejections after a backoff, which is
//! exactly the contract the coordinator's backpressure promises callers.

use std::time::Duration;

use crate::coordinator::{Coordinator, ReadRequest, SubmitError};
use crate::model::Tape;

use super::arrivals::ArrivalModel;

/// Anything the closed-loop driver can feed: a single-library
/// [`Coordinator`] or the multi-library [`crate::cluster::Cluster`] — both
/// expose the same submit contract (including `Busy` backpressure) and an
/// in-flight estimate from their metrics.
pub trait RequestSink {
    /// Submit one request under the coordinator's `submit` contract.
    fn submit_request(&self, req: ReadRequest) -> Result<(), SubmitError>;

    /// Requests accepted but not yet served, per the sink's own metrics.
    fn in_flight(&self) -> u64;
}

impl RequestSink for Coordinator {
    fn submit_request(&self, req: ReadRequest) -> Result<(), SubmitError> {
        self.submit(req)
    }

    fn in_flight(&self) -> u64 {
        // Shed requests (accepted, then dropped at dispatch because their
        // tape was deregistered) will never complete — leaving them out
        // would wedge any caller gating on the in-flight level.
        let m = self.metrics();
        m.submitted.saturating_sub(m.completed + m.shed)
    }
}

/// What the driver observed while feeding the coordinator.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LiveDriveStats {
    /// Requests accepted by the coordinator.
    pub submitted: u64,
    /// `Busy` rejections that were retried (each retry re-submits).
    pub busy_retries: u64,
    /// Requests dropped for a non-retryable reason (unknown tape, bad
    /// index, stopping service).
    pub dropped: u64,
}

/// Feed up to `limit` arrivals from `model` into `sink` (a coordinator or
/// a cluster), keeping at most `max_in_flight` requests outstanding
/// (observed through the sink's metrics) and retrying `Busy` after
/// `retry_backoff`. `tapes` maps the model's tape indices to catalog
/// names — pass the same slice the model's
/// [`super::arrivals::RequestMix`] was built from.
pub fn drive_closed_loop<S: RequestSink + ?Sized>(
    sink: &S,
    tapes: &[Tape],
    model: &mut dyn ArrivalModel,
    max_in_flight: u64,
    retry_backoff: Duration,
    limit: u64,
) -> LiveDriveStats {
    assert!(max_in_flight > 0, "closed loop needs a positive in-flight cap");
    let mut stats = LiveDriveStats::default();
    let mut id = 0u64;
    while id < limit {
        let Some(a) = model.next_arrival() else { break };
        // Gate on the in-flight level before submitting.
        while sink.in_flight() >= max_in_flight {
            std::thread::sleep(retry_backoff);
        }
        loop {
            let req = ReadRequest {
                id,
                tape: tapes[a.tape].name.clone(),
                file_index: a.file,
            };
            match sink.submit_request(req) {
                Ok(()) => {
                    stats.submitted += 1;
                    break;
                }
                Err(SubmitError::Busy) => {
                    stats.busy_retries += 1;
                    std::thread::sleep(retry_backoff);
                }
                Err(_) => {
                    stats.dropped += 1;
                    break;
                }
            }
        }
        id += 1;
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, CoordinatorConfig};
    use crate::replay::arrivals::{PoissonArrivals, RequestMix};
    use crate::sched::Gs;
    use crate::sim::DriveParams;
    use std::sync::Arc;

    #[test]
    fn drives_the_real_coordinator_to_completion() {
        let tapes = vec![
            Tape::from_sizes("T0", &[1_000; 40]),
            Tape::from_sizes("T1", &[500; 80]),
        ];
        let coord = Coordinator::start(
            CoordinatorConfig {
                n_drives: 2,
                batcher: BatcherConfig {
                    window: Duration::from_millis(2),
                    max_batch: 64,
                    ..BatcherConfig::default()
                },
                drive: DriveParams::default(),
                ..CoordinatorConfig::default()
            },
            tapes.clone(),
            Arc::new(Gs),
        );
        let mut model =
            PoissonArrivals::new(RequestMix::new(&tapes), 100.0, f64::INFINITY, 3);
        let stats = drive_closed_loop(
            &coord,
            &tapes,
            &mut model,
            64,
            Duration::from_millis(1),
            150,
        );
        assert_eq!(stats.submitted, 150);
        assert_eq!(stats.dropped, 0);
        let (completions, m) = coord.finish();
        assert_eq!(completions.len(), 150);
        assert_eq!(m.completed, 150);
    }
}
