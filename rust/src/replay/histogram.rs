//! Fixed-size log-bucketed latency histogram (HDR-style, no deps).
//!
//! Values are recorded in integer microseconds into a log-linear bucket
//! grid: exact below 64 µs, then 64 linear sub-buckets per power of two —
//! a worst-case relative quantile error of 1/64 ≈ 1.6% across the full
//! `u64` range, in a constant ~30 KB of memory. Recording is O(1) and
//! branch-light; quantile queries walk the cumulative counts.
//!
//! Everything here is integer arithmetic on a fixed grid, so two replays
//! that record the same values report byte-identical quantiles — the
//! property the determinism acceptance test leans on.

/// Linear sub-bucket resolution: 2^6 = 64 buckets per octave.
const SUB_BITS: u32 = 6;
const SUB: u64 = 1 << SUB_BITS;
/// Octaves SUB_BITS..=63 each contribute SUB buckets after the linear head.
const N_BUCKETS: usize = ((64 - SUB_BITS as usize) + 1) * SUB as usize;

/// Log-bucketed histogram over microsecond samples.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    total: u64,
    sum_us: u128,
    max_us: u64,
}

#[inline]
fn bucket_index(v_us: u64) -> usize {
    if v_us < SUB {
        return v_us as usize;
    }
    let exp = 63 - v_us.leading_zeros(); // ≥ SUB_BITS
    let mantissa = (v_us >> (exp - SUB_BITS)) - SUB; // ∈ [0, SUB)
    ((exp - SUB_BITS + 1) as u64 * SUB + mantissa) as usize
}

/// Highest value (µs) mapping into bucket `i` — quantiles report this edge,
/// so they never under-state a latency.
#[inline]
fn bucket_high_us(i: usize) -> u64 {
    if i < SUB as usize {
        return i as u64;
    }
    let exp = (i / SUB as usize) as u32 + SUB_BITS - 1;
    let mantissa = (i % SUB as usize) as u64;
    let low = (SUB + mantissa) << (exp - SUB_BITS);
    low + (1u64 << (exp - SUB_BITS)) - 1
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            counts: vec![0; N_BUCKETS],
            total: 0,
            sum_us: 0,
            max_us: 0,
        }
    }

    /// Record one sample in microseconds.
    pub fn record_us(&mut self, v_us: u64) {
        self.counts[bucket_index(v_us)] += 1;
        self.total += 1;
        self.sum_us += v_us as u128;
        self.max_us = self.max_us.max(v_us);
    }

    /// Record one sample in seconds (negative clamps to zero).
    pub fn record_seconds(&mut self, s: f64) {
        self.record_us((s.max(0.0) * 1e6).round() as u64);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Exact mean of the recorded samples, seconds (0 when empty).
    pub fn mean_s(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            (self.sum_us / self.total as u128) as f64 / 1e6
                + (self.sum_us % self.total as u128) as f64
                    / self.total as f64
                    / 1e6
        }
    }

    /// Exact maximum recorded sample, seconds.
    pub fn max_s(&self) -> f64 {
        self.max_us as f64 / 1e6
    }

    /// Samples ≤ `bound_us` — the cumulative count behind one
    /// `…_bucket{le="…"}` line of a Prometheus histogram exposition.
    /// Bucketed, so the answer is the count of samples whose *bucket*
    /// fits entirely under the bound: conservative the same way the
    /// quantiles are (a sample is never reported under a bound it might
    /// exceed).
    pub fn count_le_us(&self, bound_us: u64) -> u64 {
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if bucket_high_us(i) > bound_us {
                break;
            }
            cum += c;
        }
        cum
    }

    /// Exact sum of all recorded samples, seconds (the `…_sum` line of a
    /// histogram exposition).
    pub fn sum_seconds(&self) -> f64 {
        self.sum_us as f64 / 1e6
    }

    /// Merge another histogram into this one. The grid is fixed and all
    /// fields are integer sums (or a max), so merging per-shard
    /// histograms recorded on disjoint sample sets yields exactly the
    /// histogram an interleaved single recorder would have produced —
    /// the property the parallel replay's byte-identity leans on.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Reset to the empty state in place, keeping the bucket allocation.
    /// A cleared histogram is `==` a fresh one (the grid is fixed-size),
    /// which is what lets the replay arena reuse buffers across policies
    /// without perturbing any report.
    pub fn clear(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
        self.total = 0;
        self.sum_us = 0;
        self.max_us = 0;
    }

    /// Quantile `p` ∈ [0, 100] in seconds: the high edge of the bucket
    /// holding the ⌈p/100·n⌉-th smallest sample (≤ 1/64 relative error),
    /// clamped to the exact maximum. 0 when empty.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_high_us(i).min(self.max_us) as f64 / 1e6;
            }
        }
        self.max_s()
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::percentile_sorted;

    #[test]
    fn bucket_index_is_monotone_and_in_range() {
        let mut values: Vec<u64> = Vec::new();
        for shift in 0..64u32 {
            for delta in [0u64, 1, 3] {
                values.push((1u64 << shift).saturating_add(delta));
            }
        }
        values.push(0);
        values.sort_unstable();
        let mut last = 0usize;
        for v in values {
            let i = bucket_index(v);
            assert!(i < N_BUCKETS, "v={v} i={i}");
            assert!(i >= last, "index must be monotone in the value (v={v})");
            last = i;
            // The bucket's range must actually contain the value.
            assert!(bucket_high_us(i) >= v, "v={v} high={}", bucket_high_us(i));
        }
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
        assert_eq!(bucket_high_us(N_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn small_values_are_exact() {
        let mut h = LatencyHistogram::new();
        for v in 0..SUB {
            h.record_us(v);
        }
        assert_eq!(h.count(), SUB);
        // Every value below SUB sits in its own bucket: the k-th quantile
        // rank maps straight back to the value.
        assert_eq!(h.quantile(50.0), 31.0 / 1e6);
        assert_eq!(h.quantile(100.0), 63.0 / 1e6);
        assert_eq!(h.max_s(), 63.0 / 1e6);
    }

    #[test]
    fn quantiles_match_exact_sorted_vector_within_bucket_error() {
        // The satellite-task contract: histogram quantile math vs the exact
        // sorted-vector quantiles, across a skewed (log-normal) sample.
        let mut rng = Rng::new(0x9077);
        let mut h = LatencyHistogram::new();
        let mut xs = Vec::with_capacity(20_000);
        for _ in 0..20_000 {
            let s = rng.lognormal(2.0, 1.2); // seconds, heavy right tail
            h.record_seconds(s);
            xs.push((s * 1e6).round() / 1e6); // what the histogram saw
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [10.0, 50.0, 90.0, 95.0, 99.0, 99.9] {
            let exact = percentile_sorted(&xs, p);
            let approx = h.quantile(p);
            // High-edge reporting: at most one bucket (1/64) above, and the
            // rank convention differs from interpolation by ≤ one sample.
            let tol = exact * 0.04 + 1e-6;
            assert!(
                (approx - exact).abs() <= tol,
                "p{p}: histogram {approx} vs exact {exact}"
            );
        }
        let exact_mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((h.mean_s() - exact_mean).abs() < 1e-6, "mean is exact");
    }

    #[test]
    fn quantile_edges_p0_p100_and_single_sample() {
        // Single sample: every quantile — p=0 included — is that sample.
        let mut h = LatencyHistogram::new();
        h.record_us(5);
        for p in [0.0, 0.1, 50.0, 99.9, 100.0] {
            assert_eq!(h.quantile(p), 5.0 / 1e6, "p={p}");
        }

        // p=0 clamps the rank to the first sample; p=100 is the last.
        let mut h = LatencyHistogram::new();
        for v in 0..SUB {
            h.record_us(v);
        }
        assert_eq!(h.quantile(0.0), 0.0, "p=0 → smallest sample's bucket");
        assert_eq!(h.quantile(100.0), 63.0 / 1e6, "p=100 → the maximum");
        // p=100 never exceeds the exact max even in a wide bucket.
        let mut h = LatencyHistogram::new();
        h.record_us(1_000_003); // bucket high edge > 1_000_003
        assert_eq!(h.quantile(100.0), h.max_s(), "clamped to the exact max");
    }

    #[test]
    fn rank_near_total_pins_the_f64_ceil_behavior() {
        // (99.9/100)·1000 = 999.0000000000001 in f64, so ceil lands on
        // rank 1000 (the maximum) rather than the mathematical 999. This
        // is the documented high-edge behavior — one rank conservative,
        // never an under-statement. Pin it so a rank-formula change shows
        // up as a test diff instead of silently shifting every p99.9.
        let mut h = LatencyHistogram::new();
        for v in 0..1_000u64 {
            h.record_us(v);
        }
        assert_eq!(h.quantile(99.9), h.max_s(), "f64 ceil overshoots to rank n");
        // Where the product is exact the rank is exact: p=50 of 1000
        // samples 0..999 is the 500th smallest = 499, reported through
        // the same bucket-high-edge convention (probed via a singleton).
        let rank500_high = {
            let mut probe = LatencyHistogram::new();
            probe.record_us(499);
            probe.quantile(100.0)
        };
        assert_eq!(h.quantile(50.0), rank500_high);
        // And (99.99/100)·10000 = 9998.999999999998 ceils to the correct
        // rank 9999 — the error direction depends on the operands, which
        // is exactly why the convention must stay pinned.
        let mut h = LatencyHistogram::new();
        for v in 0..10_000u64 {
            h.record_us(v % 64);
        }
        assert_eq!(h.quantile(99.99), 63.0 / 1e6);
    }

    #[test]
    fn empty_histogram_is_zeroed() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(50.0), 0.0);
        assert_eq!(h.mean_s(), 0.0);
        assert_eq!(h.max_s(), 0.0);
        assert_eq!(h.count_le_us(u64::MAX), 0);
        assert_eq!(h.sum_seconds(), 0.0);
    }

    #[test]
    fn cumulative_bucket_counts_are_monotone_and_conservative() {
        let mut h = LatencyHistogram::new();
        for v in [10u64, 100, 1_000, 10_000, 100_000, 1_000_000] {
            h.record_us(v);
        }
        // Values below SUB are exact; 10 is counted at le=10.
        assert_eq!(h.count_le_us(9), 0);
        assert_eq!(h.count_le_us(10), 1);
        // Bucketed values count only once their whole bucket fits: never
        // under a bound the sample might exceed.
        assert!(h.count_le_us(1_000) >= 2);
        assert!(h.count_le_us(999) <= 2);
        // The ladder is monotone and tops out at the total.
        let bounds = [0u64, 10, 100, 1_000, 10_000, 100_000, 1_000_000, u64::MAX];
        let mut last = 0;
        for b in bounds {
            let c = h.count_le_us(b);
            assert!(c >= last, "le={b}: {c} < {last}");
            last = c;
        }
        assert_eq!(h.count_le_us(u64::MAX), 6);
        let want = (10 + 100 + 1_000 + 10_000 + 100_000 + 1_000_000) as f64 / 1e6;
        assert!((h.sum_seconds() - want).abs() < 1e-12);
    }

    #[test]
    fn merge_equals_interleaved_recording_and_clear_restores_fresh() {
        let mut rng = Rng::new(0xBEEF);
        let (mut a, mut b, mut whole) =
            (LatencyHistogram::new(), LatencyHistogram::new(), LatencyHistogram::new());
        for i in 0..10_000u64 {
            let v = (rng.lognormal(2.0, 1.5) * 1e6) as u64;
            whole.record_us(v);
            if i % 2 == 0 { a.record_us(v) } else { b.record_us(v) };
        }
        a.merge(&b);
        assert_eq!(a, whole, "merge of disjoint halves = interleaved recording");
        // Merging an empty histogram is the identity.
        a.merge(&LatencyHistogram::new());
        assert_eq!(a, whole);
        // Clearing restores exact equality with a fresh histogram.
        a.clear();
        assert_eq!(a, LatencyHistogram::new());
        assert_eq!(a.count(), 0);
        assert_eq!(a.quantile(99.9), 0.0);
    }

    #[test]
    fn identical_inputs_give_identical_histograms() {
        let fill = |seed: u64| {
            let mut rng = Rng::new(seed);
            let mut h = LatencyHistogram::new();
            for _ in 0..5_000 {
                h.record_seconds(rng.lognormal(1.0, 1.0));
            }
            h
        };
        assert_eq!(fill(3), fill(3));
        assert_ne!(fill(3), fill(4));
    }
}
