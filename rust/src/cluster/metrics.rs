//! Cluster-level metrics: per-shard coordinator snapshots plus routing
//! counters, rolled up into one fleet view.
//!
//! The rollup is pure arithmetic over [`MetricsSnapshot`]s — counters add,
//! means combine completion-weighted — so it can serve both the live
//! [`super::Cluster`] and any offline aggregation of per-shard snapshots.
//! Percentiles deliberately do **not** roll up here: a fleet percentile
//! cannot be derived from per-shard percentiles (only from the merged
//! sample), which is exactly why the replay engine keeps separate fleet
//! and per-shard histograms.

use crate::coordinator::MetricsSnapshot;

/// One shard's contribution: its id, how many submissions the router sent
/// its way, and its coordinator's own metrics snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardLoad {
    /// Ring shard id (stable across membership changes).
    pub shard: usize,
    /// Submissions the cluster router directed at this shard (accepted or
    /// not — rejected submissions still count as routed).
    pub routed: u64,
    pub metrics: MetricsSnapshot,
}

/// Point-in-time rollup of a whole cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterMetricsSnapshot {
    /// Per-shard loads, ascending by shard id.
    pub shards: Vec<ShardLoad>,
    pub routed_total: u64,
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Accepted requests shed at dispatch (tape deregistered mid-flight —
    /// see `MetricsSnapshot::shed`).
    pub shed: u64,
    pub batches: u64,
    /// Batches served fleet-wide without a mount (drive affinity).
    pub remount_hits: u64,
    /// Batches that paid a mount fleet-wide.
    pub remount_misses: u64,
    /// Batches that waited on a cartridge waitlist fleet-wide (per-tape
    /// mount exclusivity).
    pub cartridge_parks: u64,
    /// Park-weighted mean / fleet-worst cartridge wait, seconds.
    pub mean_cartridge_wait_s: f64,
    pub max_cartridge_wait_s: f64,
    /// Robot-arm reservations fleet-wide.
    pub arm_ops: u64,
    /// Op-weighted mean / fleet-worst arm wait, seconds.
    pub mean_arm_wait_s: f64,
    pub max_arm_wait_s: f64,
    /// Completion-weighted mean end-to-end latency, seconds.
    pub mean_latency_s: f64,
    /// Completion-weighted mean in-tape service time, seconds.
    pub mean_service_s: f64,
    /// Largest / smallest per-shard completion count — the load-imbalance
    /// extremes the routing layer is judged on.
    pub max_shard_completed: u64,
    pub min_shard_completed: u64,
    /// Incremental-backend repair work fleet-wide (0 unless shards serve
    /// with `--backend incremental`): columns appended vs. rebuilds.
    pub incremental_appends: u64,
    pub incremental_rebuilds: u64,
}

impl ClusterMetricsSnapshot {
    /// `max/min` completed across shards: 1.0 for a perfectly balanced (or
    /// empty) cluster, `∞` when some shard served nothing while another
    /// served something.
    pub fn imbalance_ratio(&self) -> f64 {
        if self.max_shard_completed == 0 {
            1.0
        } else if self.min_shard_completed == 0 {
            f64::INFINITY
        } else {
            self.max_shard_completed as f64 / self.min_shard_completed as f64
        }
    }
}

/// Weighted mean of per-group means: `Σ meanᵢ·wᵢ / Σ wᵢ`, defined as 0.0
/// — never NaN — when the total weight is zero. Every mean in
/// [`merge_snapshots`] and [`rollup`] combines through this one helper,
/// so an idle fleet (all shards zero completions/parks/ops) reports zero
/// means and downstream JSON stays finite.
pub fn weighted_mean(parts: impl IntoIterator<Item = (f64, u64)>) -> f64 {
    let (mut sum, mut total) = (0.0f64, 0u64);
    for (mean, w) in parts {
        sum += mean * w as f64;
        total += w;
    }
    if total == 0 {
        0.0
    } else {
        sum / total as f64
    }
}

/// Merge two [`MetricsSnapshot`]s of the *same* shard into one — the
/// networked coordinator's tool for stitching a shard's history across
/// worker eras (the carried accounting of a dead worker + whatever its
/// replacement has served since; see `net::server`).
///
/// Counters add; means combine weighted by their own denominators
/// (latency/service by `completed`, sched by `batches`, cartridge wait by
/// `cartridge_parks`, arm wait by `arm_ops`); maxes take the worst side.
/// Percentiles cannot be merged without the underlying samples, so the
/// side with more completions keeps its ladder — a documented
/// approximation, same reason [`rollup`] refuses to aggregate them
/// fleet-wide.
pub fn merge_snapshots(a: &MetricsSnapshot, b: &MetricsSnapshot) -> MetricsSnapshot {
    let completed = a.completed + b.completed;
    let batches = a.batches + b.batches;
    let cartridge_parks = a.cartridge_parks + b.cartridge_parks;
    let arm_ops = a.arm_ops + b.arm_ops;
    let wmean = |ma: f64, wa: u64, mb: f64, wb: u64| weighted_mean([(ma, wa), (mb, wb)]);
    let pct_side = if b.completed > a.completed { b } else { a };
    MetricsSnapshot {
        submitted: a.submitted + b.submitted,
        completed,
        rejected: a.rejected + b.rejected,
        shed: a.shed + b.shed,
        batches,
        remount_hits: a.remount_hits + b.remount_hits,
        remount_misses: a.remount_misses + b.remount_misses,
        cartridge_parks,
        mean_cartridge_wait_s: wmean(
            a.mean_cartridge_wait_s,
            a.cartridge_parks,
            b.mean_cartridge_wait_s,
            b.cartridge_parks,
        ),
        max_cartridge_wait_s: a.max_cartridge_wait_s.max(b.max_cartridge_wait_s),
        arm_ops,
        mean_arm_wait_s: wmean(a.mean_arm_wait_s, a.arm_ops, b.mean_arm_wait_s, b.arm_ops),
        max_arm_wait_s: a.max_arm_wait_s.max(b.max_arm_wait_s),
        mean_latency_s: wmean(a.mean_latency_s, a.completed, b.mean_latency_s, b.completed),
        mean_service_s: wmean(a.mean_service_s, a.completed, b.mean_service_s, b.completed),
        mean_sched_s_per_batch: wmean(
            a.mean_sched_s_per_batch,
            a.batches,
            b.mean_sched_s_per_batch,
            b.batches,
        ),
        p50_latency_s: pct_side.p50_latency_s,
        p99_latency_s: pct_side.p99_latency_s,
        incremental_appends: a.incremental_appends + b.incremental_appends,
        incremental_rebuilds: a.incremental_rebuilds + b.incremental_rebuilds,
    }
}

/// Roll per-shard loads up into one [`ClusterMetricsSnapshot`].
pub fn rollup(mut shards: Vec<ShardLoad>) -> ClusterMetricsSnapshot {
    shards.sort_by_key(|s| s.shard);
    let mut snap = ClusterMetricsSnapshot {
        shards: Vec::new(),
        routed_total: 0,
        submitted: 0,
        completed: 0,
        rejected: 0,
        shed: 0,
        batches: 0,
        remount_hits: 0,
        remount_misses: 0,
        cartridge_parks: 0,
        mean_cartridge_wait_s: 0.0,
        max_cartridge_wait_s: 0.0,
        arm_ops: 0,
        mean_arm_wait_s: 0.0,
        max_arm_wait_s: 0.0,
        mean_latency_s: 0.0,
        mean_service_s: 0.0,
        max_shard_completed: 0,
        min_shard_completed: u64::MAX,
        incremental_appends: 0,
        incremental_rebuilds: 0,
    };
    for s in &shards {
        snap.routed_total += s.routed;
        // audit:allow(acct-invariant) rollup folds sampled live snapshots whose legs are read at different instants; drain paths assert the exact ledger
        snap.submitted += s.metrics.submitted;
        snap.completed += s.metrics.completed;
        snap.rejected += s.metrics.rejected;
        snap.shed += s.metrics.shed;
        snap.batches += s.metrics.batches;
        snap.remount_hits += s.metrics.remount_hits;
        snap.remount_misses += s.metrics.remount_misses;
        snap.cartridge_parks += s.metrics.cartridge_parks;
        snap.max_cartridge_wait_s =
            snap.max_cartridge_wait_s.max(s.metrics.max_cartridge_wait_s);
        snap.arm_ops += s.metrics.arm_ops;
        snap.max_arm_wait_s = snap.max_arm_wait_s.max(s.metrics.max_arm_wait_s);
        snap.max_shard_completed = snap.max_shard_completed.max(s.metrics.completed);
        snap.min_shard_completed = snap.min_shard_completed.min(s.metrics.completed);
        snap.incremental_appends += s.metrics.incremental_appends;
        snap.incremental_rebuilds += s.metrics.incremental_rebuilds;
    }
    if shards.is_empty() {
        snap.min_shard_completed = 0;
    }
    snap.mean_latency_s =
        weighted_mean(shards.iter().map(|s| (s.metrics.mean_latency_s, s.metrics.completed)));
    snap.mean_service_s =
        weighted_mean(shards.iter().map(|s| (s.metrics.mean_service_s, s.metrics.completed)));
    snap.mean_cartridge_wait_s = weighted_mean(
        shards.iter().map(|s| (s.metrics.mean_cartridge_wait_s, s.metrics.cartridge_parks)),
    );
    snap.mean_arm_wait_s =
        weighted_mean(shards.iter().map(|s| (s.metrics.mean_arm_wait_s, s.metrics.arm_ops)));
    snap.shards = shards;
    snap
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(submitted: u64, completed: u64, rejected: u64, lat: f64, svc: f64) -> MetricsSnapshot {
        MetricsSnapshot {
            submitted,
            completed,
            rejected,
            shed: 0,
            batches: completed / 2,
            remount_hits: completed / 4,
            remount_misses: completed / 2 - completed / 4,
            cartridge_parks: completed / 10,
            mean_cartridge_wait_s: 2.0,
            max_cartridge_wait_s: lat,
            arm_ops: completed / 5,
            mean_arm_wait_s: 0.5,
            max_arm_wait_s: svc,
            mean_latency_s: lat,
            mean_service_s: svc,
            mean_sched_s_per_batch: 0.0,
            p50_latency_s: lat,
            p99_latency_s: lat,
            incremental_appends: completed / 3,
            incremental_rebuilds: completed / 6,
        }
    }

    #[test]
    fn rollup_adds_counters_and_weights_means() {
        let snap = rollup(vec![
            ShardLoad { shard: 1, routed: 40, metrics: m(30, 30, 10, 4.0, 2.0) },
            ShardLoad { shard: 0, routed: 12, metrics: m(10, 10, 2, 1.0, 0.5) },
        ]);
        // Sorted by shard id regardless of input order.
        assert_eq!(snap.shards[0].shard, 0);
        assert_eq!(snap.shards[1].shard, 1);
        assert_eq!(snap.routed_total, 52);
        assert_eq!(snap.submitted, 40);
        assert_eq!(snap.completed, 40);
        assert_eq!(snap.rejected, 12);
        // Remount counters add like every other counter: (7+2) + (5+3).
        assert_eq!(snap.remount_hits, 30 / 4 + 10 / 4);
        assert_eq!(snap.remount_misses, (15 - 7) + (5 - 2));
        // Resource-wait rollups: counts add, means weight by their own
        // denominators, maxes take the fleet worst.
        assert_eq!(snap.cartridge_parks, 3 + 1);
        assert!((snap.mean_cartridge_wait_s - 2.0).abs() < 1e-12);
        assert!((snap.max_cartridge_wait_s - 4.0).abs() < 1e-12);
        assert_eq!(snap.arm_ops, 6 + 2);
        assert!((snap.mean_arm_wait_s - 0.5).abs() < 1e-12);
        assert!((snap.max_arm_wait_s - 2.0).abs() < 1e-12);
        // Weighted means: (30·4 + 10·1)/40 = 3.25; (30·2 + 10·0.5)/40.
        assert!((snap.mean_latency_s - 3.25).abs() < 1e-12);
        assert!((snap.mean_service_s - 1.625).abs() < 1e-12);
        assert_eq!(snap.max_shard_completed, 30);
        assert_eq!(snap.min_shard_completed, 10);
        assert_eq!(snap.incremental_appends, 10 + 3);
        assert_eq!(snap.incremental_rebuilds, 5 + 1);
        assert!((snap.imbalance_ratio() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn merge_adds_counters_weights_means_and_keeps_the_bigger_ladder() {
        let a = m(30, 30, 10, 4.0, 2.0);
        let b = m(10, 10, 2, 1.0, 0.5);
        let merged = merge_snapshots(&a, &b);
        assert_eq!(merged.submitted, 40);
        assert_eq!(merged.completed, 40);
        assert_eq!(merged.rejected, 12);
        assert_eq!(merged.batches, 15 + 5);
        assert_eq!(merged.cartridge_parks, 3 + 1);
        assert_eq!(merged.arm_ops, 6 + 2);
        assert_eq!(merged.incremental_appends, 10 + 3);
        assert_eq!(merged.incremental_rebuilds, 5 + 1);
        assert!((merged.mean_latency_s - 3.25).abs() < 1e-12);
        assert!((merged.mean_service_s - 1.625).abs() < 1e-12);
        assert!((merged.max_cartridge_wait_s - 4.0).abs() < 1e-12);
        // `a` has more completions: its percentile ladder survives.
        assert_eq!(merged.p50_latency_s, 4.0);
        // Merging the zero snapshot is the identity.
        assert_eq!(merge_snapshots(&a, &MetricsSnapshot::default()), a);
        assert_eq!(merge_snapshots(&MetricsSnapshot::default(), &a), a);
    }

    #[test]
    fn empty_and_idle_rollups_are_sane() {
        let empty = rollup(Vec::new());
        assert_eq!(empty.completed, 0);
        assert_eq!(empty.min_shard_completed, 0);
        assert_eq!(empty.imbalance_ratio(), 1.0);

        let idle = rollup(vec![
            ShardLoad { shard: 0, routed: 0, metrics: m(0, 0, 0, 0.0, 0.0) },
            ShardLoad { shard: 1, routed: 5, metrics: m(5, 5, 0, 2.0, 1.0) },
        ]);
        assert_eq!(idle.min_shard_completed, 0);
        assert_eq!(idle.imbalance_ratio(), f64::INFINITY);
    }

    #[test]
    fn empty_rollup_means_are_zero_not_nan() {
        let empty = rollup(Vec::new());
        for mean in [
            empty.mean_latency_s,
            empty.mean_service_s,
            empty.mean_cartridge_wait_s,
            empty.mean_arm_wait_s,
        ] {
            assert_eq!(mean, 0.0, "zero-weight means must be exactly 0.0, never NaN");
        }
    }

    #[test]
    fn single_shard_rollup_is_the_identity_on_means() {
        let only = m(20, 20, 3, 3.5, 1.25);
        let snap = rollup(vec![ShardLoad { shard: 2, routed: 23, metrics: only.clone() }]);
        assert!((snap.mean_latency_s - only.mean_latency_s).abs() < 1e-12);
        assert!((snap.mean_service_s - only.mean_service_s).abs() < 1e-12);
        assert!((snap.mean_cartridge_wait_s - only.mean_cartridge_wait_s).abs() < 1e-12);
        assert!((snap.mean_arm_wait_s - only.mean_arm_wait_s).abs() < 1e-12);
    }

    #[test]
    fn zero_weight_shards_never_pollute_the_weighted_means() {
        // A shard with zero completions but a garbage (nonzero) mean —
        // e.g. a synthesized dead-era snapshot — must contribute nothing:
        // its weight is zero, so the fleet means are the busy shard's.
        let mut ghost = m(4, 0, 0, 0.0, 0.0);
        ghost.mean_latency_s = 99.0;
        ghost.mean_service_s = 99.0;
        ghost.mean_cartridge_wait_s = 99.0;
        ghost.mean_arm_wait_s = 99.0;
        let busy = m(10, 10, 0, 2.0, 1.0);
        let snap = rollup(vec![
            ShardLoad { shard: 0, routed: 4, metrics: ghost.clone() },
            ShardLoad { shard: 1, routed: 10, metrics: busy.clone() },
        ]);
        assert!((snap.mean_latency_s - 2.0).abs() < 1e-12);
        assert!((snap.mean_service_s - 1.0).abs() < 1e-12);
        assert!((snap.mean_cartridge_wait_s - busy.mean_cartridge_wait_s).abs() < 1e-12);
        assert!((snap.mean_arm_wait_s - busy.mean_arm_wait_s).abs() < 1e-12);

        // All shards zero-weight: 0.0 across the board, never NaN.
        let all_idle = rollup(vec![
            ShardLoad { shard: 0, routed: 0, metrics: m(0, 0, 0, 0.0, 0.0) },
            ShardLoad { shard: 1, routed: 0, metrics: m(0, 0, 0, 0.0, 0.0) },
        ]);
        assert_eq!(all_idle.mean_latency_s, 0.0);
        assert_eq!(all_idle.mean_cartridge_wait_s, 0.0);
        // And merge shares the same helper, so the same holds pairwise.
        let merged = merge_snapshots(&MetricsSnapshot::default(), &MetricsSnapshot::default());
        assert_eq!(merged.mean_latency_s, 0.0);
        assert!(!merged.mean_sched_s_per_batch.is_nan());
    }

    #[test]
    fn weighted_mean_handles_empty_and_partial_weights() {
        assert_eq!(weighted_mean([]), 0.0);
        assert_eq!(weighted_mean([(5.0, 0)]), 0.0);
        assert!((weighted_mean([(4.0, 30), (1.0, 10)]) - 3.25).abs() < 1e-12);
        assert!((weighted_mean([(7.0, 0), (2.0, 8)]) - 2.0).abs() < 1e-12);
    }
}
