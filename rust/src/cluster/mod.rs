//! The multi-library cluster layer — many tape libraries behind one
//! consistent-hash router.
//!
//! The paper's evaluation logs come from a datacenter mass-storage system
//! that spans many tape libraries served concurrently; a single
//! [`crate::coordinator::Coordinator`] models one library. This subsystem
//! scales the serving layer out: requests are partitioned across
//! libraries *before* any per-device ordering runs — which is where
//! fleet-level service time is won or lost (Bachmat; Cardonha & Villa
//! Real) — by consistent-hashing tape names onto shards.
//!
//! ```text
//!                         ┌──────────────────────────────┐
//!   clients ──submit──▶   │  Cluster router (HashRing)   │
//!                         │  tape name ─▶ shard id       │
//!                         └──┬─────────┬─────────┬───────┘
//!                            ▼         ▼         ▼
//!                       [Coordinator][Coordinator][Coordinator]
//!                        library 0    library 1    library 2
//!                        (batcher +   (batcher +   (batcher +
//!                         drive pool)  drive pool)  drive pool)
//!                            │         │         │
//!                            └────┬────┴────┬────┘
//!                                 ▼         ▼
//!                        [ClusterMetricsSnapshot rollup]
//! ```
//!
//! - [`ring`] — the deterministic consistent-hash ring (virtual nodes,
//!   bounded key movement on shard add/remove).
//! - [`backend`] — the shard-addressing seam: [`ShardBackend`] abstracts
//!   "a shard" so it can be an in-process coordinator ([`LocalShard`]) or
//!   a TCP worker handle (`net::server`), routed over a [`ShardSet`].
//! - [`router`] — [`Cluster`]: N independent coordinators, per-shard
//!   `SubmitError::Busy` backpressure, live add/remove for rebalancing.
//! - [`metrics`] — per-shard loads + routing counters rolled up into one
//!   fleet snapshot; [`merge_snapshots`] stitches one shard's history
//!   across worker eras.
//!
//! The replay engine mirrors this layout in virtual time
//! ([`crate::replay`] with `ReplayConfig::n_shards > 1`): one batcher and
//! one simulated drive pool per shard behind the same ring, producing the
//! per-shard QoS breakdown in [`crate::replay::QosReport`].

pub mod backend;
pub mod metrics;
pub mod ring;
pub mod router;

pub use backend::{partition_catalog, LocalShard, ShardBackend, ShardSet};
pub use metrics::{merge_snapshots, rollup, weighted_mean, ClusterMetricsSnapshot, ShardLoad};
pub use ring::HashRing;
pub use router::{Cluster, ClusterConfig};
