//! The cluster front-end: N independent [`Coordinator`] shards behind one
//! consistent-hash router.
//!
//! Each shard is a full single-library coordinator (its own batcher,
//! dispatcher, and drive-worker pool) holding exactly the tapes the ring
//! routes to it. `submit` hashes the tape name, bumps the shard's routing
//! counter, and delegates — so every per-shard contract (validation,
//! `SubmitError::Busy` backpressure, drain-on-finish) holds unchanged at
//! the cluster level, per shard.
//!
//! Routing is two-stage: the ring picks the **library** (stage 1), then
//! inside the shard the coordinator's placement stage picks the **drive**
//! (stage 2) — under [`crate::sim::Affinity::Lru`] preferring a drive that
//! already holds the batch's tape, so a remount hit skips the mount
//! entirely. Per-shard `remount_hits`/`remount_misses` roll up in the
//! cluster [`ClusterMetricsSnapshot`] like every other counter.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::coordinator::{
    Completion, Coordinator, CoordinatorConfig, MetricsSnapshot, ReadRequest, SubmitError,
};
use crate::model::Tape;
use crate::replay::RequestSink;
use crate::sched::Scheduler;

use super::metrics::{rollup, ClusterMetricsSnapshot, ShardLoad};
use super::ring::HashRing;

/// Cluster configuration: the ring shape plus the per-shard coordinator
/// configuration — one `shard` template for homogeneous fleets, or one
/// entry per library in `shard_configs` for heterogeneous ones.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of library shards.
    pub n_shards: usize,
    /// Base virtual nodes per shard on the consistent-hash ring.
    pub vnodes: usize,
    /// Configuration applied to every shard's coordinator (homogeneous
    /// fleets; also the template `add_shard` uses for newcomers).
    pub shard: CoordinatorConfig,
    /// Heterogeneous fleets: one configuration per shard (length must be
    /// `n_shards`; empty = homogeneous, every shard uses `shard`). The
    /// ring is then **capacity-weighted** — each shard's vnode count is
    /// proportional to its drive count, so a library with more drives
    /// owns proportionally more tapes.
    pub shard_configs: Vec<CoordinatorConfig>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            n_shards: 4,
            vnodes: 64,
            shard: CoordinatorConfig::default(),
            shard_configs: Vec::new(),
        }
    }
}

/// The running multi-library cluster. Create with [`Cluster::start`], feed
/// with [`Cluster::submit`], stop with [`Cluster::finish`].
pub struct Cluster {
    cfg: ClusterConfig,
    ring: HashRing,
    /// Shard id → running coordinator (BTreeMap: ids stay sorted and
    /// stable across add/remove).
    shards: BTreeMap<usize, Coordinator>,
    /// Shard id → the configuration that shard runs (heterogeneous
    /// fleets; mirrors `cfg.shard` everywhere otherwise).
    configs: BTreeMap<usize, CoordinatorConfig>,
    /// Whether the ring is capacity-weighted by drive count (set when the
    /// cluster started heterogeneous).
    weighted: bool,
    /// Shard id → submissions routed there (accepted or rejected).
    routed: BTreeMap<usize, AtomicU64>,
    /// Master catalog, for re-registering tapes on membership changes.
    catalog: HashMap<String, Tape>,
    policy: Arc<dyn Scheduler + Send + Sync>,
}

impl Cluster {
    /// Start `cfg.n_shards` coordinators, partitioning `catalog` across
    /// them by consistent-hashing each tape's name. With per-shard
    /// configurations (`cfg.shard_configs`) the ring is capacity-weighted
    /// by drive count.
    pub fn start(
        cfg: ClusterConfig,
        catalog: impl IntoIterator<Item = Tape>,
        policy: Arc<dyn Scheduler + Send + Sync>,
    ) -> Cluster {
        assert!(cfg.n_shards > 0, "a cluster needs at least one shard");
        assert!(cfg.vnodes > 0, "a shard needs at least one virtual node");
        let weighted = !cfg.shard_configs.is_empty();
        if weighted {
            assert_eq!(
                cfg.shard_configs.len(),
                cfg.n_shards,
                "per-shard configs must cover every shard"
            );
        }
        let ring = if weighted {
            let weights: Vec<usize> =
                cfg.shard_configs.iter().map(|c| c.n_drives).collect();
            HashRing::new_weighted(&weights, cfg.vnodes)
        } else {
            HashRing::new(cfg.n_shards, cfg.vnodes)
        };
        let catalog: HashMap<String, Tape> =
            catalog.into_iter().map(|t| (t.name.clone(), t)).collect();
        let mut per_shard: BTreeMap<usize, Vec<Tape>> =
            ring.shard_ids().iter().map(|&s| (s, Vec::new())).collect();
        for tape in catalog.values() {
            per_shard.get_mut(&ring.route(&tape.name)).unwrap().push(tape.clone());
        }
        let configs: BTreeMap<usize, CoordinatorConfig> = ring
            .shard_ids()
            .iter()
            .map(|&s| {
                let c = if weighted { cfg.shard_configs[s].clone() } else { cfg.shard.clone() };
                (s, c)
            })
            .collect();
        let shards = per_shard
            .into_iter()
            .map(|(id, tapes)| {
                (id, Coordinator::start(configs[&id].clone(), tapes, Arc::clone(&policy)))
            })
            .collect();
        let routed =
            ring.shard_ids().iter().map(|&s| (s, AtomicU64::new(0))).collect();
        Cluster { cfg, ring, shards, configs, weighted, routed, catalog, policy }
    }

    /// Submit one read request: route by tape name, delegate to the owning
    /// shard. All of the coordinator's submit errors — including the
    /// [`SubmitError::Busy`] backpressure signal — propagate per shard, so
    /// one overloaded library sheds without touching its siblings.
    pub fn submit(&self, req: ReadRequest) -> Result<(), SubmitError> {
        let shard = self.ring.route(&req.tape);
        self.routed[&shard].fetch_add(1, Ordering::Relaxed);
        self.shards[&shard].submit(req)
    }

    /// Register a tape (or replace its entry) on the shard that owns it.
    pub fn register_tape(&mut self, tape: Tape) {
        let shard = self.ring.route(&tape.name);
        self.shards[&shard].register_tape(tape.clone());
        self.catalog.insert(tape.name.clone(), tape);
    }

    /// Add one shard for rebalancing experiments: a fresh coordinator
    /// joins the ring, and only the tapes whose arcs the newcomer stole
    /// move — registered on the new shard and deregistered from their
    /// previous owner, so old catalogs don't accumulate stale entries
    /// across membership changes. (A previous owner still draining queued
    /// requests for a moved tape keeps its entry until that backlog
    /// clears — `Coordinator::deregister_tape` refuses busy tapes; the
    /// router never routes new work there either way.) Returns
    /// `(shard id, tapes moved)`.
    pub fn add_shard(&mut self) -> (usize, usize) {
        let old_owner: Vec<(String, usize)> = self
            .catalog
            .keys()
            .map(|name| (name.clone(), self.ring.route(name)))
            .collect();
        // A weighted cluster weights the newcomer like its peers: by the
        // drive count of the template config it will run.
        let id = if self.weighted {
            self.ring.add_shard_weighted(self.cfg.shard.n_drives)
        } else {
            self.ring.add_shard()
        };
        let coord = Coordinator::start(
            self.cfg.shard.clone(),
            std::iter::empty::<Tape>(),
            Arc::clone(&self.policy),
        );
        let mut moved = 0;
        for (name, owner) in old_owner {
            if self.ring.route(&name) == id {
                coord.register_tape(self.catalog[&name].clone());
                self.shards[&owner].deregister_tape(&name);
                moved += 1;
            }
        }
        self.shards.insert(id, coord);
        self.configs.insert(id, self.cfg.shard.clone());
        self.routed.insert(id, AtomicU64::new(0));
        (id, moved)
    }

    /// Drain and remove one shard (bounded movement: only its tapes remap,
    /// each to the shard now owning its arc). Returns the drained shard's
    /// completions and final metrics, or `None` when the id is not live or
    /// is the last shard.
    pub fn remove_shard(&mut self, id: usize) -> Option<(Vec<Completion>, MetricsSnapshot)> {
        if self.shards.len() <= 1 || !self.shards.contains_key(&id) {
            return None;
        }
        // The departed shard's tapes, identified before the ring changes.
        let orphans: Vec<String> = self
            .catalog
            .keys()
            .filter(|name| self.ring.route(name.as_str()) == id)
            .cloned()
            .collect();
        let coord = self.shards.remove(&id).unwrap();
        self.ring.remove_shard(id);
        self.configs.remove(&id);
        self.routed.remove(&id);
        let drained = coord.finish();
        // Hand only those tapes to the shards now owning their arcs —
        // every other tape's registration is untouched.
        for name in orphans {
            let shard = self.ring.route(&name);
            self.shards[&shard].register_tape(self.catalog[&name].clone());
        }
        Some(drained)
    }

    /// The routing ring (read-only: spread diagnostics, shard ids).
    pub fn ring(&self) -> &HashRing {
        &self.ring
    }

    /// Number of live shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total drive workers across the cluster (summed per shard — shards
    /// may differ in a heterogeneous fleet).
    pub fn n_drives(&self) -> usize {
        self.configs.values().map(|c| c.n_drives).sum()
    }

    /// The configuration shard `id` runs, if live.
    pub fn shard_config(&self, id: usize) -> Option<&CoordinatorConfig> {
        self.configs.get(&id)
    }

    /// Current rollup of every shard's metrics plus routing counters.
    pub fn metrics(&self) -> ClusterMetricsSnapshot {
        let loads = self
            .shards
            .iter()
            .map(|(&id, coord)| ShardLoad {
                shard: id,
                routed: self.routed[&id].load(Ordering::Relaxed),
                metrics: coord.metrics(),
            })
            .collect();
        rollup(loads)
    }

    /// Drain every shard and join all threads; completions come back
    /// merged and sorted by request id, with the final cluster rollup.
    pub fn finish(self) -> (Vec<Completion>, ClusterMetricsSnapshot) {
        let Cluster { shards, routed, .. } = self;
        let mut completions = Vec::new();
        let mut loads = Vec::new();
        for (id, coord) in shards {
            let n_routed = routed.get(&id).map(|a| a.load(Ordering::Relaxed)).unwrap_or(0);
            let (mut c, m) = coord.finish();
            completions.append(&mut c);
            loads.push(ShardLoad { shard: id, routed: n_routed, metrics: m });
        }
        completions.sort_by_key(|c| c.request_id);
        (completions, rollup(loads))
    }
}

impl RequestSink for Cluster {
    fn submit_request(&self, req: ReadRequest) -> Result<(), SubmitError> {
        self.submit(req)
    }

    fn in_flight(&self) -> u64 {
        // A cluster's in-flight level is the sum of its shards', by the
        // coordinator's own definition of in-flight.
        self.shards.values().map(|c| c.in_flight()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BatcherConfig;
    use crate::sched::Gs;
    use crate::sim::{Affinity, DriveParams};
    use std::time::Duration;

    fn catalog(n: usize) -> Vec<Tape> {
        (0..n).map(|i| Tape::from_sizes(format!("TAPE{i:03}"), &[1_000; 20])).collect()
    }

    fn cfg(n_shards: usize) -> ClusterConfig {
        ClusterConfig {
            n_shards,
            vnodes: 64,
            shard: CoordinatorConfig {
                n_drives: 2,
                batcher: BatcherConfig {
                    window: Duration::from_millis(5),
                    max_batch: 64,
                    ..BatcherConfig::default()
                },
                drive: DriveParams {
                    mount_s: 1.0,
                    unmount_s: 0.5,
                    bytes_per_s: 1e6,
                    uturn_s: 0.001,
                    n_arms: 0,
                },
                affinity: Affinity::None,
                exclusive_tapes: true,
            },
            shard_configs: Vec::new(),
        }
    }

    #[test]
    fn routes_to_owning_shard_and_serves_everything() {
        let tapes = catalog(32);
        let cluster = Cluster::start(cfg(3), tapes.clone(), Arc::new(Gs));
        assert_eq!(cluster.n_shards(), 3);
        assert_eq!(cluster.n_drives(), 6);
        for i in 0..300u64 {
            let tape = &tapes[(i % 32) as usize].name;
            let req = ReadRequest { id: i, tape: tape.clone(), file_index: (i % 20) as usize };
            assert!(cluster.submit(req).is_ok());
        }
        let (completions, m) = cluster.finish();
        assert_eq!(completions.len(), 300);
        assert_eq!(m.completed, 300);
        assert_eq!(m.routed_total, 300);
        assert_eq!(m.shards.len(), 3);
        // Round-robin over 32 tapes: every shard owning tapes sees load.
        assert!(m.min_shard_completed > 0, "a shard served nothing: {m:?}");
        assert_eq!(m.shards.iter().map(|s| s.metrics.completed).sum::<u64>(), 300);
        // Completions come back sorted by request id.
        assert!(completions.windows(2).all(|w| w[0].request_id < w[1].request_id));
    }

    #[test]
    fn unknown_tape_fails_on_the_routed_shard() {
        let cluster = Cluster::start(cfg(2), catalog(8), Arc::new(Gs));
        assert_eq!(
            cluster.submit(ReadRequest { id: 1, tape: "NOPE".into(), file_index: 0 }),
            Err(SubmitError::UnknownTape)
        );
        let (completions, m) = cluster.finish();
        assert!(completions.is_empty());
        // The routing counter still ticked: routing happens before
        // validation, exactly like a front-end proxy.
        assert_eq!(m.routed_total, 1);
    }

    #[test]
    fn lru_affinity_remount_counters_roll_up() {
        // One tape, one drive per shard: wherever the ring homes the tape,
        // its four cap-split batches serialize through one drive — the
        // first mounts, the rest are remount hits. Deterministic.
        let mut config = cfg(2);
        config.shard.n_drives = 1;
        config.shard.affinity = Affinity::Lru;
        config.shard.batcher.window = Duration::from_secs(3600);
        config.shard.batcher.max_batch = 4;
        let tapes = catalog(1);
        let cluster = Cluster::start(config, tapes.clone(), Arc::new(Gs));
        for i in 0..16u64 {
            let req = ReadRequest {
                id: i,
                tape: tapes[0].name.clone(),
                file_index: (i % 20) as usize,
            };
            assert!(cluster.submit(req).is_ok());
        }
        let (completions, m) = cluster.finish();
        assert_eq!(completions.len(), 16);
        assert_eq!(m.batches, 4);
        assert_eq!(m.remount_misses, 1, "only the first batch mounts");
        assert_eq!(m.remount_hits, 3);
        assert_eq!(
            m.remount_hits,
            m.shards.iter().map(|s| s.metrics.remount_hits).sum::<u64>(),
            "the rollup is the per-shard sum"
        );
    }

    #[test]
    fn heterogeneous_shards_run_their_own_configs_on_a_weighted_ring() {
        // Shard 0: 1 drive; shard 1: 6 drives. The ring weights vnodes by
        // drive count, so the big library owns most of the catalog, and
        // n_drives() sums the actual per-shard pools.
        let mut config = cfg(2);
        let mut small = config.shard.clone();
        small.n_drives = 1;
        let mut big = config.shard.clone();
        big.n_drives = 6;
        config.shard_configs = vec![small, big];
        let tapes = catalog(48);
        let cluster = Cluster::start(config, tapes.clone(), Arc::new(Gs));
        assert_eq!(cluster.n_shards(), 2);
        assert_eq!(cluster.n_drives(), 7, "1 + 6 drives, not 2 × template");
        assert_eq!(cluster.shard_config(0).unwrap().n_drives, 1);
        assert_eq!(cluster.shard_config(1).unwrap().n_drives, 6);
        assert_eq!(cluster.ring().vnodes_of(0), 64);
        assert_eq!(cluster.ring().vnodes_of(1), 6 * 64);
        let spread = cluster.ring().spread();
        assert!(
            spread[1] > spread[0],
            "6× the drives must own more key space: {spread:?}"
        );
        // Every tape routes and serves wherever it landed.
        for (i, tape) in tapes.iter().enumerate() {
            let req =
                ReadRequest { id: i as u64, tape: tape.name.clone(), file_index: 0 };
            assert!(cluster.submit(req).is_ok(), "tape {} unroutable", tape.name);
        }
        let (completions, m) = cluster.finish();
        assert_eq!(completions.len(), 48);
        assert_eq!(m.completed, 48);
        assert_eq!(m.shards.len(), 2);
    }

    #[test]
    #[should_panic(expected = "per-shard configs must cover every shard")]
    fn mismatched_shard_config_count_is_rejected() {
        let mut config = cfg(3);
        config.shard_configs = vec![config.shard.clone()];
        Cluster::start(config, catalog(4), Arc::new(Gs));
    }

    #[test]
    fn add_shard_moves_tapes_and_keeps_serving() {
        let tapes = catalog(32);
        let mut cluster = Cluster::start(cfg(2), tapes.clone(), Arc::new(Gs));
        let (id, moved) = cluster.add_shard();
        assert_eq!(id, 2);
        assert!(moved < 32, "adding one shard must not move the whole catalog");
        assert_eq!(cluster.n_shards(), 3);
        // Every tape is still servable wherever it landed.
        for (i, tape) in tapes.iter().enumerate() {
            let req =
                ReadRequest { id: i as u64, tape: tape.name.clone(), file_index: 0 };
            assert!(cluster.submit(req).is_ok(), "tape {} unroutable", tape.name);
        }
        let (completions, m) = cluster.finish();
        assert_eq!(completions.len(), 32);
        assert_eq!(m.shards.len(), 3);
    }

    #[test]
    fn remove_shard_drains_and_rehomes_tapes() {
        let tapes = catalog(24);
        let mut cluster = Cluster::start(cfg(3), tapes.clone(), Arc::new(Gs));
        let victim = *cluster.ring().shard_ids().first().unwrap();
        let (_, drained_m) = cluster.remove_shard(victim).expect("live shard");
        assert_eq!(drained_m.submitted, 0);
        assert_eq!(cluster.n_shards(), 2);
        assert!(cluster.remove_shard(victim).is_none(), "already gone");
        for (i, tape) in tapes.iter().enumerate() {
            let req =
                ReadRequest { id: i as u64, tape: tape.name.clone(), file_index: 0 };
            assert!(cluster.submit(req).is_ok(), "tape {} unroutable", tape.name);
        }
        let (completions, _) = cluster.finish();
        assert_eq!(completions.len(), 24);
    }
}
