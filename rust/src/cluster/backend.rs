//! The shard-addressing seam: one trait for "a shard that serves routed
//! submits", whether it lives in this process or across a TCP connection.
//!
//! [`Cluster`](super::Cluster) predates this seam and keeps its concrete
//! `Coordinator` map because live rebalancing (add/remove shard, tape
//! rehoming) needs coordinator-specific operations. Everything the
//! *networked* topology needs, though — route a submit by the consistent-
//! hash ring, pull a [`MetricsSnapshot`], drain for completions — fits
//! behind [`ShardBackend`], so the coordinator process (`net::server`)
//! routes over a [`ShardSet`] whose backends are TCP worker handles, and
//! tests can mix [`LocalShard`]s (a real in-process `Coordinator`) with
//! remote ones without caring which is which.
//!
//! [`ShardSet`] implements [`RequestSink`], so the closed-loop driver
//! (`replay::drive_closed_loop`) feeds a backend-agnostic fleet exactly
//! like it feeds a single `Coordinator` or the in-process `Cluster`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::{
    Completion, Coordinator, MetricsSnapshot, ReadRequest, SubmitError,
};
use crate::model::Tape;
use crate::replay::RequestSink;

use super::metrics::ShardLoad;
use super::ring::HashRing;

/// One shard, local or remote: the minimal contract the routing layer
/// needs. `drain` is terminal — the first call returns the shard's
/// completions, later calls return an empty list with the final snapshot
/// (so a `ShardSet` drain is safe even if a caller already drained one
/// shard directly).
pub trait ShardBackend: Send + Sync {
    /// Submit under the coordinator's contract (including `Busy`
    /// backpressure); [`SubmitError::ShardDown`] when the shard has no
    /// live server behind it.
    fn submit(&self, req: ReadRequest) -> Result<(), SubmitError>;

    /// Current metrics snapshot (for a dead remote shard: the synthesized
    /// accounting of its lost work).
    fn metrics(&self) -> MetricsSnapshot;

    /// Stop accepting, flush, and hand back completions + final metrics.
    fn drain(&self) -> (Vec<Completion>, MetricsSnapshot);
}

enum LocalState {
    Live(Coordinator),
    Drained(MetricsSnapshot),
}

/// A [`ShardBackend`] wrapping an in-process [`Coordinator`] — the
/// `Local(Coordinator)` arm of the seam, used by loopback tests and as
/// the reference behavior remote shards must match.
pub struct LocalShard {
    state: Mutex<LocalState>,
}

impl LocalShard {
    pub fn new(coordinator: Coordinator) -> LocalShard {
        LocalShard { state: Mutex::new(LocalState::Live(coordinator)) }
    }
}

impl ShardBackend for LocalShard {
    fn submit(&self, req: ReadRequest) -> Result<(), SubmitError> {
        match &*self.state.lock().unwrap() {
            LocalState::Live(c) => c.submit(req),
            LocalState::Drained(_) => Err(SubmitError::Stopping),
        }
    }

    fn metrics(&self) -> MetricsSnapshot {
        match &*self.state.lock().unwrap() {
            LocalState::Live(c) => c.metrics(),
            LocalState::Drained(m) => m.clone(),
        }
    }

    fn drain(&self) -> (Vec<Completion>, MetricsSnapshot) {
        let mut state = self.state.lock().unwrap();
        // Swap in a placeholder snapshot first so a poisoned finish can't
        // leave the state torn; replace it with the real one after.
        match std::mem::replace(&mut *state, LocalState::Drained(MetricsSnapshot::default()))
        {
            LocalState::Live(c) => {
                let (completions, m) = c.finish();
                *state = LocalState::Drained(m.clone());
                (completions, m)
            }
            LocalState::Drained(m) => {
                *state = LocalState::Drained(m.clone());
                (Vec::new(), m)
            }
        }
    }
}

/// Split a catalog into per-shard partitions by ring routing — the same
/// placement rule [`Cluster::start`](super::Cluster::start) applies, so a
/// networked fleet and an in-process cluster over the same catalog and
/// ring agree on which shard owns every tape.
pub fn partition_catalog(
    ring: &HashRing,
    tapes: impl IntoIterator<Item = Tape>,
) -> BTreeMap<usize, Vec<Tape>> {
    let mut parts: BTreeMap<usize, Vec<Tape>> =
        ring.shard_ids().iter().map(|&id| (id, Vec::new())).collect();
    for tape in tapes {
        let shard = ring.route(&tape.name);
        parts.entry(shard).or_default().push(tape);
    }
    parts
}

/// The extracted routing layer: a consistent-hash ring over abstract
/// [`ShardBackend`]s with per-shard routing counters. This is the shape
/// the networked coordinator serves clients through.
pub struct ShardSet {
    ring: HashRing,
    shards: BTreeMap<usize, Arc<dyn ShardBackend>>,
    routed: BTreeMap<usize, AtomicU64>,
}

impl ShardSet {
    /// An empty set over `ring`; attach one backend per ring shard id
    /// with [`ShardSet::attach`] before submitting.
    pub fn new(ring: HashRing) -> ShardSet {
        ShardSet { ring, shards: BTreeMap::new(), routed: BTreeMap::new() }
    }

    /// Attach (or replace) the backend serving shard `id`. The routed
    /// counter survives replacement — routing history belongs to the
    /// shard, not to whichever process currently serves it.
    pub fn attach(&mut self, id: usize, backend: Arc<dyn ShardBackend>) {
        assert!(
            self.ring.shard_ids().contains(&id),
            "attaching backend for shard {id} not on the ring"
        );
        self.shards.insert(id, backend);
        self.routed.entry(id).or_insert_with(|| AtomicU64::new(0));
    }

    pub fn ring(&self) -> &HashRing {
        &self.ring
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Which shard a tape routes to.
    pub fn route(&self, tape: &str) -> usize {
        self.ring.route(tape)
    }

    /// Route a submit to its owning shard.
    pub fn submit(&self, req: ReadRequest) -> Result<(), SubmitError> {
        let id = self.ring.route(&req.tape);
        let shard = self.shards.get(&id).expect("every ring shard has a backend");
        self.routed[&id].fetch_add(1, Ordering::Relaxed);
        shard.submit(req)
    }

    /// Per-shard loads (fresh snapshots), in shard-id order.
    pub fn loads(&self) -> Vec<ShardLoad> {
        self.shards
            .iter()
            .map(|(&id, shard)| ShardLoad {
                shard: id,
                routed: self.routed[&id].load(Ordering::Relaxed),
                metrics: shard.metrics(),
            })
            .collect()
    }

    /// Drain every shard: completions merged and sorted by request id
    /// (deterministic across shard interleavings), plus the final loads.
    pub fn drain(&self) -> (Vec<Completion>, Vec<ShardLoad>) {
        let mut completions = Vec::new();
        let mut loads = Vec::new();
        for (&id, shard) in &self.shards {
            let (cs, m) = shard.drain();
            completions.extend(cs);
            loads.push(ShardLoad {
                shard: id,
                routed: self.routed[&id].load(Ordering::Relaxed),
                metrics: m,
            });
        }
        completions.sort_by_key(|c| c.request_id);
        (completions, loads)
    }
}

impl RequestSink for ShardSet {
    fn submit_request(&self, req: ReadRequest) -> Result<(), SubmitError> {
        self.submit(req)
    }

    fn in_flight(&self) -> u64 {
        // Shed requests never complete; a dead shard's synthesized
        // snapshot sheds everything it had accepted, so the fleet-wide
        // in-flight level cannot wedge a gating caller.
        self.shards
            .values()
            .map(|s| {
                let m = s.metrics();
                m.submitted.saturating_sub(m.completed + m.shed)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, CoordinatorConfig};
    use crate::sched::Gs;
    use std::time::Duration;

    fn local_shard(tapes: &[Tape]) -> Arc<LocalShard> {
        Arc::new(LocalShard::new(Coordinator::start(
            CoordinatorConfig {
                n_drives: 2,
                batcher: BatcherConfig {
                    window: Duration::from_millis(2),
                    max_batch: 64,
                    ..BatcherConfig::default()
                },
                ..CoordinatorConfig::default()
            },
            tapes.iter().cloned(),
            Arc::new(Gs),
        )))
    }

    #[test]
    fn shard_set_routes_serves_and_drains_deterministically() {
        let tapes: Vec<Tape> =
            (0..6).map(|i| Tape::from_sizes(&format!("TAPE{i:03}"), &[1_000; 20])).collect();
        let ring = HashRing::new(2, 64);
        let parts = partition_catalog(&ring, tapes.iter().cloned());
        assert_eq!(parts.keys().copied().collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(parts.values().map(|p| p.len()).sum::<usize>(), tapes.len());

        let mut set = ShardSet::new(ring);
        for (&id, part) in &parts {
            set.attach(id, local_shard(part));
        }
        for (i, tape) in tapes.iter().cycle().take(60).enumerate() {
            let req = ReadRequest {
                id: i as u64,
                tape: tape.name.clone(),
                file_index: i % 20,
            };
            assert!(set.submit(req).is_ok());
        }
        let loads = set.loads();
        assert_eq!(loads.len(), 2);
        assert_eq!(loads.iter().map(|l| l.routed).sum::<u64>(), 60);
        let (completions, final_loads) = set.drain();
        assert_eq!(completions.len(), 60);
        assert!(completions.windows(2).all(|w| w[0].request_id < w[1].request_id));
        assert_eq!(final_loads.iter().map(|l| l.metrics.completed).sum::<u64>(), 60);
        assert_eq!(set.in_flight(), 0);
        // Terminal: draining again yields no completions, and submits are
        // refused as stopping.
        let (again, _) = set.drain();
        assert!(again.is_empty());
        assert_eq!(
            set.submit(ReadRequest { id: 999, tape: tapes[0].name.clone(), file_index: 0 }),
            Err(SubmitError::Stopping)
        );
    }

    #[test]
    fn partition_agrees_with_ring_routing() {
        let ring = HashRing::new(3, 32);
        let tapes: Vec<Tape> =
            (0..20).map(|i| Tape::from_sizes(&format!("T{i}"), &[100])).collect();
        let parts = partition_catalog(&ring, tapes.iter().cloned());
        for (id, part) in &parts {
            for t in part {
                assert_eq!(ring.route(&t.name), *id);
            }
        }
    }
}
