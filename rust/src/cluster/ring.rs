//! Consistent-hash ring over tape names.
//!
//! Tapes are the unit of placement: a tape lives in exactly one library
//! (shard), because a cartridge can only be mounted by drives of the
//! library that physically holds it. The ring maps tape *names* onto
//! shards through the classic virtual-node construction: every shard owns
//! `vnodes` pseudo-random points on a `u64` circle, and a key routes to
//! the shard owning the first point at or after the key's hash (wrapping).
//!
//! Properties the rest of the crate builds on:
//!
//! - **Determinism** — points and key hashes come from
//!   [`crate::util::hash::stable_hash64`] (no per-process seeding), so the
//!   same construction sequence routes every key identically across runs,
//!   processes, and platforms. Replay reports stay byte-reproducible.
//! - **Bounded movement** — adding a shard to an `N`-shard ring only
//!   *steals* arcs for the new shard: every remapped key moves **to** the
//!   newcomer, and in expectation only `keys/(N+1)` keys move (the vnode
//!   count controls the variance). Removing a shard only remaps the keys
//!   it owned. Both are exercised by `tests/cluster.rs`.
//! - **Stable shard ids** — ids are assigned by a monotone counter and
//!   survive unrelated add/remove operations, so per-shard metrics can be
//!   tracked across membership changes.
//! - **Capacity weighting** — a shard may own a *multiple* of the base
//!   vnode count ([`HashRing::new_weighted`] /
//!   [`HashRing::add_shard_weighted`]): a library with `w×` the drives
//!   gets `w×` the points and so, in expectation, `w×` the key space.
//!   Weight-1 construction is bit-identical to the unweighted ring (the
//!   vnode labels are shared), so homogeneous routing never changes.

use std::collections::BTreeMap;

use crate::util::hash::stable_hash64;

/// A consistent-hash ring: `vnodes · weight` points per shard on the
/// `u64` circle.
#[derive(Debug, Clone)]
pub struct HashRing {
    vnodes: usize,
    /// Ring points sorted by `(point, shard)`; ties (astronomically rare)
    /// break toward the smaller shard id, deterministically.
    points: Vec<(u64, usize)>,
    /// Live shard ids, in id order (ids are assigned monotonically).
    shard_ids: Vec<usize>,
    /// Vnode count per live shard (`vnodes · weight` at add time).
    shard_vnodes: BTreeMap<usize, usize>,
    next_shard: usize,
}

impl HashRing {
    /// A fresh ring with shards `0..n_shards`, each owning `vnodes` points.
    pub fn new(n_shards: usize, vnodes: usize) -> HashRing {
        assert!(n_shards > 0, "a ring needs at least one shard");
        let mut ring = HashRing::empty(vnodes);
        for _ in 0..n_shards {
            ring.add_shard();
        }
        ring
    }

    /// A capacity-weighted ring: shard `i` owns `vnodes · weights[i]`
    /// points, so key space follows capacity (e.g. pass each library's
    /// drive count). `new_weighted(&[1; n], v)` routes identically to
    /// `new(n, v)`.
    pub fn new_weighted(weights: &[usize], vnodes: usize) -> HashRing {
        assert!(!weights.is_empty(), "a ring needs at least one shard");
        let mut ring = HashRing::empty(vnodes);
        for &w in weights {
            ring.add_shard_weighted(w);
        }
        ring
    }

    fn empty(vnodes: usize) -> HashRing {
        assert!(vnodes > 0, "a shard needs at least one virtual node");
        HashRing {
            vnodes,
            points: Vec::new(),
            shard_ids: Vec::new(),
            shard_vnodes: BTreeMap::new(),
            next_shard: 0,
        }
    }

    /// Add one shard; returns its id. Only keys landing on the new shard's
    /// arcs move — everything else keeps its owner (bounded key movement).
    pub fn add_shard(&mut self) -> usize {
        self.add_shard_weighted(1)
    }

    /// Add one shard with `weight × vnodes` points (capacity weighting);
    /// returns its id. Weight 1 is exactly [`HashRing::add_shard`].
    pub fn add_shard_weighted(&mut self, weight: usize) -> usize {
        assert!(weight > 0, "a shard needs a positive capacity weight");
        let id = self.next_shard;
        self.next_shard += 1;
        self.shard_ids.push(id);
        let n_points = self.vnodes * weight;
        self.shard_vnodes.insert(id, n_points);
        // Append-then-sort rather than per-point sorted inserts: weighting
        // multiplies the point count by the drive count, and P sorted
        // inserts are O(P²) in memmoves. One sort yields the identical
        // ring — points are unique `(hash, id)` pairs, so the order is
        // exactly the old insert-before-first-≥ order.
        self.points.reserve(n_points);
        for v in 0..n_points {
            self.points
                .push((stable_hash64(format!("shard{id}:vnode{v}").as_bytes()), id));
        }
        self.points.sort_unstable();
        id
    }

    /// Remove a shard (its keys redistribute to the arcs' successors).
    /// Returns `false` when the id is not live. The last shard cannot be
    /// removed — the ring would route nothing.
    pub fn remove_shard(&mut self, id: usize) -> bool {
        let Some(pos) = self.shard_ids.iter().position(|&s| s == id) else {
            return false;
        };
        assert!(self.shard_ids.len() > 1, "cannot remove the last shard");
        self.shard_ids.remove(pos);
        self.shard_vnodes.remove(&id);
        self.points.retain(|&(_, s)| s != id);
        true
    }

    /// Route a key (a tape name) to its owning shard id.
    pub fn route(&self, key: &str) -> usize {
        let h = stable_hash64(key.as_bytes());
        let i = self.points.partition_point(|&(p, _)| p < h);
        let idx = if i == self.points.len() { 0 } else { i };
        self.points[idx].1
    }

    /// Live shard ids, ascending.
    pub fn shard_ids(&self) -> &[usize] {
        &self.shard_ids
    }

    /// Number of live shards.
    pub fn n_shards(&self) -> usize {
        self.shard_ids.len()
    }

    /// Base virtual-node count (a weight-1 shard's point count).
    pub fn vnodes_per_shard(&self) -> usize {
        self.vnodes
    }

    /// Ring points shard `id` currently owns (`vnodes · weight`), or 0
    /// for a dead shard.
    pub fn vnodes_of(&self, id: usize) -> usize {
        self.shard_vnodes.get(&id).copied().unwrap_or(0)
    }

    /// Fraction of the `u64` key space owned per live shard, aligned with
    /// [`HashRing::shard_ids`]. Sums to 1; the per-shard deviation from
    /// `1/n` is the ring's intrinsic imbalance (shrinks like `1/√vnodes`).
    pub fn spread(&self) -> Vec<f64> {
        if self.points.len() == 1 {
            return vec![1.0];
        }
        let mut owned: Vec<u128> = vec![0; self.shard_ids.len()];
        let slot: std::collections::BTreeMap<usize, usize> =
            self.shard_ids.iter().enumerate().map(|(i, &s)| (s, i)).collect();
        for (i, &(p, s)) in self.points.iter().enumerate() {
            let prev =
                if i == 0 { self.points[self.points.len() - 1].0 } else { self.points[i - 1].0 };
            // The arc (prev, p] belongs to this point's shard; wrapping
            // subtraction makes the arcs sum to exactly 2^64.
            owned[slot[&s]] += p.wrapping_sub(prev) as u128;
        }
        owned.into_iter().map(|o| o as f64 / 2f64.powi(64)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_every_key_to_a_live_shard() {
        let ring = HashRing::new(4, 64);
        assert_eq!(ring.shard_ids(), &[0, 1, 2, 3]);
        assert_eq!(ring.n_shards(), 4);
        assert_eq!(ring.vnodes_per_shard(), 64);
        for i in 0..1_000 {
            let s = ring.route(&format!("TAPE{i:04}"));
            assert!(s < 4, "routed to dead shard {s}");
        }
    }

    #[test]
    fn routing_is_deterministic_across_constructions() {
        let a = HashRing::new(5, 32);
        let b = HashRing::new(5, 32);
        for i in 0..500 {
            let key = format!("K{i}");
            assert_eq!(a.route(&key), b.route(&key));
        }
    }

    #[test]
    fn single_shard_owns_everything() {
        let ring = HashRing::new(1, 8);
        for i in 0..100 {
            assert_eq!(ring.route(&format!("T{i}")), 0);
        }
        let spread = ring.spread();
        assert_eq!(spread.len(), 1);
        assert!((spread[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spread_sums_to_one() {
        let ring = HashRing::new(4, 128);
        let spread = ring.spread();
        assert_eq!(spread.len(), 4);
        let total: f64 = spread.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "spread sums to {total}");
        for (i, s) in spread.iter().enumerate() {
            assert!(*s > 0.0, "shard {i} owns nothing");
        }
    }

    #[test]
    fn weight_one_weighted_ring_routes_like_the_unweighted_ring() {
        let a = HashRing::new(4, 64);
        let b = HashRing::new_weighted(&[1, 1, 1, 1], 64);
        for i in 0..2_000 {
            let key = format!("TAPE{i:04}");
            assert_eq!(a.route(&key), b.route(&key), "weight 1 must not move keys");
        }
        assert_eq!(a.spread(), b.spread());
    }

    #[test]
    fn capacity_weights_scale_key_space_ownership() {
        // Weights 1 : 8 (64 vs 512 points): the heavy shard must own the
        // bulk of the circle, and routing must follow.
        let ring = HashRing::new_weighted(&[1, 8], 64);
        assert_eq!(ring.vnodes_of(0), 64);
        assert_eq!(ring.vnodes_of(1), 512);
        let spread = ring.spread();
        assert!((spread.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(
            spread[1] > 2.0 * spread[0],
            "weight 8 owns {:.3} vs weight 1's {:.3}",
            spread[1],
            spread[0]
        );
        let mut counts = [0usize; 2];
        for i in 0..5_000 {
            counts[ring.route(&format!("TAPE{i:05}"))] += 1;
        }
        assert!(
            counts[1] > 2 * counts[0],
            "routing must follow capacity: {counts:?}"
        );
    }

    #[test]
    fn weighted_membership_changes_keep_bounded_movement() {
        let keys: Vec<String> = (0..3_000).map(|i| format!("K{i}")).collect();
        let mut ring = HashRing::new_weighted(&[2, 4], 32);
        let before: Vec<usize> = keys.iter().map(|k| ring.route(k)).collect();
        let id = ring.add_shard_weighted(3);
        assert_eq!(id, 2);
        assert_eq!(ring.vnodes_of(id), 96);
        let after: Vec<usize> = keys.iter().map(|k| ring.route(k)).collect();
        for (i, (b, a)) in before.iter().zip(&after).enumerate() {
            assert!(
                a == b || *a == id,
                "key {i} moved between surviving shards ({b} → {a})"
            );
        }
        // Removing the newcomer restores the original routing exactly.
        assert!(ring.remove_shard(id));
        assert_eq!(ring.vnodes_of(id), 0);
        let restored: Vec<usize> = keys.iter().map(|k| ring.route(k)).collect();
        assert_eq!(before, restored);
    }

    #[test]
    fn shard_ids_survive_membership_changes() {
        let mut ring = HashRing::new(3, 16);
        assert!(ring.remove_shard(1));
        assert!(!ring.remove_shard(1), "already removed");
        assert_eq!(ring.shard_ids(), &[0, 2]);
        let id = ring.add_shard();
        assert_eq!(id, 3, "ids are monotone, never recycled");
        assert_eq!(ring.shard_ids(), &[0, 2, 3]);
        for i in 0..200 {
            let s = ring.route(&format!("T{i}"));
            assert!(ring.shard_ids().contains(&s));
        }
    }
}
