//! Consistent-hash ring over tape names.
//!
//! Tapes are the unit of placement: a tape lives in exactly one library
//! (shard), because a cartridge can only be mounted by drives of the
//! library that physically holds it. The ring maps tape *names* onto
//! shards through the classic virtual-node construction: every shard owns
//! `vnodes` pseudo-random points on a `u64` circle, and a key routes to
//! the shard owning the first point at or after the key's hash (wrapping).
//!
//! Properties the rest of the crate builds on:
//!
//! - **Determinism** — points and key hashes come from
//!   [`crate::util::hash::stable_hash64`] (no per-process seeding), so the
//!   same construction sequence routes every key identically across runs,
//!   processes, and platforms. Replay reports stay byte-reproducible.
//! - **Bounded movement** — adding a shard to an `N`-shard ring only
//!   *steals* arcs for the new shard: every remapped key moves **to** the
//!   newcomer, and in expectation only `keys/(N+1)` keys move (the vnode
//!   count controls the variance). Removing a shard only remaps the keys
//!   it owned. Both are exercised by `tests/cluster.rs`.
//! - **Stable shard ids** — ids are assigned by a monotone counter and
//!   survive unrelated add/remove operations, so per-shard metrics can be
//!   tracked across membership changes.

use crate::util::hash::stable_hash64;

/// A consistent-hash ring: `vnodes` points per shard on the `u64` circle.
#[derive(Debug, Clone)]
pub struct HashRing {
    vnodes: usize,
    /// Ring points sorted by `(point, shard)`; ties (astronomically rare)
    /// break toward the smaller shard id, deterministically.
    points: Vec<(u64, usize)>,
    /// Live shard ids, in id order (ids are assigned monotonically).
    shard_ids: Vec<usize>,
    next_shard: usize,
}

impl HashRing {
    /// A fresh ring with shards `0..n_shards`, each owning `vnodes` points.
    pub fn new(n_shards: usize, vnodes: usize) -> HashRing {
        assert!(n_shards > 0, "a ring needs at least one shard");
        assert!(vnodes > 0, "a shard needs at least one virtual node");
        let mut ring = HashRing {
            vnodes,
            points: Vec::with_capacity(n_shards * vnodes),
            shard_ids: Vec::with_capacity(n_shards),
            next_shard: 0,
        };
        for _ in 0..n_shards {
            ring.add_shard();
        }
        ring
    }

    /// Add one shard; returns its id. Only keys landing on the new shard's
    /// arcs move — everything else keeps its owner (bounded key movement).
    pub fn add_shard(&mut self) -> usize {
        let id = self.next_shard;
        self.next_shard += 1;
        self.shard_ids.push(id);
        for v in 0..self.vnodes {
            let entry = (stable_hash64(format!("shard{id}:vnode{v}").as_bytes()), id);
            let pos = self.points.partition_point(|&p| p < entry);
            self.points.insert(pos, entry);
        }
        id
    }

    /// Remove a shard (its keys redistribute to the arcs' successors).
    /// Returns `false` when the id is not live. The last shard cannot be
    /// removed — the ring would route nothing.
    pub fn remove_shard(&mut self, id: usize) -> bool {
        let Some(pos) = self.shard_ids.iter().position(|&s| s == id) else {
            return false;
        };
        assert!(self.shard_ids.len() > 1, "cannot remove the last shard");
        self.shard_ids.remove(pos);
        self.points.retain(|&(_, s)| s != id);
        true
    }

    /// Route a key (a tape name) to its owning shard id.
    pub fn route(&self, key: &str) -> usize {
        let h = stable_hash64(key.as_bytes());
        let i = self.points.partition_point(|&(p, _)| p < h);
        let idx = if i == self.points.len() { 0 } else { i };
        self.points[idx].1
    }

    /// Live shard ids, ascending.
    pub fn shard_ids(&self) -> &[usize] {
        &self.shard_ids
    }

    /// Number of live shards.
    pub fn n_shards(&self) -> usize {
        self.shard_ids.len()
    }

    /// Virtual nodes per shard.
    pub fn vnodes_per_shard(&self) -> usize {
        self.vnodes
    }

    /// Fraction of the `u64` key space owned per live shard, aligned with
    /// [`HashRing::shard_ids`]. Sums to 1; the per-shard deviation from
    /// `1/n` is the ring's intrinsic imbalance (shrinks like `1/√vnodes`).
    pub fn spread(&self) -> Vec<f64> {
        if self.points.len() == 1 {
            return vec![1.0];
        }
        let mut owned: Vec<u128> = vec![0; self.shard_ids.len()];
        let slot: std::collections::BTreeMap<usize, usize> =
            self.shard_ids.iter().enumerate().map(|(i, &s)| (s, i)).collect();
        for (i, &(p, s)) in self.points.iter().enumerate() {
            let prev =
                if i == 0 { self.points[self.points.len() - 1].0 } else { self.points[i - 1].0 };
            // The arc (prev, p] belongs to this point's shard; wrapping
            // subtraction makes the arcs sum to exactly 2^64.
            owned[slot[&s]] += p.wrapping_sub(prev) as u128;
        }
        owned.into_iter().map(|o| o as f64 / 2f64.powi(64)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_every_key_to_a_live_shard() {
        let ring = HashRing::new(4, 64);
        assert_eq!(ring.shard_ids(), &[0, 1, 2, 3]);
        assert_eq!(ring.n_shards(), 4);
        assert_eq!(ring.vnodes_per_shard(), 64);
        for i in 0..1_000 {
            let s = ring.route(&format!("TAPE{i:04}"));
            assert!(s < 4, "routed to dead shard {s}");
        }
    }

    #[test]
    fn routing_is_deterministic_across_constructions() {
        let a = HashRing::new(5, 32);
        let b = HashRing::new(5, 32);
        for i in 0..500 {
            let key = format!("K{i}");
            assert_eq!(a.route(&key), b.route(&key));
        }
    }

    #[test]
    fn single_shard_owns_everything() {
        let ring = HashRing::new(1, 8);
        for i in 0..100 {
            assert_eq!(ring.route(&format!("T{i}")), 0);
        }
        let spread = ring.spread();
        assert_eq!(spread.len(), 1);
        assert!((spread[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spread_sums_to_one() {
        let ring = HashRing::new(4, 128);
        let spread = ring.spread();
        assert_eq!(spread.len(), 4);
        let total: f64 = spread.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "spread sums to {total}");
        for (i, s) in spread.iter().enumerate() {
            assert!(*s > 0.0, "shard {i} owns nothing");
        }
    }

    #[test]
    fn shard_ids_survive_membership_changes() {
        let mut ring = HashRing::new(3, 16);
        assert!(ring.remove_shard(1));
        assert!(!ring.remove_shard(1), "already removed");
        assert_eq!(ring.shard_ids(), &[0, 2]);
        let id = ring.add_shard();
        assert_eq!(id, 3, "ids are monotone, never recycled");
        assert_eq!(ring.shard_ids(), &[0, 2, 3]);
        for i in 0..200 {
            let s = ring.route(&format!("T{i}"));
            assert!(ring.shard_ids().contains(&s));
        }
    }
}
