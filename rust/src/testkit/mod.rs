//! Property-testing support (the offline registry has no proptest): random
//! instance generators over a deterministic PRNG, plus a tiny case-runner
//! that reports the seed of a failing case so it can be replayed.

use crate::model::{Instance, ReqFile};
use crate::util::rng::Rng;

/// Knobs for random instance generation.
#[derive(Debug, Clone, Copy)]
pub struct InstanceGenConfig {
    pub min_files: usize,
    pub max_files: usize,
    /// Max file size (sizes uniform in 1..=max).
    pub max_size: u64,
    /// Max gap before each file (uniform in 0..=max).
    pub max_gap: u64,
    /// Max request multiplicity (log-uniform-ish in 1..=max).
    pub max_x: u64,
    /// Max U-turn penalty (uniform in 0..=max).
    pub max_u: u64,
}

impl Default for InstanceGenConfig {
    fn default() -> Self {
        InstanceGenConfig {
            min_files: 1,
            max_files: 8,
            max_size: 50,
            max_gap: 30,
            max_x: 20,
            max_u: 40,
        }
    }
}

/// Generate a random valid instance.
pub fn random_instance(rng: &mut Rng, cfg: &InstanceGenConfig) -> Instance {
    let k = rng.range(cfg.min_files as u64, cfg.max_files as u64) as usize;
    let mut files = Vec::with_capacity(k);
    let mut pos = 0u64;
    for _ in 0..k {
        pos += rng.range(0, cfg.max_gap);
        let size = rng.range(1, cfg.max_size);
        // Multiplicity skewed toward small values, occasionally large.
        let x = if rng.f64() < 0.8 {
            rng.range(1, 4.min(cfg.max_x))
        } else {
            rng.range(1, cfg.max_x)
        };
        files.push(ReqFile { l: pos, r: pos + size, x });
        pos += size;
    }
    let tail = rng.range(0, cfg.max_gap);
    let u = rng.range(0, cfg.max_u);
    Instance::new(pos + tail, u, files).expect("generator produces valid instances")
}

/// Run `n_cases` random cases; on failure, panic with the replay seed.
pub fn check_cases(
    base_seed: u64,
    n_cases: u64,
    cfg: &InstanceGenConfig,
    prop: impl Fn(&Instance),
) {
    for case in 0..n_cases {
        let seed = base_seed ^ case.wrapping_mul(0xA076_1D64_78BD_642F);
        let mut rng = Rng::new(seed);
        let inst = random_instance(&mut rng, cfg);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&inst)));
        if let Err(e) = result {
            eprintln!(
                "testkit: case {case} FAILED (seed={seed:#x})\ninstance: {:?}",
                inst
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_instances_are_valid_and_varied() {
        let mut rng = Rng::new(1);
        let cfg = InstanceGenConfig::default();
        let mut ks = std::collections::HashSet::new();
        for _ in 0..200 {
            let inst = random_instance(&mut rng, &cfg);
            assert!(inst.k() >= 1 && inst.k() <= 8);
            ks.insert(inst.k());
        }
        assert!(ks.len() >= 5, "size diversity: {ks:?}");
    }

    #[test]
    fn check_cases_reports_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            check_cases(42, 10, &InstanceGenConfig::default(), |inst| {
                assert!(inst.k() == 0, "always fails");
            });
        });
        assert!(r.is_err());
    }
}
