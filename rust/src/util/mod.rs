//! Small self-contained utilities (the offline crate registry provides no
//! rand / fxhash / itertools — we carry our own).

pub mod hash;
pub mod rng;
pub mod stats;
pub mod sync;

/// Convert seconds to the virtual-time unit (integer µs, rounded to
/// nearest, negatives clamped to zero). This is the **one** µs-grid
/// rounding rule — shared by the replay clock, the drive mount-cost
/// helpers, and the batcher's µs service accounting. Byte-deterministic
/// replays depend on these call sites never diverging, so they all
/// delegate here.
#[inline]
pub fn secs_to_us(s: f64) -> u64 {
    (s.max(0.0) * 1e6).round() as u64
}
