//! Small self-contained utilities (the offline crate registry provides no
//! rand / fxhash / itertools — we carry our own).

pub mod hash;
pub mod rng;
pub mod stats;
